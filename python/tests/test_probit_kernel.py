"""L1 correctness: Pallas probit kernels vs the jnp oracle and vs
quadrature; hypothesis sweeps the cavity-parameter space."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels import probit  # noqa: E402


def test_moments_match_ref_fixed():
    rng = np.random.default_rng(0)
    n = 256
    y = jnp.asarray(rng.choice([-1.0, 1.0], size=n))
    mu = jnp.asarray(rng.normal(0, 2, size=n))
    var = jnp.asarray(rng.uniform(0.05, 5.0, size=n))
    got = probit.probit_moments(y, mu, var)
    want = probit.probit_moments_reference(y, mu, var)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-9, atol=1e-10)


@settings(max_examples=40, deadline=None)
@given(
    y=st.sampled_from([-1.0, 1.0]),
    mu=st.floats(-8.0, 8.0),
    var=st.floats(1e-3, 50.0),
)
def test_moments_hypothesis_scalarwise(y, mu, var):
    ya = jnp.full((4,), y)
    mua = jnp.full((4,), mu)
    vara = jnp.full((4,), var)
    lnz, muh, s2h = (np.asarray(v) for v in probit.probit_moments(ya, mua, vara))
    # basic sanity invariants of the tilted distribution
    assert np.all(np.isfinite(lnz))
    assert np.all(lnz <= 0.0 + 1e-12)  # Zhat <= 1
    assert np.all(s2h > 0.0)
    assert np.all(s2h < var + 1e-12)  # probit tilt shrinks variance
    # tilting pulls the mean toward the observed class
    assert np.all(y * (muh - mua) >= -1e-12)


def test_moments_match_quadrature():
    """Direct numerical check of Zhat / mu_hat / var_hat."""
    from tests.scipy_free_quad import tilted_quadrature  # local helper

    for y, mu, var in [(1.0, 0.3, 0.8), (-1.0, -1.2, 2.5), (1.0, -3.0, 0.5)]:
        lnz, muh, s2h = (
            float(np.asarray(v)[0])
            for v in probit.probit_moments(
                jnp.array([y]), jnp.array([mu]), jnp.array([var])
            )
        )
        z0, m_q, v_q = tilted_quadrature(y, mu, var)
        assert abs(lnz - np.log(z0)) < 1e-7
        assert abs(muh - m_q) < 1e-7
        assert abs(s2h - v_q) < 1e-7


def test_predict_probit_matches_ref():
    rng = np.random.default_rng(1)
    mean = jnp.asarray(rng.normal(0, 3, size=512))
    var = jnp.asarray(rng.uniform(0.01, 10.0, size=512))
    got = np.asarray(probit.predict_probit(mean, var))
    want = np.asarray(probit.predict_probit_reference(mean, var))
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-12)
    assert np.all((got >= 0) & (got <= 1))


def test_predict_probit_limits():
    p = np.asarray(
        probit.predict_probit(jnp.array([0.0, 100.0, -100.0]), jnp.array([1.0, 1.0, 1.0]))
    )
    np.testing.assert_allclose(p[0], 0.5, atol=1e-12)
    assert p[1] > 1 - 1e-10 and p[2] < 1e-10
