"""AOT path: every entry point lowers to parseable HLO text with the
manifest-declared shapes, and the lowered module computes the same
numbers as the eager kernel (executed via jax on the lowered module)."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from compile import aot, model  # noqa: E402
from compile.kernels import cov  # noqa: E402
from compile.kernels.ref import DMAX, PROBIT_BATCH, TILE  # noqa: E402


def test_entry_points_cover_all_kinds():
    eps = aot.entry_points()
    for kind in cov.KINDS:
        assert f"cov_tile_{kind}" in eps
    assert "probit_moments" in eps
    assert "predict_probit" in eps


@pytest.mark.parametrize("name", ["cov_tile_se", "cov_tile_pp3", "predict_probit"])
def test_lowering_produces_hlo_text(name):
    fn, specs, _ = aot.entry_points()[name]
    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "ROOT" in text, "does not look like HLO text"
    assert "f64" in text, "artifacts must be f64"


def test_aot_writes_artifacts(tmp_path):
    out = str(tmp_path / "artifacts")
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", out],
        check=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=env,
    )
    manifest = json.load(open(os.path.join(out, "manifest.json")))
    assert manifest["tile"] == TILE
    assert manifest["dmax"] == DMAX
    assert manifest["probit_batch"] == PROBIT_BATCH
    for name, meta in manifest["entry_points"].items():
        path = os.path.join(out, f"{name}.hlo.txt")
        assert os.path.exists(path), name
        assert os.path.getsize(path) == meta["bytes"]


def test_full_tile_shape_numerics():
    """Run the jitted full-size entry point (the exact computation the
    artifact freezes) and compare with the oracle."""
    rng = np.random.default_rng(42)
    x1 = np.zeros((TILE, DMAX))
    x2 = np.zeros((TILE, DMAX))
    d = 5
    x1[:, :d] = rng.uniform(0, 10, size=(TILE, d))
    x2[:, :d] = rng.uniform(0, 10, size=(TILE, d))
    inv_ls2 = np.zeros(DMAX)
    inv_ls2[:d] = 1.0 / 2.0**2
    scal = np.array([1.3, 5.0])
    fn = model.make_cov_tile_fn("pp3")
    (got,) = jax.jit(fn)(
        jnp.asarray(x1), jnp.asarray(x2), jnp.asarray(inv_ls2), jnp.asarray(scal)
    )
    want = cov.cov_tile_reference(
        "pp3", jnp.asarray(x1), jnp.asarray(x2), jnp.asarray(inv_ls2), jnp.asarray(scal)
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12, atol=1e-12)
