"""Quadrature oracle for the tilted probit moments (no scipy needed)."""

import numpy as np
from math import erf


def _ndtr(x):
    return 0.5 * (1.0 + erf(x / np.sqrt(2.0)))


def tilted_quadrature(y, mu, var, n=200001, width=10.0):
    """Trapezoid moments of Phi(y f) N(f | mu, var)."""
    s = np.sqrt(var)
    f = np.linspace(mu - width * s, mu + width * s, n)
    pdf = np.exp(-0.5 * ((f - mu) / s) ** 2) / (s * np.sqrt(2 * np.pi))
    w = np.array([_ndtr(y * fi) for fi in f]) * pdf
    z0 = np.trapezoid(w, f)
    m = np.trapezoid(w * f, f) / z0
    v = np.trapezoid(w * f * f, f) / z0 - m * m
    return z0, m, v
