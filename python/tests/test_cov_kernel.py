"""L1 correctness: Pallas covariance tile vs the pure-jnp oracle, with
hypothesis sweeping shapes, dtypes-relevant scales and hyperparameters."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels import cov, ref  # noqa: E402

KINDS = cov.KINDS


def make_inputs(rng, t1, t2, d, dmax, lengthscale, sigma2, jexp, side=4.0):
    x1 = np.zeros((t1, dmax))
    x2 = np.zeros((t2, dmax))
    x1[:, :d] = rng.uniform(0, side, size=(t1, d))
    x2[:, :d] = rng.uniform(0, side, size=(t2, d))
    inv_ls2 = np.zeros(dmax)
    inv_ls2[:d] = 1.0 / lengthscale**2
    scal = np.array([sigma2, jexp])
    return (
        jnp.asarray(x1),
        jnp.asarray(x2),
        jnp.asarray(inv_ls2),
        jnp.asarray(scal),
    )


@pytest.mark.parametrize("kind", KINDS)
def test_kernel_matches_ref_fixed_shape(kind):
    rng = np.random.default_rng(0)
    args = make_inputs(rng, 32, 32, 5, 16, lengthscale=1.5, sigma2=1.3, jexp=5.0)
    got = cov.cov_tile(kind, *args)
    want = cov.cov_tile_reference(kind, *args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12, atol=1e-12)


@settings(max_examples=25, deadline=None)
@given(
    kind=st.sampled_from(KINDS),
    t1=st.integers(1, 48),
    t2=st.integers(1, 48),
    d=st.integers(1, 12),
    lengthscale=st.floats(0.2, 10.0),
    sigma2=st.floats(0.01, 50.0),
    q_dim=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_hypothesis(kind, t1, t2, d, lengthscale, sigma2, q_dim, seed):
    rng = np.random.default_rng(seed)
    jexp = float(q_dim // 2 + 3 + 1)
    args = make_inputs(rng, t1, t2, d, 16, lengthscale, sigma2, jexp)
    got = np.asarray(cov.cov_tile(kind, *args))
    want = np.asarray(cov.cov_tile_reference(kind, *args))
    np.testing.assert_allclose(got, want, rtol=1e-11, atol=1e-11)
    assert got.shape == (t1, t2)


@pytest.mark.parametrize("kind", KINDS)
def test_padding_invariance(kind):
    """Zero-padded feature columns must not change the result."""
    rng = np.random.default_rng(7)
    d = 3
    small = make_inputs(rng, 16, 16, d, d, lengthscale=2.0, sigma2=1.0, jexp=4.0)
    rng = np.random.default_rng(7)
    padded = make_inputs(rng, 16, 16, d, 24, lengthscale=2.0, sigma2=1.0, jexp=4.0)
    got_small = np.asarray(cov.cov_tile(kind, *small))
    got_padded = np.asarray(cov.cov_tile(kind, *padded))
    np.testing.assert_allclose(got_small, got_padded, rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("kind", ["pp0", "pp1", "pp2", "pp3"])
def test_compact_support_is_exact_zero(kind):
    rng = np.random.default_rng(3)
    x1, x2, inv_ls2, scal = make_inputs(
        rng, 16, 16, 2, 8, lengthscale=0.5, sigma2=2.0, jexp=3.0, side=10.0
    )
    out = np.asarray(cov.cov_tile(kind, x1, x2, inv_ls2, scal))
    r = np.sqrt(np.asarray(ref.scaled_r2(x1, x2, inv_ls2)))
    assert np.all(out[r >= 1.0] == 0.0), "CS kernel must be exactly zero at r >= 1"
    assert np.any(r >= 1.0), "test geometry should include far pairs"


def test_diagonal_tile_is_symmetric_with_sigma2_diag():
    rng = np.random.default_rng(11)
    x1, _, inv_ls2, scal = make_inputs(rng, 24, 24, 4, 8, 1.0, 1.7, 4.0)
    out = np.asarray(cov.cov_tile("pp3", x1, x1, inv_ls2, scal))
    np.testing.assert_allclose(out, out.T, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(np.diag(out), 1.7, rtol=1e-12)


def test_se_ignores_jexp():
    rng = np.random.default_rng(5)
    a1 = make_inputs(rng, 8, 8, 2, 4, 1.0, 1.0, jexp=3.0)
    rng = np.random.default_rng(5)
    a2 = make_inputs(rng, 8, 8, 2, 4, 1.0, 1.0, jexp=9.0)
    np.testing.assert_array_equal(
        np.asarray(cov.cov_tile("se", *a1)), np.asarray(cov.cov_tile("se", *a2))
    )
