"""L2 — the jax compute graph that gets AOT-lowered.

Each exported function is a thin, fixed-shape jit wrapper around the L1
Pallas kernels; `aot.py` lowers them once to HLO text and the rust
runtime (`rust/src/runtime/`) loads + executes them via PJRT. Python
never runs at inference time.

Fixed artifact shapes (see kernels/ref.py):
  covariance tiles:  x1, x2: (TILE, DMAX) f64; inv_ls2: (DMAX,) f64;
                     scal: (2,) f64 = [sigma2, wendland_j]
  probit batches:    (PROBIT_BATCH,) f64 vectors
"""

import jax
import jax.numpy as jnp

from .kernels import cov as cov_kernels
from .kernels import probit as probit_kernels
from .kernels.ref import DMAX, PROBIT_BATCH, TILE

jax.config.update("jax_enable_x64", True)


def make_cov_tile_fn(kind):
    """Covariance-tile entry point for one radial profile."""

    def fn(x1, x2, inv_ls2, scal):
        return (cov_kernels.cov_tile(kind, x1, x2, inv_ls2, scal),)

    fn.__name__ = f"cov_tile_{kind}"
    return fn


def probit_moments_fn(y, mu, var):
    """Batched EP tilted moments."""
    return probit_kernels.probit_moments(y, mu, var)


def predict_probit_fn(mean, var):
    """Batched averaged predictive probability."""
    return (probit_kernels.predict_probit(mean, var),)


def cov_tile_specs():
    """(example-input ShapeDtypeStructs) for the covariance tiles."""
    f64 = jnp.float64
    return (
        jax.ShapeDtypeStruct((TILE, DMAX), f64),
        jax.ShapeDtypeStruct((TILE, DMAX), f64),
        jax.ShapeDtypeStruct((DMAX,), f64),
        jax.ShapeDtypeStruct((2,), f64),
    )


def probit_specs(n_inputs):
    f64 = jnp.float64
    return tuple(jax.ShapeDtypeStruct((PROBIT_BATCH,), f64) for _ in range(n_inputs))
