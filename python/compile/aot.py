"""AOT lowering: jax (L2, calling the L1 Pallas kernels) -> HLO text.

HLO *text* is the interchange format, not serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  python -m compile.aot --out ../artifacts
Writes one .hlo.txt per entry point plus manifest.json and a `.stamp`
file that the Makefile uses for freshness.
"""

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402
from .kernels.cov import KINDS  # noqa: E402
from .kernels.ref import DMAX, PROBIT_BATCH, TILE  # noqa: E402


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def entry_points():
    """name -> (fn, example_args, n_outputs)."""
    eps = {}
    for kind in KINDS:
        eps[f"cov_tile_{kind}"] = (
            model.make_cov_tile_fn(kind),
            model.cov_tile_specs(),
            1,
        )
    eps["probit_moments"] = (model.probit_moments_fn, model.probit_specs(3), 3)
    eps["predict_probit"] = (model.predict_probit_fn, model.probit_specs(2), 1)
    return eps


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {
        "tile": TILE,
        "dmax": DMAX,
        "probit_batch": PROBIT_BATCH,
        "dtype": "f64",
        "entry_points": {},
    }
    for name, (fn, specs, n_out) in entry_points().items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["entry_points"][name] = {
            "inputs": [list(s.shape) for s in specs],
            "n_outputs": n_out,
            "bytes": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    with open(os.path.join(args.out, ".stamp"), "w") as f:
        f.write("ok\n")
    print(f"manifest: {len(manifest['entry_points'])} entry points")


if __name__ == "__main__":
    main()
