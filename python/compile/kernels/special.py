"""Normal-cdf special functions lowered WITHOUT the `erf` HLO opcode.

jax >= 0.5 lowers `jax.scipy.special.ndtr`/`log_ndtr` to an `erf`
instruction, which the xla_extension 0.5.1 HLO text parser (the version
the rust `xla` crate binds) does not know. These implementations use the
regularized incomplete gamma function — series + continued fraction, the
same algorithm as rust/src/gp/likelihood.rs — so the lowered HLO contains
only exp/log/power/while ops that 0.5.1 parses, and the three layers
agree to ~1e-14.
"""

import jax.numpy as jnp
from jax import lax

LN_SQRT_PI = 0.5723649429247001  # ln Γ(1/2)
_A = 0.5
_SPLIT = 2.5  # x² threshold between series and continued fraction
_FPMIN = 1e-300


def _gamma_p_series(x2):
    """P(1/2, x2) by series (used for x2 < _SPLIT; input clamped)."""
    x = jnp.minimum(x2, _SPLIT)

    def body(_, carry):
        ap, delv, s = carry
        ap = ap + 1.0
        delv = delv * x / ap
        return (ap, delv, s + delv)

    init = (
        jnp.full_like(x, _A),
        jnp.full_like(x, 1.0 / _A),
        jnp.full_like(x, 1.0 / _A),
    )
    _, _, s = lax.fori_loop(0, 100, body, init)
    return s * jnp.exp(-x + _A * jnp.log(jnp.maximum(x, _FPMIN)) - LN_SQRT_PI)


def _ln_gamma_q_cf(x2):
    """ln Q(1/2, x2) by modified-Lentz continued fraction (x2 >= _SPLIT;
    input clamped)."""
    x = jnp.maximum(x2, _SPLIT)
    b = x + 1.0 - _A
    c = jnp.full_like(x, 1.0 / _FPMIN)
    d = 1.0 / b
    h = d

    def body(i, carry):
        b, c, d, h = carry
        fi = i.astype(x.dtype)
        an = -fi * (fi - _A)
        b = b + 2.0
        d = an * d + b
        d = jnp.where(jnp.abs(d) < _FPMIN, _FPMIN, d)
        c = b + an / c
        c = jnp.where(jnp.abs(c) < _FPMIN, _FPMIN, c)
        d = 1.0 / d
        h = h * d * c
        return (b, c, d, h)

    b, c, d, h = lax.fori_loop(1, 160, body, (b, c, d, h))
    return -x + _A * jnp.log(x) - LN_SQRT_PI + jnp.log(h)


def erfc(x):
    """Complementary error function (elementwise, f64 accuracy ~1e-14)."""
    ax = jnp.abs(x)
    x2 = ax * ax
    small = x2 < _SPLIT
    e = jnp.where(small, 1.0 - _gamma_p_series(x2), jnp.exp(_ln_gamma_q_cf(x2)))
    return jnp.where(x >= 0.0, e, 2.0 - e)


def ndtr(z):
    """Standard normal cdf Φ(z)."""
    return 0.5 * erfc(-z / jnp.sqrt(2.0))


def log_ndtr(z):
    """ln Φ(z), stable into the deep negative tail."""
    t2 = 0.5 * z * z  # (|z|/√2)²
    # z >= 0: log1p(−½ erfc(z/√2))
    pos = jnp.log1p(-0.5 * erfc(jnp.abs(z) / jnp.sqrt(2.0)))
    # z < 0, moderate: log(½ (1 − P))
    neg_small = jnp.log(
        jnp.maximum(0.5 * (1.0 - _gamma_p_series(t2)), _FPMIN)
    )
    # z < 0, deep tail: fully log-domain
    neg_big = _ln_gamma_q_cf(t2) - jnp.log(2.0)
    neg = jnp.where(t2 < _SPLIT, neg_small, neg_big)
    return jnp.where(z >= 0.0, pos, neg)
