"""L1 — Pallas covariance-tile kernel.

The dense compute hot-spot of the pipeline: a TILE×TILE block of the
covariance matrix K[i, j] = sigma2 * phi(r(x1_i, x2_j)) for one of the
radial profiles (se / pp0..pp3 / matern). The L3 rust coordinator calls
the AOT-compiled artifact per tile pair and *sparsifies* the result (CS
profiles are exactly zero at r >= 1).

TPU shaping (DESIGN.md §Hardware-Adaptation): the cross term of
r² = ‖a‖² + ‖b‖² − 2·a bᵀ is a (TILE, DMAX) @ (DMAX, TILE) contraction —
MXU work — while the polynomial cutoff is elementwise VPU work on the
tile while it sits in VMEM. VMEM footprint: 2·128·64·8 B inputs +
128·128·8 B output ≈ 260 KiB, far under the ~16 MiB budget, leaving room
to widen the grid on a real TPU. Here the kernel runs under
interpret=True (CPU PJRT cannot execute Mosaic custom-calls).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

KINDS = ("se", "pp0", "pp1", "pp2", "pp3", "matern32", "matern52")


def _profile(kind, r, jexp):
    """Radial profile, written with jnp ops Pallas supports."""
    if kind == "se":
        return jnp.exp(-r * r)
    if kind == "matern32":
        a = jnp.sqrt(3.0) * r
        return (1.0 + a) * jnp.exp(-a)
    if kind == "matern52":
        a = jnp.sqrt(5.0) * r
        return (1.0 + a + a * a / 3.0) * jnp.exp(-a)
    q = int(kind[2])
    u = jnp.maximum(1.0 - r, 0.0)
    j = jexp
    if q == 0:
        base, poly = u**j, 1.0
    elif q == 1:
        base, poly = u ** (j + 1.0), (j + 1.0) * r + 1.0
    elif q == 2:
        base = u ** (j + 2.0)
        poly = ((j * j + 4.0 * j + 3.0) * r * r + (3.0 * j + 6.0) * r + 3.0) / 3.0
    else:
        base = u ** (j + 3.0)
        poly = (
            (j**3 + 9.0 * j * j + 23.0 * j + 15.0) * r**3
            + (6.0 * j * j + 36.0 * j + 45.0) * r * r
            + (15.0 * j + 45.0) * r
            + 15.0
        ) / 15.0
    return jnp.where(r < 1.0, base * poly, 0.0)


def _cov_kernel(kind, x1_ref, x2_ref, inv_ls2_ref, scal_ref, o_ref):
    """Pallas kernel body. scal_ref = [sigma2, jexp] (shape (2,))."""
    scale = jnp.sqrt(inv_ls2_ref[...])[None, :]
    a = x1_ref[...] * scale
    b = x2_ref[...] * scale
    r2 = (
        jnp.sum(a * a, axis=1)[:, None]
        + jnp.sum(b * b, axis=1)[None, :]
        - 2.0 * jnp.dot(a, b.T)
    )
    r = jnp.sqrt(jnp.maximum(r2, 0.0))
    sigma2 = scal_ref[0]
    jexp = scal_ref[1]
    o_ref[...] = sigma2 * _profile(kind, r, jexp)


@functools.partial(jax.jit, static_argnums=0)
def cov_tile(kind, x1, x2, inv_ls2, scal):
    """One covariance tile via the Pallas kernel.

    Args:
      kind: one of KINDS (static).
      x1, x2: (T, D) input blocks (zero-padded columns allowed).
      inv_ls2: (D,) 1/l_d² (zero for padded columns).
      scal: (2,) = [sigma2, wendland_j] (j ignored by non-pp kinds).
    """
    t = x1.shape[0]
    return pl.pallas_call(
        functools.partial(_cov_kernel, kind),
        out_shape=jax.ShapeDtypeStruct((t, x2.shape[0]), x1.dtype),
        interpret=True,
    )(x1, x2, inv_ls2, scal)


def cov_tile_reference(kind, x1, x2, inv_ls2, scal):
    """The pure-jnp oracle with the same calling convention."""
    return ref.cov_tile_ref(kind, x1, x2, inv_ls2, scal[0], scal[1])
