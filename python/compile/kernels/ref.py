"""Pure-jnp oracles for the Pallas kernels (the build-time correctness
signal: every kernel in cov.py / probit.py must match these to ~1e-12).

These mirror rust/src/gp/covariance.rs and likelihood.rs exactly, so the
pytest suite here plus the rust agreement tests pin all three layers to
the same numbers.
"""

import jax.numpy as jnp
import jax.scipy.special as jsp

# Tile geometry shared with the AOT artifacts (see aot.py):
TILE = 128  # covariance tile edge
DMAX = 64   # padded feature dimension (covers Sonar's d = 60)
PROBIT_BATCH = 1024


def scaled_r2(x1, x2, inv_ls2):
    """Pairwise squared scaled distance r² between rows of x1 and x2.

    Padding convention: unused feature columns carry x = 0 and
    inv_ls2 = 0, so they contribute nothing.
    """
    a = x1 * jnp.sqrt(inv_ls2)[None, :]
    b = x2 * jnp.sqrt(inv_ls2)[None, :]
    r2 = (
        jnp.sum(a * a, axis=1)[:, None]
        + jnp.sum(b * b, axis=1)[None, :]
        - 2.0 * a @ b.T
    )
    return jnp.maximum(r2, 0.0)


def cov_profile(kind, r, jexp):
    """Unit-magnitude radial profile phi(r). `jexp` is the Wendland
    exponent j = floor(D/2) + q + 1 (ignored by non-pp kinds)."""
    if kind == "se":
        return jnp.exp(-r * r)
    if kind == "matern32":
        a = jnp.sqrt(3.0) * r
        return (1.0 + a) * jnp.exp(-a)
    if kind == "matern52":
        a = jnp.sqrt(5.0) * r
        return (1.0 + a + a * a / 3.0) * jnp.exp(-a)
    if kind.startswith("pp"):
        q = int(kind[2])
        u = jnp.maximum(1.0 - r, 0.0)
        j = jexp
        if q == 0:
            poly = jnp.ones_like(r)
            base = u**j
        elif q == 1:
            poly = (j + 1.0) * r + 1.0
            base = u ** (j + 1.0)
        elif q == 2:
            poly = ((j * j + 4.0 * j + 3.0) * r * r + (3.0 * j + 6.0) * r + 3.0) / 3.0
            base = u ** (j + 2.0)
        elif q == 3:
            poly = (
                (j**3 + 9.0 * j * j + 23.0 * j + 15.0) * r**3
                + (6.0 * j * j + 36.0 * j + 45.0) * r * r
                + (15.0 * j + 45.0) * r
                + 15.0
            ) / 15.0
            base = u ** (j + 3.0)
        else:
            raise ValueError(f"pp q must be 0..3, got {q}")
        return jnp.where(r < 1.0, base * poly, 0.0)
    raise ValueError(f"unknown covariance kind {kind!r}")


def cov_tile_ref(kind, x1, x2, inv_ls2, sigma2, jexp):
    """Reference covariance tile: K[i, j] = sigma2 * phi(r(x1_i, x2_j))."""
    r = jnp.sqrt(scaled_r2(x1, x2, inv_ls2))
    return sigma2 * cov_profile(kind, r, jexp)


def probit_moments_ref(y, mu, var):
    """Tilted moments of Phi(y f) N(f | mu, var):
    returns (ln Zhat, mu_hat, sigma2_hat) — mirrors
    rust/src/gp/likelihood.rs::probit_moments."""
    denom = jnp.sqrt(1.0 + var)
    z = y * mu / denom
    ln_zhat = jsp.log_ndtr(z)
    ln_pdf = -0.5 * z * z - 0.5 * jnp.log(2.0 * jnp.pi)
    rho = jnp.exp(ln_pdf - ln_zhat)
    mu_hat = mu + y * var * rho / denom
    sigma2_hat = var - var * var * rho * (z + rho) / (1.0 + var)
    return ln_zhat, mu_hat, sigma2_hat


def predict_probit_ref(mean, var):
    """Averaged predictive probability pi* = Phi(mean / sqrt(1 + var))."""
    return jsp.ndtr(mean / jnp.sqrt(1.0 + var))
