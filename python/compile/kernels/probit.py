"""L1 — Pallas kernels for the probit-likelihood transforms.

Two batched elementwise kernels:

* `probit_moments`: the EP tilted-moment computation (ln Zhat, mu_hat,
  sigma2_hat) for a batch of cavity parameters — used by the parallel-EP
  path and by the serving coordinator's calibration endpoint.
* `predict_probit`: the averaged predictive probability
  pi* = Phi(mean / sqrt(1 + var)) for a batch of latent predictions —
  the last stage of every serving request.

Pure VPU work; batch = 1024 keeps the artifact shape static.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from . import special


def _moments_kernel(y_ref, mu_ref, var_ref, lnz_ref, muh_ref, s2h_ref):
    y = y_ref[...]
    mu = mu_ref[...]
    var = var_ref[...]
    denom = jnp.sqrt(1.0 + var)
    z = y * mu / denom
    ln_zhat = special.log_ndtr(z)
    ln_pdf = -0.5 * z * z - 0.5 * jnp.log(2.0 * jnp.pi)
    rho = jnp.exp(ln_pdf - ln_zhat)
    lnz_ref[...] = ln_zhat
    muh_ref[...] = mu + y * var * rho / denom
    s2h_ref[...] = var - var * var * rho * (z + rho) / (1.0 + var)


@jax.jit
def probit_moments(y, mu, var):
    """Batched tilted moments through the Pallas kernel."""
    shape = jax.ShapeDtypeStruct(y.shape, y.dtype)
    return pl.pallas_call(
        _moments_kernel,
        out_shape=(shape, shape, shape),
        interpret=True,
    )(y, mu, var)


def _predict_kernel(mean_ref, var_ref, p_ref):
    mean = mean_ref[...]
    var = var_ref[...]
    p_ref[...] = special.ndtr(mean / jnp.sqrt(1.0 + var))


@jax.jit
def predict_probit(mean, var):
    """Batched pi* through the Pallas kernel."""
    return pl.pallas_call(
        _predict_kernel,
        out_shape=jax.ShapeDtypeStruct(mean.shape, mean.dtype),
        interpret=True,
    )(mean, var)


# oracles with identical calling conventions
probit_moments_reference = ref.probit_moments_ref
predict_probit_reference = ref.predict_probit_ref
