//! Table 1: density of the CS covariance matrix (fill-K) and of its
//! Cholesky factor (fill-L) as n grows, on the 2-D and 5-D cluster data.
//! The paper reports fill-L/fill-K ratios of ≈2.6–4.6.

use csgp::data::synthetic::{cluster_dataset, ClusterConfig};
use csgp::gp::covariance::{CovFunction, CovKind};
use csgp::sparse::ordering::{compute_ordering, Ordering};
use csgp::sparse::symbolic::Symbolic;

fn main() {
    let full = std::env::var("CSGP_FULL").is_ok();
    let ns: Vec<usize> =
        if full { vec![500, 1000, 2000, 5000, 10000] } else { vec![500, 1000, 2000, 5000] };

    println!("# Table 1: fill-L / fill-K (per cent), RCM ordering");
    println!("| data | {} |", ns.iter().map(|n| format!("n = {n}")).collect::<Vec<_>>().join(" | "));
    println!("|---|{}|", ns.iter().map(|_| "---").collect::<Vec<_>>().join("|"));

    for (dim, ls) in [(2usize, 1.3), (5usize, 5.0)] {
        let mut cells = Vec::new();
        let cfg = if dim == 2 {
            ClusterConfig::paper_2d(*ns.iter().max().unwrap())
        } else {
            ClusterConfig::paper_5d(*ns.iter().max().unwrap())
        };
        let data = cluster_dataset(&cfg, 7);
        let cov = CovFunction::new(CovKind::Pp(3), dim, 1.0, ls);
        for &n in &ns {
            let x = &data.x[..n];
            let k = cov.cov_matrix(x);
            let perm = compute_ordering(&k, Ordering::Rcm);
            let kp = k.permute_sym(&perm);
            let sym = Symbolic::analyze(&kp);
            let (fk, fl) = (k.density(), sym.fill_l());
            cells.push(format!("{:.0}/{:.0} = {:.1}", fl * 100.0, fk * 100.0, fl / fk));
            assert!(fl >= fk * 0.5, "fill-L should not collapse below fill-K");
        }
        println!("| {dim}D | {} |", cells.join(" | "));
    }
    println!("\npaper shape: fill-L grows with n and faster than fill-K (ratio 2.6–4.6); 5-D much denser than 2-D.");
}
