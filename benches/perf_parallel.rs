//! Worker-pool scaling of the per-sweep hot loops: the supernodal numeric
//! LDLᵀ factorization (`factor`), the parallel-EP / CS+FIC
//! marginal-variance loops (`sweep`), the Takahashi-based gradient path
//! (`gradient`) and batched latent prediction (`predict`), each measured
//! at pool widths 1/2/4/8 on the same fitted state. Every measurement
//! also asserts that the output is bitwise-identical to the width-1
//! (serial) path — the pool's determinism contract.
//!
//! Results are printed as a markdown table and written to
//! `BENCH_parallel.json` (bench, backend, n, threads, ns/iter — see
//! README "Solver stack") so the perf trajectory is tracked across PRs.
//!
//! Run: `cargo bench --bench perf_parallel` (`CSGP_FULL=1` for n = 8000).

use csgp::bench::report::Report;
use csgp::bench::{fmt_duration, Bencher};
use csgp::data::kmeans::kmeans;
use csgp::data::synthetic::{cluster_dataset, uniform_points, ClusterConfig};
use csgp::gp::cache::GradScratch;
use csgp::gp::covariance::{AdditiveCov, CovFunction, CovKind};
use csgp::gp::csfic::CsFicEp;
use csgp::gp::ep_parallel::ParallelEp;
use csgp::gp::marginal::EpOptions;
use csgp::sparse::cholesky::LdlFactor;
use csgp::sparse::csc::CscMatrix;
use csgp::sparse::ordering::{compute_ordering, Ordering};
use csgp::sparse::symbolic::Symbolic;
use csgp::sparse::takahashi::SparseInverse;
use std::sync::Arc;

const WIDTHS: [usize; 4] = [1, 2, 4, 8];

/// Min-degree-permute `b`, analyse the permuted pattern, and return an
/// identity factor over it plus the permuted matrix — the refactor target
/// the `factor` stage times.
fn mindeg_factor(b: &CscMatrix) -> (LdlFactor, CscMatrix) {
    let perm = compute_ordering(b, Ordering::MinDegree);
    let b_perm = b.permute_sym(&perm);
    let sym = Arc::new(Symbolic::analyze(&b_perm));
    (LdlFactor::identity(sym), b_perm)
}

/// Measure `f` at every pool width, asserting output identity against the
/// width-1 reference, pushing every measurement into the report, and
/// returning (t1, t4) median nanoseconds for the speedup summary.
fn measure<T: PartialEq>(
    rep: &mut Report,
    bench: &str,
    backend: &str,
    n: usize,
    mut f: impl FnMut() -> T,
) -> (f64, f64) {
    let b = Bencher::quick();
    let reference = csgp::par::with_max_threads(1, &mut f);
    let (mut t1, mut t4) = (0.0f64, 0.0f64);
    for &w in &WIDTHS {
        let stats = csgp::par::with_max_threads(w, || {
            let out = f();
            assert!(
                out == reference,
                "{backend}/{bench}: width-{w} output differs from the serial path"
            );
            b.run(&mut f)
        });
        let ns = stats.median.as_nanos() as f64;
        if w == 1 {
            t1 = ns;
        }
        if w == 4 {
            t4 = ns;
        }
        println!(
            "| {n} | {backend} | {bench} | {w} | {} | {:.2}x |",
            fmt_duration(stats.median),
            t1 / ns
        );
        rep.push(bench, backend, n, w, &stats);
    }
    (t1, t4)
}

/// Like [`measure`] but for the factor stage: the width-vs-serial
/// bitwise-identity check runs *outside* the timed region, so ns/iter
/// times only `refactor` itself — cloning L/D per iteration would add a
/// width-independent `O(nnz(L))` memcpy that dilutes the measured
/// scaling of exactly the stage this bench gates on.
fn measure_factor(
    rep: &mut Report,
    bench: &str,
    backend: &str,
    n: usize,
    fac: &mut LdlFactor,
    b: &CscMatrix,
) -> (f64, f64) {
    let harness = Bencher::quick();
    let (ref_l, ref_d) = csgp::par::with_max_threads(1, || {
        fac.refactor(b).unwrap();
        (fac.l.clone(), fac.d.clone())
    });
    let (mut t1, mut t4) = (0.0f64, 0.0f64);
    for &w in &WIDTHS {
        let stats = csgp::par::with_max_threads(w, || {
            fac.refactor(b).unwrap();
            assert!(
                fac.l == ref_l && fac.d == ref_d,
                "{backend}/{bench}: width-{w} factor differs from the serial path"
            );
            harness.run(|| fac.refactor(b).unwrap())
        });
        let ns = stats.median.as_nanos() as f64;
        if w == 1 {
            t1 = ns;
        }
        if w == 4 {
            t4 = ns;
        }
        println!(
            "| {n} | {backend} | {bench} | {w} | {} | {:.2}x |",
            fmt_duration(stats.median),
            t1 / ns
        );
        rep.push(bench, backend, n, w, &stats);
    }
    (t1, t4)
}

fn main() {
    let full = std::env::var("CSGP_FULL").is_ok();
    let n = if full { 8000 } else { 4000 };
    let cores = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    let mut rep = Report::new("BENCH_parallel.json");

    println!("# Worker-pool scaling (n = {n}, host cores = {cores})");
    println!("| n | backend | loop | threads | median | speedup |");
    println!("|---|---|---|---|---|---|");

    // ---- CS backend: parallel EP on the pure Wendland prior -------------
    let data = cluster_dataset(&ClusterConfig::paper_2d(n), 7);
    let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.2);
    let opts = EpOptions { max_sweeps: 40, tol: 1e-6, damping: 0.8 };
    let ep = ParallelEp::run(&cov, &data.x, &data.y, Ordering::Rcm, &opts).unwrap();
    let probes = uniform_points(2000, 2, 10.0, 99);

    // numeric LDLᵀ of B at the converged sites: the supernodal
    // wave-scheduled kernel, in isolation. Wave width depends on the
    // fill-reducing ordering: RCM's banded etrees are near-paths (little
    // to fan out), so `factor` measures the min-degree (AMD-analogue)
    // permutation of the same matrix — the ordering a factorization-bound
    // deployment picks — and `factor_rcm` tracks the EP fit's own factor.
    let b_cs = csgp::gp::ep_sparse::build_b(&ep.k, &ep.sites.tau);
    let (mut fac_md, b_md) = mindeg_factor(&b_cs);
    let (fac_t1, fac_t4) = measure_factor(&mut rep, "factor", "cs", n, &mut fac_md, &b_md);
    let mut fac_cs = ep.factor.clone();
    measure_factor(&mut rep, "factor_rcm", "cs", n, &mut fac_cs, &b_cs);
    let (cs_t1, cs_t4) = measure(&mut rep, "sweep", "cs", n, || ep.recompute_sigma_diag());
    let mut zi = SparseInverse::default();
    measure(&mut rep, "gradient", "cs", n, || {
        ep.factor.takahashi_inverse_into(&mut zi);
        (zi.z_lower.clone(), zi.z_diag.clone())
    });
    measure(&mut rep, "predict", "cs", n, || ep.predict_latent_batch(&cov, &probes));

    // ---- CS+FIC backend: hybrid prior through the Woodbury solver -------
    let hybrid = AdditiveCov::new(CovFunction::new(CovKind::Se, 2, 0.6, 3.0), cov.clone()).unwrap();
    let xu = kmeans(&data.x, 64, 25, 0xf1c);
    let hopts = EpOptions { max_sweeps: 15, tol: 1e-6, damping: 0.8 };
    let hep = CsFicEp::run(&hybrid, &data.x, &data.y, &xu, &hopts).unwrap();

    // numeric LDLᵀ of S_B (the sparse half of the Woodbury B) — same
    // kernel, CS+FIC pattern, min-degree and RCM like the CS stage
    let sb = hep.sparse_b();
    let (mut hfac_md, sb_md) = mindeg_factor(&sb);
    let (hfac_t1, hfac_t4) =
        measure_factor(&mut rep, "factor", "csfic", n, &mut hfac_md, &sb_md);
    let mut fac_hy = hep.sparse_factor().clone();
    measure_factor(&mut rep, "factor_rcm", "csfic", n, &mut fac_hy, &sb);
    let hu = hep.fic_factor(); // rebuilt once, outside the timed loop
    let (hy_t1, hy_t4) =
        measure(&mut rep, "sweep", "csfic", n, || hep.recompute_sigma_diag_with(&hu));
    let mut scratch = GradScratch::default();
    measure(&mut rep, "gradient", "csfic", n, || hep.log_z_grad_cs_cached(&mut scratch));
    measure(&mut rep, "predict", "csfic", n, || hep.predict_latent_batch(&probes));

    rep.write().expect("writing BENCH_parallel.json");
    println!();
    println!(
        "per-sweep variance loop, 4 threads vs 1: cs {:.2}x, csfic {:.2}x \
         (target >= 2.5x on a >= 4-core host)",
        cs_t1 / cs_t4,
        hy_t1 / hy_t4
    );
    println!(
        "numeric LDL factorization, 4 threads vs 1: cs {:.2}x, csfic {:.2}x \
         (target > 1x on a >= 4-core host; wave structure caps the ideal)",
        fac_t1 / fac_t4,
        hfac_t1 / hfac_t4
    );
    println!("machine-readable results: BENCH_parallel.json ({} records)", rep.records().len());
    if cores >= 4 && (cs_t1 / cs_t4 < 2.5 || hy_t1 / hy_t4 < 2.5) {
        println!("WARNING: 4-thread speedup below the 2.5x target on this host");
    }
    if cores >= 4 && (fac_t1 / fac_t4 <= 1.0 || hfac_t1 / hfac_t4 <= 1.0) {
        println!("WARNING: factor stage not scaling beyond width 1 on this host");
    }
}
