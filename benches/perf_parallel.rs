//! Worker-pool scaling of the per-sweep hot loops: the supernodal numeric
//! LDLᵀ factorization (`factor*`, one row per fill-reducing ordering),
//! the parallel-EP / CS+FIC marginal-variance loops (`sweep`), the
//! Takahashi-based gradient path (`gradient`) and batched latent
//! prediction (`predict`), each measured at pool widths 1/2/4/8 on the
//! same fitted state. Every measurement also asserts that the output is
//! bitwise-identical to the width-1 (serial) path — the pool's
//! determinism contract.
//!
//! The factor stage runs the same matrix under min-degree (`factor`),
//! the EP fit's own RCM plan (`factor_rcm`), nested dissection
//! (`factor_nd`, geometric fast path on the permuted inputs), the auto
//! policy (`factor_auto`) and ND with relaxed amalgamation disabled
//! (`factor_nd_strict`, the `CSGP_AMALG=0` configuration), recording
//! per-ordering structure — `nnz_l`, `padded_nnz`, supernode count and
//! width, wave count, max wave width, the dense-equivalent `flops` — and
//! `ns_per_col` next to the timings so ordering and amalgamation quality
//! stay visible in the perf trajectory.
//!
//! Results are printed as a markdown table and written to
//! `BENCH_parallel.json` (bench, backend, n, threads, ns/iter, plus the
//! factor-stage structure fields — see README "Solver stack").
//!
//! Run: `cargo bench --bench perf_parallel` (`CSGP_FULL=1` for n = 8000).

use csgp::bench::report::Report;
use csgp::bench::{fmt_duration, Bencher};
use csgp::data::kmeans::kmeans;
use csgp::data::synthetic::{cluster_dataset, uniform_points, ClusterConfig};
use csgp::gp::cache::GradScratch;
use csgp::gp::covariance::{AdditiveCov, CovFunction, CovKind};
use csgp::gp::csfic::CsFicEp;
use csgp::gp::ep_parallel::ParallelEp;
use csgp::gp::marginal::EpOptions;
use csgp::sparse::cholesky::LdlFactor;
use csgp::sparse::csc::CscMatrix;
use csgp::sparse::ordering::{order, Ordering};
use csgp::sparse::symbolic::{AmalgConfig, Symbolic};
use std::sync::Arc;

const WIDTHS: [usize; 4] = [1, 2, 4, 8];

/// Median ns/iter at widths 1, 4 and 8 — the numbers the summary lines
/// compare.
#[derive(Clone, Copy, Default)]
struct WidthTimes {
    t1: f64,
    t4: f64,
    t8: f64,
}

/// Per-ordering structure of a factor target: what the fill-reducing
/// ordering and the relaxed amalgamation bought, recorded next to the
/// timings.
#[derive(Clone, Copy)]
struct FactorShape {
    nnz_l: usize,
    padded_nnz: usize,
    snodes: usize,
    max_snode_cols: usize,
    waves: usize,
    max_wave_width: usize,
    /// Dense-equivalent factor work on the stored pattern,
    /// `Σ_j c_j (c_j + 3)` with `c_j` column j's stored off-diagonals —
    /// the classic right-looking count, so `flops / time` tracks kernel
    /// throughput across orderings and amalgamation settings.
    flops: f64,
}

impl FactorShape {
    fn of(sym: &Symbolic) -> FactorShape {
        let flops: f64 = sym
            .col_ptr
            .windows(2)
            .map(|w| {
                let c = (w[1] - w[0]) as f64;
                c * (c + 3.0)
            })
            .sum();
        FactorShape {
            nnz_l: sym.nnz_l(),
            padded_nnz: sym.padded_nnz(),
            snodes: sym.schedule.n_snodes(),
            max_snode_cols: sym.schedule.max_snode_cols(),
            waves: sym.schedule.n_waves(),
            max_wave_width: sym.schedule.wave_width_max(),
            flops,
        }
    }

    fn extra(&self) -> [(&'static str, f64); 7] {
        [
            ("nnz_l", self.nnz_l as f64),
            ("padded_nnz", self.padded_nnz as f64),
            ("snodes", self.snodes as f64),
            ("max_snode_cols", self.max_snode_cols as f64),
            ("waves", self.waves as f64),
            ("max_wave_width", self.max_wave_width as f64),
            ("flops", self.flops),
        ]
    }
}

/// Permute `b` with `ord` (ND/Auto get the point coordinates for the
/// geometric path), analyse the permuted pattern, and return an identity
/// factor over it plus the permuted matrix — the refactor target the
/// factor stage times — and the resulting structure.
fn ordered_factor(
    b: &CscMatrix,
    ord: Ordering,
    points: Option<&[Vec<f64>]>,
    amalg: Option<&AmalgConfig>,
) -> (LdlFactor, CscMatrix, FactorShape, Ordering) {
    let res = order(b, ord, points);
    let b_perm = b.permute_sym(&res.perm);
    let septree = res.septree.map(Arc::new);
    let sym = Arc::new(match amalg {
        Some(cfg) => Symbolic::analyze_with(&b_perm, septree, cfg),
        None => Symbolic::analyze_with_septree(&b_perm, septree),
    });
    let shape = FactorShape::of(&sym);
    (LdlFactor::identity(sym), b_perm, shape, res.resolved)
}

/// Delta of the pool/cache obs counters across one measured row, rendered
/// as report extras. Call [`obs_row_start`] before the timed region; the
/// imbalance gauge is reset there so its watermark is per-row.
fn obs_row_start() -> csgp::obs::Snapshot {
    csgp::obs::counters::POOL_IMBALANCE_MAX_PERMILLE.reset();
    csgp::obs::snapshot()
}

fn obs_row_extras(before: csgp::obs::Snapshot) -> Vec<(&'static str, f64)> {
    let after = csgp::obs::snapshot();
    let hits = (after.cache_hit - before.cache_hit) as f64;
    let lookups = hits + (after.cache_miss - before.cache_miss) as f64;
    vec![
        ("pool_chunks", (after.pool_chunks - before.pool_chunks) as f64),
        ("pool_steals", (after.pool_steals - before.pool_steals) as f64),
        (
            "pool_imbalance_max_permille",
            csgp::obs::counters::POOL_IMBALANCE_MAX_PERMILLE.get() as f64,
        ),
        // serialized as null when the row did no cache lookups
        ("cache_hit_rate", if lookups > 0.0 { hits / lookups } else { f64::NAN }),
    ]
}

/// Measure `f` at every pool width, asserting output identity against the
/// width-1 reference, pushing every measurement into the report, and
/// returning the per-width medians for the speedup summary.
fn measure<T: PartialEq>(
    rep: &mut Report,
    bench: &str,
    backend: &str,
    n: usize,
    mut f: impl FnMut() -> T,
) -> WidthTimes {
    let b = Bencher::quick();
    let reference = csgp::par::with_max_threads(1, &mut f);
    let mut t = WidthTimes::default();
    for &w in &WIDTHS {
        let (stats, obs_before) = csgp::par::with_max_threads(w, || {
            let out = f();
            assert!(
                out == reference,
                "{backend}/{bench}: width-{w} output differs from the serial path"
            );
            let before = obs_row_start();
            (b.run(&mut f), before)
        });
        let ns = stats.median.as_nanos() as f64;
        match w {
            1 => t.t1 = ns,
            4 => t.t4 = ns,
            8 => t.t8 = ns,
            _ => {}
        }
        println!(
            "| {n} | {backend} | {bench} | {w} | {} | {:.2}x |",
            fmt_duration(stats.median),
            t.t1 / ns
        );
        rep.push_with(bench, backend, n, w, &stats, &obs_row_extras(obs_before));
    }
    t
}

/// Like [`measure`] but for the factor stage: the width-vs-serial
/// bitwise-identity check runs *outside* the timed region, so ns/iter
/// times only `refactor` itself — cloning L/D per iteration would add a
/// width-independent `O(nnz(L))` memcpy that dilutes the measured
/// scaling of exactly the stage this bench gates on. Every record also
/// carries the ordering's structure fields.
fn measure_factor(
    rep: &mut Report,
    bench: &str,
    backend: &str,
    n: usize,
    fac: &mut LdlFactor,
    b: &CscMatrix,
    shape: FactorShape,
) -> WidthTimes {
    let harness = Bencher::quick();
    let (ref_l, ref_d) = csgp::par::with_max_threads(1, || {
        fac.refactor(b).unwrap();
        (fac.l.clone(), fac.d.clone())
    });
    let mut t = WidthTimes::default();
    for &w in &WIDTHS {
        let (stats, obs_before) = csgp::par::with_max_threads(w, || {
            fac.refactor(b).unwrap();
            assert!(
                fac.l == ref_l && fac.d == ref_d,
                "{backend}/{bench}: width-{w} factor differs from the serial path"
            );
            let before = obs_row_start();
            (harness.run(|| fac.refactor(b).unwrap()), before)
        });
        let ns = stats.median.as_nanos() as f64;
        match w {
            1 => t.t1 = ns,
            4 => t.t4 = ns,
            8 => t.t8 = ns,
            _ => {}
        }
        println!(
            "| {n} | {backend} | {bench} | {w} | {} | {:.2}x |",
            fmt_duration(stats.median),
            t.t1 / ns
        );
        let mut extra: Vec<(&str, f64)> = shape.extra().to_vec();
        extra.push(("ns_per_col", ns / n as f64));
        extra.extend(obs_row_extras(obs_before));
        rep.push_with(bench, backend, n, w, &stats, &extra);
    }
    t
}

/// All four factor-stage rows for one backend's sparse matrix `b` (given
/// in the EP fit's RCM-permuted space, with `rcm_factor` the fit's own
/// factor over it and `xp` the matching permuted inputs). Returns
/// (per-ordering (name, shape, times)) for the summary.
fn factor_stage(
    rep: &mut Report,
    backend: &str,
    n: usize,
    b: &CscMatrix,
    rcm_factor: &LdlFactor,
    xp: &[Vec<f64>],
) -> Vec<(&'static str, FactorShape, WidthTimes)> {
    let mut out = Vec::new();
    let strict = AmalgConfig::disabled();
    for (name, ord, amalg) in [
        ("factor", Ordering::MinDegree, None),
        ("factor_nd", Ordering::Nd, None),
        // same ND plan, relaxed amalgamation off: isolates what the
        // fattened supernodes buy the blocked kernel
        ("factor_nd_strict", Ordering::Nd, Some(&strict)),
        ("factor_auto", Ordering::Auto, None),
    ] {
        let (mut fac, b_ord, shape, resolved) = ordered_factor(b, ord, Some(xp), amalg);
        println!(
            "<!-- {backend}/{name} ({resolved:?}): nnz_l={} padded_nnz={} snodes={} \
             max_snode_cols={} waves={} max_wave_width={} -->",
            shape.nnz_l,
            shape.padded_nnz,
            shape.snodes,
            shape.max_snode_cols,
            shape.waves,
            shape.max_wave_width
        );
        let t = measure_factor(rep, name, backend, n, &mut fac, &b_ord, shape);
        out.push((name, shape, t));
    }
    // the EP fit's own (RCM) factor of the same matrix
    let mut fac = rcm_factor.clone();
    let shape = FactorShape::of(&fac.symbolic);
    println!(
        "<!-- {backend}/factor_rcm (Rcm): nnz_l={} padded_nnz={} snodes={} max_snode_cols={} \
         waves={} max_wave_width={} -->",
        shape.nnz_l,
        shape.padded_nnz,
        shape.snodes,
        shape.max_snode_cols,
        shape.waves,
        shape.max_wave_width
    );
    let t = measure_factor(rep, "factor_rcm", backend, n, &mut fac, b, shape);
    out.push(("factor_rcm", shape, t));
    out
}

/// Print the ordering-quality summary for one backend's factor stage:
/// ND-vs-RCM wave widths and the 8-thread nd-vs-best(md, rcm) gate,
/// with WARNING lines when either target is missed.
fn factor_summary(backend: &str, n: usize, rows: &[(&'static str, FactorShape, WidthTimes)]) {
    let get = |name: &str| rows.iter().find(|r| r.0 == name).unwrap();
    let (_, nd_shape, nd_t) = get("factor_nd");
    let (_, rcm_shape, rcm_t) = get("factor_rcm");
    let (_, md_shape, md_t) = get("factor");
    let (_, strict_shape, strict_t) = get("factor_nd_strict");
    println!(
        "{backend} factor nd amalgamation: {:.0} ns/col (snodes {}, padded_nnz {}) vs \
         strict {:.0} ns/col (snodes {}, padded_nnz {}) at width 1 -> {:.2}x; width 8 {:.2}x",
        nd_t.t1 / n as f64,
        nd_shape.snodes,
        nd_shape.padded_nnz,
        strict_t.t1 / n as f64,
        strict_shape.snodes,
        strict_shape.padded_nnz,
        strict_t.t1 / nd_t.t1,
        strict_t.t8 / nd_t.t8,
    );
    println!(
        "{backend} factor orderings: nd max wave width {} vs rcm {} (md {}); \
         8-thread factor nd {} vs best(md, rcm) {} \
         (target: nd wider waves than rcm, nd time <= best)",
        nd_shape.max_wave_width,
        rcm_shape.max_wave_width,
        md_shape.max_wave_width,
        fmt_duration(std::time::Duration::from_nanos(nd_t.t8 as u64)),
        fmt_duration(std::time::Duration::from_nanos(md_t.t8.min(rcm_t.t8) as u64)),
    );
    if nd_shape.max_wave_width <= rcm_shape.max_wave_width {
        println!("WARNING: {backend}: ND waves not wider than RCM");
    }
    if nd_t.t8 > md_t.t8.min(rcm_t.t8) {
        println!("WARNING: {backend}: 8-thread ND factor slower than best of md/rcm");
    }
}

fn main() {
    // counters-only tracing for the whole bench: every row snapshots the
    // pool/cache counters so steal counts, per-region imbalance and cache
    // behaviour land in BENCH_parallel.json next to the timings (spans
    // stay off — the bench measures the hot loops, not the trace path)
    csgp::obs::set_mode(csgp::obs::TraceMode::Counters);
    // CSGP_SMOKE: the CI bench-gate size — small enough for a PR check,
    // keyed identically (bench, backend, n, threads) to the committed
    // baselines in benches/baselines/
    let smoke = std::env::var("CSGP_SMOKE").is_ok();
    let full = std::env::var("CSGP_FULL").is_ok();
    let n = if smoke {
        600
    } else if full {
        8000
    } else {
        4000
    };
    let cores = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    let mut rep = Report::new("BENCH_parallel.json");

    println!("# Worker-pool scaling (n = {n}, host cores = {cores})");
    println!("| n | backend | loop | threads | median | speedup |");
    println!("|---|---|---|---|---|---|");

    // ---- CS backend: parallel EP on the pure Wendland prior -------------
    let data = cluster_dataset(&ClusterConfig::paper_2d(n), 7);
    let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.2);
    let opts = EpOptions { max_sweeps: 40, tol: 1e-6, damping: 0.8, ..EpOptions::default() };
    let ep = ParallelEp::run(&cov, &data.x, &data.y, Ordering::Rcm, &opts).unwrap();
    let probes = uniform_points(2000, 2, 10.0, 99);

    // numeric LDLᵀ of B at the converged sites: the supernodal
    // wave-scheduled kernel, in isolation, under every ordering. Wave
    // width depends on the fill-reducing ordering: RCM's banded etrees
    // are near-paths (little to fan out), min-degree bushes out, and
    // nested dissection's balanced separator tree fans out widest — the
    // ordering a factorization-bound deployment (and the Auto policy)
    // picks.
    let b_cs = csgp::gp::ep_sparse::build_b(&ep.k, &ep.sites.tau);
    let cs_rows = factor_stage(&mut rep, "cs", n, &b_cs, &ep.factor, &ep.xp);
    let (cs_t1, cs_t4) = {
        let t = measure(&mut rep, "sweep", "cs", n, || ep.recompute_sigma_diag());
        (t.t1, t.t4)
    };
    let mut zi = csgp::sparse::takahashi::SparseInverse::default();
    measure(&mut rep, "gradient", "cs", n, || {
        ep.factor.takahashi_inverse_into(&mut zi);
        (zi.z_lower.clone(), zi.z_diag.clone())
    });
    measure(&mut rep, "predict", "cs", n, || ep.predict_latent_batch(&cov, &probes));

    // ---- CS+FIC backend: hybrid prior through the Woodbury solver -------
    let hybrid = AdditiveCov::new(CovFunction::new(CovKind::Se, 2, 0.6, 3.0), cov.clone()).unwrap();
    let xu = kmeans(&data.x, 64, 25, 0xf1c);
    let hopts = EpOptions { max_sweeps: 15, tol: 1e-6, damping: 0.8, ..EpOptions::default() };
    let hep = CsFicEp::run(&hybrid, &data.x, &data.y, &xu, &hopts).unwrap();

    // numeric LDLᵀ of S_B (the sparse half of the Woodbury B) — same
    // kernel, CS+FIC pattern, same four orderings
    let sb = hep.sparse_b();
    let hy_rows = factor_stage(&mut rep, "csfic", n, &sb, hep.sparse_factor(), &hep.xp);
    let hu = hep.fic_factor(); // rebuilt once, outside the timed loop
    let (hy_t1, hy_t4) = {
        let t = measure(&mut rep, "sweep", "csfic", n, || hep.recompute_sigma_diag_with(&hu));
        (t.t1, t.t4)
    };
    let mut scratch = GradScratch::default();
    measure(&mut rep, "gradient", "csfic", n, || hep.log_z_grad_cs_cached(&mut scratch));
    measure(&mut rep, "predict", "csfic", n, || hep.predict_latent_batch(&probes));

    rep.write().expect("writing BENCH_parallel.json");
    println!();
    factor_summary("cs", n, &cs_rows);
    factor_summary("csfic", n, &hy_rows);
    println!(
        "per-sweep variance loop, 4 threads vs 1: cs {:.2}x, csfic {:.2}x \
         (target >= 2.5x on a >= 4-core host)",
        cs_t1 / cs_t4,
        hy_t1 / hy_t4
    );
    let (fac_t1, fac_t4) = {
        let t = cs_rows.iter().find(|r| r.0 == "factor").unwrap().2;
        (t.t1, t.t4)
    };
    let (hfac_t1, hfac_t4) = {
        let t = hy_rows.iter().find(|r| r.0 == "factor").unwrap().2;
        (t.t1, t.t4)
    };
    println!(
        "numeric LDL factorization (min-degree), 4 threads vs 1: cs {:.2}x, csfic {:.2}x \
         (target > 1x on a >= 4-core host; wave structure caps the ideal)",
        fac_t1 / fac_t4,
        hfac_t1 / hfac_t4
    );
    println!("machine-readable results: BENCH_parallel.json ({} records)", rep.records().len());
    if cores >= 4 && (cs_t1 / cs_t4 < 2.5 || hy_t1 / hy_t4 < 2.5) {
        println!("WARNING: 4-thread speedup below the 2.5x target on this host");
    }
    if cores >= 4 && (fac_t1 / fac_t4 <= 1.0 || hfac_t1 / hfac_t4 <= 1.0) {
        println!("WARNING: factor stage not scaling beyond width 1 on this host");
    }
}
