//! Serving-path benchmark: what the online story costs end to end.
//!
//! Stages, all on the sparse backend at the serving scale (n = 4000;
//! `CSGP_SMOKE=1` shrinks to n = 600 for CI, `CSGP_FULL=1` grows to
//! n = 8000):
//!
//! * `online_update` — absorb k ∈ {1, 16} fresh points through
//!   `GpClassifier::update` (incremental factor extension + resumed EP)
//!   vs `cold_refit` on the union. The acceptance contract, asserted
//!   here at n ≥ 4000: the online update is ≥ 5× faster than the refit.
//! * `snapshot_save` / `snapshot_load` — model durability round-trip.
//! * `serve_request` / `serve_batch` — the prediction service under
//!   concurrent client load; percentiles come from the service's own
//!   admission-layer samplers.
//!
//! Results go to `BENCH_serving.json`. Every record carries `p50_ns`,
//! `p90_ns` and `p99_ns` next to the median `ns_per_iter`; the
//! `online_update` records add `k` and `speedup_vs_refit`.
//!
//! Run: `cargo bench --bench perf_serving`

use std::sync::Arc;
use std::time::{Duration, Instant};

use csgp::bench::report::Report;
use csgp::bench::{fmt_duration, Stats};
use csgp::coordinator::{PredictionService, ServiceConfig};
use csgp::data::synthetic::{cluster_dataset, ClusterConfig};
use csgp::gp::covariance::{CovFunction, CovKind};
use csgp::gp::model::{FittedClassifier, GpClassifier, Inference};
use csgp::gp::UpdatePath;
use csgp::rng::Rng;
use csgp::sparse::ordering::Ordering;

fn pcts(s: &Stats) -> [(&'static str, f64); 3] {
    [
        ("p50_ns", s.p50.as_nanos() as f64),
        ("p90_ns", s.p90.as_nanos() as f64),
        ("p99_ns", s.p99.as_nanos() as f64),
    ]
}

fn main() {
    let smoke = std::env::var("CSGP_SMOKE").is_ok();
    let full = std::env::var("CSGP_FULL").is_ok();
    let n = if smoke {
        600
    } else if full {
        8000
    } else {
        4000
    };
    let reps = if smoke { 3 } else { 5 };
    let refit_reps = if smoke { 2 } else { 3 };
    let threads = csgp::par::default_threads();
    let mut report = Report::new("BENCH_serving.json");

    println!("# Serving-path benchmark (n = {n}, {threads} threads)");
    let data = cluster_dataset(&ClusterConfig::paper_2d(n), 7);
    let model = GpClassifier::new(
        CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.3),
        Inference::Sparse(Ordering::Rcm),
    );
    let t0 = Instant::now();
    let fitted = model.infer_only(&data.x, &data.y).unwrap();
    println!("base fit: {} (fill-L {:.3})", fmt_duration(t0.elapsed()), fitted.report.fill_l);

    // --- online update vs cold refit -----------------------------------
    println!("\n| stage | k | median | p99 | speedup vs refit |");
    println!("|---|---|---|---|---|");
    for k in [1usize, 16] {
        let batch = cluster_dataset(&ClusterConfig::paper_2d(k), 991);
        let mut upd = Vec::with_capacity(reps);
        for _ in 0..reps {
            let t = Instant::now();
            let (_, rep) = model.update(&fitted, &batch.x, &batch.y).unwrap();
            upd.push(t.elapsed());
            assert_eq!(rep.path, UpdatePath::Incremental, "k={k} must take the fast path");
        }
        let upd = Stats::from_samples(upd);

        let mut xu = data.x.clone();
        xu.extend(batch.x.iter().cloned());
        let mut yu = data.y.clone();
        yu.extend_from_slice(&batch.y);
        let mut ref_samples = Vec::with_capacity(refit_reps);
        for _ in 0..refit_reps {
            let t = Instant::now();
            let _ = model.infer_only(&xu, &yu).unwrap();
            ref_samples.push(t.elapsed());
        }
        let refit = Stats::from_samples(ref_samples);

        let speedup = refit.median.as_secs_f64() / upd.median.as_secs_f64().max(1e-12);
        println!(
            "| online_update | {k} | {} | {} | {speedup:.1}x |",
            fmt_duration(upd.median),
            fmt_duration(upd.p99)
        );
        println!(
            "| cold_refit | {k} | {} | {} | 1.0x |",
            fmt_duration(refit.median),
            fmt_duration(refit.p99)
        );
        let mut extra = pcts(&upd).to_vec();
        extra.push(("k", k as f64));
        extra.push(("speedup_vs_refit", speedup));
        report.push_with("online_update", "sparse", n, threads, &upd, &extra);
        let mut extra = pcts(&refit).to_vec();
        extra.push(("k", k as f64));
        report.push_with("cold_refit", "sparse", n, threads, &refit, &extra);
        // the acceptance contract — only meaningful at serving scale
        if n >= 4000 {
            assert!(
                speedup >= 5.0,
                "online update of k={k} at n={n} is only {speedup:.1}x faster than refit"
            );
        }
    }

    // --- snapshot durability -------------------------------------------
    let path = std::env::temp_dir().join(format!("csgp-perf-serving-{}.snap", std::process::id()));
    let mut saves = Vec::with_capacity(reps);
    let mut loads = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        fitted.save_snapshot(&path).unwrap();
        saves.push(t.elapsed());
        let t = Instant::now();
        let _ = FittedClassifier::load_snapshot(&path).unwrap();
        loads.push(t.elapsed());
    }
    let bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
    let _ = std::fs::remove_file(&path);
    let saves = Stats::from_samples(saves);
    let loads = Stats::from_samples(loads);
    println!("\nsnapshot: save {} / load {} ({bytes} bytes)", fmt_duration(saves.median), fmt_duration(loads.median));
    let mut extra = pcts(&saves).to_vec();
    extra.push(("snapshot_bytes", bytes as f64));
    report.push_with("snapshot_save", "sparse", n, threads, &saves, &extra);
    report.push_with("snapshot_load", "sparse", n, threads, &loads, &pcts(&loads));

    // --- prediction service under load ---------------------------------
    let requests = if smoke { 400 } else { 4000 };
    let clients = 8;
    let svc = Arc::new(PredictionService::start(
        Arc::new(fitted),
        None,
        ServiceConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(2),
            ..ServiceConfig::default()
        },
    ));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let svc = svc.clone();
        let per = requests / clients;
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(c as u64 + 1);
            for _ in 0..per {
                let x = vec![rng.uniform_in(0.0, 10.0), rng.uniform_in(0.0, 10.0)];
                svc.predict(x).unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let wall = t0.elapsed();
    let req = svc.stats.request_latency_stats().expect("request samples");
    let bat = svc.stats.batch_latency_stats().expect("batch samples");
    println!(
        "service: {requests} requests in {} ({:.0} req/s) | request p50 {} p99 {} | batch p50 {} p99 {}",
        fmt_duration(wall),
        requests as f64 / wall.as_secs_f64(),
        fmt_duration(req.p50),
        fmt_duration(req.p99),
        fmt_duration(bat.p50),
        fmt_duration(bat.p99),
    );
    let mut extra = pcts(&req).to_vec();
    extra.push(("req_per_s", requests as f64 / wall.as_secs_f64()));
    report.push_with("serve_request", "sparse", n, threads, &req, &extra);
    report.push_with("serve_batch", "sparse", n, threads, &bat, &pcts(&bat));
    svc.shutdown();

    report.write().expect("write BENCH_serving.json");
    println!("\nwrote BENCH_serving.json");
}
