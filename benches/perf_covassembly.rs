//! Perf: covariance assembly through the AOT XLA tile artifact vs the
//! native rust loop — the L1/L2 hot path measured from the L3 side.
//! (Numbers are CPU-PJRT; on a real TPU the tile runs on the MXU and the
//! crossover moves sharply toward XLA — see DESIGN.md §Hardware-Adaptation.)

use std::time::Instant;

use csgp::data::synthetic::uniform_points;
use csgp::gp::covariance::{CovFunction, CovKind};
use csgp::runtime::{Runtime, XlaCovarianceAssembler};

fn main() {
    let Ok(rt) = Runtime::open_default() else {
        println!("artifacts/ not built — run `make artifacts` first");
        return;
    };
    let asm = XlaCovarianceAssembler::new(&rt);
    let full = std::env::var("CSGP_FULL").is_ok();
    let ns: Vec<usize> = if full { vec![512, 1024, 2048, 4096] } else { vec![256, 512, 1024, 2048] };

    println!("# Perf: covariance assembly — XLA tiles vs native rust");
    println!("| n | kind | native | xla (PJRT CPU) | nnz agreement |");
    println!("|---|---|---|---|---|");
    for &n in &ns {
        let x = uniform_points(n, 2, 10.0, 77);
        for kind in [CovKind::Se, CovKind::Pp(3)] {
            let cov = CovFunction::new(kind, 2, 1.0, 1.5);
            let t0 = Instant::now();
            let k_native = cov.cov_matrix(&x);
            let t_native = t0.elapsed();
            let t0 = Instant::now();
            let k_xla = asm.cov_matrix(&cov, &x).unwrap();
            let t_xla = t0.elapsed();
            assert_eq!(k_native.nnz(), k_xla.nnz(), "pattern mismatch");
            println!(
                "| {n} | {:?} | {} | {} | {} nnz ✓ |",
                kind,
                csgp::bench::fmt_duration(t_native),
                csgp::bench::fmt_duration(t_xla),
                k_native.nnz()
            );
        }
    }
}
