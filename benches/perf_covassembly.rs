//! Perf: CS-covariance assembly through the spatial [`NeighborIndex`]
//! (`cov_matrix`, the default path at n ≥ 64) vs the all-pairs O(n²) scan
//! (`cov_matrix_brute`, the seed implementation, kept as the reference /
//! comparison path). The acceptance target is ≥5× at n = 4000, dim = 2,
//! pp3. Also measures the `PatternCache` hit path (values re-evaluated on
//! a cached pattern — what every non-growing SCG step pays) and the
//! cross-covariance column used per prediction.
//!
//! `CSGP_FULL=1` extends the sweep; `CSGP_SKIP_BRUTE=1` drops the
//! brute-force column (for profiling just the indexed path at large n).

use std::time::Instant;

use csgp::bench::{fmt_duration, header, row, Bencher};
use csgp::data::synthetic::uniform_points;
use csgp::geom::NeighborIndex;
use csgp::gp::cache::PatternCache;
use csgp::gp::covariance::{CovFunction, CovKind};
use csgp::sparse::ordering::Ordering;

fn main() {
    let full = std::env::var("CSGP_FULL").is_ok();
    let skip_brute = std::env::var("CSGP_SKIP_BRUTE").is_ok();
    let ns: Vec<usize> =
        if full { vec![1000, 2000, 4000, 8000, 16000] } else { vec![1000, 2000, 4000] };

    println!("# Perf: CS covariance assembly — neighbor index vs brute force");
    println!("# (pp3, dim 2, lengthscale 1.0 on [0,10]²; identical pattern & values)");
    header(&["n", "brute O(n²)", "indexed O(n·k)", "speedup", "cache-hit refill", "nnz"]);
    for &n in &ns {
        let x = uniform_points(n, 2, 10.0, 77);
        let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.0);
        let b = Bencher::quick();

        let indexed = b.run(|| cov.cov_matrix(&x));
        let k_indexed = cov.cov_matrix(&x);

        // the PatternCache hit path: values only, structure reused
        let mut cache = PatternCache::new(Ordering::Natural);
        let cached = cache.pattern_for(&cov, &x);
        let refill = b.run(|| cov.cov_values_on_pattern(&x, &cached.pattern));

        let (brute_cell, speedup_cell) = if skip_brute {
            ("skipped".to_string(), "-".to_string())
        } else {
            let brute = b.run(|| cov.cov_matrix_brute(&x));
            let k_brute = cov.cov_matrix_brute(&x);
            assert_eq!(k_indexed, k_brute, "indexed assembly must match brute force exactly");
            let speedup = brute.median.as_secs_f64() / indexed.median.as_secs_f64();
            (fmt_duration(brute.median), format!("{speedup:.1}x"))
        };
        row(&[
            n.to_string(),
            brute_cell,
            fmt_duration(indexed.median),
            speedup_cell,
            fmt_duration(refill.median),
            k_indexed.nnz().to_string(),
        ]);
    }

    // per-prediction cross-covariance column: indexed vs full scan
    println!("\n# Cross-covariance per test point (pp3, dim 2, n = 4000)");
    header(&["path", "time / query", "nnz(k*)"]);
    let n = 4000;
    let x = uniform_points(n, 2, 10.0, 77);
    let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.0);
    let index = NeighborIndex::build(&x, cov.support_radius().unwrap());
    let queries = uniform_points(256, 2, 10.0, 123);
    let mut rows = Vec::new();
    let mut vals = Vec::new();
    for (label, idx) in [("scan", None), ("indexed", Some(&index))] {
        let t0 = Instant::now();
        let mut nnz = 0usize;
        for q in &queries {
            cov.cross_cov_into(&x, q, idx, &mut rows, &mut vals);
            nnz += rows.len();
        }
        let per = t0.elapsed() / queries.len() as u32;
        row(&[label.to_string(), fmt_duration(per), (nnz / queries.len()).to_string()]);
    }
    println!("\ntarget: indexed assembly >= 5x brute at n = 4000 (pp3, dim 2).");
}
