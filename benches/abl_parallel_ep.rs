//! Ablation: the paper's sequential sparse EP (Algorithm 1, rowmod-based)
//! vs batched "parallel EP" (all sites updated from the same posterior,
//! one refactorization per sweep). Parallel EP needs damping and more
//! sweeps; sequential EP pays the rowmod cost per site.

use std::time::Instant;

use csgp::data::synthetic::{cluster_dataset, ClusterConfig};
use csgp::gp::covariance::{CovFunction, CovKind};
use csgp::gp::ep_parallel::ParallelEp;
use csgp::gp::ep_sparse::SparseEp;
use csgp::gp::marginal::EpOptions;
use csgp::sparse::ordering::Ordering;

fn main() {
    let full = std::env::var("CSGP_FULL").is_ok();
    let ns: Vec<usize> = if full { vec![500, 1000, 2000, 4000] } else { vec![500, 1000, 2000] };
    println!("# Ablation: sequential sparse EP vs parallel EP");
    println!("| n | variant | time | sweeps | logZ |");
    println!("|---|---|---|---|---|");

    for &n in &ns {
        let data = cluster_dataset(&ClusterConfig::paper_2d(n), 21);
        let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.3);
        let opts = EpOptions { max_sweeps: 100, tol: 1e-6, damping: 1.0, ..EpOptions::default() };

        let t0 = Instant::now();
        let seq = SparseEp::run(&cov, &data.x, &data.y, Ordering::Rcm, &opts, None).unwrap();
        let t_seq = t0.elapsed();

        let opts_par = EpOptions { max_sweeps: 300, tol: 1e-6, damping: 0.8, ..EpOptions::default() };
        let t0 = Instant::now();
        let par = ParallelEp::run(&cov, &data.x, &data.y, Ordering::Rcm, &opts_par).unwrap();
        let t_par = t0.elapsed();

        assert!(
            (seq.log_z - par.log_z).abs() < 1e-3 * (1.0 + seq.log_z.abs()),
            "fixed points diverged: {} vs {}",
            seq.log_z,
            par.log_z
        );
        println!(
            "| {n} | sequential (Alg 1) | {} | {} | {:.3} |",
            csgp::bench::fmt_duration(t_seq),
            seq.sweeps,
            seq.log_z
        );
        println!(
            "| {n} | parallel (damped) | {} | {} | {:.3} |",
            csgp::bench::fmt_duration(t_par),
            par.sweeps,
            par.log_z
        );
    }
    println!("\nboth reach the same fixed point; the trade is rowmod-per-site vs damping-induced extra sweeps.");
}
