//! Figure 1: covariance-function shapes — k_se (dashed in the paper) and
//! k_pp,q for input dimensions D = 1, 5, 10, with l_se = 1 and l_pp = 3.
//! Prints the series the figure plots; the qualitative check (pp curves
//! drop faster as D grows, k_se independent of D) is asserted.

use csgp::gp::covariance::{CovFunction, CovKind};

fn main() {
    println!("# Figure 1: covariance profiles (l_se = 1, l_pp = 3)");
    let rs: Vec<f64> = (0..=30).map(|i| i as f64 * 0.1).collect();
    let se = CovFunction::new(CovKind::Se, 1, 1.0, 1.0);

    for q in [0u8, 1, 2, 3] {
        println!("\n## k_pp,{q} vs k_se");
        let mut header = vec!["r".to_string(), "k_se".to_string()];
        for d in [1usize, 5, 10] {
            header.push(format!("pp{q}(D={d})"));
        }
        println!("| {} |", header.join(" | "));
        println!("|{}|", header.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
        for &r in &rs {
            let mut cells = vec![format!("{r:.1}"), format!("{:.4}", se.profile(r))];
            for d in [1usize, 5, 10] {
                // paper scales pp distances by l_pp = 3
                let pp = CovFunction::new(CovKind::Pp(q), d, 1.0, 3.0);
                cells.push(format!("{:.4}", pp.profile(r / 3.0)));
            }
            println!("| {} |", cells.join(" | "));
        }
    }

    // the paper's qualitative claims
    for q in [0u8, 1, 2, 3] {
        let p1 = CovFunction::new(CovKind::Pp(q), 1, 1.0, 3.0);
        let p5 = CovFunction::new(CovKind::Pp(q), 5, 1.0, 3.0);
        let p10 = CovFunction::new(CovKind::Pp(q), 10, 1.0, 3.0);
        let r = 0.5;
        assert!(
            p10.profile(r) < p5.profile(r) && p5.profile(r) < p1.profile(r),
            "pp{q}: correlation must decay faster with D"
        );
    }
    println!("\nqualitative check: decay rate increases with D for all pp_q ✓ (k_se D-independent by construction)");
}
