//! Ablation: fill-reducing orderings (the paper's stated future work —
//! "a detailed evaluation of different permutation algorithms"). Reports
//! fill-L and the numeric factorization time under natural / RCM /
//! greedy-min-degree orderings on the paper's geometric matrices.

use std::sync::Arc;
use std::time::Instant;

use csgp::data::synthetic::{cluster_dataset, ClusterConfig};
use csgp::gp::covariance::{CovFunction, CovKind};
use csgp::sparse::cholesky::LdlFactor;
use csgp::sparse::ordering::{compute_ordering, Ordering};
use csgp::sparse::symbolic::Symbolic;

fn main() {
    let full = std::env::var("CSGP_FULL").is_ok();
    let ns: Vec<usize> = if full { vec![1000, 2000, 4000] } else { vec![500, 1000, 2000] };
    println!("# Ablation: ordering algorithms (pp3 covariance matrices)");
    println!("| dim | n | ordering | fill-K | fill-L | ordering time | factor time |");
    println!("|---|---|---|---|---|---|---|");

    for (dim, ls) in [(2usize, 1.3), (5usize, 5.0)] {
        for &n in &ns {
            let cfg = if dim == 2 { ClusterConfig::paper_2d(n) } else { ClusterConfig::paper_5d(n) };
            let data = cluster_dataset(&cfg, 9);
            let cov = CovFunction::new(CovKind::Pp(3), dim, 1.0, ls);
            let k0 = cov.cov_matrix(&data.x);
            for ord in [Ordering::Natural, Ordering::Rcm, Ordering::MinDegree] {
                if ord == Ordering::MinDegree && dim == 5 && n > 1000 {
                    // greedy min-degree is quadratic on dense-ish graphs
                    println!("| {dim}D | {n} | {ord:?} | — | skipped (quadratic) | | |");
                    continue;
                }
                let t0 = Instant::now();
                let perm = compute_ordering(&k0, ord);
                let t_ord = t0.elapsed();
                let kp = k0.permute_sym(&perm);
                let sym = Arc::new(Symbolic::analyze(&kp));
                let t0 = Instant::now();
                let _f = LdlFactor::factor(sym.clone(), &kp).unwrap();
                let t_fac = t0.elapsed();
                println!(
                    "| {dim}D | {n} | {ord:?} | {:.3} | {:.3} | {} | {} |",
                    k0.density(),
                    sym.fill_l(),
                    csgp::bench::fmt_duration(t_ord),
                    csgp::bench::fmt_duration(t_fac)
                );
            }
        }
    }
    println!("\nexpectation: RCM/min-degree beat natural; the fill gap drives the EP speedup (paper §5.4).");
}
