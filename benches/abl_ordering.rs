//! Ablation: fill-reducing orderings (the paper's stated future work —
//! "a detailed evaluation of different permutation algorithms"). Reports
//! fill-L, ordering time, factor time and the supernodal wave shape under
//! natural / RCM / quotient-min-degree / nested-dissection / auto
//! orderings on the paper's geometric matrices. ND runs its geometric
//! fast path (the data's coordinates are passed through), which is the
//! configuration the `Ordering::Auto` policy deploys.
//!
//! `CSGP_SMOKE=1` shrinks the sweep to one tiny 2-D case — the CI smoke
//! run that keeps the ND and Auto code paths from rotting.
//! `CSGP_FULL=1` grows it to n = 4000.

use std::sync::Arc;
use std::time::Instant;

use csgp::data::synthetic::{cluster_dataset, ClusterConfig};
use csgp::gp::covariance::{CovFunction, CovKind};
use csgp::sparse::cholesky::LdlFactor;
use csgp::sparse::ordering::{order, Ordering};
use csgp::sparse::symbolic::Symbolic;

fn main() {
    let smoke = std::env::var("CSGP_SMOKE").is_ok();
    let full = std::env::var("CSGP_FULL").is_ok();
    let ns: Vec<usize> = if smoke {
        vec![300]
    } else if full {
        vec![1000, 2000, 4000]
    } else {
        vec![500, 1000, 2000]
    };
    let dims: &[(usize, f64)] = if smoke { &[(2, 1.3)] } else { &[(2, 1.3), (5, 5.0)] };
    println!("# Ablation: ordering algorithms (pp3 covariance matrices)");
    println!(
        "| dim | n | ordering | fill-K | fill-L | order time | factor time | waves | max wave width |"
    );
    println!("|---|---|---|---|---|---|---|---|---|");

    for &(dim, ls) in dims {
        for &n in &ns {
            let cfg = if dim == 2 { ClusterConfig::paper_2d(n) } else { ClusterConfig::paper_5d(n) };
            let data = cluster_dataset(&cfg, 9);
            let cov = CovFunction::new(CovKind::Pp(3), dim, 1.0, ls);
            let k0 = cov.cov_matrix(&data.x);
            for ord in [
                Ordering::Natural,
                Ordering::Rcm,
                Ordering::MinDegree,
                Ordering::Nd,
                Ordering::Auto,
            ] {
                let t0 = Instant::now();
                let res = order(&k0, ord, Some(&data.x));
                let t_ord = t0.elapsed();
                let kp = k0.permute_sym(&res.perm);
                let sym =
                    Arc::new(Symbolic::analyze_with_septree(&kp, res.septree.map(Arc::new)));
                let t0 = Instant::now();
                let _f = LdlFactor::factor(sym.clone(), &kp).unwrap();
                let t_fac = t0.elapsed();
                let label = if ord == Ordering::Auto {
                    format!("Auto->{:?}", res.resolved)
                } else {
                    format!("{ord:?}")
                };
                println!(
                    "| {dim}D | {n} | {label} | {:.3} | {:.3} | {} | {} | {} | {} |",
                    k0.density(),
                    sym.fill_l(),
                    csgp::bench::fmt_duration(t_ord),
                    csgp::bench::fmt_duration(t_fac),
                    sym.schedule.n_waves(),
                    sym.schedule.wave_width_max(),
                );
            }
        }
    }
    println!(
        "\nexpectation: RCM/min-degree/ND beat natural on fill (paper §5.4); ND's \
         max wave width beats RCM's by an order of magnitude at n >= 2000 — the \
         parallel factorization's headroom — and the quotient-graph min-degree \
         orders n = 4000 in well under a second."
    );
}
