//! Figure 2: for data simulated from a GP with a pp_q covariance on
//! [0,10]² (+0.04 I noise), train GPs whose pp_q uses a *different*
//! Wendland dimension parameter D, and record the posterior-mode
//! length-scale and the resulting covariance fill. The paper's finding:
//! both grow with D (the pp family needs longer length-scales in higher
//! "nominal" dimension to capture the same correlations, densifying K).
//!
//! Scaled down from the paper's 10 datasets / D up to 70 to keep the
//! bench minutes-scale; CSGP_FULL=1 restores a denser sweep.

use csgp::gp::covariance::{CovFunction, CovKind};
use csgp::gp::regression::{optimize_hypers, sample_gp};
use csgp::data::synthetic::uniform_points as random_points;
use csgp::rng::Rng;

fn main() {
    let full = std::env::var("CSGP_FULL").is_ok();
    let n = if full { 250 } else { 150 };
    let n_datasets = if full { 10 } else { 4 };
    let dims: Vec<usize> =
        if full { (1..=14).map(|k| k * 5).collect() } else { vec![2, 5, 10, 20, 35, 50, 70] };
    let noise = 0.04;

    println!("# Figure 2: posterior length-scale mode and fill-K vs Wendland D");
    println!("(data simulated from pp_q with D=2, l=2 on [0,10]^2, n={n}, {n_datasets} replicates)");
    println!("| q | D | lengthscale (mean ± sd) | fill-K (mean ± sd) |");
    println!("|---|---|---|---|");

    for q in [0u8, 1, 2, 3] {
        let mut base_fill = f64::NAN;
        for &dparam in &dims {
            let mut ls = Vec::new();
            let mut fills = Vec::new();
            for rep in 0..n_datasets {
                let seed = 1000 + rep as u64;
                let x = random_points(n, 2, 10.0, seed);
                let truth = CovFunction::new(CovKind::Pp(q), 2, 1.0, 2.0);
                let mut rng = Rng::new(seed);
                let y = sample_gp(&truth, noise, &x, &mut rng);
                // train with the same family but Wendland parameter D
                // (the data stays 2-D: D only sets the exponent j)
                let mut start = CovFunction::new(CovKind::Pp(q), dparam, 1.0, 2.0);
                start.lengthscales = vec![2.0; 2];
                let (fit, _) = optimize_hypers(&start, noise, &x, &y, 40);
                ls.push(fit.lengthscales[0]);
                fills.push(fit.cov_matrix(&x).density());
            }
            let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
            let sd = |v: &[f64]| {
                let m = mean(v);
                (v.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / v.len() as f64).sqrt()
            };
            println!(
                "| pp{q} | {dparam} | {:.2} ± {:.2} | {:.3} ± {:.3} |",
                mean(&ls),
                sd(&ls),
                mean(&fills),
                sd(&fills)
            );
            if dparam == dims[0] {
                base_fill = mean(&fills);
            } else if dparam == *dims.last().unwrap() {
                let final_fill = mean(&fills);
                println!(
                    "| pp{q} | — | fill growth D={} → D={}: {:.2}× | |",
                    dims[0],
                    dparam,
                    final_fill / base_fill
                );
            }
        }
    }
    println!("\npaper shape: both the length-scale mode and fill-K increase with D.");
}
