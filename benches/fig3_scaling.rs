//! Figure 3: EP running time and classification error vs training-set
//! size, for the k_se full GP (dense EP), the k_pp3 CS GP (the paper's
//! sparse EP) and FIC — on the paper's 2-D and 5-D cluster data.
//!
//! Default sweep caps n (dense EP is O(n³); the paper's 10⁴ point takes
//! hours). CSGP_FULL=1 extends the sweep. Times are a single EP run to
//! convergence at fixed, sensible hyperparameters (the paper measures at
//! the posterior mode; the *ratio* between methods is what Figure 3
//! conveys and is preserved). Sparse covariance assembly goes through the
//! `geom::NeighborIndex` path (O(n·k) candidate pairs), so at the large-n
//! end of the sweep the EP column measures EP, not the O(n²) assembly the
//! seed paid on top of it.

use std::time::Instant;

use csgp::data::kmeans::kmeans;
use csgp::data::synthetic::{cluster_dataset, ClusterConfig};
use csgp::gp::covariance::{AdditiveCov, CovFunction, CovKind};
use csgp::gp::marginal::EpOptions;
use csgp::gp::model::{GpClassifier, Inference};
use csgp::gp::{CsFicEp, ParallelEp, SparseEp};
use csgp::sparse::ordering::Ordering;

fn main() {
    let full = std::env::var("CSGP_FULL").is_ok();
    let ns_dense: Vec<usize> = if full { vec![500, 1000, 2000, 5000] } else { vec![500, 1000] };
    let ns_sparse: Vec<usize> =
        if full { vec![500, 1000, 2000, 5000, 10000] } else { vec![500, 1000, 2000] };
    let n_test = 1000;

    println!("# Figure 3: EP run time and classification error vs n");
    for (dim, ls_pp, ls_se) in [(2usize, 1.3, 1.3), (5usize, 5.0, 3.0)] {
        println!("\n## {dim}-D cluster data");
        println!("| model | n | EP time | test err | fill-K | fill-L |");
        println!("|---|---|---|---|---|---|");
        let cfg_max = *ns_sparse.iter().max().unwrap() + n_test;
        let cfg = if dim == 2 {
            ClusterConfig::paper_2d(cfg_max)
        } else {
            ClusterConfig::paper_5d(cfg_max)
        };
        let data = cluster_dataset(&cfg, 42);

        for (label, ns, model_for_dim) in [
            (
                "k_se (dense EP)",
                &ns_dense,
                GpClassifier::new(CovFunction::new(CovKind::Se, dim, 1.0, ls_se), Inference::Dense),
            ),
            (
                "k_pp3 (sparse EP)",
                &ns_sparse,
                GpClassifier::new(
                    CovFunction::new(CovKind::Pp(3), dim, 1.0, ls_pp),
                    Inference::Sparse(Ordering::Rcm),
                ),
            ),
            (
                "FIC m=400 (EP)",
                &ns_sparse,
                GpClassifier::new(
                    CovFunction::new(CovKind::Se, dim, 1.0, ls_se),
                    Inference::Fic { m: 400 },
                ),
            ),
            (
                "CS+FIC m=64 (EP)",
                &ns_sparse,
                GpClassifier::new_cs_fic(
                    CovFunction::new(CovKind::Pp(3), dim, 1.0, ls_pp),
                    CovFunction::new(CovKind::Se, dim, 0.7, ls_se * 2.0),
                    64,
                )
                .unwrap(),
            ),
        ] {
            for &n in ns.iter() {
                let (train, rest) = data.split(n);
                let test = csgp::data::Dataset {
                    name: "test".into(),
                    x: rest.x[..n_test.min(rest.n())].to_vec(),
                    y: rest.y[..n_test.min(rest.n())].to_vec(),
                };
                let t0 = Instant::now();
                let fitted = match model_for_dim.infer_only(&train.x, &train.y) {
                    Ok(f) => f,
                    Err(e) => {
                        println!("| {label} | {n} | FAILED: {e} | | | |");
                        continue;
                    }
                };
                let ep_time = t0.elapsed();
                let m = fitted.evaluate(&test.x, &test.y);
                println!(
                    "| {label} | {n} | {} | {:.3} | {:.3} | {:.3} |",
                    csgp::bench::fmt_duration(ep_time),
                    m.err,
                    fitted.report.fill_k,
                    fitted.report.fill_l
                );
            }
        }
    }
    // ---- hybrid per-sweep cost at n >= 4000 ----------------------------
    // The CS+FIC acceptance bar: a hybrid sweep (parallel site updates
    // through the sparse-plus-low-rank Woodbury solver) must stay within
    // ~2x of a CS-only sweep at the same n. Compared against both the
    // sequential rowmod sweep (SparseEp) and the apples-to-apples batched
    // sweep (ParallelEp).
    let n_big = if full { 8000 } else { 4000 };
    println!("\n## hybrid vs CS-only per-sweep cost (2-D, n = {n_big})");
    let cfg = ClusterConfig::paper_2d(n_big + 100);
    let data = cluster_dataset(&cfg, 7);
    let (train, _) = data.split(n_big);
    let cs = CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.3);
    let opts = EpOptions { max_sweeps: 40, tol: 1e-6, damping: 0.8, ..EpOptions::default() };
    let t0 = Instant::now();
    let seq = SparseEp::run(&cs, &train.x, &train.y, Ordering::Rcm, &opts, None).unwrap();
    let t_seq = t0.elapsed() / seq.sweeps.max(1) as u32;
    let t0 = Instant::now();
    let par = ParallelEp::run(&cs, &train.x, &train.y, Ordering::Rcm, &opts).unwrap();
    let t_par = t0.elapsed() / par.sweeps.max(1) as u32;
    let add = AdditiveCov::new(CovFunction::new(CovKind::Se, 2, 0.7, 2.6), cs.clone()).unwrap();
    let xu = kmeans(&train.x, 64, 25, 0xf1c);
    let t0 = Instant::now();
    let hy = CsFicEp::run(&add, &train.x, &train.y, &xu, &opts).unwrap();
    let t_hy = t0.elapsed() / hy.sweeps.max(1) as u32;
    let (s_seq, s_par, s_hy) = (
        csgp::bench::fmt_duration(t_seq),
        csgp::bench::fmt_duration(t_par),
        csgp::bench::fmt_duration(t_hy),
    );
    println!("| sweep | time/sweep | sweeps |");
    println!("|---|---|---|");
    println!("| CS-only sequential (rowmod) | {s_seq} | {} |", seq.sweeps);
    println!("| CS-only parallel (refactor) | {s_par} | {} |", par.sweeps);
    println!("| CS+FIC hybrid (m=64) | {s_hy} | {} |", hy.sweeps);
    println!(
        "hybrid/parallel ratio: {:.2}x (target <= ~2x)",
        t_hy.as_secs_f64() / t_par.as_secs_f64().max(1e-12)
    );

    println!("\npaper shape: pp3 ~10-20x faster than se at 2-D, ~3-7x at 5-D; FIC ~linear in n but worst error on fast-varying latents; CS+FIC tracks the CS cost while adding the global trend.");
}
