//! Figure 3: EP running time and classification error vs training-set
//! size, for the k_se full GP (dense EP), the k_pp3 CS GP (the paper's
//! sparse EP) and FIC — on the paper's 2-D and 5-D cluster data.
//!
//! Default sweep caps n (dense EP is O(n³); the paper's 10⁴ point takes
//! hours). CSGP_FULL=1 extends the sweep. Times are a single EP run to
//! convergence at fixed, sensible hyperparameters (the paper measures at
//! the posterior mode; the *ratio* between methods is what Figure 3
//! conveys and is preserved). Sparse covariance assembly goes through the
//! `geom::NeighborIndex` path (O(n·k) candidate pairs), so at the large-n
//! end of the sweep the EP column measures EP, not the O(n²) assembly the
//! seed paid on top of it.

use std::time::Instant;

use csgp::data::synthetic::{cluster_dataset, ClusterConfig};
use csgp::gp::covariance::{CovFunction, CovKind};
use csgp::gp::model::{GpClassifier, Inference};
use csgp::sparse::ordering::Ordering;

fn main() {
    let full = std::env::var("CSGP_FULL").is_ok();
    let ns_dense: Vec<usize> = if full { vec![500, 1000, 2000, 5000] } else { vec![500, 1000] };
    let ns_sparse: Vec<usize> =
        if full { vec![500, 1000, 2000, 5000, 10000] } else { vec![500, 1000, 2000] };
    let n_test = 1000;

    println!("# Figure 3: EP run time and classification error vs n");
    for (dim, ls_pp, ls_se) in [(2usize, 1.3, 1.3), (5usize, 5.0, 3.0)] {
        println!("\n## {dim}-D cluster data");
        println!("| model | n | EP time | test err | fill-K | fill-L |");
        println!("|---|---|---|---|---|---|");
        let cfg_max = *ns_sparse.iter().max().unwrap() + n_test;
        let cfg = if dim == 2 {
            ClusterConfig::paper_2d(cfg_max)
        } else {
            ClusterConfig::paper_5d(cfg_max)
        };
        let data = cluster_dataset(&cfg, 42);

        for (label, ns, model_for_dim) in [
            (
                "k_se (dense EP)",
                &ns_dense,
                GpClassifier::new(CovFunction::new(CovKind::Se, dim, 1.0, ls_se), Inference::Dense),
            ),
            (
                "k_pp3 (sparse EP)",
                &ns_sparse,
                GpClassifier::new(
                    CovFunction::new(CovKind::Pp(3), dim, 1.0, ls_pp),
                    Inference::Sparse(Ordering::Rcm),
                ),
            ),
            (
                "FIC m=400 (EP)",
                &ns_sparse,
                GpClassifier::new(
                    CovFunction::new(CovKind::Se, dim, 1.0, ls_se),
                    Inference::Fic { m: 400 },
                ),
            ),
        ] {
            for &n in ns.iter() {
                let (train, rest) = data.split(n);
                let test = csgp::data::Dataset {
                    name: "test".into(),
                    x: rest.x[..n_test.min(rest.n())].to_vec(),
                    y: rest.y[..n_test.min(rest.n())].to_vec(),
                };
                let t0 = Instant::now();
                let fitted = match model_for_dim.infer_only(&train.x, &train.y) {
                    Ok(f) => f,
                    Err(e) => {
                        println!("| {label} | {n} | FAILED: {e} | | | |");
                        continue;
                    }
                };
                let ep_time = t0.elapsed();
                let m = fitted.evaluate(&test.x, &test.y);
                println!(
                    "| {label} | {n} | {} | {:.3} | {:.3} | {:.3} |",
                    csgp::bench::fmt_duration(ep_time),
                    m.err,
                    fitted.report.fill_k,
                    fitted.report.fill_l
                );
            }
        }
    }
    println!("\npaper shape: pp3 ~10-20x faster than se at 2-D, ~3-7x at 5-D; FIC ~linear in n but worst error on fast-varying latents.");
}
