//! Ablation: Takahashi sparsified inverse (paper eq. 11) vs a dense
//! B⁻¹ for the marginal-likelihood gradient trace term.

use std::sync::Arc;
use std::time::Instant;

use csgp::data::synthetic::{cluster_dataset, ClusterConfig};
use csgp::gp::covariance::{CovFunction, CovKind};
use csgp::gp::ep_sparse::build_b;
use csgp::sparse::cholesky::LdlFactor;
use csgp::sparse::ordering::{compute_ordering, Ordering};
use csgp::sparse::symbolic::Symbolic;

fn main() {
    let full = std::env::var("CSGP_FULL").is_ok();
    let ns: Vec<usize> = if full { vec![500, 1000, 2000, 4000] } else { vec![500, 1000, 2000] };
    println!("# Ablation: Takahashi Z^sp vs dense inverse for tr(Z ∂K)");
    println!("| n | fill-L | takahashi | dense inverse | speedup | max |Δtrace| |");
    println!("|---|---|---|---|---|---|");

    for &n in &ns {
        let data = cluster_dataset(&ClusterConfig::paper_2d(n), 5);
        let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.3);
        let k0 = cov.cov_matrix(&data.x);
        let perm = compute_ordering(&k0, Ordering::Rcm);
        let k = k0.permute_sym(&perm);
        let sym = Arc::new(Symbolic::analyze(&k));
        let tau = vec![1.5; n];
        let b = build_b(&k, &tau);
        let f = LdlFactor::factor(sym.clone(), &b).unwrap();

        // Takahashi path
        let t0 = Instant::now();
        let zsp = f.takahashi_inverse();
        let mut tr_sparse = 0.0;
        for j in 0..n {
            for p in k.col_ptr[j]..k.col_ptr[j + 1] {
                let i = k.row_idx[p];
                tr_sparse += zsp.get(&sym, i, j).unwrap() * k.values[p];
            }
        }
        let t_tak = t0.elapsed();

        // dense-inverse path (n solves)
        let t0 = Instant::now();
        let mut tr_dense = 0.0;
        let mut e = vec![0.0; n];
        for j in 0..n {
            e[j] = 1.0;
            let col = f.solve(&e);
            e[j] = 0.0;
            for p in k.col_ptr[j]..k.col_ptr[j + 1] {
                tr_dense += col[k.row_idx[p]] * k.values[p];
            }
        }
        let t_dense = t0.elapsed();

        let diff = (tr_sparse - tr_dense).abs() / (1.0 + tr_dense.abs());
        assert!(diff < 1e-8, "trace mismatch: {tr_sparse} vs {tr_dense}");
        println!(
            "| {n} | {:.3} | {} | {} | {:.1}x | {:.1e} |",
            sym.fill_l(),
            csgp::bench::fmt_duration(t_tak),
            csgp::bench::fmt_duration(t_dense),
            t_dense.as_secs_f64() / t_tak.as_secs_f64(),
            diff
        );
    }
    println!("\nexpectation: Takahashi computes the exact same trace in a fraction of the time.");
}
