//! Tables 2 & 3: the six UCI(-analogue) datasets — classification error
//! and nlpd by cross-validation (Table 2), hyperparameter-optimization
//! time, single-EP time and fill-L (Table 3) for k_se (dense EP), k_pp3
//! (sparse EP) and FIC (m = 10, as in the paper).
//!
//! Default: 5-fold CV at fixed hyperparameters plus a single-fold
//! optimization run for the opt column (the paper optimizes in every
//! fold; that protocol is minutes-to-hours — CSGP_FULL=1 enables 10-fold
//! and per-fold optimization on the small datasets).

use std::time::Instant;

use csgp::data::cv::cross_validate;
use csgp::data::uci::{generate, UCI_SPECS};
use csgp::gp::covariance::{CovFunction, CovKind};
use csgp::gp::model::{GpClassifier, Inference};
use csgp::sparse::ordering::Ordering;

fn main() {
    let full = std::env::var("CSGP_FULL").is_ok();
    let folds = if full { 10 } else { 5 };
    println!("# Tables 2 & 3: UCI-analogue datasets ({folds}-fold CV)");
    println!("NOTE: synthetic analogues with the paper's (n, d) — see DESIGN.md §Substitutions;");
    println!("absolute err/nlpd are not comparable to the paper, relative cost columns are.\n");
    println!("| dataset | n/d | model | err | nlpd | opt | EP | fill-L |");
    println!("|---|---|---|---|---|---|---|---|");

    for spec in &UCI_SPECS {
        let data = generate(spec, 11);
        let models: Vec<(&str, GpClassifier)> = vec![
            (
                "k_se",
                GpClassifier::new(CovFunction::new(CovKind::Se, spec.d, 1.0, 2.5), Inference::Dense),
            ),
            (
                "k_pp3",
                GpClassifier::new(
                    CovFunction::new(CovKind::Pp(3), spec.d, 1.0, 4.0),
                    Inference::Sparse(Ordering::Rcm),
                ),
            ),
            (
                "FIC",
                GpClassifier::new(
                    CovFunction::new(CovKind::Se, spec.d, 1.0, 2.5),
                    Inference::Fic { m: 10 },
                ),
            ),
        ];
        for (name, mut model) in models {
            model.opt_opts.max_iters = if full { 12 } else { 3 };
            // CV for err/nlpd (+ per-fold EP time)
            let res = match cross_validate(&model, &data, folds, full, 3) {
                Ok(r) => r,
                Err(e) => {
                    println!("| {} | {}/{} | {name} | FAILED: {e} | | | | |", spec.name, spec.n, spec.d);
                    continue;
                }
            };
            // one optimization run on the full data for the opt column
            let t0 = Instant::now();
            let fitted = model.fit(&data.x, &data.y);
            let opt_time = t0.elapsed();
            let fill_l = fitted.as_ref().map(|f| f.report.fill_l).unwrap_or(f64::NAN);
            println!(
                "| {} | {}/{} | {name} | {:.3} | {:.3} | {} | {} | {:.2} |",
                spec.name,
                spec.n,
                spec.d,
                res.err,
                res.nlpd,
                csgp::bench::fmt_duration(opt_time),
                csgp::bench::fmt_duration(res.ep_time),
                fill_l
            );
        }
    }
    println!("\npaper shape: pp3 EP-run ≤ se EP-run even at fill-L ≈ 1; FIC per-EP fastest; pp3 ≈ se in err/nlpd.");
}
