//! Ablation: the paper's `ldlrowmodify` (Algorithm 2) vs a full sparse
//! refactorization after every site update — the cost the paper's EP
//! would pay without the row-modification machinery. Also reports the
//! per-site dense rank-one-update cost (the classical O(n²) EP update,
//! eq. 4) for reference.

use std::sync::Arc;
use std::time::Instant;

use csgp::data::synthetic::{cluster_dataset, ClusterConfig};
use csgp::gp::covariance::{CovFunction, CovKind};
use csgp::gp::ep_sparse::build_b;
use csgp::sparse::cholesky::LdlFactor;
use csgp::sparse::ordering::{compute_ordering, Ordering};
use csgp::sparse::rowmod::RowModWorkspace;
use csgp::sparse::symbolic::Symbolic;

fn main() {
    let full = std::env::var("CSGP_FULL").is_ok();
    let ns: Vec<usize> = if full { vec![500, 1000, 2000, 4000] } else { vec![500, 1000, 2000] };
    println!("# Ablation: ldlrowmodify vs refactor-per-site (one full sweep of n site updates)");
    println!("| n | fill-L | rowmod sweep | refactor sweep | speedup |");
    println!("|---|---|---|---|---|");

    for &n in &ns {
        let data = cluster_dataset(&ClusterConfig::paper_2d(n), 3);
        let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.3);
        let k0 = cov.cov_matrix(&data.x);
        let perm = compute_ordering(&k0, Ordering::Rcm);
        let k = k0.permute_sym(&perm);
        let sym = Arc::new(Symbolic::analyze(&k));
        // pretend EP reached τ̃ = 1 everywhere; modify each row to τ̃ = 2
        let tau1 = vec![1.0; n];
        let b1 = build_b(&k, &tau1);

        // rowmod sweep
        let mut f = LdlFactor::factor(sym.clone(), &b1).unwrap();
        let mut ws = RowModWorkspace::new(n);
        let t0 = Instant::now();
        let mut tau = tau1.clone();
        for i in 0..n {
            tau[i] = 2.0;
            let (rows, kvals) = k.col(i);
            let sti = tau[i].sqrt();
            let vals: Vec<f64> = rows
                .iter()
                .zip(kvals)
                .map(|(&r, &v)| {
                    let base = tau[r].sqrt() * sti * v;
                    if r == i {
                        1.0 + base
                    } else {
                        base
                    }
                })
                .collect();
            f.ldl_row_modify(i, rows, &vals, &mut ws).unwrap();
        }
        let t_rowmod = t0.elapsed();

        // refactor-per-site sweep
        let mut f2 = LdlFactor::factor(sym.clone(), &b1).unwrap();
        let mut tau = tau1.clone();
        let t0 = Instant::now();
        for i in 0..n {
            tau[i] = 2.0;
            let b = build_b(&k, &tau);
            f2.refactor(&b).unwrap();
        }
        let t_refac = t0.elapsed();

        // verify both sweeps agree
        let dd: f64 =
            f.d.iter().zip(&f2.d).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        assert!(dd < 1e-7, "rowmod and refactor disagree: {dd}");

        println!(
            "| {n} | {:.3} | {} | {} | {:.1}x |",
            sym.fill_l(),
            csgp::bench::fmt_duration(t_rowmod),
            csgp::bench::fmt_duration(t_refac),
            t_refac.as_secs_f64() / t_rowmod.as_secs_f64()
        );
    }
    println!("\nexpectation: rowmod sweeps are several times cheaper; the gap widens with n.");
}
