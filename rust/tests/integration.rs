//! Integration tests over the public API: data generation → model fit →
//! prediction → metrics, across inference backends and covariance
//! families, plus invariants that span modules (ordering × EP × predict).

use csgp::data::synthetic::{cluster_dataset, uniform_points, ClusterConfig};
use csgp::data::uci;
use csgp::gp::covariance::{CovFunction, CovKind};
use csgp::gp::marginal::EpOptions;
use csgp::gp::model::{GpClassifier, Inference};
use csgp::gp::SparseEp;
use csgp::rng::Rng;
use csgp::sparse::ordering::Ordering;

fn cluster(n: usize, seed: u64) -> csgp::data::Dataset {
    cluster_dataset(&ClusterConfig::paper_2d(n), seed)
}

#[test]
fn full_pipeline_sparse_pp3_beats_chance_substantially() {
    let data = cluster(700, 3);
    let (train, test) = data.split(500);
    let model = GpClassifier::new(
        CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.5),
        Inference::Sparse(Ordering::Rcm),
    );
    let fitted = model.infer_only(&train.x, &train.y).unwrap();
    let m = fitted.evaluate(&test.x, &test.y);
    assert!(m.err < 0.30, "err = {}", m.err);
    assert!(m.nlpd < 0.65, "nlpd = {}", m.nlpd);
    // probabilities are calibrated-ish: mean prob of predicted class > 0.5
    let probs = fitted.predict_proba(&test.x);
    let conf: f64 =
        probs.iter().map(|&p| p.max(1.0 - p)).sum::<f64>() / probs.len() as f64;
    assert!(conf > 0.6, "mean confidence {conf}");
}

#[test]
fn every_covariance_family_runs_through_sparse_ep() {
    let data = cluster(120, 9);
    for kind in [
        CovKind::Pp(0),
        CovKind::Pp(1),
        CovKind::Pp(2),
        CovKind::Pp(3),
        CovKind::Matern32,
        CovKind::Matern52,
        CovKind::Se,
    ] {
        // globally supported kernels exercise the dense-pattern path
        let ls = if matches!(kind, CovKind::Pp(_)) { 1.8 } else { 1.2 };
        let cov = CovFunction::new(kind, 2, 1.0, ls);
        let ep = SparseEp::run(&cov, &data.x, &data.y, Ordering::Rcm, &EpOptions::default(), None)
            .unwrap_or_else(|e| panic!("{kind:?}: {e}"));
        assert!(ep.log_z.is_finite(), "{kind:?}");
        assert!(ep.converged, "{kind:?} did not converge");
    }
}

#[test]
fn ordering_choice_does_not_change_the_answer() {
    // every ordering — the new nested-dissection and quotient min-degree
    // included — is exact: EP reaches the same fixed point up to the
    // permutation, only the fill differs
    let data = cluster(150, 21);
    let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.6);
    let opts = EpOptions { max_sweeps: 100, tol: 1e-10, damping: 1.0, ..EpOptions::default() };
    let runs: Vec<SparseEp> =
        [Ordering::Natural, Ordering::Rcm, Ordering::MinDegree, Ordering::Nd, Ordering::Auto]
            .iter()
            .map(|&o| SparseEp::run(&cov, &data.x, &data.y, o, &opts, None).unwrap())
            .collect();
    for pair in runs.windows(2) {
        assert!(
            (pair[0].log_z - pair[1].log_z).abs() < 1e-7,
            "{} vs {}",
            pair[0].log_z,
            pair[1].log_z
        );
        // predictions agree at random probe points
        let mut rng = Rng::new(5);
        for _ in 0..10 {
            let p = vec![rng.uniform_in(0.0, 10.0), rng.uniform_in(0.0, 10.0)];
            let (m0, v0) = pair[0].predict_latent(&cov, &p);
            let (m1, v1) = pair[1].predict_latent(&cov, &p);
            assert!((m0 - m1).abs() < 1e-6 && (v0 - v1).abs() < 1e-6);
        }
    }
    // but the fill should differ (that's the point of ordering)
    assert!(runs[0].fill_l > runs[1].fill_l, "natural should have more fill than RCM");
}

#[test]
fn uci_analogues_fit_with_all_models() {
    let spec = uci::UCI_SPECS.iter().find(|s| s.name == "crabs").unwrap();
    let data = uci::generate(spec, 4);
    for inference in [
        Inference::Dense,
        Inference::Sparse(Ordering::Rcm),
        Inference::Fic { m: 12 },
    ] {
        let kind =
            if matches!(inference, Inference::Sparse(_)) { CovKind::Pp(3) } else { CovKind::Se };
        let model = GpClassifier::new(CovFunction::new(kind, spec.d, 1.0, 3.0), inference);
        let fitted = model.infer_only(&data.x, &data.y).unwrap();
        let m = fitted.evaluate(&data.x, &data.y); // train-set sanity
        assert!(m.err < 0.35, "{:?}: train err {}", fitted.report.log_z, m.err);
    }
    // CS+FIC hybrid: local pp3 term plus a global SE trend through 12
    // k-means inducing points
    let model = GpClassifier::new_cs_fic(
        CovFunction::new(CovKind::Pp(3), spec.d, 1.0, 3.0),
        CovFunction::new(CovKind::Se, spec.d, 1.0, 3.0),
        12,
    )
    .unwrap();
    let fitted = model.infer_only(&data.x, &data.y).unwrap();
    let m = fitted.evaluate(&data.x, &data.y);
    assert!(m.err < 0.35, "cs+fic train err {}", m.err);
}

#[test]
fn hyperparameter_optimization_moves_toward_the_data_scale() {
    // data drawn with lengthscale 2: starting from 0.5, the MAP search
    // should increase the lengthscale and the log posterior
    let x = uniform_points(150, 2, 10.0, 31);
    let truth = CovFunction::new(CovKind::Pp(3), 2, 1.0, 2.0);
    let mut rng = Rng::new(8);
    let f = csgp::gp::regression::sample_gp(&truth, 1e-6, &x, &mut rng);
    let y: Vec<f64> = f.iter().map(|&v| if v > 0.0 { 1.0 } else { -1.0 }).collect();
    let mut model = GpClassifier::new(
        CovFunction::new(CovKind::Pp(3), 2, 1.0, 0.5),
        Inference::Sparse(Ordering::Rcm),
    );
    model.opt_opts.max_iters = 12;
    let before = model.infer_only(&x, &y).unwrap().report.log_post;
    let fitted = model.fit(&x, &y).unwrap();
    assert!(fitted.report.log_post > before, "{} !> {before}", fitted.report.log_post);
    assert!(
        fitted.cov.lengthscales[0] > 0.5,
        "lengthscale should grow from 0.5, got {}",
        fitted.cov.lengthscales[0]
    );
}

#[test]
fn sparse_ep_scales_better_than_dense_on_sparse_problems() {
    // not a benchmark — just the qualitative invariant on a mid-size case
    let data = cluster(800, 77);
    let cov_cs = CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.2);
    let cov_se = CovFunction::new(CovKind::Se, 2, 1.0, 1.2);
    let t0 = std::time::Instant::now();
    let se_sparse = GpClassifier::new(cov_cs, Inference::Sparse(Ordering::Rcm))
        .infer_only(&data.x, &data.y)
        .unwrap();
    let t_sparse = t0.elapsed();
    let t0 = std::time::Instant::now();
    let _de = GpClassifier::new(cov_se, Inference::Dense).infer_only(&data.x, &data.y).unwrap();
    let t_dense = t0.elapsed();
    assert!(
        t_sparse < t_dense,
        "sparse {t_sparse:?} should beat dense {t_dense:?} (fill-L {})",
        se_sparse.report.fill_l
    );
}

#[test]
fn batched_prediction_matches_per_point_calls() {
    // the batched path shares one neighbor index + one solve workspace;
    // it must agree with the allocate-per-call path to the last bit
    let data = cluster(300, 33);
    let (train, test) = data.split(220);
    let mut models = vec![];
    for inference in [Inference::Sparse(Ordering::Rcm), Inference::Parallel(Ordering::Rcm)] {
        models.push(GpClassifier::new(CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.4), inference));
    }
    models.push(
        GpClassifier::new_cs_fic(
            CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.4),
            CovFunction::new(CovKind::Se, 2, 0.7, 3.0),
            16,
        )
        .unwrap(),
    );
    for model in models {
        let fitted = model.infer_only(&train.x, &train.y).unwrap();
        let batched = fitted.predict_latent_batch(&test.x);
        let mut predictor = fitted.predictor();
        for (x, &(mb, vb)) in test.x.iter().zip(&batched) {
            let (m1, v1) = fitted.predict_latent(x);
            let (m2, v2) = predictor.predict_latent(x);
            assert!((mb - m1).abs() < 1e-12 && (vb - v1).abs() < 1e-12);
            assert!((mb - m2).abs() < 1e-12 && (vb - v2).abs() < 1e-12);
        }
    }
}

#[test]
fn pool_width_never_changes_any_result() {
    // The worker-pool contract: EP sweeps, gradients and batched
    // prediction are bitwise-identical at every pool width, and width 1
    // *is* the pre-pool serial path (one participant, inline execution).
    // CI re-runs the whole suite under CSGP_THREADS=1 and =4 to exercise
    // the process-wide default; this test sweeps widths in-process.
    use csgp::data::kmeans::kmeans;
    use csgp::gp::covariance::AdditiveCov;
    use csgp::gp::{CsFicEp, ParallelEp};

    let data = cluster(300, 41);
    let (train, test) = data.split(220);
    let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.4);
    let opts = EpOptions { max_sweeps: 200, tol: 1e-8, damping: 0.8, ..EpOptions::default() };
    let hybrid =
        AdditiveCov::new(CovFunction::new(CovKind::Se, 2, 0.7, 3.0), cov.clone()).unwrap();
    let xu = kmeans(&train.x, 12, 25, 3);

    // width-1 references: the inline serial path, no pool participation
    let (s_lz, s_mu, s_sig, s_grad, s_preds, s_fac) = csgp::par::with_max_threads(1, || {
        let ep = ParallelEp::run(&cov, &train.x, &train.y, Ordering::Rcm, &opts).unwrap();
        let sep =
            SparseEp::run(&cov, &train.x, &train.y, Ordering::Rcm, &opts, None).unwrap();
        // the supernodal numeric LDLᵀ in isolation: refactor B at the
        // converged sites and keep the raw factor bits
        let b = csgp::gp::ep_sparse::build_b(&ep.k, &ep.sites.tau);
        let mut fac = ep.factor.clone();
        fac.refactor(&b).unwrap();
        (
            ep.log_z,
            ep.mu.clone(),
            ep.recompute_sigma_diag(),
            sep.log_z_grad(&cov),
            ep.predict_latent_batch(&cov, &test.x),
            (fac.l.clone(), fac.d.clone()),
        )
    });
    // the same factorization under nested dissection: ND's wide waves put
    // far more supernodes in flight per wave than RCM, so it is the
    // ordering that stresses the determinism contract hardest
    let (nd_lz, nd_fac) = csgp::par::with_max_threads(1, || {
        let ep = ParallelEp::run(&cov, &train.x, &train.y, Ordering::Nd, &opts).unwrap();
        let b = csgp::gp::ep_sparse::build_b(&ep.k, &ep.sites.tau);
        let mut fac = ep.factor.clone();
        fac.refactor(&b).unwrap();
        (ep.log_z, (fac.l.clone(), fac.d.clone()))
    });
    assert!(
        (nd_lz - s_lz).abs() < 1e-7,
        "orderings must agree on logZ: nd {nd_lz} vs rcm {s_lz}"
    );
    let (h_lz, h_mu, h_sig, h_grad, h_preds) = csgp::par::with_max_threads(1, || {
        let ep = CsFicEp::run(&hybrid, &train.x, &train.y, &xu, &opts).unwrap();
        (
            ep.log_z,
            ep.mu.clone(),
            ep.recompute_sigma_diag_with(&ep.fic_factor()),
            ep.log_z_grad_cs(),
            ep.predict_latent_batch(&test.x),
        )
    });

    // relaxed amalgamation pinned ON regardless of the CSGP_AMALG env (CI
    // also runs this suite under CSGP_AMALG=0): the blocked factor,
    // solves and Takahashi inverse over a padded pattern must be
    // bitwise width-invariant too
    use csgp::sparse::cholesky::LdlFactor;
    use csgp::sparse::symbolic::{AmalgConfig, Symbolic};
    let mut kmat = cov.cov_matrix(&train.x);
    for j in 0..kmat.n_cols {
        *kmat.get_mut(j, j) += 1.0;
    }
    let sym_am =
        std::sync::Arc::new(Symbolic::analyze_with(&kmat, None, &AmalgConfig::default()));
    assert!(
        sym_am.padded_nnz() >= sym_am.nnz_l(),
        "padded storage can never be smaller than the strict pattern"
    );
    let (am_fac, am_z, am_solve) = csgp::par::with_max_threads(1, || {
        let f = LdlFactor::factor(sym_am.clone(), &kmat).unwrap();
        let z = f.takahashi_inverse();
        let mut v: Vec<f64> = (0..kmat.n_rows).map(|i| (i as f64 * 0.37).sin()).collect();
        f.solve_in_place(&mut v);
        ((f.l.clone(), f.d.clone()), (z.z_lower, z.z_diag), v)
    });

    for width in [2usize, 7] {
        csgp::par::with_max_threads(width, || {
            let ep = ParallelEp::run(&cov, &train.x, &train.y, Ordering::Rcm, &opts).unwrap();
            assert!(ep.log_z == s_lz, "width {width}: logZ {} vs {}", ep.log_z, s_lz);
            assert_eq!(ep.mu, s_mu, "width {width}");
            assert_eq!(ep.recompute_sigma_diag(), s_sig, "width {width}");
            let sep =
                SparseEp::run(&cov, &train.x, &train.y, Ordering::Rcm, &opts, None).unwrap();
            assert_eq!(sep.log_z_grad(&cov), s_grad, "width {width}");
            assert_eq!(ep.predict_latent_batch(&cov, &test.x), s_preds, "width {width}");
            let b = csgp::gp::ep_sparse::build_b(&ep.k, &ep.sites.tau);
            let mut fac = ep.factor.clone();
            fac.refactor(&b).unwrap();
            assert_eq!(fac.l, s_fac.0, "width {width}: factor L bits differ");
            assert_eq!(fac.d, s_fac.1, "width {width}: factor D bits differ");

            let nd_ep =
                ParallelEp::run(&cov, &train.x, &train.y, Ordering::Nd, &opts).unwrap();
            assert!(nd_ep.log_z == nd_lz, "width {width}: nd logZ drifted");
            let nd_b = csgp::gp::ep_sparse::build_b(&nd_ep.k, &nd_ep.sites.tau);
            let mut fac_nd = nd_ep.factor.clone();
            fac_nd.refactor(&nd_b).unwrap();
            assert_eq!(fac_nd.l, nd_fac.0, "width {width}: nd factor L bits differ");
            assert_eq!(fac_nd.d, nd_fac.1, "width {width}: nd factor D bits differ");

            let hep = CsFicEp::run(&hybrid, &train.x, &train.y, &xu, &opts).unwrap();
            assert!(hep.log_z == h_lz, "width {width}: logZ {} vs {}", hep.log_z, h_lz);
            assert_eq!(hep.mu, h_mu, "width {width}");
            assert_eq!(hep.recompute_sigma_diag_with(&hep.fic_factor()), h_sig, "width {width}");
            assert_eq!(hep.log_z_grad_cs(), h_grad, "width {width}");
            assert_eq!(hep.predict_latent_batch(&test.x), h_preds, "width {width}");

            // amalgamation-on factor / solve / Takahashi, bit for bit
            let f = LdlFactor::factor(sym_am.clone(), &kmat).unwrap();
            assert_eq!(f.l, am_fac.0, "width {width}: amalg factor L bits differ");
            assert_eq!(f.d, am_fac.1, "width {width}: amalg factor D bits differ");
            let z = f.takahashi_inverse();
            assert_eq!(z.z_lower, am_z.0, "width {width}: amalg takahashi differs");
            assert_eq!(z.z_diag, am_z.1, "width {width}: amalg takahashi diag differs");
            let mut v: Vec<f64> = (0..kmat.n_rows).map(|i| (i as f64 * 0.37).sin()).collect();
            f.solve_in_place(&mut v);
            assert_eq!(v, am_solve, "width {width}: amalg solve differs");
        });
    }
}

#[test]
fn optimizer_loop_reuses_structure_across_evaluations() {
    // a short MAP fit on a CS kernel: the SCG loop must not re-analyse
    // structure on every gradient evaluation (σ²-only and shrinking steps
    // hit the cache), and the fit must still improve the posterior
    let data = cluster(200, 51);
    let cov = CovFunction::new(CovKind::Pp(3), 2, 0.8, 1.8);
    let mut model = GpClassifier::new(cov, Inference::Sparse(Ordering::Rcm));
    model.opt_opts.max_iters = 8;
    let before = model.infer_only(&data.x, &data.y).unwrap().report.log_post;
    let fitted = model.fit(&data.x, &data.y).unwrap();
    assert!(fitted.report.log_post >= before - 1e-6);
    assert!(fitted.report.fn_evals > 0);
}

#[test]
fn tracing_modes_never_change_results_and_spans_nest() {
    // The obs inertness contract: the fitted state is bitwise-identical
    // with tracing off, counters-only and full — at every pool width.
    // Tracing only observes (timestamps, counts); it must never steer
    // kernels, chunking or scheduling.
    use csgp::gp::ParallelEp;
    use csgp::obs::{self, TraceMode};

    let data = cluster(200, 61);
    let (train, test) = data.split(150);
    let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.4);
    let opts = EpOptions { max_sweeps: 60, tol: 1e-8, damping: 0.8, ..EpOptions::default() };
    let run = |width: usize| {
        csgp::par::with_max_threads(width, || {
            let ep = ParallelEp::run(&cov, &train.x, &train.y, Ordering::Rcm, &opts).unwrap();
            let sig = ep.recompute_sigma_diag();
            let preds = ep.predict_latent_batch(&cov, &test.x);
            (ep.log_z, ep.mu.clone(), sig, preds)
        })
    };

    let mut reference: Option<(f64, Vec<f64>, Vec<f64>, Vec<(f64, f64)>)> = None;
    for mode in [TraceMode::Off, TraceMode::Counters, TraceMode::Full] {
        obs::with_mode(mode, || {
            for width in [1usize, 2, 7] {
                let out = run(width);
                match &reference {
                    None => reference = Some(out),
                    Some(r) => {
                        assert!(
                            out.0 == r.0,
                            "mode {mode:?} width {width}: logZ bits differ ({} vs {})",
                            out.0,
                            r.0
                        );
                        assert_eq!(out.1, r.1, "mode {mode:?} width {width}: mu differs");
                        assert_eq!(out.2, r.2, "mode {mode:?} width {width}: sigma differs");
                        assert_eq!(out.3, r.3, "mode {mode:?} width {width}: preds differ");
                    }
                }
            }
        });
    }
}

#[test]
fn full_trace_spans_are_well_formed_under_the_pool() {
    // Every drained span must be balanced (exit after enter) and nest
    // inside its parent's interval — including cross-thread par.worker
    // spans spliced under the issuing span — at pool widths 1, 2 and 7.
    use std::collections::{HashMap, HashSet};

    use csgp::gp::ParallelEp;
    use csgp::obs::{self, SpanEvent, TraceMode};

    let data = cluster(200, 62);
    let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.4);
    let opts = EpOptions { max_sweeps: 30, tol: 1e-8, damping: 0.8, ..EpOptions::default() };

    obs::with_mode(TraceMode::Full, || {
        let _ = obs::take_events(); // discard other tests' leftovers
        for width in [1usize, 2, 7] {
            let lz = csgp::par::with_max_threads(width, || {
                ParallelEp::run(&cov, &data.x, &data.y, Ordering::Rcm, &opts).unwrap().log_z
            });
            assert!(lz.is_finite());
        }
        let events = obs::take_events();
        assert!(!events.is_empty(), "a Full-mode fit must record spans");
        let by_id: HashMap<u64, &SpanEvent> = events.iter().map(|e| (e.id, e)).collect();
        for e in &events {
            assert!(e.id != 0, "span ids are never 0");
            assert!(e.t1_ns >= e.t0_ns, "span {} exits before it enters", e.name);
            // parents close after children. A parent missing from this
            // drain window belongs to a still-open span (or a concurrent
            // test's earlier drain) — skip those, the invariant is only
            // checkable when both ends were captured together.
            if e.parent != 0 {
                if let Some(p) = by_id.get(&e.parent) {
                    assert!(
                        p.t0_ns <= e.t0_ns && e.t1_ns <= p.t1_ns,
                        "child {} [{}, {}] escapes parent {} [{}, {}]",
                        e.name,
                        e.t0_ns,
                        e.t1_ns,
                        p.name,
                        p.t0_ns,
                        p.t1_ns
                    );
                }
            }
        }
        let names: HashSet<&str> = events.iter().map(|e| e.name).collect();
        for required in ["ep.sweep", "factor", "factor.wave"] {
            assert!(names.contains(required), "missing {required} spans in {names:?}");
        }
        // widths 2 and 7 broadcast to pool workers, which open par.worker
        // spans spliced under the issuing thread's current span
        assert!(names.contains("par.worker"), "no worker spans at widths >= 2: {names:?}");
    });
}

#[test]
fn pattern_cache_counters_track_hits_and_misses() {
    // Counter accuracy for the PatternCache: the obs counters must agree
    // with the cache's own hit/miss bookkeeping for the four documented
    // step kinds (build / σ²-only / shrink / growth).
    use csgp::gp::cache::PatternCache;
    use csgp::obs::{self, TraceMode};

    let x: Vec<Vec<f64>> = (0..60).map(|i| vec![(i % 10) as f64, (i / 10) as f64]).collect();
    obs::with_mode(TraceMode::Counters, || {
        let before = obs::snapshot();
        let mut cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 2.0);
        let mut cache = PatternCache::new(Ordering::Rcm);
        let _ = cache.plan_for(&cov, &x); // miss: first build
        cov.sigma2 = 2.5;
        let _ = cache.plan_for(&cov, &x); // hit: σ²-only step
        cov.lengthscales = vec![1.5, 1.5];
        let _ = cache.plan_for(&cov, &x); // hit: shrink, superset reuse
        cov.lengthscales = vec![3.0, 3.0];
        let _ = cache.plan_for(&cov, &x); // miss: growth, full reanalysis
        assert_eq!((cache.hits, cache.misses), (2, 2));
        let after = obs::snapshot();
        // >= rather than ==: CI also runs this suite under
        // CSGP_TRACE=full, where concurrently running tests bump the same
        // process-wide counters
        assert!(after.cache_hit - before.cache_hit >= 2, "{after:?} vs {before:?}");
        assert!(after.cache_miss - before.cache_miss >= 2, "{after:?} vs {before:?}");
        assert!(after.cache_shrink_reuse - before.cache_shrink_reuse >= 1);
        assert!(after.cache_grow_reanalyze - before.cache_grow_reanalyze >= 1);
    });
}

#[test]
fn cv_and_jobs_compose() {
    let data = cluster(160, 15);
    let model = GpClassifier::new(
        CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.5),
        Inference::Sparse(Ordering::Rcm),
    );
    let res = csgp::data::cv::cross_validate(&model, &data, 4, false, 2).unwrap();
    assert!(res.err < 0.4);
    let mgr = csgp::coordinator::JobManager::start(2);
    let id = mgr
        .submit(csgp::coordinator::TrainSpec {
            dataset: data,
            cov: CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.5),
            global_cov: None,
            inference: Inference::Sparse(Ordering::Rcm),
            optimize: false,
            snapshot_save: None,
        })
        .unwrap();
    let st = mgr.wait(id, std::time::Duration::from_secs(60)).unwrap();
    assert!(matches!(st, csgp::coordinator::JobStatus::Done { .. }), "{st:?}");
    mgr.shutdown();
}

// The fault-injection recovery tests live in their own binary
// (`tests/fault_recovery.rs`): fault plans are process-global, so they
// must not share a test process with unrelated factorizations.

#[test]
fn clean_fixtures_record_zero_recovery_events() {
    // Half of the self-healing acceptance contract: on healthy inputs the
    // recovery machinery must be pure bookkeeping — no jitter retries, no
    // skipped sites, no rollbacks, no injected faults, no job retries.
    use csgp::gp::ParallelEp;
    use csgp::obs::{self, TraceMode};

    let data = cluster(150, 81);
    let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.6);
    let opts = EpOptions::default();
    obs::with_mode(TraceMode::Counters, || {
        let before = obs::snapshot();
        let se = SparseEp::run(&cov, &data.x, &data.y, Ordering::Rcm, &opts, None).unwrap();
        let pe = ParallelEp::run(&cov, &data.x, &data.y, Ordering::Rcm, &opts).unwrap();
        assert!(se.converged && pe.converged);
        let after = obs::snapshot();
        assert_eq!(after.ep_rollbacks, before.ep_rollbacks, "clean run rolled back");
        assert_eq!(after.ep_skipped_sites, before.ep_skipped_sites, "clean run skipped sites");
        assert_eq!(
            after.factor_jitter_retries, before.factor_jitter_retries,
            "clean run needed jitter"
        );
        assert_eq!(after.faults_injected, before.faults_injected, "faults fired unplanned");
        assert_eq!(after.job_retries, before.job_retries, "a clean job retried");
    });
}
