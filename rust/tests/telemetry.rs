//! Telemetry-consumption conformance: the contracts `csgp trace` and the
//! metrics exporter rely on.
//!
//! * **Golden trace** — `fixtures/golden_trace_v1.jsonl` is a hand-built
//!   trace with every span kind the profiler consumes (nested EP sweeps,
//!   a two-wave factor with pool workers, Takahashi, a service batch,
//!   two metrics snapshots). Every profile aggregate is pinned against
//!   hand-computed values, so a change to the aggregation semantics —
//!   inclusive/exclusive accounting, critical-path definition, cost-row
//!   units — fails loudly instead of silently re-baselining.
//! * **Inclusive/exclusive invariant** — on randomly generated
//!   well-nested span forests, each span's inclusive time equals its
//!   exclusive time plus its direct children's inclusive times, and the
//!   forest's total exclusive time equals the roots' inclusive total.
//! * **Exporter under load** — `serve --metrics`-style snapshots written
//!   while concurrent clients hammer `predict` stay parseable, strictly
//!   sequenced, and monotone in `t_ns`, and round-trip through the
//!   analyzer.

use std::sync::Arc;
use std::time::Duration;

use csgp::coordinator::{MetricsExporter, PredictionService, ServiceConfig};
use csgp::data::synthetic::{cluster_dataset, ClusterConfig};
use csgp::gp::covariance::{CovFunction, CovKind};
use csgp::gp::model::{GpClassifier, Inference};
use csgp::obs::profile::{self, Profile, SpanRec, TraceData};
use csgp::rng::Rng;
use csgp::sparse::ordering::Ordering;

const GOLDEN: &str = include_str!("fixtures/golden_trace_v1.jsonl");

#[test]
fn golden_trace_v1_aggregates_are_pinned() {
    let data = profile::parse_trace(GOLDEN).expect("fixture parses");
    assert_eq!(data.spans.len(), 9);
    assert_eq!(data.metrics.len(), 2);
    assert_eq!(data.skipped, 0);
    let p = Profile::from_trace(&data);

    assert_eq!(p.spans, 9);
    assert_eq!(p.orphans, 0);
    assert_eq!(p.wall_ns, 2_100_000);

    // phase table, sorted by inclusive time descending
    let names: Vec<&str> = p.phases.iter().map(|x| x.name.as_str()).collect();
    assert_eq!(
        names,
        ["ep.sweep", "factor", "factor.wave", "par.worker", "takahashi", "svc.batch"]
    );
    let phase = |n: &str| p.phases.iter().find(|x| x.name == n).unwrap();
    let sweep = phase("ep.sweep");
    assert_eq!((sweep.count, sweep.inclusive_ns), (2, 1_800_000));
    // exclusive = inclusive minus the nested factor (400k) and takahashi (300k)
    assert_eq!(sweep.exclusive_ns, 1_100_000);
    assert_eq!((sweep.min_ns, sweep.max_ns), (800_000, 1_000_000));
    let factor = phase("factor");
    assert_eq!((factor.inclusive_ns, factor.exclusive_ns), (400_000, 30_000));
    let wave = phase("factor.wave");
    assert_eq!(wave.inclusive_ns, 370_000);
    // wave 0's overlapping parallel workers saturate its exclusive to 0;
    // wave 1 ran inline, so only its 180k survives
    assert_eq!(wave.exclusive_ns, 180_000);
    assert_eq!(phase("par.worker").inclusive_ns, 358_000);
    assert_eq!(phase("takahashi").exclusive_ns, 300_000);
    assert_eq!(phase("svc.batch").inclusive_ns, 100_000);

    // factor: flops from the wave fields, critical path over wave barriers
    let f = p.factor.as_ref().expect("factor profile");
    assert_eq!((f.count, f.total_ns), (1, 400_000));
    assert_eq!(f.flops, 1_000_000);
    assert_eq!(f.nnz, 1_500);
    assert_eq!(f.waves, 2);
    // wave 0: longest worker busy 180k; wave 1 inline: its 180k duration
    assert_eq!(f.critical_path_ns, 360_000);
    assert_eq!(f.busy_ns, 480_000);
    assert!((f.flops_per_s() - 2.5e9).abs() < 1e3);
    assert!((f.achieved_parallelism() - 1.2).abs() < 1e-12);
    assert!((f.max_parallelism() - 480.0 / 360.0).abs() < 1e-12);
    assert!(f.outliers.is_empty(), "a single instance has no outliers");

    // pool: both workers parent under wave 0 => one region
    let pool = p.pool.as_ref().expect("pool profile");
    assert_eq!((pool.worker_spans, pool.regions), (2, 1));
    assert_eq!(pool.chunks, 5);
    assert_eq!(pool.stolen_spans, 1);
    assert_eq!((pool.busy_ns, pool.span_ns), (300_000, 358_000));
    assert!((pool.utilization() - 300_000.0 / 358_000.0).abs() < 1e-12);
    // busy 180k vs 120k: max/mean = 180/150 = 1.2
    assert_eq!(pool.imbalance_max_permille, 1_200);

    // ep trajectory
    let ep = p.ep.as_ref().expect("ep profile");
    assert_eq!(ep.sweeps, 2);
    assert_eq!(ep.backends, ["sparse"]);
    assert_eq!(ep.final_dlogz, Some(-0.01));
    assert_eq!(ep.final_max_site_delta, Some(0.001));
    assert_eq!((ep.rollbacks, ep.skipped_sites), (1, 2));

    // cost-model attribution rows
    let row = |n: &str| p.cost.iter().find(|r| r.phase == n).unwrap();
    let rf = row("factor");
    assert_eq!((rf.measured_ns, rf.units as u64), (400_000, 1_000_000));
    assert!((rf.ns_per_unit - 0.4).abs() < 1e-12);
    let rt = row("takahashi");
    assert_eq!(rt.measured_ns, 300_000);
    assert!((rt.ns_per_unit - 0.3).abs() < 1e-12);
    let rs = row("ep.sweep");
    assert_eq!(rs.unit, "nnz·sweep");
    // exclusive 1.1M ns over nnz(L)=1500 x 2 sweeps
    assert_eq!(rs.measured_ns, 1_100_000);
    assert_eq!(rs.units as u64, 3_000);
    assert!((rs.ns_per_unit - 1_100_000.0 / 3_000.0).abs() < 1e-9);
    assert!(p.cost.iter().any(|r| r.phase == "svc.batch"));

    // metrics stream summary
    let m = p.metrics.as_ref().expect("metrics profile");
    assert_eq!(m.snapshots, 2);
    assert!(m.monotone);
    assert_eq!(m.span_ns, 100_000);
    assert_eq!(m.last_in_flight, 1);
    assert_eq!(m.requests_delta, 32);
    assert_eq!(m.rejected_delta, 0);
    assert_eq!(m.last_request_p50_ns, Some(90_000));
    assert_eq!(m.last_request_p99_ns, Some(100_000));
    assert_eq!(
        m.counter_deltas,
        vec![("solves".to_string(), 40), ("ep_sweeps".to_string(), 2)]
    );
}

/// The rendered reports are pinned on their load-bearing fragments (not
/// byte-for-byte, so cosmetic spacing can evolve without re-baselining
/// the numbers).
#[test]
fn golden_trace_v1_report_is_pinned() {
    let data = profile::parse_trace(GOLDEN).unwrap();
    let p = Profile::from_trace(&data);
    let text = p.render_text();
    for needle in [
        "trace profile: 9 spans",
        "ep.sweep",
        "1.00 Mflop over 2 waves -> 2.50 Gflop/s",
        "nnz(L) = 1500",
        "84% utilization",
        "imbalance max 1200 permille",
        "ep: 2 sweep(s) [sparse]",
        "rollbacks 1, skipped sites 2",
        "cost model (measured vs predicted work units)",
        "nnz·sweep",
        "metrics: 2 snapshot(s)",
    ] {
        assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
    }
    let json = p.render_json();
    for needle in [
        "\"wall_ns\": 2100000",
        "\"flops\": 1000000",
        "\"critical_path_ns\": 360000",
        "\"imbalance_max_permille\": 1200",
        "\"phase\": \"ep.sweep\"",
        "\"snapshots\": 2",
    ] {
        assert!(json.contains(needle), "missing {needle:?} in:\n{json}");
    }
    // and the JSON report parses with the same parser the CLI uses
    let parsed = profile::Json::parse(&json).expect("render_json emits valid JSON");
    assert_eq!(parsed.get("wall_ns").and_then(profile::Json::as_u64), Some(2_100_000));
}

/// Diffing a trace against itself never flags drift, and ratios are 1.
#[test]
fn self_diff_is_clean() {
    let data = profile::parse_trace(GOLDEN).unwrap();
    let p = Profile::from_trace(&data);
    let d = profile::diff(&p, &p, 0.25);
    assert_eq!(d.flagged(), 0);
    assert!(d.phases.iter().all(|x| x.ratio == Some(1.0)));
    assert!(d.cost.iter().all(|c| (c.ratio - 1.0).abs() < 1e-12));
    assert!(d.render_text().contains("no drift beyond tolerance"));
}

// ---------------------------------------------------------------------------
// Property: inclusive/exclusive accounting on random well-nested forests.
// ---------------------------------------------------------------------------

/// Append a span covering [t0, t1) under `parent`, then recursively carve
/// disjoint child intervals out of it. Names are unique per span so the
/// per-phase table is a per-span table.
fn build_tree(
    spans: &mut Vec<SpanRec>,
    next_id: &mut u64,
    rng: &mut Rng,
    parent: u64,
    t0: u64,
    t1: u64,
    depth: usize,
) {
    let id = *next_id;
    *next_id += 1;
    spans.push(SpanRec {
        name: format!("s{id}"),
        tid: 1,
        id,
        parent,
        t0_ns: t0,
        t1_ns: t1,
        fields: Vec::new(),
    });
    if depth == 0 || t1 - t0 < 16 {
        return;
    }
    let children = rng.below(4); // 0..=3
    let mut cursor = t0;
    for _ in 0..children {
        if t1 - cursor < 8 {
            break;
        }
        let start = cursor + 1 + rng.below(((t1 - cursor) / 4).max(1) as usize) as u64;
        if start >= t1 {
            break;
        }
        let end = start + 1 + rng.below((t1 - start).max(1) as usize) as u64;
        let end = end.min(t1);
        if end <= start {
            break;
        }
        build_tree(spans, next_id, rng, id, start, end, depth - 1);
        cursor = end;
    }
}

#[test]
fn inclusive_equals_exclusive_plus_direct_children_on_random_forests() {
    let mut rng = Rng::new(0x2026_0808);
    for trial in 0..25 {
        let mut spans = Vec::new();
        let mut next_id = 1u64;
        let mut t = 0u64;
        for _ in 0..(1 + rng.below(4)) {
            let dur = 1_000 + rng.below(50_000) as u64;
            build_tree(&mut spans, &mut next_id, &mut rng, 0, t, t + dur, 4);
            t += dur + 1 + rng.below(100) as u64;
        }
        let data = TraceData { spans: spans.clone(), metrics: Vec::new(), skipped: 0 };
        let p = Profile::from_trace(&data);
        assert_eq!(p.spans as usize, spans.len(), "trial {trial}");
        assert_eq!(p.orphans, 0, "trial {trial}");

        // names are unique, so phases are spans
        let phase = |name: &str| p.phases.iter().find(|x| x.name == name).unwrap();
        for s in &spans {
            let child_sum: u64 = spans
                .iter()
                .filter(|c| c.parent == s.id)
                .map(|c| c.t1_ns - c.t0_ns)
                .sum();
            let ph = phase(&s.name);
            assert_eq!(ph.inclusive_ns, s.t1_ns - s.t0_ns, "trial {trial} span {}", s.id);
            assert_eq!(
                ph.inclusive_ns,
                ph.exclusive_ns + child_sum,
                "trial {trial} span {}: inclusive must equal exclusive + direct children",
                s.id
            );
        }
        // forest-level: total exclusive == total root inclusive (time is
        // partitioned, never double counted)
        let total_exclusive: u64 = p.phases.iter().map(|x| x.exclusive_ns).sum();
        let root_inclusive: u64 =
            spans.iter().filter(|s| s.parent == 0).map(|s| s.t1_ns - s.t0_ns).sum();
        assert_eq!(total_exclusive, root_inclusive, "trial {trial}");
    }
}

// ---------------------------------------------------------------------------
// Exporter under concurrent predict load.
// ---------------------------------------------------------------------------

#[test]
fn exporter_stays_monotone_under_concurrent_predict_load() {
    csgp::obs::set_mode(csgp::obs::TraceMode::Counters);
    let data = cluster_dataset(&ClusterConfig::paper_2d(60), 5);
    let model = GpClassifier::new(
        CovFunction::new(CovKind::Pp(3), 2, 1.0, 2.0),
        Inference::Sparse(Ordering::Rcm),
    );
    let fitted = Arc::new(model.infer_only(&data.x, &data.y).unwrap());
    let svc = Arc::new(PredictionService::start(fitted, None, ServiceConfig::default()));
    let path = std::env::temp_dir()
        .join(format!("csgp-telemetry-exporter-{}.jsonl", std::process::id()));
    let exporter =
        MetricsExporter::start(&path, Duration::from_millis(3), Some(svc.stats.clone()))
            .expect("exporter start");

    let mut handles = Vec::new();
    for c in 0..4u64 {
        let svc = svc.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(c);
            for _ in 0..30 {
                let x = vec![rng.uniform_in(0.0, 10.0), rng.uniform_in(0.0, 10.0)];
                svc.predict(x).unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    std::thread::sleep(Duration::from_millis(10));
    exporter.stop();
    svc.shutdown();

    let text = std::fs::read_to_string(&path).expect("metrics file");
    let _ = std::fs::remove_file(&path);
    let data = profile::parse_trace(&text).expect("every exporter line parses");
    assert_eq!(data.skipped, 0, "no foreign lines");
    assert!(data.metrics.len() >= 3, "immediate + periodic + final snapshots");
    for w in data.metrics.windows(2) {
        assert!(w[1].seq == w[0].seq + 1, "seq is dense and increasing");
        assert!(w[1].t_ns >= w[0].t_ns, "t_ns is monotone");
    }
    let last = data.metrics.last().unwrap();
    assert_eq!(last.requests, 120, "final snapshot sees every request");
    assert_eq!(last.in_flight, 0);

    // round-trip: the analyzer consumes serve --metrics output directly
    let p = Profile::from_trace(&data);
    let m = p.metrics.expect("metrics profile");
    assert!(m.monotone);
    assert_eq!(m.requests_delta, 120);
    assert!(p.render_text().contains("snapshot(s)"));
}
