//! Fault-injection recovery tests, isolated in their own test binary:
//! a [`csgp::fault::Plan`] is process-global, so an armed fault could be
//! consumed by any concurrent factorization in the same process. Cargo
//! runs test binaries sequentially, and every test here serializes on
//! `obs::with_mode`, so planned faults only ever fire in the run that
//! planned them.

use csgp::data::synthetic::{cluster_dataset, ClusterConfig};
use csgp::fault::{self, Plan};
use csgp::gp::covariance::{CovFunction, CovKind};
use csgp::gp::marginal::EpOptions;
use csgp::gp::SparseEp;
use csgp::obs::{self, TraceMode};
use csgp::sparse::ordering::Ordering;

fn cluster(n: usize, seed: u64) -> csgp::data::Dataset {
    cluster_dataset(&ClusterConfig::paper_2d(n), seed)
}

#[test]
fn injected_faults_recover_identically_at_every_width() {
    // The self-healing acceptance contract: an injected pivot failure and
    // an injected NaN site update both complete through recovery (not an
    // error), the recovered fit matches the clean fixed point, and the
    // recovery sequence is bitwise-identical at pool widths 1, 2 and 7.
    let data = cluster(150, 71);
    let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.6);
    let opts = EpOptions { max_sweeps: 100, tol: 1e-8, damping: 1.0, ..EpOptions::default() };
    let clean = SparseEp::run(&cov, &data.x, &data.y, Ordering::Rcm, &opts, None).unwrap();

    obs::with_mode(TraceMode::Counters, || {
        // pivot failure at elimination column 40: the recovery refactor
        // absorbs it with escalating diagonal jitter
        let before = obs::snapshot();
        let runs: Vec<SparseEp> = [1usize, 2, 7]
            .iter()
            .map(|&width| {
                fault::with_plan(Plan::new().pivot(40), || {
                    csgp::par::with_max_threads(width, || {
                        SparseEp::run(&cov, &data.x, &data.y, Ordering::Rcm, &opts, None)
                            .unwrap()
                    })
                })
            })
            .collect();
        let after = obs::snapshot();
        assert!(after.faults_injected - before.faults_injected >= 3, "{after:?}");
        assert!(after.factor_jitter_retries - before.factor_jitter_retries >= 3, "{after:?}");
        for ep in &runs {
            assert!(
                (ep.log_z - clean.log_z).abs() < 1e-5,
                "recovered fit drifted: {} vs clean {}",
                ep.log_z,
                clean.log_z
            );
        }
        for ep in &runs[1..] {
            assert!(ep.log_z == runs[0].log_z, "recovery is not width-invariant");
            assert_eq!(ep.sweeps, runs[0].sweeps, "sweep counts differ across widths");
            assert_eq!(ep.factor.l, runs[0].factor.l, "factor bits differ across widths");
        }

        // NaN site update at (sweep 1, site 5): the poisoned visit is
        // skipped, the sweep rolls back to the last-good snapshot with
        // halved damping, and EP still converges to the clean fixed point
        let before = obs::snapshot();
        let nruns: Vec<SparseEp> = [1usize, 2, 7]
            .iter()
            .map(|&width| {
                fault::with_plan(Plan::new().nan_site(1, 5), || {
                    csgp::par::with_max_threads(width, || {
                        SparseEp::run(&cov, &data.x, &data.y, Ordering::Rcm, &opts, None)
                            .unwrap()
                    })
                })
            })
            .collect();
        let after = obs::snapshot();
        assert!(after.faults_injected - before.faults_injected >= 3, "{after:?}");
        assert!(after.ep_skipped_sites - before.ep_skipped_sites >= 3, "{after:?}");
        assert!(after.ep_rollbacks - before.ep_rollbacks >= 3, "{after:?}");
        for ep in &nruns {
            assert!(
                (ep.log_z - clean.log_z).abs() < 1e-5,
                "rolled-back fit drifted: {} vs clean {}",
                ep.log_z,
                clean.log_z
            );
        }
        for ep in &nruns[1..] {
            assert!(ep.log_z == nruns[0].log_z, "rollback is not width-invariant");
            assert_eq!(ep.sweeps, nruns[0].sweeps, "sweep counts differ across widths");
        }
    });
}

#[test]
fn batched_backends_roll_back_injected_nan_sites() {
    // The same NaN-site fault through the two batched backends: parallel
    // EP and the CS+FIC hybrid both skip the poisoned merge, roll back,
    // and still reach their clean fixed points.
    use csgp::data::kmeans::kmeans;
    use csgp::gp::covariance::AdditiveCov;
    use csgp::gp::{CsFicEp, ParallelEp};

    let data = cluster(150, 72);
    let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.6);
    let opts = EpOptions { max_sweeps: 300, tol: 1e-8, damping: 0.8, ..EpOptions::default() };
    let hybrid =
        AdditiveCov::new(CovFunction::new(CovKind::Se, 2, 0.7, 3.0), cov.clone()).unwrap();
    let xu = kmeans(&data.x, 12, 25, 3);

    let clean_pe = ParallelEp::run(&cov, &data.x, &data.y, Ordering::Rcm, &opts).unwrap();
    let clean_he = CsFicEp::run(&hybrid, &data.x, &data.y, &xu, &opts).unwrap();

    obs::with_mode(TraceMode::Counters, || {
        let before = obs::snapshot();
        let pe = fault::with_plan(Plan::new().nan_site(2, 9), || {
            ParallelEp::run(&cov, &data.x, &data.y, Ordering::Rcm, &opts).unwrap()
        });
        let he = fault::with_plan(Plan::new().nan_site(2, 9), || {
            CsFicEp::run(&hybrid, &data.x, &data.y, &xu, &opts).unwrap()
        });
        let after = obs::snapshot();
        assert!(after.ep_skipped_sites - before.ep_skipped_sites >= 2, "{after:?}");
        assert!(after.ep_rollbacks - before.ep_rollbacks >= 2, "{after:?}");
        assert!(
            (pe.log_z - clean_pe.log_z).abs() < 1e-5,
            "parallel EP drifted: {} vs {}",
            pe.log_z,
            clean_pe.log_z
        );
        assert!(
            (he.log_z - clean_he.log_z).abs() < 1e-5,
            "CS+FIC drifted: {} vs {}",
            he.log_z,
            clean_he.log_z
        );
    });
}

#[test]
fn job_ladder_recovers_from_exhausted_ep_divergence() {
    // Five consecutive poisoned sweeps exhaust the in-backend rollback
    // budget (max_recoveries = 4), so the EP run errors — and the job
    // manager's degradation ladder retries on the sequential sweep with
    // heavier damping, by which point the one-shot faults are consumed.
    use csgp::coordinator::{JobManager, JobStatus, TrainSpec};
    use csgp::gp::model::Inference;

    let data = cluster(120, 91);
    obs::with_mode(TraceMode::Counters, || {
        let before = obs::snapshot();
        let plan = Plan::new()
            .nan_site(0, 3)
            .nan_site(1, 3)
            .nan_site(2, 3)
            .nan_site(3, 3)
            .nan_site(4, 3);
        let st = fault::with_plan(plan, || {
            let mgr = JobManager::start(1);
            let id = mgr
                .submit(TrainSpec {
                    dataset: data.clone(),
                    cov: CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.6),
                    global_cov: None,
                    inference: Inference::Sparse(Ordering::Rcm),
                    optimize: false,
                    snapshot_save: None,
                })
                .unwrap();
            let st = mgr.wait(id, std::time::Duration::from_secs(120)).unwrap();
            mgr.shutdown();
            st
        });
        assert!(matches!(st, JobStatus::Done { .. }), "ladder did not recover: {st:?}");
        let after = obs::snapshot();
        assert!(after.job_retries - before.job_retries >= 1, "{after:?}");
        assert!(after.ep_rollbacks - before.ep_rollbacks >= 4, "{after:?}");
    });
}

#[test]
fn slow_chunk_faults_only_stretch_time_never_results() {
    // `slowchunk` faults delay one pool chunk; the width contract says
    // the numbers cannot move.
    let data = cluster(150, 73);
    let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.6);
    let opts = EpOptions::default();
    let clean = SparseEp::run(&cov, &data.x, &data.y, Ordering::Rcm, &opts, None).unwrap();
    let slowed = fault::with_plan(Plan::new().slow_chunk(0, 5), || {
        csgp::par::with_max_threads(4, || {
            SparseEp::run(&cov, &data.x, &data.y, Ordering::Rcm, &opts, None).unwrap()
        })
    });
    assert!(slowed.log_z == clean.log_z, "a timing fault changed the result");
    assert_eq!(slowed.mu, clean.mu);
}

#[test]
fn online_updates_recover_from_injected_faults() {
    // Satellite of the serving story: a pivot failure and a NaN site
    // update injected *during the incremental online update* must travel
    // the same recovery ladder as a cold fit — the update still converges
    // to the union fixed point instead of erroring out or drifting.
    use csgp::gp::model::{GpClassifier, Inference};

    let all = cluster(170, 77);
    let n_old = 160;
    let model = GpClassifier::new(
        CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.6),
        Inference::Sparse(Ordering::Rcm),
    );
    let fitted = model.infer_only(&all.x[..n_old], &all.y[..n_old]).unwrap();
    let refit = model.infer_only(&all.x, &all.y).unwrap();

    obs::with_mode(TraceMode::Counters, || {
        // pivot failure in the embedded factor's first refactor
        let before = obs::snapshot();
        let (up, _) = fault::with_plan(Plan::new().pivot(40), || {
            model.update(&fitted, &all.x[n_old..], &all.y[n_old..]).unwrap()
        });
        let after = obs::snapshot();
        assert!(after.faults_injected - before.faults_injected >= 1, "{after:?}");
        assert!(
            after.factor_jitter_retries - before.factor_jitter_retries >= 1
                || after.online_refits - before.online_refits >= 1,
            "neither jitter recovery nor refit fallback engaged: {after:?}"
        );
        assert!(
            (up.report.log_z - refit.report.log_z).abs() < 1e-5,
            "pivot-faulted update drifted: {} vs {}",
            up.report.log_z,
            refit.report.log_z
        );

        // NaN site during the resumed sweep: skip + rollback, then converge
        let before = obs::snapshot();
        let (un, _) = fault::with_plan(Plan::new().nan_site(0, 3), || {
            model.update(&fitted, &all.x[n_old..], &all.y[n_old..]).unwrap()
        });
        let after = obs::snapshot();
        assert!(after.faults_injected - before.faults_injected >= 1, "{after:?}");
        assert!(
            after.ep_rollbacks - before.ep_rollbacks >= 1
                || after.ep_skipped_sites - before.ep_skipped_sites >= 1,
            "the poisoned site was never skipped or rolled back: {after:?}"
        );
        assert!(
            (un.report.log_z - refit.report.log_z).abs() < 1e-5,
            "NaN-faulted update drifted: {} vs {}",
            un.report.log_z,
            refit.report.log_z
        );
    });
}

#[test]
fn snapshot_save_faults_never_leave_partial_files() {
    // A crash injected mid-write (`io@snapshot.save`) fails the save —
    // through the job manager it fails the job at the snapshot stage —
    // but the destination path is never touched and no temp file stays
    // behind: the pre-existing snapshot (if any) remains loadable.
    use csgp::coordinator::{JobErrorKind, JobManager, JobStage, JobStatus, TrainSpec};
    use csgp::gp::model::{FittedClassifier, Inference};

    let dir = std::env::temp_dir().join("csgp-fault-tests");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("save-fault-{}.snap", std::process::id()));
    let _ = std::fs::remove_file(&path);

    let data = cluster(80, 79);
    let spec = TrainSpec {
        dataset: data.clone(),
        cov: CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.6),
        global_cov: None,
        inference: Inference::Sparse(Ordering::Rcm),
        optimize: false,
        snapshot_save: Some(path.clone()),
    };

    // serialized through the with_mode lock like every planned fault here
    let st = obs::with_mode(TraceMode::Counters, || {
        fault::with_plan(Plan::new().io("snapshot.save"), || {
            let mgr = JobManager::start(1);
            let id = mgr.submit(spec.clone()).unwrap();
            let st = mgr.wait(id, std::time::Duration::from_secs(120)).unwrap();
            // the fit itself succeeded: the model is still collectable
            assert!(mgr.result(id).is_some(), "fitted model lost with the save");
            mgr.shutdown();
            st
        })
    });
    match st {
        JobStatus::Failed(err) => {
            assert_eq!(err.kind, JobErrorKind::Io);
            assert_eq!(err.stage, JobStage::Snapshot);
        }
        other => panic!("expected a snapshot-stage failure, got {other:?}"),
    }
    assert!(!path.exists(), "faulted save published a destination file");
    let mut tmp = path.clone().into_os_string();
    tmp.push(".tmp");
    assert!(!std::path::Path::new(&tmp).exists(), "faulted save leaked its temp file");

    // the fault is consumed: the same job succeeds and the file loads
    let mgr = JobManager::start(1);
    let id = mgr.submit(spec).unwrap();
    let st = mgr.wait(id, std::time::Duration::from_secs(120)).unwrap();
    assert!(matches!(st, JobStatus::Done { .. }), "{st:?}");
    mgr.shutdown();
    let loaded = FittedClassifier::load_snapshot(&path).unwrap();
    assert_eq!(loaded.x.len(), data.x.len());
    let _ = std::fs::remove_file(&path);
}
