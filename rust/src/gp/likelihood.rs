//! Probit likelihood: numerically stable normal cdf machinery and the
//! tilted (EP "moment-matching") integrals.
//!
//! Two `Φ` kernels live here. The reference path computes `erfc` through
//! the regularized incomplete gamma function (series + continued
//! fraction, Numerical-Recipes style but run to f64 convergence), with a
//! log-domain continued fraction for the deep negative tail so
//! `log Φ(z)` is finite and accurate down to z ≈ −1e7. The *fast* path
//! ([`erfc_fast`] and the `_fast`/batch entry points built on it) is
//! Cody's rational-Chebyshev `erfc` (SPECFUN `CALERF`): three fixed-size
//! rational polynomials plus at most two `exp`s per call, no iteration —
//! the EP site loops run thousands of these per sweep, and the batched
//! form keeps the transcendental work in tight contiguous loops. The
//! reference `erfc` stays the test oracle (the two agree to ≲1e-13
//! relative everywhere the result is normal).

use std::f64::consts::PI;

const LN_SQRT_PI: f64 = 0.5723649429247001; // ln Γ(1/2) = ln √π
const EPS: f64 = 1e-16;
const FPMIN: f64 = 1e-300;

/// Regularized lower incomplete gamma P(a, x) by series expansion.
fn gamma_p_series(a: f64, x: f64, ln_gamma_a: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    let mut ap = a;
    let mut sum = 1.0 / a;
    let mut del = sum;
    for _ in 0..500 {
        ap += 1.0;
        del *= x / ap;
        sum += del;
        if del.abs() < sum.abs() * EPS {
            break;
        }
    }
    sum * (-x + a * x.ln() - ln_gamma_a).exp()
}

/// ln of the regularized upper incomplete gamma Q(a, x) by continued
/// fraction (modified Lentz). Accurate for x ≳ a + 1.
fn ln_gamma_q_cf(a: f64, x: f64, ln_gamma_a: f64) -> f64 {
    let mut b = x + 1.0 - a;
    let mut c = 1.0 / FPMIN;
    let mut d = 1.0 / b;
    let mut h = d;
    for i in 1..500 {
        let an = -(i as f64) * (i as f64 - a);
        b += 2.0;
        d = an * d + b;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = b + an / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < EPS {
            break;
        }
    }
    -x + a * x.ln() - ln_gamma_a + h.ln()
}

/// Complementary error function, |relative error| ≲ 1e-14.
pub fn erfc(x: f64) -> f64 {
    if x < 0.0 {
        return 2.0 - erfc(-x);
    }
    let x2 = x * x;
    if x2 < 1.5 {
        1.0 - gamma_p_series(0.5, x2, LN_SQRT_PI)
    } else {
        ln_gamma_q_cf(0.5, x2, LN_SQRT_PI).exp()
    }
}

/// Standard normal pdf.
#[inline]
pub fn norm_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * PI).sqrt()
}

/// ln of the standard normal pdf.
#[inline]
pub fn ln_norm_pdf(z: f64) -> f64 {
    -0.5 * z * z - 0.5 * (2.0 * PI).ln()
}

/// Standard normal cdf Φ(z).
pub fn norm_cdf(z: f64) -> f64 {
    0.5 * erfc(-z / std::f64::consts::SQRT_2)
}

/// ln Φ(z), stable in the deep negative tail.
pub fn ln_norm_cdf(z: f64) -> f64 {
    if z >= 0.0 {
        // 1 − Φ(z) = ½ erfc(z/√2) ≤ ½; ln1p is exact here
        (-0.5 * erfc(z / std::f64::consts::SQRT_2)).ln_1p()
    } else {
        let x2 = 0.5 * z * z; // (|z|/√2)²
        if x2 < 1.5 {
            (0.5 * erfc(-z / std::f64::consts::SQRT_2)).ln()
        } else {
            // ln(½ Q(½, z²/2)) — fully log-domain
            ln_gamma_q_cf(0.5, x2, LN_SQRT_PI) - std::f64::consts::LN_2
        }
    }
}

/// φ(z)/Φ(z), the inverse Mills ratio (stable for very negative z).
pub fn mills_ratio_inv(z: f64) -> f64 {
    (ln_norm_pdf(z) - ln_norm_cdf(z)).exp()
}

/// Moments of the tilted distribution `∝ Φ(y·f) N(f | m, s²)`:
/// returns `(ln Ẑ, μ̂, σ̂²)` — the EP moment-matching step for the probit
/// likelihood (Rasmussen & Williams eqs. 3.58, 3.85).
pub fn probit_moments(y: f64, m: f64, s2: f64) -> (f64, f64, f64) {
    debug_assert!(y == 1.0 || y == -1.0);
    let denom = (1.0 + s2).sqrt();
    let z = y * m / denom;
    let ln_zhat = ln_norm_cdf(z);
    let rho = mills_ratio_inv(z);
    let mu_hat = m + y * s2 * rho / denom;
    let sigma2_hat = s2 - s2 * s2 * rho * (z + rho) / (1.0 + s2);
    (ln_zhat, mu_hat, sigma2_hat)
}

/// EP site update from the current marginal `(mu_i, sigma2_i)` and site
/// `(tau_site, nu_site)`: returns `(ln Ẑ, cavity τ₋, cavity ν₋, new τ̃,
/// new ν̃)`. Returns `None` when the cavity precision is non-positive
/// (site skipped, standard EP practice).
pub fn probit_site_update(
    y: f64,
    mu_i: f64,
    sigma2_i: f64,
    tau_site: f64,
    nu_site: f64,
) -> Option<(f64, f64, f64, f64, f64)> {
    let tau_cav = 1.0 / sigma2_i - tau_site;
    if tau_cav <= 0.0 {
        return None;
    }
    let nu_cav = mu_i / sigma2_i - nu_site;
    let m = nu_cav / tau_cav;
    let s2 = 1.0 / tau_cav;
    let (ln_zhat, mu_hat, sigma2_hat) = probit_moments(y, m, s2);
    let tau_new = 1.0 / sigma2_hat - tau_cav;
    let nu_new = mu_hat / sigma2_hat - nu_cav;
    Some((ln_zhat, tau_cav, nu_cav, tau_new, nu_new))
}

// ---------------------------------------------------------------------
// Fast path: Cody's rational-Chebyshev erfc and the batched site kernel
// ---------------------------------------------------------------------

/// 1/√π.
const SQRPI: f64 = 0.56418958354775628695;
/// erfc underflows to 0 beyond this argument (SPECFUN XBIG for f64).
const ERFC_XBIG: f64 = 26.543;

// Cody (1969/1990) rational coefficients, SPECFUN CALERF.
const CODY_A: [f64; 5] = [
    3.16112374387056560e0,
    1.13864154151050156e2,
    3.77485237685302021e2,
    3.20937758913846947e3,
    1.85777706184603153e-1,
];
const CODY_B: [f64; 4] =
    [2.36012909523441209e1, 2.44024637934444173e2, 1.28261652607737228e3, 2.84423683343917062e3];
const CODY_C: [f64; 9] = [
    5.64188496988670089e-1,
    8.88314979438837594e0,
    6.61191906371416295e1,
    2.98635138197400131e2,
    8.81952221241769090e2,
    1.71204761263407058e3,
    2.05107837782607147e3,
    1.23033935479799725e3,
    2.15311535474403846e-8,
];
const CODY_D: [f64; 8] = [
    1.57449261107098347e1,
    1.17693950891312499e2,
    5.37181101862009858e2,
    1.62138957456669019e3,
    3.29079923573345963e3,
    4.36261909014324716e3,
    3.43936767414372164e3,
    1.23033935480374942e3,
];
const CODY_P: [f64; 6] = [
    3.05326634961232344e-1,
    3.60344899949804439e-1,
    1.25781726111229246e-1,
    1.60837851487422766e-2,
    6.58749161529837803e-4,
    1.63153871373020978e-2,
];
const CODY_Q: [f64; 5] = [
    2.56852019228982242e0,
    1.87295284992346047e0,
    5.27905102951428412e-1,
    6.05183413124413191e-2,
    2.33520497626869185e-3,
];

/// `exp(−y²)` split as `exp(−⌊16y⌋²/256)·exp(−(y−q)(y+q))` with
/// `q = ⌊16y⌋/16`, so the big exponent is formed from an exactly
/// representable argument (Cody's trick — keeps erfc's *relative* error
/// flat across the tail instead of growing like y²·ulp).
#[inline]
fn exp_neg_sq_split(y: f64) -> f64 {
    let q = (y * 16.0).trunc() / 16.0;
    let del = (y - q) * (y + q);
    (-q * q).exp() * (-del).exp()
}

/// Complementary error function, Cody's rational-Chebyshev forms
/// (|relative error| ≲ 2e-16 against the true value; agrees with the
/// iterative [`erfc`] oracle to ≲1e-13 relative wherever the result is
/// a normal number). Three fixed-cost regions, no iteration.
pub fn erfc_fast(x: f64) -> f64 {
    let y = x.abs();
    if y <= 0.46875 {
        // erf(x) = x·R(x²); erfc = 1 − erf
        let z = y * y;
        let mut num = CODY_A[4] * z;
        let mut den = z;
        for i in 0..3 {
            num = (num + CODY_A[i]) * z;
            den = (den + CODY_B[i]) * z;
        }
        return 1.0 - x * (num + CODY_A[3]) / (den + CODY_B[3]);
    }
    let result = if y <= 4.0 {
        let mut num = CODY_C[8] * y;
        let mut den = y;
        for i in 0..7 {
            num = (num + CODY_C[i]) * y;
            den = (den + CODY_D[i]) * y;
        }
        exp_neg_sq_split(y) * (num + CODY_C[7]) / (den + CODY_D[7])
    } else if y < ERFC_XBIG {
        // erfc(y) = exp(−y²)/(y√π) · (1 − R(1/y²)/…): asymptotic form
        let z = 1.0 / (y * y);
        let mut num = CODY_P[5] * z;
        let mut den = z;
        for i in 0..4 {
            num = (num + CODY_P[i]) * z;
            den = (den + CODY_Q[i]) * z;
        }
        let r = z * (num + CODY_P[4]) / (den + CODY_Q[4]);
        exp_neg_sq_split(y) * (SQRPI - r) / y
    } else {
        0.0
    };
    if x < 0.0 {
        2.0 - result
    } else {
        result
    }
}

/// Batched [`erfc_fast`] — one tight loop over contiguous storage.
pub fn erfc_batch(xs: &[f64], out: &mut [f64]) {
    assert_eq!(xs.len(), out.len());
    for (o, &x) in out.iter_mut().zip(xs) {
        *o = erfc_fast(x);
    }
}

/// Batched standard normal cdf through the fast kernel.
pub fn norm_cdf_batch(zs: &[f64], out: &mut [f64]) {
    assert_eq!(zs.len(), out.len());
    for (o, &z) in out.iter_mut().zip(zs) {
        *o = 0.5 * erfc_fast(-z / std::f64::consts::SQRT_2);
    }
}

/// ln Φ(z) through the fast kernel. `erfc_fast` stays normal down to
/// z ≈ −37, so only the deep tail (where EP essentially never lands)
/// falls back to the log-domain continued fraction.
pub fn ln_norm_cdf_fast(z: f64) -> f64 {
    if z >= 0.0 {
        (-0.5 * erfc_fast(z / std::f64::consts::SQRT_2)).ln_1p()
    } else if z > -26.0 {
        (0.5 * erfc_fast(-z / std::f64::consts::SQRT_2)).ln()
    } else {
        ln_gamma_q_cf(0.5, 0.5 * z * z, LN_SQRT_PI) - std::f64::consts::LN_2
    }
}

/// [`probit_moments`] with the fast `Φ` kernel — same formulas, the
/// rounding differs only at the erfc kernel's ≲1e-13 agreement level.
pub fn probit_moments_fast(y: f64, m: f64, s2: f64) -> (f64, f64, f64) {
    debug_assert!(y == 1.0 || y == -1.0);
    let denom = (1.0 + s2).sqrt();
    let z = y * m / denom;
    let ln_zhat = ln_norm_cdf_fast(z);
    let rho = (ln_norm_pdf(z) - ln_zhat).exp();
    let mu_hat = m + y * s2 * rho / denom;
    let sigma2_hat = s2 - s2 * s2 * rho * (z + rho) / (1.0 + s2);
    (ln_zhat, mu_hat, sigma2_hat)
}

/// [`probit_site_update`] with the fast `Φ` kernel — the sequential EP
/// sweep's per-site hot path.
pub fn probit_site_update_fast(
    y: f64,
    mu_i: f64,
    sigma2_i: f64,
    tau_site: f64,
    nu_site: f64,
) -> Option<(f64, f64, f64, f64, f64)> {
    let tau_cav = 1.0 / sigma2_i - tau_site;
    if tau_cav <= 0.0 {
        return None;
    }
    let nu_cav = mu_i / sigma2_i - nu_site;
    let m = nu_cav / tau_cav;
    let s2 = 1.0 / tau_cav;
    let (ln_zhat, mu_hat, sigma2_hat) = probit_moments_fast(y, m, s2);
    let tau_new = 1.0 / sigma2_hat - tau_cav;
    let nu_new = mu_hat / sigma2_hat - nu_cav;
    Some((ln_zhat, tau_cav, nu_cav, tau_new, nu_new))
}

/// Batched EP site updates for the parallel-sweep backends: all cavities
/// are formed in one pass, the transcendental kernel (`ln Φ` + the Mills
/// ratio `exp`) runs over the contiguous z batch, and a final pass
/// moment-matches back to site parameters. Bitwise-identical per entry to
/// [`probit_site_update_fast`]; sites with a non-positive cavity
/// precision get `valid[i] = false` and their outputs are unspecified.
#[derive(Default)]
pub struct SiteBatch {
    pub valid: Vec<bool>,
    pub ln_zhat: Vec<f64>,
    pub tau_cav: Vec<f64>,
    pub nu_cav: Vec<f64>,
    pub tau_new: Vec<f64>,
    pub nu_new: Vec<f64>,
    z: Vec<f64>,
    rho: Vec<f64>,
    s2: Vec<f64>,
}

impl SiteBatch {
    pub fn new() -> SiteBatch {
        SiteBatch::default()
    }

    /// Recompute every site from the current marginals `(mu, sigma2)`
    /// and site parameters `(tau, nu)`; buffers are reused across sweeps.
    pub fn update(&mut self, y: &[f64], mu: &[f64], sigma2: &[f64], tau: &[f64], nu: &[f64]) {
        let n = y.len();
        assert!(mu.len() == n && sigma2.len() == n && tau.len() == n && nu.len() == n);
        self.valid.clear();
        self.valid.resize(n, false);
        for v in [
            &mut self.ln_zhat,
            &mut self.tau_cav,
            &mut self.nu_cav,
            &mut self.tau_new,
            &mut self.nu_new,
            &mut self.z,
            &mut self.rho,
            &mut self.s2,
        ] {
            v.clear();
            v.resize(n, 0.0);
        }
        // pass 1: cavity parameters and the tilted argument z
        for i in 0..n {
            let tau_cav = 1.0 / sigma2[i] - tau[i];
            self.tau_cav[i] = tau_cav;
            if tau_cav <= 0.0 {
                continue;
            }
            self.valid[i] = true;
            let nu_cav = mu[i] / sigma2[i] - nu[i];
            let s2 = 1.0 / tau_cav;
            self.nu_cav[i] = nu_cav;
            self.s2[i] = s2;
            let m = nu_cav / tau_cav;
            self.z[i] = y[i] * m / (1.0 + s2).sqrt();
        }
        // pass 2: the transcendental kernel over the contiguous batch
        // (invalid slots hold z = 0 — harmless, cheap, branch-free)
        for i in 0..n {
            let z = self.z[i];
            let lnphi = ln_norm_cdf_fast(z);
            self.ln_zhat[i] = lnphi;
            self.rho[i] = (ln_norm_pdf(z) - lnphi).exp();
        }
        // pass 3: moment matching back to site parameters
        for i in 0..n {
            if !self.valid[i] {
                continue;
            }
            let (s2, z, rho) = (self.s2[i], self.z[i], self.rho[i]);
            let m = self.nu_cav[i] / self.tau_cav[i];
            let denom = (1.0 + s2).sqrt();
            let mu_hat = m + y[i] * s2 * rho / denom;
            let sigma2_hat = s2 - s2 * s2 * rho * (z + rho) / (1.0 + s2);
            self.tau_new[i] = 1.0 / sigma2_hat - self.tau_cav[i];
            self.nu_new[i] = mu_hat / sigma2_hat - self.nu_cav[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Brute-force Φ by Simpson integration of the pdf (test oracle).
    fn phi_numeric(z: f64) -> f64 {
        let lo = (-12.0f64).min(z - 1.0);
        let n = 40000;
        let h = (z - lo) / n as f64;
        let mut s = norm_pdf(lo) + norm_pdf(z);
        for i in 1..n {
            let x = lo + i as f64 * h;
            s += norm_pdf(x) * if i % 2 == 1 { 4.0 } else { 2.0 };
        }
        s * h / 3.0
    }

    #[test]
    fn erfc_reference_values() {
        // reference values (Abramowitz & Stegun / mpmath)
        let cases = [
            (0.0, 1.0),
            (0.5, 0.4795001221869535),
            (1.0, 0.15729920705028513),
            (2.0, 0.004677734981063127),
            (3.0, 2.209049699858544e-5),
            (-1.0, 1.842700792949715),
        ];
        for (x, want) in cases {
            let got = erfc(x);
            assert!((got - want).abs() < 1e-13 * (1.0 + want.abs()), "erfc({x}) = {got}, want {want}");
        }
    }

    #[test]
    fn norm_cdf_matches_numeric() {
        for &z in &[-3.0, -1.5, -0.5, 0.0, 0.7, 2.2] {
            let got = norm_cdf(z);
            let want = phi_numeric(z);
            assert!((got - want).abs() < 1e-8, "Phi({z}) = {got}, numeric {want}");
        }
    }

    #[test]
    fn ln_norm_cdf_deep_tail() {
        // asymptotics: ln Φ(z) ≈ −z²/2 − ln(−z√(2π)) for z → −∞
        for &z in &[-10.0, -30.0, -100.0, -1000.0] {
            let got = ln_norm_cdf(z);
            let asym = -0.5 * z * z - (-z * (2.0 * PI).sqrt()).ln();
            assert!(
                (got - asym).abs() < 1e-2 * asym.abs().max(1.0),
                "lnPhi({z}) = {got}, asym {asym}"
            );
            assert!(got.is_finite());
        }
        // symmetric identity Φ(z) + Φ(−z) = 1 around the centre
        for &z in &[-5.0, -2.0, -0.3, 0.0, 1.7] {
            let s = ln_norm_cdf(z).exp() + ln_norm_cdf(-z).exp();
            assert!((s - 1.0).abs() < 1e-12, "z={z}: {s}");
        }
    }

    #[test]
    fn mills_ratio_limits() {
        // ρ(z) → −z as z → −∞; ρ(0) = 2φ(0) = √(2/π)
        assert!((mills_ratio_inv(0.0) - (2.0 / PI).sqrt()).abs() < 1e-12);
        for &z in &[-20.0, -50.0] {
            let rho = mills_ratio_inv(z);
            assert!(rho > -z && rho < -z + 1.0 / (-z), "rho({z}) = {rho}");
        }
    }

    /// Tilted moments vs brute-force quadrature over f.
    #[test]
    fn probit_moments_match_quadrature() {
        for &(y, m, s2) in &[(1.0, 0.3, 0.8), (-1.0, -1.2, 2.5), (1.0, -3.0, 0.5), (-1.0, 2.0, 4.0)] {
            let (ln_zhat, mu_hat, sigma2_hat) = probit_moments(y, m, s2);
            // quadrature
            let s = s2.sqrt();
            let n = 200001;
            let lo = m - 10.0 * s;
            let hi = m + 10.0 * s;
            let h = (hi - lo) / (n - 1) as f64;
            let (mut z0, mut z1, mut z2) = (0.0, 0.0, 0.0);
            for i in 0..n {
                let f = lo + i as f64 * h;
                let w = if i == 0 || i == n - 1 { 0.5 } else { 1.0 };
                let p = norm_cdf(y * f) * norm_pdf((f - m) / s) / s;
                z0 += w * p;
                z1 += w * p * f;
                z2 += w * p * f * f;
            }
            z0 *= h;
            z1 *= h;
            z2 *= h;
            let mu_q = z1 / z0;
            let var_q = z2 / z0 - mu_q * mu_q;
            assert!((ln_zhat - z0.ln()).abs() < 1e-6, "lnZ: {ln_zhat} vs {}", z0.ln());
            assert!((mu_hat - mu_q).abs() < 1e-6, "mu: {mu_hat} vs {mu_q}");
            assert!((sigma2_hat - var_q).abs() < 1e-6, "var: {sigma2_hat} vs {var_q}");
        }
    }

    #[test]
    fn site_update_gives_positive_site_precision() {
        // probit tilted variance is strictly below cavity variance, so the
        // new site precision must be positive
        for &(y, mu, s2, ts, ns) in &[
            (1.0, 0.0, 1.0, 0.0, 0.0),
            (-1.0, 0.5, 2.0, 0.3, 0.1),
            (1.0, -2.0, 0.7, 0.5, -0.4),
        ] {
            let (_, tau_cav, _, tau_new, _) =
                probit_site_update(y, mu, s2, ts, ns).expect("cavity valid");
            assert!(tau_cav > 0.0);
            assert!(tau_new > 0.0, "tau_new = {tau_new}");
        }
    }

    #[test]
    fn site_update_skips_bad_cavity() {
        assert!(probit_site_update(1.0, 0.0, 1.0, 2.0, 0.0).is_none());
    }

    /// The Cody kernel agrees with the iterative series/CF oracle to
    /// ≤1e-13 relative everywhere across the bulk and the whole tail
    /// (grid hits both sides of the ⌊16y⌋/16 exp split).
    #[test]
    fn fast_erfc_matches_series_oracle_across_the_tail() {
        let mut x = -6.0;
        while x < 26.0 {
            for off in [0.0, 0.013, 0.0624999] {
                let xx = x + off;
                let want = erfc(xx);
                let got = erfc_fast(xx);
                let rel = (got - want).abs() / want.abs().max(f64::MIN_POSITIVE);
                // deep in the tail exp(−x²) itself carries ~x²·ε relative
                // rounding in either kernel — scale the floor accordingly
                let tol = 1e-13f64.max(2.0 * xx * xx * f64::EPSILON);
                assert!(
                    rel <= tol,
                    "erfc_fast({xx}) = {got:e}, oracle {want:e}, rel {rel:e}"
                );
            }
            x += 0.0625;
        }
        // underflow region: both sides flush to zero / two
        assert_eq!(erfc_fast(27.0), 0.0);
        assert_eq!(erfc_fast(-27.0), 2.0);
    }

    #[test]
    fn batch_wrappers_match_their_scalar_kernels() {
        let xs: Vec<f64> = (-40..=40).map(|k| k as f64 * 0.37).collect();
        let mut out = vec![0.0; xs.len()];
        erfc_batch(&xs, &mut out);
        for (&x, &o) in xs.iter().zip(&out) {
            assert_eq!(o, erfc_fast(x));
        }
        norm_cdf_batch(&xs, &mut out);
        for (&z, &o) in xs.iter().zip(&out) {
            assert_eq!(o, 0.5 * erfc_fast(-z / std::f64::consts::SQRT_2));
        }
    }

    #[test]
    fn fast_ln_norm_cdf_and_moments_match_reference() {
        for k in -350..=100 {
            let z = k as f64 * 0.1;
            let want = ln_norm_cdf(z);
            let got = ln_norm_cdf_fast(z);
            assert!(
                (got - want).abs() <= 1e-11 * want.abs().max(1.0),
                "lnPhi_fast({z}) = {got}, reference {want}"
            );
        }
        for &(y, m, s2) in &[
            (1.0, 0.3, 0.8),
            (-1.0, -1.2, 2.5),
            (1.0, -9.0, 0.5),
            (-1.0, 14.0, 4.0),
            (1.0, 0.0, 1.0),
        ] {
            let (l0, m0, s0) = probit_moments(y, m, s2);
            let (l1, m1, s1) = probit_moments_fast(y, m, s2);
            assert!((l0 - l1).abs() <= 1e-11 * l0.abs().max(1.0), "lnZ {l0} vs {l1}");
            assert!((m0 - m1).abs() <= 1e-11 * m0.abs().max(1.0), "mu {m0} vs {m1}");
            assert!((s0 - s1).abs() <= 1e-11 * s0.abs().max(1.0), "s2 {s0} vs {s1}");
        }
    }

    /// The batched site kernel is bitwise-identical to the scalar fast
    /// path (the parallel sweeps rely on this), and both track the
    /// reference site update within rounding.
    #[test]
    fn site_batch_matches_scalar_fast_path_bitwise() {
        let cases: Vec<(f64, f64, f64, f64, f64)> = vec![
            (1.0, 0.0, 1.0, 0.0, 0.0),
            (-1.0, 0.5, 2.0, 0.3, 0.1),
            (1.0, -2.0, 0.7, 0.5, -0.4),
            (1.0, 0.0, 1.0, 2.0, 0.0), // bad cavity -> skipped
            (-1.0, 3.0, 0.4, 1.0, 0.6),
        ];
        let y: Vec<f64> = cases.iter().map(|c| c.0).collect();
        let mu: Vec<f64> = cases.iter().map(|c| c.1).collect();
        let s2: Vec<f64> = cases.iter().map(|c| c.2).collect();
        let tau: Vec<f64> = cases.iter().map(|c| c.3).collect();
        let nu: Vec<f64> = cases.iter().map(|c| c.4).collect();
        let mut batch = SiteBatch::new();
        batch.update(&y, &mu, &s2, &tau, &nu);
        for i in 0..cases.len() {
            match probit_site_update_fast(y[i], mu[i], s2[i], tau[i], nu[i]) {
                None => assert!(!batch.valid[i], "site {i} should be skipped"),
                Some((lz, tc, nc, tn, nn)) => {
                    assert!(batch.valid[i]);
                    assert_eq!(batch.ln_zhat[i], lz, "site {i} lnZ");
                    assert_eq!(batch.tau_cav[i], tc, "site {i} tau_cav");
                    assert_eq!(batch.nu_cav[i], nc, "site {i} nu_cav");
                    assert_eq!(batch.tau_new[i], tn, "site {i} tau_new");
                    assert_eq!(batch.nu_new[i], nn, "site {i} nu_new");
                    let (lz0, tc0, nc0, tn0, nn0) =
                        probit_site_update(y[i], mu[i], s2[i], tau[i], nu[i]).unwrap();
                    for (a, b) in [(lz, lz0), (tc, tc0), (nc, nc0), (tn, tn0), (nn, nn0)] {
                        assert!((a - b).abs() <= 1e-10 * b.abs().max(1.0), "site {i}: {a} vs {b}");
                    }
                }
            }
        }
    }
}
