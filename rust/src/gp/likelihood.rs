//! Probit likelihood: numerically stable normal cdf machinery and the
//! tilted (EP "moment-matching") integrals.
//!
//! `Φ` is computed through the regularized incomplete gamma function
//! (series + continued fraction, Numerical-Recipes style but run to f64
//! convergence), with a log-domain continued fraction for the deep
//! negative tail so `log Φ(z)` is finite and accurate down to z ≈ −1e7.

use std::f64::consts::PI;

const LN_SQRT_PI: f64 = 0.5723649429247001; // ln Γ(1/2) = ln √π
const EPS: f64 = 1e-16;
const FPMIN: f64 = 1e-300;

/// Regularized lower incomplete gamma P(a, x) by series expansion.
fn gamma_p_series(a: f64, x: f64, ln_gamma_a: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    let mut ap = a;
    let mut sum = 1.0 / a;
    let mut del = sum;
    for _ in 0..500 {
        ap += 1.0;
        del *= x / ap;
        sum += del;
        if del.abs() < sum.abs() * EPS {
            break;
        }
    }
    sum * (-x + a * x.ln() - ln_gamma_a).exp()
}

/// ln of the regularized upper incomplete gamma Q(a, x) by continued
/// fraction (modified Lentz). Accurate for x ≳ a + 1.
fn ln_gamma_q_cf(a: f64, x: f64, ln_gamma_a: f64) -> f64 {
    let mut b = x + 1.0 - a;
    let mut c = 1.0 / FPMIN;
    let mut d = 1.0 / b;
    let mut h = d;
    for i in 1..500 {
        let an = -(i as f64) * (i as f64 - a);
        b += 2.0;
        d = an * d + b;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = b + an / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < EPS {
            break;
        }
    }
    -x + a * x.ln() - ln_gamma_a + h.ln()
}

/// Complementary error function, |relative error| ≲ 1e-14.
pub fn erfc(x: f64) -> f64 {
    if x < 0.0 {
        return 2.0 - erfc(-x);
    }
    let x2 = x * x;
    if x2 < 1.5 {
        1.0 - gamma_p_series(0.5, x2, LN_SQRT_PI)
    } else {
        ln_gamma_q_cf(0.5, x2, LN_SQRT_PI).exp()
    }
}

/// Standard normal pdf.
#[inline]
pub fn norm_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * PI).sqrt()
}

/// ln of the standard normal pdf.
#[inline]
pub fn ln_norm_pdf(z: f64) -> f64 {
    -0.5 * z * z - 0.5 * (2.0 * PI).ln()
}

/// Standard normal cdf Φ(z).
pub fn norm_cdf(z: f64) -> f64 {
    0.5 * erfc(-z / std::f64::consts::SQRT_2)
}

/// ln Φ(z), stable in the deep negative tail.
pub fn ln_norm_cdf(z: f64) -> f64 {
    if z >= 0.0 {
        // 1 − Φ(z) = ½ erfc(z/√2) ≤ ½; ln1p is exact here
        (-0.5 * erfc(z / std::f64::consts::SQRT_2)).ln_1p()
    } else {
        let x2 = 0.5 * z * z; // (|z|/√2)²
        if x2 < 1.5 {
            (0.5 * erfc(-z / std::f64::consts::SQRT_2)).ln()
        } else {
            // ln(½ Q(½, z²/2)) — fully log-domain
            ln_gamma_q_cf(0.5, x2, LN_SQRT_PI) - std::f64::consts::LN_2
        }
    }
}

/// φ(z)/Φ(z), the inverse Mills ratio (stable for very negative z).
pub fn mills_ratio_inv(z: f64) -> f64 {
    (ln_norm_pdf(z) - ln_norm_cdf(z)).exp()
}

/// Moments of the tilted distribution `∝ Φ(y·f) N(f | m, s²)`:
/// returns `(ln Ẑ, μ̂, σ̂²)` — the EP moment-matching step for the probit
/// likelihood (Rasmussen & Williams eqs. 3.58, 3.85).
pub fn probit_moments(y: f64, m: f64, s2: f64) -> (f64, f64, f64) {
    debug_assert!(y == 1.0 || y == -1.0);
    let denom = (1.0 + s2).sqrt();
    let z = y * m / denom;
    let ln_zhat = ln_norm_cdf(z);
    let rho = mills_ratio_inv(z);
    let mu_hat = m + y * s2 * rho / denom;
    let sigma2_hat = s2 - s2 * s2 * rho * (z + rho) / (1.0 + s2);
    (ln_zhat, mu_hat, sigma2_hat)
}

/// EP site update from the current marginal `(mu_i, sigma2_i)` and site
/// `(tau_site, nu_site)`: returns `(ln Ẑ, cavity τ₋, cavity ν₋, new τ̃,
/// new ν̃)`. Returns `None` when the cavity precision is non-positive
/// (site skipped, standard EP practice).
pub fn probit_site_update(
    y: f64,
    mu_i: f64,
    sigma2_i: f64,
    tau_site: f64,
    nu_site: f64,
) -> Option<(f64, f64, f64, f64, f64)> {
    let tau_cav = 1.0 / sigma2_i - tau_site;
    if tau_cav <= 0.0 {
        return None;
    }
    let nu_cav = mu_i / sigma2_i - nu_site;
    let m = nu_cav / tau_cav;
    let s2 = 1.0 / tau_cav;
    let (ln_zhat, mu_hat, sigma2_hat) = probit_moments(y, m, s2);
    let tau_new = 1.0 / sigma2_hat - tau_cav;
    let nu_new = mu_hat / sigma2_hat - nu_cav;
    Some((ln_zhat, tau_cav, nu_cav, tau_new, nu_new))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Brute-force Φ by Simpson integration of the pdf (test oracle).
    fn phi_numeric(z: f64) -> f64 {
        let lo = (-12.0f64).min(z - 1.0);
        let n = 40000;
        let h = (z - lo) / n as f64;
        let mut s = norm_pdf(lo) + norm_pdf(z);
        for i in 1..n {
            let x = lo + i as f64 * h;
            s += norm_pdf(x) * if i % 2 == 1 { 4.0 } else { 2.0 };
        }
        s * h / 3.0
    }

    #[test]
    fn erfc_reference_values() {
        // reference values (Abramowitz & Stegun / mpmath)
        let cases = [
            (0.0, 1.0),
            (0.5, 0.4795001221869535),
            (1.0, 0.15729920705028513),
            (2.0, 0.004677734981063127),
            (3.0, 2.209049699858544e-5),
            (-1.0, 1.842700792949715),
        ];
        for (x, want) in cases {
            let got = erfc(x);
            assert!((got - want).abs() < 1e-13 * (1.0 + want.abs()), "erfc({x}) = {got}, want {want}");
        }
    }

    #[test]
    fn norm_cdf_matches_numeric() {
        for &z in &[-3.0, -1.5, -0.5, 0.0, 0.7, 2.2] {
            let got = norm_cdf(z);
            let want = phi_numeric(z);
            assert!((got - want).abs() < 1e-8, "Phi({z}) = {got}, numeric {want}");
        }
    }

    #[test]
    fn ln_norm_cdf_deep_tail() {
        // asymptotics: ln Φ(z) ≈ −z²/2 − ln(−z√(2π)) for z → −∞
        for &z in &[-10.0, -30.0, -100.0, -1000.0] {
            let got = ln_norm_cdf(z);
            let asym = -0.5 * z * z - (-z * (2.0 * PI).sqrt()).ln();
            assert!(
                (got - asym).abs() < 1e-2 * asym.abs().max(1.0),
                "lnPhi({z}) = {got}, asym {asym}"
            );
            assert!(got.is_finite());
        }
        // symmetric identity Φ(z) + Φ(−z) = 1 around the centre
        for &z in &[-5.0, -2.0, -0.3, 0.0, 1.7] {
            let s = ln_norm_cdf(z).exp() + ln_norm_cdf(-z).exp();
            assert!((s - 1.0).abs() < 1e-12, "z={z}: {s}");
        }
    }

    #[test]
    fn mills_ratio_limits() {
        // ρ(z) → −z as z → −∞; ρ(0) = 2φ(0) = √(2/π)
        assert!((mills_ratio_inv(0.0) - (2.0 / PI).sqrt()).abs() < 1e-12);
        for &z in &[-20.0, -50.0] {
            let rho = mills_ratio_inv(z);
            assert!(rho > -z && rho < -z + 1.0 / (-z), "rho({z}) = {rho}");
        }
    }

    /// Tilted moments vs brute-force quadrature over f.
    #[test]
    fn probit_moments_match_quadrature() {
        for &(y, m, s2) in &[(1.0, 0.3, 0.8), (-1.0, -1.2, 2.5), (1.0, -3.0, 0.5), (-1.0, 2.0, 4.0)] {
            let (ln_zhat, mu_hat, sigma2_hat) = probit_moments(y, m, s2);
            // quadrature
            let s = s2.sqrt();
            let n = 200001;
            let lo = m - 10.0 * s;
            let hi = m + 10.0 * s;
            let h = (hi - lo) / (n - 1) as f64;
            let (mut z0, mut z1, mut z2) = (0.0, 0.0, 0.0);
            for i in 0..n {
                let f = lo + i as f64 * h;
                let w = if i == 0 || i == n - 1 { 0.5 } else { 1.0 };
                let p = norm_cdf(y * f) * norm_pdf((f - m) / s) / s;
                z0 += w * p;
                z1 += w * p * f;
                z2 += w * p * f * f;
            }
            z0 *= h;
            z1 *= h;
            z2 *= h;
            let mu_q = z1 / z0;
            let var_q = z2 / z0 - mu_q * mu_q;
            assert!((ln_zhat - z0.ln()).abs() < 1e-6, "lnZ: {ln_zhat} vs {}", z0.ln());
            assert!((mu_hat - mu_q).abs() < 1e-6, "mu: {mu_hat} vs {mu_q}");
            assert!((sigma2_hat - var_q).abs() < 1e-6, "var: {sigma2_hat} vs {var_q}");
        }
    }

    #[test]
    fn site_update_gives_positive_site_precision() {
        // probit tilted variance is strictly below cavity variance, so the
        // new site precision must be positive
        for &(y, mu, s2, ts, ns) in &[
            (1.0, 0.0, 1.0, 0.0, 0.0),
            (-1.0, 0.5, 2.0, 0.3, 0.1),
            (1.0, -2.0, 0.7, 0.5, -0.4),
        ] {
            let (_, tau_cav, _, tau_new, _) =
                probit_site_update(y, mu, s2, ts, ns).expect("cavity valid");
            assert!(tau_cav > 0.0);
            assert!(tau_new > 0.0, "tau_new = {tau_new}");
        }
    }

    #[test]
    fn site_update_skips_bad_cavity() {
        assert!(probit_site_update(1.0, 0.0, 1.0, 2.0, 0.0).is_none());
    }
}
