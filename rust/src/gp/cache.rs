//! Pattern / symbolic-structure reuse across hyperparameter evaluations.
//!
//! The optimizer loop (SCG over `log Z_EP`) evaluates EP at a fresh
//! hyperparameter point on every step. The *values* of the covariance
//! matrix change every time, but its sparsity pattern only changes when a
//! length-scale change actually grows the compact-support radius: a
//! σ²-only step leaves the pattern untouched, and a shrinking length-scale
//! produces a pattern that is a *subset* of the cached one (the extra
//! entries evaluate to exact zeros, so EP on the superset pattern computes
//! the identical fixed point). Re-running the neighbor queries, the
//! fill-reducing ordering, and the symbolic Cholesky analysis on every
//! gradient evaluation — as the seed did — is therefore pure waste; cf.
//! Vanhatalo & Vehtari (2008), which reuses sparse structure across
//! hyperparameter evaluations in GPstuff.
//!
//! [`PatternCache`] holds, per training set:
//!
//! * one [`NeighborIndex`] over the inputs (built once; radius queries
//!   adapt to any support radius),
//! * the covariance pattern keyed by the Euclidean support radius it was
//!   built at (`∞` for globally supported kernels),
//! * the fill-reducing permutation, the permuted inputs, the permuted
//!   pattern and its [`Symbolic`] analysis (the "factorization plan"),
//!   computed lazily — exact-GP regression only needs the pattern. The
//!   `Symbolic` carries the supernode partition and assembly-tree wave
//!   schedule of the parallel numeric LDLᵀ
//!   ([`SupernodeSchedule`](crate::sparse::symbolic::SupernodeSchedule)),
//!   so — like the Takahashi wave schedule kept in [`GradScratch`] — the
//!   factorization's parallel schedule is built once per pattern and
//!   reused by every sweep of every EP run in the optimizer loop.
//!
//! The cache contract: one `PatternCache` serves one fixed point set `x`
//! and one ordering choice. A hit requires the new ARD support ellipsoid
//! to be contained in the built one — per-axis `l'_d <= l_d`, not just a
//! smaller `max_d l_d` (growing any single axis can create pairs outside
//! the cached ellipsoid pattern); anything else rebuilds and re-keys.
//! Because values are always re-evaluated on the cached pattern with
//! [`CovFunction::cov_values_on_pattern`], a hit and a miss produce
//! bitwise-identical covariance values on the shared entries and exact
//! zeros on the superset-only entries — `SparseEp::log_z_grad`'s pattern
//! agreement is an invariant, not a hope.

use std::sync::Arc;

use crate::geom::NeighborIndex;
use crate::gp::covariance::{CovFunction, INDEX_MIN_N};
use crate::sparse::csc::CscMatrix;
use crate::sparse::lowrank::InversePatternScratch;
use crate::sparse::ordering::{self, Ordering};
use crate::sparse::symbolic::Symbolic;
use crate::sparse::takahashi::SparseInverse;

/// Buffers reused across gradient evaluations while the pattern holds.
///
/// Every SCG step evaluates `log Z` *and* its gradient; the gradient's
/// trace term rebuilds the Takahashi sparsified inverse — `O(nnz(L))`
/// values (plus, for CS+FIC, the n×m `V` block and the `B⁻¹`-on-pattern
/// output). The *values* change with every site/hyperparameter move, but
/// on a cache hit the *sizes* do not, so the optimizer loop keeps these
/// buffers in its `PatternCache` instead of reallocating tens of
/// megabytes per gradient evaluation. The compute methods
/// (`LdlFactor::takahashi_inverse_into`,
/// `SparseLowRank::inverse_on_pattern_into`) resize on demand, so a
/// pattern rebuild simply regrows them — no invalidation hook needed.
#[derive(Default)]
pub struct GradScratch {
    /// Takahashi z-buffers for `SparseEp::log_z_grad_cached`.
    pub takahashi: SparseInverse,
    /// Takahashi + V buffers for `CsFicEp::log_z_grad_cs_cached`.
    pub lowrank: InversePatternScratch,
    /// `B⁻¹` values on the CS pattern (CS+FIC trace term).
    pub binv: Vec<f64>,
}

/// A covariance pattern valid for every ARD support ellipsoid contained
/// in the one it was built at.
#[derive(Clone, Debug)]
pub struct CachedPattern {
    /// Euclidean support radius the pattern was built at
    /// (`f64::INFINITY` for globally supported kernels — the pattern is
    /// dense and covers everything).
    pub radius: f64,
    /// ARD length-scales the pattern was built at. The pattern is the
    /// exact support *ellipsoid* `Σ_d Δ_d²/l_d² < 1`, so reuse requires
    /// per-axis containment (`l'_d <= l_d` for every `d`) — a smaller
    /// `max_d l'_d` alone does NOT make the new support a subset when one
    /// axis grew.
    pub lengthscales: Vec<f64>,
    /// Unpermuted pattern over the original inputs (values are from the
    /// build-time hyperparameters; callers re-fill with
    /// [`CovFunction::cov_values_on_pattern`]).
    pub pattern: CscMatrix,
}

impl CachedPattern {
    /// Does this pattern provably contain every nonzero of `cov`'s Gram
    /// matrix? Dense-built patterns contain everything; compact-support
    /// patterns require the new ellipsoid to fit inside the built one,
    /// axis by axis.
    fn covers(&self, cov: &CovFunction) -> bool {
        if self.radius.is_infinite() {
            return true;
        }
        if !cov.is_compact() || cov.lengthscales.len() != self.lengthscales.len() {
            return false;
        }
        cov.lengthscales.iter().zip(&self.lengthscales).all(|(new, old)| new <= old)
    }
}

/// Everything the sparse factorization needs, derived from a
/// [`CachedPattern`]: permutation, permuted inputs/pattern, symbolic
/// analysis.
#[derive(Clone, Debug)]
pub struct FactorPlan {
    /// old index -> permuted index (shared — EP runs keep a handle
    /// instead of deep-cloning per evaluation).
    pub perm: Arc<Vec<usize>>,
    /// Permuted inputs (covariance values must be built against these;
    /// shared for the same reason).
    pub xp: Arc<Vec<Vec<f64>>>,
    /// Permuted pattern `P K Pᵀ`.
    pub pattern_perm: CscMatrix,
    /// Symbolic Cholesky analysis of `pattern_perm`, including the
    /// supernode/wave schedule that drives the parallel numeric
    /// factorization — every `LdlFactor` of this plan shares it by `Arc` —
    /// and, under nested dissection, the ordering's separator tree.
    pub symbolic: Arc<Symbolic>,
    /// The concrete ordering this plan's permutation came from. The
    /// cache's configured choice may be [`Ordering::Auto`]; this is what
    /// the policy resolved it to at build time (re-resolved on every
    /// pattern rebuild, since the statistics it reads come from the
    /// pattern). Never `Auto`.
    pub ordering: Ordering,
}

/// Reusable covariance structure for repeated evaluations on one fixed
/// training set. See the module docs for the reuse contract.
///
/// A σ²-only hyperparameter step keeps the whole plan — pattern,
/// ordering, symbolic analysis and the factorization's supernode/wave
/// schedule:
///
/// ```
/// use csgp::gp::cache::PatternCache;
/// use csgp::gp::covariance::{CovFunction, CovKind};
/// use csgp::sparse::ordering::Ordering;
///
/// let x: Vec<Vec<f64>> =
///     (0..50).map(|i| vec![(i % 10) as f64, (i / 10) as f64]).collect();
/// let mut cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 2.0);
/// let mut cache = PatternCache::new(Ordering::Rcm);
///
/// let (_, plan) = cache.plan_for(&cov, &x);  // miss: full analysis
/// cov.sigma2 = 2.5;                          // σ²-only step
/// let (_, plan2) = cache.plan_for(&cov, &x); // hit: same structure
/// assert!(std::sync::Arc::ptr_eq(&plan, &plan2));
/// assert_eq!((cache.hits, cache.misses), (1, 1));
/// ```
pub struct PatternCache {
    ordering: Ordering,
    index: Option<NeighborIndex>,
    pattern: Option<Arc<CachedPattern>>,
    plan: Option<Arc<FactorPlan>>,
    /// Cheap identity check on the point set the cache was built for
    /// (length + first/last point bits) so that handing a cache a
    /// different dataset misses instead of silently reusing the old
    /// pattern.
    data_fp: u64,
    /// Evaluations answered from the cached pattern.
    pub hits: usize,
    /// Evaluations that had to rebuild the pattern.
    pub misses: usize,
    /// Gradient-evaluation buffers reused across SCG steps (see
    /// [`GradScratch`]).
    pub grad_scratch: GradScratch,
}

/// O(d) fingerprint of a point set: length plus the raw bits of the
/// first and last points. Not collision-proof in general, but any two
/// datasets that agree on it and still differ violate the documented
/// one-point-set-per-cache contract in a way no cheap check can catch.
fn point_set_fingerprint(x: &[Vec<f64>]) -> u64 {
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    x.len().hash(&mut h);
    for p in [x.first(), x.last()].into_iter().flatten() {
        for v in p {
            v.to_bits().hash(&mut h);
        }
    }
    h.finish()
}

impl PatternCache {
    /// A cache computing its factorization plans with `ordering`.
    /// [`Ordering::Auto`] is resolved per plan build (pattern statistics +
    /// pool width, `CSGP_ORDERING` override); the concrete choice is
    /// recorded in [`FactorPlan::ordering`], and a nested-dissection plan
    /// carries its separator tree inside the symbolic analysis.
    pub fn new(ordering: Ordering) -> PatternCache {
        PatternCache {
            ordering,
            index: None,
            pattern: None,
            plan: None,
            data_fp: 0,
            hits: 0,
            misses: 0,
            grad_scratch: GradScratch::default(),
        }
    }

    pub fn ordering(&self) -> Ordering {
        self.ordering
    }

    /// The covariance pattern for `cov` on `x`, reusing the cached
    /// (superset) pattern when the new support ellipsoid is contained in
    /// the cached one (see [`CachedPattern::covers`]).
    pub fn pattern_for(&mut self, cov: &CovFunction, x: &[Vec<f64>]) -> Arc<CachedPattern> {
        let radius = cov.support_radius().unwrap_or(f64::INFINITY);
        let fp = point_set_fingerprint(x);
        if let Some(cached) = &self.pattern {
            // the fingerprint covers the length, so n_cols needs no check
            if self.data_fp == fp && cached.covers(cov) {
                self.hits += 1;
                crate::obs::counters::CACHE_HIT.add(1);
                if cov.lengthscales != cached.lengthscales {
                    // superset reuse: the ellipsoid shrank, values are
                    // re-evaluated on the cached (bigger) pattern
                    crate::obs::counters::CACHE_SHRINK_REUSE.add(1);
                }
                return cached.clone();
            }
        }
        self.misses += 1;
        crate::obs::counters::CACHE_MISS.add(1);
        if self.pattern.is_some() && self.data_fp == fp {
            // same point set, grown support: new neighbor queries, new
            // ordering, new symbolic analysis
            crate::obs::counters::CACHE_GROW_REANALYZE.add(1);
        }
        let pattern = match cov.support_radius() {
            Some(r) if x.len() >= INDEX_MIN_N => {
                // one index serves every rebuild: grid/kd-tree queries
                // accept any radius after construction. Drop it when the
                // point set itself changed (contract misuse — rebuild
                // rather than compound it with a wrong pattern).
                if self.data_fp != fp {
                    self.index = None;
                }
                let index = self.index.get_or_insert_with(|| NeighborIndex::build(x, r));
                cov.cov_matrix_with(x, index)
            }
            _ => cov.cov_matrix_brute(x),
        };
        let cached = Arc::new(CachedPattern {
            radius,
            lengthscales: cov.lengthscales.clone(),
            pattern,
        });
        self.data_fp = fp;
        self.pattern = Some(cached.clone());
        self.plan = None; // derived structure is stale
        cached
    }

    /// The pattern *and* its factorization plan (permutation + symbolic),
    /// rebuilding the plan only when the pattern itself was rebuilt.
    pub fn plan_for(
        &mut self,
        cov: &CovFunction,
        x: &[Vec<f64>],
    ) -> (Arc<CachedPattern>, Arc<FactorPlan>) {
        let cached = self.pattern_for(cov, x);
        if let Some(plan) = &self.plan {
            return (cached, plan.clone());
        }
        let n = x.len();
        let mut pspan = crate::obs::span("cache.plan");
        if pspan.is_active() {
            pspan.field_u64("n", n as u64);
            pspan.field_u64("nnz", cached.pattern.nnz() as u64);
        }
        // the training inputs are exactly the pattern's node coordinates,
        // so nested dissection (chosen directly or by the Auto policy)
        // always gets its geometric-bisection fast path here
        let ordered = ordering::order(&cached.pattern, self.ordering, Some(x));
        let pattern_perm = cached.pattern.permute_sym(&ordered.perm);
        let mut xp = vec![Vec::new(); n];
        for old in 0..n {
            xp[ordered.perm[old]] = x[old].clone();
        }
        let symbolic = Arc::new(Symbolic::analyze_with_septree(
            &pattern_perm,
            ordered.septree.map(Arc::new),
        ));
        let plan = Arc::new(FactorPlan {
            perm: Arc::new(ordered.perm),
            xp: Arc::new(xp),
            pattern_perm,
            symbolic,
            ordering: ordered.resolved,
        });
        self.plan = Some(plan.clone());
        (cached, plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::covariance::CovKind;
    use crate::testutil::random_points;

    #[test]
    fn sigma2_step_and_shrink_hit_growth_misses() {
        let x = random_points(80, 2, 8.0, 7);
        let mut cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 2.0);
        let mut cache = PatternCache::new(Ordering::Rcm);
        let (p0, plan0) = cache.plan_for(&cov, &x);
        assert_eq!(cache.misses, 1);

        // σ²-only step: same radius, must hit and keep the plan
        cov.sigma2 = 3.7;
        let (p1, plan1) = cache.plan_for(&cov, &x);
        assert_eq!((cache.hits, cache.misses), (1, 1));
        assert!(Arc::ptr_eq(&p0, &p1) && Arc::ptr_eq(&plan0, &plan1));

        // shrinking length-scale: superset reuse
        cov.lengthscales = vec![1.1, 1.1];
        let (p2, _) = cache.plan_for(&cov, &x);
        assert_eq!((cache.hits, cache.misses), (2, 1));
        assert!(Arc::ptr_eq(&p0, &p2));

        // growing length-scale: rebuild pattern + plan
        cov.lengthscales = vec![2.5, 2.5];
        let (p3, plan3) = cache.plan_for(&cov, &x);
        assert_eq!((cache.hits, cache.misses), (2, 2));
        assert!(!Arc::ptr_eq(&p0, &p3) && !Arc::ptr_eq(&plan0, &plan3));
        assert!(p3.pattern.nnz() > p0.pattern.nnz());
    }

    /// The anisotropic trap: a *smaller max* lengthscale whose ellipsoid
    /// still pokes out of the cached one along a grown axis must MISS —
    /// a hit would silently drop true nonzero covariance entries.
    #[test]
    fn anisotropic_axis_growth_misses_despite_smaller_max() {
        let x = random_points(90, 2, 8.0, 23);
        let mut built = CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.0);
        built.lengthscales = vec![2.0, 1.0];
        let mut probe = built.clone();
        probe.lengthscales = vec![1.9, 1.5]; // max shrank, axis 1 grew
        let mut cache = PatternCache::new(Ordering::Natural);
        let _ = cache.pattern_for(&built, &x);
        let p = cache.pattern_for(&probe, &x);
        assert_eq!((cache.hits, cache.misses), (0, 2), "axis growth must rebuild");
        // rebuilt pattern is the probe kernel's exact pattern
        assert_eq!(p.pattern, probe.cov_matrix(&x));
        // and a per-axis shrink of the new pattern hits again
        let mut shrunk = probe.clone();
        shrunk.lengthscales = vec![1.0, 1.5];
        let _ = cache.pattern_for(&shrunk, &x);
        assert_eq!((cache.hits, cache.misses), (1, 2));
    }

    #[test]
    fn superset_values_match_exact_assembly_on_shared_entries() {
        let x = random_points(120, 3, 6.0, 19);
        let big = CovFunction::new(CovKind::Pp(2), 3, 1.3, 2.2);
        let mut small = big.clone();
        small.lengthscales = vec![1.4, 1.0, 1.2];
        let mut cache = PatternCache::new(Ordering::Natural);
        let cached = cache.pattern_for(&big, &x); // key at the big radius
        let on_superset = small.cov_values_on_pattern(&x, &cached.pattern);
        let exact = small.cov_matrix(&x);
        assert_eq!(cache.misses, 1);
        assert_eq!(cache.hits, 0);
        let _ = cache.pattern_for(&small, &x);
        assert_eq!(cache.hits, 1);
        // every exact entry appears in the superset with the same value;
        // superset-only entries are exact zeros
        for j in 0..x.len() {
            let (rows, vals) = on_superset.col(j);
            for (&i, &v) in rows.iter().zip(vals) {
                assert_eq!(v, exact.get(i, j), "({i},{j})");
            }
            let (erows, evals) = exact.col(j);
            for (&i, &v) in erows.iter().zip(evals) {
                if v != 0.0 {
                    assert_eq!(on_superset.get(i, j), v, "missing ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn different_point_set_misses_even_at_same_size() {
        // the cache contract is one point set per cache; handing it a
        // different dataset (same size or not) must rebuild pattern AND
        // index rather than silently reuse the old structure
        let x1 = random_points(80, 2, 8.0, 1);
        let x2 = random_points(80, 2, 8.0, 2); // same size, different points
        let x3 = random_points(120, 2, 8.0, 3);
        let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 2.0);
        let mut cache = PatternCache::new(Ordering::Natural);
        let _ = cache.pattern_for(&cov, &x1);
        let p2 = cache.pattern_for(&cov, &x2);
        assert_eq!((cache.hits, cache.misses), (0, 2));
        assert_eq!(p2.pattern, cov.cov_matrix(&x2));
        let p3 = cache.pattern_for(&cov, &x3);
        assert_eq!((cache.hits, cache.misses), (0, 3));
        assert_eq!(p3.pattern.n_cols, 120);
        assert_eq!(p3.pattern, cov.cov_matrix(&x3));
    }

    /// Auto and ND plans: the resolved ordering is recorded (never
    /// `Auto`), an ND plan threads its separator tree into the symbolic
    /// analysis, and the structure still reuses across σ²-only steps.
    #[test]
    fn auto_and_nd_plans_resolve_and_carry_structure() {
        let x = random_points(120, 2, 8.0, 31);
        let mut cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 2.0);
        let mut cache = PatternCache::new(Ordering::Nd);
        let (_, plan) = cache.plan_for(&cov, &x);
        assert_eq!(plan.ordering, Ordering::Nd);
        let tree = plan.symbolic.septree.as_ref().expect("nd plan keeps its separator tree");
        tree.validate(&plan.pattern_perm).unwrap();
        cov.sigma2 = 2.0; // σ²-only step: same plan, same tree
        let (_, plan2) = cache.plan_for(&cov, &x);
        assert!(Arc::ptr_eq(&plan, &plan2));

        let mut auto_cache = PatternCache::new(Ordering::Auto);
        let (_, aplan) = auto_cache.plan_for(&cov, &x);
        assert_ne!(aplan.ordering, Ordering::Auto, "Auto must resolve at build time");
        // whatever it resolved to, the plan is a valid permutation setup
        assert_eq!(aplan.perm.len(), x.len());
        assert_eq!(aplan.pattern_perm.n_cols, x.len());
    }

    #[test]
    fn dense_kernels_cache_with_infinite_radius() {
        let x = random_points(30, 2, 5.0, 3);
        let mut cov = CovFunction::new(CovKind::Se, 2, 1.0, 1.0);
        let mut cache = PatternCache::new(Ordering::Natural);
        let (p0, _) = cache.plan_for(&cov, &x);
        assert!((p0.pattern.density() - 1.0).abs() < 1e-15);
        cov.lengthscales = vec![9.0, 0.2];
        let _ = cache.plan_for(&cov, &x);
        assert_eq!((cache.hits, cache.misses), (1, 1));
    }
}
