//! Dense EP for GP binary classification — the paper's baseline.
//!
//! Rasmussen & Williams Algorithm 3.5: sequential site updates with the
//! O(n²) rank-one posterior update (paper eq. 4), a full recompute of
//! `Σ, μ` from the Cholesky of `B` at the end of each sweep for numerical
//! hygiene, and the GPML-form `log Z_EP`.

use crate::gp::covariance::CovFunction;
use crate::gp::likelihood::probit_site_update;
use crate::gp::marginal::{ep_log_z, grad_quadratic_term, EpOptions, EpSites};
use crate::sparse::dense::{DenseCholesky, DenseMatrix};

/// Converged dense-EP state.
pub struct DenseEp {
    pub sites: EpSites,
    pub log_z: f64,
    pub mu: Vec<f64>,
    pub sigma_diag: Vec<f64>,
    pub sweeps: usize,
    pub converged: bool,
    /// sqrt of site precisions.
    pub sw: Vec<f64>,
    /// Cholesky of B = I + sW K sW.
    pub chol_b: DenseCholesky,
    /// `ν̃ − sW ⊙ B⁻¹ (sW ⊙ K ν̃)` — the representer weights: the latent
    /// predictive mean is `k*ᵀ w_pred`, and eq. (6)'s `b` vector.
    pub w_pred: Vec<f64>,
}

impl DenseEp {
    /// Run EP to convergence.
    pub fn run(
        cov: &CovFunction,
        x: &[Vec<f64>],
        y: &[f64],
        opts: &EpOptions,
    ) -> Result<DenseEp, String> {
        let n = x.len();
        assert_eq!(y.len(), n);
        let k = cov.cov_matrix(x).to_dense();
        let mut sites = EpSites::zeros(n);
        let mut sigma = k.clone();
        let mut mu = vec![0.0; n];
        let mut log_z_old = f64::NEG_INFINITY;
        let mut log_z = f64::NEG_INFINITY;
        let mut sweeps = 0;
        let mut converged = false;
        let mut chol_b = DenseMatrix::identity(n).cholesky().unwrap();

        while sweeps < opts.max_sweeps {
            for i in 0..n {
                let Some((lz, tc, nc, mut tn, mut nn)) =
                    probit_site_update(y[i], mu[i], sigma.at(i, i), sites.tau[i], sites.nu[i])
                else {
                    continue;
                };
                if opts.damping < 1.0 {
                    tn = opts.damping * tn + (1.0 - opts.damping) * sites.tau[i];
                    nn = opts.damping * nn + (1.0 - opts.damping) * sites.nu[i];
                }
                let dtau = tn - sites.tau[i];
                let dnu = nn - sites.nu[i];
                sites.ln_zhat[i] = lz;
                sites.tau_cav[i] = tc;
                sites.nu_cav[i] = nc;
                sites.tau[i] = tn;
                sites.nu[i] = nn;
                // rank-one update of Σ (paper eq. 4) and incremental μ
                let delta = dtau / (1.0 + dtau * sigma.at(i, i));
                let s: Vec<f64> = (0..n).map(|r| sigma.at(r, i)).collect();
                let s_dot_nu_old: f64 =
                    s.iter().zip(&sites.nu).map(|(a, b)| a * b).sum::<f64>() - s[i] * dnu;
                for r in 0..n {
                    for c in 0..n {
                        *sigma.at_mut(r, c) -= delta * s[r] * s[c];
                    }
                }
                let coef = dnu - delta * s_dot_nu_old - delta * s[i] * dnu;
                for r in 0..n {
                    mu[r] += coef * s[r];
                }
            }
            sweeps += 1;

            // full recompute of Σ, μ from the Cholesky of B
            let (sig, m, ch, sw) = recompute(&k, &sites);
            sigma = sig;
            mu = m;
            chol_b = ch;
            let nu_dot_mu: f64 = sites.nu.iter().zip(&mu).map(|(a, b)| a * b).sum();
            log_z = ep_log_z(&sites, chol_b.logdet(), nu_dot_mu);
            let _ = sw;
            if (log_z - log_z_old).abs() < opts.tol {
                converged = true;
                break;
            }
            log_z_old = log_z;
        }

        let sw: Vec<f64> = sites.tau.iter().map(|&t| t.max(0.0).sqrt()).collect();
        // w_pred = ν̃ − sW ⊙ B⁻¹ (sW ⊙ (K ν̃))
        let knu = k.matvec(&sites.nu);
        let swknu: Vec<f64> = sw.iter().zip(&knu).map(|(a, b)| a * b).collect();
        let binv_swknu = chol_b.solve(&swknu);
        let w_pred: Vec<f64> = (0..n).map(|i| sites.nu[i] - sw[i] * binv_swknu[i]).collect();
        let sigma_diag = (0..n).map(|i| sigma.at(i, i)).collect();

        Ok(DenseEp { sites, log_z, mu, sigma_diag, sweeps, converged, sw, chol_b, w_pred })
    }

    /// Gradient of `log Z_EP` w.r.t. the covariance log-parameters
    /// (paper eq. 6, dense evaluation).
    pub fn log_z_grad(&self, cov: &CovFunction, x: &[Vec<f64>]) -> Vec<f64> {
        let n = x.len();
        let (kmat, grads) = cov.cov_matrix_grads(x);
        let mut out = grad_quadratic_term(&kmat, &grads, &self.w_pred);
        // trace term: Z = sW B⁻¹ sW, evaluated densely
        let mut binv_col = vec![0.0; n];
        let mut z = DenseMatrix::zeros(n, n);
        for j in 0..n {
            binv_col.iter_mut().for_each(|v| *v = 0.0);
            binv_col[j] = 1.0;
            let col = self.chol_b.solve(&binv_col);
            for i in 0..n {
                *z.at_mut(i, j) = self.sw[i] * col[i] * self.sw[j];
            }
        }
        for j in 0..kmat.n_cols {
            for p in kmat.col_ptr[j]..kmat.col_ptr[j + 1] {
                let i = kmat.row_idx[p];
                let zij = z.at(i, j);
                for (g, o) in grads.iter().zip(out.iter_mut()) {
                    *o -= 0.5 * zij * g[p];
                }
            }
        }
        out
    }

    /// Latent predictive mean and variance at a test point.
    pub fn predict_latent(&self, cov: &CovFunction, x: &[Vec<f64>], xstar: &[f64]) -> (f64, f64) {
        let (rows, vals) = cov.cross_cov(x, xstar);
        let mean: f64 = rows.iter().zip(&vals).map(|(&i, &v)| v * self.w_pred[i]).sum();
        let n = x.len();
        let mut u = vec![0.0; n];
        for (&i, &v) in rows.iter().zip(&vals) {
            u[i] = self.sw[i] * v;
        }
        let biu = self.chol_b.solve(&u);
        let quad: f64 = u.iter().zip(&biu).map(|(a, b)| a * b).sum();
        let kss = cov.sigma2; // k(x*, x*) = σ² for all radial kernels here
        (mean, (kss - quad).max(1e-12))
    }
}

/// Recompute Σ = K − Vᵀ V, μ = Σ ν̃ and chol(B) from the current sites.
fn recompute(
    k: &DenseMatrix,
    sites: &EpSites,
) -> (DenseMatrix, Vec<f64>, DenseCholesky, Vec<f64>) {
    let n = k.n_rows;
    let sw: Vec<f64> = sites.tau.iter().map(|&t| t.max(0.0).sqrt()).collect();
    let mut b = DenseMatrix::from_fn(n, n, |i, j| sw[i] * k.at(i, j) * sw[j]);
    b.add_diag(1.0);
    let chol = b.cholesky().expect("B = I + sWKsW must be PD");
    // V = L⁻¹ diag(sW) K  (column by column)
    let mut v = DenseMatrix::zeros(n, n);
    let mut col = vec![0.0; n];
    for c in 0..n {
        for r in 0..n {
            col[r] = sw[r] * k.at(r, c);
        }
        let sol = chol.solve_lower(&col);
        for r in 0..n {
            *v.at_mut(r, c) = sol[r];
        }
    }
    let mut sigma = k.clone();
    for i in 0..n {
        for j in 0..n {
            let mut s = 0.0;
            for r in 0..n {
                s += v.at(r, i) * v.at(r, j);
            }
            *sigma.at_mut(i, j) -= s;
        }
    }
    let mu = sigma.matvec(&sites.nu);
    (sigma, mu, chol, sw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::covariance::CovKind;
    use crate::gp::likelihood::{norm_cdf, norm_pdf};
    use crate::testutil::random_points;

    fn toy_problem(n: usize, seed: u64) -> (CovFunction, Vec<Vec<f64>>, Vec<f64>) {
        let x = random_points(n, 2, 4.0, seed);
        let y: Vec<f64> =
            x.iter().map(|p| if p[0] + 0.5 * p[1] > 3.0 { 1.0 } else { -1.0 }).collect();
        (CovFunction::new(CovKind::Se, 2, 1.2, 1.5), x, y)
    }

    #[test]
    fn converges_on_toy_data() {
        let (cov, x, y) = toy_problem(25, 1);
        let ep = DenseEp::run(&cov, &x, &y, &EpOptions::default()).unwrap();
        assert!(ep.converged, "EP did not converge");
        assert!(ep.log_z.is_finite());
        assert!(ep.sites.tau.iter().all(|&t| t > 0.0), "site precisions positive");
        // training-point predictions should mostly match the labels
        let correct = x
            .iter()
            .zip(&y)
            .filter(|(xi, &yi)| {
                let (m, v) = ep.predict_latent(&cov, &x, xi);
                (norm_cdf(m / (1.0 + v).sqrt()) - 0.5).signum() == yi
            })
            .count();
        assert!(correct as f64 / x.len() as f64 > 0.8, "train acc {correct}/{}", x.len());
    }

    /// Two-site problem: compare log Z_EP against 2-D quadrature of the
    /// exact marginal likelihood (EP is extremely accurate for probit).
    #[test]
    fn log_z_close_to_quadrature_n2() {
        let x = vec![vec![0.0], vec![0.9]];
        let y = vec![1.0, -1.0];
        let cov = CovFunction::new(CovKind::Se, 1, 1.4, 1.1);
        let mut opts = EpOptions::default();
        opts.tol = 1e-12;
        let ep = DenseEp::run(&cov, &x, &y, &opts).unwrap();
        // exact Z by quadrature
        let kd = cov.cov_matrix(&x).to_dense();
        let (k11, k12, k22) = (kd.at(0, 0), kd.at(0, 1), kd.at(1, 1));
        let det = k11 * k22 - k12 * k12;
        let m = 401;
        let lim = 6.0 * k11.sqrt();
        let h = 2.0 * lim / (m - 1) as f64;
        let mut z = 0.0;
        for a in 0..m {
            let f1 = -lim + a as f64 * h;
            for b in 0..m {
                let f2 = -lim + b as f64 * h;
                let q = (k22 * f1 * f1 - 2.0 * k12 * f1 * f2 + k11 * f2 * f2) / det;
                let prior = (-0.5 * q).exp() / (2.0 * std::f64::consts::PI * det.sqrt());
                z += norm_cdf(y[0] * f1) * norm_cdf(y[1] * f2) * prior;
            }
        }
        z *= h * h;
        assert!(
            (ep.log_z - z.ln()).abs() < 5e-3,
            "logZ_EP = {}, quadrature = {}",
            ep.log_z,
            z.ln()
        );
        let _ = norm_pdf(0.0);
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let (mut cov, x, y) = toy_problem(14, 3);
        let mut opts = EpOptions::default();
        opts.tol = 1e-12;
        opts.max_sweeps = 200;
        let ep = DenseEp::run(&cov, &x, &y, &opts).unwrap();
        let grad = ep.log_z_grad(&cov, &x);
        let p0 = cov.params();
        for p in 0..cov.n_params() {
            let h = 1e-5;
            let mut pp = p0.clone();
            pp[p] += h;
            cov.set_params(&pp);
            let zp = DenseEp::run(&cov, &x, &y, &opts).unwrap().log_z;
            pp[p] -= 2.0 * h;
            cov.set_params(&pp);
            let zm = DenseEp::run(&cov, &x, &y, &opts).unwrap().log_z;
            cov.set_params(&p0);
            let fd = (zp - zm) / (2.0 * h);
            assert!(
                (fd - grad[p]).abs() < 2e-4 * (1.0 + grad[p].abs()),
                "param {p}: fd={fd} analytic={}",
                grad[p]
            );
        }
    }

    #[test]
    fn balanced_symmetric_problem_has_symmetric_posterior() {
        // two points, opposite labels, symmetric geometry => μ₁ = −μ₂
        let x = vec![vec![-1.0], vec![1.0]];
        let y = vec![1.0, -1.0];
        let cov = CovFunction::new(CovKind::Se, 1, 1.0, 2.0);
        let ep = DenseEp::run(&cov, &x, &y, &EpOptions::default()).unwrap();
        assert!((ep.mu[0] + ep.mu[1]).abs() < 1e-8);
        assert!((ep.sites.tau[0] - ep.sites.tau[1]).abs() < 1e-8);
    }
}
