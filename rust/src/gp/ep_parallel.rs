//! Parallel EP ablation: instead of the paper's sequential site visits
//! with `ldlrowmodify`, update *all* sites from the current posterior
//! marginals, then rebuild and refactor `B` once per sweep.
//!
//! This trades the row-modification machinery for `n` sparse solves plus
//! one sparse refactorization per sweep, at the cost of needing damping to
//! converge. The `abl_parallel_ep` bench quantifies the trade-off against
//! Algorithm 1.
//!
//! The `n` per-site variance solves are independent, so they fan out over
//! the [`crate::par`] worker pool (`marginal_variances`): each worker
//! owns a `SparseSolveWorkspace` and writes disjoint `σᵢ²` slots, keeping
//! the sweep bitwise-identical to the serial loop at any thread count
//! (`perf_parallel` measures the scaling). The once-per-sweep
//! refactorization of `B` — the last serial chunk of this backend before
//! the supernodal rewrite — now runs on the same pool through
//! [`LdlFactor::refactor`]'s wave schedule, so a whole sweep is parallel
//! end to end.

use crate::gp::cache::PatternCache;
use crate::gp::covariance::CovFunction;
use crate::gp::ep_sparse::build_b;
use crate::gp::likelihood::SiteBatch;
use crate::gp::marginal::{ep_log_z, EpOptions, EpSites};
use crate::gp::predict::PredictWorkspace;
use crate::sparse::cholesky::LdlFactor;
use crate::sparse::csc::CscMatrix;
use crate::sparse::ordering::Ordering;
use crate::sparse::triangular::SparseSolveWorkspace;

/// Converged parallel-EP state (permuted space, like `SparseEp`).
pub struct ParallelEp {
    pub perm: std::sync::Arc<Vec<usize>>,
    pub xp: std::sync::Arc<Vec<Vec<f64>>>,
    pub k: CscMatrix,
    pub factor: LdlFactor,
    pub sites: EpSites,
    pub log_z: f64,
    pub mu: Vec<f64>,
    pub sweeps: usize,
    pub converged: bool,
    pub w_pred: Vec<f64>,
}

impl ParallelEp {
    /// Run with a private, throwaway [`PatternCache`]; optimizer loops
    /// should hold a cache and call [`ParallelEp::run_cached`].
    pub fn run(
        cov: &CovFunction,
        x: &[Vec<f64>],
        y: &[f64],
        ordering: Ordering,
        opts: &EpOptions,
    ) -> Result<ParallelEp, String> {
        let mut cache = PatternCache::new(ordering);
        ParallelEp::run_cached(cov, x, y, opts, &mut cache)
    }

    /// Run parallel EP reusing `cache`'s pattern / ordering / symbolic
    /// analysis (same contract as [`crate::gp::SparseEp::run_cached`]).
    pub fn run_cached(
        cov: &CovFunction,
        x: &[Vec<f64>],
        y: &[f64],
        opts: &EpOptions,
        cache: &mut PatternCache,
    ) -> Result<ParallelEp, String> {
        ParallelEp::run_cached_warm(cov, x, y, opts, cache, None)
    }

    /// Accessor for warm starts and snapshots: the converged sites in the
    /// *original* index order.
    pub fn sites_unpermuted(&self) -> EpSites {
        self.sites.unpermuted(&self.perm)
    }

    /// [`ParallelEp::run_cached`] with an optional warm start from
    /// converged sites in the *original* (unpermuted) index order — the
    /// online-update path appends τ̃ = 0 sites for the new points and
    /// resumes from the old fixed point instead of re-deriving it.
    pub fn run_cached_warm(
        cov: &CovFunction,
        x: &[Vec<f64>],
        y: &[f64],
        opts: &EpOptions,
        cache: &mut PatternCache,
        warm_start: Option<&EpSites>,
    ) -> Result<ParallelEp, String> {
        let n = x.len();
        let (_, plan) = cache.plan_for(cov, x);
        let k = cov.cov_values_on_pattern(&plan.xp, &plan.pattern_perm);
        let perm = plan.perm.clone(); // Arc handle, not a deep copy
        let xp = plan.xp.clone();
        let mut yp = vec![0.0; n];
        for old in 0..n {
            yp[perm[old]] = y[old];
        }
        let mut factor = LdlFactor::identity(plan.symbolic.clone());
        let mut sites = match warm_start {
            Some(warm) => {
                assert_eq!(warm.len(), n, "warm sites must match n");
                warm.permuted(&perm)
            }
            None => EpSites::zeros(n),
        };
        // parallel EP needs damping; honour opts.damping but cap at 0.9.
        // The working value halves on every divergence rollback.
        let jitter = opts.jitter_policy();
        let mut damping = opts.effective_damping(0.9);
        let mut monitor = crate::gp::marginal::DivergenceMonitor::new();
        let mut recoveries = 0usize;

        let mut gamma = vec![0.0; n];
        let mut mu = vec![0.0; n];
        let mut sigma_diag: Vec<f64> = (0..n).map(|i| k.get(i, i)).collect();
        if warm_start.is_some() {
            // The first batched update reads the marginals, so a warm
            // start must land the factor *and* the posterior state on the
            // warm sites before the loop (one refactorization plus one
            // round of marginal recomputation — the same per-sweep cost
            // the resumed trajectory saves many times over).
            let b = build_b(&k, &sites.tau);
            factor.refactor_with_recovery(&b, &jitter)?;
            gamma = k.matvec(&sites.nu);
            let mut swg: Vec<f64> =
                (0..n).map(|i| sites.tau[i].max(0.0).sqrt() * gamma[i]).collect();
            factor.solve_in_place(&mut swg);
            let scaled: Vec<f64> =
                (0..n).map(|i| sites.tau[i].max(0.0).sqrt() * swg[i]).collect();
            let kv = k.matvec(&scaled);
            for i in 0..n {
                mu[i] = gamma[i] - kv[i];
            }
            sigma_diag = marginal_variances(&k, &factor, &sites.tau);
        }
        let mut log_z = f64::NEG_INFINITY;
        let mut log_z_old = f64::NEG_INFINITY;
        let mut sweeps = 0;
        let mut converged = false;
        let mut batch = SiteBatch::new();

        // Last-good snapshot for rollback: sites plus the marginals the
        // next sweep's batched update reads (the τ̃ = 0 prior start is
        // trivially healthy).
        let mut snap_sites = sites.clone();
        let mut snap_gamma = gamma.clone();
        let mut snap_mu = mu.clone();
        let mut snap_sigma = sigma_diag.clone();
        let mut snap_log_z = log_z;

        while sweeps < opts.max_sweeps {
            // Convergence telemetry (ΔlogZ, max site delta, damping count)
            // is observed from values the sweep computes anyway — the
            // tracked max never feeds back into any update.
            let track = crate::obs::counters_on();
            let mut sweep_span = crate::obs::span("ep.sweep");
            let mut max_site_delta = 0.0f64;
            let mut updated = 0u64;
            let mut skipped = 0u64;
            // batched site updates from current marginals: the
            // transcendental kernel runs over the whole batch at once
            batch.update(&yp, &mu, &sigma_diag, &sites.tau, &sites.nu);
            for i in 0..n {
                if !batch.valid[i] {
                    continue;
                }
                let (tau_old, nu_old) = (sites.tau[i], sites.nu[i]);
                let mut tau_new = batch.tau_new[i];
                if crate::fault::should_poison_site(sweeps, i) {
                    tau_new = f64::NAN;
                }
                let tau_next = damping * tau_new + (1.0 - damping) * tau_old;
                let nu_next = damping * batch.nu_new[i] + (1.0 - damping) * nu_old;
                // Per-site recovery guard (same contract as the sequential
                // sweep): a non-finite or negative site precision is not
                // merged — the site keeps its last value and the sweep-end
                // rollback repairs the trajectory. `batch.valid` already
                // filters the likelihood kernel's own rejects; only these
                // new guards count toward recovery telemetry.
                if !tau_next.is_finite() || !nu_next.is_finite() || tau_next < 0.0 {
                    crate::obs::counters::EP_SKIPPED_SITES.add(1);
                    skipped += 1;
                    continue;
                }
                sites.ln_zhat[i] = batch.ln_zhat[i];
                sites.tau_cav[i] = batch.tau_cav[i];
                sites.nu_cav[i] = batch.nu_cav[i];
                sites.tau[i] = tau_next;
                sites.nu[i] = nu_next;
                // max_site_delta feeds the divergence monitor, so it is
                // tracked unconditionally (not gated on trace mode).
                let delta = (tau_next - tau_old).abs().max((nu_next - nu_old).abs());
                max_site_delta = max_site_delta.max(delta);
                if track {
                    updated += 1;
                }
            }

            // one refactor of B for the whole batch (with pivot recovery)
            let b = build_b(&k, &sites.tau);
            factor.refactor_with_recovery(&b, &jitter)?;

            // recompute γ = K ν̃ and all marginals through the new factor
            gamma = k.matvec(&sites.nu);
            let mut swg: Vec<f64> =
                (0..n).map(|i| sites.tau[i].max(0.0).sqrt() * gamma[i]).collect();
            factor.solve_in_place(&mut swg);
            let scaled: Vec<f64> =
                (0..n).map(|i| sites.tau[i].max(0.0).sqrt() * swg[i]).collect();
            let kv = k.matvec(&scaled);
            for i in 0..n {
                mu[i] = gamma[i] - kv[i];
            }
            sigma_diag = marginal_variances(&k, &factor, &sites.tau);

            sweeps += 1;
            let nu_dot_mu: f64 = sites.nu.iter().zip(&mu).map(|(a, b)| a * b).sum();
            log_z = ep_log_z(&sites, factor.logdet(), nu_dot_mu);
            let diverged = skipped > 0 || monitor.diverged(log_z, max_site_delta, opts);
            if track {
                crate::obs::counters::EP_SWEEPS.add(1);
                crate::obs::counters::EP_SITE_VISITS.add(n as u64);
                crate::obs::counters::EP_DAMPED_UPDATES.add(updated);
            }
            if sweep_span.is_active() {
                sweep_span.field_str("backend", "parallel");
                sweep_span.field_u64("sweep", sweeps as u64);
                sweep_span.field_f64("logz", log_z);
                sweep_span.field_f64("dlogz", log_z - log_z_old);
                sweep_span.field_f64("max_site_delta", max_site_delta);
                sweep_span.field_u64("damped_updates", updated);
                sweep_span.field_f64("damping", damping);
                sweep_span.field_u64("skipped_sites", skipped);
                sweep_span.field_bool("rolled_back", diverged);
            }
            if diverged {
                // Roll back to the last-good snapshot and halve the
                // damping before trying again (the sweep ordinal keeps
                // advancing, so a one-shot injected fault is not re-hit).
                if recoveries >= opts.max_recoveries {
                    return Err(format!(
                        "EP diverged at sweep {sweeps} with the recovery budget \
                         ({}) exhausted",
                        opts.max_recoveries
                    ));
                }
                recoveries += 1;
                crate::obs::counters::EP_ROLLBACKS.add(1);
                damping = (0.5 * damping).max(opts.min_damping);
                sites.clone_from(&snap_sites);
                gamma.clone_from(&snap_gamma);
                mu.clone_from(&snap_mu);
                sigma_diag.clone_from(&snap_sigma);
                let b = build_b(&k, &sites.tau);
                factor.refactor_with_recovery(&b, &jitter)?;
                log_z = snap_log_z;
                continue;
            }
            snap_sites.clone_from(&sites);
            snap_gamma.clone_from(&gamma);
            snap_mu.clone_from(&mu);
            snap_sigma.clone_from(&sigma_diag);
            snap_log_z = log_z;
            if (log_z - log_z_old).abs() < opts.tol {
                converged = true;
                break;
            }
            log_z_old = log_z;
        }

        let mut swg: Vec<f64> = (0..n).map(|i| sites.tau[i].max(0.0).sqrt() * gamma[i]).collect();
        factor.solve_in_place(&mut swg);
        let w_pred: Vec<f64> =
            (0..n).map(|i| sites.nu[i] - sites.tau[i].max(0.0).sqrt() * swg[i]).collect();

        Ok(ParallelEp { perm, xp, k, factor, sites, log_z, mu, sweeps, converged, w_pred })
    }

    /// Latent predictive mean/variance (same representation as `SparseEp`).
    pub fn predict_latent(&self, cov: &CovFunction, xstar: &[f64]) -> (f64, f64) {
        let mut pws = PredictWorkspace::one_shot(self.k.n_rows);
        self.predict_latent_with(cov, xstar, &mut pws)
    }

    /// Workspace for repeated predictions against this EP state.
    pub fn predict_workspace(&self, cov: &CovFunction) -> PredictWorkspace {
        PredictWorkspace::new(cov, &self.xp)
    }

    /// Latent prediction through a shared workspace (no per-call
    /// allocation; indexed cross-covariance).
    pub fn predict_latent_with(
        &self,
        cov: &CovFunction,
        xstar: &[f64],
        pws: &mut PredictWorkspace,
    ) -> (f64, f64) {
        crate::gp::predict::sparse_latent_with(
            cov,
            &self.xp,
            &self.factor,
            &self.sites.tau,
            &self.w_pred,
            xstar,
            pws,
        )
    }

    /// Batched latent predictions fanned out over the worker pool: one
    /// neighbor index is built once and shared (`Arc`) by every worker's
    /// forked workspace; each test point is an independent task, so the
    /// results equal the per-point path bitwise.
    pub fn predict_latent_batch(&self, cov: &CovFunction, xs: &[Vec<f64>]) -> Vec<(f64, f64)> {
        let proto = self.predict_workspace(cov);
        crate::gp::predict::batch_with_forks(&proto, xs.len(), |pws, i| {
            self.predict_latent_with(cov, &xs[i], pws)
        })
    }

    /// Recompute all marginal variances from the current factor/site
    /// state — the per-sweep loop `perf_parallel` measures in isolation.
    pub fn recompute_sigma_diag(&self) -> Vec<f64> {
        marginal_variances(&self.k, &self.factor, &self.sites.tau)
    }
}

/// All `n` marginal variances `σᵢ² = K_ii − aᵢᵀ B⁻¹ aᵢ` with
/// `aᵢ = S̃^{1/2} K[:, i]` — the dominant per-sweep cost of parallel EP
/// for CS kernels. The sites are independent, so the solves fan out over
/// [`crate::par`]: each participant owns one `SparseSolveWorkspace` and
/// one dense solution vector, and slot `i` is written by exactly one
/// chunk, so the output is bitwise-identical to the serial loop at any
/// thread count. The workspaces are built once per participant per call
/// (not per site) — `O(threads·n)` against the loop's `O(n·nnz(L))`
/// solve work, the price of keeping the per-sweep API stateless.
pub(crate) fn marginal_variances(k: &CscMatrix, factor: &LdlFactor, tau: &[f64]) -> Vec<f64> {
    let n = k.n_rows;
    crate::par::map_indexed(
        n,
        64,
        || (SparseSolveWorkspace::new(n), vec![0.0; n], Vec::with_capacity(64)),
        |scratch, i| {
            let (ws, t, a_vals) = scratch;
            let (krows, kvals) = k.col(i);
            a_vals.clear();
            a_vals.extend(krows.iter().zip(kvals).map(|(&r, &v)| tau[r].max(0.0).sqrt() * v));
            factor.solve_sparse_rhs(krows, a_vals, ws, t);
            let quad: f64 = krows.iter().zip(a_vals.iter()).map(|(&r, &v)| v * t[r]).sum();
            ws.clear_solution(t);
            k.get(i, i) - quad
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::covariance::CovKind;
    use crate::gp::ep_sparse::SparseEp;
    use crate::testutil::random_points;

    #[test]
    fn parallel_ep_reaches_same_fixed_point_as_sequential() {
        let x = random_points(30, 2, 6.0, 77);
        let y: Vec<f64> =
            x.iter().map(|p| if p[0] > 3.0 { 1.0 } else { -1.0 }).collect();
        let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 2.0);
        let opts = EpOptions { max_sweeps: 300, tol: 1e-10, damping: 0.8, ..EpOptions::default() };
        let pe = ParallelEp::run(&cov, &x, &y, Ordering::Rcm, &opts).unwrap();
        let se = SparseEp::run(&cov, &x, &y, Ordering::Rcm, &opts, None).unwrap();
        assert!(pe.converged, "parallel EP failed to converge");
        assert!(
            (pe.log_z - se.log_z).abs() < 1e-5,
            "logZ parallel {} vs sequential {}",
            pe.log_z,
            se.log_z
        );
        for px in [vec![1.0, 2.0], vec![4.5, 4.0]] {
            let (mp, vp) = pe.predict_latent(&cov, &px);
            let (ms, vs) = se.predict_latent(&cov, &px);
            assert!((mp - ms).abs() < 1e-4, "{mp} vs {ms}");
            assert!((vp - vs).abs() < 1e-4, "{vp} vs {vs}");
        }
    }
}
