//! Exact GP regression. Used by the Figure-2 reproduction: sample data
//! from a GP with a `k_pp,q` covariance + noise, then find the posterior
//! mode of the length-scale for a range of Wendland dimension parameters D
//! and record how the covariance fill grows with D.
//!
//! Compactly supported kernels run entirely through the sparse stack: the
//! [`PatternCache`]'s factorization plan, the supernodal (parallel)
//! LDLᵀ of `K + σn²I`, and — for the gradient's `tr(K_y⁻¹ ∂K/∂θ)` term —
//! the Takahashi sparsified inverse, which yields exactly the `K_y⁻¹`
//! entries on `K`'s pattern that the trace needs. Globally supported
//! kernels fall back to the dense Cholesky path; both paths compute the
//! identical quantities (the sparse one without ever densifying).

use crate::gp::cache::PatternCache;
use crate::gp::covariance::CovFunction;
use crate::rng::Rng;
use crate::sparse::cholesky::LdlFactor;
use crate::sparse::ordering::Ordering;

/// Regression is factorization-bound, so its throwaway caches let the
/// auto policy pick the ordering from pattern statistics and pool width
/// (quotient min-degree when serial, nested dissection when the
/// supernodal kernel has threads to feed — docs/ARCHITECTURE.md
/// §Ordering layer); `CSGP_ORDERING` overrides the choice.
const REGRESSION_ORDERING: Ordering = Ordering::Auto;

/// log marginal likelihood of GP regression with iid noise σn²:
/// `−½ yᵀ(K+σn²I)⁻¹y − ½ log|K+σn²I| − n/2 log 2π`.
pub fn log_marginal(cov: &CovFunction, noise_var: f64, x: &[Vec<f64>], y: &[f64]) -> f64 {
    log_marginal_cached(cov, noise_var, x, y, &mut PatternCache::new(REGRESSION_ORDERING))
}

/// [`log_marginal`] drawing the covariance pattern from `cache`, so a
/// hyperparameter search re-runs neighbor queries only when the support
/// radius grows (see [`PatternCache`]). Compact kernels go through the
/// cached factorization plan and the supernodal sparse LDLᵀ
/// (`O(nnz(L))`-ish); dense kernels through a dense Cholesky.
pub fn log_marginal_cached(
    cov: &CovFunction,
    noise_var: f64,
    x: &[Vec<f64>],
    y: &[f64],
    cache: &mut PatternCache,
) -> f64 {
    let n = x.len();
    let norm = -0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln();
    if cov.support_radius().is_some() {
        return sparse_marginal(cov, noise_var, x, y, cache).value;
    }
    let cached = cache.pattern_for(cov, x);
    let mut ky = cov.cov_values_on_pattern(x, &cached.pattern).to_dense();
    ky.add_diag(noise_var);
    let ch = ky.cholesky().expect("K + σn²I must be PD");
    let alpha = ch.solve(y);
    let quad: f64 = y.iter().zip(&alpha).map(|(a, b)| a * b).sum();
    -0.5 * quad - 0.5 * ch.logdet() + norm
}

/// Everything the compact-kernel marginal needs, computed once: the
/// supernodal factor of `K_y = K + σn²I` on the cached plan, the
/// permuted `α = K_y⁻¹ y`, and the log marginal itself. Shared by the
/// value-only and value+gradient entry points so the sparse likelihood
/// evaluation lives in exactly one place.
struct SparseMarginal {
    /// Cached factorization plan (the gradient needs `xp`).
    plan: std::sync::Arc<crate::gp::cache::FactorPlan>,
    /// `K + σn²I` on the (permuted, possibly superset) pattern — the
    /// gradient loops iterate its pattern, which equals `K`'s.
    ky: crate::sparse::csc::CscMatrix,
    factor: LdlFactor,
    /// `K_y⁻¹ y` in permuted space.
    alpha: Vec<f64>,
    value: f64,
}

fn sparse_marginal(
    cov: &CovFunction,
    noise_var: f64,
    x: &[Vec<f64>],
    y: &[f64],
    cache: &mut PatternCache,
) -> SparseMarginal {
    let n = x.len();
    let norm = -0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln();
    let (_, plan) = cache.plan_for(cov, x);
    let mut ky = cov.cov_values_on_pattern(&plan.xp, &plan.pattern_perm);
    for j in 0..n {
        *ky.get_mut(j, j) += noise_var;
    }
    let factor = LdlFactor::factor(plan.symbolic.clone(), &ky).expect("K + σn²I must be PD");
    let mut yp = vec![0.0; n];
    for old in 0..n {
        yp[plan.perm[old]] = y[old];
    }
    let mut alpha = yp.clone();
    factor.solve_in_place(&mut alpha);
    let quad: f64 = yp.iter().zip(&alpha).map(|(a, b)| a * b).sum();
    let value = -0.5 * quad - 0.5 * factor.logdet() + norm;
    SparseMarginal { plan, ky, factor, alpha, value }
}

/// Gradient of the log marginal w.r.t. the covariance log-parameters:
/// `½ tr((ααᵀ − Ky⁻¹) ∂K/∂θ)`.
pub fn log_marginal_grad(
    cov: &CovFunction,
    noise_var: f64,
    x: &[Vec<f64>],
    y: &[f64],
) -> Vec<f64> {
    log_marginal_grad_cached(cov, noise_var, x, y, &mut PatternCache::new(REGRESSION_ORDERING))
}

/// [`log_marginal_grad`] on a cached pattern: the gradient values are
/// evaluated entry-aligned with the cached (possibly superset) pattern;
/// out-of-support entries carry exactly zero gradient, so the result
/// matches the uncached computation.
pub fn log_marginal_grad_cached(
    cov: &CovFunction,
    noise_var: f64,
    x: &[Vec<f64>],
    y: &[f64],
    cache: &mut PatternCache,
) -> Vec<f64> {
    log_marginal_with_grad_cached(cov, noise_var, x, y, cache).1
}

/// Log marginal *and* its gradient from one assembly + one factorization
/// — the form the SCG objective wants (calling the value and gradient
/// entry points separately factors the identical `K + σn²I` twice per
/// optimizer step).
///
/// For compact kernels the trace term `tr((ααᵀ − K_y⁻¹) ∂K/∂θ)` only
/// reads `K_y⁻¹` where `K` is nonzero, and `K`'s pattern is inside the
/// `L + Lᵀ` pattern — so the whole evaluation runs on the supernodal
/// sparse factor plus its Takahashi inverse, with the `O(nnz(L))`
/// z-buffers recycled across SCG steps through the cache's
/// [`GradScratch`](crate::gp::cache::GradScratch).
pub fn log_marginal_with_grad_cached(
    cov: &CovFunction,
    noise_var: f64,
    x: &[Vec<f64>],
    y: &[f64],
    cache: &mut PatternCache,
) -> (f64, Vec<f64>) {
    let n = x.len();
    let norm = -0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln();
    if cov.support_radius().is_some() {
        let sm = sparse_marginal(cov, noise_var, x, y, cache);
        // ky's pattern equals K's (noise only shifts the diagonal), so
        // the gradient values align entry-for-entry with its storage
        let grads = cov.cov_grads_on_pattern(&sm.plan.xp, &sm.ky);
        let zsp = &mut cache.grad_scratch.takahashi;
        sm.factor.takahashi_inverse_into(zsp);
        let sym = &sm.factor.symbolic;
        let mut out = vec![0.0; grads.len()];
        for j in 0..n {
            for p in sm.ky.col_ptr[j]..sm.ky.col_ptr[j + 1] {
                let i = sm.ky.row_idx[p];
                let kinv_ij =
                    zsp.get(sym, i, j).expect("K pattern must be inside the L+Lᵀ pattern");
                let w = sm.alpha[i] * sm.alpha[j] - kinv_ij;
                for (g, o) in grads.iter().zip(out.iter_mut()) {
                    *o += 0.5 * w * g[p];
                }
            }
        }
        return (sm.value, out);
    }
    let cached = cache.pattern_for(cov, x);
    let kmat = cov.cov_values_on_pattern(x, &cached.pattern);
    let grads = cov.cov_grads_on_pattern(x, &kmat);
    let mut ky = kmat.to_dense();
    ky.add_diag(noise_var);
    let ch = ky.cholesky().expect("K + σn²I must be PD");
    let alpha = ch.solve(y);
    let quad: f64 = y.iter().zip(&alpha).map(|(a, b)| a * b).sum();
    let value = -0.5 * quad - 0.5 * ch.logdet() + norm;
    let kinv = ky.inverse_spd().expect("PD");
    let mut out = vec![0.0; grads.len()];
    for j in 0..n {
        for p in kmat.col_ptr[j]..kmat.col_ptr[j + 1] {
            let i = kmat.row_idx[p];
            let w = alpha[i] * alpha[j] - kinv.at(i, j);
            for (g, o) in grads.iter().zip(out.iter_mut()) {
                *o += 0.5 * w * g[p];
            }
        }
    }
    (value, out)
}

/// Draw a sample from a zero-mean GP with covariance `cov` plus
/// `noise_var` iid noise at inputs `x`.
pub fn sample_gp(cov: &CovFunction, noise_var: f64, x: &[Vec<f64>], rng: &mut Rng) -> Vec<f64> {
    let n = x.len();
    let mut k = cov.cov_matrix(x).to_dense();
    k.add_diag(noise_var + 1e-10);
    let ch = k.cholesky().expect("covariance must be PD");
    let z = rng.normal_vec(n);
    // y = L z
    (0..n).map(|i| (0..=i).map(|j| ch.at(i, j) * z[j]).sum()).collect()
}

/// Posterior predictive mean at `xstar` for GP regression.
pub fn predict_mean(
    cov: &CovFunction,
    noise_var: f64,
    x: &[Vec<f64>],
    y: &[f64],
    xstar: &[f64],
) -> f64 {
    let mut ky = cov.cov_matrix(x).to_dense();
    ky.add_diag(noise_var);
    let alpha = ky.solve_spd(y).expect("PD");
    let (rows, vals) = cov.cross_cov(x, xstar);
    rows.iter().zip(&vals).map(|(&i, &v)| v * alpha[i]).sum()
}

/// Maximize the regression log marginal over `[ln σ², ln l…]` with SCG.
/// Returns the optimized covariance and the achieved log marginal.
pub fn optimize_hypers(
    cov: &CovFunction,
    noise_var: f64,
    x: &[Vec<f64>],
    y: &[f64],
    max_iters: usize,
) -> (CovFunction, f64) {
    let mut c = cov.clone();
    // one pattern cache across the whole search (every evaluation at a
    // non-growing support radius skips assembly structure), and one
    // combined value+gradient evaluation per SCG step — a single
    // assembly + supernodal factorization, not one of each
    let mut cache = PatternCache::new(REGRESSION_ORDERING);
    let res = crate::opt::scg::scg(
        &c.params(),
        |p| {
            let mut ct = c.clone();
            ct.set_params(p);
            let (f, g) = log_marginal_with_grad_cached(&ct, noise_var, x, y, &mut cache);
            (-f, g.iter().map(|v| -v).collect())
        },
        &crate::opt::scg::ScgOptions { max_iters, x_tol: 1e-5, f_tol: 1e-7 },
    );
    c.set_params(&res.x);
    (c, -res.f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::covariance::CovKind;
    use crate::testutil::random_points;

    #[test]
    fn grad_matches_finite_difference() {
        let x = random_points(15, 2, 5.0, 8);
        let mut rng = Rng::new(4);
        let mut cov = CovFunction::new(CovKind::Pp(3), 2, 1.2, 2.0);
        let y = sample_gp(&cov, 0.1, &x, &mut rng);
        let g = log_marginal_grad(&cov, 0.1, &x, &y);
        let p0 = cov.params();
        for p in 0..cov.n_params() {
            let h = 1e-6;
            let mut pp = p0.clone();
            pp[p] += h;
            cov.set_params(&pp);
            let fp = log_marginal(&cov, 0.1, &x, &y);
            pp[p] -= 2.0 * h;
            cov.set_params(&pp);
            let fm = log_marginal(&cov, 0.1, &x, &y);
            cov.set_params(&p0);
            let fd = (fp - fm) / (2.0 * h);
            assert!((fd - g[p]).abs() < 1e-4 * (1.0 + g[p].abs()), "p{p}: {fd} vs {}", g[p]);
        }
    }

    /// The compact-kernel path (supernodal sparse LDLᵀ + Takahashi
    /// inverse) computes the same log marginal and gradient as a directly
    /// assembled dense Cholesky oracle.
    #[test]
    fn sparse_path_matches_dense_oracle() {
        let x = random_points(50, 2, 6.0, 9);
        let mut rng = Rng::new(3);
        let cov = CovFunction::new(CovKind::Pp(3), 2, 1.1, 1.8);
        let noise = 0.1;
        let y = sample_gp(&cov, noise, &x, &mut rng);

        let lm = log_marginal(&cov, noise, &x, &y);
        let g = log_marginal_grad(&cov, noise, &x, &y);

        // dense oracle, assembled without the sparse machinery
        let n = x.len();
        let kmat = cov.cov_matrix(&x);
        let mut ky = kmat.to_dense();
        ky.add_diag(noise);
        let ch = ky.cholesky().unwrap();
        let alpha = ch.solve(&y);
        let quad: f64 = y.iter().zip(&alpha).map(|(a, b)| a * b).sum();
        let oracle =
            -0.5 * quad - 0.5 * ch.logdet() - 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln();
        assert!((lm - oracle).abs() < 1e-8, "logML {lm} vs {oracle}");

        let kinv = ky.inverse_spd().unwrap();
        let grads = cov.cov_grads_on_pattern(&x, &kmat);
        let mut g_oracle = vec![0.0; grads.len()];
        for j in 0..n {
            for p in kmat.col_ptr[j]..kmat.col_ptr[j + 1] {
                let i = kmat.row_idx[p];
                let w = alpha[i] * alpha[j] - kinv.at(i, j);
                for (gr, o) in grads.iter().zip(g_oracle.iter_mut()) {
                    *o += 0.5 * w * gr[p];
                }
            }
        }
        for (a, b) in g.iter().zip(&g_oracle) {
            assert!((a - b).abs() < 1e-7 * (1.0 + b.abs()), "grad {a} vs {b}");
        }
    }

    #[test]
    fn optimization_recovers_plausible_lengthscale() {
        // sample from a GP with l = 2, start the optimizer at l = 0.7 and
        // check the optimum lands in a sane neighbourhood
        let x = random_points(60, 2, 10.0, 17);
        let mut rng = Rng::new(5);
        let truth = CovFunction::new(CovKind::Pp(3), 2, 1.0, 2.0);
        let y = sample_gp(&truth, 0.04, &x, &mut rng);
        let start = CovFunction::new(CovKind::Pp(3), 2, 0.5, 0.7);
        let (fit, lml) = optimize_hypers(&start, 0.04, &x, &y, 60);
        assert!(lml > log_marginal(&start, 0.04, &x, &y), "optimizer made things worse");
        let l = fit.lengthscales[0];
        assert!(l > 0.5 && l < 8.0, "recovered lengthscale {l}");
    }

    #[test]
    fn sample_statistics_match_prior() {
        // marginal variance of samples ≈ σ² + noise
        let x = random_points(400, 2, 50.0, 23); // far apart -> nearly iid
        let cov = CovFunction::new(CovKind::Pp(2), 2, 1.5, 0.5);
        let mut rng = Rng::new(6);
        let y = sample_gp(&cov, 0.1, &x, &mut rng);
        let var = y.iter().map(|v| v * v).sum::<f64>() / y.len() as f64;
        assert!((var - 1.6).abs() < 0.4, "sample var {var}");
    }

    #[test]
    fn predict_mean_interpolates() {
        let x = vec![vec![0.0], vec![1.0], vec![2.0]];
        let y = vec![1.0, 2.0, 3.0];
        let cov = CovFunction::new(CovKind::Se, 1, 2.0, 1.5);
        let m = predict_mean(&cov, 1e-6, &x, &y, &[1.0]);
        assert!((m - 2.0).abs() < 0.05, "m = {m}");
    }
}
