//! Exact GP regression (dense). Used by the Figure-2 reproduction: sample
//! data from a GP with a `k_pp,q` covariance + noise, then find the
//! posterior mode of the length-scale for a range of Wendland dimension
//! parameters D and record how the covariance fill grows with D.

use crate::gp::cache::PatternCache;
use crate::gp::covariance::CovFunction;
use crate::rng::Rng;
use crate::sparse::ordering::Ordering;

/// log marginal likelihood of GP regression with iid noise σn²:
/// `−½ yᵀ(K+σn²I)⁻¹y − ½ log|K+σn²I| − n/2 log 2π`.
pub fn log_marginal(cov: &CovFunction, noise_var: f64, x: &[Vec<f64>], y: &[f64]) -> f64 {
    log_marginal_cached(cov, noise_var, x, y, &mut PatternCache::new(Ordering::Natural))
}

/// [`log_marginal`] drawing the covariance pattern from `cache`, so a
/// hyperparameter search re-runs neighbor queries only when the support
/// radius grows (see [`PatternCache`]).
pub fn log_marginal_cached(
    cov: &CovFunction,
    noise_var: f64,
    x: &[Vec<f64>],
    y: &[f64],
    cache: &mut PatternCache,
) -> f64 {
    let n = x.len();
    let cached = cache.pattern_for(cov, x);
    let mut ky = cov.cov_values_on_pattern(x, &cached.pattern).to_dense();
    ky.add_diag(noise_var);
    let ch = ky.cholesky().expect("K + σn²I must be PD");
    let alpha = ch.solve(y);
    let quad: f64 = y.iter().zip(&alpha).map(|(a, b)| a * b).sum();
    -0.5 * quad - 0.5 * ch.logdet() - 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln()
}

/// Gradient of the log marginal w.r.t. the covariance log-parameters:
/// `½ tr((ααᵀ − Ky⁻¹) ∂K/∂θ)`.
pub fn log_marginal_grad(
    cov: &CovFunction,
    noise_var: f64,
    x: &[Vec<f64>],
    y: &[f64],
) -> Vec<f64> {
    log_marginal_grad_cached(cov, noise_var, x, y, &mut PatternCache::new(Ordering::Natural))
}

/// [`log_marginal_grad`] on a cached pattern: the gradient values are
/// evaluated entry-aligned with the cached (possibly superset) pattern;
/// out-of-support entries carry exactly zero gradient, so the result
/// matches the uncached computation.
pub fn log_marginal_grad_cached(
    cov: &CovFunction,
    noise_var: f64,
    x: &[Vec<f64>],
    y: &[f64],
    cache: &mut PatternCache,
) -> Vec<f64> {
    let n = x.len();
    let cached = cache.pattern_for(cov, x);
    let kmat = cov.cov_values_on_pattern(x, &cached.pattern);
    let grads = cov.cov_grads_on_pattern(x, &kmat);
    let mut ky = kmat.to_dense();
    ky.add_diag(noise_var);
    let ch = ky.cholesky().expect("K + σn²I must be PD");
    let alpha = ch.solve(y);
    let kinv = ky.inverse_spd().expect("PD");
    let mut out = vec![0.0; grads.len()];
    for j in 0..n {
        for p in kmat.col_ptr[j]..kmat.col_ptr[j + 1] {
            let i = kmat.row_idx[p];
            let w = alpha[i] * alpha[j] - kinv.at(i, j);
            for (g, o) in grads.iter().zip(out.iter_mut()) {
                *o += 0.5 * w * g[p];
            }
        }
    }
    out
}

/// Draw a sample from a zero-mean GP with covariance `cov` plus
/// `noise_var` iid noise at inputs `x`.
pub fn sample_gp(cov: &CovFunction, noise_var: f64, x: &[Vec<f64>], rng: &mut Rng) -> Vec<f64> {
    let n = x.len();
    let mut k = cov.cov_matrix(x).to_dense();
    k.add_diag(noise_var + 1e-10);
    let ch = k.cholesky().expect("covariance must be PD");
    let z = rng.normal_vec(n);
    // y = L z
    (0..n).map(|i| (0..=i).map(|j| ch.at(i, j) * z[j]).sum()).collect()
}

/// Posterior predictive mean at `xstar` for GP regression.
pub fn predict_mean(
    cov: &CovFunction,
    noise_var: f64,
    x: &[Vec<f64>],
    y: &[f64],
    xstar: &[f64],
) -> f64 {
    let mut ky = cov.cov_matrix(x).to_dense();
    ky.add_diag(noise_var);
    let alpha = ky.solve_spd(y).expect("PD");
    let (rows, vals) = cov.cross_cov(x, xstar);
    rows.iter().zip(&vals).map(|(&i, &v)| v * alpha[i]).sum()
}

/// Maximize the regression log marginal over `[ln σ², ln l…]` with SCG.
/// Returns the optimized covariance and the achieved log marginal.
pub fn optimize_hypers(
    cov: &CovFunction,
    noise_var: f64,
    x: &[Vec<f64>],
    y: &[f64],
    max_iters: usize,
) -> (CovFunction, f64) {
    let mut c = cov.clone();
    // one pattern cache across the whole search: every objective/gradient
    // evaluation at a non-growing support radius skips assembly structure
    let mut cache = PatternCache::new(Ordering::Natural);
    let res = crate::opt::scg::scg(
        &c.params(),
        |p| {
            let mut ct = c.clone();
            ct.set_params(p);
            let f = -log_marginal_cached(&ct, noise_var, x, y, &mut cache);
            let g: Vec<f64> = log_marginal_grad_cached(&ct, noise_var, x, y, &mut cache)
                .iter()
                .map(|v| -v)
                .collect();
            (f, g)
        },
        &crate::opt::scg::ScgOptions { max_iters, x_tol: 1e-5, f_tol: 1e-7 },
    );
    c.set_params(&res.x);
    (c, -res.f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::covariance::CovKind;
    use crate::testutil::random_points;

    #[test]
    fn grad_matches_finite_difference() {
        let x = random_points(15, 2, 5.0, 8);
        let mut rng = Rng::new(4);
        let mut cov = CovFunction::new(CovKind::Pp(3), 2, 1.2, 2.0);
        let y = sample_gp(&cov, 0.1, &x, &mut rng);
        let g = log_marginal_grad(&cov, 0.1, &x, &y);
        let p0 = cov.params();
        for p in 0..cov.n_params() {
            let h = 1e-6;
            let mut pp = p0.clone();
            pp[p] += h;
            cov.set_params(&pp);
            let fp = log_marginal(&cov, 0.1, &x, &y);
            pp[p] -= 2.0 * h;
            cov.set_params(&pp);
            let fm = log_marginal(&cov, 0.1, &x, &y);
            cov.set_params(&p0);
            let fd = (fp - fm) / (2.0 * h);
            assert!((fd - g[p]).abs() < 1e-4 * (1.0 + g[p].abs()), "p{p}: {fd} vs {}", g[p]);
        }
    }

    #[test]
    fn optimization_recovers_plausible_lengthscale() {
        // sample from a GP with l = 2, start the optimizer at l = 0.7 and
        // check the optimum lands in a sane neighbourhood
        let x = random_points(60, 2, 10.0, 17);
        let mut rng = Rng::new(5);
        let truth = CovFunction::new(CovKind::Pp(3), 2, 1.0, 2.0);
        let y = sample_gp(&truth, 0.04, &x, &mut rng);
        let start = CovFunction::new(CovKind::Pp(3), 2, 0.5, 0.7);
        let (fit, lml) = optimize_hypers(&start, 0.04, &x, &y, 60);
        assert!(lml > log_marginal(&start, 0.04, &x, &y), "optimizer made things worse");
        let l = fit.lengthscales[0];
        assert!(l > 0.5 && l < 8.0, "recovered lengthscale {l}");
    }

    #[test]
    fn sample_statistics_match_prior() {
        // marginal variance of samples ≈ σ² + noise
        let x = random_points(400, 2, 50.0, 23); // far apart -> nearly iid
        let cov = CovFunction::new(CovKind::Pp(2), 2, 1.5, 0.5);
        let mut rng = Rng::new(6);
        let y = sample_gp(&cov, 0.1, &x, &mut rng);
        let var = y.iter().map(|v| v * v).sum::<f64>() / y.len() as f64;
        assert!((var - 1.6).abs() < 0.4, "sample var {var}");
    }

    #[test]
    fn predict_mean_interpolates() {
        let x = vec![vec![0.0], vec![1.0], vec![2.0]];
        let y = vec![1.0, 2.0, 3.0];
        let cov = CovFunction::new(CovKind::Se, 1, 2.0, 1.5);
        let m = predict_mean(&cov, 1e-6, &x, &y, &[1.0]);
        assert!((m - 2.0).abs() < 0.05, "m = {m}");
    }
}
