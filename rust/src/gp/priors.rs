//! Hyperpriors. The paper places a half-Student-t prior (Gelman 2006) with
//! ν = 4 degrees of freedom and scale 6 on each covariance hyperparameter
//! (magnitude and length-scales), and optimizes the posterior mode of
//! `log Z_EP + log p(θ)` in log-parameter space — so the log-densities
//! here include the `exp` Jacobian.

/// Half-Student-t prior on a positive parameter.
#[derive(Clone, Copy, Debug)]
pub struct HalfStudentT {
    pub nu: f64,
    pub scale: f64,
}

impl HalfStudentT {
    /// Paper's setting: ν = 4, s = 6.
    pub fn paper_default() -> Self {
        HalfStudentT { nu: 4.0, scale: 6.0 }
    }

    /// Unnormalized log density at x > 0.
    pub fn ln_pdf(&self, x: f64) -> f64 {
        debug_assert!(x > 0.0);
        -(self.nu + 1.0) / 2.0 * (1.0 + x * x / (self.nu * self.scale * self.scale)).ln()
    }

    /// log p(θ(u)) + log|dθ/du| at u = ln x (the quantity added to the
    /// objective when optimizing in log space).
    pub fn ln_pdf_log_space(&self, u: f64) -> f64 {
        self.ln_pdf(u.exp()) + u
    }

    /// d/du of [`Self::ln_pdf_log_space`].
    pub fn ln_pdf_log_space_grad(&self, u: f64) -> f64 {
        let x = u.exp();
        let x2 = x * x;
        -(self.nu + 1.0) * x2 / (self.nu * self.scale * self.scale + x2) + 1.0
    }
}

/// A prior per log-parameter of a covariance function.
#[derive(Clone, Debug)]
pub struct HyperPrior {
    pub per_param: Vec<HalfStudentT>,
}

impl HyperPrior {
    /// The paper's prior replicated over `n_params` log-parameters.
    pub fn paper_default(n_params: usize) -> Self {
        HyperPrior { per_param: vec![HalfStudentT::paper_default(); n_params] }
    }

    pub fn ln_pdf(&self, log_params: &[f64]) -> f64 {
        self.per_param.iter().zip(log_params).map(|(p, &u)| p.ln_pdf_log_space(u)).sum()
    }

    pub fn ln_pdf_grad(&self, log_params: &[f64]) -> Vec<f64> {
        self.per_param
            .iter()
            .zip(log_params)
            .map(|(p, &u)| p.ln_pdf_log_space_grad(u))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grad_matches_finite_difference() {
        let p = HalfStudentT::paper_default();
        for &u in &[-2.0, -0.5, 0.0, 1.0, 3.0] {
            let h = 1e-6;
            let fd = (p.ln_pdf_log_space(u + h) - p.ln_pdf_log_space(u - h)) / (2.0 * h);
            let an = p.ln_pdf_log_space_grad(u);
            assert!((fd - an).abs() < 1e-6, "u={u}: fd={fd} an={an}");
        }
    }

    #[test]
    fn heavier_tail_than_normal() {
        let p = HalfStudentT::paper_default();
        let l10 = p.ln_pdf(60.0);
        let l20 = p.ln_pdf(120.0);
        // a Gaussian with scale 6 would give l20 − l10 ≈ −150
        assert!(l20 - l10 > -5.0, "tail too light: {}", l20 - l10);
    }

    #[test]
    fn favors_small_values() {
        let p = HalfStudentT::paper_default();
        assert!(p.ln_pdf(1.0) > p.ln_pdf(10.0));
        assert!(p.ln_pdf(10.0) > p.ln_pdf(100.0));
    }

    #[test]
    fn hyperprior_sums_over_params() {
        let hp = HyperPrior::paper_default(3);
        let u = vec![0.1, 0.2, 0.3];
        let single: f64 =
            u.iter().map(|&ui| HalfStudentT::paper_default().ln_pdf_log_space(ui)).sum();
        assert!((hp.ln_pdf(&u) - single).abs() < 1e-12);
        assert_eq!(hp.ln_pdf_grad(&u).len(), 3);
    }
}
