//! High-level GP classifier: hyperparameter MAP optimization (SCG over
//! `log Z_EP + log p(θ)`) wrapped around the chosen inference backend.
//! This is the user-facing API the examples and benches drive.

use std::time::{Duration, Instant};

use crate::gp::cache::PatternCache;
use crate::gp::covariance::{AdditiveCov, CovFunction};
use crate::gp::csfic::CsFicEp;
use crate::gp::ep_dense::DenseEp;
use crate::gp::ep_parallel::ParallelEp;
use crate::gp::ep_sparse::SparseEp;
use crate::gp::fic::FicEp;
use crate::gp::marginal::EpOptions;
use crate::gp::predict::{evaluate, LatentPredictor, Metrics as PredMetrics};
use crate::gp::priors::HyperPrior;
use crate::opt::scg::{scg, ScgOptions};
use crate::sparse::ordering::Ordering;

/// Which EP backend to run.
#[derive(Clone, Debug)]
pub enum Inference {
    /// Dense EP with full covariance (the k_se baseline).
    Dense,
    /// The paper's sparse EP (Algorithm 1) with the given fill-reducing
    /// ordering ([`Ordering::Auto`] lets the policy pick from pattern
    /// statistics and pool width — the recommended default for this
    /// factorization-bound backend).
    Sparse(Ordering),
    /// Parallel-EP ablation on the sparse representation.
    Parallel(Ordering),
    /// FIC with `m` k-means inducing inputs.
    Fic { m: usize },
    /// CS+FIC hybrid: `cov` is the sparse CS (local) term, the globally
    /// supported trend term lives in `GpClassifier::global_cov`, FIC'd
    /// through `m` k-means inducing inputs. The CS block's fill-reducing
    /// ordering defaults to [`Ordering::Auto`] (CLI: `--ordering`). Build
    /// with [`GpClassifier::new_cs_fic`].
    CsFic { m: usize, ordering: Ordering },
}

/// Model configuration.
#[derive(Clone, Debug)]
pub struct GpClassifier {
    pub cov: CovFunction,
    pub inference: Inference,
    /// The globally supported trend kernel of the CS+FIC hybrid
    /// (`Inference::CsFic`); `None` for every other backend.
    pub global_cov: Option<CovFunction>,
    /// None = maximum (marginal) likelihood; Some = MAP with this prior.
    pub prior: Option<HyperPrior>,
    pub ep_opts: EpOptions,
    pub opt_opts: ScgOptions,
}

impl GpClassifier {
    pub fn new(cov: CovFunction, inference: Inference) -> GpClassifier {
        let n_params = cov.n_params();
        GpClassifier {
            cov,
            inference,
            global_cov: None,
            prior: Some(HyperPrior::paper_default(n_params)),
            ep_opts: EpOptions::default(),
            opt_opts: ScgOptions { max_iters: 50, x_tol: 1e-4, f_tol: 1e-5 },
        }
    }

    /// CS+FIC hybrid classifier: `cs` is the compactly supported local
    /// term (it drives the sparse structure), `global` the globally
    /// supported trend term approximated by FIC with `m` k-means inducing
    /// inputs. Hyperparameters of both kernels are optimized jointly
    /// (`[cs params…, global params…]`).
    pub fn new_cs_fic(
        cs: CovFunction,
        global: CovFunction,
        m: usize,
    ) -> Result<GpClassifier, String> {
        GpClassifier::new_cs_fic_with_ordering(cs, global, m, Ordering::Auto)
    }

    /// [`GpClassifier::new_cs_fic`] with an explicit fill-reducing
    /// ordering for the CS block (the plain constructor uses
    /// [`Ordering::Auto`]) — the single place the choice enters, so
    /// callers never patch `inference` after construction.
    pub fn new_cs_fic_with_ordering(
        cs: CovFunction,
        global: CovFunction,
        m: usize,
        ordering: Ordering,
    ) -> Result<GpClassifier, String> {
        let add = AdditiveCov::new(global, cs)?; // validates support + dims
        let n_params = add.n_params();
        Ok(GpClassifier {
            cov: add.cs,
            inference: Inference::CsFic { m, ordering },
            global_cov: Some(add.global),
            prior: Some(HyperPrior::paper_default(n_params)),
            ep_opts: EpOptions::default(),
            opt_opts: ScgOptions { max_iters: 50, x_tol: 1e-4, f_tol: 1e-5 },
        })
    }

    /// A [`PatternCache`] matching this model's ordering choice. One cache
    /// serves one training set; `fit` holds it across the whole SCG loop
    /// so structure is re-analysed only when the support radius grows.
    pub(crate) fn fresh_cache(&self) -> PatternCache {
        let ordering = match &self.inference {
            Inference::Sparse(ord)
            | Inference::Parallel(ord)
            | Inference::CsFic { ordering: ord, .. } => *ord,
            Inference::Dense | Inference::Fic { .. } => Ordering::Natural,
        };
        PatternCache::new(ordering)
    }

    /// Inducing inputs for the low-rank backends (k-means centres of the
    /// training inputs); empty for the full-rank backends. One helper
    /// shared by `fit` and `infer_only`, FIC and CS+FIC.
    fn inducing_inputs(&self, x: &[Vec<f64>]) -> Vec<Vec<f64>> {
        match &self.inference {
            Inference::Fic { m } | Inference::CsFic { m, .. } => {
                crate::data::kmeans::kmeans(x, *m, 25, 0xf1c)
            }
            _ => Vec::new(),
        }
    }

    /// One EP run at the current hyperparameters: returns (logZ, grad,
    /// backend). FIC gradients use central finite differences (see
    /// DESIGN.md §Substitutions), warm-started from the converged sites.
    /// CS+FIC gradients are analytic for the CS block and warm-started
    /// finite differences for the global block. Sparse backends draw their
    /// structure (pattern / ordering / symbolic) from `cache`.
    #[allow(clippy::too_many_arguments)]
    fn ep_at(
        &self,
        cov: &CovFunction,
        gcov: Option<&CovFunction>,
        x: &[Vec<f64>],
        y: &[f64],
        xu: &[Vec<f64>],
        want_grad: bool,
        cache: &mut PatternCache,
    ) -> Result<(f64, Vec<f64>, Backend), String> {
        match &self.inference {
            Inference::Dense => {
                let ep = DenseEp::run(cov, x, y, &self.ep_opts)?;
                let g = if want_grad { ep.log_z_grad(cov, x) } else { vec![] };
                Ok((ep.log_z, g, Backend::Dense(ep)))
            }
            Inference::Sparse(_) => {
                let ep = SparseEp::run_cached(cov, x, y, &self.ep_opts, None, cache)?;
                let g = if want_grad {
                    // reuse the cache's Takahashi buffers across SCG steps
                    ep.log_z_grad_cached(cov, &mut cache.grad_scratch)
                } else {
                    vec![]
                };
                Ok((ep.log_z, g, Backend::Sparse(ep)))
            }
            Inference::Parallel(_) => {
                // analytic gradient shares the sparse-EP machinery: rerun
                // the sequential algorithm is wasteful, so reuse sparse-EP
                // formula through a SparseEp run only when a gradient is
                // needed (the ablation rarely optimizes hyperparameters).
                let ep = ParallelEp::run_cached(cov, x, y, &self.ep_opts, cache)?;
                let g = if want_grad {
                    let sep = SparseEp::run_cached(cov, x, y, &self.ep_opts, None, cache)?;
                    sep.log_z_grad_cached(cov, &mut cache.grad_scratch)
                } else {
                    vec![]
                };
                Ok((ep.log_z, g, Backend::Parallel(ep)))
            }
            Inference::Fic { .. } => {
                let ep = FicEp::run(cov, x, y, xu, &self.ep_opts)?;
                let g = if want_grad {
                    // central finite differences, warm-started from the
                    // converged sites: each perturbed run starts one or
                    // two sweeps from its fixed point instead of
                    // max_sweeps from zero sites
                    let p0 = cov.params();
                    let mut g = vec![0.0; cov.n_params()];
                    let h = 1e-4;
                    for p in 0..cov.n_params() {
                        let mut c = cov.clone();
                        let mut pp = p0.clone();
                        pp[p] += h;
                        c.set_params(&pp);
                        let zp =
                            FicEp::run_warm(&c, x, y, xu, &self.ep_opts, Some(&ep.sites))?.log_z;
                        pp[p] -= 2.0 * h;
                        c.set_params(&pp);
                        let zm =
                            FicEp::run_warm(&c, x, y, xu, &self.ep_opts, Some(&ep.sites))?.log_z;
                        g[p] = (zp - zm) / (2.0 * h);
                    }
                    g
                } else {
                    vec![]
                };
                Ok((ep.log_z, g, Backend::Fic(ep)))
            }
            Inference::CsFic { .. } => {
                let global = gcov.ok_or(
                    "Inference::CsFic requires global_cov (use GpClassifier::new_cs_fic)",
                )?;
                let add = AdditiveCov::new(global.clone(), cov.clone())?;
                let ep = CsFicEp::run_cached(&add, x, y, xu, &self.ep_opts, None, cache)?;
                let g = if want_grad {
                    // CS block: analytic through the sparse-plus-low-rank
                    // structure. Global block: warm-started central FDs
                    // (the fixed CS hypers keep the pattern cache hitting,
                    // and sites travel in unpermuted order).
                    let mut g = ep.log_z_grad_cs_cached(&mut cache.grad_scratch);
                    let warm = ep.sites_unpermuted();
                    let p0 = global.params();
                    let h = 1e-4;
                    for p in 0..global.n_params() {
                        let mut c = add.clone();
                        let mut pp = p0.clone();
                        pp[p] += h;
                        c.global.set_params(&pp);
                        let zp = CsFicEp::run_cached(
                            &c,
                            x,
                            y,
                            xu,
                            &self.ep_opts,
                            Some(&warm),
                            cache,
                        )?
                        .log_z;
                        pp[p] -= 2.0 * h;
                        c.global.set_params(&pp);
                        let zm = CsFicEp::run_cached(
                            &c,
                            x,
                            y,
                            xu,
                            &self.ep_opts,
                            Some(&warm),
                            cache,
                        )?
                        .log_z;
                        g.push((zp - zm) / (2.0 * h));
                    }
                    g
                } else {
                    vec![]
                };
                Ok((ep.log_z, g, Backend::CsFic(ep)))
            }
        }
    }

    /// The CS+FIC global kernel (cloned), validated against the inference
    /// choice: `Some` iff the backend is `CsFic`.
    fn global_for_inference(&self) -> Result<Option<CovFunction>, String> {
        match (&self.inference, &self.global_cov) {
            (Inference::CsFic { .. }, Some(g)) => Ok(Some(g.clone())),
            (Inference::CsFic { .. }, None) => Err(
                "Inference::CsFic requires global_cov (use GpClassifier::new_cs_fic)".into(),
            ),
            _ => Ok(None),
        }
    }

    /// Optimize hyperparameters (MAP) and return the fitted classifier.
    /// For CS+FIC the SCG search runs jointly over both kernels'
    /// log-parameters (`[cs…, global…]`).
    pub fn fit(&self, x: &[Vec<f64>], y: &[f64]) -> Result<FittedClassifier, String> {
        let xu = self.inducing_inputs(x);
        let t_opt = Instant::now();
        let mut cov = self.cov.clone();
        let mut gcov = self.global_for_inference()?;
        let nc = cov.n_params();
        let mut p0 = cov.params();
        if let Some(g) = &gcov {
            p0.extend(g.params());
        }
        let mut last_err: Option<String> = None;
        // one structure cache across the whole optimization: σ²-only steps
        // and shrinking length-scales reuse pattern + ordering + symbolic
        let mut cache = self.fresh_cache();
        let res = scg(
            &p0,
            |p| {
                let mut c = cov.clone();
                c.set_params(&p[..nc]);
                let mut gc = gcov.clone();
                if let Some(g) = gc.as_mut() {
                    g.set_params(&p[nc..]);
                }
                match self.ep_at(&c, gc.as_ref(), x, y, &xu, true, &mut cache) {
                    Ok((logz, grad, _)) => {
                        let mut f = -logz;
                        let mut g: Vec<f64> = grad.iter().map(|v| -v).collect();
                        if let Some(prior) = &self.prior {
                            f -= prior.ln_pdf(p);
                            for (gi, pg) in g.iter_mut().zip(prior.ln_pdf_grad(p)) {
                                *gi -= pg;
                            }
                        }
                        (f, g)
                    }
                    Err(e) => {
                        // EP blow-up at extreme hyperparameters: return a
                        // large objective so the optimizer backs off.
                        last_err = Some(e);
                        (1e10, p.iter().map(|_| 0.0).collect())
                    }
                }
            },
            &self.opt_opts,
        );
        let opt_time = t_opt.elapsed();
        cov.set_params(&res.x[..nc]);
        if let Some(g) = gcov.as_mut() {
            g.set_params(&res.x[nc..]);
        }

        // final EP run at the mode (this is the paper's "EP" timing column).
        // Use a fresh cache: the optimizer cache's radius only ratchets up,
        // and an SCG overshoot would otherwise leave the fitted model (and
        // its fill/timing stats) on a needlessly dense superset pattern.
        let t_ep = Instant::now();
        let mut final_cache = self.fresh_cache();
        let (log_z, _, backend) = self
            .ep_at(&cov, gcov.as_ref(), x, y, &xu, false, &mut final_cache)
            .map_err(|e| match &last_err {
                Some(prev) => format!("{e} (last optimizer-side EP failure: {prev})"),
                None => e,
            })?;
        let ep_time = t_ep.elapsed();

        let packed = {
            let mut p = cov.params();
            if let Some(g) = &gcov {
                p.extend(g.params());
            }
            p
        };
        let log_post =
            log_z + self.prior.as_ref().map(|pr| pr.ln_pdf(&packed)).unwrap_or(0.0);
        let (fill_k, fill_l) = fill_stats(&backend);
        Ok(FittedClassifier {
            cov,
            x: x.to_vec(),
            y: y.to_vec(),
            backend,
            report: FitReport {
                log_z,
                log_post,
                opt_iters: res.iterations,
                fn_evals: res.fn_evals,
                opt_time,
                ep_time,
                fill_k,
                fill_l,
                opt_converged: res.converged,
            },
        })
    }

    /// Run EP once at the current hyperparameters without optimizing.
    pub fn infer_only(&self, x: &[Vec<f64>], y: &[f64]) -> Result<FittedClassifier, String> {
        let xu = self.inducing_inputs(x);
        let gcov = self.global_for_inference()?;
        let t_ep = Instant::now();
        let mut cache = self.fresh_cache();
        let (log_z, _, backend) =
            self.ep_at(&self.cov, gcov.as_ref(), x, y, &xu, false, &mut cache)?;
        let ep_time = t_ep.elapsed();
        let (fill_k, fill_l) = fill_stats(&backend);
        Ok(FittedClassifier {
            cov: self.cov.clone(),
            x: x.to_vec(),
            y: y.to_vec(),
            backend,
            report: FitReport {
                log_z,
                log_post: log_z,
                opt_iters: 0,
                fn_evals: 0,
                opt_time: Duration::ZERO,
                ep_time,
                fill_k,
                fill_l,
                opt_converged: true,
            },
        })
    }
}

/// Fill statistics of a fitted backend (1.0/1.0 for the dense ones).
fn fill_stats(backend: &Backend) -> (f64, f64) {
    match backend {
        Backend::Sparse(ep) => (ep.fill_k, ep.fill_l),
        Backend::CsFic(ep) => (ep.fill_k, ep.fill_l),
        _ => (1.0, 1.0),
    }
}

/// The fitted EP state, backend-specific.
pub enum Backend {
    Dense(DenseEp),
    Sparse(SparseEp),
    Parallel(ParallelEp),
    Fic(FicEp),
    CsFic(CsFicEp),
}

/// Timing/quality report of a fit — the raw material of Tables 2 & 3.
#[derive(Clone, Debug)]
pub struct FitReport {
    pub log_z: f64,
    pub log_post: f64,
    pub opt_iters: usize,
    pub fn_evals: usize,
    pub opt_time: Duration,
    pub ep_time: Duration,
    pub fill_k: f64,
    pub fill_l: f64,
    pub opt_converged: bool,
}

/// A trained classifier ready for prediction.
pub struct FittedClassifier {
    pub cov: CovFunction,
    pub x: Vec<Vec<f64>>,
    /// Training labels (±1), kept so the online-update path
    /// ([`GpClassifier::update`](crate::gp::online)) can refit or extend
    /// on the union without the caller re-supplying the history.
    pub y: Vec<f64>,
    pub backend: Backend,
    pub report: FitReport,
}

impl FittedClassifier {
    /// Latent predictive (mean, variance) at one point. Allocates scratch
    /// per call on the sparse backends — streams of predictions should go
    /// through [`FittedClassifier::predictor`].
    pub fn predict_latent(&self, xstar: &[f64]) -> (f64, f64) {
        match &self.backend {
            Backend::Dense(ep) => ep.predict_latent(&self.cov, &self.x, xstar),
            Backend::Sparse(ep) => ep.predict_latent(&self.cov, xstar),
            Backend::Parallel(ep) => ep.predict_latent(&self.cov, xstar),
            Backend::Fic(ep) => ep.predict_latent(&self.cov, xstar),
            // the hybrid backend carries both kernels internally
            Backend::CsFic(ep) => ep.predict_latent(xstar),
        }
    }

    /// Reusable predictor: one neighbor index + one solve workspace shared
    /// across every prediction made through it.
    pub fn predictor(&self) -> LatentPredictor<'_> {
        LatentPredictor::new(self)
    }

    /// Latent predictions for a batch: one shared neighbor index, with the
    /// per-point solves fanned out over the worker pool on the
    /// workspace-backed backends (see
    /// [`LatentPredictor::predict_latent_batch`]).
    pub fn predict_latent_batch(&self, xs: &[Vec<f64>]) -> Vec<(f64, f64)> {
        let mut predictor = self.predictor();
        predictor.predict_latent_batch(xs)
    }

    /// Class probabilities π* for a batch — the latent stage fans out
    /// over the worker pool like
    /// [`predict_latent_batch`](FittedClassifier::predict_latent_batch);
    /// the probit squash is a pure function of each `(μ*, σ*²)`.
    pub fn predict_proba(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        self.predict_latent_batch(xs)
            .into_iter()
            .map(|(m, v)| crate::gp::predict::class_probability(m, v))
            .collect()
    }

    /// Error / nlpd metrics on a labelled test set.
    pub fn evaluate(&self, xs: &[Vec<f64>], ys: &[f64]) -> PredMetrics {
        evaluate(&self.predict_latent_batch(xs), ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::covariance::CovKind;
    use crate::testutil::random_points;

    fn blob_data(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let x = random_points(n, 2, 6.0, seed);
        let y: Vec<f64> = x
            .iter()
            .map(|p| if (p[0] - 3.0).hypot(p[1] - 3.0) < 2.0 { 1.0 } else { -1.0 })
            .collect();
        (x, y)
    }

    #[test]
    fn fit_improves_log_posterior() {
        let (x, y) = blob_data(40, 91);
        let cov = CovFunction::new(CovKind::Pp(3), 2, 0.6, 0.8);
        let mut model = GpClassifier::new(cov, Inference::Sparse(Ordering::Rcm));
        model.opt_opts.max_iters = 15;
        let before = model.infer_only(&x, &y).unwrap().report.log_post;
        let fitted = model.fit(&x, &y).unwrap();
        assert!(
            fitted.report.log_post >= before - 1e-6,
            "fit made log posterior worse: {} -> {}",
            before,
            fitted.report.log_post
        );
    }

    #[test]
    fn all_backends_fit_and_predict() {
        let (x, y) = blob_data(30, 17);
        let (xt, yt) = blob_data(30, 18);
        let mut models = vec![];
        for inference in [
            Inference::Dense,
            Inference::Sparse(Ordering::Rcm),
            Inference::Parallel(Ordering::Rcm),
            Inference::Fic { m: 9 },
        ] {
            let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 2.0);
            models.push(GpClassifier::new(cov, inference));
        }
        models.push(
            GpClassifier::new_cs_fic(
                CovFunction::new(CovKind::Pp(3), 2, 0.8, 2.0),
                CovFunction::new(CovKind::Se, 2, 0.6, 3.0),
                9,
            )
            .unwrap(),
        );
        for model in models {
            let fitted = model.infer_only(&x, &y).unwrap();
            let m = fitted.evaluate(&xt, &yt);
            assert!(m.err <= 0.5, "{:?}: err {}", model.inference, m.err);
            assert!(m.nlpd.is_finite());
            let probs = fitted.predict_proba(&xt);
            assert!(probs.iter().all(|&p| (0.0..=1.0).contains(&p)));
            let _ = yt.len();
        }
    }

    /// CS+FIC without its global kernel is a configuration error, not a
    /// panic or a silently degraded model.
    #[test]
    fn cs_fic_without_global_cov_errors() {
        let (x, y) = blob_data(20, 5);
        let model = GpClassifier::new(
            CovFunction::new(CovKind::Pp(3), 2, 1.0, 2.0),
            Inference::CsFic { m: 5, ordering: Ordering::Auto },
        );
        assert!(model.infer_only(&x, &y).is_err());
        assert!(model.fit(&x, &y).is_err());
    }

    /// Joint MAP over both kernels' hyperparameters (analytic CS gradient
    /// + warm-started FD global gradient) must not make the posterior
    /// worse.
    #[test]
    fn cs_fic_fit_improves_log_posterior() {
        let (x, y) = blob_data(40, 91);
        let mut model = GpClassifier::new_cs_fic(
            CovFunction::new(CovKind::Pp(3), 2, 0.6, 0.9),
            CovFunction::new(CovKind::Se, 2, 0.5, 3.0),
            8,
        )
        .unwrap();
        model.opt_opts.max_iters = 6;
        // like-for-like MAP objective at the start: logZ + prior over the
        // *joint* parameter vector (infer_only's log_post omits the prior)
        let mut p0 = model.cov.params();
        p0.extend(model.global_cov.as_ref().unwrap().params());
        let before = model.infer_only(&x, &y).unwrap().report.log_z
            + model.prior.as_ref().unwrap().ln_pdf(&p0);
        let fitted = model.fit(&x, &y).unwrap();
        assert!(
            fitted.report.log_post >= before - 1e-6,
            "fit made log posterior worse: {} -> {}",
            before,
            fitted.report.log_post
        );
        // both kernels' hypers were free to move and stayed positive
        assert!(fitted.cov.sigma2 > 0.0);
    }
}
