//! High-level GP classifier: hyperparameter MAP optimization (SCG over
//! `log Z_EP + log p(θ)`) wrapped around the chosen inference backend.
//! This is the user-facing API the examples and benches drive.

use std::time::{Duration, Instant};

use crate::gp::cache::PatternCache;
use crate::gp::covariance::CovFunction;
use crate::gp::ep_dense::DenseEp;
use crate::gp::ep_parallel::ParallelEp;
use crate::gp::ep_sparse::SparseEp;
use crate::gp::fic::FicEp;
use crate::gp::marginal::EpOptions;
use crate::gp::predict::{evaluate, LatentPredictor, Metrics as PredMetrics};
use crate::gp::priors::HyperPrior;
use crate::opt::scg::{scg, ScgOptions};
use crate::sparse::ordering::Ordering;

/// Which EP backend to run.
#[derive(Clone, Debug)]
pub enum Inference {
    /// Dense EP with full covariance (the k_se baseline).
    Dense,
    /// The paper's sparse EP (Algorithm 1) with the given fill-reducing
    /// ordering.
    Sparse(Ordering),
    /// Parallel-EP ablation on the sparse representation.
    Parallel(Ordering),
    /// FIC with `m` k-means inducing inputs.
    Fic { m: usize },
}

/// Model configuration.
#[derive(Clone, Debug)]
pub struct GpClassifier {
    pub cov: CovFunction,
    pub inference: Inference,
    /// None = maximum (marginal) likelihood; Some = MAP with this prior.
    pub prior: Option<HyperPrior>,
    pub ep_opts: EpOptions,
    pub opt_opts: ScgOptions,
}

impl GpClassifier {
    pub fn new(cov: CovFunction, inference: Inference) -> GpClassifier {
        let n_params = cov.n_params();
        GpClassifier {
            cov,
            inference,
            prior: Some(HyperPrior::paper_default(n_params)),
            ep_opts: EpOptions::default(),
            opt_opts: ScgOptions { max_iters: 50, x_tol: 1e-4, f_tol: 1e-5 },
        }
    }

    /// A [`PatternCache`] matching this model's ordering choice. One cache
    /// serves one training set; `fit` holds it across the whole SCG loop
    /// so structure is re-analysed only when the support radius grows.
    fn fresh_cache(&self) -> PatternCache {
        let ordering = match &self.inference {
            Inference::Sparse(ord) | Inference::Parallel(ord) => *ord,
            Inference::Dense | Inference::Fic { .. } => Ordering::Natural,
        };
        PatternCache::new(ordering)
    }

    /// One EP run at the current hyperparameters: returns (logZ, grad,
    /// backend). FIC gradients use central finite differences (see
    /// DESIGN.md §Substitutions). Sparse backends draw their structure
    /// (pattern / ordering / symbolic) from `cache`.
    fn ep_at(
        &self,
        cov: &CovFunction,
        x: &[Vec<f64>],
        y: &[f64],
        xu: &[Vec<f64>],
        want_grad: bool,
        cache: &mut PatternCache,
    ) -> Result<(f64, Vec<f64>, Backend), String> {
        match &self.inference {
            Inference::Dense => {
                let ep = DenseEp::run(cov, x, y, &self.ep_opts)?;
                let g = if want_grad { ep.log_z_grad(cov, x) } else { vec![] };
                Ok((ep.log_z, g, Backend::Dense(ep)))
            }
            Inference::Sparse(_) => {
                let ep = SparseEp::run_cached(cov, x, y, &self.ep_opts, None, cache)?;
                let g = if want_grad { ep.log_z_grad(cov) } else { vec![] };
                Ok((ep.log_z, g, Backend::Sparse(ep)))
            }
            Inference::Parallel(_) => {
                // analytic gradient shares the sparse-EP machinery: rerun
                // the sequential algorithm is wasteful, so reuse sparse-EP
                // formula through a SparseEp run only when a gradient is
                // needed (the ablation rarely optimizes hyperparameters).
                let ep = ParallelEp::run_cached(cov, x, y, &self.ep_opts, cache)?;
                let g = if want_grad {
                    SparseEp::run_cached(cov, x, y, &self.ep_opts, None, cache)?.log_z_grad(cov)
                } else {
                    vec![]
                };
                Ok((ep.log_z, g, Backend::Parallel(ep)))
            }
            Inference::Fic { .. } => {
                let ep = FicEp::run(cov, x, y, xu, &self.ep_opts)?;
                let g = if want_grad {
                    let p0 = cov.params();
                    let mut g = vec![0.0; cov.n_params()];
                    let h = 1e-4;
                    for p in 0..cov.n_params() {
                        let mut c = cov.clone();
                        let mut pp = p0.clone();
                        pp[p] += h;
                        c.set_params(&pp);
                        let zp = FicEp::run(&c, x, y, xu, &self.ep_opts)?.log_z;
                        pp[p] -= 2.0 * h;
                        c.set_params(&pp);
                        let zm = FicEp::run(&c, x, y, xu, &self.ep_opts)?.log_z;
                        g[p] = (zp - zm) / (2.0 * h);
                    }
                    g
                } else {
                    vec![]
                };
                Ok((ep.log_z, g, Backend::Fic(ep)))
            }
        }
    }

    /// Optimize hyperparameters (MAP) and return the fitted classifier.
    pub fn fit(&self, x: &[Vec<f64>], y: &[f64]) -> Result<FittedClassifier, String> {
        let xu = match &self.inference {
            Inference::Fic { m } => crate::data::kmeans::kmeans(x, *m, 25, 0xf1c),
            _ => Vec::new(),
        };
        let t_opt = Instant::now();
        let mut cov = self.cov.clone();
        let p0 = cov.params();
        let mut last_err: Option<String> = None;
        // one structure cache across the whole optimization: σ²-only steps
        // and shrinking length-scales reuse pattern + ordering + symbolic
        let mut cache = self.fresh_cache();
        let res = scg(
            &p0,
            |p| {
                let mut c = cov.clone();
                c.set_params(p);
                match self.ep_at(&c, x, y, &xu, true, &mut cache) {
                    Ok((logz, grad, _)) => {
                        let mut f = -logz;
                        let mut g: Vec<f64> = grad.iter().map(|v| -v).collect();
                        if let Some(prior) = &self.prior {
                            f -= prior.ln_pdf(p);
                            for (gi, pg) in g.iter_mut().zip(prior.ln_pdf_grad(p)) {
                                *gi -= pg;
                            }
                        }
                        (f, g)
                    }
                    Err(e) => {
                        // EP blow-up at extreme hyperparameters: return a
                        // large objective so the optimizer backs off.
                        last_err = Some(e);
                        (1e10, p.iter().map(|_| 0.0).collect())
                    }
                }
            },
            &self.opt_opts,
        );
        let opt_time = t_opt.elapsed();
        cov.set_params(&res.x);

        // final EP run at the mode (this is the paper's "EP" timing column).
        // Use a fresh cache: the optimizer cache's radius only ratchets up,
        // and an SCG overshoot would otherwise leave the fitted model (and
        // its fill/timing stats) on a needlessly dense superset pattern.
        let t_ep = Instant::now();
        let mut final_cache = self.fresh_cache();
        let (log_z, _, backend) =
            self.ep_at(&cov, x, y, &xu, false, &mut final_cache).map_err(|e| match &last_err {
                Some(prev) => format!("{e} (last optimizer-side EP failure: {prev})"),
                None => e,
            })?;
        let ep_time = t_ep.elapsed();

        let log_post = log_z
            + self.prior.as_ref().map(|pr| pr.ln_pdf(&cov.params())).unwrap_or(0.0);
        let (fill_k, fill_l) = match &backend {
            Backend::Sparse(ep) => (ep.fill_k, ep.fill_l),
            _ => (1.0, 1.0),
        };
        Ok(FittedClassifier {
            cov,
            x: x.to_vec(),
            backend,
            report: FitReport {
                log_z,
                log_post,
                opt_iters: res.iterations,
                fn_evals: res.fn_evals,
                opt_time,
                ep_time,
                fill_k,
                fill_l,
                opt_converged: res.converged,
            },
        })
    }

    /// Run EP once at the current hyperparameters without optimizing.
    pub fn infer_only(&self, x: &[Vec<f64>], y: &[f64]) -> Result<FittedClassifier, String> {
        let xu = match &self.inference {
            Inference::Fic { m } => crate::data::kmeans::kmeans(x, *m, 25, 0xf1c),
            _ => Vec::new(),
        };
        let t_ep = Instant::now();
        let mut cache = self.fresh_cache();
        let (log_z, _, backend) = self.ep_at(&self.cov, x, y, &xu, false, &mut cache)?;
        let ep_time = t_ep.elapsed();
        let (fill_k, fill_l) = match &backend {
            Backend::Sparse(ep) => (ep.fill_k, ep.fill_l),
            _ => (1.0, 1.0),
        };
        Ok(FittedClassifier {
            cov: self.cov.clone(),
            x: x.to_vec(),
            backend,
            report: FitReport {
                log_z,
                log_post: log_z,
                opt_iters: 0,
                fn_evals: 0,
                opt_time: Duration::ZERO,
                ep_time,
                fill_k,
                fill_l,
                opt_converged: true,
            },
        })
    }
}

/// The fitted EP state, backend-specific.
pub enum Backend {
    Dense(DenseEp),
    Sparse(SparseEp),
    Parallel(ParallelEp),
    Fic(FicEp),
}

/// Timing/quality report of a fit — the raw material of Tables 2 & 3.
#[derive(Clone, Debug)]
pub struct FitReport {
    pub log_z: f64,
    pub log_post: f64,
    pub opt_iters: usize,
    pub fn_evals: usize,
    pub opt_time: Duration,
    pub ep_time: Duration,
    pub fill_k: f64,
    pub fill_l: f64,
    pub opt_converged: bool,
}

/// A trained classifier ready for prediction.
pub struct FittedClassifier {
    pub cov: CovFunction,
    pub x: Vec<Vec<f64>>,
    pub backend: Backend,
    pub report: FitReport,
}

impl FittedClassifier {
    /// Latent predictive (mean, variance) at one point. Allocates scratch
    /// per call on the sparse backends — streams of predictions should go
    /// through [`FittedClassifier::predictor`].
    pub fn predict_latent(&self, xstar: &[f64]) -> (f64, f64) {
        match &self.backend {
            Backend::Dense(ep) => ep.predict_latent(&self.cov, &self.x, xstar),
            Backend::Sparse(ep) => ep.predict_latent(&self.cov, xstar),
            Backend::Parallel(ep) => ep.predict_latent(&self.cov, xstar),
            Backend::Fic(ep) => ep.predict_latent(&self.cov, xstar),
        }
    }

    /// Reusable predictor: one neighbor index + one solve workspace shared
    /// across every prediction made through it.
    pub fn predictor(&self) -> LatentPredictor<'_> {
        LatentPredictor::new(self)
    }

    /// Latent predictions for a batch (one shared workspace).
    pub fn predict_latent_batch(&self, xs: &[Vec<f64>]) -> Vec<(f64, f64)> {
        let mut predictor = self.predictor();
        xs.iter().map(|x| predictor.predict_latent(x)).collect()
    }

    /// Class probabilities π* for a batch (one shared workspace).
    pub fn predict_proba(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        let mut predictor = self.predictor();
        xs.iter().map(|x| predictor.predict_proba(x)).collect()
    }

    /// Error / nlpd metrics on a labelled test set.
    pub fn evaluate(&self, xs: &[Vec<f64>], ys: &[f64]) -> PredMetrics {
        evaluate(&self.predict_latent_batch(xs), ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::covariance::CovKind;
    use crate::testutil::random_points;

    fn blob_data(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let x = random_points(n, 2, 6.0, seed);
        let y: Vec<f64> = x
            .iter()
            .map(|p| if (p[0] - 3.0).hypot(p[1] - 3.0) < 2.0 { 1.0 } else { -1.0 })
            .collect();
        (x, y)
    }

    #[test]
    fn fit_improves_log_posterior() {
        let (x, y) = blob_data(40, 91);
        let cov = CovFunction::new(CovKind::Pp(3), 2, 0.6, 0.8);
        let mut model = GpClassifier::new(cov, Inference::Sparse(Ordering::Rcm));
        model.opt_opts.max_iters = 15;
        let before = model.infer_only(&x, &y).unwrap().report.log_post;
        let fitted = model.fit(&x, &y).unwrap();
        assert!(
            fitted.report.log_post >= before - 1e-6,
            "fit made log posterior worse: {} -> {}",
            before,
            fitted.report.log_post
        );
    }

    #[test]
    fn all_backends_fit_and_predict() {
        let (x, y) = blob_data(30, 17);
        let (xt, yt) = blob_data(30, 18);
        for inference in [
            Inference::Dense,
            Inference::Sparse(Ordering::Rcm),
            Inference::Parallel(Ordering::Rcm),
            Inference::Fic { m: 9 },
        ] {
            let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 2.0);
            let model = GpClassifier::new(cov, inference.clone());
            let fitted = model.infer_only(&x, &y).unwrap();
            let m = fitted.evaluate(&xt, &yt);
            assert!(m.err <= 0.5, "{inference:?}: err {}", m.err);
            assert!(m.nlpd.is_finite());
            let probs = fitted.predict_proba(&xt);
            assert!(probs.iter().all(|&p| (0.0..=1.0).contains(&p)));
            let _ = yt.len();
        }
    }
}
