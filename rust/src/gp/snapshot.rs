//! Versioned binary model snapshots — save a [`FittedClassifier`] to
//! disk and reload it predict-ready, **without re-running symbolic
//! analysis or numeric factorization**.
//!
//! The serving story this enables: fit once (expensive — SCG over EP),
//! snapshot, and have replicas `load` the converged state in I/O time.
//! Every posterior block a prediction touches is stored verbatim — sites,
//! the numeric LDLᵀ values, the Woodbury capacitance blocks, permutation
//! and symbolic pattern — so a loaded model answers its first prediction
//! without a single factorization, and an online update
//! ([`crate::gp::online`]) can extend the restored factor directly.
//!
//! ## Format (all little-endian, std-only)
//!
//! ```text
//! offset  size  field
//! 0       8     magic "CSGPSNAP"
//! 8       4     format version (u32, currently 1)
//! 12      1     backend tag (0 dense, 1 sparse, 2 parallel, 3 fic, 4 csfic)
//! 13      8     payload length (u64)
//! 21      8     FNV-1a 64 checksum of the payload
//! 29      …     payload
//! ```
//!
//! The payload is a flat field-by-field encoding: `u64` lengths, `f64`
//! values, UTF-8 strings for kernel kind names. `usize` values are stored
//! as `u64` (the `usize::MAX` etree-root sentinel round-trips as
//! `u64::MAX`). The symbolic analysis stores only its *defining* parts
//! (etree parent, padded pattern, strict nnz, supernode partition);
//! [`Symbolic::from_parts`] rebuilds the derived row map and wave
//! schedule in `O(nnz)` — data movement, not analysis.
//!
//! ## Durability
//!
//! [`save`] writes to a `<path>.tmp` sibling and `rename`s it into place,
//! so a crash (or an injected `io@snapshot.save` fault, see
//! [`crate::fault`]) never leaves a partial file at the destination:
//! readers see the old snapshot or the new one, nothing in between.
//!
//! ## Failure model
//!
//! Loading is total: corrupted, truncated, or foreign files produce a
//! typed [`SnapshotError`], never a panic. The checksum rejects payload
//! corruption before any structure is built; structural invariants that
//! downstream kernels assume (pattern shapes, aligned lengths) are
//! re-validated after decoding.

use std::fmt;
use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use crate::gp::covariance::{AdditiveCov, CovFunction, CovKind};
use crate::gp::csfic::CsFicEp;
use crate::gp::ep_dense::DenseEp;
use crate::gp::ep_parallel::ParallelEp;
use crate::gp::ep_sparse::SparseEp;
use crate::gp::fic::FicEp;
use crate::gp::marginal::EpSites;
use crate::gp::model::{Backend, FitReport, FittedClassifier};
use crate::sparse::cholesky::LdlFactor;
use crate::sparse::csc::CscMatrix;
use crate::sparse::dense::{DenseCholesky, DenseMatrix};
use crate::sparse::lowrank::SparseLowRank;
use crate::sparse::symbolic::Symbolic;

const MAGIC: &[u8; 8] = b"CSGPSNAP";
const VERSION: u32 = 1;
const HEADER_LEN: usize = 8 + 4 + 1 + 8 + 8;

const TAG_DENSE: u8 = 0;
const TAG_SPARSE: u8 = 1;
const TAG_PARALLEL: u8 = 2;
const TAG_FIC: u8 = 3;
const TAG_CSFIC: u8 = 4;

/// Why a snapshot could not be written or read back.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SnapshotError {
    /// Filesystem-level failure (open, write, rename, read).
    Io(String),
    /// The file does not start with the `CSGPSNAP` magic.
    BadMagic,
    /// The file is a snapshot, but of a format version this build does
    /// not understand.
    UnsupportedVersion(u32),
    /// The backend tag byte names no known backend.
    BadBackendTag(u8),
    /// The file ends before the declared payload does.
    Truncated,
    /// The payload checksum does not match the header.
    ChecksumMismatch,
    /// The payload decoded, but violates a structural invariant.
    Corrupted(String),
}

impl fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SnapshotError::Io(e) => write!(f, "snapshot I/O error: {e}"),
            SnapshotError::BadMagic => write!(f, "not a csgp snapshot (bad magic)"),
            SnapshotError::UnsupportedVersion(v) => {
                write!(f, "unsupported snapshot version {v} (this build reads {VERSION})")
            }
            SnapshotError::BadBackendTag(t) => write!(f, "unknown backend tag {t}"),
            SnapshotError::Truncated => write!(f, "snapshot file is truncated"),
            SnapshotError::ChecksumMismatch => {
                write!(f, "snapshot payload checksum mismatch (file corrupted)")
            }
            SnapshotError::Corrupted(why) => write!(f, "snapshot payload corrupted: {why}"),
        }
    }
}

impl std::error::Error for SnapshotError {}

/// What [`probe`] reports without building any model state.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SnapshotInfo {
    pub version: u32,
    /// Backend name: `dense`, `sparse`, `parallel`, `fic` or `csfic`.
    pub backend: &'static str,
    pub payload_len: u64,
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

fn tag_name(tag: u8) -> Result<&'static str, SnapshotError> {
    match tag {
        TAG_DENSE => Ok("dense"),
        TAG_SPARSE => Ok("sparse"),
        TAG_PARALLEL => Ok("parallel"),
        TAG_FIC => Ok("fic"),
        TAG_CSFIC => Ok("csfic"),
        other => Err(SnapshotError::BadBackendTag(other)),
    }
}

// ---------------------------------------------------------------------------
// Flat little-endian encoding
// ---------------------------------------------------------------------------

fn w_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn w_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn w_usize(buf: &mut Vec<u8>, v: usize) {
    w_u64(buf, v as u64);
}

fn w_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn w_bool(buf: &mut Vec<u8>, v: bool) {
    buf.push(v as u8);
}

fn w_f64s(buf: &mut Vec<u8>, v: &[f64]) {
    w_usize(buf, v.len());
    for &x in v {
        w_f64(buf, x);
    }
}

fn w_usizes(buf: &mut Vec<u8>, v: &[usize]) {
    w_usize(buf, v.len());
    for &x in v {
        w_usize(buf, x);
    }
}

fn w_str(buf: &mut Vec<u8>, s: &str) {
    w_usize(buf, s.len());
    buf.extend_from_slice(s.as_bytes());
}

/// Point sets are rectangular (`n` points × `dim` coordinates), stored
/// flat.
fn w_points(buf: &mut Vec<u8>, pts: &[Vec<f64>]) {
    let dim = pts.first().map_or(0, Vec::len);
    w_usize(buf, pts.len());
    w_usize(buf, dim);
    for p in pts {
        debug_assert_eq!(p.len(), dim);
        for &c in p {
            w_f64(buf, c);
        }
    }
}

/// Bounds-checked payload reader: every decode either yields a value or a
/// typed error — no slicing panics, no unchecked allocations (vector
/// lengths are capped by the bytes actually remaining).
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn is_empty(&self) -> bool {
        self.pos == self.buf.len()
    }

    fn take(&mut self, k: usize) -> Result<&'a [u8], SnapshotError> {
        let end = self.pos.checked_add(k).ok_or(SnapshotError::Truncated)?;
        if end > self.buf.len() {
            return Err(SnapshotError::Truncated);
        }
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8, SnapshotError> {
        Ok(self.take(1)?[0])
    }

    fn u64(&mut self) -> Result<u64, SnapshotError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn usize(&mut self) -> Result<usize, SnapshotError> {
        Ok(self.u64()? as usize)
    }

    fn f64(&mut self) -> Result<f64, SnapshotError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn bool(&mut self) -> Result<bool, SnapshotError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => Err(SnapshotError::Corrupted(format!("bad bool byte {other}"))),
        }
    }

    /// A declared element count, rejected unless `count * elem_size`
    /// bytes actually remain — a corrupted length can never trigger a
    /// huge allocation.
    fn len(&mut self, elem_size: usize) -> Result<usize, SnapshotError> {
        let len = self.usize()?;
        if len > (self.buf.len() - self.pos) / elem_size.max(1) {
            return Err(SnapshotError::Truncated);
        }
        Ok(len)
    }

    fn f64s(&mut self) -> Result<Vec<f64>, SnapshotError> {
        let len = self.len(8)?;
        (0..len).map(|_| self.f64()).collect()
    }

    fn usizes(&mut self) -> Result<Vec<usize>, SnapshotError> {
        let len = self.len(8)?;
        (0..len).map(|_| self.usize()).collect()
    }

    fn str(&mut self) -> Result<String, SnapshotError> {
        let len = self.len(1)?;
        String::from_utf8(self.take(len)?.to_vec())
            .map_err(|_| SnapshotError::Corrupted("non-UTF-8 string".into()))
    }

    fn points(&mut self) -> Result<Vec<Vec<f64>>, SnapshotError> {
        let n = self.len(8)?;
        let dim = self.usize()?;
        let row_bytes = dim.checked_mul(8).ok_or(SnapshotError::Truncated)?;
        if dim > 0 && n > (self.buf.len() - self.pos) / row_bytes {
            return Err(SnapshotError::Truncated);
        }
        (0..n).map(|_| (0..dim).map(|_| self.f64()).collect()).collect()
    }

    fn duration(&mut self) -> Result<Duration, SnapshotError> {
        let secs = self.f64()?;
        if !secs.is_finite() || !(0.0..1e15).contains(&secs) {
            return Err(SnapshotError::Corrupted(format!("bad duration {secs}")));
        }
        Ok(Duration::from_secs_f64(secs))
    }
}

// ---------------------------------------------------------------------------
// Component codecs
// ---------------------------------------------------------------------------

fn w_cov(buf: &mut Vec<u8>, cov: &CovFunction) {
    w_str(buf, &cov.kind.name());
    w_usize(buf, cov.input_dim);
    w_f64(buf, cov.sigma2);
    w_f64s(buf, &cov.lengthscales);
}

fn r_cov(r: &mut Reader) -> Result<CovFunction, SnapshotError> {
    let kind = CovKind::parse(&r.str()?).map_err(SnapshotError::Corrupted)?;
    let input_dim = r.usize()?;
    let sigma2 = r.f64()?;
    let lengthscales = r.f64s()?;
    if lengthscales.len() != input_dim {
        return Err(SnapshotError::Corrupted(format!(
            "{} lengthscales for input_dim {input_dim}",
            lengthscales.len()
        )));
    }
    Ok(CovFunction { kind, input_dim, sigma2, lengthscales })
}

fn w_sites(buf: &mut Vec<u8>, s: &EpSites) {
    w_f64s(buf, &s.tau);
    w_f64s(buf, &s.nu);
    w_f64s(buf, &s.tau_cav);
    w_f64s(buf, &s.nu_cav);
    w_f64s(buf, &s.ln_zhat);
}

fn r_sites(r: &mut Reader) -> Result<EpSites, SnapshotError> {
    let tau = r.f64s()?;
    let nu = r.f64s()?;
    let tau_cav = r.f64s()?;
    let nu_cav = r.f64s()?;
    let ln_zhat = r.f64s()?;
    let n = tau.len();
    if [&nu, &tau_cav, &nu_cav, &ln_zhat].iter().any(|v| v.len() != n) {
        return Err(SnapshotError::Corrupted("site vectors disagree on n".into()));
    }
    Ok(EpSites { tau, nu, tau_cav, nu_cav, ln_zhat })
}

fn w_csc(buf: &mut Vec<u8>, m: &CscMatrix) {
    w_usize(buf, m.n_rows);
    w_usize(buf, m.n_cols);
    w_usizes(buf, &m.col_ptr);
    w_usizes(buf, &m.row_idx);
    w_f64s(buf, &m.values);
}

fn r_csc(r: &mut Reader) -> Result<CscMatrix, SnapshotError> {
    let n_rows = r.usize()?;
    let n_cols = r.usize()?;
    let col_ptr = r.usizes()?;
    let row_idx = r.usizes()?;
    let values = r.f64s()?;
    let ok = n_cols.checked_add(1) == Some(col_ptr.len())
        && col_ptr.first() == Some(&0)
        && col_ptr.windows(2).all(|w| w[0] <= w[1])
        && col_ptr.last() == Some(&row_idx.len())
        && values.len() == row_idx.len()
        && row_idx.iter().all(|&i| i < n_rows);
    if !ok {
        return Err(SnapshotError::Corrupted("malformed CSC matrix".into()));
    }
    Ok(CscMatrix { n_rows, n_cols, col_ptr, row_idx, values })
}

fn w_dense(buf: &mut Vec<u8>, m: &DenseMatrix) {
    w_usize(buf, m.n_rows);
    w_usize(buf, m.n_cols);
    w_f64s(buf, &m.data);
}

fn r_dense(r: &mut Reader) -> Result<DenseMatrix, SnapshotError> {
    let n_rows = r.usize()?;
    let n_cols = r.usize()?;
    let data = r.f64s()?;
    if n_rows.checked_mul(n_cols) != Some(data.len()) {
        return Err(SnapshotError::Corrupted("dense matrix shape mismatch".into()));
    }
    Ok(DenseMatrix { n_rows, n_cols, data })
}

fn w_chol(buf: &mut Vec<u8>, c: &DenseCholesky) {
    w_usize(buf, c.n);
    w_f64s(buf, &c.l);
}

fn r_chol(r: &mut Reader) -> Result<DenseCholesky, SnapshotError> {
    let n = r.usize()?;
    let l = r.f64s()?;
    if n.checked_mul(n) != Some(l.len()) {
        return Err(SnapshotError::Corrupted("Cholesky factor shape mismatch".into()));
    }
    Ok(DenseCholesky { n, l })
}

/// The symbolic analysis stores its defining parts; the derived row map
/// and supernodal wave schedule are rebuilt by [`Symbolic::from_parts`]
/// in `O(nnz)` on load (data movement — not an `analyze` rerun).
fn w_symbolic(buf: &mut Vec<u8>, s: &Symbolic) {
    w_usize(buf, s.n);
    w_usizes(buf, &s.parent);
    w_usizes(buf, &s.col_ptr);
    w_usizes(buf, &s.row_idx);
    w_usize(buf, s.nnz_strict);
    w_usizes(buf, &s.schedule.snode_ptr);
}

fn r_symbolic(r: &mut Reader) -> Result<Arc<Symbolic>, SnapshotError> {
    let n = r.usize()?;
    let parent = r.usizes()?;
    let col_ptr = r.usizes()?;
    let row_idx = r.usizes()?;
    let nnz_strict = r.usize()?;
    let snode_ptr = r.usizes()?;
    // Everything `Symbolic::from_parts` (and the schedule rebuild it
    // drives) indexes with must be pre-validated — a corrupted file must
    // produce an error here, not an out-of-bounds panic there.
    let ok = parent.len() == n
        && parent.iter().enumerate().all(|(j, &p)| p == usize::MAX || (p > j && p < n))
        && n.checked_add(1) == Some(col_ptr.len())
        && col_ptr.first() == Some(&0)
        && col_ptr.windows(2).all(|w| w[0] <= w[1])
        && col_ptr.last() == Some(&row_idx.len())
        && row_idx.iter().all(|&i| i < n)
        && snode_ptr.first() == Some(&0)
        && snode_ptr.last() == Some(&n)
        && snode_ptr.windows(2).all(|w| w[0] < w[1]);
    if !ok {
        return Err(SnapshotError::Corrupted("malformed symbolic analysis".into()));
    }
    Ok(Arc::new(Symbolic::from_parts(n, parent, col_ptr, row_idx, nnz_strict, snode_ptr)))
}

fn w_factor(buf: &mut Vec<u8>, f: &LdlFactor) {
    w_f64s(buf, &f.l);
    w_f64s(buf, &f.d);
    w_f64(buf, f.jitter);
}

/// Numeric LDLᵀ values, realigned with an already-decoded symbolic
/// pattern — the factor is solve-ready as stored, nothing is refactored.
fn r_factor(r: &mut Reader, symbolic: Arc<Symbolic>) -> Result<LdlFactor, SnapshotError> {
    let l = r.f64s()?;
    let d = r.f64s()?;
    let jitter = r.f64()?;
    if l.len() != symbolic.row_idx.len() || d.len() != symbolic.n {
        return Err(SnapshotError::Corrupted("factor values misaligned with pattern".into()));
    }
    Ok(LdlFactor { symbolic, l, d, jitter })
}

fn w_report(buf: &mut Vec<u8>, rep: &FitReport) {
    w_f64(buf, rep.log_z);
    w_f64(buf, rep.log_post);
    w_usize(buf, rep.opt_iters);
    w_usize(buf, rep.fn_evals);
    w_f64(buf, rep.opt_time.as_secs_f64());
    w_f64(buf, rep.ep_time.as_secs_f64());
    w_f64(buf, rep.fill_k);
    w_f64(buf, rep.fill_l);
    w_bool(buf, rep.opt_converged);
}

fn r_report(r: &mut Reader) -> Result<FitReport, SnapshotError> {
    Ok(FitReport {
        log_z: r.f64()?,
        log_post: r.f64()?,
        opt_iters: r.usize()?,
        fn_evals: r.usize()?,
        opt_time: r.duration()?,
        ep_time: r.duration()?,
        fill_k: r.f64()?,
        fill_l: r.f64()?,
        opt_converged: r.bool()?,
    })
}

// ---------------------------------------------------------------------------
// Backend payloads
// ---------------------------------------------------------------------------

fn backend_tag(backend: &Backend) -> u8 {
    match backend {
        Backend::Dense(_) => TAG_DENSE,
        Backend::Sparse(_) => TAG_SPARSE,
        Backend::Parallel(_) => TAG_PARALLEL,
        Backend::Fic(_) => TAG_FIC,
        Backend::CsFic(_) => TAG_CSFIC,
    }
}

fn w_backend(buf: &mut Vec<u8>, backend: &Backend) {
    match backend {
        Backend::Dense(ep) => {
            w_sites(buf, &ep.sites);
            w_f64(buf, ep.log_z);
            w_f64s(buf, &ep.mu);
            w_f64s(buf, &ep.sigma_diag);
            w_usize(buf, ep.sweeps);
            w_bool(buf, ep.converged);
            w_f64s(buf, &ep.sw);
            w_chol(buf, &ep.chol_b);
            w_f64s(buf, &ep.w_pred);
        }
        Backend::Sparse(ep) => {
            w_usizes(buf, &ep.perm);
            w_points(buf, &ep.xp);
            w_csc(buf, &ep.k);
            w_symbolic(buf, &ep.symbolic);
            w_factor(buf, &ep.factor);
            w_sites(buf, &ep.sites);
            w_f64(buf, ep.log_z);
            w_f64s(buf, &ep.mu);
            w_f64s(buf, &ep.sigma_diag);
            w_f64s(buf, &ep.w_pred);
            w_usize(buf, ep.sweeps);
            w_bool(buf, ep.converged);
            w_f64(buf, ep.fill_k);
            w_f64(buf, ep.fill_l);
        }
        Backend::Parallel(ep) => {
            w_usizes(buf, &ep.perm);
            w_points(buf, &ep.xp);
            w_csc(buf, &ep.k);
            w_symbolic(buf, &ep.factor.symbolic);
            w_factor(buf, &ep.factor);
            w_sites(buf, &ep.sites);
            w_f64(buf, ep.log_z);
            w_f64s(buf, &ep.mu);
            w_f64s(buf, &ep.w_pred);
            w_usize(buf, ep.sweeps);
            w_bool(buf, ep.converged);
        }
        Backend::Fic(ep) => {
            let (u, luu, p_mean, g_var) = ep.saved_parts();
            w_points(buf, &ep.xu);
            w_sites(buf, &ep.sites);
            w_f64(buf, ep.log_z);
            w_f64s(buf, &ep.mu);
            w_f64s(buf, &ep.sigma_diag);
            w_usize(buf, ep.sweeps);
            w_bool(buf, ep.converged);
            w_dense(buf, u);
            w_chol(buf, luu);
            w_f64s(buf, p_mean);
            w_dense(buf, g_var);
        }
        Backend::CsFic(ep) => {
            let (luu, solver, p_mean, m2) = ep.saved_parts();
            w_usizes(buf, &ep.perm);
            w_points(buf, &ep.xp);
            w_cov(buf, &ep.cov.global);
            w_cov(buf, &ep.cov.cs);
            w_csc(buf, &ep.k_cs);
            w_f64s(buf, &ep.lambda);
            w_points(buf, &ep.xu);
            w_sites(buf, &ep.sites);
            w_f64(buf, ep.log_z);
            w_f64s(buf, &ep.mu);
            w_f64s(buf, &ep.sigma_diag);
            w_f64s(buf, &ep.w_pred);
            w_usize(buf, ep.sweeps);
            w_bool(buf, ep.converged);
            w_f64(buf, ep.fill_k);
            w_f64(buf, ep.fill_l);
            w_chol(buf, luu);
            // Woodbury solver: sparse factor + low-rank blocks, verbatim
            w_symbolic(buf, &solver.factor.symbolic);
            w_factor(buf, &solver.factor);
            w_dense(buf, &solver.u);
            w_dense(buf, &solver.w);
            w_dense(buf, &solver.m1);
            w_chol(buf, &solver.cap);
            w_f64s(buf, p_mean);
            w_dense(buf, m2);
        }
    }
}

/// `n` aligned vectors sanity check: every per-site vector of a backend
/// payload must agree with the site count.
fn check_n(n: usize, lens: &[usize]) -> Result<(), SnapshotError> {
    if lens.iter().any(|&l| l != n) {
        return Err(SnapshotError::Corrupted("per-site vectors disagree on n".into()));
    }
    Ok(())
}

fn r_backend(r: &mut Reader, tag: u8) -> Result<Backend, SnapshotError> {
    match tag {
        TAG_DENSE => {
            let sites = r_sites(r)?;
            let log_z = r.f64()?;
            let mu = r.f64s()?;
            let sigma_diag = r.f64s()?;
            let sweeps = r.usize()?;
            let converged = r.bool()?;
            let sw = r.f64s()?;
            let chol_b = r_chol(r)?;
            let w_pred = r.f64s()?;
            let n = sites.tau.len();
            check_n(n, &[mu.len(), sigma_diag.len(), sw.len(), chol_b.n, w_pred.len()])?;
            Ok(Backend::Dense(DenseEp {
                sites,
                log_z,
                mu,
                sigma_diag,
                sweeps,
                converged,
                sw,
                chol_b,
                w_pred,
            }))
        }
        TAG_SPARSE => {
            let perm = Arc::new(r.usizes()?);
            let xp = Arc::new(r.points()?);
            let k = r_csc(r)?;
            let symbolic = r_symbolic(r)?;
            let factor = r_factor(r, symbolic.clone())?;
            let sites = r_sites(r)?;
            let log_z = r.f64()?;
            let mu = r.f64s()?;
            let sigma_diag = r.f64s()?;
            let w_pred = r.f64s()?;
            let sweeps = r.usize()?;
            let converged = r.bool()?;
            let fill_k = r.f64()?;
            let fill_l = r.f64()?;
            let n = symbolic.n;
            check_n(
                n,
                &[
                    perm.len(),
                    xp.len(),
                    k.n_rows,
                    k.n_cols,
                    sites.tau.len(),
                    mu.len(),
                    sigma_diag.len(),
                    w_pred.len(),
                ],
            )?;
            Ok(Backend::Sparse(SparseEp {
                perm,
                xp,
                k,
                symbolic,
                factor,
                sites,
                log_z,
                mu,
                sigma_diag,
                w_pred,
                sweeps,
                converged,
                fill_k,
                fill_l,
            }))
        }
        TAG_PARALLEL => {
            let perm = Arc::new(r.usizes()?);
            let xp = Arc::new(r.points()?);
            let k = r_csc(r)?;
            let symbolic = r_symbolic(r)?;
            let factor = r_factor(r, symbolic)?;
            let sites = r_sites(r)?;
            let log_z = r.f64()?;
            let mu = r.f64s()?;
            let w_pred = r.f64s()?;
            let sweeps = r.usize()?;
            let converged = r.bool()?;
            let n = factor.symbolic.n;
            check_n(
                n,
                &[perm.len(), xp.len(), k.n_rows, k.n_cols, sites.tau.len(), mu.len(), w_pred.len()],
            )?;
            Ok(Backend::Parallel(ParallelEp {
                perm,
                xp,
                k,
                factor,
                sites,
                log_z,
                mu,
                sweeps,
                converged,
                w_pred,
            }))
        }
        TAG_FIC => {
            let xu = r.points()?;
            let sites = r_sites(r)?;
            let log_z = r.f64()?;
            let mu = r.f64s()?;
            let sigma_diag = r.f64s()?;
            let sweeps = r.usize()?;
            let converged = r.bool()?;
            let u = r_dense(r)?;
            let luu = r_chol(r)?;
            let p_mean = r.f64s()?;
            let g_var = r_dense(r)?;
            let n = sites.tau.len();
            let m = xu.len();
            check_n(n, &[mu.len(), sigma_diag.len(), u.n_rows])?;
            if u.n_cols != m || luu.n != m || p_mean.len() != m || g_var.n_rows != m {
                return Err(SnapshotError::Corrupted("FIC low-rank blocks disagree on m".into()));
            }
            Ok(Backend::Fic(FicEp::from_saved(
                xu, sites, log_z, mu, sigma_diag, sweeps, converged, u, luu, p_mean, g_var,
            )))
        }
        TAG_CSFIC => {
            let perm = Arc::new(r.usizes()?);
            let xp = Arc::new(r.points()?);
            let global = r_cov(r)?;
            let cs = r_cov(r)?;
            let cov = AdditiveCov::new(global, cs).map_err(SnapshotError::Corrupted)?;
            let k_cs = r_csc(r)?;
            let lambda = r.f64s()?;
            let xu = r.points()?;
            let sites = r_sites(r)?;
            let log_z = r.f64()?;
            let mu = r.f64s()?;
            let sigma_diag = r.f64s()?;
            let w_pred = r.f64s()?;
            let sweeps = r.usize()?;
            let converged = r.bool()?;
            let fill_k = r.f64()?;
            let fill_l = r.f64()?;
            let luu = r_chol(r)?;
            let symbolic = r_symbolic(r)?;
            let factor = r_factor(r, symbolic)?;
            let u = r_dense(r)?;
            let w = r_dense(r)?;
            let m1 = r_dense(r)?;
            let cap = r_chol(r)?;
            let p_mean = r.f64s()?;
            let m2 = r_dense(r)?;
            let n = factor.symbolic.n;
            let m = xu.len();
            check_n(
                n,
                &[
                    perm.len(),
                    xp.len(),
                    k_cs.n_rows,
                    k_cs.n_cols,
                    lambda.len(),
                    sites.tau.len(),
                    mu.len(),
                    sigma_diag.len(),
                    w_pred.len(),
                    u.n_rows,
                    w.n_rows,
                ],
            )?;
            let blocks_ok = luu.n == m
                && u.n_cols == m
                && w.n_cols == m
                && m1.n_rows == m
                && m1.n_cols == m
                && cap.n == m
                && p_mean.len() == m
                && m2.n_rows == m
                && m2.n_cols == m;
            if !blocks_ok {
                return Err(SnapshotError::Corrupted(
                    "CS+FIC low-rank blocks disagree on m".into(),
                ));
            }
            let solver = SparseLowRank { factor, u, w, m1, cap };
            Ok(Backend::CsFic(CsFicEp::from_saved(
                perm, xp, cov, k_cs, lambda, xu, sites, log_z, mu, sigma_diag, w_pred, sweeps,
                converged, fill_k, fill_l, luu, solver, p_mean, m2,
            )))
        }
        other => Err(SnapshotError::BadBackendTag(other)),
    }
}

// ---------------------------------------------------------------------------
// Container
// ---------------------------------------------------------------------------

/// Parse + verify the container: magic, version, tag, length, checksum.
/// Returns the backend tag and the checksum-verified payload slice.
fn parse_container(bytes: &[u8]) -> Result<(u8, &[u8]), SnapshotError> {
    if bytes.len() < MAGIC.len() || &bytes[..MAGIC.len()] != MAGIC {
        return Err(SnapshotError::BadMagic);
    }
    if bytes.len() < HEADER_LEN {
        return Err(SnapshotError::Truncated);
    }
    let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    if version != VERSION {
        return Err(SnapshotError::UnsupportedVersion(version));
    }
    let tag = bytes[12];
    tag_name(tag)?;
    let payload_len = u64::from_le_bytes(bytes[13..21].try_into().unwrap());
    let checksum = u64::from_le_bytes(bytes[21..29].try_into().unwrap());
    let body = &bytes[HEADER_LEN..];
    if (body.len() as u64) < payload_len {
        return Err(SnapshotError::Truncated);
    }
    if (body.len() as u64) > payload_len {
        return Err(SnapshotError::Corrupted("trailing bytes after payload".into()));
    }
    if fnv1a(body) != checksum {
        return Err(SnapshotError::ChecksumMismatch);
    }
    Ok((tag, body))
}

fn tmp_path(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    PathBuf::from(os)
}

/// Serialize `fitted` to `path`, atomically: the bytes land in a
/// `<path>.tmp` sibling first and are `rename`d into place only once
/// fully written and synced. On any failure — including an injected
/// `io@snapshot.save` fault — the temp file is removed and the
/// destination is left exactly as it was.
pub fn save(fitted: &FittedClassifier, path: &Path) -> Result<(), SnapshotError> {
    let mut payload = Vec::new();
    w_cov(&mut payload, &fitted.cov);
    w_points(&mut payload, &fitted.x);
    w_f64s(&mut payload, &fitted.y);
    w_report(&mut payload, &fitted.report);
    w_backend(&mut payload, &fitted.backend);

    let mut bytes = Vec::with_capacity(HEADER_LEN + payload.len());
    bytes.extend_from_slice(MAGIC);
    w_u32(&mut bytes, VERSION);
    bytes.push(backend_tag(&fitted.backend));
    w_u64(&mut bytes, payload.len() as u64);
    w_u64(&mut bytes, fnv1a(&payload));
    bytes.extend_from_slice(&payload);

    let tmp = tmp_path(path);
    let write_all = |bytes: &[u8]| -> std::io::Result<()> {
        let mut f = fs::File::create(&tmp)?;
        // An injected fault models a crash mid-write: half the bytes
        // land in the temp file and the operation errors out before the
        // publishing rename.
        if crate::fault::should_fail_io("snapshot.save") {
            f.write_all(&bytes[..bytes.len() / 2])?;
            return Err(std::io::Error::new(
                std::io::ErrorKind::Other,
                "injected snapshot.save fault",
            ));
        }
        f.write_all(bytes)?;
        f.sync_all()
    };
    if let Err(e) = write_all(&bytes) {
        let _ = fs::remove_file(&tmp);
        return Err(SnapshotError::Io(e.to_string()));
    }
    if let Err(e) = fs::rename(&tmp, path) {
        let _ = fs::remove_file(&tmp);
        return Err(SnapshotError::Io(e.to_string()));
    }
    crate::obs::counters::SNAPSHOT_SAVES.add(1);
    Ok(())
}

/// Load a snapshot into a predict-ready [`FittedClassifier`]. The stored
/// factors, permutations and posterior blocks are restored verbatim —
/// no symbolic analysis, no numeric factorization, no EP sweeps.
pub fn load(path: &Path) -> Result<FittedClassifier, SnapshotError> {
    let bytes = fs::read(path).map_err(|e| SnapshotError::Io(e.to_string()))?;
    let (tag, payload) = parse_container(&bytes)?;
    let mut r = Reader::new(payload);
    let cov = r_cov(&mut r)?;
    let x = r.points()?;
    let y = r.f64s()?;
    let report = r_report(&mut r)?;
    let backend = r_backend(&mut r, tag)?;
    if !r.is_empty() {
        return Err(SnapshotError::Corrupted("unread payload bytes".into()));
    }
    if x.len() != y.len() {
        return Err(SnapshotError::Corrupted("x/y length mismatch".into()));
    }
    crate::obs::counters::SNAPSHOT_LOADS.add(1);
    Ok(FittedClassifier { cov, x, y, backend, report })
}

/// Compatibility probe: validate the container (magic, version, backend
/// tag, length, checksum) without decoding the payload into model state.
pub fn probe(path: &Path) -> Result<SnapshotInfo, SnapshotError> {
    let bytes = fs::read(path).map_err(|e| SnapshotError::Io(e.to_string()))?;
    let (tag, payload) = parse_container(&bytes)?;
    Ok(SnapshotInfo {
        version: VERSION,
        backend: tag_name(tag)?,
        payload_len: payload.len() as u64,
    })
}

impl FittedClassifier {
    /// [`snapshot::save`](save) as a method.
    pub fn save_snapshot(&self, path: &Path) -> Result<(), SnapshotError> {
        save(self, path)
    }

    /// [`snapshot::load`](load) as a method.
    pub fn load_snapshot(path: &Path) -> Result<FittedClassifier, SnapshotError> {
        load(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::model::{GpClassifier, Inference};
    use crate::gp::covariance::{CovFunction, CovKind};
    use crate::sparse::ordering::Ordering;
    use crate::testutil::random_points;

    fn blob_data(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let x = random_points(n, 2, 6.0, seed);
        let y: Vec<f64> = x
            .iter()
            .map(|p| if (p[0] - 3.0).hypot(p[1] - 3.0) < 2.0 { 1.0 } else { -1.0 })
            .collect();
        (x, y)
    }

    fn tmp_file(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("csgp-snapshot-tests");
        fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}-{}.snap", std::process::id()))
    }

    fn fit_sparse(n: usize, seed: u64) -> FittedClassifier {
        let (x, y) = blob_data(n, seed);
        let cov = CovFunction::new(CovKind::Pp(3), 2, 0.8, 1.6);
        let model = GpClassifier::new(cov, Inference::Sparse(Ordering::Auto));
        model.infer_only(&x, &y).unwrap()
    }

    #[test]
    fn sparse_roundtrip_is_bitwise() {
        let fitted = fit_sparse(90, 5);
        let path = tmp_file("sparse-roundtrip");
        fitted.save_snapshot(&path).unwrap();
        let loaded = FittedClassifier::load_snapshot(&path).unwrap();
        let xs = random_points(25, 2, 6.0, 99);
        let want = fitted.predict_latent_batch(&xs);
        let got = loaded.predict_latent_batch(&xs);
        for (w, g) in want.iter().zip(&got) {
            assert_eq!(w.0.to_bits(), g.0.to_bits(), "mean must round-trip bitwise");
            assert_eq!(w.1.to_bits(), g.1.to_bits(), "variance must round-trip bitwise");
        }
        assert_eq!(fitted.report.log_z, loaded.report.log_z);
        fs::remove_file(&path).unwrap();
    }

    #[test]
    fn probe_reports_backend_without_decoding() {
        let fitted = fit_sparse(60, 7);
        let path = tmp_file("probe");
        fitted.save_snapshot(&path).unwrap();
        let info = probe(&path).unwrap();
        assert_eq!(info.version, VERSION);
        assert_eq!(info.backend, "sparse");
        assert!(info.payload_len > 0);
        fs::remove_file(&path).unwrap();
    }

    #[test]
    fn corrupted_truncated_and_foreign_files_yield_typed_errors() {
        let fitted = fit_sparse(60, 11);
        let path = tmp_file("corrupt");
        fitted.save_snapshot(&path).unwrap();
        let good = fs::read(&path).unwrap();

        // flip one payload byte -> checksum mismatch
        let mut bad = good.clone();
        let i = HEADER_LEN + bad[HEADER_LEN..].len() / 2;
        bad[i] ^= 0xff;
        fs::write(&path, &bad).unwrap();
        assert_eq!(load(&path).unwrap_err(), SnapshotError::ChecksumMismatch);

        // truncate -> Truncated
        fs::write(&path, &good[..good.len() / 2]).unwrap();
        assert_eq!(load(&path).unwrap_err(), SnapshotError::Truncated);

        // foreign file -> BadMagic
        fs::write(&path, b"definitely not a snapshot").unwrap();
        assert_eq!(load(&path).unwrap_err(), SnapshotError::BadMagic);

        // future version -> UnsupportedVersion
        let mut future = good.clone();
        future[8..12].copy_from_slice(&99u32.to_le_bytes());
        fs::write(&path, &future).unwrap();
        assert_eq!(load(&path).unwrap_err(), SnapshotError::UnsupportedVersion(99));

        // unknown backend tag -> BadBackendTag
        let mut tagged = good.clone();
        tagged[12] = 42;
        fs::write(&path, &tagged).unwrap();
        assert_eq!(load(&path).unwrap_err(), SnapshotError::BadBackendTag(42));

        fs::remove_file(&path).unwrap();
    }

    #[test]
    fn injected_save_fault_leaves_no_file_behind() {
        let fitted = fit_sparse(60, 13);
        let path = tmp_file("fault");
        let _ = fs::remove_file(&path);
        crate::fault::with_plan(crate::fault::Plan::new().io("snapshot.save"), || {
            let err = fitted.save_snapshot(&path).unwrap_err();
            assert!(matches!(err, SnapshotError::Io(_)), "got {err:?}");
        });
        assert!(!path.exists(), "failed save must not leave a destination file");
        assert!(!tmp_path(&path).exists(), "failed save must clean up its temp file");
        // the very next save (fault consumed) succeeds and is loadable
        fitted.save_snapshot(&path).unwrap();
        assert!(FittedClassifier::load_snapshot(&path).is_ok());
        fs::remove_file(&path).unwrap();
    }
}
