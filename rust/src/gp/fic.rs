//! FIC (fully independent conditional) sparse approximation with EP —
//! the paper's second baseline (Snelson & Ghahramani 2006;
//! Naish-Guzman & Holden 2008).
//!
//! Prior covariance `P = Λ + U Uᵀ` with `U = K_fu L_uu⁻ᵀ` (so `U Uᵀ = Q`)
//! and `Λ = diag(K_ff − diag(Q))`. All EP algebra runs through the
//! diagonal-plus-low-rank structure (Woodbury), giving `O(n m²)` per sweep.
//! Site updates are batched with damping (parallel-EP style), which is the
//! standard robust implementation of EP-FITC.
//!
//! Inducing inputs are chosen by k-means (see DESIGN.md §Substitutions:
//! the paper co-optimizes them, which it reports as slow and unstable;
//! k-means placement if anything *favours* FIC in the timing comparison).

use crate::gp::covariance::CovFunction;
use crate::gp::likelihood::SiteBatch;
use crate::gp::marginal::{ep_log_z, EpOptions, EpSites};
use crate::sparse::dense::{DenseCholesky, DenseMatrix};

/// Converged FIC-EP state.
pub struct FicEp {
    pub xu: Vec<Vec<f64>>,
    pub sites: EpSites,
    pub log_z: f64,
    pub mu: Vec<f64>,
    pub sigma_diag: Vec<f64>,
    pub sweeps: usize,
    pub converged: bool,
    /// U = K_fu L_uu⁻ᵀ (n×m).
    u: DenseMatrix,
    /// L_uu (Cholesky of K_uu + jitter).
    luu: DenseCholesky,
    /// m-vector: `p = Uᵀ w` with `w = (P+Σ̃)⁻¹ μ̃` — predictive mean weights.
    p_mean: Vec<f64>,
    /// m×m: `G = Uᵀ (P+Σ̃)⁻¹ U` — predictive variance correction.
    g_var: DenseMatrix,
}

/// Woodbury solver for `B = D₀ + Us Usᵀ` with diagonal `D₀`.
struct WoodburyB {
    d0: Vec<f64>,
    us: DenseMatrix,
    /// Cholesky of `I_m + Usᵀ D₀⁻¹ Us`.
    inner: DenseCholesky,
}

impl WoodburyB {
    fn new(d0: Vec<f64>, us: DenseMatrix) -> WoodburyB {
        let (n, m) = (us.n_rows, us.n_cols);
        let mut inner = DenseMatrix::identity(m);
        for a in 0..m {
            for b in 0..m {
                let mut s = 0.0;
                for i in 0..n {
                    s += us.at(i, a) * us.at(i, b) / d0[i];
                }
                *inner.at_mut(a, b) += s;
            }
        }
        let inner = inner.cholesky().expect("I + Usᵀ D₀⁻¹ Us must be PD");
        WoodburyB { d0, us, inner }
    }

    /// B⁻¹ v.
    fn solve(&self, v: &[f64]) -> Vec<f64> {
        let (n, m) = (self.us.n_rows, self.us.n_cols);
        let d0v: Vec<f64> = v.iter().zip(&self.d0).map(|(a, b)| a / b).collect();
        let mut rhs = vec![0.0; m];
        for a in 0..m {
            rhs[a] = (0..n).map(|i| self.us.at(i, a) * d0v[i]).sum();
        }
        let sol = self.inner.solve(&rhs);
        (0..n)
            .map(|i| {
                let corr: f64 = (0..m).map(|a| self.us.at(i, a) * sol[a]).sum();
                d0v[i] - corr / self.d0[i]
            })
            .collect()
    }

    /// log |B| = Σ log d₀ᵢ + log |inner|.
    fn logdet(&self) -> f64 {
        self.d0.iter().map(|d| d.ln()).sum::<f64>() + self.inner.logdet()
    }
}

impl FicEp {
    /// Run EP with the FIC prior. `xu` are the inducing inputs.
    pub fn run(
        cov: &CovFunction,
        x: &[Vec<f64>],
        y: &[f64],
        xu: &[Vec<f64>],
        opts: &EpOptions,
    ) -> Result<FicEp, String> {
        FicEp::run_warm(cov, x, y, xu, opts, None)
    }

    /// Like [`FicEp::run`], optionally warm-started from converged sites —
    /// the finite-difference gradient loop re-runs EP at slightly
    /// perturbed hyperparameters, where the old fixed point is one or two
    /// sweeps from the new one instead of `max_sweeps` from zero sites.
    pub fn run_warm(
        cov: &CovFunction,
        x: &[Vec<f64>],
        y: &[f64],
        xu: &[Vec<f64>],
        opts: &EpOptions,
        warm_start: Option<&EpSites>,
    ) -> Result<FicEp, String> {
        let n = x.len();
        let m = xu.len();
        assert!(m >= 1 && m <= n);
        let jitter = 1e-8 * cov.sigma2;

        // U = K_fu L_uu⁻ᵀ, Λ = diag(K_ff − diag(UUᵀ))
        let mut kuu = DenseMatrix::from_fn(m, m, |a, b| cov.kernel(&xu[a], &xu[b]));
        kuu.add_diag(jitter);
        let luu = kuu.cholesky().map_err(|e| format!("K_uu: {e}"))?;
        let kfu = DenseMatrix::from_fn(n, m, |i, a| cov.kernel(&x[i], &xu[a]));
        // U rows: solve L_uu u_iᵀ = k_fu,iᵀ
        let mut u = DenseMatrix::zeros(n, m);
        for i in 0..n {
            let sol = luu.solve_lower(kfu.row(i));
            for a in 0..m {
                *u.at_mut(i, a) = sol[a];
            }
        }
        let lambda: Vec<f64> = (0..n)
            .map(|i| {
                let q: f64 = (0..m).map(|a| u.at(i, a) * u.at(i, a)).sum();
                (cov.sigma2 - q).max(1e-10)
            })
            .collect();

        let mut sites = match warm_start {
            Some(w) => {
                assert_eq!(w.tau.len(), n, "warm-start sites must match n");
                w.clone()
            }
            None => EpSites::zeros(n),
        };
        let mut mu = vec![0.0; n];
        let mut sigma_diag = vec![0.0; n];
        let damping = opts.effective_damping(0.8);
        let mut log_z = f64::NEG_INFINITY;
        let mut log_z_old = f64::NEG_INFINITY;
        let mut sweeps = 0;
        let mut converged = false;
        // initial posterior refresh from the (possibly warm) sites; for
        // zero sites this reproduces the prior marginals exactly
        let mut wb = refresh_posterior(&lambda, &u, &sites, &mut mu, &mut sigma_diag);

        let mut batch = SiteBatch::new();
        while sweeps < opts.max_sweeps {
            // batched site updates: one transcendental pass per sweep
            batch.update(y, &mu, &sigma_diag, &sites.tau, &sites.nu);
            for i in 0..n {
                if !batch.valid[i] {
                    continue;
                }
                sites.ln_zhat[i] = batch.ln_zhat[i];
                sites.tau_cav[i] = batch.tau_cav[i];
                sites.nu_cav[i] = batch.nu_cav[i];
                sites.tau[i] = damping * batch.tau_new[i] + (1.0 - damping) * sites.tau[i];
                sites.nu[i] = damping * batch.nu_new[i] + (1.0 - damping) * sites.nu[i];
            }

            wb = refresh_posterior(&lambda, &u, &sites, &mut mu, &mut sigma_diag);
            sweeps += 1;
            let nu_dot_mu: f64 = sites.nu.iter().zip(&mu).map(|(a, b)| a * b).sum();
            log_z = ep_log_z(&sites, wb.logdet(), nu_dot_mu);
            if (log_z - log_z_old).abs() < opts.tol {
                converged = true;
                break;
            }
            log_z_old = log_z;
        }

        // predictive weights: w = ν̃ − S̃^{1/2} B⁻¹ S̃^{1/2} P ν̃
        let sw: Vec<f64> = sites.tau.iter().map(|&t| t.max(0.0).sqrt()).collect();
        let gamma = apply_p(&lambda, &u, &sites.nu);
        let swg: Vec<f64> = (0..n).map(|i| sw[i] * gamma[i]).collect();
        let bswg = wb.solve(&swg);
        let w: Vec<f64> = (0..n).map(|i| sites.nu[i] - sw[i] * bswg[i]).collect();
        let p_mean: Vec<f64> = (0..m).map(|a| (0..n).map(|i| u.at(i, a) * w[i]).sum()).collect();
        // G = (S̃^{1/2}U)ᵀ B⁻¹ (S̃^{1/2}U): m solves
        let mut g_var = DenseMatrix::zeros(m, m);
        for a in 0..m {
            let col: Vec<f64> = (0..n).map(|i| sw[i] * u.at(i, a)).collect();
            let bicol = wb.solve(&col);
            for b in 0..m {
                let mut s = 0.0;
                for i in 0..n {
                    s += sw[i] * u.at(i, b) * bicol[i];
                }
                *g_var.at_mut(b, a) = s;
            }
        }

        Ok(FicEp {
            xu: xu.to_vec(),
            sites,
            log_z,
            mu,
            sigma_diag,
            sweeps,
            converged,
            u,
            luu,
            p_mean,
            g_var,
        })
    }

    /// The private predictive blocks, for the snapshot writer
    /// (`gp::snapshot`): `(U, L_uu, p_mean, G)`.
    pub(crate) fn saved_parts(&self) -> (&DenseMatrix, &DenseCholesky, &[f64], &DenseMatrix) {
        (&self.u, &self.luu, &self.p_mean, &self.g_var)
    }

    /// Reassemble a converged state from snapshotted parts — every field
    /// is restored verbatim; no EP sweeps, no factorizations.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_saved(
        xu: Vec<Vec<f64>>,
        sites: EpSites,
        log_z: f64,
        mu: Vec<f64>,
        sigma_diag: Vec<f64>,
        sweeps: usize,
        converged: bool,
        u: DenseMatrix,
        luu: DenseCholesky,
        p_mean: Vec<f64>,
        g_var: DenseMatrix,
    ) -> FicEp {
        FicEp { xu, sites, log_z, mu, sigma_diag, sweeps, converged, u, luu, p_mean, g_var }
    }

    /// Latent predictive mean/variance at a test point.
    pub fn predict_latent(&self, cov: &CovFunction, xstar: &[f64]) -> (f64, f64) {
        let m = self.xu.len();
        let ksu: Vec<f64> = self.xu.iter().map(|xu| cov.kernel(xstar, xu)).collect();
        let ustar = self.luu.solve_lower(&ksu);
        let mean: f64 = ustar.iter().zip(&self.p_mean).map(|(a, b)| a * b).sum();
        let mut quad = 0.0;
        for a in 0..m {
            for b in 0..m {
                quad += ustar[a] * self.g_var.at(a, b) * ustar[b];
            }
        }
        let _ = &self.u;
        (mean, (cov.sigma2 - quad).max(1e-12))
    }
}

/// Posterior refresh from the current sites: rebuild the Woodbury factor
/// of `B = D₀ + Us Usᵀ` (D₀ = I + S̃Λ, Us = S̃^{1/2} U) and recompute
/// `μ = γ − P S̃^{1/2} B⁻¹ S̃^{1/2} γ` (γ = P ν̃) and the marginal
/// variances — all in `O(n m²)`.
///
/// Σᵢᵢ = Pᵢᵢ − aᵢᵀ B⁻¹ aᵢ, aᵢ = S̃^{1/2} P[:, i]. With P = Λ + UUᵀ the
/// diagonal works per-column structure:
///   colᵢ = sw_i λ_i e_i + Us uᵢᵀ (n-vector)
/// and B⁻¹ = D₀⁻¹ − D₀⁻¹ Us M⁻¹ Usᵀ D₀⁻¹ (M = inner), so the diag term is
/// colᵢᵀ D₀⁻¹ colᵢ − hᵢᵀ M⁻¹ hᵢ with hᵢ = Usᵀ D₀⁻¹ colᵢ;
/// colᵢᵀD₀⁻¹colᵢ = sw²λ²/d₀ᵢ + 2 swλ (Us uᵢᵀ)ᵢ/d₀ᵢ + uᵢ T uᵢᵀ with the
/// precomputed T = UsᵀD₀⁻¹Us (m×m), keeping the per-i work at O(m²).
fn refresh_posterior(
    lambda: &[f64],
    u: &DenseMatrix,
    sites: &EpSites,
    mu: &mut [f64],
    sigma_diag: &mut [f64],
) -> WoodburyB {
    let (n, m) = (u.n_rows, u.n_cols);
    let sw: Vec<f64> = sites.tau.iter().map(|&t| t.max(0.0).sqrt()).collect();
    let d0: Vec<f64> = (0..n).map(|i| 1.0 + sites.tau[i] * lambda[i]).collect();
    let us = DenseMatrix::from_fn(n, m, |i, a| sw[i] * u.at(i, a));
    let wb = WoodburyB::new(d0, us);

    // μ = γ − P S̃^{1/2} B⁻¹ S̃^{1/2} γ with γ = P ν̃
    let gamma = apply_p(lambda, u, &sites.nu);
    let swg: Vec<f64> = (0..n).map(|i| sw[i] * gamma[i]).collect();
    let bswg = wb.solve(&swg);
    let scaled: Vec<f64> = (0..n).map(|i| sw[i] * bswg[i]).collect();
    let pscaled = apply_p(lambda, u, &scaled);
    for i in 0..n {
        mu[i] = gamma[i] - pscaled[i];
    }

    let mut t_mat = DenseMatrix::zeros(m, m);
    for a in 0..m {
        for b in 0..m {
            let mut s = 0.0;
            for r in 0..n {
                s += wb.us.at(r, a) * wb.us.at(r, b) / wb.d0[r];
            }
            *t_mat.at_mut(a, b) = s;
        }
    }
    for i in 0..n {
        let swl = sw[i] * lambda[i];
        let ui: Vec<f64> = (0..m).map(|a| u.at(i, a)).collect();
        // q1 = colᵢᵀ D₀⁻¹ colᵢ
        let usui_i: f64 = (0..m).map(|a| wb.us.at(i, a) * ui[a]).sum();
        let mut q1 = swl * swl / wb.d0[i] + 2.0 * swl * usui_i / wb.d0[i];
        // Σ_r (Us uᵢᵀ)_r² / d₀_r = uᵢ T uᵢᵀ
        for a in 0..m {
            for b in 0..m {
                q1 += ui[a] * t_mat.at(a, b) * ui[b];
            }
        }
        // hᵢ = UsᵀD₀⁻¹colᵢ = swλ/d₀ᵢ · Usᵢ,: + T uᵢ
        let mut h = vec![0.0; m];
        for a in 0..m {
            h[a] = swl / wb.d0[i] * wb.us.at(i, a)
                + (0..m).map(|b| t_mat.at(a, b) * ui[b]).sum::<f64>();
        }
        let mih = wb.inner.solve(&h);
        let q2: f64 = h.iter().zip(&mih).map(|(a, b)| a * b).sum();
        let pii = lambda[i] + ui.iter().map(|v| v * v).sum::<f64>();
        sigma_diag[i] = (pii - (q1 - q2)).max(1e-12);
    }
    wb
}

/// v ↦ P v = Λv + U (Uᵀ v).
fn apply_p(lambda: &[f64], u: &DenseMatrix, v: &[f64]) -> Vec<f64> {
    let (n, m) = (u.n_rows, u.n_cols);
    let mut utv = vec![0.0; m];
    for a in 0..m {
        utv[a] = (0..n).map(|i| u.at(i, a) * v[i]).sum();
    }
    (0..n)
        .map(|i| lambda[i] * v[i] + (0..m).map(|a| u.at(i, a) * utv[a]).sum::<f64>())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::covariance::CovKind;
    use crate::gp::ep_dense::DenseEp;
    use crate::testutil::random_points;

    /// With m = n and X_u = X, FIC's prior equals the exact GP prior
    /// (Q = K, Λ = jitter-sized), so FIC-EP must match dense EP closely.
    #[test]
    fn full_inducing_set_matches_dense_ep() {
        let x = random_points(20, 2, 5.0, 31);
        let y: Vec<f64> = x.iter().map(|p| if p[0] > 2.5 { 1.0 } else { -1.0 }).collect();
        let cov = CovFunction::new(CovKind::Se, 2, 1.0, 1.5);
        let opts = EpOptions { max_sweeps: 400, tol: 1e-10, damping: 0.8, ..EpOptions::default() };
        let fic = FicEp::run(&cov, &x, &y, &x, &opts).unwrap();
        let de = DenseEp::run(&cov, &x, &y, &opts).unwrap();
        assert!(fic.converged);
        assert!(
            (fic.log_z - de.log_z).abs() < 1e-3,
            "logZ FIC {} vs dense {}",
            fic.log_z,
            de.log_z
        );
        for px in [vec![1.0, 1.0], vec![4.0, 3.0]] {
            let (mf, vf) = fic.predict_latent(&cov, &px);
            let (md, vd) = de.predict_latent(&cov, &x, &px);
            assert!((mf - md).abs() < 5e-3, "{mf} vs {md}");
            assert!((vf - vd).abs() < 5e-3, "{vf} vs {vd}");
        }
    }

    /// Warm-started re-runs (the finite-difference gradient path) must
    /// land on the same fixed point, in far fewer sweeps.
    #[test]
    fn warm_start_reaches_the_same_fixed_point_in_fewer_sweeps() {
        let x = random_points(50, 2, 6.0, 21);
        let y: Vec<f64> = x.iter().map(|p| if p[0] > 3.0 { 1.0 } else { -1.0 }).collect();
        let cov = CovFunction::new(CovKind::Se, 2, 1.0, 2.0);
        let mut xu = Vec::new();
        for a in 0..3 {
            for b in 0..3 {
                xu.push(vec![1.0 + 2.0 * a as f64, 1.0 + 2.0 * b as f64]);
            }
        }
        let opts = EpOptions { max_sweeps: 300, tol: 1e-9, damping: 0.8, ..EpOptions::default() };
        let cold = FicEp::run(&cov, &x, &y, &xu, &opts).unwrap();
        assert!(cold.converged);
        // same θ: the warm run must stop almost immediately at the same logZ
        let warm = FicEp::run_warm(&cov, &x, &y, &xu, &opts, Some(&cold.sites)).unwrap();
        assert!(warm.converged);
        assert!(warm.sweeps <= 3, "warm sweeps {}", warm.sweeps);
        assert!((warm.log_z - cold.log_z).abs() < 1e-7);
        // perturbed θ: a warm-started run still reaches the cold fixed point
        let mut c2 = cov.clone();
        let mut p = c2.params();
        p[0] += 1e-3;
        c2.set_params(&p);
        let warm2 = FicEp::run_warm(&c2, &x, &y, &xu, &opts, Some(&cold.sites)).unwrap();
        let cold2 = FicEp::run(&c2, &x, &y, &xu, &opts).unwrap();
        assert!((warm2.log_z - cold2.log_z).abs() < 1e-6);
        assert!(warm2.sweeps <= cold2.sweeps, "{} !<= {}", warm2.sweeps, cold2.sweeps);
    }

    #[test]
    fn few_inducing_points_still_converges_and_classifies() {
        let x = random_points(60, 2, 6.0, 41);
        let y: Vec<f64> =
            x.iter().map(|p| if p[0] + p[1] > 6.0 { 1.0 } else { -1.0 }).collect();
        let cov = CovFunction::new(CovKind::Se, 2, 1.0, 2.0);
        // inducing: a coarse grid
        let mut xu = Vec::new();
        for a in 0..3 {
            for b in 0..3 {
                xu.push(vec![1.0 + 2.0 * a as f64, 1.0 + 2.0 * b as f64]);
            }
        }
        let opts = EpOptions { max_sweeps: 300, tol: 1e-8, damping: 0.8, ..EpOptions::default() };
        let fic = FicEp::run(&cov, &x, &y, &xu, &opts).unwrap();
        assert!(fic.converged);
        let correct = x
            .iter()
            .zip(&y)
            .filter(|(xi, &yi)| {
                let (mf, _) = fic.predict_latent(&cov, xi);
                mf.signum() == yi
            })
            .count();
        assert!(correct as f64 / x.len() as f64 > 0.8, "train acc {correct}/60");
    }
}
