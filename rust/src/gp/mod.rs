//! Gaussian-process machinery: covariance functions, the probit
//! likelihood, EP inference (dense baseline, the paper's sparse algorithm,
//! a parallel-EP ablation, the FIC approximation, and the CS+FIC hybrid),
//! marginal likelihood with gradients, hyperpriors, prediction and exact
//! GP regression.

pub mod cache;
pub mod covariance;
pub mod csfic;
pub mod ep_dense;
pub mod ep_parallel;
pub mod ep_sparse;
pub mod fic;
pub mod likelihood;
pub mod marginal;
pub mod model;
pub mod online;
pub mod predict;
pub mod priors;
pub mod regression;
pub mod snapshot;

pub use cache::PatternCache;
pub use covariance::{AdditiveCov, CovFunction, CovKind};
pub use csfic::CsFicEp;
pub use ep_dense::DenseEp;
pub use ep_parallel::ParallelEp;
pub use ep_sparse::SparseEp;
pub use model::{FittedClassifier, GpClassifier, Inference};
pub use online::{UpdatePath, UpdateReport};
pub use predict::{LatentPredictor, PredictWorkspace};
pub use snapshot::SnapshotError;
