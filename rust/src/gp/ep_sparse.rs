//! Sparse EP — the paper's Algorithm 1.
//!
//! Works on the permuted, compactly-supported covariance `K` so that
//! `B = I + S̃^{1/2} K S̃^{1/2}` shares `K`'s (static) sparsity pattern.
//! Per site visit:
//!
//! * `a = S̃^{1/2} K[:, i]` (sparse),
//! * `t = B⁻¹ a` via the factor's sparse-RHS solve,
//! * marginal moments `σᵢ² = K_ii − aᵀt`, `μᵢ = γᵢ − tᵀ (S̃^{1/2} γ)`,
//! * probit site update,
//! * `ldlrowmodify` of the factor with the new column of `B`,
//! * `γ ← γ + K[:, i] Δν̃ᵢ`.
//!
//! No per-site allocation, no symbolic re-analysis: everything runs on the
//! pattern computed once by [`Symbolic::analyze`]. The factor is refreshed
//! by a full refactorization once per sweep to cap the drift of several
//! thousand row modifications — since the supernodal rewrite of
//! [`LdlFactor::refactor`] that sweep-end step fans out over the worker
//! pool on the `Symbolic`'s cached wave schedule (bitwise-identical to
//! the serial path at any width), so even this backend's per-sweep serial
//! work is just the sequential site visits themselves.

use std::sync::Arc;

use crate::gp::cache::PatternCache;
use crate::gp::covariance::CovFunction;
use crate::gp::likelihood::probit_site_update_fast;
use crate::gp::marginal::{ep_log_z, grad_quadratic_term, EpOptions, EpSites};
use crate::gp::predict::PredictWorkspace;
use crate::metrics::Metrics;
use crate::sparse::cholesky::LdlFactor;
use crate::sparse::csc::CscMatrix;
use crate::sparse::ordering::Ordering;
use crate::sparse::rowmod::RowModWorkspace;
use crate::sparse::symbolic::Symbolic;
use crate::sparse::triangular::SparseSolveWorkspace;

/// Converged sparse-EP state (everything stored in the *permuted* index
/// space; accessors translate back through `perm`).
pub struct SparseEp {
    /// old index -> permuted index (shared with the `PatternCache` plan).
    pub perm: Arc<Vec<usize>>,
    /// Permuted inputs (cross-covariances must be built against these;
    /// shared with the `PatternCache` plan).
    pub xp: Arc<Vec<Vec<f64>>>,
    /// Permuted covariance matrix.
    pub k: CscMatrix,
    pub symbolic: Arc<Symbolic>,
    pub factor: LdlFactor,
    /// Site state, permuted order.
    pub sites: EpSites,
    pub log_z: f64,
    /// Posterior mean (permuted).
    pub mu: Vec<f64>,
    /// Marginal variances recorded at the last visit (permuted).
    pub sigma_diag: Vec<f64>,
    /// Representer weights `ν̃ − S̃^{1/2} B⁻¹ S̃^{1/2} K ν̃` (permuted):
    /// predictive latent mean is `k*ᵀ w_pred`; also eq. (6)'s `b`.
    pub w_pred: Vec<f64>,
    pub sweeps: usize,
    pub converged: bool,
    /// fill statistics for the paper's tables
    pub fill_k: f64,
    pub fill_l: f64,
}

/// The structural inputs of one sparse-EP run: permutation, permuted
/// inputs, permuted covariance values and the symbolic analysis. Normally
/// built from a [`PatternCache`]; the online-update path
/// (`gp::online`) assembles one directly by extending a fitted model's
/// structure instead of re-running ordering + analysis.
pub struct SparsePlan {
    /// old index -> permuted index.
    pub perm: Arc<Vec<usize>>,
    /// Permuted inputs.
    pub xp: Arc<Vec<Vec<f64>>>,
    /// Permuted covariance values on the (possibly superset) pattern.
    pub k: CscMatrix,
    pub symbolic: Arc<Symbolic>,
}

impl SparsePlan {
    /// The plan [`SparseEp::run_cached`] uses: pattern / ordering /
    /// analysis from the cache, covariance values re-evaluated on it.
    pub fn from_cache(
        cov: &CovFunction,
        x: &[Vec<f64>],
        metrics: Option<&Metrics>,
        cache: &mut PatternCache,
    ) -> SparsePlan {
        let (_, plan) = cache.plan_for(cov, x);
        let k = match metrics {
            Some(m) => m.time("ep.cov_values", || {
                cov.cov_values_on_pattern(&plan.xp, &plan.pattern_perm)
            }),
            None => cov.cov_values_on_pattern(&plan.xp, &plan.pattern_perm),
        };
        SparsePlan {
            perm: plan.perm.clone(),
            xp: plan.xp.clone(),
            k,
            symbolic: plan.symbolic.clone(),
        }
    }
}

/// How a sparse-EP run initializes its site state and factor.
pub enum SparseInit<'a> {
    /// The τ̃ = 0 prior start (`B = I`).
    Cold,
    /// Warm start from converged sites given in the *original*
    /// (unpermuted) index order — the warm-start currency (see
    /// [`SparseEp::sites_unpermuted`]), so a warm start stays valid even
    /// when the plan's permutation differs from the run that produced the
    /// sites. Costs one upfront refactorization of `B` at the warm sites.
    Warm(&'a EpSites),
    /// Online extension: `sites` are already in this plan's *permuted*
    /// order — the old converged sites followed by fresh τ̃ = 0 sites at
    /// permuted indices `n_old..` — and `old_factor` is the old run's
    /// converged factor, embedded into the extended analysis by pure data
    /// movement ([`LdlFactor::embed`]; no refactorization). The first
    /// sweep visits only the appended sites, integrating the new data
    /// through the `ldl_row_modify` rank-one machinery; later sweeps
    /// revise every site as usual.
    Extend {
        sites: EpSites,
        old_factor: &'a LdlFactor,
        n_old: usize,
    },
}

impl SparseEp {
    /// Run sparse EP to convergence on `(x, y)` with a private, throwaway
    /// [`PatternCache`]. Optimizer loops should hold a cache and call
    /// [`SparseEp::run_cached`] so the neighbor queries, ordering and
    /// symbolic analysis amortize across evaluations.
    pub fn run(
        cov: &CovFunction,
        x: &[Vec<f64>],
        y: &[f64],
        ordering: Ordering,
        opts: &EpOptions,
        metrics: Option<&Metrics>,
    ) -> Result<SparseEp, String> {
        let mut cache = PatternCache::new(ordering);
        SparseEp::run_cached(cov, x, y, opts, metrics, &mut cache)
    }

    /// Run sparse EP reusing `cache`'s structure (pattern, permutation,
    /// symbolic analysis) whenever the support ellipsoid allows. A cache hit
    /// skips the neighbor queries, the fill-reducing ordering and
    /// `Symbolic::analyze` entirely; values are re-evaluated on the cached
    /// pattern, which reproduces the uncached fixed point exactly (the
    /// superset-only entries are exact zeros).
    pub fn run_cached(
        cov: &CovFunction,
        x: &[Vec<f64>],
        y: &[f64],
        opts: &EpOptions,
        metrics: Option<&Metrics>,
        cache: &mut PatternCache,
    ) -> Result<SparseEp, String> {
        let plan = SparsePlan::from_cache(cov, x, metrics, cache);
        SparseEp::run_with_init(plan, y, opts, metrics, SparseInit::Cold)
    }

    /// Accessor for warm starts and snapshots: the converged sites in the
    /// *original* index order, so they stay meaningful when the next run
    /// (or a serving replica) resolves a different permutation.
    pub fn sites_unpermuted(&self) -> EpSites {
        self.sites.unpermuted(&self.perm)
    }

    /// Run sparse EP on a prebuilt [`SparsePlan`] with an explicit
    /// [`SparseInit`]. This is the core loop: `run`/`run_cached` call it
    /// with [`SparseInit::Cold`] (bitwise-identical to the historical
    /// path), the online-update layer calls it with
    /// [`SparseInit::Extend`], and snapshot replicas with a foreign
    /// ordering call it with [`SparseInit::Warm`].
    pub fn run_with_init(
        plan: SparsePlan,
        y: &[f64],
        opts: &EpOptions,
        metrics: Option<&Metrics>,
        init: SparseInit,
    ) -> Result<SparseEp, String> {
        let SparsePlan { perm, xp, k, symbolic } = plan;
        let n = k.n_rows;
        assert_eq!(y.len(), n);
        let mut yp = vec![0.0; n];
        for old in 0..n {
            yp[perm[old]] = y[old];
        }
        let fill_k = k.density();
        let fill_l = symbolic.fill_l();
        let jitter = opts.jitter_policy();

        // Initial factor / sites / first-sweep window. The cold path keeps
        // its exact historical state (B = I at τ̃ = 0); warm starts pay one
        // refactorization at the warm sites; extend embeds the old factor
        // without any numeric work and sweeps only the appended tail first.
        let (mut factor, mut sites, mut visit_from) = match init {
            SparseInit::Cold => {
                (LdlFactor::identity(symbolic.clone()), EpSites::zeros(n), 0usize)
            }
            SparseInit::Warm(warm) => {
                assert_eq!(warm.len(), n, "warm sites must match n");
                let sites = warm.permuted(&perm);
                let mut factor = LdlFactor::identity(symbolic.clone());
                let b = build_b(&k, &sites.tau);
                factor.refactor_with_recovery(&b, &jitter)?;
                (factor, sites, 0usize)
            }
            SparseInit::Extend { sites, old_factor, n_old } => {
                assert_eq!(sites.len(), n, "extended sites must match n");
                assert!(n_old <= n);
                let factor = LdlFactor::embed(old_factor, symbolic.clone());
                (factor, sites, n_old)
            }
        };
        // γ = K ν̃ and the cached scalings, consistent with whatever sites
        // we start from (all-zero for the cold path, matching its old
        // explicit zero init bitwise).
        let mut gamma = k.matvec(&sites.nu);
        let mut sw: Vec<f64> = sites.tau.iter().map(|&t| t.max(0.0).sqrt()).collect();
        let mut swg: Vec<f64> = (0..n).map(|i| sw[i] * gamma[i]).collect();
        let mut t = vec![0.0; n];
        let mut solve_ws = SparseSolveWorkspace::new(n);
        let mut rowmod_ws = RowModWorkspace::new(n);
        let mut a_vals: Vec<f64> = Vec::with_capacity(n);
        let mut b_vals: Vec<f64> = Vec::with_capacity(n);
        let mut sigma_diag = vec![0.0; n];
        let mut mu_rec = vec![0.0; n];

        let mut log_z = f64::NEG_INFINITY;
        let mut log_z_old = f64::NEG_INFINITY;
        let mut sweeps = 0;
        let mut converged = false;

        // Recovery state: the working damping starts at the configured
        // value and halves on every rollback; the snapshot is the site
        // state at the end of the last healthy sweep (the τ̃ = 0 start is
        // trivially healthy).
        let mut damping = opts.effective_damping(1.0);
        let mut monitor = crate::gp::marginal::DivergenceMonitor::new();
        let mut recoveries = 0usize;
        let mut snap_sites = sites.clone();
        let mut snap_gamma = gamma.clone();
        let mut snap_log_z = log_z;

        while sweeps < opts.max_sweeps {
            // Per-sweep telemetry only (the per-site path is too hot for
            // spans — its whole obs footprint is the gated counter inside
            // `solve_sparse_rhs`); everything tracked here is observed
            // from values the sweep computes anyway.
            let track = crate::obs::counters_on();
            let mut sweep_span = crate::obs::span("ep.sweep");
            let mut max_site_delta = 0.0f64;
            let mut updated = 0u64;
            let mut skipped = 0u64;
            let visited = (n - visit_from) as u64;
            for i in visit_from..n {
                let (krows, kvals) = k.col(i);
                // a = S̃^{1/2} K[:, i]
                a_vals.clear();
                a_vals.extend(krows.iter().zip(kvals).map(|(&r, &v)| sw[r] * v));
                // t = B⁻¹ a
                match metrics {
                    Some(m) => m.time("ep.solve_t", || {
                        factor.solve_sparse_rhs(krows, &a_vals, &mut solve_ws, &mut t)
                    }),
                    None => factor.solve_sparse_rhs(krows, &a_vals, &mut solve_ws, &mut t),
                }
                // marginal moments
                let kii = k.get(i, i);
                let a_dot_t: f64 = krows.iter().zip(&a_vals).map(|(&r, &v)| v * t[r]).sum();
                let sigma2_i = kii - a_dot_t;
                let t_dot_swg: f64 = solve_ws.written.iter().map(|&r| t[r] * swg[r]).sum();
                let mu_i = gamma[i] - t_dot_swg;
                // re-zero only the entries the solve actually wrote —
                // O(nnz(t)) instead of an O(n) sweep per site visit
                solve_ws.clear_solution(&mut t);
                sigma_diag[i] = sigma2_i;
                mu_rec[i] = mu_i;
                if sigma2_i <= 0.0 {
                    return Err(format!("negative marginal variance at site {i}: {sigma2_i}"));
                }

                // probit site update (Cody-kernel fast path — the
                // sequential sweep calls this once per site visit)
                let Some((lz, tc, nc, mut tn, mut nn)) =
                    probit_site_update_fast(yp[i], mu_i, sigma2_i, sites.tau[i], sites.nu[i])
                else {
                    continue;
                };
                if crate::fault::should_poison_site(sweeps, i) {
                    tn = f64::NAN;
                }
                if damping < 1.0 {
                    tn = damping * tn + (1.0 - damping) * sites.tau[i];
                    nn = damping * nn + (1.0 - damping) * sites.nu[i];
                }
                // Per-site recovery guard: a non-finite or negative site
                // precision would corrupt the factor through the row
                // modification below, so the visit is skipped (the site
                // keeps its last value) and the sweep-end rollback repairs
                // the trajectory. Probit site precisions are positive, so
                // clean runs never take this branch.
                if !tn.is_finite() || !nn.is_finite() || tn < 0.0 {
                    crate::obs::counters::EP_SKIPPED_SITES.add(1);
                    skipped += 1;
                    continue;
                }
                let dnu = nn - sites.nu[i];
                // max_site_delta feeds the divergence monitor, so it is
                // tracked unconditionally (not gated on trace mode).
                let delta = (tn - sites.tau[i]).abs().max(dnu.abs());
                max_site_delta = max_site_delta.max(delta);
                if track && damping < 1.0 {
                    updated += 1;
                }
                sites.ln_zhat[i] = lz;
                sites.tau_cav[i] = tc;
                sites.nu_cav[i] = nc;
                sites.tau[i] = tn;
                sites.nu[i] = nn;

                // new column i of B: δ_ri + sqrt(τ̃_r) sqrt(τ̃_i) K[r, i]
                let sti = tn.max(0.0).sqrt();
                sw[i] = sti;
                swg[i] = sti * gamma[i];
                b_vals.clear();
                b_vals.extend(krows.iter().zip(kvals).map(|(&r, &v)| {
                    let base = sw[r] * sti * v;
                    if r == i {
                        1.0 + base
                    } else {
                        base
                    }
                }));
                let rowmod = match metrics {
                    Some(m) => m.time("ep.rowmod", || {
                        factor.ldl_row_modify(i, krows, &b_vals, &mut rowmod_ws)
                    }),
                    None => factor.ldl_row_modify(i, krows, &b_vals, &mut rowmod_ws),
                };
                if rowmod.is_err() {
                    // A failed row modification leaves the factor partially
                    // mutated (see the recovery contract in
                    // `sparse::rowmod`), so retrying it in place is not an
                    // option: rebuild B from the current sites and refactor
                    // with pivot recovery.
                    let b = build_b(&k, &sites.tau);
                    factor.refactor_with_recovery(&b, &jitter)?;
                }
                // γ += K[:, i] Δν̃ᵢ (and the cached sw ⊙ γ alongside)
                for (&r, &v) in krows.iter().zip(kvals) {
                    gamma[r] += v * dnu;
                    swg[r] = sw[r] * gamma[r];
                }
                if let Some(m) = metrics {
                    m.incr("ep.sites", 1);
                }
            }
            sweeps += 1;
            // Only the very first sweep of an Extend init is partial (it
            // integrates just the appended sites); every later sweep —
            // including the convergence-confirming one and any rollback
            // retry — revises all sites.
            visit_from = 0;

            // sweep-end: refactor B from scratch (cheap, O(sparse chol),
            // with pivot recovery) and evaluate log Z_EP
            let b = build_b(&k, &sites.tau);
            factor.refactor_with_recovery(&b, &jitter)?;
            let mu = posterior_mean(&k, &factor, &sites, &gamma, &mut solve_ws);
            let nu_dot_mu: f64 = sites.nu.iter().zip(&mu).map(|(a, b)| a * b).sum();
            log_z = ep_log_z(&sites, factor.logdet(), nu_dot_mu);
            let diverged = skipped > 0 || monitor.diverged(log_z, max_site_delta, opts);
            if track {
                crate::obs::counters::EP_SWEEPS.add(1);
                crate::obs::counters::EP_SITE_VISITS.add(visited);
                crate::obs::counters::EP_DAMPED_UPDATES.add(updated);
            }
            if sweep_span.is_active() {
                sweep_span.field_str("backend", "sparse");
                sweep_span.field_u64("sweep", sweeps as u64);
                sweep_span.field_f64("logz", log_z);
                sweep_span.field_f64("dlogz", log_z - log_z_old);
                sweep_span.field_f64("max_site_delta", max_site_delta);
                sweep_span.field_u64("damped_updates", updated);
                sweep_span.field_f64("damping", damping);
                sweep_span.field_u64("skipped_sites", skipped);
                sweep_span.field_bool("rolled_back", diverged);
            }
            if diverged {
                // Roll back to the last-good snapshot and halve the
                // damping before trying again. The sweep ordinal keeps
                // advancing across rollbacks, so a one-shot injected fault
                // is not re-hit on the retry.
                if recoveries >= opts.max_recoveries {
                    return Err(format!(
                        "EP diverged at sweep {sweeps} with the recovery budget \
                         ({}) exhausted",
                        opts.max_recoveries
                    ));
                }
                recoveries += 1;
                crate::obs::counters::EP_ROLLBACKS.add(1);
                damping = (0.5 * damping).max(opts.min_damping);
                sites.clone_from(&snap_sites);
                gamma.clone_from(&snap_gamma);
                for j in 0..n {
                    sw[j] = sites.tau[j].max(0.0).sqrt();
                    swg[j] = sw[j] * gamma[j];
                }
                let b = build_b(&k, &sites.tau);
                factor.refactor_with_recovery(&b, &jitter)?;
                log_z = snap_log_z;
                continue;
            }
            snap_sites.clone_from(&sites);
            snap_gamma.clone_from(&gamma);
            snap_log_z = log_z;
            if (log_z - log_z_old).abs() < opts.tol {
                converged = true;
                mu_rec = mu;
                break;
            }
            mu_rec = mu;
            log_z_old = log_z;
        }

        // representer weights for prediction / gradients
        let w_pred = representer_weights(&k, &factor, &sites, &gamma);

        Ok(SparseEp {
            perm,
            xp,
            k,
            symbolic,
            factor,
            sites,
            log_z,
            mu: mu_rec,
            sigma_diag,
            w_pred,
            sweeps,
            converged,
            fill_k,
            fill_l,
        })
    }

    /// Gradient of `log Z_EP` w.r.t. the covariance log-parameters using
    /// the Takahashi sparsified inverse for the trace term (paper eq. 11).
    ///
    /// The gradient values are evaluated directly on the pattern the EP
    /// run factored (`self.k`), so pattern agreement is structural — no
    /// covariance re-assembly, no re-ordering, no chance of a `col_ptr`
    /// mismatch between the run and its gradient. Allocates the Takahashi
    /// buffers fresh; optimizer loops should call
    /// [`SparseEp::log_z_grad_cached`] with their cache's scratch.
    pub fn log_z_grad(&self, cov: &CovFunction) -> Vec<f64> {
        let mut zsp = crate::sparse::takahashi::SparseInverse::default();
        self.factor.takahashi_inverse_into(&mut zsp);
        self.log_z_grad_with(cov, &zsp)
    }

    /// [`SparseEp::log_z_grad`] reusing the optimizer cache's
    /// [`GradScratch`](crate::gp::cache::GradScratch): while the
    /// `PatternCache` hits (only site parameters / covariance values
    /// changed), the `O(nnz(L))` Takahashi buffers are recycled across
    /// SCG steps instead of reallocated per gradient evaluation.
    pub fn log_z_grad_cached(
        &self,
        cov: &CovFunction,
        scratch: &mut crate::gp::cache::GradScratch,
    ) -> Vec<f64> {
        self.factor.takahashi_inverse_into(&mut scratch.takahashi);
        self.log_z_grad_with(cov, &scratch.takahashi)
    }

    fn log_z_grad_with(
        &self,
        cov: &CovFunction,
        zsp: &crate::sparse::takahashi::SparseInverse,
    ) -> Vec<f64> {
        let kmat = &self.k;
        let grads = cov.cov_grads_on_pattern(&self.xp, kmat);
        let mut out = grad_quadratic_term(kmat, &grads, &self.w_pred);
        // trace term via Z^sp: paper-Z_ij = sqrt(τ̃_i) Binv_ij sqrt(τ̃_j)
        let sym = &self.symbolic;
        let sw: Vec<f64> = self.sites.tau.iter().map(|&t| t.max(0.0).sqrt()).collect();
        for j in 0..kmat.n_cols {
            for p in kmat.col_ptr[j]..kmat.col_ptr[j + 1] {
                let i = kmat.row_idx[p];
                let binv_ij = zsp
                    .get(sym, i, j)
                    .expect("K pattern must be inside the L+Lᵀ pattern");
                let zij = sw[i] * binv_ij * sw[j];
                for (g, o) in grads.iter().zip(out.iter_mut()) {
                    *o -= 0.5 * zij * g[p];
                }
            }
        }
        out
    }

    /// Latent predictive mean and variance at a test point (original,
    /// unpermuted coordinates — cross covariance is built against `xp`).
    ///
    /// Allocates a fresh workspace per call; batch callers should build
    /// one [`PredictWorkspace`] with [`SparseEp::predict_workspace`] and
    /// use [`SparseEp::predict_latent_with`] /
    /// [`SparseEp::predict_latent_batch`].
    pub fn predict_latent(&self, cov: &CovFunction, xstar: &[f64]) -> (f64, f64) {
        let mut pws = PredictWorkspace::one_shot(self.k.n_rows);
        self.predict_latent_with(cov, xstar, &mut pws)
    }

    /// Workspace for repeated predictions against this EP state: one
    /// neighbor index over the (permuted) inputs plus one sparse-solve
    /// scratch, reused across every test point.
    pub fn predict_workspace(&self, cov: &CovFunction) -> PredictWorkspace {
        PredictWorkspace::new(cov, &self.xp)
    }

    /// Latent prediction reusing `pws` — no per-call allocation, and the
    /// cross-covariance runs through the workspace's neighbor index
    /// (`O(k)` instead of `O(n)` per test point for compact kernels).
    pub fn predict_latent_with(
        &self,
        cov: &CovFunction,
        xstar: &[f64],
        pws: &mut PredictWorkspace,
    ) -> (f64, f64) {
        crate::gp::predict::sparse_latent_with(
            cov,
            &self.xp,
            &self.factor,
            &self.sites.tau,
            &self.w_pred,
            xstar,
            pws,
        )
    }

    /// Batched latent predictions fanned out over the worker pool: one
    /// neighbor index is built once and shared (`Arc`) by every worker's
    /// forked workspace; each test point is an independent task, so the
    /// results equal the per-point path bitwise.
    pub fn predict_latent_batch(&self, cov: &CovFunction, xs: &[Vec<f64>]) -> Vec<(f64, f64)> {
        let proto = self.predict_workspace(cov);
        crate::gp::predict::batch_with_forks(&proto, xs.len(), |pws, i| {
            self.predict_latent_with(cov, &xs[i], pws)
        })
    }
}

/// Assemble B = I + S̃^{1/2} K S̃^{1/2} on K's pattern.
pub fn build_b(k: &CscMatrix, tau: &[f64]) -> CscMatrix {
    let mut b = k.clone();
    for j in 0..b.n_cols {
        let stj = tau[j].max(0.0).sqrt();
        for p in b.col_ptr[j]..b.col_ptr[j + 1] {
            let i = b.row_idx[p];
            let sti = tau[i].max(0.0).sqrt();
            b.values[p] = sti * stj * b.values[p] + if i == j { 1.0 } else { 0.0 };
        }
    }
    b
}

/// μ = γ − K S̃^{1/2} B⁻¹ S̃^{1/2} γ.
fn posterior_mean(
    k: &CscMatrix,
    factor: &LdlFactor,
    sites: &EpSites,
    gamma: &[f64],
    _ws: &mut SparseSolveWorkspace,
) -> Vec<f64> {
    let n = k.n_rows;
    let mut swg: Vec<f64> = (0..n).map(|i| sites.tau[i].max(0.0).sqrt() * gamma[i]).collect();
    factor.solve_in_place(&mut swg);
    let scaled: Vec<f64> = (0..n).map(|i| sites.tau[i].max(0.0).sqrt() * swg[i]).collect();
    let kv = k.matvec(&scaled);
    (0..n).map(|i| gamma[i] - kv[i]).collect()
}

/// w = ν̃ − S̃^{1/2} B⁻¹ S̃^{1/2} γ (γ = K ν̃).
fn representer_weights(
    k: &CscMatrix,
    factor: &LdlFactor,
    sites: &EpSites,
    gamma: &[f64],
) -> Vec<f64> {
    let n = k.n_rows;
    let mut swg: Vec<f64> = (0..n).map(|i| sites.tau[i].max(0.0).sqrt() * gamma[i]).collect();
    factor.solve_in_place(&mut swg);
    (0..n).map(|i| sites.nu[i] - sites.tau[i].max(0.0).sqrt() * swg[i]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::covariance::CovKind;
    use crate::gp::ep_dense::DenseEp;
    use crate::testutil::{assert_close, random_points};

    fn toy(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let x = random_points(n, 2, 6.0, seed);
        let y: Vec<f64> = x
            .iter()
            .map(|p| if (p[0] - 3.0).hypot(p[1] - 3.0) < 2.2 { 1.0 } else { -1.0 })
            .collect();
        (x, y)
    }

    fn tight() -> EpOptions {
        EpOptions { max_sweeps: 200, tol: 1e-11, damping: 1.0, ..EpOptions::default() }
    }

    /// The central correctness test: sparse EP and dense EP compute the
    /// same fixed point (same logZ, sites, predictions).
    #[test]
    fn agrees_with_dense_ep_cs_covariance() {
        for seed in [1u64, 5] {
            let (x, y) = toy(30, seed);
            let cov = CovFunction::new(CovKind::Pp(3), 2, 1.1, 2.0);
            let de = DenseEp::run(&cov, &x, &y, &tight()).unwrap();
            for ordering in [Ordering::Natural, Ordering::Rcm, Ordering::MinDegree] {
                let se = SparseEp::run(&cov, &x, &y, ordering, &tight(), None).unwrap();
                assert!(se.converged);
                assert!(
                    (se.log_z - de.log_z).abs() < 1e-6,
                    "seed {seed} {ordering:?}: logZ {} vs {}",
                    se.log_z,
                    de.log_z
                );
                // sites agree after unpermuting
                let mut tau_unperm = vec![0.0; x.len()];
                for old in 0..x.len() {
                    tau_unperm[old] = se.sites.tau[se.perm[old]];
                }
                assert_close(&tau_unperm, &de.sites.tau, 1e-5, "tau sites");
                // predictions agree at fresh points
                for px in [vec![1.0, 1.0], vec![3.0, 3.0], vec![5.0, 2.0]] {
                    let (ms, vs) = se.predict_latent(&cov, &px);
                    let (md, vd) = de.predict_latent(&cov, &x, &px);
                    assert!((ms - md).abs() < 1e-5, "pred mean {ms} vs {md}");
                    assert!((vs - vd).abs() < 1e-5, "pred var {vs} vs {vd}");
                }
            }
        }
    }

    /// Dense-pattern cross-check: with a length-scale so large the CS
    /// matrix is full, sparse EP must still match dense EP.
    #[test]
    fn agrees_with_dense_ep_full_pattern() {
        let (x, y) = toy(20, 9);
        let cov = CovFunction::new(CovKind::Pp(2), 2, 1.0, 50.0);
        let de = DenseEp::run(&cov, &x, &y, &tight()).unwrap();
        let se = SparseEp::run(&cov, &x, &y, Ordering::Natural, &tight(), None).unwrap();
        assert!((se.fill_k - 1.0).abs() < 1e-12, "pattern should be full");
        assert!((se.log_z - de.log_z).abs() < 1e-6);
    }

    /// A `PatternCache` hit (superset pattern reuse after a shrinking
    /// length-scale / σ²-only step) and a miss (grown support) must both
    /// reproduce the fixed point of an uncached run.
    #[test]
    fn pattern_cache_hit_and_miss_reproduce_uncached_fixed_point() {
        let (x, y) = toy(70, 5);
        let big = CovFunction::new(CovKind::Pp(3), 2, 1.1, 2.4);
        let mut small = big.clone();
        small.sigma2 = 1.45; // σ² step
        small.lengthscales = vec![1.5, 1.5]; // shrinking support
        let mut grown = big.clone();
        grown.lengthscales = vec![2.9, 2.9];

        let mut cache = crate::gp::cache::PatternCache::new(Ordering::Rcm);
        // miss: first evaluation
        let run_big = SparseEp::run_cached(&big, &x, &y, &tight(), None, &mut cache).unwrap();
        // hit: superset reuse
        let run_small = SparseEp::run_cached(&small, &x, &y, &tight(), None, &mut cache).unwrap();
        assert_eq!((cache.hits, cache.misses), (1, 1));
        // miss again: support grew
        let run_grown = SparseEp::run_cached(&grown, &x, &y, &tight(), None, &mut cache).unwrap();
        assert_eq!((cache.hits, cache.misses), (1, 2));

        for (cached, cov) in [(&run_big, &big), (&run_small, &small), (&run_grown, &grown)] {
            let fresh = SparseEp::run(cov, &x, &y, Ordering::Rcm, &tight(), None).unwrap();
            assert!(cached.converged && fresh.converged);
            assert!(
                (cached.log_z - fresh.log_z).abs() < 1e-7,
                "logZ {} vs {}",
                cached.log_z,
                fresh.log_z
            );
            // sites agree in the original (unpermuted) index space even
            // though the superset run may use a different permutation
            for old in 0..x.len() {
                let a = cached.sites.tau[cached.perm[old]];
                let b = fresh.sites.tau[fresh.perm[old]];
                assert!((a - b).abs() < 1e-6, "site {old}: {a} vs {b}");
            }
            for px in [vec![1.5, 2.0], vec![3.0, 3.0], vec![4.5, 1.0]] {
                let (mc, vc) = cached.predict_latent(cov, &px);
                let (mf, vf) = fresh.predict_latent(cov, &px);
                assert!((mc - mf).abs() < 1e-6 && (vc - vf).abs() < 1e-6);
            }
            // gradients also run on the (possibly superset) stored pattern
            let gc = cached.log_z_grad(cov);
            let gf = fresh.log_z_grad(cov);
            for (a, b) in gc.iter().zip(&gf) {
                assert!((a - b).abs() < 1e-6 * (1.0 + a.abs()), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let (x, y) = toy(18, 3);
        let mut cov = CovFunction::new(CovKind::Pp(3), 2, 1.3, 2.5);
        let se = SparseEp::run(&cov, &x, &y, Ordering::Rcm, &tight(), None).unwrap();
        let grad = se.log_z_grad(&cov);
        let p0 = cov.params();
        for p in 0..cov.n_params() {
            let h = 1e-5;
            let mut pp = p0.clone();
            pp[p] += h;
            cov.set_params(&pp);
            // NB: pattern changes with length-scale are second-order here
            let zp = SparseEp::run(&cov, &x, &y, Ordering::Rcm, &tight(), None).unwrap().log_z;
            pp[p] -= 2.0 * h;
            cov.set_params(&pp);
            let zm = SparseEp::run(&cov, &x, &y, Ordering::Rcm, &tight(), None).unwrap().log_z;
            cov.set_params(&p0);
            let fd = (zp - zm) / (2.0 * h);
            assert!(
                (fd - grad[p]).abs() < 5e-4 * (1.0 + grad[p].abs()),
                "param {p}: fd={fd} analytic={}",
                grad[p]
            );
        }
    }

    #[test]
    fn fill_statistics_are_sane() {
        let (x, y) = toy(60, 11);
        let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.5);
        let se = SparseEp::run(&cov, &x, &y, Ordering::Rcm, &EpOptions::default(), None).unwrap();
        assert!(se.fill_k > 0.0 && se.fill_k < 0.7, "fill-K = {}", se.fill_k);
        assert!(se.fill_l >= se.fill_k * 0.3 && se.fill_l <= 1.0, "fill-L = {}", se.fill_l);
    }

    #[test]
    fn metrics_are_recorded() {
        let (x, y) = toy(20, 13);
        let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 2.0);
        let m = crate::metrics::Metrics::new();
        let _ =
            SparseEp::run(&cov, &x, &y, Ordering::Rcm, &EpOptions::default(), Some(&m)).unwrap();
        assert!(m.count("ep.sites") >= 20);
        assert!(m.total("ep.rowmod") > std::time::Duration::ZERO);
    }
}
