//! EP marginal-likelihood approximation `log Z_EP` (paper eq. 5) and its
//! hyperparameter gradient (eqs. 6, 11).
//!
//! The log marginal is assembled from per-site quantities saved during the
//! sweep plus `log|B|` from the factor — the numerically robust form used
//! by GPML/GPstuff (Rasmussen & Williams eqs. 3.65/3.73, written via the
//! Cholesky of `B = I + S̃^{1/2} K S̃^{1/2}`).

/// Per-site state of an EP run (all length n).
#[derive(Clone, Debug, Default)]
pub struct EpSites {
    pub tau: Vec<f64>,
    pub nu: Vec<f64>,
    pub tau_cav: Vec<f64>,
    pub nu_cav: Vec<f64>,
    pub ln_zhat: Vec<f64>,
}

impl EpSites {
    pub fn zeros(n: usize) -> EpSites {
        EpSites {
            tau: vec![0.0; n],
            nu: vec![0.0; n],
            tau_cav: vec![1.0; n],
            nu_cav: vec![0.0; n],
            ln_zhat: vec![0.0; n],
        }
    }

    /// Sites re-indexed by `perm` (old index → new index):
    /// `out[perm[i]] = self[i]`. Used to carry warm-start sites from the
    /// original index space into a permuted EP run.
    pub fn permuted(&self, perm: &[usize]) -> EpSites {
        let n = self.tau.len();
        assert_eq!(perm.len(), n);
        let mut out = EpSites::zeros(n);
        for old in 0..n {
            let new = perm[old];
            out.tau[new] = self.tau[old];
            out.nu[new] = self.nu[old];
            out.tau_cav[new] = self.tau_cav[old];
            out.nu_cav[new] = self.nu_cav[old];
            out.ln_zhat[new] = self.ln_zhat[old];
        }
        out
    }

    /// Append `k` untrained sites (τ̃ = 0, the prior), the online-update
    /// seed: a model extended this way has exactly the posterior of the
    /// old model on the old points and the prior on the new ones, so
    /// `B_ext = diag(B_old, I_k)` and the old factor embeds unchanged
    /// (see `LdlFactor::embed`).
    pub fn extend(&mut self, k: usize) {
        self.tau.extend(std::iter::repeat(0.0).take(k));
        self.nu.extend(std::iter::repeat(0.0).take(k));
        self.tau_cav.extend(std::iter::repeat(1.0).take(k));
        self.nu_cav.extend(std::iter::repeat(0.0).take(k));
        self.ln_zhat.extend(std::iter::repeat(0.0).take(k));
    }

    /// Number of sites.
    pub fn len(&self) -> usize {
        self.tau.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tau.is_empty()
    }

    /// Inverse of [`EpSites::permuted`]: `out[i] = self[perm[i]]`.
    pub fn unpermuted(&self, perm: &[usize]) -> EpSites {
        let n = self.tau.len();
        assert_eq!(perm.len(), n);
        let mut out = EpSites::zeros(n);
        for old in 0..n {
            let new = perm[old];
            out.tau[old] = self.tau[new];
            out.nu[old] = self.nu[new];
            out.tau_cav[old] = self.tau_cav[new];
            out.nu_cav[old] = self.nu_cav[new];
            out.ln_zhat[old] = self.ln_zhat[new];
        }
        out
    }
}

/// Options shared by every EP variant.
#[derive(Clone, Copy, Debug)]
pub struct EpOptions {
    pub max_sweeps: usize,
    /// Convergence tolerance on |Δ log Z_EP| between sweeps.
    pub tol: f64,
    /// Site-update damping in (0, 1]; 1 = undamped (paper setting).
    pub damping: f64,
    /// Floor for adaptive damping: each rollback halves the working
    /// damping, never below this.
    pub min_damping: f64,
    /// Rollback budget per run: after this many snapshot restores the run
    /// errors out instead of recovering (0 disables rollback entirely).
    pub max_recoveries: usize,
    /// Pivot-recovery budget per factorization: how many times the
    /// escalating-jitter retry may double before giving up (see
    /// [`crate::sparse::cholesky::JitterPolicy`]).
    pub max_jitter_retries: usize,
    /// Relative log Z_EP regression that counts as divergence: a sweep
    /// ending with `logZ < prev - divergence_tol·(1 + |prev|)` triggers a
    /// rollback. Generous by design — healthy EP trajectories wobble by
    /// tolerances, diverging ones fall off a cliff.
    pub divergence_tol: f64,
}

impl Default for EpOptions {
    fn default() -> Self {
        EpOptions {
            max_sweeps: 60,
            tol: 1e-6,
            damping: 1.0,
            min_damping: 0.1,
            max_recoveries: 4,
            max_jitter_retries: 30,
            divergence_tol: 0.5,
        }
    }
}

impl EpOptions {
    /// The damping a backend actually starts with: `damping` clamped to
    /// the backend's stability ceiling (the batched backends cannot take
    /// full undamped steps — parallel EP caps at 0.9, CS+FIC at 0.8; the
    /// sequential sweep passes `cap = 1.0`). The single source of truth
    /// for the clamp, so adaptive halving composes with it: the working
    /// damping starts at `effective_damping(cap)` and each rollback
    /// halves it down to `min_damping`.
    pub fn effective_damping(&self, cap: f64) -> f64 {
        self.damping.min(cap)
    }

    /// The jitter schedule this run's factorizations recover with.
    pub fn jitter_policy(&self) -> crate::sparse::cholesky::JitterPolicy {
        crate::sparse::cholesky::JitterPolicy {
            max_retries: self.max_jitter_retries,
            ..crate::sparse::cholesky::JitterPolicy::default()
        }
    }
}

/// Sweep-level divergence detector shared by the EP backends: watches the
/// `log Z_EP` trajectory and the per-sweep `max_site_delta` and reports
/// when a sweep has gone off the rails. Conservative on purpose — the
/// acceptance bar is that *clean* fixtures never trip it — so it only
/// fires on a non-finite logZ, a logZ cliff (relative regression beyond
/// `divergence_tol`), or a site-delta oscillation that has blown 10×
/// past the best delta seen after the trajectory had settled.
#[derive(Clone, Debug)]
pub struct DivergenceMonitor {
    prev_log_z: Option<f64>,
    best_delta: f64,
    healthy_sweeps: usize,
}

impl Default for DivergenceMonitor {
    fn default() -> Self {
        Self::new()
    }
}

impl DivergenceMonitor {
    pub fn new() -> DivergenceMonitor {
        DivergenceMonitor { prev_log_z: None, best_delta: f64::INFINITY, healthy_sweeps: 0 }
    }

    /// Judge one finished sweep. Returns `true` if the sweep diverged (the
    /// caller should roll back; the diverged values are *not* recorded, so
    /// the restored trajectory is judged against the last good sweep).
    pub fn diverged(&mut self, log_z: f64, max_site_delta: f64, opts: &EpOptions) -> bool {
        if !log_z.is_finite() || !max_site_delta.is_finite() {
            return true;
        }
        if let Some(prev) = self.prev_log_z {
            if log_z < prev - opts.divergence_tol * (1.0 + prev.abs()) {
                return true;
            }
        }
        // Oscillation: deltas shrink as EP settles; a delta exploding two
        // orders past the best seen (and past any convergence-scale noise)
        // after at least three settled sweeps is a blow-up, not progress.
        if self.healthy_sweeps >= 3
            && max_site_delta > 10.0 * self.best_delta
            && max_site_delta > 100.0 * opts.tol
        {
            return true;
        }
        self.prev_log_z = Some(log_z);
        self.best_delta = self.best_delta.min(max_site_delta);
        self.healthy_sweeps += 1;
        false
    }
}

/// `log Z_EP` from converged per-site state.
///
/// * `logdet_b` — `log |B|`
/// * `nu_dot_mu` — `ν̃ᵀ μ` with `μ = Σ ν̃` the posterior mean.
pub fn ep_log_z(sites: &EpSites, logdet_b: f64, nu_dot_mu: f64) -> f64 {
    let n = sites.tau.len();
    let mut nlz = 0.5 * logdet_b - 0.5 * nu_dot_mu;
    for i in 0..n {
        let (tt, tn) = (sites.tau[i], sites.tau_cav[i]);
        let (nt, nn) = (sites.nu[i], sites.nu_cav[i]);
        nlz -= sites.ln_zhat[i];
        nlz -= 0.5 * nn * ((tt / tn * nn - 2.0 * nt) / (tt + tn));
        nlz += 0.5 * nt * nt / (tn + tt);
        nlz -= 0.5 * (1.0 + tt / tn).ln();
    }
    -nlz
}

/// Quadratic-form part of the gradient: `½ bᵀ (∂K/∂θ_p) b` for every
/// parameter, with `∂K` given as pattern-aligned value arrays over the
/// pattern of `k` (see `CovFunction::cov_matrix_grads`).
pub fn grad_quadratic_term(
    k: &crate::sparse::csc::CscMatrix,
    grads: &[Vec<f64>],
    b: &[f64],
) -> Vec<f64> {
    let mut out = vec![0.0; grads.len()];
    for j in 0..k.n_cols {
        let bj = b[j];
        for p in k.col_ptr[j]..k.col_ptr[j + 1] {
            let i = k.row_idx[p];
            let w = b[i] * bj;
            for (g, o) in grads.iter().zip(out.iter_mut()) {
                *o += 0.5 * w * g[p];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// n = 1 probit classification: Z = ∫ Φ(y f) N(f | 0, k) df = Φ(0) = ½
    /// exactly, and EP is exact for a single site. ep_log_z must give ln ½.
    #[test]
    fn single_site_log_z_is_exact() {
        use crate::gp::likelihood::probit_site_update;
        let k = 2.3; // prior variance
        let y = 1.0;
        // EP fixed point for one site: marginal = prior on first visit,
        // then iterate site updates until stationary.
        let (mut tau_s, mut nu_s) = (0.0, 0.0);
        let mut sites = EpSites::zeros(1);
        for _ in 0..200 {
            // posterior marginal given the site
            let sigma2 = 1.0 / (1.0 / k + tau_s);
            let mu = sigma2 * nu_s;
            let (lz, tc, nc, tn, nn) = probit_site_update(y, mu, sigma2, tau_s, nu_s).unwrap();
            tau_s = tn;
            nu_s = nn;
            sites = EpSites {
                tau: vec![tn],
                nu: vec![nn],
                tau_cav: vec![tc],
                nu_cav: vec![nc],
                ln_zhat: vec![lz],
            };
        }
        let b = 1.0 + tau_s * k; // B = 1 + sqrt(τ) k sqrt(τ)
        let sigma2 = 1.0 / (1.0 / k + tau_s);
        let mu = sigma2 * nu_s;
        let logz = ep_log_z(&sites, b.ln(), nu_s * mu);
        assert!(
            (logz - 0.5f64.ln()).abs() < 1e-9,
            "logZ = {logz}, want {}",
            0.5f64.ln()
        );
    }

    #[test]
    fn permuted_unpermuted_roundtrip() {
        let sites = EpSites {
            tau: vec![1.0, 2.0, 3.0],
            nu: vec![-1.0, 0.5, 0.25],
            tau_cav: vec![4.0, 5.0, 6.0],
            nu_cav: vec![0.1, 0.2, 0.3],
            ln_zhat: vec![-0.5, -0.25, -0.125],
        };
        let perm = vec![2usize, 0, 1];
        let p = sites.permuted(&perm);
        assert_eq!(p.tau, vec![2.0, 3.0, 1.0]);
        assert_eq!(p.nu[perm[0]], sites.nu[0]);
        let back = p.unpermuted(&perm);
        assert_eq!(back.tau, sites.tau);
        assert_eq!(back.nu, sites.nu);
        assert_eq!(back.tau_cav, sites.tau_cav);
        assert_eq!(back.nu_cav, sites.nu_cav);
        assert_eq!(back.ln_zhat, sites.ln_zhat);
    }

    #[test]
    fn effective_damping_is_the_single_clamp() {
        let opts = EpOptions::default(); // damping = 1.0
        assert_eq!(opts.effective_damping(1.0), 1.0);
        assert_eq!(opts.effective_damping(0.8), 0.8);
        let gentle = EpOptions { damping: 0.5, ..EpOptions::default() };
        assert_eq!(gentle.effective_damping(0.8), 0.5);
    }

    #[test]
    fn divergence_monitor_passes_healthy_and_flags_cliffs() {
        let opts = EpOptions::default();
        let mut m = DivergenceMonitor::new();
        // A settling trajectory with small wobbles is healthy.
        for (lz, delta) in [(-60.0, 1.0), (-55.0, 0.3), (-55.2, 0.1), (-54.9, 0.02)] {
            assert!(!m.diverged(lz, delta, &opts), "healthy sweep flagged at lz={lz}");
        }
        // Non-finite logZ always diverges, and is not recorded.
        assert!(m.diverged(f64::NAN, 0.01, &opts));
        // A cliff relative to the last *good* sweep diverges.
        assert!(m.diverged(-54.9 - 0.5 * (1.0 + 54.9) - 1.0, 0.01, &opts));
        // An exploded site delta after settling diverges too.
        assert!(m.diverged(-54.8, 5.0, &opts));
        // ... and the restored trajectory continues cleanly.
        assert!(!m.diverged(-54.85, 0.015, &opts));
    }

    #[test]
    fn quadratic_term_matches_dense() {
        use crate::sparse::csc::CscMatrix;
        let k = CscMatrix::from_triplets(
            2,
            2,
            &[(0, 0, 1.0), (1, 0, 0.5), (0, 1, 0.5), (1, 1, 2.0)],
        );
        let g0: Vec<f64> = k.values.clone(); // pretend dK/dθ = K
        let b = vec![1.0, -2.0];
        let out = grad_quadratic_term(&k, &[g0], &b);
        // ½ bᵀKb = ½ (1*1 + 2*0.5*1*(-2) + 4*2) = ½ (1 - 2 + 8) ... compute
        let want = 0.5 * (1.0 * 1.0 + 0.5 * 1.0 * -2.0 * 2.0 + 2.0 * 4.0);
        assert!((out[0] - want).abs() < 1e-12, "{} vs {want}", out[0]);
    }
}
