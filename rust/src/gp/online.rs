//! Online model updates: absorb newly arrived `(x, y)` points into a
//! fitted classifier without refitting from scratch.
//!
//! The paper's Algorithm 2 (`ldlrowmodify`) already makes every EP site
//! visit an incremental factor update; this module extends the same idea
//! across *dataset growth*. For the sequential sparse backend the update
//! is structural end to end:
//!
//! 1. append the new points to the permuted order (identity tail — the
//!    old points keep their slots, so no re-ordering runs),
//! 2. splice the covariance matrix: the old block is copied verbatim and
//!    only the new columns (plus their mirrored rows) are evaluated,
//! 3. re-run the (cheap, value-free) symbolic analysis on the union
//!    pattern and *embed* the converged factor into it
//!    ([`LdlFactor::embed`](crate::sparse::cholesky::LdlFactor::embed) —
//!    pure data movement: appended sites start at τ̃ = 0, so the extended
//!    `B` is exactly `diag(B_old, I)`),
//! 4. resume EP from the converged sites with a *partial first sweep*
//!    that visits only the appended sites through the rank-one
//!    `ldl_row_modify` machinery, then full sweeps until the usual
//!    convergence test passes.
//!
//! A warm resume typically converges in 2–3 sweeps against the ~10+ of a
//! cold start, and skips the fill-reducing ordering entirely — the
//! `perf_serving` bench records the resulting speedup. The parallel and
//! CS+FIC backends resume by warm-starting their batched runs from the
//! extended site vector (sites travel in unpermuted order, so a different
//! ordering resolution on the union is harmless); the dense and FIC
//! backends, and any update too large to be worth extending, fall back to
//! a cold refit on the union. Every path returns a fully predict-ready
//! [`FittedClassifier`] for the union dataset.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::geom::NeighborIndex;
use crate::gp::covariance::{CovFunction, RADIUS_PAD};
use crate::gp::csfic::CsFicEp;
use crate::gp::ep_parallel::ParallelEp;
use crate::gp::ep_sparse::{SparseEp, SparseInit, SparsePlan};
use crate::gp::marginal::EpOptions;
use crate::gp::model::{Backend, FitReport, FittedClassifier, GpClassifier, Inference};
use crate::sparse::csc::CscMatrix;
use crate::sparse::symbolic::Symbolic;

/// Which route an online update actually took.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdatePath {
    /// The converged factor was embedded into the union structure and
    /// revised in place (sequential sparse backend).
    Incremental,
    /// The backend re-ran on the union warm-started from the extended
    /// converged sites (parallel / CS+FIC backends).
    WarmRestart,
    /// Cold refit on the union (dense/FIC backend, oversized batch, or a
    /// failed resume).
    ColdRefit,
}

/// What an online update did and what it cost.
#[derive(Clone, Debug)]
pub struct UpdateReport {
    pub path: UpdatePath,
    pub n_old: usize,
    pub k_new: usize,
    /// EP sweeps the resumed (or refitted) run needed.
    pub sweeps: usize,
    pub update_time: Duration,
}

/// Largest appended batch the incremental / warm paths accept before the
/// update degrades to a cold refit: beyond this the resumed trajectory is
/// no longer near its fixed point and a fresh ordering pays for itself.
pub fn max_incremental_batch(n_old: usize) -> usize {
    (n_old / 4).max(64)
}

impl GpClassifier {
    /// Absorb `(new_x, new_y)` into `fitted` (a model this classifier —
    /// or one configured identically — produced) and return the fitted
    /// union model plus a report of the path taken. Hyperparameters are
    /// **not** re-optimized: the update keeps `fitted`'s kernel and
    /// resumes EP from its converged state; callers wanting fresh hypers
    /// should `fit` on the union instead.
    pub fn update(
        &self,
        fitted: &FittedClassifier,
        new_x: &[Vec<f64>],
        new_y: &[f64],
    ) -> Result<(FittedClassifier, UpdateReport), String> {
        validate_batch(fitted, new_x, new_y)?;
        let n_old = fitted.x.len();
        let k_new = new_x.len();
        let t0 = Instant::now();
        // union in the original index order: history first, new points last
        let mut x_union = fitted.x.clone();
        x_union.extend(new_x.iter().cloned());
        let mut y_union = fitted.y.clone();
        y_union.extend_from_slice(new_y);

        if k_new > max_incremental_batch(n_old) {
            return self.refit_union(fitted, x_union, y_union, n_old, k_new, t0);
        }

        // Incremental / warm paths only when the configured inference
        // matches the fitted backend (anything else is a reconfiguration,
        // which is a refit by definition).
        match &fitted.backend {
            Backend::Sparse(old) if matches!(self.inference, Inference::Sparse(_)) => {
                match extend_sparse(&fitted.cov, old, &y_union, new_x, &self.ep_opts) {
                    Ok(ep) => {
                        crate::obs::counters::ONLINE_UPDATES.add(1);
                        let report = UpdateReport {
                            path: UpdatePath::Incremental,
                            n_old,
                            k_new,
                            sweeps: ep.sweeps,
                            update_time: t0.elapsed(),
                        };
                        let fit = FittedClassifier {
                            cov: fitted.cov.clone(),
                            x: x_union,
                            y: y_union,
                            report: online_report(ep.log_z, t0.elapsed(), ep.fill_k, ep.fill_l),
                            backend: Backend::Sparse(ep),
                        };
                        Ok((fit, report))
                    }
                    Err(_) => self.refit_union(fitted, x_union, y_union, n_old, k_new, t0),
                }
            }
            Backend::Parallel(old) if matches!(self.inference, Inference::Parallel(_)) => {
                let mut warm = old.sites_unpermuted();
                warm.extend(k_new);
                let mut cache = self.fresh_cache();
                match ParallelEp::run_cached_warm(
                    &fitted.cov,
                    &x_union,
                    &y_union,
                    &self.ep_opts,
                    &mut cache,
                    Some(&warm),
                ) {
                    Ok(ep) => {
                        crate::obs::counters::ONLINE_UPDATES.add(1);
                        let report = UpdateReport {
                            path: UpdatePath::WarmRestart,
                            n_old,
                            k_new,
                            sweeps: ep.sweeps,
                            update_time: t0.elapsed(),
                        };
                        let fit = FittedClassifier {
                            cov: fitted.cov.clone(),
                            x: x_union,
                            y: y_union,
                            report: online_report(ep.log_z, t0.elapsed(), 1.0, 1.0),
                            backend: Backend::Parallel(ep),
                        };
                        Ok((fit, report))
                    }
                    Err(_) => self.refit_union(fitted, x_union, y_union, n_old, k_new, t0),
                }
            }
            Backend::CsFic(old) if matches!(self.inference, Inference::CsFic { .. }) => {
                let mut warm = old.sites_unpermuted();
                warm.extend(k_new);
                let mut cache = self.fresh_cache();
                // keep the fitted kernel pair AND the fitted inducing set:
                // re-running k-means on the union would shift the FIC
                // basis and with it the fixed point being resumed
                match CsFicEp::run_cached(
                    &old.cov,
                    &x_union,
                    &y_union,
                    &old.xu,
                    &self.ep_opts,
                    Some(&warm),
                    &mut cache,
                ) {
                    Ok(ep) => {
                        crate::obs::counters::ONLINE_UPDATES.add(1);
                        let report = UpdateReport {
                            path: UpdatePath::WarmRestart,
                            n_old,
                            k_new,
                            sweeps: ep.sweeps,
                            update_time: t0.elapsed(),
                        };
                        let fit = FittedClassifier {
                            cov: fitted.cov.clone(),
                            x: x_union,
                            y: y_union,
                            report: online_report(ep.log_z, t0.elapsed(), ep.fill_k, ep.fill_l),
                            backend: Backend::CsFic(ep),
                        };
                        Ok((fit, report))
                    }
                    Err(_) => self.refit_union(fitted, x_union, y_union, n_old, k_new, t0),
                }
            }
            _ => self.refit_union(fitted, x_union, y_union, n_old, k_new, t0),
        }
    }

    /// The degradation path: one cold `infer_only` on the union at the
    /// *fitted* hyperparameters (the old model's kernel, and for CS+FIC
    /// its global kernel too).
    fn refit_union(
        &self,
        fitted: &FittedClassifier,
        x_union: Vec<Vec<f64>>,
        y_union: Vec<f64>,
        n_old: usize,
        k_new: usize,
        t0: Instant,
    ) -> Result<(FittedClassifier, UpdateReport), String> {
        crate::obs::counters::ONLINE_REFITS.add(1);
        let mut model = self.clone();
        model.cov = fitted.cov.clone();
        if let Backend::CsFic(ep) = &fitted.backend {
            model.global_cov = Some(ep.cov.global.clone());
        }
        let fit = model.infer_only(&x_union, &y_union)?;
        let report = UpdateReport {
            path: UpdatePath::ColdRefit,
            n_old,
            k_new,
            sweeps: backend_sweeps(&fit.backend),
            update_time: t0.elapsed(),
        };
        Ok((fit, report))
    }
}

fn backend_sweeps(backend: &Backend) -> usize {
    match backend {
        Backend::Dense(ep) => ep.sweeps,
        Backend::Sparse(ep) => ep.sweeps,
        Backend::Parallel(ep) => ep.sweeps,
        Backend::Fic(ep) => ep.sweeps,
        Backend::CsFic(ep) => ep.sweeps,
    }
}

/// The fit report of an online update: no optimizer ran, `ep_time` is the
/// whole update (structure splice included).
fn online_report(log_z: f64, ep_time: Duration, fill_k: f64, fill_l: f64) -> FitReport {
    FitReport {
        log_z,
        log_post: log_z,
        opt_iters: 0,
        fn_evals: 0,
        opt_time: Duration::ZERO,
        ep_time,
        fill_k,
        fill_l,
        opt_converged: true,
    }
}

/// Same admission contract as `TrainSpec` validation: dimensions ragged
/// against the fitted inputs, non-finite coordinates and non-±1 labels
/// are caller errors, reported before any numeric work.
fn validate_batch(
    fitted: &FittedClassifier,
    new_x: &[Vec<f64>],
    new_y: &[f64],
) -> Result<(), String> {
    if new_x.is_empty() {
        return Err("online update: empty batch".into());
    }
    if new_x.len() != new_y.len() {
        return Err(format!(
            "online update: {} points but {} labels",
            new_x.len(),
            new_y.len()
        ));
    }
    let dim = fitted.x.first().map(|p| p.len()).unwrap_or_else(|| new_x[0].len());
    for (i, p) in new_x.iter().enumerate() {
        if p.len() != dim {
            return Err(format!(
                "online update: point {i} has dim {} (model expects {dim})",
                p.len()
            ));
        }
        if p.iter().any(|v| !v.is_finite()) {
            return Err(format!("online update: non-finite coordinate in point {i}"));
        }
    }
    if let Some(i) = new_y.iter().position(|&v| v != 1.0 && v != -1.0) {
        return Err(format!("online update: label {i} is {} (must be ±1)", new_y[i]));
    }
    Ok(())
}

/// The sequential-sparse incremental path (see the module docs): splice
/// structure, embed the factor, resume EP with a partial first sweep.
fn extend_sparse(
    cov: &CovFunction,
    old: &SparseEp,
    y_union: &[f64],
    new_x: &[Vec<f64>],
    opts: &EpOptions,
) -> Result<SparseEp, String> {
    let n_old = old.k.n_rows;
    let k_new = new_x.len();
    let n = n_old + k_new;
    // identity-tail permutation: old points keep their permuted slots
    // (the factor embed depends on the leading block staying put), the
    // appended points are eliminated last.
    let mut perm_ext = Vec::with_capacity(n);
    perm_ext.extend(old.perm.iter().copied());
    perm_ext.extend(n_old..n);
    let mut xp_ext: Vec<Vec<f64>> = Vec::with_capacity(n);
    xp_ext.extend(old.xp.iter().cloned());
    xp_ext.extend(new_x.iter().cloned());
    let k_ext = extend_cov_matrix(cov, &old.k, &xp_ext, n_old);
    // value-free symbolic analysis on the union pattern — appending
    // last-eliminated vertices adds no fill to the leading block, so the
    // old factor embeds exactly (LdlFactor::embed documents the argument)
    let symbolic = Arc::new(Symbolic::analyze(&k_ext));
    let mut sites = old.sites.clone();
    sites.extend(k_new);
    let plan = SparsePlan {
        perm: Arc::new(perm_ext),
        xp: Arc::new(xp_ext),
        k: k_ext,
        symbolic,
    };
    SparseEp::run_with_init(
        plan,
        y_union,
        opts,
        None,
        SparseInit::Extend { sites, old_factor: &old.factor, n_old },
    )
}

/// Extend a (permuted) covariance matrix by `n − n_old` appended points:
/// the old block's entries are copied verbatim — no kernel re-evaluation,
/// and any cache-superset explicit zeros are preserved — while the new
/// columns and their mirrored rows are evaluated fresh (`O(k · nnz/col)`
/// kernel calls instead of `O(nnz)`).
fn extend_cov_matrix(
    cov: &CovFunction,
    old_k: &CscMatrix,
    xp_ext: &[Vec<f64>],
    n_old: usize,
) -> CscMatrix {
    let n = xp_ext.len();
    let radius = cov.support_radius();
    let index = radius.map(|r| NeighborIndex::build(xp_ext, r));
    // new columns, ascending; rows sorted (neighbors_sorted / 0..n)
    let mut new_cols: Vec<(Vec<usize>, Vec<f64>)> = Vec::with_capacity(n - n_old);
    let mut cand: Vec<usize> = Vec::new();
    for j in n_old..n {
        let mut rows = Vec::new();
        let mut vals = Vec::new();
        match (&index, radius) {
            (Some(idx), Some(r)) => {
                idx.neighbors_sorted(&xp_ext[j], r * (1.0 + RADIUS_PAD), &mut cand);
                for &i in &cand {
                    if i == j {
                        rows.push(i);
                        vals.push(cov.sigma2);
                        continue;
                    }
                    let rr = cov.r(&xp_ext[i], &xp_ext[j]);
                    if rr < 1.0 {
                        rows.push(i);
                        vals.push(cov.sigma2 * cov.profile(rr));
                    }
                }
            }
            _ => {
                // globally supported kernel: dense column
                for (i, xi) in xp_ext.iter().enumerate() {
                    rows.push(i);
                    vals.push(if i == j { cov.sigma2 } else { cov.kernel(xi, &xp_ext[j]) });
                }
            }
        }
        new_cols.push((rows, vals));
    }
    // mirror: entry (i, j) of new column j also lives at (j, i) in old
    // column i; pushing in ascending j keeps each mirror list sorted
    let mut mirror: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n_old];
    for (cj, (rows, vals)) in new_cols.iter().enumerate() {
        let j = n_old + cj;
        for (&i, &v) in rows.iter().zip(vals) {
            if i < n_old {
                mirror[i].push((j, v));
            }
        }
    }
    let extra: usize = mirror.iter().map(|m| m.len()).sum();
    let new_nnz: usize = new_cols.iter().map(|(r, _)| r.len()).sum();
    let mut col_ptr = Vec::with_capacity(n + 1);
    let mut row_idx = Vec::with_capacity(old_k.nnz() + extra + new_nnz);
    let mut values = Vec::with_capacity(old_k.nnz() + extra + new_nnz);
    col_ptr.push(0);
    for c in 0..n_old {
        let (rows, vals) = old_k.col(c);
        row_idx.extend_from_slice(rows);
        values.extend_from_slice(vals);
        // mirrored tail rows are all >= n_old > every old row: still sorted
        for &(r, v) in &mirror[c] {
            row_idx.push(r);
            values.push(v);
        }
        col_ptr.push(row_idx.len());
    }
    for (rows, vals) in new_cols {
        row_idx.extend(rows);
        values.extend(vals);
        col_ptr.push(row_idx.len());
    }
    CscMatrix { n_rows: n, n_cols: n, col_ptr, row_idx, values }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::covariance::CovKind;
    use crate::sparse::ordering::Ordering;
    use crate::testutil::random_points;

    fn blob(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let x = random_points(n, 2, 6.0, seed);
        let y: Vec<f64> = x
            .iter()
            .map(|p| if (p[0] - 3.0).hypot(p[1] - 3.0) < 2.0 { 1.0 } else { -1.0 })
            .collect();
        (x, y)
    }

    /// The structural core: splicing the covariance must agree exactly
    /// with assembling the union from scratch in the same order.
    #[test]
    fn extended_cov_matrix_matches_fresh_assembly() {
        let (x, _) = blob(120, 31);
        let cov = CovFunction::new(CovKind::Pp(3), 2, 1.1, 2.0);
        let n_old = 100;
        let old_k = cov.cov_matrix(&x[..n_old]);
        let ext = extend_cov_matrix(&cov, &old_k, &x, n_old);
        let fresh = cov.cov_matrix(&x);
        assert_eq!(ext.col_ptr, fresh.col_ptr, "pattern col_ptr");
        assert_eq!(ext.row_idx, fresh.row_idx, "pattern rows");
        for (a, b) in ext.values.iter().zip(&fresh.values) {
            assert_eq!(a.to_bits(), b.to_bits(), "values must match bitwise");
        }
    }

    #[test]
    fn incremental_update_matches_cold_refit() {
        let (x, y) = blob(160, 7);
        let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 2.0);
        let model = GpClassifier::new(cov, Inference::Sparse(Ordering::Rcm));
        let n_old = 144;
        let fitted = model.infer_only(&x[..n_old], &y[..n_old]).unwrap();
        let (updated, report) = model.update(&fitted, &x[n_old..], &y[n_old..]).unwrap();
        assert_eq!(report.path, UpdatePath::Incremental);
        assert_eq!((report.n_old, report.k_new), (n_old, x.len() - n_old));
        let refit = model.infer_only(&x, &y).unwrap();
        assert!(
            (updated.report.log_z - refit.report.log_z).abs() < 1e-5,
            "logZ {} vs refit {}",
            updated.report.log_z,
            refit.report.log_z
        );
        for px in [vec![1.0, 2.0], vec![3.0, 3.0], vec![4.5, 1.5]] {
            let (mu, vu) = updated.predict_latent(&px);
            let (mr, vr) = refit.predict_latent(&px);
            assert!((mu - mr).abs() < 1e-5, "pred mean {mu} vs {mr}");
            assert!((vu - vr).abs() < 1e-5, "pred var {vu} vs {vr}");
        }
    }

    #[test]
    fn oversized_batch_degrades_to_cold_refit() {
        let (x, y) = blob(80, 3);
        let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 2.0);
        let model = GpClassifier::new(cov, Inference::Sparse(Ordering::Rcm));
        let fitted = model.infer_only(&x[..10], &y[..10]).unwrap();
        // 70 appended > max_incremental_batch(10) = 64
        let (updated, report) = model.update(&fitted, &x[10..], &y[10..]).unwrap();
        assert_eq!(report.path, UpdatePath::ColdRefit);
        assert_eq!(updated.x.len(), 80);
    }

    #[test]
    fn invalid_batches_are_rejected_before_any_numeric_work() {
        let (x, y) = blob(40, 5);
        let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 2.0);
        let model = GpClassifier::new(cov, Inference::Sparse(Ordering::Rcm));
        let fitted = model.infer_only(&x, &y).unwrap();
        assert!(model.update(&fitted, &[], &[]).is_err(), "empty batch");
        assert!(
            model.update(&fitted, &[vec![1.0]], &[1.0]).is_err(),
            "ragged dimension"
        );
        assert!(
            model.update(&fitted, &[vec![f64::NAN, 0.0]], &[1.0]).is_err(),
            "non-finite coordinate"
        );
        assert!(
            model.update(&fitted, &[vec![1.0, 1.0]], &[0.5]).is_err(),
            "label must be ±1"
        );
        assert!(
            model.update(&fitted, &[vec![1.0, 1.0]], &[1.0, -1.0]).is_err(),
            "length mismatch"
        );
    }
}
