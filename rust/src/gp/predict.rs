//! Class-probability prediction and the paper's evaluation metrics.
//!
//! The latent predictive `(μ*, σ*²)` comes from whichever EP backend ran
//! (dense, sparse, parallel or FIC); the averaged predictive probability
//! for the probit likelihood is the closed form
//! `π* = Φ(μ* / sqrt(1 + σ*²))` (Rasmussen & Williams eq. 3.77).

use crate::gp::likelihood::{ln_norm_cdf, norm_cdf};

/// π* from a latent mean/variance.
#[inline]
pub fn class_probability(mean: f64, var: f64) -> f64 {
    norm_cdf(mean / (1.0 + var).sqrt())
}

/// −log p(y* | D) for a single test case with label y ∈ {−1, +1}.
#[inline]
pub fn neg_log_pred_density(y: f64, mean: f64, var: f64) -> f64 {
    -ln_norm_cdf(y * mean / (1.0 + var).sqrt())
}

/// Hard decision: sign of the latent mean (equivalently π* ≷ ½).
#[inline]
pub fn classify(mean: f64) -> f64 {
    if mean >= 0.0 {
        1.0
    } else {
        -1.0
    }
}

/// Aggregated test metrics: mean classification error and mean nlpd —
/// the columns of the paper's Table 2.
#[derive(Clone, Copy, Debug, Default)]
pub struct Metrics {
    pub err: f64,
    pub nlpd: f64,
    pub n: usize,
}

/// Evaluate predictions `(mean, var)` against labels.
pub fn evaluate(preds: &[(f64, f64)], y: &[f64]) -> Metrics {
    assert_eq!(preds.len(), y.len());
    let n = y.len();
    let mut errors = 0usize;
    let mut nlpd = 0.0;
    for (&(m, v), &yi) in preds.iter().zip(y) {
        if classify(m) != yi {
            errors += 1;
        }
        nlpd += neg_log_pred_density(yi, m, v);
    }
    Metrics { err: errors as f64 / n as f64, nlpd: nlpd / n as f64, n }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probability_is_half_at_zero_mean() {
        assert!((class_probability(0.0, 3.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn variance_shrinks_confidence() {
        let p_low_var = class_probability(1.0, 0.01);
        let p_high_var = class_probability(1.0, 100.0);
        assert!(p_low_var > p_high_var);
        assert!(p_high_var > 0.5);
    }

    #[test]
    fn nlpd_consistency_with_probability() {
        let (m, v) = (0.7, 1.3);
        let p = class_probability(m, v);
        assert!((neg_log_pred_density(1.0, m, v) + p.ln()).abs() < 1e-12);
        assert!((neg_log_pred_density(-1.0, m, v) + (1.0 - p).ln()).abs() < 1e-9);
    }

    #[test]
    fn evaluate_counts_errors() {
        let preds = vec![(1.0, 0.1), (-2.0, 0.1), (0.5, 0.1), (-0.5, 0.1)];
        let y = vec![1.0, -1.0, -1.0, -1.0];
        let m = evaluate(&preds, &y);
        assert!((m.err - 0.25).abs() < 1e-12);
        assert!(m.nlpd > 0.0);
        assert_eq!(m.n, 4);
    }
}
