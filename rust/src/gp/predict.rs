//! Class-probability prediction and the paper's evaluation metrics.
//!
//! The latent predictive `(μ*, σ*²)` comes from whichever EP backend ran
//! (dense, sparse, parallel or FIC); the averaged predictive probability
//! for the probit likelihood is the closed form
//! `π* = Φ(μ* / sqrt(1 + σ*²))` (Rasmussen & Williams eq. 3.77).
//!
//! Batch prediction goes through [`PredictWorkspace`] /
//! [`LatentPredictor`]: one neighbor index over the training inputs and
//! one sparse-solve scratch shared across every test point, so a compact
//! kernel's per-point cost is `O(k + nnz(L))` with zero allocation rather
//! than a fresh index scan plus two `n`-vectors per call.

use std::sync::Arc;

use crate::geom::NeighborIndex;
use crate::gp::covariance::{CovFunction, INDEX_MIN_N};
use crate::gp::likelihood::{ln_norm_cdf, norm_cdf};
use crate::gp::model::{Backend, FittedClassifier};
use crate::sparse::cholesky::LdlFactor;
use crate::sparse::triangular::SparseSolveWorkspace;

/// Reusable scratch for repeated latent predictions against one sparse EP
/// state (sequential or parallel backend).
pub struct PredictWorkspace {
    pub(crate) ws: SparseSolveWorkspace,
    pub(crate) t: Vec<f64>,
    pub(crate) rows: Vec<usize>,
    pub(crate) vals: Vec<f64>,
    pub(crate) u_vals: Vec<f64>,
    /// Neighbor index over the training inputs the cross-covariances are
    /// built against (only for compact kernels on large sets). `Arc` so a
    /// pool-parallel batch can [`fork`](PredictWorkspace::fork) one
    /// workspace per worker without rebuilding or deep-copying the index.
    pub(crate) index: Option<Arc<NeighborIndex>>,
}

impl PredictWorkspace {
    /// Workspace for a batch of predictions: builds a neighbor index over
    /// `xp` when the kernel is compact and the set is large enough for the
    /// index to pay off.
    pub fn new(cov: &CovFunction, xp: &[Vec<f64>]) -> PredictWorkspace {
        let index = match cov.support_radius() {
            Some(radius) if xp.len() >= INDEX_MIN_N => {
                Some(Arc::new(NeighborIndex::build(xp, radius)))
            }
            _ => None,
        };
        let mut pws = PredictWorkspace::one_shot(xp.len());
        pws.index = index;
        pws
    }

    /// Workspace for a single prediction — skips the index build.
    pub fn one_shot(n: usize) -> PredictWorkspace {
        PredictWorkspace {
            ws: SparseSolveWorkspace::new(n),
            t: vec![0.0; n],
            rows: Vec::new(),
            vals: Vec::new(),
            u_vals: Vec::new(),
            index: None,
        }
    }

    /// A fresh workspace sharing this one's neighbor index (`Arc` clone,
    /// not a rebuild). The pool's batched-prediction paths create one fork
    /// per participating worker; since every per-point computation clears
    /// its scratch, a forked workspace produces bitwise-identical results
    /// to the original.
    pub fn fork(&self) -> PredictWorkspace {
        PredictWorkspace {
            ws: SparseSolveWorkspace::new(self.t.len()),
            t: vec![0.0; self.t.len()],
            rows: Vec::new(),
            vals: Vec::new(),
            u_vals: Vec::new(),
            index: self.index.clone(),
        }
    }
}

/// The one batched-prediction fan-out every backend shares: run `f` for
/// each index in `0..n` over the [`crate::par`] worker pool, each
/// participant working through its own fork of `proto` (same `Arc`'d
/// neighbor index, fresh solve scratch). Slot `i` is written by exactly
/// one task and each per-point computation clears its scratch, so the
/// result is bitwise-identical to a serial loop over one workspace.
pub(crate) fn batch_with_forks<T>(
    proto: &PredictWorkspace,
    n: usize,
    f: impl Fn(&mut PredictWorkspace, usize) -> T + Sync,
) -> Vec<T>
where
    T: Send + Default + Clone,
{
    crate::par::map_indexed(n, 32, || proto.fork(), f)
}

/// Shared latent-prediction kernel for the sparse EP representations:
/// mean `k*ᵀ w` and variance `k** − uᵀ B⁻¹ u` with `u = S̃^{1/2} k*`,
/// everything through the caller's workspace.
pub(crate) fn sparse_latent_with(
    cov: &CovFunction,
    xp: &[Vec<f64>],
    factor: &LdlFactor,
    tau: &[f64],
    w_pred: &[f64],
    xstar: &[f64],
    pws: &mut PredictWorkspace,
) -> (f64, f64) {
    cov.cross_cov_into(xp, xstar, pws.index.as_deref(), &mut pws.rows, &mut pws.vals);
    let mean: f64 = pws.rows.iter().zip(&pws.vals).map(|(&i, &v)| v * w_pred[i]).sum();
    pws.u_vals.clear();
    pws.u_vals
        .extend(pws.rows.iter().zip(&pws.vals).map(|(&i, &v)| tau[i].max(0.0).sqrt() * v));
    factor.solve_sparse_rhs(&pws.rows, &pws.u_vals, &mut pws.ws, &mut pws.t);
    let quad: f64 = pws.rows.iter().zip(&pws.u_vals).map(|(&i, &v)| v * pws.t[i]).sum();
    pws.ws.clear_solution(&mut pws.t);
    (mean, (cov.sigma2 - quad).max(1e-12))
}

/// Batch-friendly view of a [`FittedClassifier`]: holds the per-backend
/// [`PredictWorkspace`] so a stream of predictions (the batching service,
/// `evaluate`, the benches) reuses one index and one solve scratch.
pub struct LatentPredictor<'a> {
    fitted: &'a FittedClassifier,
    ws: Option<PredictWorkspace>,
}

impl<'a> LatentPredictor<'a> {
    pub fn new(fitted: &'a FittedClassifier) -> LatentPredictor<'a> {
        let ws = match &fitted.backend {
            Backend::Sparse(ep) => Some(ep.predict_workspace(&fitted.cov)),
            Backend::Parallel(ep) => Some(ep.predict_workspace(&fitted.cov)),
            Backend::CsFic(ep) => Some(ep.predict_workspace()),
            Backend::Dense(_) | Backend::Fic(_) => None,
        };
        LatentPredictor { fitted, ws }
    }

    /// Latent predictive (mean, variance) at one point.
    pub fn predict_latent(&mut self, xstar: &[f64]) -> (f64, f64) {
        match (&self.fitted.backend, &mut self.ws) {
            (Backend::Sparse(ep), Some(ws)) => {
                ep.predict_latent_with(&self.fitted.cov, xstar, ws)
            }
            (Backend::Parallel(ep), Some(ws)) => {
                ep.predict_latent_with(&self.fitted.cov, xstar, ws)
            }
            (Backend::CsFic(ep), Some(ws)) => ep.predict_latent_with(xstar, ws),
            _ => self.fitted.predict_latent(xstar),
        }
    }

    /// Class probability π* at one point.
    pub fn predict_proba(&mut self, xstar: &[f64]) -> f64 {
        let (m, v) = self.predict_latent(xstar);
        class_probability(m, v)
    }

    /// Latent predictions for a batch of points, fanned out over the
    /// [`crate::par`] worker pool on the workspace-backed backends: each
    /// participant forks the predictor's workspace (sharing its neighbor
    /// index by `Arc`) and owns a disjoint slice of output slots, so the
    /// result is bitwise-identical to calling
    /// [`predict_latent`](LatentPredictor::predict_latent) per point. The
    /// dense backends fall back to the plain serial map.
    ///
    /// Batches too small to amortize a workspace fork (and width-1 pools)
    /// run inline on the predictor's own held workspace — the
    /// zero-allocation path single-request serving traffic takes.
    pub fn predict_latent_batch(&mut self, xs: &[Vec<f64>]) -> Vec<(f64, f64)> {
        if xs.len() < 32 || crate::par::current_threads() <= 1 {
            return xs.iter().map(|x| self.predict_latent(x)).collect();
        }
        match (&self.fitted.backend, &self.ws) {
            (Backend::Sparse(ep), Some(proto)) => batch_with_forks(proto, xs.len(), |pws, i| {
                ep.predict_latent_with(&self.fitted.cov, &xs[i], pws)
            }),
            (Backend::Parallel(ep), Some(proto)) => batch_with_forks(proto, xs.len(), |pws, i| {
                ep.predict_latent_with(&self.fitted.cov, &xs[i], pws)
            }),
            (Backend::CsFic(ep), Some(proto)) => {
                batch_with_forks(proto, xs.len(), |pws, i| ep.predict_latent_with(&xs[i], pws))
            }
            _ => xs.iter().map(|x| self.fitted.predict_latent(x)).collect(),
        }
    }
}

/// π* from a latent mean/variance.
#[inline]
pub fn class_probability(mean: f64, var: f64) -> f64 {
    norm_cdf(mean / (1.0 + var).sqrt())
}

/// −log p(y* | D) for a single test case with label y ∈ {−1, +1}.
#[inline]
pub fn neg_log_pred_density(y: f64, mean: f64, var: f64) -> f64 {
    -ln_norm_cdf(y * mean / (1.0 + var).sqrt())
}

/// Hard decision: sign of the latent mean (equivalently π* ≷ ½).
#[inline]
pub fn classify(mean: f64) -> f64 {
    if mean >= 0.0 {
        1.0
    } else {
        -1.0
    }
}

/// Aggregated test metrics: mean classification error and mean nlpd —
/// the columns of the paper's Table 2.
#[derive(Clone, Copy, Debug, Default)]
pub struct Metrics {
    pub err: f64,
    pub nlpd: f64,
    pub n: usize,
}

/// Evaluate predictions `(mean, var)` against labels.
pub fn evaluate(preds: &[(f64, f64)], y: &[f64]) -> Metrics {
    assert_eq!(preds.len(), y.len());
    let n = y.len();
    let mut errors = 0usize;
    let mut nlpd = 0.0;
    for (&(m, v), &yi) in preds.iter().zip(y) {
        if classify(m) != yi {
            errors += 1;
        }
        nlpd += neg_log_pred_density(yi, m, v);
    }
    Metrics { err: errors as f64 / n as f64, nlpd: nlpd / n as f64, n }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probability_is_half_at_zero_mean() {
        assert!((class_probability(0.0, 3.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn variance_shrinks_confidence() {
        let p_low_var = class_probability(1.0, 0.01);
        let p_high_var = class_probability(1.0, 100.0);
        assert!(p_low_var > p_high_var);
        assert!(p_high_var > 0.5);
    }

    #[test]
    fn nlpd_consistency_with_probability() {
        let (m, v) = (0.7, 1.3);
        let p = class_probability(m, v);
        assert!((neg_log_pred_density(1.0, m, v) + p.ln()).abs() < 1e-12);
        assert!((neg_log_pred_density(-1.0, m, v) + (1.0 - p).ln()).abs() < 1e-9);
    }

    #[test]
    fn evaluate_counts_errors() {
        let preds = vec![(1.0, 0.1), (-2.0, 0.1), (0.5, 0.1), (-0.5, 0.1)];
        let y = vec![1.0, -1.0, -1.0, -1.0];
        let m = evaluate(&preds, &y);
        assert!((m.err - 0.25).abs() < 1e-12);
        assert!(m.nlpd > 0.0);
        assert_eq!(m.n, 4);
    }
}
