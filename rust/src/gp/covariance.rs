//! Covariance functions: the globally-supported squared exponential and
//! Matérn family, and the compactly supported Wendland piecewise
//! polynomials `k_pp,q` of the paper (eqs. 7–10).
//!
//! All functions are radial: `k(x, x') = σ² φ(r)` with the ARD distance
//! `r = sqrt(Σ_d (x_d − x'_d)² / l_d²)`. CS functions vanish exactly for
//! `r ≥ 1`, which is what makes the covariance matrix sparse; the Wendland
//! exponent `j = ⌊D/2⌋ + q + 1` ties the polynomial degree to the input
//! dimension `D` to keep the function positive definite (Wendland 2005).
//!
//! Hyperparameters are handled in log space throughout
//! (`params = [ln σ², ln l₁, …, ln l_D]`), matching how the optimizer and
//! the priors operate.

use crate::geom::NeighborIndex;
use crate::sparse::csc::CscMatrix;

/// Below this many points the O(n²) scan beats building a spatial index;
/// `cov_matrix` only auto-builds an index at or above it.
pub const INDEX_MIN_N: usize = 64;

/// Relative padding applied to neighbor-query radii so floating-point
/// rounding in the index's Euclidean distance can never drop a pair that
/// the exact `r < 1` kernel test would keep.
pub const RADIUS_PAD: f64 = 1e-9;

/// `u^e` for the Wendland exponents, which are small non-negative
/// integers by construction (`j = ⌊D/2⌋ + q + 1` plus 0..=3): `powi` is
/// several times cheaper than `powf` and exact for these cases. This is
/// on the assembly hot path — every stored entry of every CS covariance
/// evaluation goes through it.
#[inline]
fn powj(u: f64, e: f64) -> f64 {
    debug_assert!(e >= 0.0 && e.fract() == 0.0 && e <= 127.0, "bad Wendland exponent {e}");
    u.powi(e as i32)
}

/// Which radial profile.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CovKind {
    /// Squared exponential, `σ² exp(−r²)` (paper eq. 1 — note no ½).
    Se,
    /// Wendland piecewise polynomial of smoothness q ∈ {0, 1, 2, 3}.
    Pp(u8),
    /// Matérn ν = 3/2.
    Matern32,
    /// Matérn ν = 5/2.
    Matern52,
}

impl CovKind {
    pub fn name(&self) -> String {
        match self {
            CovKind::Se => "se".into(),
            CovKind::Pp(q) => format!("pp{q}"),
            CovKind::Matern32 => "matern32".into(),
            CovKind::Matern52 => "matern52".into(),
        }
    }

    pub fn parse(s: &str) -> Result<CovKind, String> {
        match s {
            "se" => Ok(CovKind::Se),
            "pp0" => Ok(CovKind::Pp(0)),
            "pp1" => Ok(CovKind::Pp(1)),
            "pp2" => Ok(CovKind::Pp(2)),
            "pp3" => Ok(CovKind::Pp(3)),
            "matern32" => Ok(CovKind::Matern32),
            "matern52" => Ok(CovKind::Matern52),
            other => Err(format!("unknown covariance '{other}'")),
        }
    }
}

/// A covariance function with its hyperparameters.
#[derive(Clone, Debug)]
pub struct CovFunction {
    pub kind: CovKind,
    /// Input dimension D (sets the Wendland exponent j).
    pub input_dim: usize,
    /// Magnitude σ².
    pub sigma2: f64,
    /// ARD length-scales, one per input dimension.
    pub lengthscales: Vec<f64>,
}

impl CovFunction {
    pub fn new(kind: CovKind, input_dim: usize, sigma2: f64, lengthscale: f64) -> CovFunction {
        CovFunction { kind, input_dim, sigma2, lengthscales: vec![lengthscale; input_dim] }
    }

    /// Is the support compact (k ≡ 0 for r ≥ 1)?
    pub fn is_compact(&self) -> bool {
        matches!(self.kind, CovKind::Pp(_))
    }

    /// Euclidean support radius: `k(x, x') = 0` whenever
    /// `‖x − x'‖ >= max_d l_d` for a compact kernel (the ARD support
    /// ellipsoid is contained in that ball). `None` for globally
    /// supported kernels.
    pub fn support_radius(&self) -> Option<f64> {
        if self.is_compact() {
            Some(self.lengthscales.iter().copied().fold(0.0, f64::max))
        } else {
            None
        }
    }

    /// Wendland exponent j = ⌊D/2⌋ + q + 1.
    pub fn wendland_j(&self) -> f64 {
        match self.kind {
            CovKind::Pp(q) => (self.input_dim / 2) as f64 + q as f64 + 1.0,
            _ => panic!("wendland_j on non-pp covariance"),
        }
    }

    // ---- log-parameter plumbing ------------------------------------------

    pub fn n_params(&self) -> usize {
        1 + self.lengthscales.len()
    }

    /// `[ln σ², ln l₁, …, ln l_D]`.
    pub fn params(&self) -> Vec<f64> {
        let mut p = Vec::with_capacity(self.n_params());
        p.push(self.sigma2.ln());
        p.extend(self.lengthscales.iter().map(|l| l.ln()));
        p
    }

    pub fn set_params(&mut self, p: &[f64]) {
        assert_eq!(p.len(), self.n_params());
        self.sigma2 = p[0].exp();
        for (l, &lp) in self.lengthscales.iter_mut().zip(&p[1..]) {
            *l = lp.exp();
        }
    }

    // ---- radial profile ---------------------------------------------------

    /// Scaled distance r between two points.
    #[inline]
    pub fn r(&self, x1: &[f64], x2: &[f64]) -> f64 {
        let mut r2 = 0.0;
        for d in 0..x1.len() {
            let diff = (x1[d] - x2[d]) / self.lengthscales[d];
            r2 += diff * diff;
        }
        r2.sqrt()
    }

    /// Unit-magnitude radial profile φ(r) (so k = σ² φ(r)).
    pub fn profile(&self, r: f64) -> f64 {
        match self.kind {
            CovKind::Se => (-r * r).exp(),
            CovKind::Matern32 => {
                let a = 3f64.sqrt() * r;
                (1.0 + a) * (-a).exp()
            }
            CovKind::Matern52 => {
                let a = 5f64.sqrt() * r;
                (1.0 + a + a * a / 3.0) * (-a).exp()
            }
            CovKind::Pp(q) => {
                if r >= 1.0 {
                    return 0.0;
                }
                let j = self.wendland_j();
                let u = 1.0 - r;
                match q {
                    0 => powj(u, j),
                    1 => powj(u, j + 1.0) * ((j + 1.0) * r + 1.0),
                    2 => {
                        let a = j * j + 4.0 * j + 3.0;
                        let b = 3.0 * j + 6.0;
                        powj(u, j + 2.0) * (a * r * r + b * r + 3.0) / 3.0
                    }
                    3 => {
                        let a = j * j * j + 9.0 * j * j + 23.0 * j + 15.0;
                        let b = 6.0 * j * j + 36.0 * j + 45.0;
                        let c = 15.0 * j + 45.0;
                        powj(u, j + 3.0) * (a * r * r * r + b * r * r + c * r + 15.0) / 15.0
                    }
                    _ => panic!("pp q must be 0..=3"),
                }
            }
        }
    }

    /// dφ/dr.
    pub fn profile_deriv(&self, r: f64) -> f64 {
        match self.kind {
            CovKind::Se => -2.0 * r * (-r * r).exp(),
            CovKind::Matern32 => {
                let s = 3f64.sqrt();
                let a = s * r;
                // d/dr[(1+a)e^{-a}] = -s*a*e^{-a}
                -s * a * (-a).exp()
            }
            CovKind::Matern52 => {
                let s = 5f64.sqrt();
                let a = s * r;
                // d/dr[(1+a+a²/3)e^{-a}] = -(s/3)a(1+a)e^{-a}
                -(s / 3.0) * a * (1.0 + a) * (-a).exp()
            }
            CovKind::Pp(q) => {
                if r >= 1.0 {
                    return 0.0;
                }
                let j = self.wendland_j();
                let u = 1.0 - r;
                match q {
                    0 => -j * powj(u, j - 1.0),
                    1 => {
                        // product rule on u^{j+1}((j+1)r+1)
                        -(j + 1.0) * powj(u, j) * ((j + 1.0) * r + 1.0)
                            + powj(u, j + 1.0) * (j + 1.0)
                    }
                    2 => {
                        let a = j * j + 4.0 * j + 3.0;
                        let b = 3.0 * j + 6.0;
                        (-(j + 2.0) * powj(u, j + 1.0) * (a * r * r + b * r + 3.0)
                            + powj(u, j + 2.0) * (2.0 * a * r + b))
                            / 3.0
                    }
                    3 => {
                        let a = j * j * j + 9.0 * j * j + 23.0 * j + 15.0;
                        let b = 6.0 * j * j + 36.0 * j + 45.0;
                        let c = 15.0 * j + 45.0;
                        (-(j + 3.0) * powj(u, j + 2.0) * (a * r * r * r + b * r * r + c * r + 15.0)
                            + powj(u, j + 3.0) * (3.0 * a * r * r + 2.0 * b * r + c))
                            / 15.0
                    }
                    _ => panic!("pp q must be 0..=3"),
                }
            }
        }
    }

    /// k(x1, x2).
    #[inline]
    pub fn kernel(&self, x1: &[f64], x2: &[f64]) -> f64 {
        self.sigma2 * self.profile(self.r(x1, x2))
    }

    /// k(x1, x2) plus the gradient w.r.t. the log parameters
    /// `[ln σ², ln l₁, …]` written into `grad`.
    pub fn kernel_grad(&self, x1: &[f64], x2: &[f64], grad: &mut [f64]) -> f64 {
        debug_assert_eq!(grad.len(), self.n_params());
        let r = self.r(x1, x2);
        let phi = self.profile(r);
        let k = self.sigma2 * phi;
        grad[0] = k; // d/d ln σ² = k
        if r == 0.0 {
            for g in grad[1..].iter_mut() {
                *g = 0.0;
            }
            return k;
        }
        let dphi = self.profile_deriv(r);
        for d in 0..self.lengthscales.len() {
            let diff = (x1[d] - x2[d]) / self.lengthscales[d];
            // dr/d ln l_d = −diff² / r
            grad[1 + d] = self.sigma2 * dphi * (-(diff * diff) / r);
        }
        k
    }

    // ---- matrix assembly --------------------------------------------------

    /// Full-storage CSC covariance matrix of `x`. For compact support only
    /// pairs with r < 1 are stored (plus the diagonal); globally supported
    /// functions yield a dense pattern.
    ///
    /// Compact kernels on large point sets go through a spatial
    /// [`NeighborIndex`] (`O(n·k)` candidate pairs); the result is
    /// identical — pattern and values — to `cov_matrix_brute`, which
    /// remains available for comparison.
    pub fn cov_matrix(&self, x: &[Vec<f64>]) -> CscMatrix {
        match self.support_radius() {
            Some(radius) if x.len() >= INDEX_MIN_N => {
                let index = NeighborIndex::build(x, radius);
                self.cov_matrix_with(x, &index)
            }
            _ => self.cov_matrix_brute(x),
        }
    }

    /// The O(n²) all-pairs assembly (the seed implementation). Kept as the
    /// reference path for benchmarks and exactness tests.
    pub fn cov_matrix_brute(&self, x: &[Vec<f64>]) -> CscMatrix {
        let n = x.len();
        let compact = self.is_compact();
        let mut col_ptr = Vec::with_capacity(n + 1);
        let mut row_idx = Vec::new();
        let mut values = Vec::new();
        col_ptr.push(0);
        for j in 0..n {
            for (i, xi) in x.iter().enumerate() {
                if i == j {
                    row_idx.push(i);
                    values.push(self.sigma2);
                    continue;
                }
                if compact {
                    let r = self.r(xi, &x[j]);
                    if r < 1.0 {
                        row_idx.push(i);
                        values.push(self.sigma2 * self.profile(r));
                    }
                } else {
                    row_idx.push(i);
                    values.push(self.kernel(xi, &x[j]));
                }
            }
            col_ptr.push(row_idx.len());
        }
        CscMatrix { n_rows: n, n_cols: n, col_ptr, row_idx, values }
    }

    /// Index-backed assembly: per column, enumerate only the points inside
    /// the Euclidean support ball, then apply the exact `r < 1` test. For
    /// globally supported kernels this degenerates to the brute path (the
    /// pattern is dense anyway). `index` must have been built over `x`.
    ///
    /// Columns are independent (the index is read-only), so at pool width
    /// > 1 they fan out over [`crate::par`] — each task produces its own
    /// column, and the columns are concatenated in order, so the result is
    /// identical to the serial sweep.
    pub fn cov_matrix_with(&self, x: &[Vec<f64>], index: &NeighborIndex) -> CscMatrix {
        let Some(radius) = self.support_radius() else {
            return self.cov_matrix_brute(x);
        };
        let n = x.len();
        debug_assert_eq!(index.len(), n, "index built over a different point set");
        let query_r = radius * (1.0 + RADIUS_PAD);
        if crate::par::current_threads() <= 1 {
            // serial sweep: one shared candidate buffer, zero per-column
            // allocation
            let mut col_ptr = Vec::with_capacity(n + 1);
            let mut row_idx = Vec::new();
            let mut values = Vec::new();
            let mut cand: Vec<usize> = Vec::new();
            col_ptr.push(0);
            for (j, xj) in x.iter().enumerate() {
                index.neighbors_sorted(xj, query_r, &mut cand);
                self.fill_column(x, j, &cand, &mut row_idx, &mut values);
                col_ptr.push(row_idx.len());
            }
            return CscMatrix { n_rows: n, n_cols: n, col_ptr, row_idx, values };
        }
        // one (rows, values) pair per column, stitched in column order
        let cols: Vec<(Vec<usize>, Vec<f64>)> = crate::par::map_indexed(
            n,
            16,
            Vec::<usize>::new,
            |cand, j| {
                index.neighbors_sorted(&x[j], query_r, cand);
                let mut rows = Vec::with_capacity(cand.len());
                let mut vals = Vec::with_capacity(cand.len());
                self.fill_column(x, j, cand, &mut rows, &mut vals);
                (rows, vals)
            },
        );
        let nnz: usize = cols.iter().map(|(r, _)| r.len()).sum();
        let mut col_ptr = Vec::with_capacity(n + 1);
        let mut row_idx = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        col_ptr.push(0);
        for (rows, vals) in cols {
            row_idx.extend(rows);
            values.extend(vals);
            col_ptr.push(row_idx.len());
        }
        CscMatrix { n_rows: n, n_cols: n, col_ptr, row_idx, values }
    }

    /// Shared kernel of the serial and parallel index-backed assemblies:
    /// evaluate column `j` over the candidate set, appending the surviving
    /// entries (exact `r < 1` test plus the diagonal).
    fn fill_column(
        &self,
        x: &[Vec<f64>],
        j: usize,
        cand: &[usize],
        rows: &mut Vec<usize>,
        vals: &mut Vec<f64>,
    ) {
        for &i in cand {
            if i == j {
                rows.push(i);
                vals.push(self.sigma2);
                continue;
            }
            let r = self.r(&x[i], &x[j]);
            if r < 1.0 {
                rows.push(i);
                vals.push(self.sigma2 * self.profile(r));
            }
        }
    }

    /// Covariance values re-evaluated on a *fixed* pattern (which may be a
    /// superset of the current support — out-of-support entries come out
    /// as exact zeros). This is the `PatternCache` hit path: `O(nnz)`
    /// kernel evaluations, no neighbor queries, no re-sorting.
    /// Each pattern entry is written by exactly one column task, so the
    /// pool-parallel evaluation is bitwise-identical to the serial sweep.
    pub fn cov_values_on_pattern(&self, x: &[Vec<f64>], pattern: &CscMatrix) -> CscMatrix {
        debug_assert_eq!(pattern.n_cols, x.len());
        let mut k = pattern.clone();
        let n_cols = k.n_cols;
        {
            let (col_ptr, row_idx) = (&k.col_ptr, &k.row_idx);
            let vs = crate::par::SyncSlice::new(&mut k.values);
            crate::par::for_chunks(
                n_cols,
                64,
                || (),
                |_, range| {
                    for j in range {
                        for p in col_ptr[j]..col_ptr[j + 1] {
                            let i = row_idx[p];
                            let v = if i == j {
                                self.sigma2
                            } else {
                                self.sigma2 * self.profile(self.r(&x[i], &x[j]))
                            };
                            // SAFETY: entry p lies in column j's range,
                            // owned by exactly this chunk.
                            unsafe { vs.set(p, v) };
                        }
                    }
                },
            );
        }
        k
    }

    /// Per-parameter gradient values aligned with an existing pattern:
    /// `grads[p][e]` is `∂K/∂θ_p` at pattern entry `e`. Entry slots are
    /// owned by their column's task, so the pool-parallel evaluation is
    /// bitwise-identical to the serial sweep.
    pub fn cov_grads_on_pattern(&self, x: &[Vec<f64>], pattern: &CscMatrix) -> Vec<Vec<f64>> {
        let np = self.n_params();
        let mut grads = vec![vec![0.0; pattern.nnz()]; np];
        {
            let slices: Vec<crate::par::SyncSlice<'_, f64>> =
                grads.iter_mut().map(|g| crate::par::SyncSlice::new(g)).collect();
            crate::par::for_chunks(
                pattern.n_cols,
                32,
                || vec![0.0; np],
                |g, range| {
                    for j in range {
                        for p in pattern.col_ptr[j]..pattern.col_ptr[j + 1] {
                            let i = pattern.row_idx[p];
                            self.kernel_grad(&x[i], &x[j], g);
                            for (q, &gq) in g.iter().enumerate() {
                                // SAFETY: entry p lies in column j's range,
                                // owned by exactly this chunk.
                                unsafe { slices[q].set(p, gq) };
                            }
                        }
                    }
                },
            );
        }
        grads
    }

    /// Covariance matrix plus per-parameter gradient values aligned with
    /// the matrix pattern: `grads[p][e]` is `∂K/∂θ_p` at pattern entry `e`.
    pub fn cov_matrix_grads(&self, x: &[Vec<f64>]) -> (CscMatrix, Vec<Vec<f64>>) {
        let k = self.cov_matrix(x);
        let grads = self.cov_grads_on_pattern(x, &k);
        (k, grads)
    }

    /// Sparse cross-covariance column k(X, x*): (row indices, values).
    pub fn cross_cov(&self, x: &[Vec<f64>], xstar: &[f64]) -> (Vec<usize>, Vec<f64>) {
        let mut rows = Vec::new();
        let mut vals = Vec::new();
        self.cross_cov_into(x, xstar, None, &mut rows, &mut vals);
        (rows, vals)
    }

    /// Cross-covariance written into caller-provided buffers (cleared
    /// first), optionally routed through a [`NeighborIndex`] built over
    /// `x` — the per-test-point cost then drops from `O(n)` to `O(k)` for
    /// compact kernels. Pattern and values match the brute path exactly.
    pub fn cross_cov_into(
        &self,
        x: &[Vec<f64>],
        xstar: &[f64],
        index: Option<&NeighborIndex>,
        rows: &mut Vec<usize>,
        vals: &mut Vec<f64>,
    ) {
        rows.clear();
        vals.clear();
        match (self.support_radius(), index) {
            (Some(radius), Some(idx)) => {
                debug_assert_eq!(idx.len(), x.len());
                // `rows` doubles as the candidate buffer (filtered and
                // compacted in place) so the serving hot path stays free
                // of per-call allocation.
                idx.neighbors_sorted(xstar, radius * (1.0 + RADIUS_PAD), rows);
                let mut kept = 0;
                for read in 0..rows.len() {
                    let i = rows[read];
                    let r = self.r(&x[i], xstar);
                    if r < 1.0 {
                        let v = self.sigma2 * self.profile(r);
                        if v != 0.0 {
                            rows[kept] = i;
                            vals.push(v);
                            kept += 1;
                        }
                    }
                }
                rows.truncate(kept);
            }
            _ => {
                let compact = self.is_compact();
                for (i, xi) in x.iter().enumerate() {
                    let r = self.r(xi, xstar);
                    if !compact || r < 1.0 {
                        let v = self.sigma2 * self.profile(r);
                        if v != 0.0 {
                            rows.push(i);
                            vals.push(v);
                        }
                    }
                }
            }
        }
    }
}

/// Additive two-kernel composition for the CS+FIC hybrid prior:
/// `k(x, x') = k_cs(x, x') + k_global(x, x')` with independent
/// hyperparameters for each term.
///
/// The CS term is kept exact and sparse (it drives the covariance
/// pattern, the cache and the symbolic factorization); the global term is
/// approximated by FIC inducing points in `gp::csfic`. Log-space
/// parameters are the concatenation `[cs: ln σ², ln l…, global: ln σ²,
/// ln l…]`, matching the optimizer layout of `Inference::CsFic`.
#[derive(Clone, Debug)]
pub struct AdditiveCov {
    /// Globally supported trend term (SE / Matérn).
    pub global: CovFunction,
    /// Compactly supported local term (Wendland pp0..pp3).
    pub cs: CovFunction,
}

impl AdditiveCov {
    pub fn new(global: CovFunction, cs: CovFunction) -> Result<AdditiveCov, String> {
        if global.input_dim != cs.input_dim {
            return Err(format!(
                "AdditiveCov: input dims differ ({} vs {})",
                global.input_dim, cs.input_dim
            ));
        }
        if !cs.is_compact() {
            return Err("AdditiveCov: the cs term must be compactly supported (pp0..pp3)".into());
        }
        if global.is_compact() {
            return Err("AdditiveCov: the global term must be globally supported".into());
        }
        Ok(AdditiveCov { global, cs })
    }

    pub fn n_params(&self) -> usize {
        self.cs.n_params() + self.global.n_params()
    }

    /// `[cs params…, global params…]` in log space.
    pub fn params(&self) -> Vec<f64> {
        let mut p = self.cs.params();
        p.extend(self.global.params());
        p
    }

    pub fn set_params(&mut self, p: &[f64]) {
        assert_eq!(p.len(), self.n_params());
        let nc = self.cs.n_params();
        self.cs.set_params(&p[..nc]);
        self.global.set_params(&p[nc..]);
    }

    /// k(x1, x2) = k_cs + k_global.
    pub fn kernel(&self, x1: &[f64], x2: &[f64]) -> f64 {
        self.cs.kernel(x1, x2) + self.global.kernel(x1, x2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::random_points;

    fn all_kinds() -> Vec<CovKind> {
        vec![
            CovKind::Se,
            CovKind::Pp(0),
            CovKind::Pp(1),
            CovKind::Pp(2),
            CovKind::Pp(3),
            CovKind::Matern32,
            CovKind::Matern52,
        ]
    }

    #[test]
    fn profile_at_zero_is_one() {
        for kind in all_kinds() {
            for dim in [1, 2, 5, 10] {
                let c = CovFunction::new(kind, dim, 1.7, 2.0);
                assert!(
                    (c.profile(0.0) - 1.0).abs() < 1e-12,
                    "{kind:?} D={dim}: {}",
                    c.profile(0.0)
                );
            }
        }
    }

    #[test]
    fn pp_vanish_beyond_support() {
        for q in 0..4u8 {
            let c = CovFunction::new(CovKind::Pp(q), 3, 1.0, 1.0);
            assert_eq!(c.profile(1.0), 0.0);
            assert_eq!(c.profile(1.5), 0.0);
            assert!(c.profile(0.999) > 0.0);
        }
    }

    #[test]
    fn profiles_decrease_monotonically() {
        for kind in all_kinds() {
            let c = CovFunction::new(kind, 2, 1.0, 1.0);
            let mut prev = c.profile(0.0);
            let mut r = 0.01;
            while r < 1.0 {
                let v = c.profile(r);
                assert!(v <= prev + 1e-12, "{kind:?} not decreasing at r={r}");
                prev = v;
                r += 0.01;
            }
        }
    }

    #[test]
    fn profile_deriv_matches_finite_difference() {
        for kind in all_kinds() {
            for dim in [1, 2, 5] {
                let c = CovFunction::new(kind, dim, 1.0, 1.0);
                for &r in &[0.05, 0.3, 0.7, 0.95, 1.2] {
                    let h = 1e-6;
                    let fd = (c.profile(r + h) - c.profile(r - h)) / (2.0 * h);
                    let an = c.profile_deriv(r);
                    assert!(
                        (fd - an).abs() < 1e-5 * (1.0 + an.abs()),
                        "{kind:?} D={dim} r={r}: fd={fd} an={an}"
                    );
                }
            }
        }
    }

    #[test]
    fn kernel_grad_matches_finite_difference() {
        let x = random_points(6, 3, 4.0, 11);
        for kind in all_kinds() {
            let mut c = CovFunction::new(kind, 3, 1.5, 2.5);
            c.lengthscales = vec![2.0, 3.0, 2.5];
            let p0 = c.params();
            let mut g = vec![0.0; c.n_params()];
            for i in 0..x.len() {
                for j in 0..x.len() {
                    if i == j {
                        continue;
                    }
                    c.kernel_grad(&x[i], &x[j], &mut g);
                    for p in 0..c.n_params() {
                        let h = 1e-6;
                        let mut cp = c.clone();
                        let mut pp = p0.clone();
                        pp[p] += h;
                        cp.set_params(&pp);
                        let kp = cp.kernel(&x[i], &x[j]);
                        pp[p] -= 2.0 * h;
                        cp.set_params(&pp);
                        let km = cp.kernel(&x[i], &x[j]);
                        let fd = (kp - km) / (2.0 * h);
                        assert!(
                            (fd - g[p]).abs() < 1e-5 * (1.0 + g[p].abs()),
                            "{kind:?} ({i},{j}) param {p}: fd={fd} an={}",
                            g[p]
                        );
                    }
                }
            }
        }
    }

    /// Anisotropic ARD length-scales plus exact duplicates and a pair
    /// sitting exactly on the support boundary (r == 1).
    fn tricky_points(dim: usize, seed: u64, ls: &[f64]) -> Vec<Vec<f64>> {
        let mut x = random_points(90, dim, 6.0, seed);
        x.push(x[3].clone()); // exact duplicate
        x.push(x[3].clone()); // triple
        // boundary pair: offset along the max-lengthscale axis by exactly
        // that lengthscale => ARD distance exactly 1 (excluded by r < 1,
        // returned by the inclusive index query — both paths must agree)
        let dmax = (0..dim).max_by(|&a, &b| ls[a].total_cmp(&ls[b])).unwrap();
        let mut origin = vec![0.0; dim];
        origin[0] = 0.25;
        let mut edge = origin.clone();
        edge[dmax] += ls[dmax];
        x.push(origin);
        x.push(edge);
        x
    }

    /// The exactness property the whole index-backed path rests on:
    /// identical pattern AND bitwise-identical values vs brute force, for
    /// every covariance kind, dims 1..=6, ARD anisotropy, duplicates and
    /// boundary pairs, on the auto-selected index and on both forced
    /// backends.
    #[test]
    fn indexed_assembly_matches_brute_force_exactly() {
        for dim in 1usize..=6 {
            for kind in all_kinds() {
                let mut cov = CovFunction::new(kind, dim, 1.3, 2.5);
                cov.lengthscales = (0..dim).map(|d| 0.75 + 0.5 * d as f64).collect();
                let x = tricky_points(dim, 40 + dim as u64, &cov.lengthscales);
                let brute = cov.cov_matrix_brute(&x);
                // public entry point (auto index above INDEX_MIN_N)
                assert_eq!(cov.cov_matrix(&x), brute, "{kind:?} dim {dim} (auto)");
                // explicit index, both backends, regardless of dimension
                for index in [
                    NeighborIndex::grid(&x, 1.1),
                    NeighborIndex::kdtree(&x),
                    NeighborIndex::build(&x, cov.support_radius().unwrap_or(1.0)),
                ] {
                    assert_eq!(
                        cov.cov_matrix_with(&x, &index),
                        brute,
                        "{kind:?} dim {dim} {index:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn indexed_cross_cov_matches_scan_exactly() {
        for dim in 1usize..=6 {
            for kind in all_kinds() {
                let mut cov = CovFunction::new(kind, dim, 0.9, 2.0);
                cov.lengthscales = (0..dim).map(|d| 2.1 - 0.2 * d as f64).collect();
                let x = tricky_points(dim, 70 + dim as u64, &cov.lengthscales);
                let index = NeighborIndex::build(&x, cov.support_radius().unwrap_or(1.0));
                let mut rows_i = Vec::new();
                let mut vals_i = Vec::new();
                let mut rows_s = Vec::new();
                let mut vals_s = Vec::new();
                // probe on-sample points (incl. the duplicates and the
                // boundary pair) and off-sample points
                let mut probes: Vec<Vec<f64>> = x.iter().rev().take(6).cloned().collect();
                probes.extend(random_points(6, dim, 7.0, 5));
                for q in &probes {
                    cov.cross_cov_into(&x, q, Some(&index), &mut rows_i, &mut vals_i);
                    cov.cross_cov_into(&x, q, None, &mut rows_s, &mut vals_s);
                    assert_eq!(rows_i, rows_s, "{kind:?} dim {dim}");
                    assert_eq!(vals_i, vals_s, "{kind:?} dim {dim}");
                }
            }
        }
    }

    #[test]
    fn grads_on_pattern_match_matrix_grads() {
        let x = random_points(80, 2, 8.0, 91);
        let c = CovFunction::new(CovKind::Pp(3), 2, 1.2, 1.7);
        let (k, grads) = c.cov_matrix_grads(&x);
        let on_pattern = c.cov_grads_on_pattern(&x, &k);
        assert_eq!(grads, on_pattern);
        // values re-filled on the same pattern reproduce the matrix
        assert_eq!(c.cov_values_on_pattern(&x, &k), k);
    }

    #[test]
    fn cov_matrix_is_spd_and_symmetric() {
        let x = random_points(40, 2, 10.0, 3);
        for kind in all_kinds() {
            let c = CovFunction::new(kind, 2, 1.0, 2.0);
            let k = c.cov_matrix(&x);
            assert!(k.check());
            assert!(k.is_symmetric(1e-12), "{kind:?} not symmetric");
            // jittered PD check (covariance matrices can be near-singular)
            let mut kd = k.to_dense();
            kd.add_diag(1e-8);
            assert!(kd.cholesky().is_ok(), "{kind:?} not PSD");
        }
    }

    #[test]
    fn cs_matrix_is_sparse_se_is_dense() {
        let x = random_points(60, 2, 10.0, 9);
        let cs = CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.5).cov_matrix(&x);
        let se = CovFunction::new(CovKind::Se, 2, 1.0, 1.5).cov_matrix(&x);
        assert!(cs.density() < 0.5, "CS density {}", cs.density());
        assert!((se.density() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn wendland_j_depends_on_dim() {
        let c2 = CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.0);
        let c10 = CovFunction::new(CovKind::Pp(3), 10, 1.0, 1.0);
        assert_eq!(c2.wendland_j(), 5.0);
        assert_eq!(c10.wendland_j(), 9.0);
        // correlation decays faster in higher dim at the same r (Fig 1)
        assert!(c10.profile(0.5) < c2.profile(0.5));
    }

    #[test]
    fn params_roundtrip() {
        let mut c = CovFunction::new(CovKind::Se, 3, 2.0, 1.5);
        let p = c.params();
        c.set_params(&p);
        assert!((c.sigma2 - 2.0).abs() < 1e-12);
        assert!(c.lengthscales.iter().all(|&l| (l - 1.5).abs() < 1e-12));
    }

    #[test]
    fn cross_cov_matches_kernel() {
        let x = random_points(20, 2, 5.0, 21);
        let c = CovFunction::new(CovKind::Pp(2), 2, 1.3, 2.0);
        let xs = vec![2.5, 2.5];
        let (rows, vals) = c.cross_cov(&x, &xs);
        for (&i, &v) in rows.iter().zip(&vals) {
            assert!((v - c.kernel(&x[i], &xs)).abs() < 1e-14);
        }
        // entries not listed are genuinely zero
        for i in 0..20 {
            if !rows.contains(&i) {
                assert_eq!(c.kernel(&x[i], &xs), 0.0);
            }
        }
    }

    #[test]
    fn additive_cov_is_the_sum_and_roundtrips_params() {
        let global = CovFunction::new(CovKind::Se, 2, 0.7, 3.0);
        let cs = CovFunction::new(CovKind::Pp(3), 2, 1.3, 1.5);
        let mut add = AdditiveCov::new(global.clone(), cs.clone()).unwrap();
        assert_eq!(add.n_params(), 6);
        let x = random_points(10, 2, 5.0, 3);
        for i in 0..x.len() {
            for j in 0..x.len() {
                let want = cs.kernel(&x[i], &x[j]) + global.kernel(&x[i], &x[j]);
                assert!((add.kernel(&x[i], &x[j]) - want).abs() < 1e-14);
            }
        }
        let p = add.params();
        assert_eq!(&p[..3], cs.params().as_slice());
        assert_eq!(&p[3..], global.params().as_slice());
        add.set_params(&p);
        assert!((add.cs.sigma2 - 1.3).abs() < 1e-12);
        assert!((add.global.sigma2 - 0.7).abs() < 1e-12);
        // validation: both-compact or both-global compositions are rejected
        assert!(AdditiveCov::new(cs.clone(), cs.clone()).is_err());
        assert!(AdditiveCov::new(global.clone(), global.clone()).is_err());
        assert!(AdditiveCov::new(CovFunction::new(CovKind::Se, 3, 1.0, 1.0), cs).is_err());
    }

    #[test]
    fn cov_matrix_grads_align_with_pattern() {
        let x = random_points(15, 2, 6.0, 31);
        let c = CovFunction::new(CovKind::Pp(3), 2, 1.0, 2.0);
        let (k, grads) = c.cov_matrix_grads(&x);
        assert_eq!(grads.len(), 3);
        for g in &grads {
            assert_eq!(g.len(), k.nnz());
        }
        // d/d ln σ² equals K itself
        for (e, &v) in k.values.iter().enumerate() {
            assert!((grads[0][e] - v).abs() < 1e-13);
        }
    }
}
