//! Covariance functions: the globally-supported squared exponential and
//! Matérn family, and the compactly supported Wendland piecewise
//! polynomials `k_pp,q` of the paper (eqs. 7–10).
//!
//! All functions are radial: `k(x, x') = σ² φ(r)` with the ARD distance
//! `r = sqrt(Σ_d (x_d − x'_d)² / l_d²)`. CS functions vanish exactly for
//! `r ≥ 1`, which is what makes the covariance matrix sparse; the Wendland
//! exponent `j = ⌊D/2⌋ + q + 1` ties the polynomial degree to the input
//! dimension `D` to keep the function positive definite (Wendland 2005).
//!
//! Hyperparameters are handled in log space throughout
//! (`params = [ln σ², ln l₁, …, ln l_D]`), matching how the optimizer and
//! the priors operate.

use crate::sparse::csc::CscMatrix;

/// Which radial profile.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CovKind {
    /// Squared exponential, `σ² exp(−r²)` (paper eq. 1 — note no ½).
    Se,
    /// Wendland piecewise polynomial of smoothness q ∈ {0, 1, 2, 3}.
    Pp(u8),
    /// Matérn ν = 3/2.
    Matern32,
    /// Matérn ν = 5/2.
    Matern52,
}

impl CovKind {
    pub fn name(&self) -> String {
        match self {
            CovKind::Se => "se".into(),
            CovKind::Pp(q) => format!("pp{q}"),
            CovKind::Matern32 => "matern32".into(),
            CovKind::Matern52 => "matern52".into(),
        }
    }

    pub fn parse(s: &str) -> Result<CovKind, String> {
        match s {
            "se" => Ok(CovKind::Se),
            "pp0" => Ok(CovKind::Pp(0)),
            "pp1" => Ok(CovKind::Pp(1)),
            "pp2" => Ok(CovKind::Pp(2)),
            "pp3" => Ok(CovKind::Pp(3)),
            "matern32" => Ok(CovKind::Matern32),
            "matern52" => Ok(CovKind::Matern52),
            other => Err(format!("unknown covariance '{other}'")),
        }
    }
}

/// A covariance function with its hyperparameters.
#[derive(Clone, Debug)]
pub struct CovFunction {
    pub kind: CovKind,
    /// Input dimension D (sets the Wendland exponent j).
    pub input_dim: usize,
    /// Magnitude σ².
    pub sigma2: f64,
    /// ARD length-scales, one per input dimension.
    pub lengthscales: Vec<f64>,
}

impl CovFunction {
    pub fn new(kind: CovKind, input_dim: usize, sigma2: f64, lengthscale: f64) -> CovFunction {
        CovFunction { kind, input_dim, sigma2, lengthscales: vec![lengthscale; input_dim] }
    }

    /// Is the support compact (k ≡ 0 for r ≥ 1)?
    pub fn is_compact(&self) -> bool {
        matches!(self.kind, CovKind::Pp(_))
    }

    /// Wendland exponent j = ⌊D/2⌋ + q + 1.
    pub fn wendland_j(&self) -> f64 {
        match self.kind {
            CovKind::Pp(q) => (self.input_dim / 2) as f64 + q as f64 + 1.0,
            _ => panic!("wendland_j on non-pp covariance"),
        }
    }

    // ---- log-parameter plumbing ------------------------------------------

    pub fn n_params(&self) -> usize {
        1 + self.lengthscales.len()
    }

    /// `[ln σ², ln l₁, …, ln l_D]`.
    pub fn params(&self) -> Vec<f64> {
        let mut p = Vec::with_capacity(self.n_params());
        p.push(self.sigma2.ln());
        p.extend(self.lengthscales.iter().map(|l| l.ln()));
        p
    }

    pub fn set_params(&mut self, p: &[f64]) {
        assert_eq!(p.len(), self.n_params());
        self.sigma2 = p[0].exp();
        for (l, &lp) in self.lengthscales.iter_mut().zip(&p[1..]) {
            *l = lp.exp();
        }
    }

    // ---- radial profile ---------------------------------------------------

    /// Scaled distance r between two points.
    #[inline]
    pub fn r(&self, x1: &[f64], x2: &[f64]) -> f64 {
        let mut r2 = 0.0;
        for d in 0..x1.len() {
            let diff = (x1[d] - x2[d]) / self.lengthscales[d];
            r2 += diff * diff;
        }
        r2.sqrt()
    }

    /// Unit-magnitude radial profile φ(r) (so k = σ² φ(r)).
    pub fn profile(&self, r: f64) -> f64 {
        match self.kind {
            CovKind::Se => (-r * r).exp(),
            CovKind::Matern32 => {
                let a = 3f64.sqrt() * r;
                (1.0 + a) * (-a).exp()
            }
            CovKind::Matern52 => {
                let a = 5f64.sqrt() * r;
                (1.0 + a + a * a / 3.0) * (-a).exp()
            }
            CovKind::Pp(q) => {
                if r >= 1.0 {
                    return 0.0;
                }
                let j = self.wendland_j();
                let u = 1.0 - r;
                match q {
                    0 => u.powf(j),
                    1 => u.powf(j + 1.0) * ((j + 1.0) * r + 1.0),
                    2 => {
                        let a = j * j + 4.0 * j + 3.0;
                        let b = 3.0 * j + 6.0;
                        u.powf(j + 2.0) * (a * r * r + b * r + 3.0) / 3.0
                    }
                    3 => {
                        let a = j * j * j + 9.0 * j * j + 23.0 * j + 15.0;
                        let b = 6.0 * j * j + 36.0 * j + 45.0;
                        let c = 15.0 * j + 45.0;
                        u.powf(j + 3.0) * (a * r * r * r + b * r * r + c * r + 15.0) / 15.0
                    }
                    _ => panic!("pp q must be 0..=3"),
                }
            }
        }
    }

    /// dφ/dr.
    pub fn profile_deriv(&self, r: f64) -> f64 {
        match self.kind {
            CovKind::Se => -2.0 * r * (-r * r).exp(),
            CovKind::Matern32 => {
                let s = 3f64.sqrt();
                let a = s * r;
                // d/dr[(1+a)e^{-a}] = -s*a*e^{-a}
                -s * a * (-a).exp()
            }
            CovKind::Matern52 => {
                let s = 5f64.sqrt();
                let a = s * r;
                // d/dr[(1+a+a²/3)e^{-a}] = -(s/3)a(1+a)e^{-a}
                -(s / 3.0) * a * (1.0 + a) * (-a).exp()
            }
            CovKind::Pp(q) => {
                if r >= 1.0 {
                    return 0.0;
                }
                let j = self.wendland_j();
                let u = 1.0 - r;
                match q {
                    0 => -j * u.powf(j - 1.0),
                    1 => {
                        // product rule on u^{j+1}((j+1)r+1)
                        -(j + 1.0) * u.powf(j) * ((j + 1.0) * r + 1.0)
                            + u.powf(j + 1.0) * (j + 1.0)
                    }
                    2 => {
                        let a = j * j + 4.0 * j + 3.0;
                        let b = 3.0 * j + 6.0;
                        (-(j + 2.0) * u.powf(j + 1.0) * (a * r * r + b * r + 3.0)
                            + u.powf(j + 2.0) * (2.0 * a * r + b))
                            / 3.0
                    }
                    3 => {
                        let a = j * j * j + 9.0 * j * j + 23.0 * j + 15.0;
                        let b = 6.0 * j * j + 36.0 * j + 45.0;
                        let c = 15.0 * j + 45.0;
                        (-(j + 3.0) * u.powf(j + 2.0) * (a * r * r * r + b * r * r + c * r + 15.0)
                            + u.powf(j + 3.0) * (3.0 * a * r * r + 2.0 * b * r + c))
                            / 15.0
                    }
                    _ => panic!("pp q must be 0..=3"),
                }
            }
        }
    }

    /// k(x1, x2).
    #[inline]
    pub fn kernel(&self, x1: &[f64], x2: &[f64]) -> f64 {
        self.sigma2 * self.profile(self.r(x1, x2))
    }

    /// k(x1, x2) plus the gradient w.r.t. the log parameters
    /// `[ln σ², ln l₁, …]` written into `grad`.
    pub fn kernel_grad(&self, x1: &[f64], x2: &[f64], grad: &mut [f64]) -> f64 {
        debug_assert_eq!(grad.len(), self.n_params());
        let r = self.r(x1, x2);
        let phi = self.profile(r);
        let k = self.sigma2 * phi;
        grad[0] = k; // d/d ln σ² = k
        if r == 0.0 {
            for g in grad[1..].iter_mut() {
                *g = 0.0;
            }
            return k;
        }
        let dphi = self.profile_deriv(r);
        for d in 0..self.lengthscales.len() {
            let diff = (x1[d] - x2[d]) / self.lengthscales[d];
            // dr/d ln l_d = −diff² / r
            grad[1 + d] = self.sigma2 * dphi * (-(diff * diff) / r);
        }
        k
    }

    // ---- matrix assembly --------------------------------------------------

    /// Full-storage CSC covariance matrix of `x`. For compact support only
    /// pairs with r < 1 are stored (plus the diagonal); globally supported
    /// functions yield a dense pattern.
    pub fn cov_matrix(&self, x: &[Vec<f64>]) -> CscMatrix {
        let n = x.len();
        let compact = self.is_compact();
        let mut col_ptr = Vec::with_capacity(n + 1);
        let mut row_idx = Vec::new();
        let mut values = Vec::new();
        col_ptr.push(0);
        for j in 0..n {
            for (i, xi) in x.iter().enumerate() {
                if i == j {
                    row_idx.push(i);
                    values.push(self.sigma2);
                    continue;
                }
                if compact {
                    let r = self.r(xi, &x[j]);
                    if r < 1.0 {
                        row_idx.push(i);
                        values.push(self.sigma2 * self.profile(r));
                    }
                } else {
                    row_idx.push(i);
                    values.push(self.kernel(xi, &x[j]));
                }
            }
            col_ptr.push(row_idx.len());
        }
        CscMatrix { n_rows: n, n_cols: n, col_ptr, row_idx, values }
    }

    /// Covariance matrix plus per-parameter gradient values aligned with
    /// the matrix pattern: `grads[p][e]` is `∂K/∂θ_p` at pattern entry `e`.
    pub fn cov_matrix_grads(&self, x: &[Vec<f64>]) -> (CscMatrix, Vec<Vec<f64>>) {
        let k = self.cov_matrix(x);
        let np = self.n_params();
        let mut grads = vec![Vec::with_capacity(k.nnz()); np];
        let mut g = vec![0.0; np];
        for j in 0..k.n_cols {
            let (rows, _) = k.col(j);
            for &i in rows {
                self.kernel_grad(&x[i], &x[j], &mut g);
                for (p, gp) in g.iter().enumerate() {
                    grads[p].push(*gp);
                }
            }
        }
        (k, grads)
    }

    /// Sparse cross-covariance column k(X, x*): (row indices, values).
    pub fn cross_cov(&self, x: &[Vec<f64>], xstar: &[f64]) -> (Vec<usize>, Vec<f64>) {
        let mut rows = Vec::new();
        let mut vals = Vec::new();
        let compact = self.is_compact();
        for (i, xi) in x.iter().enumerate() {
            let r = self.r(xi, xstar);
            if !compact || r < 1.0 {
                let v = self.sigma2 * self.profile(r);
                if v != 0.0 {
                    rows.push(i);
                    vals.push(v);
                }
            }
        }
        (rows, vals)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::random_points;

    fn all_kinds() -> Vec<CovKind> {
        vec![
            CovKind::Se,
            CovKind::Pp(0),
            CovKind::Pp(1),
            CovKind::Pp(2),
            CovKind::Pp(3),
            CovKind::Matern32,
            CovKind::Matern52,
        ]
    }

    #[test]
    fn profile_at_zero_is_one() {
        for kind in all_kinds() {
            for dim in [1, 2, 5, 10] {
                let c = CovFunction::new(kind, dim, 1.7, 2.0);
                assert!(
                    (c.profile(0.0) - 1.0).abs() < 1e-12,
                    "{kind:?} D={dim}: {}",
                    c.profile(0.0)
                );
            }
        }
    }

    #[test]
    fn pp_vanish_beyond_support() {
        for q in 0..4u8 {
            let c = CovFunction::new(CovKind::Pp(q), 3, 1.0, 1.0);
            assert_eq!(c.profile(1.0), 0.0);
            assert_eq!(c.profile(1.5), 0.0);
            assert!(c.profile(0.999) > 0.0);
        }
    }

    #[test]
    fn profiles_decrease_monotonically() {
        for kind in all_kinds() {
            let c = CovFunction::new(kind, 2, 1.0, 1.0);
            let mut prev = c.profile(0.0);
            let mut r = 0.01;
            while r < 1.0 {
                let v = c.profile(r);
                assert!(v <= prev + 1e-12, "{kind:?} not decreasing at r={r}");
                prev = v;
                r += 0.01;
            }
        }
    }

    #[test]
    fn profile_deriv_matches_finite_difference() {
        for kind in all_kinds() {
            for dim in [1, 2, 5] {
                let c = CovFunction::new(kind, dim, 1.0, 1.0);
                for &r in &[0.05, 0.3, 0.7, 0.95, 1.2] {
                    let h = 1e-6;
                    let fd = (c.profile(r + h) - c.profile(r - h)) / (2.0 * h);
                    let an = c.profile_deriv(r);
                    assert!(
                        (fd - an).abs() < 1e-5 * (1.0 + an.abs()),
                        "{kind:?} D={dim} r={r}: fd={fd} an={an}"
                    );
                }
            }
        }
    }

    #[test]
    fn kernel_grad_matches_finite_difference() {
        let x = random_points(6, 3, 4.0, 11);
        for kind in all_kinds() {
            let mut c = CovFunction::new(kind, 3, 1.5, 2.5);
            c.lengthscales = vec![2.0, 3.0, 2.5];
            let p0 = c.params();
            let mut g = vec![0.0; c.n_params()];
            for i in 0..x.len() {
                for j in 0..x.len() {
                    if i == j {
                        continue;
                    }
                    c.kernel_grad(&x[i], &x[j], &mut g);
                    for p in 0..c.n_params() {
                        let h = 1e-6;
                        let mut cp = c.clone();
                        let mut pp = p0.clone();
                        pp[p] += h;
                        cp.set_params(&pp);
                        let kp = cp.kernel(&x[i], &x[j]);
                        pp[p] -= 2.0 * h;
                        cp.set_params(&pp);
                        let km = cp.kernel(&x[i], &x[j]);
                        let fd = (kp - km) / (2.0 * h);
                        assert!(
                            (fd - g[p]).abs() < 1e-5 * (1.0 + g[p].abs()),
                            "{kind:?} ({i},{j}) param {p}: fd={fd} an={}",
                            g[p]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn cov_matrix_is_spd_and_symmetric() {
        let x = random_points(40, 2, 10.0, 3);
        for kind in all_kinds() {
            let c = CovFunction::new(kind, 2, 1.0, 2.0);
            let k = c.cov_matrix(&x);
            assert!(k.check());
            assert!(k.is_symmetric(1e-12), "{kind:?} not symmetric");
            // jittered PD check (covariance matrices can be near-singular)
            let mut kd = k.to_dense();
            kd.add_diag(1e-8);
            assert!(kd.cholesky().is_ok(), "{kind:?} not PSD");
        }
    }

    #[test]
    fn cs_matrix_is_sparse_se_is_dense() {
        let x = random_points(60, 2, 10.0, 9);
        let cs = CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.5).cov_matrix(&x);
        let se = CovFunction::new(CovKind::Se, 2, 1.0, 1.5).cov_matrix(&x);
        assert!(cs.density() < 0.5, "CS density {}", cs.density());
        assert!((se.density() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn wendland_j_depends_on_dim() {
        let c2 = CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.0);
        let c10 = CovFunction::new(CovKind::Pp(3), 10, 1.0, 1.0);
        assert_eq!(c2.wendland_j(), 5.0);
        assert_eq!(c10.wendland_j(), 9.0);
        // correlation decays faster in higher dim at the same r (Fig 1)
        assert!(c10.profile(0.5) < c2.profile(0.5));
    }

    #[test]
    fn params_roundtrip() {
        let mut c = CovFunction::new(CovKind::Se, 3, 2.0, 1.5);
        let p = c.params();
        c.set_params(&p);
        assert!((c.sigma2 - 2.0).abs() < 1e-12);
        assert!(c.lengthscales.iter().all(|&l| (l - 1.5).abs() < 1e-12));
    }

    #[test]
    fn cross_cov_matches_kernel() {
        let x = random_points(20, 2, 5.0, 21);
        let c = CovFunction::new(CovKind::Pp(2), 2, 1.3, 2.0);
        let xs = vec![2.5, 2.5];
        let (rows, vals) = c.cross_cov(&x, &xs);
        for (&i, &v) in rows.iter().zip(&vals) {
            assert!((v - c.kernel(&x[i], &xs)).abs() < 1e-14);
        }
        // entries not listed are genuinely zero
        for i in 0..20 {
            if !rows.contains(&i) {
                assert_eq!(c.kernel(&x[i], &xs), 0.0);
            }
        }
    }

    #[test]
    fn cov_matrix_grads_align_with_pattern() {
        let x = random_points(15, 2, 6.0, 31);
        let c = CovFunction::new(CovKind::Pp(3), 2, 1.0, 2.0);
        let (k, grads) = c.cov_matrix_grads(&x);
        assert_eq!(grads.len(), 3);
        for g in &grads {
            assert_eq!(g.len(), k.nnz());
        }
        // d/d ln σ² equals K itself
        for (e, &v) in k.values.iter().enumerate() {
            assert!((grads[0][e] - v).abs() < 1e-13);
        }
    }
}
