//! CS+FIC hybrid EP — local structure through a compactly supported
//! kernel, global trends through FIC inducing points.
//!
//! Prior covariance (Vanhatalo & Vehtari 2008, *Modelling local and
//! global phenomena with sparse Gaussian processes*):
//!
//! ```text
//! P = K_cs + Λ + U Uᵀ,   U = K_fu L_uu⁻ᵀ  (so U Uᵀ = Q, the FIC
//!                                          approximation of k_global)
//! Λ = diag(k_g(xᵢ,xᵢ) − qᵢᵢ)              (exact global diagonal)
//! ```
//!
//! `K_cs` is the sparse Wendland Gram matrix on the `PatternCache`
//! structure; the global term is rank-m. EP runs parallel (batched,
//! damped) site updates, and every posterior quantity flows through the
//! [`SparseLowRank`] factorization of
//! `B = I + S̃^{1/2} P S̃^{1/2} = S_B + Us Usᵀ` with
//! `S_B = I + S̃^{1/2}(K_cs + Λ)S̃^{1/2}` on the CS pattern and
//! `Us = S̃^{1/2} U`. A sweep costs `O(n·(solve + k·m + m²) + m·nnz(L))`
//! — the n×n prior is never assembled and no dense n×n matrix is ever
//! materialized.

use std::sync::Arc;

use crate::gp::cache::{GradScratch, PatternCache};
use crate::gp::covariance::AdditiveCov;
use crate::gp::likelihood::SiteBatch;
use crate::gp::marginal::{ep_log_z, grad_quadratic_term, EpOptions, EpSites};
use crate::gp::predict::PredictWorkspace;
use crate::sparse::cholesky::LdlFactor;
use crate::sparse::csc::CscMatrix;
use crate::sparse::dense::{DenseCholesky, DenseMatrix};
use crate::sparse::lowrank::{InversePatternScratch, SparseLowRank};
use crate::sparse::ordering::Ordering;
use crate::sparse::triangular::SparseSolveWorkspace;

/// Converged CS+FIC EP state (sparse quantities live in the *permuted*
/// index space, like `SparseEp`).
pub struct CsFicEp {
    /// old index -> permuted index (shared with the `PatternCache` plan).
    pub perm: Arc<Vec<usize>>,
    /// Permuted inputs (cross-covariances are built against these).
    pub xp: Arc<Vec<Vec<f64>>>,
    /// Both kernels at the hyperparameters EP ran at.
    pub cov: AdditiveCov,
    /// Sparse CS covariance on the (cached, possibly superset) pattern.
    pub k_cs: CscMatrix,
    /// FIC diagonal correction Λ (permuted order).
    pub lambda: Vec<f64>,
    /// Inducing inputs.
    pub xu: Vec<Vec<f64>>,
    /// Site state, permuted order.
    pub sites: EpSites,
    pub log_z: f64,
    /// Posterior mean (permuted).
    pub mu: Vec<f64>,
    /// Posterior marginal variances (permuted).
    pub sigma_diag: Vec<f64>,
    /// Representer weights (permuted): latent mean is `p*ᵀ w_pred`.
    pub w_pred: Vec<f64>,
    pub sweeps: usize,
    pub converged: bool,
    /// fill statistics of the CS block (for the paper-style tables)
    pub fill_k: f64,
    pub fill_l: f64,
    /// Cholesky of `K_uu + jitter`.
    luu: DenseCholesky,
    /// Woodbury solver of `B` at convergence.
    solver: SparseLowRank,
    /// `Uᵀ w_pred` (m) — low-rank half of the predictive mean.
    p_mean: Vec<f64>,
    /// `Usᵀ B⁻¹ Us` (m×m) — low-rank block of the predictive variance.
    m2: DenseMatrix,
}

impl CsFicEp {
    /// Run CS+FIC EP with a private, throwaway [`PatternCache`] (auto
    /// ordering policy on the CS block). Optimizer loops should hold a
    /// cache and call [`CsFicEp::run_cached`].
    pub fn run(
        cov: &AdditiveCov,
        x: &[Vec<f64>],
        y: &[f64],
        xu: &[Vec<f64>],
        opts: &EpOptions,
    ) -> Result<CsFicEp, String> {
        let mut cache = PatternCache::new(Ordering::Auto);
        CsFicEp::run_cached(cov, x, y, xu, opts, None, &mut cache)
    }

    /// Run CS+FIC EP reusing `cache`'s CS structure (pattern, permutation,
    /// symbolic analysis — keyed by `cov.cs` only; the global term never
    /// affects the sparsity). `warm_start` sites are given in the
    /// *original* index order (see [`CsFicEp::sites_unpermuted`]), so a
    /// warm start stays valid even when a cache rebuild changes the
    /// permutation.
    pub fn run_cached(
        cov: &AdditiveCov,
        x: &[Vec<f64>],
        y: &[f64],
        xu: &[Vec<f64>],
        opts: &EpOptions,
        warm_start: Option<&EpSites>,
        cache: &mut PatternCache,
    ) -> Result<CsFicEp, String> {
        let n = x.len();
        assert_eq!(y.len(), n);
        let m = xu.len();
        assert!(m >= 1 && m <= n, "need 1 <= m <= n inducing inputs");

        // ---- sparse CS structure through the shared pattern cache -------
        let (_, plan) = cache.plan_for(&cov.cs, x);
        let k_cs = cov.cs.cov_values_on_pattern(&plan.xp, &plan.pattern_perm);
        let perm = plan.perm.clone(); // Arc handle, not a deep copy
        let xp = plan.xp.clone();
        let mut yp = vec![0.0; n];
        for old in 0..n {
            yp[perm[old]] = y[old];
        }
        let fill_k = k_cs.density();
        let fill_l = plan.symbolic.fill_l();

        // ---- global low-rank structure (FIC over the permuted inputs) ---
        let jitter = 1e-8 * cov.global.sigma2;
        let mut kuu = DenseMatrix::from_fn(m, m, |a, b| cov.global.kernel(&xu[a], &xu[b]));
        kuu.add_diag(jitter);
        let luu = kuu.cholesky().map_err(|e| format!("K_uu: {e}"))?;
        let u = build_fic_factor(&cov.global, xp.as_slice(), xu, &luu);
        let lambda: Vec<f64> = (0..n)
            .map(|i| {
                let q: f64 = u.row(i).iter().map(|v| v * v).sum();
                (cov.global.sigma2 - q).max(1e-10)
            })
            .collect();

        // ---- EP state ---------------------------------------------------
        let mut sites = match warm_start {
            Some(w) => {
                assert_eq!(w.tau.len(), n, "warm-start sites must match n");
                w.permuted(&perm)
            }
            None => EpSites::zeros(n),
        };
        // CS+FIC EP is a batched update, so it needs heavier damping than
        // the sequential sweep; the working value halves on every
        // divergence rollback.
        let mut damping = opts.effective_damping(0.8);
        let mut monitor = crate::gp::marginal::DivergenceMonitor::new();
        let mut recoveries = 0usize;
        let mut mu = vec![0.0; n];
        let mut sigma_diag = vec![0.0; n];
        let mut gamma = vec![0.0; n];

        // B = S_B + Us Usᵀ; the initial refresh sets the prior (or
        // warm-started) marginals — for all-zero sites S_B = I, Us = 0.
        let sb = build_sparse_b(&k_cs, &lambda, &sites.tau);
        let us0 = scaled_u(&u, &sites.tau);
        let mut solver = SparseLowRank::new(&sb, plan.symbolic.clone(), us0)?;
        let mut m2 = refresh_posterior(
            &k_cs,
            &lambda,
            &u,
            &solver,
            &sites,
            &mut gamma,
            &mut mu,
            &mut sigma_diag,
        );

        let mut log_z = f64::NEG_INFINITY;
        let mut log_z_old = f64::NEG_INFINITY;
        let mut sweeps = 0;
        let mut converged = false;
        let mut batch = SiteBatch::new();

        // Last-good snapshot for rollback: sites plus the marginals the
        // next sweep's batched update reads (the starting state — prior or
        // warm start — is taken as healthy).
        let mut snap_sites = sites.clone();
        let mut snap_gamma = gamma.clone();
        let mut snap_mu = mu.clone();
        let mut snap_sigma = sigma_diag.clone();
        let mut snap_m2 = m2.clone();
        let mut snap_log_z = log_z;

        while sweeps < opts.max_sweeps {
            // per-sweep convergence telemetry, observed only (see ep_parallel)
            let track = crate::obs::counters_on();
            let mut sweep_span = crate::obs::span("ep.sweep");
            let mut max_site_delta = 0.0f64;
            let mut updated = 0u64;
            let mut skipped = 0u64;
            // batched (parallel-EP) site updates from the current marginals
            batch.update(&yp, &mu, &sigma_diag, &sites.tau, &sites.nu);
            for i in 0..n {
                if !batch.valid[i] {
                    continue;
                }
                let (tau_old, nu_old) = (sites.tau[i], sites.nu[i]);
                let mut tau_new = batch.tau_new[i];
                if crate::fault::should_poison_site(sweeps, i) {
                    tau_new = f64::NAN;
                }
                let tau_next = damping * tau_new + (1.0 - damping) * tau_old;
                let nu_next = damping * batch.nu_new[i] + (1.0 - damping) * nu_old;
                // Per-site recovery guard (same contract as the other EP
                // backends): a non-finite or negative site precision is
                // not merged; the sweep-end rollback repairs the
                // trajectory. `batch.valid` already filters the likelihood
                // kernel's own rejects — only these new guards count
                // toward recovery telemetry.
                if !tau_next.is_finite() || !nu_next.is_finite() || tau_next < 0.0 {
                    crate::obs::counters::EP_SKIPPED_SITES.add(1);
                    skipped += 1;
                    continue;
                }
                sites.ln_zhat[i] = batch.ln_zhat[i];
                sites.tau_cav[i] = batch.tau_cav[i];
                sites.nu_cav[i] = batch.nu_cav[i];
                sites.tau[i] = tau_next;
                sites.nu[i] = nu_next;
                // max_site_delta feeds the divergence monitor, so it is
                // tracked unconditionally (not gated on trace mode).
                let delta = (tau_next - tau_old).abs().max((nu_next - nu_old).abs());
                max_site_delta = max_site_delta.max(delta);
                if track {
                    updated += 1;
                }
            }

            // one refactor of B = S_B + Us Usᵀ for the whole batch. A
            // refresh failure (pivot loss on this site state) is treated
            // as divergence: the rollback below rebuilds the solver from
            // the last-good sites instead of erroring out.
            let sb = build_sparse_b(&k_cs, &lambda, &sites.tau);
            let refresh_err = solver.refresh(&sb, scaled_u(&u, &sites.tau)).err();
            if refresh_err.is_none() {
                m2 = refresh_posterior(
                    &k_cs,
                    &lambda,
                    &u,
                    &solver,
                    &sites,
                    &mut gamma,
                    &mut mu,
                    &mut sigma_diag,
                );
            }

            sweeps += 1;
            if refresh_err.is_none() {
                let nu_dot_mu: f64 =
                    sites.nu.iter().zip(&mu).map(|(a, b)| a * b).sum();
                log_z = ep_log_z(&sites, solver.logdet(), nu_dot_mu);
            }
            let diverged = refresh_err.is_some()
                || skipped > 0
                || monitor.diverged(log_z, max_site_delta, opts);
            if track {
                crate::obs::counters::EP_SWEEPS.add(1);
                crate::obs::counters::EP_SITE_VISITS.add(n as u64);
                crate::obs::counters::EP_DAMPED_UPDATES.add(updated);
            }
            if sweep_span.is_active() {
                sweep_span.field_str("backend", "csfic");
                sweep_span.field_u64("sweep", sweeps as u64);
                sweep_span.field_f64("logz", log_z);
                sweep_span.field_f64("dlogz", log_z - log_z_old);
                sweep_span.field_f64("max_site_delta", max_site_delta);
                sweep_span.field_u64("damped_updates", updated);
                sweep_span.field_f64("damping", damping);
                sweep_span.field_u64("skipped_sites", skipped);
                sweep_span.field_bool("rolled_back", diverged);
            }
            if diverged {
                // Roll back to the last-good snapshot and halve the
                // damping before trying again (the sweep ordinal keeps
                // advancing, so a one-shot injected fault is not re-hit).
                if recoveries >= opts.max_recoveries {
                    return Err(refresh_err.unwrap_or_else(|| {
                        format!(
                            "EP diverged at sweep {sweeps} with the recovery \
                             budget ({}) exhausted",
                            opts.max_recoveries
                        )
                    }));
                }
                recoveries += 1;
                crate::obs::counters::EP_ROLLBACKS.add(1);
                damping = (0.5 * damping).max(opts.min_damping);
                sites.clone_from(&snap_sites);
                gamma.clone_from(&snap_gamma);
                mu.clone_from(&snap_mu);
                sigma_diag.clone_from(&snap_sigma);
                m2 = snap_m2.clone();
                let sb = build_sparse_b(&k_cs, &lambda, &sites.tau);
                solver.refresh(&sb, scaled_u(&u, &sites.tau))?;
                log_z = snap_log_z;
                continue;
            }
            snap_sites.clone_from(&sites);
            snap_gamma.clone_from(&gamma);
            snap_mu.clone_from(&mu);
            snap_sigma.clone_from(&sigma_diag);
            snap_m2.clone_from(&m2);
            snap_log_z = log_z;
            if (log_z - log_z_old).abs() < opts.tol {
                converged = true;
                break;
            }
            log_z_old = log_z;
        }

        // representer weights w = ν̃ − S̃^{1/2} B⁻¹ S̃^{1/2} γ and the
        // low-rank prediction blocks
        let sw: Vec<f64> = sites.tau.iter().map(|&v| v.max(0.0).sqrt()).collect();
        let swg: Vec<f64> = (0..n).map(|i| sw[i] * gamma[i]).collect();
        let bswg = solver.solve(&swg);
        let w_pred: Vec<f64> = (0..n).map(|i| sites.nu[i] - sw[i] * bswg[i]).collect();
        let p_mean: Vec<f64> =
            (0..m).map(|a| (0..n).map(|i| u.at(i, a) * w_pred[i]).sum()).collect();

        Ok(CsFicEp {
            perm,
            xp,
            cov: cov.clone(),
            k_cs,
            lambda,
            xu: xu.to_vec(),
            sites,
            log_z,
            mu,
            sigma_diag,
            w_pred,
            sweeps,
            converged,
            fill_k,
            fill_l,
            luu,
            solver,
            p_mean,
            m2,
        })
    }

    /// Sites in the original (unpermuted) index order — the warm-start
    /// currency, valid across cache rebuilds that change the permutation.
    pub fn sites_unpermuted(&self) -> EpSites {
        self.sites.unpermuted(&self.perm)
    }

    /// The private posterior blocks, for the snapshot writer
    /// (`gp::snapshot`): `(L_uu, Woodbury solver, p_mean, M₂)`.
    pub(crate) fn saved_parts(
        &self,
    ) -> (&DenseCholesky, &SparseLowRank, &[f64], &DenseMatrix) {
        (&self.luu, &self.solver, &self.p_mean, &self.m2)
    }

    /// Reassemble a converged state from snapshotted parts — every field
    /// is restored verbatim; no EP sweeps, no factorizations.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_saved(
        perm: Arc<Vec<usize>>,
        xp: Arc<Vec<Vec<f64>>>,
        cov: AdditiveCov,
        k_cs: CscMatrix,
        lambda: Vec<f64>,
        xu: Vec<Vec<f64>>,
        sites: EpSites,
        log_z: f64,
        mu: Vec<f64>,
        sigma_diag: Vec<f64>,
        w_pred: Vec<f64>,
        sweeps: usize,
        converged: bool,
        fill_k: f64,
        fill_l: f64,
        luu: DenseCholesky,
        solver: SparseLowRank,
        p_mean: Vec<f64>,
        m2: DenseMatrix,
    ) -> CsFicEp {
        CsFicEp {
            perm,
            xp,
            cov,
            k_cs,
            lambda,
            xu,
            sites,
            log_z,
            mu,
            sigma_diag,
            w_pred,
            sweeps,
            converged,
            fill_k,
            fill_l,
            luu,
            solver,
            p_mean,
            m2,
        }
    }

    /// Analytic gradient of `log Z_EP` w.r.t. the CS kernel's
    /// log-parameters `[ln σ²_cs, ln l…]` (paper eqs. 6, 11 with
    /// `∂P/∂θ = ∂K_cs/∂θ`): quadratic term through the representer
    /// weights, trace term through `B⁻¹` on the CS pattern — the Takahashi
    /// sparsified inverse of the sparse part minus the rank-m Woodbury
    /// correction. The global kernel's parameters enter through `U` and
    /// `Λ`; the model layer differentiates those with warm-started finite
    /// differences. Allocates the Takahashi / `V` / `B⁻¹` buffers fresh;
    /// optimizer loops should call [`CsFicEp::log_z_grad_cs_cached`] with
    /// their cache's scratch.
    pub fn log_z_grad_cs(&self) -> Vec<f64> {
        let mut lowrank = InversePatternScratch::default();
        let mut binv = Vec::new();
        self.log_z_grad_cs_with(&mut lowrank, &mut binv)
    }

    /// [`CsFicEp::log_z_grad_cs`] reusing the optimizer cache's
    /// [`GradScratch`]: while the `PatternCache` hits (only site
    /// parameters / covariance values changed), the `O(nnz(L))` Takahashi
    /// z-arrays, the n×m `V` block and the `B⁻¹`-on-pattern output are
    /// recycled across SCG steps instead of reallocated per gradient
    /// evaluation.
    pub fn log_z_grad_cs_cached(&self, scratch: &mut GradScratch) -> Vec<f64> {
        let GradScratch { lowrank, binv, .. } = scratch;
        self.log_z_grad_cs_with(lowrank, binv)
    }

    fn log_z_grad_cs_with(
        &self,
        lowrank: &mut InversePatternScratch,
        binv: &mut Vec<f64>,
    ) -> Vec<f64> {
        let kmat = &self.k_cs;
        let grads = self.cov.cs.cov_grads_on_pattern(&self.xp, kmat);
        let mut out = grad_quadratic_term(kmat, &grads, &self.w_pred);
        self.solver.inverse_on_pattern_into(kmat, lowrank, binv);
        let sw: Vec<f64> = self.sites.tau.iter().map(|&t| t.max(0.0).sqrt()).collect();
        for j in 0..kmat.n_cols {
            for p in kmat.col_ptr[j]..kmat.col_ptr[j + 1] {
                let i = kmat.row_idx[p];
                let zij = sw[i] * binv[p] * sw[j];
                for (g, o) in grads.iter().zip(out.iter_mut()) {
                    *o -= 0.5 * zij * g[p];
                }
            }
        }
        out
    }

    /// Latent predictive mean and variance at a test point (original
    /// coordinates). Allocates a fresh workspace per call; batch callers
    /// should use [`CsFicEp::predict_workspace`] +
    /// [`CsFicEp::predict_latent_with`].
    pub fn predict_latent(&self, xstar: &[f64]) -> (f64, f64) {
        let mut pws = PredictWorkspace::one_shot(self.k_cs.n_rows);
        self.predict_latent_with(xstar, &mut pws)
    }

    /// Workspace for repeated predictions against this EP state: one
    /// neighbor index over the (permuted) inputs for the sparse CS
    /// cross-covariances plus one sparse-solve scratch.
    pub fn predict_workspace(&self) -> PredictWorkspace {
        PredictWorkspace::new(&self.cov.cs, &self.xp)
    }

    /// Latent prediction through a shared workspace: the CS half goes
    /// through the neighbor index + a sparse-RHS solve, the global half
    /// through `u* = L_uu⁻¹ k_u(x*)` and the precomputed m×m blocks —
    /// `O(k + nnz(L) + m²)` per point, no n-vector densification.
    pub fn predict_latent_with(&self, xstar: &[f64], pws: &mut PredictWorkspace) -> (f64, f64) {
        let m = self.xu.len();
        // CS half: sparse cross-covariance against the permuted inputs
        self.cov.cs.cross_cov_into(
            &self.xp,
            xstar,
            pws.index.as_deref(),
            &mut pws.rows,
            &mut pws.vals,
        );
        // global half: u* = L_uu⁻¹ k_u(x*); prior cross-cov is
        // p*ᵢ = k_cs(xᵢ, x*) + uᵢ · u*  (Λ adds nothing off-sample)
        let ksu: Vec<f64> = self.xu.iter().map(|p| self.cov.global.kernel(xstar, p)).collect();
        let ustar = self.luu.solve_lower(&ksu);

        let mean_cs: f64 =
            pws.rows.iter().zip(&pws.vals).map(|(&i, &v)| v * self.w_pred[i]).sum();
        let mean_lr: f64 = ustar.iter().zip(&self.p_mean).map(|(a, b)| a * b).sum();

        // variance: p** − (a* + Us u*)ᵀ B⁻¹ (a* + Us u*), a* = S̃^{1/2} k_cs*
        let tau = &self.sites.tau;
        pws.u_vals.clear();
        pws.u_vals
            .extend(pws.rows.iter().zip(&pws.vals).map(|(&i, &v)| tau[i].max(0.0).sqrt() * v));
        self.solver.factor.solve_sparse_rhs(&pws.rows, &pws.u_vals, &mut pws.ws, &mut pws.t);
        let q1: f64 = pws.rows.iter().zip(&pws.u_vals).map(|(&i, &v)| v * pws.t[i]).sum();
        pws.ws.clear_solution(&mut pws.t);
        let g = self.solver.wt_sparse(&pws.rows, &pws.u_vals);
        let z = self.solver.cap.solve(&g);
        let q2: f64 = g.iter().zip(&z).map(|(a, b)| a * b).sum();
        // cross: u*ᵀ (Usᵀ B⁻¹ a*) with Usᵀ B⁻¹ a* = g − M₁ z
        let mut cross = 0.0;
        let mut quad_lr = 0.0;
        for a in 0..m {
            let m1z: f64 = (0..m).map(|b| self.solver.m1.at(a, b) * z[b]).sum();
            cross += ustar[a] * (g[a] - m1z);
            let m2u: f64 = (0..m).map(|b| self.m2.at(a, b) * ustar[b]).sum();
            quad_lr += ustar[a] * m2u;
        }
        let quad = (q1 - q2) + 2.0 * cross + quad_lr;
        // p** = σ²_cs + k_g(x*,x*): FIC's Λ* makes the global test-point
        // prior variance exact
        let pss = self.cov.cs.sigma2 + self.cov.global.sigma2;
        (mean_cs + mean_lr, (pss - quad).max(1e-12))
    }

    /// Batched latent predictions fanned out over the worker pool: one
    /// neighbor index is built once and shared (`Arc`) by every worker's
    /// forked workspace; each test point is an independent task, so the
    /// results equal the per-point path bitwise.
    pub fn predict_latent_batch(&self, xs: &[Vec<f64>]) -> Vec<(f64, f64)> {
        let proto = self.predict_workspace();
        crate::gp::predict::batch_with_forks(&proto, xs.len(), |pws, i| {
            self.predict_latent_with(&xs[i], pws)
        })
    }

    /// `S_B = I + S̃^{1/2}(K_cs + Λ)S̃^{1/2}` at the converged sites — the
    /// sparse part of the Woodbury solver's `B`, the matrix every CS+FIC
    /// sweep hands to the supernodal numeric LDLᵀ
    /// ([`SparseLowRank::refresh`]). Rebuilt on demand (one pattern
    /// clone); the `factor` stage of `perf_parallel` measures refactoring
    /// it at several pool widths.
    pub fn sparse_b(&self) -> CscMatrix {
        build_sparse_b(&self.k_cs, &self.lambda, &self.sites.tau)
    }

    /// Read-only view of the converged sparse LDLᵀ factor inside the
    /// Woodbury solver (benches clone it to time refactoring
    /// [`CsFicEp::sparse_b`] in isolation; the fitted state itself stays
    /// sealed — mutating the solver would desynchronize the cached
    /// posterior blocks).
    pub fn sparse_factor(&self) -> &LdlFactor {
        &self.solver.factor
    }

    /// Rebuild the FIC factor `U = K_fu L_uu⁻ᵀ` (n×m, permuted rows).
    /// `U` is *not* retained on the fitted state (no serving path reads
    /// it, and it would add an n×m matrix to every long-lived model);
    /// callers that re-run the variance loop build it once and pass it to
    /// [`CsFicEp::recompute_sigma_diag_with`].
    pub fn fic_factor(&self) -> DenseMatrix {
        build_fic_factor(&self.cov.global, self.xp.as_slice(), &self.xu, &self.luu)
    }

    /// Recompute all marginal variances from the current solver/site
    /// state and the given FIC factor (see [`CsFicEp::fic_factor`]) — the
    /// per-sweep loop `perf_parallel` measures in isolation for the
    /// CS+FIC backend.
    pub fn recompute_sigma_diag_with(&self, u: &DenseMatrix) -> Vec<f64> {
        let sw: Vec<f64> = self.sites.tau.iter().map(|&v| v.max(0.0).sqrt()).collect();
        posterior_variances(&self.k_cs, &self.lambda, u, &self.solver, &sw, &self.m2)
    }
}

/// `U = K_fu L_uu⁻ᵀ` over the permuted inputs. Each row is an independent
/// m-kernel-eval + m²-solve task, so the build fans out over the worker
/// pool (the global-hyper FD gradient rebuilds U per perturbed run); row
/// i's slots are written by exactly one chunk, so the result is
/// bitwise-identical to the serial build.
fn build_fic_factor(
    global: &crate::gp::covariance::CovFunction,
    xp: &[Vec<f64>],
    xu: &[Vec<f64>],
    luu: &DenseCholesky,
) -> DenseMatrix {
    let (n, m) = (xp.len(), xu.len());
    let mut u = DenseMatrix::zeros(n, m);
    {
        let ud = crate::par::SyncSlice::new(&mut u.data);
        crate::par::for_chunks(
            n,
            64,
            || vec![0.0; m],
            |ksu, range| {
                for i in range {
                    for (a, k) in ksu.iter_mut().enumerate() {
                        *k = global.kernel(&xp[i], &xu[a]);
                    }
                    let sol = luu.solve_lower(ksu);
                    for (a, &s) in sol.iter().enumerate() {
                        // SAFETY: row i's slots belong to this chunk only.
                        unsafe { ud.set(i * m + a, s) };
                    }
                }
            },
        );
    }
    u
}

/// `S_B = I + S̃^{1/2} (K_cs + Λ) S̃^{1/2}` on `k_cs`'s pattern.
fn build_sparse_b(k_cs: &CscMatrix, lambda: &[f64], tau: &[f64]) -> CscMatrix {
    let mut b = k_cs.clone();
    for j in 0..b.n_cols {
        let stj = tau[j].max(0.0).sqrt();
        for p in b.col_ptr[j]..b.col_ptr[j + 1] {
            let i = b.row_idx[p];
            let sti = tau[i].max(0.0).sqrt();
            b.values[p] = if i == j {
                1.0 + sti * stj * (b.values[p] + lambda[j])
            } else {
                sti * stj * b.values[p]
            };
        }
    }
    b
}

/// `Us = S̃^{1/2} U`.
fn scaled_u(u: &DenseMatrix, tau: &[f64]) -> DenseMatrix {
    DenseMatrix::from_fn(u.n_rows, u.n_cols, |i, a| tau[i].max(0.0).sqrt() * u.at(i, a))
}

/// `v ↦ P v = K_cs v + Λ∘v + U (Uᵀ v)` — `O(nnz + n·m)`.
fn apply_p(k_cs: &CscMatrix, lambda: &[f64], u: &DenseMatrix, v: &[f64]) -> Vec<f64> {
    let (n, m) = (u.n_rows, u.n_cols);
    let mut out = k_cs.matvec(v);
    for i in 0..n {
        out[i] += lambda[i] * v[i];
    }
    let mut utv = vec![0.0; m];
    for (a, ua) in utv.iter_mut().enumerate() {
        *ua = (0..n).map(|i| u.at(i, a) * v[i]).sum();
    }
    for i in 0..n {
        out[i] += u.row(i).iter().zip(&utv).map(|(a, b)| a * b).sum::<f64>();
    }
    out
}

/// Recompute `γ = P ν̃`, `μ = γ − P S̃^{1/2} B⁻¹ S̃^{1/2} γ` and the
/// marginal variances `Σᵢᵢ = Pᵢᵢ − (S̃^{1/2} P[:,i])ᵀ B⁻¹ (S̃^{1/2} P[:,i])`
/// through the sparse-plus-low-rank structure.
///
/// Splitting `S̃^{1/2} P[:,i] = aᵢ + Us uᵢ` (aᵢ = S̃^{1/2}(K_cs+Λ)[:,i]
/// sparse, uᵢ = row i of U) gives per site
///
/// ```text
/// quadᵢ = aᵢᵀB⁻¹aᵢ + 2 uᵢᵀ(UsᵀB⁻¹aᵢ) + uᵢᵀ M₂ uᵢ
/// ```
///
/// with `UsᵀB⁻¹aᵢ = g − M₁ C⁻¹ g` (g = Wᵀaᵢ) and the once-per-refresh
/// `M₂ = UsᵀB⁻¹Us` — one sparse-RHS solve plus `O(k·m + m²)` per site,
/// fanned out over the worker pool by [`posterior_variances`].
/// Returns the `M₂` it built so the converged state can keep it without
/// recomputing.
#[allow(clippy::too_many_arguments)]
fn refresh_posterior(
    k_cs: &CscMatrix,
    lambda: &[f64],
    u: &DenseMatrix,
    solver: &SparseLowRank,
    sites: &EpSites,
    gamma: &mut Vec<f64>,
    mu: &mut [f64],
    sigma_diag: &mut [f64],
) -> DenseMatrix {
    let n = k_cs.n_rows;
    let sw: Vec<f64> = sites.tau.iter().map(|&v| v.max(0.0).sqrt()).collect();

    // posterior mean
    *gamma = apply_p(k_cs, lambda, u, &sites.nu);
    let swg: Vec<f64> = (0..n).map(|i| sw[i] * gamma[i]).collect();
    let bswg = solver.solve(&swg);
    let scaled: Vec<f64> = (0..n).map(|i| sw[i] * bswg[i]).collect();
    let pscaled = apply_p(k_cs, lambda, u, &scaled);
    for i in 0..n {
        mu[i] = gamma[i] - pscaled[i];
    }

    // marginal variances
    let m2 = solver.m2();
    sigma_diag.copy_from_slice(&posterior_variances(k_cs, lambda, u, solver, &sw, &m2));
    m2
}

/// All `n` hybrid marginal variances
/// `Σᵢᵢ = Pᵢᵢ − (S̃^{1/2} P[:,i])ᵀ B⁻¹ (S̃^{1/2} P[:,i])` through the
/// sparse-plus-low-rank split (see [`refresh_posterior`]) — one
/// sparse-RHS solve plus `O(k·m + m²)` per site. Sites are independent,
/// so the loop fans out over [`crate::par`]: each participant owns a
/// `SparseSolveWorkspace` and a dense solution vector, and slot `i` is
/// written by exactly one chunk — bitwise-identical to the serial loop at
/// any thread count. Workspaces are built once per participant per call
/// (`O(threads·n)` against `O(n·(nnz + m²))` solve work). This is the
/// loop `perf_parallel` measures for the CS+FIC backend.
pub(crate) fn posterior_variances(
    k_cs: &CscMatrix,
    lambda: &[f64],
    u: &DenseMatrix,
    solver: &SparseLowRank,
    sw: &[f64],
    m2: &DenseMatrix,
) -> Vec<f64> {
    let n = k_cs.n_rows;
    let m = u.n_cols;
    crate::par::map_indexed(
        n,
        64,
        || (SparseSolveWorkspace::new(n), vec![0.0; n], Vec::with_capacity(64)),
        |scratch, i| {
            let (ws, t, a_vals) = scratch;
            let (krows, kvals) = k_cs.col(i);
            // aᵢ = S̃^{1/2} (K_cs + Λ)[:, i] — Λ only touches the diagonal
            a_vals.clear();
            a_vals.extend(krows.iter().zip(kvals).map(|(&r, &v)| {
                sw[r] * (v + if r == i { lambda[i] } else { 0.0 })
            }));
            solver.factor.solve_sparse_rhs(krows, a_vals, ws, t);
            let q1: f64 = krows.iter().zip(a_vals.iter()).map(|(&r, &v)| v * t[r]).sum();
            ws.clear_solution(t);
            let g = solver.wt_sparse(krows, a_vals);
            let z = solver.cap.solve(&g);
            let q2: f64 = g.iter().zip(&z).map(|(a, b)| a * b).sum();
            let ui = u.row(i);
            let mut cross = 0.0;
            let mut quad_lr = 0.0;
            for a in 0..m {
                let m1z: f64 = (0..m).map(|b| solver.m1.at(a, b) * z[b]).sum();
                cross += ui[a] * (g[a] - m1z);
                let m2u: f64 = (0..m).map(|b| m2.at(a, b) * ui[b]).sum();
                quad_lr += ui[a] * m2u;
            }
            let pii = k_cs.get(i, i) + lambda[i] + ui.iter().map(|v| v * v).sum::<f64>();
            let quad = (q1 - q2) + 2.0 * cross + quad_lr;
            (pii - quad).max(1e-12)
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::kmeans::kmeans;
    use crate::gp::covariance::{CovFunction, CovKind};
    use crate::gp::ep_dense::DenseEp;
    use crate::gp::likelihood::probit_site_update;
    use crate::testutil::random_points;

    fn circle_labels(x: &[Vec<f64>]) -> Vec<f64> {
        x.iter()
            .map(|p| if (p[0] - 3.0).hypot(p[1] - 3.0) < 2.2 { 1.0 } else { -1.0 })
            .collect()
    }

    fn hybrid_cov() -> AdditiveCov {
        AdditiveCov::new(
            CovFunction::new(CovKind::Se, 2, 0.8, 3.0),
            CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.5),
        )
        .unwrap()
    }

    fn tight() -> EpOptions {
        EpOptions { max_sweeps: 400, tol: 1e-11, damping: 0.8, ..EpOptions::default() }
    }

    /// Explicitly assembled dense prior `P = K_cs + Λ + U Uᵀ` over the
    /// permuted inputs, plus the pieces needed for the dense prediction
    /// reference.
    fn dense_prior(
        cov: &AdditiveCov,
        xp: &[Vec<f64>],
        xu: &[Vec<f64>],
    ) -> (DenseMatrix, DenseMatrix, DenseCholesky) {
        let n = xp.len();
        let m = xu.len();
        let jitter = 1e-8 * cov.global.sigma2;
        let mut kuu = DenseMatrix::from_fn(m, m, |a, b| cov.global.kernel(&xu[a], &xu[b]));
        kuu.add_diag(jitter);
        let luu = kuu.cholesky().unwrap();
        let mut u = DenseMatrix::zeros(n, m);
        for i in 0..n {
            let ksu: Vec<f64> = xu.iter().map(|p| cov.global.kernel(&xp[i], p)).collect();
            let sol = luu.solve_lower(&ksu);
            for (a, &s) in sol.iter().enumerate() {
                *u.at_mut(i, a) = s;
            }
        }
        let mut p = DenseMatrix::from_fn(n, n, |i, j| cov.cs.kernel(&xp[i], &xp[j]));
        for i in 0..n {
            for j in 0..n {
                let qij: f64 = (0..m).map(|a| u.at(i, a) * u.at(j, a)).sum();
                *p.at_mut(i, j) += qij;
            }
            let qii: f64 = (0..m).map(|a| u.at(i, a) * u.at(i, a)).sum();
            *p.at_mut(i, i) += (cov.global.sigma2 - qii).max(1e-10);
        }
        (p, u, luu)
    }

    /// Dense reference EP: the *same* batched/damped schedule as
    /// `CsFicEp::run`, but every step through a dense Cholesky of the
    /// explicitly assembled prior.
    struct DenseRef {
        sites: EpSites,
        log_z: f64,
        mu: Vec<f64>,
        sigma_diag: Vec<f64>,
        w_pred: Vec<f64>,
        chol_b: DenseCholesky,
        sw: Vec<f64>,
    }

    fn dense_reference(p: &DenseMatrix, y: &[f64], opts: &EpOptions) -> DenseRef {
        let n = y.len();
        let damping = opts.effective_damping(0.8);
        let mut sites = EpSites::zeros(n);
        let mut mu = vec![0.0; n];
        let mut sigma_diag: Vec<f64> = (0..n).map(|i| p.at(i, i)).collect();
        let mut gamma = vec![0.0; n];
        let mut chol_b = DenseMatrix::identity(n).cholesky().unwrap();
        let mut log_z = f64::NEG_INFINITY;
        let mut log_z_old = f64::NEG_INFINITY;
        let mut sweeps = 0;
        while sweeps < opts.max_sweeps {
            let mut new_tau = sites.tau.clone();
            let mut new_nu = sites.nu.clone();
            for i in 0..n {
                let Some((lz, tc, nc, tn, nn)) =
                    probit_site_update(y[i], mu[i], sigma_diag[i], sites.tau[i], sites.nu[i])
                else {
                    continue;
                };
                sites.ln_zhat[i] = lz;
                sites.tau_cav[i] = tc;
                sites.nu_cav[i] = nc;
                new_tau[i] = damping * tn + (1.0 - damping) * sites.tau[i];
                new_nu[i] = damping * nn + (1.0 - damping) * sites.nu[i];
            }
            sites.tau = new_tau;
            sites.nu = new_nu;
            let sw: Vec<f64> = sites.tau.iter().map(|&t| t.max(0.0).sqrt()).collect();
            let mut b = DenseMatrix::from_fn(n, n, |i, j| sw[i] * p.at(i, j) * sw[j]);
            b.add_diag(1.0);
            chol_b = b.cholesky().unwrap();
            gamma = p.matvec(&sites.nu);
            let swg: Vec<f64> = (0..n).map(|i| sw[i] * gamma[i]).collect();
            let bswg = chol_b.solve(&swg);
            let scaled: Vec<f64> = (0..n).map(|i| sw[i] * bswg[i]).collect();
            let pscaled = p.matvec(&scaled);
            for i in 0..n {
                mu[i] = gamma[i] - pscaled[i];
            }
            for i in 0..n {
                let a: Vec<f64> = (0..n).map(|r| sw[r] * p.at(r, i)).collect();
                let bia = chol_b.solve(&a);
                let quad: f64 = a.iter().zip(&bia).map(|(x, y)| x * y).sum();
                sigma_diag[i] = (p.at(i, i) - quad).max(1e-12);
            }
            sweeps += 1;
            let nu_dot_mu: f64 = sites.nu.iter().zip(&mu).map(|(a, b)| a * b).sum();
            log_z = ep_log_z(&sites, chol_b.logdet(), nu_dot_mu);
            if (log_z - log_z_old).abs() < opts.tol {
                break;
            }
            log_z_old = log_z;
        }
        let sw: Vec<f64> = sites.tau.iter().map(|&t| t.max(0.0).sqrt()).collect();
        let swg: Vec<f64> = (0..n).map(|i| sw[i] * gamma[i]).collect();
        let bswg = chol_b.solve(&swg);
        let w_pred: Vec<f64> = (0..n).map(|i| sites.nu[i] - sw[i] * bswg[i]).collect();
        DenseRef { sites, log_z, mu, sigma_diag, w_pred, chol_b, sw }
    }

    #[allow(clippy::too_many_arguments)]
    fn reference_predict(
        cov: &AdditiveCov,
        xp: &[Vec<f64>],
        xu: &[Vec<f64>],
        u: &DenseMatrix,
        luu: &DenseCholesky,
        r: &DenseRef,
        xstar: &[f64],
    ) -> (f64, f64) {
        let n = xp.len();
        let m = xu.len();
        let ksu: Vec<f64> = xu.iter().map(|p| cov.global.kernel(xstar, p)).collect();
        let ustar = luu.solve_lower(&ksu);
        let pstar: Vec<f64> = (0..n)
            .map(|i| {
                let q: f64 = (0..m).map(|a| u.at(i, a) * ustar[a]).sum();
                cov.cs.kernel(&xp[i], xstar) + q
            })
            .collect();
        let mean: f64 = pstar.iter().zip(&r.w_pred).map(|(a, b)| a * b).sum();
        let a: Vec<f64> = (0..n).map(|i| r.sw[i] * pstar[i]).collect();
        let bia = r.chol_b.solve(&a);
        let quad: f64 = a.iter().zip(&bia).map(|(x, y)| x * y).sum();
        let pss = cov.cs.sigma2 + cov.global.sigma2;
        (mean, (pss - quad).max(1e-12))
    }

    /// The acceptance-criterion test: on a small problem the hybrid EP's
    /// marginals, logZ and predictions match a dense EP run on the
    /// explicitly assembled `K_cs + Λ + Q` prior to ≤ 1e-6 — while the
    /// hybrid path never materializes that n×n matrix.
    #[test]
    fn matches_dense_ep_on_the_assembled_prior() {
        let x = random_points(90, 2, 6.0, 13);
        let y = circle_labels(&x);
        let cov = hybrid_cov();
        let xu = kmeans(&x, 10, 25, 0xf1c);
        let ep = CsFicEp::run(&cov, &x, &y, &xu, &tight()).unwrap();
        assert!(ep.converged, "hybrid EP did not converge");
        let n = x.len();
        let mut yp = vec![0.0; n];
        for old in 0..n {
            yp[ep.perm[old]] = y[old];
        }
        let (p, u, luu) = dense_prior(&cov, &ep.xp, &xu);
        let r = dense_reference(&p, &yp, &tight());
        assert!(
            (ep.log_z - r.log_z).abs() < 1e-6,
            "logZ hybrid {} vs dense {}",
            ep.log_z,
            r.log_z
        );
        for i in 0..n {
            assert!((ep.mu[i] - r.mu[i]).abs() < 1e-6, "mu[{i}]");
            assert!((ep.sigma_diag[i] - r.sigma_diag[i]).abs() < 1e-6, "sigma[{i}]");
            assert!((ep.sites.tau[i] - r.sites.tau[i]).abs() < 1e-6, "tau[{i}]");
        }
        for xs in [vec![1.0, 1.0], vec![3.0, 3.0], vec![5.0, 2.0]] {
            let (mh, vh) = ep.predict_latent(&xs);
            let (mr, vr) = reference_predict(&cov, &ep.xp, &xu, &u, &luu, &r, &xs);
            assert!((mh - mr).abs() < 1e-6, "pred mean {mh} vs {mr}");
            assert!((vh - vr).abs() < 1e-6, "pred var {vh} vs {vr}");
        }
    }

    /// With a vanishing global magnitude the hybrid prior collapses to
    /// the plain CS GP, so CS+FIC EP must agree with dense EP on the CS
    /// kernel alone (an independent implementation, sequential schedule).
    #[test]
    fn vanishing_global_term_reduces_to_the_cs_gp() {
        let x = random_points(40, 2, 6.0, 3);
        let y = circle_labels(&x);
        let cs = CovFunction::new(CovKind::Pp(3), 2, 1.1, 2.0);
        let cov =
            AdditiveCov::new(CovFunction::new(CovKind::Se, 2, 1e-10, 3.0), cs.clone()).unwrap();
        let xu = kmeans(&x, 6, 25, 2);
        let ep = CsFicEp::run(&cov, &x, &y, &xu, &tight()).unwrap();
        let de = DenseEp::run(&cs, &x, &y, &tight()).unwrap();
        assert!(ep.converged);
        assert!(
            (ep.log_z - de.log_z).abs() < 1e-4,
            "logZ {} vs {}",
            ep.log_z,
            de.log_z
        );
        for xs in [vec![2.0, 2.0], vec![4.0, 3.5]] {
            let (mh, vh) = ep.predict_latent(&xs);
            let (md, vd) = de.predict_latent(&cs, &x, &xs);
            assert!((mh - md).abs() < 1e-4, "{mh} vs {md}");
            assert!((vh - vd).abs() < 1e-4, "{vh} vs {vd}");
        }
    }

    /// Analytic CS-block gradient vs central finite differences of the
    /// hybrid's own logZ.
    #[test]
    fn cs_gradient_matches_finite_difference() {
        let x = random_points(40, 2, 6.0, 7);
        let y = circle_labels(&x);
        let mut cov = AdditiveCov::new(
            CovFunction::new(CovKind::Se, 2, 0.7, 3.0),
            CovFunction::new(CovKind::Pp(3), 2, 1.2, 1.8),
        )
        .unwrap();
        let xu = kmeans(&x, 8, 25, 1);
        let ep = CsFicEp::run(&cov, &x, &y, &xu, &tight()).unwrap();
        let grad = ep.log_z_grad_cs();
        let p0 = cov.cs.params();
        for p in 0..cov.cs.n_params() {
            let h = 1e-5;
            let mut pp = p0.clone();
            pp[p] += h;
            cov.cs.set_params(&pp);
            let zp = CsFicEp::run(&cov, &x, &y, &xu, &tight()).unwrap().log_z;
            pp[p] -= 2.0 * h;
            cov.cs.set_params(&pp);
            let zm = CsFicEp::run(&cov, &x, &y, &xu, &tight()).unwrap().log_z;
            cov.cs.set_params(&p0);
            let fd = (zp - zm) / (2.0 * h);
            assert!(
                (fd - grad[p]).abs() < 5e-4 * (1.0 + grad[p].abs()),
                "param {p}: fd={fd} analytic={}",
                grad[p]
            );
        }
    }

    /// Warm-started re-runs (the global-hyper FD gradient path) reuse the
    /// fixed point: immediate convergence at the same θ, and the cold
    /// fixed point at a perturbed θ.
    #[test]
    fn warm_start_reuses_the_fixed_point() {
        let x = random_points(60, 2, 6.0, 19);
        let y = circle_labels(&x);
        let cov = hybrid_cov();
        let xu = kmeans(&x, 9, 25, 4);
        let mut cache = PatternCache::new(Ordering::Rcm);
        let cold = CsFicEp::run_cached(&cov, &x, &y, &xu, &tight(), None, &mut cache).unwrap();
        assert!(cold.converged);
        let warm_sites = cold.sites_unpermuted();
        let warm =
            CsFicEp::run_cached(&cov, &x, &y, &xu, &tight(), Some(&warm_sites), &mut cache)
                .unwrap();
        assert!(warm.sweeps <= 3, "warm sweeps {}", warm.sweeps);
        assert!((warm.log_z - cold.log_z).abs() < 1e-7);
        // perturbed global hypers: the warm run must land on the cold
        // fixed point of the new θ
        let mut c2 = cov.clone();
        let mut p = c2.global.params();
        p[1] += 1e-3;
        c2.global.set_params(&p);
        let warm2 =
            CsFicEp::run_cached(&c2, &x, &y, &xu, &tight(), Some(&warm_sites), &mut cache)
                .unwrap();
        let cold2 = CsFicEp::run(&c2, &x, &y, &xu, &tight()).unwrap();
        assert!(
            (warm2.log_z - cold2.log_z).abs() < 1e-6,
            "{} vs {}",
            warm2.log_z,
            cold2.log_z
        );
        assert!(warm2.sweeps <= cold2.sweeps);
    }

    /// A `PatternCache` hit (σ²-only CS step) must reproduce the uncached
    /// fixed point, like the sparse backends.
    #[test]
    fn pattern_cache_hit_reproduces_uncached_fixed_point() {
        let x = random_points(70, 2, 6.0, 23);
        let y = circle_labels(&x);
        let cov = hybrid_cov();
        let xu = kmeans(&x, 8, 25, 5);
        let mut cache = PatternCache::new(Ordering::Rcm);
        let _ = CsFicEp::run_cached(&cov, &x, &y, &xu, &tight(), None, &mut cache).unwrap();
        let mut c2 = cov.clone();
        c2.cs.sigma2 = 1.4;
        let cached = CsFicEp::run_cached(&c2, &x, &y, &xu, &tight(), None, &mut cache).unwrap();
        assert_eq!((cache.hits, cache.misses), (1, 1));
        let fresh = CsFicEp::run(&c2, &x, &y, &xu, &tight()).unwrap();
        assert!((cached.log_z - fresh.log_z).abs() < 1e-7);
        for xs in [vec![1.5, 2.0], vec![4.5, 1.0]] {
            let (mc, vc) = cached.predict_latent(&xs);
            let (mf, vf) = fresh.predict_latent(&xs);
            assert!((mc - mf).abs() < 1e-6 && (vc - vf).abs() < 1e-6);
        }
    }

    #[test]
    fn batched_prediction_matches_one_shot() {
        let x = random_points(80, 2, 6.0, 29);
        let y = circle_labels(&x);
        let cov = hybrid_cov();
        let xu = kmeans(&x, 10, 25, 6);
        let ep = CsFicEp::run(&cov, &x, &y, &xu, &EpOptions::default()).unwrap();
        let probes = random_points(15, 2, 7.0, 31);
        let batched = ep.predict_latent_batch(&probes);
        for (xs, &(mb, vb)) in probes.iter().zip(&batched) {
            let (m1, v1) = ep.predict_latent(xs);
            assert!((mb - m1).abs() < 1e-12 && (vb - v1).abs() < 1e-12);
        }
    }
}
