//! `ldlrowmodify` — the paper's Algorithm 2 (Davis & Hager 2005, fused).
//!
//! When EP updates site i, only the i'th row/column of
//! `B = I + S̃^{1/2} K S̃^{1/2}` changes. Because τ̃ stays positive the
//! sparsity pattern of `B` — and hence of `L` — is invariant, so row
//! deletion + row addition collapse into a single in-place pass:
//!
//! 1. `L₁₁ D₁₁ l̄₁₂ = b̄₁₂` — sparse forward solve for the new row i of L;
//! 2. `d̄₂₂ = b̄₂₂ − l̄₁₂ᵀ D₁₁ l̄₁₂`;
//! 3. `l̄₃₂ = (b̄₃₂ − L₃₁ D₁₁ l̄₁₂) / d̄₂₂` — the new column i of L;
//! 4. rank-one update of `L₃₃` with `w₁ = l₃₂ √d₂₂` (old values) and
//!    downdate with `w₂ = l̄₃₂ √d̄₂₂` (new values).
//!
//! Fill discipline: all scatter targets are inside the static symbolic
//! pattern (the Cholesky fill rule `L[r,j]≠0 ∧ L[i,j]≠0 ∧ j<r<i ⇒ L[i,r]≠0`
//! guarantees it), so no allocation and no symbolic re-analysis happen per
//! site — the property the paper's speedup rests on.
//!
//! # Recovery contract
//!
//! A failed modification (a lost `d̄₂₂` pivot here, or an indefinite
//! fused downdate in step 4) leaves the factor **partially mutated**: the
//! new row-i entries of step 1 are written before the pivot check, and
//! `rank1_pair` stops mid-path. There is therefore no in-place retry —
//! recovery belongs to the caller, which still holds the site state the
//! factor was tracking. The sparse EP sweep rebuilds from scratch:
//! `build_b(K, τ̃)` from the *current* sites, then
//! [`LdlFactor::refactor_with_recovery`] with the run's jitter schedule.
//! That is deterministic at any pool width (the sweep driver is serial)
//! and restores the exact factor the remaining sites expect.

use crate::sparse::cholesky::LdlFactor;


/// Preallocated scratch for repeated row modifications.
pub struct RowModWorkspace {
    /// Dense accumulator for the forward solve / L₃₁-product / b-scatter.
    x: Vec<f64>,
    /// Dense scratches handed to the fused rank-one kernel.
    w_scratch: Vec<f64>,
    w_scratch2: Vec<f64>,
    /// Sparse w buffers (pattern of column i).
    w_rows: Vec<usize>,
    w1_vals: Vec<f64>,
    w2_vals: Vec<f64>,
}

impl RowModWorkspace {
    pub fn new(n: usize) -> Self {
        RowModWorkspace {
            x: vec![0.0; n],
            w_scratch: vec![0.0; n],
            w_scratch2: vec![0.0; n],
            w_rows: Vec::with_capacity(n),
            w1_vals: Vec::with_capacity(n),
            w2_vals: Vec::with_capacity(n),
        }
    }
}

impl LdlFactor {
    /// Replace row/column `i` of the factored matrix with the sparse column
    /// `(b_rows, b_vals)` (the full new column i of `B`, diagonal included,
    /// sorted rows, pattern ⊆ the analysed pattern of `B`), updating the
    /// factor in place.
    pub fn ldl_row_modify(
        &mut self,
        i: usize,
        b_rows: &[usize],
        b_vals: &[f64],
        ws: &mut RowModWorkspace,
    ) -> Result<(), String> {
        let sym = self.symbolic.clone();
        let n = sym.n;
        debug_assert!(i < n);

        // -- capture old column i as w1 = l32 * sqrt(d22_old) -------------
        let d22_old = self.d[i];
        debug_assert!(d22_old > 0.0);
        let sd_old = d22_old.sqrt();
        let colpat = sym.col_pattern(i);
        let colvals = self.col_values(i);
        ws.w_rows.clear();
        ws.w1_vals.clear();
        ws.w_rows.extend_from_slice(colpat);
        ws.w1_vals.extend(colvals.iter().map(|&v| v * sd_old));

        // -- scatter the new B column into ws.x ---------------------------
        // (cleared selectively at the end; entries < i are consumed by the
        // forward solve, entries > i are read off the column pattern)
        let mut b22 = 0.0;
        for (&r, &v) in b_rows.iter().zip(b_vals) {
            if r == i {
                b22 = v;
            } else {
                ws.x[r] = v;
            }
        }

        // -- step 1+2: forward solve L11 z = b12 along row pattern of i ----
        // row_pattern(i) is sorted by column (ascending == topological).
        // z[j] accumulates in ws.x[j]; l̄12[j] = z[j] / d[j].
        let mut d22_new = b22;
        for &(j, pos) in sym.row_pattern(i) {
            let zj = ws.x[j];
            ws.x[j] = 0.0;
            // subtract z_j * L(:,j) from the remaining rhs; rows r with
            // j < r < i stay in the solve, rows r > i accumulate the
            // L31*D11*l̄12 product negatively (exactly what step 3 needs).
            if zj != 0.0 {
                // SAFETY: pattern indices are < n by construction.
                unsafe {
                    let lo = *sym.col_ptr.get_unchecked(j);
                    let hi = *sym.col_ptr.get_unchecked(j + 1);
                    for p in lo..hi {
                        let r = *sym.row_idx.get_unchecked(p);
                        if r != i {
                            *ws.x.get_unchecked_mut(r) -= self.l.get_unchecked(p) * zj;
                        }
                    }
                }
            }
            let lbar = zj / self.d[j];
            d22_new -= lbar * zj;
            // write the new row-i entry
            self.l[pos] = lbar;
        }
        if d22_new <= 0.0 {
            return Err(format!("ldl_row_modify: new pivot d22 <= 0 at row {i} ({d22_new})"));
        }
        self.d[i] = d22_new;

        // -- step 3: new column i ------------------------------------------
        // ws.x[r] for r > i now holds b̄32[r] − (L31 D11 l̄12)[r].
        let sd_new = d22_new.sqrt();
        ws.w2_vals.clear();
        {
            let lo = sym.col_ptr[i];
            let hi = sym.col_ptr[i + 1];
            for p in lo..hi {
                let r = sym.row_idx[p];
                let lnew = ws.x[r] / d22_new;
                ws.x[r] = 0.0;
                self.l[p] = lnew;
                ws.w2_vals.push(lnew * sd_new);
            }
        }

        // -- step 4: fused rank-one update (old) + downdate (new) of L33 ---
        self.rank1_pair(
            &ws.w_rows,
            &ws.w1_vals,
            &ws.w2_vals,
            &mut ws.w_scratch,
            &mut ws.w_scratch2,
        )?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::sparse::csc::CscMatrix;
    use crate::sparse::symbolic::Symbolic;
    use crate::testutil::random_sparse_spd;
    use std::sync::Arc;

    /// Build the new B after replacing row/col i, as a dense oracle.
    fn apply_dense_rowmod(a: &CscMatrix, i: usize, rows: &[usize], vals: &[f64]) -> CscMatrix {
        let mut b = a.clone();
        // zero old row/col i within pattern
        let n = a.n_rows;
        for j in 0..n {
            let (r, _) = a.col(j);
            for &rr in r {
                if rr == i {
                    *b.get_mut(i, j) = 0.0;
                }
                if j == i {
                    *b.get_mut(rr, i) = 0.0;
                }
            }
        }
        for (&r, &v) in rows.iter().zip(vals) {
            *b.get_mut(r, i) = v;
            if r != i {
                *b.get_mut(i, r) = v;
            }
        }
        b
    }

    /// Random SPD matrix, modify each row in turn with fresh random values
    /// (keeping SPD via a dominant diagonal), compare against refactoring.
    #[test]
    fn rowmod_matches_refactorization_many_rows() {
        for seed in 0..6 {
            let n = 24;
            let a = random_sparse_spd(n, 0.18, seed + 200);
            let sym = Arc::new(Symbolic::analyze(&a));
            let mut f = LdlFactor::factor(sym.clone(), &a).unwrap();
            let mut ws = RowModWorkspace::new(n);
            let mut rng = Rng::new(seed);
            let mut cur = a.clone();
            for i in (0..n).step_by(3) {
                // new column i: same pattern as B's column i, new values
                let (rows_b, _) = a.col(i);
                let rows: Vec<usize> = rows_b.to_vec();
                let vals: Vec<f64> = rows
                    .iter()
                    .map(|&r| if r == i { 0.0 } else { rng.uniform_in(-0.4, 0.4) })
                    .collect();
                // dominant diagonal keeps every intermediate matrix SPD
                let diag = vals.iter().map(|v| v.abs()).sum::<f64>() * 2.0 + 2.0 + rng.uniform();
                let vals: Vec<f64> =
                    rows.iter().zip(vals).map(|(&r, v)| if r == i { diag } else { v }).collect();

                f.ldl_row_modify(i, &rows, &vals, &mut ws).unwrap();
                cur = apply_dense_rowmod(&cur, i, &rows, &vals);
                let oracle = LdlFactor::factor(sym.clone(), &cur).unwrap();
                let dl: f64 = f
                    .l
                    .iter()
                    .zip(&oracle.l)
                    .map(|(x, y)| (x - y).abs())
                    .fold(0.0, f64::max);
                let dd: f64 =
                    f.d.iter().zip(&oracle.d).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
                assert!(dl < 1e-8 && dd < 1e-8, "seed {seed} row {i}: dl={dl} dd={dd}");
            }
        }
    }

    /// The EP trajectory: start from B = I (identity factor) and "switch
    /// on" rows one by one — exactly what the first EP sweep does.
    #[test]
    fn rowmod_from_identity_like_first_ep_sweep() {
        for seed in 0..4 {
            let n = 20;
            let mut a = random_sparse_spd(n, 0.2, seed + 300);
            // Rescale off-diagonals so every row's off-diagonal sum < 0.9:
            // every intermediate matrix in the sweep (mixed identity /
            // activated rows, diagonals >= 1) is then strictly diagonally
            // dominant, hence SPD.
            let mut max_offdiag_rowsum = 0.0f64;
            for j in 0..n {
                let (rows, vals) = a.col(j);
                let s: f64 =
                    rows.iter().zip(vals).filter(|(&r, _)| r != j).map(|(_, v)| v.abs()).sum();
                max_offdiag_rowsum = max_offdiag_rowsum.max(s);
            }
            let scale = 0.9 / max_offdiag_rowsum.max(1e-9);
            for j in 0..n {
                for p in a.col_ptr[j]..a.col_ptr[j + 1] {
                    if a.row_idx[p] != j {
                        a.values[p] *= scale;
                    } else {
                        a.values[p] = a.values[p].max(1.0);
                    }
                }
            }
            let a = a;
            let sym = Arc::new(Symbolic::analyze(&a));
            let mut f = LdlFactor::identity(sym.clone());
            let mut ws = RowModWorkspace::new(n);
            // identity with the same pattern as a
            let mut cur = a.clone();
            for v in cur.values.iter_mut() {
                *v = 0.0;
            }
            for i in 0..n {
                *cur.get_mut(i, i) = 1.0;
            }
            let order: Vec<usize> = {
                let mut rng = Rng::new(seed);
                rng.permutation(n)
            };
            for &i in &order {
                let (rows_b, vals_b) = a.col(i);
                // switch row i to its final value from `a` but keep rows not
                // yet activated consistent (symmetric update handles both)
                f.ldl_row_modify(i, rows_b, vals_b, &mut ws).unwrap();
                cur = apply_dense_rowmod(&cur, i, rows_b, vals_b);
                let oracle = LdlFactor::factor(sym.clone(), &cur).unwrap();
                let dd: f64 =
                    f.d.iter().zip(&oracle.d).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
                assert!(dd < 1e-8, "seed {seed} site {i}: dd={dd}");
            }
            // after all sites: factor of `a` itself... up to the rows that
            // were overwritten multiple times; final `cur` has every row at
            // its `a` value only if later mods didn't clobber earlier ones.
            // We validated against `cur` at each step, which is the real
            // invariant.
        }
    }

    /// Regression guard between the two numeric paths EP interleaves: a
    /// factor maintained by sequential-sweep row modifications must stay
    /// consistent with a *fresh supernodal refactorization* of the same
    /// matrix (the default parallel kernel) — and with the up-looking
    /// serial oracle — after every site visit, on a real CS covariance
    /// fixture (debug-tolerance 1e-8, same bound the rowmod-vs-oracle
    /// tests use).
    #[test]
    fn rowmod_factor_matches_supernodal_and_uplooking_refactorization() {
        use crate::gp::covariance::{CovFunction, CovKind};
        use crate::testutil::random_points;
        let n = 60;
        let x = random_points(n, 2, 6.0, 33);
        let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.7);
        let mut a = cov.cov_matrix(&x);
        for j in 0..n {
            *a.get_mut(j, j) += 1.0; // B = I + K shape
        }
        // Rescale off-diagonals so every row's off-diagonal sum stays
        // below 0.8: site updates below only ever *shrink* off-diagonal
        // magnitudes, so every intermediate matrix is strictly diagonally
        // dominant (diag >= 2), hence SPD.
        let mut max_row_sum = 0.0f64;
        for j in 0..n {
            let (rows, vals) = a.col(j);
            let s: f64 =
                rows.iter().zip(vals).filter(|(&r, _)| r != j).map(|(_, v)| v.abs()).sum();
            max_row_sum = max_row_sum.max(s);
        }
        let scale = 0.8 / max_row_sum.max(1e-9);
        for j in 0..n {
            for p in a.col_ptr[j]..a.col_ptr[j + 1] {
                if a.row_idx[p] != j {
                    a.values[p] *= scale;
                }
            }
        }
        let a = a;

        let sym = Arc::new(Symbolic::analyze(&a));
        let mut f = LdlFactor::factor(sym.clone(), &a).unwrap();
        let mut ws = RowModWorkspace::new(n);
        let mut rng = Rng::new(12);
        let mut cur = a.clone();
        for i in (0..n).step_by(5) {
            // new column i: original pattern, off-diagonals damped by a
            // random factor in [-0.9, 0.9], diagonal unchanged
            let (rows_b, vals_b) = a.col(i);
            let rows: Vec<usize> = rows_b.to_vec();
            let vals: Vec<f64> = rows
                .iter()
                .zip(vals_b)
                .map(|(&r, &v)| if r == i { v } else { v * rng.uniform_in(-0.9, 0.9) })
                .collect();
            f.ldl_row_modify(i, &rows, &vals, &mut ws).unwrap();
            cur = apply_dense_rowmod(&cur, i, &rows, &vals);

            let snodal = LdlFactor::factor(sym.clone(), &cur).unwrap();
            let mut uplook = LdlFactor::identity(sym.clone());
            uplook.refactor_uplooking(&cur).unwrap();
            for (oracle, name) in [(&snodal, "supernodal"), (&uplook, "up-looking")] {
                let dl: f64 =
                    f.l.iter().zip(&oracle.l).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
                let dd: f64 =
                    f.d.iter().zip(&oracle.d).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
                assert!(dl < 1e-8 && dd < 1e-8, "{name} after site {i}: dl={dl} dd={dd}");
            }
        }
    }

    #[test]
    fn rowmod_rejects_indefinite() {
        let a = CscMatrix::from_triplets(
            2,
            2,
            &[(0, 0, 2.0), (1, 0, 0.5), (0, 1, 0.5), (1, 1, 2.0)],
        );
        let sym = Arc::new(Symbolic::analyze(&a));
        let mut f = LdlFactor::factor(sym, &a).unwrap();
        let mut ws = RowModWorkspace::new(2);
        // new row 0 with huge off-diagonal breaks positive definiteness
        let r = f.ldl_row_modify(0, &[0, 1], &[1.0, 10.0], &mut ws);
        assert!(r.is_err());
    }
}
