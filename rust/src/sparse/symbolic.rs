//! Symbolic Cholesky analysis: the *static* nonzero pattern of `L`.
//!
//! The paper's EP algorithm exploits the fact that the sparsity pattern of
//! `B = I + S̃^{1/2} K S̃^{1/2}` never changes while sites are updated
//! (section 5.2): the pattern — including fill — is analysed once here, and
//! every numeric kernel (factorization, row modification, rank-one
//! update/downdate, Takahashi inverse) then works in-place on it.
//!
//! Besides the column/row pattern maps, the analysis derives the
//! [`SupernodeSchedule`]: contiguous column blocks with nested sub-pattern
//! (supernodes) grouped into assembly-tree level waves, the static
//! parallel schedule of [`crate::sparse::cholesky`]'s numeric
//! factorization. Everything here is computed once per pattern —
//! `O(nnz(L))` beyond the two `ereach` passes — and shared through
//! `Arc<Symbolic>` by every factor, so the optimizer loop's
//! [`crate::gp::cache::PatternCache`] amortizes it across all
//! hyperparameter evaluations that keep the pattern.

use std::sync::{Arc, OnceLock};

use crate::sparse::csc::CscMatrix;
use crate::sparse::etree::{ereach, etree, height_waves};
use crate::sparse::ordering::SeparatorTree;

/// Relaxed-amalgamation policy: how much *explicit zero fill* the analysis
/// may pad into the factor pattern to fatten thin supernodes.
///
/// Strict supernodes (`pat(j) = {j+1} ∪ pat(j+1)`) on covariance-sparse
/// patterns are mostly 1–3 columns wide, which starves the blocked numeric
/// kernels of panel width. Amalgamation merges a supernode into its
/// assembly-tree parent when the padding cost stays under
/// `abs + rel · strict_nnz(merged)` entries — the classical relaxed
/// supernode idea (Ashcraft/Grimes, CHOLMOD), except the padded entries
/// here are *structural* zeros that stay exactly `0.0` through every
/// refactorization, so all downstream consumers (solves, Takahashi,
/// rank-one updates, row modification) keep their semantics.
///
/// The process-wide default is tunable via `CSGP_AMALG`:
/// `0`/`off` disables, `rel` or `rel,abs` tunes the budget, anything else
/// (or unset) keeps the defaults. Tests and benches pin an explicit
/// config through [`Symbolic::analyze_with`].
#[derive(Clone, Debug, PartialEq)]
pub struct AmalgConfig {
    /// `false` = keep exactly the strict supernodes (no padding).
    pub enabled: bool,
    /// Padded entries allowed per merged supernode, relative to its
    /// strict entry count.
    pub rel: f64,
    /// Flat padded-entry allowance per merged supernode (lets tiny
    /// supernodes merge even when `rel` rounds to nothing).
    pub abs: usize,
    /// Hard cap on merged supernode width, bounding panel scratch.
    pub max_cols: usize,
}

impl Default for AmalgConfig {
    fn default() -> Self {
        AmalgConfig { enabled: true, rel: 0.25, abs: 16, max_cols: 192 }
    }
}

impl AmalgConfig {
    /// Strict supernodes only — the pre-amalgamation behavior.
    pub fn disabled() -> Self {
        AmalgConfig { enabled: false, ..Default::default() }
    }

    /// Parse a `CSGP_AMALG` value: `0`/`off`/`false` disables, `1`/`on`
    /// keeps the defaults, `rel` or `rel,abs` tunes the budget. `None`
    /// (or an unparsable value) means "no override".
    pub fn parse_override(var: Option<&str>) -> Option<AmalgConfig> {
        let s = var?.trim();
        if s.is_empty() {
            return None;
        }
        match s.to_ascii_lowercase().as_str() {
            "0" | "off" | "false" => return Some(AmalgConfig::disabled()),
            "1" | "on" | "true" => return Some(AmalgConfig::default()),
            _ => {}
        }
        let mut parts = s.split(',');
        let rel: f64 = parts.next()?.trim().parse().ok()?;
        let abs: usize = match parts.next() {
            Some(t) => t.trim().parse().ok()?,
            None => AmalgConfig::default().abs,
        };
        if parts.next().is_some() || !rel.is_finite() || rel < 0.0 {
            return None;
        }
        Some(AmalgConfig { enabled: true, rel, abs, ..Default::default() })
    }

    /// The process-wide policy: `CSGP_AMALG` if set and parsable, the
    /// defaults otherwise. Read once (same contract as `CSGP_THREADS` /
    /// `CSGP_ORDERING`).
    pub fn global() -> &'static AmalgConfig {
        static G: OnceLock<AmalgConfig> = OnceLock::new();
        G.get_or_init(|| {
            AmalgConfig::parse_override(std::env::var("CSGP_AMALG").ok().as_deref())
                .unwrap_or_default()
        })
    }
}

/// Supernode partition of the columns plus the assembly-tree wave
/// schedule — the static scaffolding of the parallel numeric LDLᵀ.
///
/// A *supernode* is a maximal run of consecutive columns `j, j+1, …` where
/// each column's strictly-lower pattern is the next column's pattern plus
/// that next column itself (`pat(j) = {j+1} ∪ pat(j+1)`, detected as
/// `parent[j] == j+1 && |pat(j)| == |pat(j+1)| + 1`). Such columns share
/// one (suffix-nested) sub-pattern, always form a path in the etree, and
/// are factored by a single task. Contracting each supernode to a node of
/// the etree yields the *assembly tree*; its height-level waves
/// ([`crate::sparse::etree::height_waves`], leaves first) are the parallel
/// schedule: column j of L depends only on columns in j's etree subtree,
/// so every supernode's dependencies complete in strictly earlier waves
/// and the supernodes of one wave are independent tasks.
///
/// Invariants: `snode_ptr` is strictly increasing with
/// `snode_ptr[0] == 0`, `snode_ptr[n_snodes] == n`; `wave_snodes` is a
/// permutation of `0..n_snodes` ascending within each wave; a supernode's
/// wave index is strictly greater than every child's.
#[derive(Clone, Debug)]
pub struct SupernodeSchedule {
    /// Supernode s spans columns `snode_ptr[s]..snode_ptr[s + 1]`.
    pub snode_ptr: Vec<usize>,
    /// Supernode owning each column (inverse of `snode_ptr`).
    pub snode_of: Vec<usize>,
    /// Assembly-tree parent of each supernode (usize::MAX at roots) — the
    /// supernode owning the etree parent of this supernode's last column.
    pub sparent: Vec<usize>,
    /// Supernode ids grouped by assembly-tree height, leaves first:
    /// `wave_snodes[wave_ptr[w]..wave_ptr[w + 1]]` is wave w.
    pub wave_snodes: Vec<usize>,
    /// Wave boundaries into `wave_snodes` (`len == n_waves + 1`).
    pub wave_ptr: Vec<usize>,
    /// Per-supernode update sources, CSR by target: supernode s pulls
    /// rank-k updates from supernodes
    /// `src_snodes[src_ptr[s]..src_ptr[s + 1]]` (ascending — the order
    /// that pins the blocked kernel's deterministic summation).
    pub src_ptr: Vec<usize>,
    /// Concatenated ascending source-supernode lists.
    pub src_snodes: Vec<usize>,
}

impl SupernodeSchedule {
    /// Build the wave schedule and the source lists for an arbitrary
    /// supernode partition of the pattern `(col_ptr, row_idx)`.
    fn build(
        parent: &[usize],
        snode_ptr: Vec<usize>,
        col_ptr: &[usize],
        row_idx: &[usize],
    ) -> SupernodeSchedule {
        let n = parent.len();
        let n_snodes = snode_ptr.len().saturating_sub(1);

        // column -> supernode map, then the contracted (assembly) tree:
        // the parent supernode is the one owning the etree parent of the
        // supernode's last column.
        let mut snode_of = vec![0usize; n];
        for s in 0..n_snodes {
            for j in snode_ptr[s]..snode_ptr[s + 1] {
                snode_of[j] = s;
            }
        }
        let mut sparent = vec![usize::MAX; n_snodes];
        for s in 0..n_snodes {
            let last = snode_ptr[s + 1] - 1;
            let p = parent[last];
            if p != usize::MAX {
                sparent[s] = snode_of[p];
            }
        }

        let (mut wave_snodes, mut wave_ptr) = (Vec::new(), Vec::new());
        height_waves(&sparent, &mut wave_snodes, &mut wave_ptr);

        // Source lists: supernode q updates supernode s iff q's top-column
        // pattern (which every column of q stores as its suffix) reaches
        // into s's column range. The pattern is sorted, so the distinct
        // targets are a run-length pass; pushing edges with q ascending
        // makes each target's source list ascending after the counting
        // sort — exactly the pull order the numeric kernel must keep.
        let mut edges: Vec<(usize, usize)> = Vec::new();
        for q in 0..n_snodes {
            let top = snode_ptr[q + 1] - 1;
            let mut prev = usize::MAX;
            for &i in &row_idx[col_ptr[top]..col_ptr[top + 1]] {
                let s = snode_of[i];
                if s != prev {
                    edges.push((s, q));
                    prev = s;
                }
            }
        }
        let mut src_ptr = vec![0usize; n_snodes + 1];
        for &(s, _) in &edges {
            src_ptr[s + 1] += 1;
        }
        for s in 0..n_snodes {
            src_ptr[s + 1] += src_ptr[s];
        }
        let mut next = src_ptr.clone();
        let mut src_snodes = vec![0usize; edges.len()];
        for &(s, q) in &edges {
            src_snodes[next[s]] = q;
            next[s] += 1;
        }

        SupernodeSchedule { snode_ptr, snode_of, sparent, wave_snodes, wave_ptr, src_ptr, src_snodes }
    }

    /// Detect the *strict* supernode partition: maximal runs where each
    /// column's strictly-lower pattern is the next column's pattern plus
    /// that column (`parent[j] == j+1 && |pat(j)| == |pat(j+1)| + 1`).
    fn strict_partition(parent: &[usize], col_ptr: &[usize]) -> Vec<usize> {
        let n = parent.len();
        let count = |j: usize| col_ptr[j + 1] - col_ptr[j];
        let mut snode_ptr = Vec::with_capacity(n + 1);
        snode_ptr.push(0);
        for j in 1..n {
            let prev = j - 1;
            let merges = parent[prev] == j && count(prev) == count(j) + 1;
            if !merges {
                snode_ptr.push(j);
            }
        }
        if n > 0 {
            snode_ptr.push(n);
        }
        snode_ptr
    }

    /// Number of supernodes.
    pub fn n_snodes(&self) -> usize {
        self.snode_ptr.len().saturating_sub(1)
    }

    /// Number of level waves in the schedule.
    pub fn n_waves(&self) -> usize {
        self.wave_ptr.len().saturating_sub(1)
    }

    /// Columns of supernode `s`.
    #[inline]
    pub fn columns(&self, s: usize) -> std::ops::Range<usize> {
        self.snode_ptr[s]..self.snode_ptr[s + 1]
    }

    /// Supernode ids of wave `w` (ascending).
    #[inline]
    pub fn wave(&self, w: usize) -> &[usize] {
        &self.wave_snodes[self.wave_ptr[w]..self.wave_ptr[w + 1]]
    }

    /// Widest wave, in supernodes — the schedule's peak task parallelism.
    /// This is the number the fill-reducing ordering controls: RCM's
    /// near-path etrees cap it near 1, nested dissection's balanced
    /// separator hierarchy fans it out (see `sparse::ordering`).
    pub fn wave_width_max(&self) -> usize {
        (0..self.n_waves()).map(|w| self.wave(w).len()).max().unwrap_or(0)
    }

    /// Widest wave, in columns — the work (not task) width, a load-balance
    /// ceiling for the chunked dispatch.
    pub fn wave_cols_max(&self) -> usize {
        (0..self.n_waves())
            .map(|w| {
                self.wave(w).iter().map(|&s| self.columns(s).len()).sum::<usize>()
            })
            .max()
            .unwrap_or(0)
    }

    /// Ascending source supernodes of `s` — the supernodes whose columns
    /// carry rank-k updates into `s`'s panel.
    #[inline]
    pub fn sources(&self, s: usize) -> &[usize] {
        &self.src_snodes[self.src_ptr[s]..self.src_ptr[s + 1]]
    }

    /// Widest supernode, in columns — the amalgamation result the blocked
    /// kernels' panel scratch is sized by.
    pub fn max_snode_cols(&self) -> usize {
        self.snode_ptr.windows(2).map(|w| w[1] - w[0]).max().unwrap_or(0)
    }
}

/// Greedy left-to-right relaxed amalgamation over the strict partition.
///
/// A group `[g0, b)` absorbs the next strict supernode `[b, e)` only when
/// all of:
///
/// * **assembly adjacency** — `parent[b-1] ∈ [b, e)`: the candidate is the
///   assembly-tree parent of the group, so the etree path out of any group
///   column runs through the candidate's column chain and the padded
///   pattern `{j+1..e-1} ∪ pat(e-1)` stays closed under the fill rule
///   (this is also what keeps the rank-one update's path walk covering);
/// * **width cap** — the merged supernode stays within `cfg.max_cols`;
/// * **fill budget** — the padding
///   `(t·u + u(u-1)/2) − strict_nnz ≤ abs + rel · strict_nnz`, where `u`
///   is the merged width and `t = |pat(e-1)|` the merged top count.
///
/// Returns the merged `snode_ptr` (the strict one when disabled).
fn amalgamate(parent: &[usize], col_ptr: &[usize], strict: Vec<usize>, cfg: &AmalgConfig) -> Vec<usize> {
    let ns = strict.len().saturating_sub(1);
    if !cfg.enabled || ns <= 1 {
        return strict;
    }
    let n = parent.len();
    let mut out = Vec::with_capacity(strict.len());
    out.push(0usize);
    let mut g0 = 0usize;
    for s in 1..ns {
        let b = strict[s];
        let e = strict[s + 1];
        let u = e - g0;
        let adjacent = parent[b - 1] != usize::MAX && parent[b - 1] < e;
        let strict_nnz = col_ptr[e] - col_ptr[g0];
        let t = col_ptr[e] - col_ptr[e - 1];
        let padded = t * u + u * (u - 1) / 2;
        // `padded >= strict_nnz` holds whenever `adjacent` does (pattern
        // closure); saturate so the non-adjacent evaluation can't wrap.
        let extra = padded.saturating_sub(strict_nnz);
        let within = extra as f64 <= cfg.abs as f64 + cfg.rel * strict_nnz as f64;
        if !(adjacent && u <= cfg.max_cols && within) {
            out.push(b);
            g0 = b;
        }
    }
    out.push(n);
    out
}

/// Rebuild `(col_ptr, row_idx)` with every supernode's columns padded to
/// the trapezoidal panel pattern `{j+1..jend-1} ∪ pat(jend-1)`. For a
/// strict supernode this reproduces its pattern exactly (suffix nesting),
/// so only genuinely merged columns gain (structurally zero) slots.
fn pad_pattern(
    snode_ptr: &[usize],
    col_ptr: &[usize],
    row_idx: &[usize],
    n: usize,
) -> (Vec<usize>, Vec<usize>) {
    let ns = snode_ptr.len() - 1;
    let mut pcol = vec![0usize; n + 1];
    for s in 0..ns {
        let (j0, jend) = (snode_ptr[s], snode_ptr[s + 1]);
        let t = col_ptr[jend] - col_ptr[jend - 1];
        for j in j0..jend {
            pcol[j + 1] = (jend - 1 - j) + t;
        }
    }
    for j in 0..n {
        pcol[j + 1] += pcol[j];
    }
    let mut pidx = vec![0usize; pcol[n]];
    for s in 0..ns {
        let (j0, jend) = (snode_ptr[s], snode_ptr[s + 1]);
        let top = &row_idx[col_ptr[jend - 1]..col_ptr[jend]];
        for j in j0..jend {
            let mut p = pcol[j];
            for i in j + 1..jend {
                pidx[p] = i;
                p += 1;
            }
            pidx[p..p + top.len()].copy_from_slice(top);
        }
    }
    (pcol, pidx)
}

/// Static symbolic factorization of a symmetric matrix pattern.
///
/// With amalgamation enabled (the default) the stored pattern is the
/// *padded* pattern: every column of a supernode `[j0, jend)` stores
/// `{j+1..jend-1} ∪ pat(jend-1)` so the supernode is a dense trapezoidal
/// panel. Padded slots are structural zeros — every refactorization
/// computes them as exactly `0.0` — and `nnz_strict` keeps the true
/// (unpadded) count for fill statistics and ordering comparisons.
#[derive(Clone, Debug)]
pub struct Symbolic {
    pub n: usize,
    /// Elimination-tree parent (usize::MAX at roots).
    pub parent: Vec<usize>,
    /// Column pointers of the strictly-lower-triangular pattern of L
    /// (padded when amalgamation merged supernodes).
    pub col_ptr: Vec<usize>,
    /// Row indices (sorted, all > column index) of the L pattern.
    pub row_idx: Vec<usize>,
    /// Strictly-lower nonzero count of the *strict* (unpadded) pattern —
    /// what the factor would store with amalgamation off.
    pub nnz_strict: usize,
    /// Row-structure map (CSR over the same pattern): for each row i, the
    /// positions `p` into `row_idx`/values such that `row_idx[p] == i`,
    /// together with the owning column. Lets `ldlrowmodify` write row i of
    /// L without searching, and the left-pulling numeric factorization
    /// walk row j's update sources in ascending column order.
    pub rowmap_ptr: Vec<usize>,
    /// (column j, position p) pairs, ordered by row then column.
    pub rowmap: Vec<(usize, usize)>,
    /// Supernode partition + assembly-tree waves (see
    /// [`SupernodeSchedule`]); the parallel schedule of the numeric LDLᵀ.
    pub schedule: SupernodeSchedule,
    /// The nested-dissection separator tree behind the permutation this
    /// pattern was analysed in, when the ordering produced one. The
    /// assembly tree the [`SupernodeSchedule`] waves over is exactly this
    /// hierarchy refined into supernode chains — eliminating one dissection
    /// half never reaches into the other, so sibling branches land in
    /// disjoint etree subtrees and fan out as independent wave tasks. Kept
    /// here (rather than in the ordering layer) so every factor, bench and
    /// scheduler holding an `Arc<Symbolic>` can see the block hierarchy
    /// its waves came from; the separator invariant is re-validated against
    /// the analysed pattern in debug builds.
    pub septree: Option<Arc<SeparatorTree>>,
}

impl Symbolic {
    /// Analyse the pattern of symmetric `a` (full storage, diagonal present).
    pub fn analyze(a: &CscMatrix) -> Symbolic {
        Symbolic::analyze_with(a, None, AmalgConfig::global())
    }

    /// [`Symbolic::analyze`], threading through the separator tree of the
    /// (nested-dissection) ordering `a` was permuted with.
    pub fn analyze_with_septree(
        a: &CscMatrix,
        septree: Option<Arc<SeparatorTree>>,
    ) -> Symbolic {
        Symbolic::analyze_with(a, septree, AmalgConfig::global())
    }

    /// The full analysis with an explicit amalgamation policy (tests and
    /// benches pin `AmalgConfig::disabled()` / tuned budgets here; the
    /// public wrappers use the process-wide `CSGP_AMALG` policy). Debug
    /// builds re-check the separator invariant — no pattern edge between
    /// sibling branches — against `a` itself, so a mismatched
    /// tree/permutation pair fails loudly instead of silently
    /// mis-describing the factor.
    pub fn analyze_with(
        a: &CscMatrix,
        septree: Option<Arc<SeparatorTree>>,
        amalg: &AmalgConfig,
    ) -> Symbolic {
        assert_eq!(a.n_rows, a.n_cols);
        if let Some(tree) = &septree {
            debug_assert!(
                tree.validate(a).is_ok(),
                "separator tree does not match the permuted pattern: {:?}",
                tree.validate(a)
            );
        }
        let n = a.n_rows;
        let parent = etree(a);
        let mut mark = vec![usize::MAX; n];
        let mut rowpat = Vec::new();

        // Pass 1: column counts of L (strictly lower) via row patterns.
        let mut counts = vec![0usize; n];
        for k in 0..n {
            ereach(a, k, &parent, &mut mark, &mut rowpat);
            for &j in rowpat.iter() {
                counts[j] += 1; // L[k, j] exists
            }
        }
        let mut col_ptr = vec![0usize; n + 1];
        for j in 0..n {
            col_ptr[j + 1] = col_ptr[j] + counts[j];
        }
        let nnz = col_ptr[n];

        // Pass 2: fill row indices. Processing k ascending appends rows in
        // ascending order within each column.
        let mut next = col_ptr.clone();
        let mut row_idx = vec![0usize; nnz];
        let mut mark2 = vec![usize::MAX; n];
        for k in 0..n {
            ereach(a, k, &parent, &mut mark2, &mut rowpat);
            for &j in rowpat.iter() {
                row_idx[next[j]] = k;
                next[j] += 1;
            }
        }

        let nnz_strict = nnz;

        // Supernode partition: strict detection, then relaxed
        // amalgamation, then (when anything merged) the padded pattern
        // `{j+1..jend-1} ∪ pat(jend-1)` per merged column.
        let strict_ptr = SupernodeSchedule::strict_partition(&parent, &col_ptr);
        let snode_ptr = amalgamate(&parent, &col_ptr, strict_ptr.clone(), amalg);
        let (col_ptr, row_idx) = if snode_ptr.len() == strict_ptr.len() {
            (col_ptr, row_idx)
        } else {
            pad_pattern(&snode_ptr, &col_ptr, &row_idx, n)
        };
        let nnz = row_idx.len();

        // Row-structure map: CSR over (row -> [(col, pos)]).
        let mut rcount = vec![0usize; n + 1];
        for &i in &row_idx {
            rcount[i + 1] += 1;
        }
        for i in 0..n {
            rcount[i + 1] += rcount[i];
        }
        let rowmap_ptr = rcount.clone();
        let mut rnext = rcount;
        let mut rowmap = vec![(0usize, 0usize); nnz];
        for j in 0..n {
            for p in col_ptr[j]..col_ptr[j + 1] {
                let i = row_idx[p];
                rowmap[rnext[i]] = (j, p);
                rnext[i] += 1;
            }
        }

        let schedule = SupernodeSchedule::build(&parent, snode_ptr, &col_ptr, &row_idx);
        Symbolic { n, parent, col_ptr, row_idx, nnz_strict, rowmap_ptr, rowmap, schedule, septree }
    }

    /// Reassemble an analysis from its serialized parts (the model
    /// snapshot loader): the elimination tree, the (possibly padded)
    /// column pattern, the strict nonzero count and the supernode
    /// partition are stored verbatim; the derived structures — the row
    /// map and the wave/source schedule — are deterministic functions of
    /// them and are rebuilt here in `O(nnz)`, so a loaded factor is
    /// solve- and refactor-ready without re-running `analyze` (no etree,
    /// no ereach passes, no amalgamation policy — the snapshot pins the
    /// exact pattern the factor's values are aligned with). The separator
    /// tree is not restored: it only accelerates fresh ND *orderings*,
    /// which a loaded plan never recomputes.
    pub fn from_parts(
        n: usize,
        parent: Vec<usize>,
        col_ptr: Vec<usize>,
        row_idx: Vec<usize>,
        nnz_strict: usize,
        snode_ptr: Vec<usize>,
    ) -> Symbolic {
        assert_eq!(parent.len(), n);
        assert_eq!(col_ptr.len(), n + 1);
        assert_eq!(*col_ptr.last().unwrap_or(&0), row_idx.len());
        let nnz = row_idx.len();
        let mut rcount = vec![0usize; n + 1];
        for &i in &row_idx {
            rcount[i + 1] += 1;
        }
        for i in 0..n {
            rcount[i + 1] += rcount[i];
        }
        let rowmap_ptr = rcount.clone();
        let mut rnext = rcount;
        let mut rowmap = vec![(0usize, 0usize); nnz];
        for j in 0..n {
            for p in col_ptr[j]..col_ptr[j + 1] {
                let i = row_idx[p];
                rowmap[rnext[i]] = (j, p);
                rnext[i] += 1;
            }
        }
        let schedule = SupernodeSchedule::build(&parent, snode_ptr, &col_ptr, &row_idx);
        Symbolic {
            n,
            parent,
            col_ptr,
            row_idx,
            nnz_strict,
            rowmap_ptr,
            rowmap,
            schedule,
            septree: None,
        }
    }

    /// Number of nonzeros in L including the diagonal — the *strict*
    /// count (padding excluded), so fill statistics and ordering-quality
    /// comparisons measure true fill regardless of the amalgamation
    /// policy. Storage sizing goes through `row_idx.len()` /
    /// [`Symbolic::padded_nnz`].
    pub fn nnz_l(&self) -> usize {
        self.nnz_strict + self.n
    }

    /// Stored nonzeros of L including the diagonal and any amalgamation
    /// padding — the factor's actual allocation size.
    pub fn padded_nnz(&self) -> usize {
        self.row_idx.len() + self.n
    }

    /// Paper's fill-L statistic: nnz(L) / (n(n+1)/2).
    pub fn fill_l(&self) -> f64 {
        self.nnz_l() as f64 / (self.n as f64 * (self.n as f64 + 1.0) / 2.0)
    }

    /// Strictly-lower pattern entries of column j.
    #[inline]
    pub fn col_pattern(&self, j: usize) -> &[usize] {
        &self.row_idx[self.col_ptr[j]..self.col_ptr[j + 1]]
    }

    /// (column, position) pairs of row i's strictly-lower entries.
    #[inline]
    pub fn row_pattern(&self, i: usize) -> &[(usize, usize)] {
        &self.rowmap[self.rowmap_ptr[i]..self.rowmap_ptr[i + 1]]
    }

    /// Position of entry (i, j) in the value array, if present.
    pub fn find(&self, i: usize, j: usize) -> Option<usize> {
        let lo = self.col_ptr[j];
        let hi = self.col_ptr[j + 1];
        self.row_idx[lo..hi].binary_search(&i).ok().map(|p| lo + p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::csc::CscMatrix;

    fn tridiag(n: usize) -> CscMatrix {
        let mut t = Vec::new();
        for i in 0..n {
            t.push((i, i, 4.0));
            if i + 1 < n {
                t.push((i, i + 1, 1.0));
                t.push((i + 1, i, 1.0));
            }
        }
        CscMatrix::from_triplets(n, n, &t)
    }

    /// Analyse with amalgamation pinned off — the strict-supernode shape
    /// the structural tests below assert.
    fn analyze_strict(a: &CscMatrix) -> Symbolic {
        Symbolic::analyze_with(a, None, &AmalgConfig::disabled())
    }

    #[test]
    fn tridiagonal_no_fill() {
        let s = analyze_strict(&tridiag(6));
        // strictly lower: one entry per column except the last
        assert_eq!(s.row_idx.len(), 5);
        for j in 0..5 {
            assert_eq!(s.col_pattern(j), &[j + 1]);
        }
        assert!(s.col_pattern(5).is_empty());
    }

    #[test]
    fn fill_in_happens() {
        // "bowtie": row 0 connected to everything -> eliminating 0 first
        // fills in the rest completely.
        let n = 5;
        let mut t = Vec::new();
        for i in 0..n {
            t.push((i, i, 4.0));
            if i > 0 {
                t.push((0, i, 1.0));
                t.push((i, 0, 1.0));
            }
        }
        let a = CscMatrix::from_triplets(n, n, &t);
        let s = Symbolic::analyze(&a);
        // After eliminating node 0 the remainder is a clique: L is full.
        assert_eq!(s.nnz_l(), n * (n + 1) / 2);
        assert!((s.fill_l() - 1.0).abs() < 1e-12);
    }

    /// Dense pattern: every column's pattern is the suffix of the next ->
    /// one supernode, one wave (a dense LDLᵀ is inherently sequential).
    #[test]
    fn dense_pattern_is_one_supernode() {
        let n = 6;
        let mut t = Vec::new();
        for i in 0..n {
            for j in 0..n {
                t.push((i, j, if i == j { 4.0 } else { 0.5 }));
            }
        }
        let s = Symbolic::analyze(&CscMatrix::from_triplets(n, n, &t));
        assert_eq!(s.schedule.n_snodes(), 1);
        assert_eq!(s.schedule.columns(0), 0..n);
        assert_eq!(s.schedule.n_waves(), 1);
    }

    /// Tridiagonal: pat(j) = {j+1} is NOT `{j+1} ∪ pat(j+1)` for interior
    /// columns (pat(j+1) = {j+2} ≠ ∅), so only the final pair merges
    /// (pat(n−2) = {n−1} = {n−1} ∪ pat(n−1)); the bidiagonal dependency
    /// chain makes every wave a singleton.
    #[test]
    fn tridiagonal_has_singleton_supernodes_in_a_chain() {
        let n = 7;
        let s = analyze_strict(&tridiag(n));
        assert_eq!(s.schedule.n_snodes(), n - 1, "last two columns merge");
        assert_eq!(s.schedule.columns(n - 2), n - 2..n);
        assert_eq!(s.schedule.n_waves(), n - 1);
        for w in 0..n - 1 {
            assert_eq!(s.schedule.wave(w), &[w]);
        }
    }

    /// Arrow matrix (dense last row/col): columns 0..n-2 are independent
    /// leaves in one wide wave; the last two columns share a pattern
    /// suffix and merge into the root supernode.
    #[test]
    fn arrow_pattern_merges_the_tail_and_parallelizes_the_leaves() {
        let n = 8;
        let mut t = Vec::new();
        for i in 0..n {
            t.push((i, i, 4.0));
            if i + 1 < n {
                t.push((i, n - 1, 1.0));
                t.push((n - 1, i, 1.0));
            }
        }
        let s = analyze_strict(&CscMatrix::from_triplets(n, n, &t));
        let sched = &s.schedule;
        assert_eq!(sched.n_snodes(), n - 1, "n-2 leaves + merged {{n-2, n-1}} root");
        assert_eq!(sched.columns(n - 2), n - 2..n);
        assert_eq!(sched.n_waves(), 2);
        assert_eq!(sched.wave(0).len(), n - 2);
        assert_eq!(sched.wave(1), &[n - 2]);
    }

    /// Structural invariants on irregular (geometric CS covariance)
    /// patterns: supernodes partition the columns, merged columns have the
    /// promised suffix-nested pattern, and every column's update sources
    /// (its row pattern) complete in an earlier wave or earlier in the
    /// same supernode.
    #[test]
    fn schedule_invariants_on_cs_covariance_patterns() {
        use crate::gp::covariance::{CovFunction, CovKind};
        use crate::testutil::random_points;
        for (seed, ls) in [(1u64, 1.4), (2, 2.2)] {
            let x = random_points(120, 2, 7.0, seed);
            let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, ls);
            let mut k = cov.cov_matrix(&x);
            for j in 0..k.n_cols {
                *k.get_mut(j, j) += 1.0;
            }
            let s = Symbolic::analyze(&k);
            let sched = &s.schedule;
            let n = s.n;
            // partition + permutation
            assert_eq!(*sched.snode_ptr.first().unwrap(), 0);
            assert_eq!(*sched.snode_ptr.last().unwrap(), n);
            assert!(sched.snode_ptr.windows(2).all(|w| w[0] < w[1]));
            let mut seen: Vec<usize> = sched.wave_snodes.clone();
            seen.sort_unstable();
            assert_eq!(seen, (0..sched.n_snodes()).collect::<Vec<_>>());
            // nested pattern inside each supernode
            let mut snode_of = vec![0usize; n];
            for sn in 0..sched.n_snodes() {
                let cols = sched.columns(sn);
                for j in cols.clone() {
                    snode_of[j] = sn;
                }
                for j in cols.start..cols.end.saturating_sub(1) {
                    let pat = s.col_pattern(j);
                    assert_eq!(pat[0], j + 1, "first below-diagonal entry is the next column");
                    assert_eq!(&pat[1..], s.col_pattern(j + 1), "suffix-nested supernode pattern");
                }
            }
            let mut wave_of = vec![0usize; sched.n_snodes()];
            for w in 0..sched.n_waves() {
                for &sn in sched.wave(w) {
                    wave_of[sn] = w;
                }
            }
            for j in 0..n {
                for &(k_src, _) in s.row_pattern(j) {
                    let (ss, st) = (snode_of[k_src], snode_of[j]);
                    assert!(
                        wave_of[ss] < wave_of[st] || (ss == st && k_src < j),
                        "source column {k_src} of {j} not scheduled before it"
                    );
                }
            }
        }
    }

    /// Arrow: one wave of n−2 singleton-column supernode leaves, then the
    /// merged root — the width helpers must read exactly that off the
    /// schedule.
    #[test]
    fn wave_width_helpers_measure_the_schedule() {
        let n = 8;
        let mut t = Vec::new();
        for i in 0..n {
            t.push((i, i, 4.0));
            if i + 1 < n {
                t.push((i, n - 1, 1.0));
                t.push((n - 1, i, 1.0));
            }
        }
        let s = analyze_strict(&CscMatrix::from_triplets(n, n, &t));
        assert_eq!(s.schedule.wave_width_max(), n - 2);
        assert_eq!(s.schedule.wave_cols_max(), n - 2);
        assert!(s.septree.is_none(), "plain analyze carries no separator tree");
    }

    /// A nested-dissection plan threads its separator tree into the
    /// analysis; the schedule built on it fans out wider than the same
    /// pattern under RCM (the balanced-assembly-tree claim, checked at
    /// unit scale — `benches/perf_parallel.rs` tracks it at n >= 4000).
    #[test]
    fn separator_tree_threads_into_the_analysis() {
        use crate::gp::covariance::{CovFunction, CovKind};
        use crate::sparse::ordering::{order, Ordering};
        use crate::testutil::random_points;
        let x = random_points(400, 2, 9.0, 3);
        let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.3);
        let mut k = cov.cov_matrix(&x);
        for j in 0..k.n_cols {
            *k.get_mut(j, j) += 1.0;
        }
        let nd = order(&k, Ordering::Nd, Some(&x));
        let tree = Arc::new(nd.septree.expect("nd must produce a separator tree"));
        let s_nd =
            Symbolic::analyze_with_septree(&k.permute_sym(&nd.perm), Some(tree.clone()));
        assert!(Arc::ptr_eq(s_nd.septree.as_ref().unwrap(), &tree));
        s_nd.septree.as_ref().unwrap().validate(&k.permute_sym(&nd.perm)).unwrap();
        let rcm = order(&k, Ordering::Rcm, None);
        let s_rcm = Symbolic::analyze(&k.permute_sym(&rcm.perm));
        assert!(
            s_nd.schedule.wave_width_max() > s_rcm.schedule.wave_width_max(),
            "nd wave width {} vs rcm {}",
            s_nd.schedule.wave_width_max(),
            s_rcm.schedule.wave_width_max()
        );
    }

    #[test]
    fn rowmap_consistent_with_colmap() {
        let a = tridiag(7);
        let s = Symbolic::analyze(&a);
        for i in 0..7 {
            for &(j, p) in s.row_pattern(i) {
                assert_eq!(s.row_idx[p], i);
                assert!(s.col_ptr[j] <= p && p < s.col_ptr[j + 1]);
            }
        }
    }

    /// The snapshot loader's contract: rebuilding an analysis from its
    /// serialized parts reproduces every derived structure of the
    /// original `analyze` exactly (row map, supernode partition, wave
    /// schedule, source lists).
    #[test]
    fn from_parts_reproduces_analyze() {
        for a in [tridiag(9), cs_pattern(80, 1.8, 5)] {
            let s = Symbolic::analyze(&a);
            let r = Symbolic::from_parts(
                s.n,
                s.parent.clone(),
                s.col_ptr.clone(),
                s.row_idx.clone(),
                s.nnz_strict,
                s.schedule.snode_ptr.clone(),
            );
            assert_eq!(r.rowmap_ptr, s.rowmap_ptr);
            assert_eq!(r.rowmap, s.rowmap);
            assert_eq!(r.schedule.snode_ptr, s.schedule.snode_ptr);
            assert_eq!(r.schedule.snode_of, s.schedule.snode_of);
            assert_eq!(r.schedule.sparent, s.schedule.sparent);
            assert_eq!(r.schedule.wave_snodes, s.schedule.wave_snodes);
            assert_eq!(r.schedule.wave_ptr, s.schedule.wave_ptr);
            assert_eq!(r.schedule.src_ptr, s.schedule.src_ptr);
            assert_eq!(r.schedule.src_snodes, s.schedule.src_snodes);
            assert_eq!(r.nnz_l(), s.nnz_l());
        }
    }

    #[test]
    fn find_locates_entries() {
        let s = analyze_strict(&tridiag(5));
        assert!(s.find(1, 0).is_some());
        assert!(s.find(2, 0).is_none());
    }

    /// A geometric CS covariance pattern — the fixture the amalgamation
    /// tests run on (thin strict supernodes, real fill).
    fn cs_pattern(n: usize, ls: f64, seed: u64) -> CscMatrix {
        use crate::gp::covariance::{CovFunction, CovKind};
        use crate::testutil::random_points;
        let x = random_points(n, 2, 7.0, seed);
        let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, ls);
        let mut k = cov.cov_matrix(&x);
        for j in 0..k.n_cols {
            *k.get_mut(j, j) += 1.0;
        }
        k
    }

    /// Relaxed amalgamation fattens the strict chain of a tridiagonal
    /// pattern into multi-column panels, the padding stays within the
    /// budget, and `nnz_l` keeps reporting strict fill.
    #[test]
    fn amalgamation_fattens_thin_supernodes_within_budget() {
        let n = 40;
        let cfg = AmalgConfig::default();
        let s = Symbolic::analyze_with(&tridiag(n), None, &cfg);
        let strict = analyze_strict(&tridiag(n));
        assert!(
            s.schedule.max_snode_cols() > strict.schedule.max_snode_cols(),
            "amalgamation must widen some supernode ({} vs {})",
            s.schedule.max_snode_cols(),
            strict.schedule.max_snode_cols()
        );
        assert_eq!(s.nnz_l(), strict.nnz_l(), "nnz_l reports strict fill");
        assert!(s.padded_nnz() > s.nnz_l(), "tridiag padding is real fill");
        // per-supernode budget: padded − strict ≤ abs + rel·strict
        for sn in 0..s.schedule.n_snodes() {
            let cols = s.schedule.columns(sn);
            let padded: usize = cols.clone().map(|j| s.col_pattern(j).len()).sum();
            let strict_nnz: usize =
                cols.clone().map(|j| strict.col_pattern(j).len()).sum();
            assert!(
                (padded - strict_nnz) as f64
                    <= cfg.abs as f64 + cfg.rel * strict_nnz as f64,
                "supernode {sn} over budget: {padded} padded vs {strict_nnz} strict"
            );
            assert!(cols.len() <= cfg.max_cols);
        }
    }

    /// Every padded column is the trapezoidal panel pattern
    /// `{j+1..jend-1} ∪ pat(jend-1)`, and contains its strict pattern.
    #[test]
    fn padded_pattern_is_trapezoidal_and_contains_strict() {
        let k = cs_pattern(140, 1.8, 9);
        let s = Symbolic::analyze_with(&k, None, &AmalgConfig::default());
        let strict = analyze_strict(&k);
        assert!(s.padded_nnz() >= strict.padded_nnz());
        for sn in 0..s.schedule.n_snodes() {
            let cols = s.schedule.columns(sn);
            let jend = cols.end;
            let top = s.col_pattern(jend - 1);
            for j in cols {
                let pat = s.col_pattern(j);
                let expect: Vec<usize> =
                    (j + 1..jend).chain(top.iter().copied()).collect();
                assert_eq!(pat, &expect[..], "column {j} not trapezoidal");
                for &i in strict.col_pattern(j) {
                    assert!(
                        pat.binary_search(&i).is_ok(),
                        "strict entry ({i},{j}) missing from padded pattern"
                    );
                }
            }
        }
    }

    /// The source lists are exactly the cross-supernode edges of the row
    /// patterns, ascending — the pull order the numeric kernel keys on.
    #[test]
    fn source_lists_cover_row_pattern_edges() {
        let k = cs_pattern(140, 2.2, 4);
        for cfg in [AmalgConfig::default(), AmalgConfig::disabled()] {
            let s = Symbolic::analyze_with(&k, None, &cfg);
            let sched = &s.schedule;
            for sn in 0..sched.n_snodes() {
                let srcs = sched.sources(sn);
                assert!(srcs.windows(2).all(|w| w[0] < w[1]), "sources not ascending");
                assert!(srcs.iter().all(|&q| q < sn), "source after target");
            }
            for j in 0..s.n {
                let sj = sched.snode_of[j];
                assert!(sched.columns(sj).contains(&j));
                for &(ksrc, _) in s.row_pattern(j) {
                    let sk = sched.snode_of[ksrc];
                    if sk != sj {
                        assert!(
                            sched.sources(sj).binary_search(&sk).is_ok(),
                            "supernode {sk} updates {sj} but is not a listed source"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn amalg_env_override_parses() {
        assert_eq!(AmalgConfig::parse_override(None), None);
        assert_eq!(AmalgConfig::parse_override(Some("")), None);
        assert_eq!(AmalgConfig::parse_override(Some("junk")), None);
        assert_eq!(AmalgConfig::parse_override(Some("-1")), None);
        assert_eq!(
            AmalgConfig::parse_override(Some("0")),
            Some(AmalgConfig::disabled())
        );
        assert_eq!(
            AmalgConfig::parse_override(Some("off")),
            Some(AmalgConfig::disabled())
        );
        assert_eq!(
            AmalgConfig::parse_override(Some("on")),
            Some(AmalgConfig::default())
        );
        let tuned = AmalgConfig::parse_override(Some("0.5,32")).unwrap();
        assert!(tuned.enabled && tuned.rel == 0.5 && tuned.abs == 32);
        let rel_only = AmalgConfig::parse_override(Some("0.1")).unwrap();
        assert!(rel_only.enabled && rel_only.rel == 0.1);
        assert_eq!(rel_only.abs, AmalgConfig::default().abs);
    }
}
