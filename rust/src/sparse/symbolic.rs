//! Symbolic Cholesky analysis: the *static* nonzero pattern of `L`.
//!
//! The paper's EP algorithm exploits the fact that the sparsity pattern of
//! `B = I + S̃^{1/2} K S̃^{1/2}` never changes while sites are updated
//! (section 5.2): the pattern — including fill — is analysed once here, and
//! every numeric kernel (factorization, row modification, rank-one
//! update/downdate, Takahashi inverse) then works in-place on it.

use crate::sparse::csc::CscMatrix;
use crate::sparse::etree::{ereach, etree};

/// Static symbolic factorization of a symmetric matrix pattern.
#[derive(Clone, Debug)]
pub struct Symbolic {
    pub n: usize,
    /// Elimination-tree parent (usize::MAX at roots).
    pub parent: Vec<usize>,
    /// Column pointers of the strictly-lower-triangular pattern of L.
    pub col_ptr: Vec<usize>,
    /// Row indices (sorted, all > column index) of the L pattern.
    pub row_idx: Vec<usize>,
    /// Row-structure map (CSR over the same pattern): for each row i, the
    /// positions `p` into `row_idx`/values such that `row_idx[p] == i`,
    /// together with the owning column. Lets `ldlrowmodify` write row i of
    /// L without searching.
    pub rowmap_ptr: Vec<usize>,
    /// (column j, position p) pairs, ordered by row then column.
    pub rowmap: Vec<(usize, usize)>,
}

impl Symbolic {
    /// Analyse the pattern of symmetric `a` (full storage, diagonal present).
    pub fn analyze(a: &CscMatrix) -> Symbolic {
        assert_eq!(a.n_rows, a.n_cols);
        let n = a.n_rows;
        let parent = etree(a);
        let mut mark = vec![usize::MAX; n];
        let mut rowpat = Vec::new();

        // Pass 1: column counts of L (strictly lower) via row patterns.
        let mut counts = vec![0usize; n];
        for k in 0..n {
            ereach(a, k, &parent, &mut mark, &mut rowpat);
            for &j in rowpat.iter() {
                counts[j] += 1; // L[k, j] exists
            }
        }
        let mut col_ptr = vec![0usize; n + 1];
        for j in 0..n {
            col_ptr[j + 1] = col_ptr[j] + counts[j];
        }
        let nnz = col_ptr[n];

        // Pass 2: fill row indices. Processing k ascending appends rows in
        // ascending order within each column.
        let mut next = col_ptr.clone();
        let mut row_idx = vec![0usize; nnz];
        let mut mark2 = vec![usize::MAX; n];
        for k in 0..n {
            ereach(a, k, &parent, &mut mark2, &mut rowpat);
            for &j in rowpat.iter() {
                row_idx[next[j]] = k;
                next[j] += 1;
            }
        }

        // Row-structure map: CSR over (row -> [(col, pos)]).
        let mut rcount = vec![0usize; n + 1];
        for &i in &row_idx {
            rcount[i + 1] += 1;
        }
        for i in 0..n {
            rcount[i + 1] += rcount[i];
        }
        let rowmap_ptr = rcount.clone();
        let mut rnext = rcount;
        let mut rowmap = vec![(0usize, 0usize); nnz];
        for j in 0..n {
            for p in col_ptr[j]..col_ptr[j + 1] {
                let i = row_idx[p];
                rowmap[rnext[i]] = (j, p);
                rnext[i] += 1;
            }
        }

        Symbolic { n, parent, col_ptr, row_idx, rowmap_ptr, rowmap }
    }

    /// Number of nonzeros in L including the diagonal.
    pub fn nnz_l(&self) -> usize {
        self.row_idx.len() + self.n
    }

    /// Paper's fill-L statistic: nnz(L) / (n(n+1)/2).
    pub fn fill_l(&self) -> f64 {
        self.nnz_l() as f64 / (self.n as f64 * (self.n as f64 + 1.0) / 2.0)
    }

    /// Strictly-lower pattern entries of column j.
    #[inline]
    pub fn col_pattern(&self, j: usize) -> &[usize] {
        &self.row_idx[self.col_ptr[j]..self.col_ptr[j + 1]]
    }

    /// (column, position) pairs of row i's strictly-lower entries.
    #[inline]
    pub fn row_pattern(&self, i: usize) -> &[(usize, usize)] {
        &self.rowmap[self.rowmap_ptr[i]..self.rowmap_ptr[i + 1]]
    }

    /// Position of entry (i, j) in the value array, if present.
    pub fn find(&self, i: usize, j: usize) -> Option<usize> {
        let lo = self.col_ptr[j];
        let hi = self.col_ptr[j + 1];
        self.row_idx[lo..hi].binary_search(&i).ok().map(|p| lo + p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::csc::CscMatrix;

    fn tridiag(n: usize) -> CscMatrix {
        let mut t = Vec::new();
        for i in 0..n {
            t.push((i, i, 4.0));
            if i + 1 < n {
                t.push((i, i + 1, 1.0));
                t.push((i + 1, i, 1.0));
            }
        }
        CscMatrix::from_triplets(n, n, &t)
    }

    #[test]
    fn tridiagonal_no_fill() {
        let s = Symbolic::analyze(&tridiag(6));
        // strictly lower: one entry per column except the last
        assert_eq!(s.row_idx.len(), 5);
        for j in 0..5 {
            assert_eq!(s.col_pattern(j), &[j + 1]);
        }
        assert!(s.col_pattern(5).is_empty());
    }

    #[test]
    fn fill_in_happens() {
        // "bowtie": row 0 connected to everything -> eliminating 0 first
        // fills in the rest completely.
        let n = 5;
        let mut t = Vec::new();
        for i in 0..n {
            t.push((i, i, 4.0));
            if i > 0 {
                t.push((0, i, 1.0));
                t.push((i, 0, 1.0));
            }
        }
        let a = CscMatrix::from_triplets(n, n, &t);
        let s = Symbolic::analyze(&a);
        // After eliminating node 0 the remainder is a clique: L is full.
        assert_eq!(s.nnz_l(), n * (n + 1) / 2);
        assert!((s.fill_l() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rowmap_consistent_with_colmap() {
        let a = tridiag(7);
        let s = Symbolic::analyze(&a);
        for i in 0..7 {
            for &(j, p) in s.row_pattern(i) {
                assert_eq!(s.row_idx[p], i);
                assert!(s.col_ptr[j] <= p && p < s.col_ptr[j + 1]);
            }
        }
    }

    #[test]
    fn find_locates_entries() {
        let s = Symbolic::analyze(&tridiag(5));
        assert!(s.find(1, 0).is_some());
        assert!(s.find(2, 0).is_none());
    }
}
