//! Triangular solves against an [`LdlFactor`].
//!
//! The EP inner loop solves `B t = a` once per site visit with a *sparse*
//! right-hand side `a = S̃^{1/2} K[:, i]` (paper §5.1). The forward solve
//! only touches the etree reach of `a`'s pattern; the backward solve is
//! column-oriented over all of `L` (the paper notes `t` is generally
//! dense), so a solve costs `O(nnz(L))` rather than `O(n²)`.

use crate::sparse::cholesky::LdlFactor;

/// Union of etree paths from `seeds` (all < usize::MAX), i.e. the nonzero
/// pattern of `L⁻¹ b` when `seeds` is the pattern of `b`. Output sorted
/// ascending. `mark` is caller-provided scratch of length n, all entries
/// != `tag` on entry.
pub fn etree_reach(
    parent: &[usize],
    seeds: &[usize],
    mark: &mut [usize],
    tag: usize,
    out: &mut Vec<usize>,
) {
    out.clear();
    for &s in seeds {
        let mut i = s;
        while i != usize::MAX && mark[i] != tag {
            mark[i] = tag;
            out.push(i);
            i = parent[i];
        }
    }
    out.sort_unstable();
}

impl LdlFactor {
    /// Dense forward solve L y = b in place (L unit lower), blocked by
    /// supernode: each supernode's intra-panel updates are contiguous
    /// axpys (`x[j+1..jend] -= xⱼ · L`), and its below-panel updates
    /// accumulate into a dense scratch over the shared top pattern before
    /// one indexed scatter — `O(t)` pattern lookups per supernode instead
    /// of per column. Allocates the `O(max t)` scratch; the hot sparse-RHS
    /// path reuses a workspace instead.
    pub fn solve_lower_dense(&self, x: &mut [f64]) {
        let mut ext = Vec::new();
        self.solve_lower_blocked(x, &mut ext);
    }

    fn solve_lower_blocked(&self, x: &mut [f64], ext: &mut Vec<f64>) {
        let sym = &self.symbolic;
        debug_assert_eq!(x.len(), sym.n);
        let sched = &sym.schedule;
        for s in 0..sched.n_snodes() {
            let (j0, jend) = (sched.snode_ptr[s], sched.snode_ptr[s + 1]);
            let w = jend - j0;
            let t = sym.col_ptr[jend] - sym.col_ptr[jend - 1];
            if ext.len() < t {
                ext.resize(t, 0.0);
            }
            let acc = &mut ext[..t];
            acc.fill(0.0);
            let mut any = false;
            for c in 0..w {
                let j = j0 + c;
                let xj = x[j];
                if xj == 0.0 {
                    continue;
                }
                any = true;
                let lo = sym.col_ptr[j];
                // intra rows j+1..jend are the column's first w-1-c slots
                let (intra, below) = self.l[lo..sym.col_ptr[j + 1]].split_at(w - 1 - c);
                for (xi, &lv) in x[j + 1..jend].iter_mut().zip(intra) {
                    *xi -= lv * xj;
                }
                // the remaining t slots align with the shared top pattern
                for (av, &lv) in acc.iter_mut().zip(below) {
                    *av += lv * xj;
                }
            }
            if any && t > 0 {
                let top = &sym.row_idx[sym.col_ptr[jend - 1]..sym.col_ptr[jend]];
                for (&i, &av) in top.iter().zip(acc.iter()) {
                    x[i] -= av;
                }
            }
        }
    }

    /// Dense backward solve Lᵀ x = y in place (blocked by supernode; see
    /// [`LdlFactor::solve_lower_dense`]).
    pub fn solve_upper_dense(&self, x: &mut [f64]) {
        let mut ext = Vec::new();
        self.solve_upper_impl(x, None, &mut ext);
    }

    /// The shared Lᵀ substitution, blocked by supernode: the supernode's
    /// top-pattern entries of `x` are gathered once into a dense scratch,
    /// then every column's update is two contiguous dot products (the
    /// intra-panel tail and the gathered top), descending so each column
    /// sees its successors' finished values. Optionally records every
    /// index left nonzero into `written` (the sparse-RHS path's cleanup
    /// set), in the same descending order as the scalar kernel.
    fn solve_upper_impl(
        &self,
        x: &mut [f64],
        mut written: Option<&mut Vec<usize>>,
        ext: &mut Vec<f64>,
    ) {
        let sym = &self.symbolic;
        debug_assert_eq!(x.len(), sym.n);
        let sched = &sym.schedule;
        for s in (0..sched.n_snodes()).rev() {
            let (j0, jend) = (sched.snode_ptr[s], sched.snode_ptr[s + 1]);
            let w = jend - j0;
            let top = &sym.row_idx[sym.col_ptr[jend - 1]..sym.col_ptr[jend]];
            let t = top.len();
            if ext.len() < t {
                ext.resize(t, 0.0);
            }
            let xt = &mut ext[..t];
            for (xv, &i) in xt.iter_mut().zip(top) {
                *xv = x[i];
            }
            for c in (0..w).rev() {
                let j = j0 + c;
                let lo = sym.col_ptr[j];
                let (intra, below) = self.l[lo..sym.col_ptr[j + 1]].split_at(w - 1 - c);
                let mut s_intra = 0.0;
                for (&lv, &xv) in intra.iter().zip(&x[j + 1..jend]) {
                    s_intra += lv * xv;
                }
                let mut s_ext = 0.0;
                for (&lv, &xv) in below.iter().zip(xt.iter()) {
                    s_ext += lv * xv;
                }
                let v = x[j] - s_intra - s_ext;
                x[j] = v;
                if v != 0.0 {
                    if let Some(wr) = written.as_mut() {
                        wr.push(j);
                    }
                }
            }
        }
    }

    /// Divide by D in place.
    pub fn solve_diag_dense(&self, x: &mut [f64]) {
        for (xi, di) in x.iter_mut().zip(&self.d) {
            *xi /= di;
        }
    }

    /// Full solve A x = b with dense b (A = L D Lᵀ).
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        self.solve_in_place(&mut x);
        x
    }

    pub fn solve_in_place(&self, x: &mut [f64]) {
        crate::obs::counters::SOLVES.add(1);
        self.solve_lower_dense(x);
        self.solve_diag_dense(x);
        self.solve_upper_dense(x);
    }

    /// Solve A t = a with *sparse* a, writing the dense result into `t`
    /// (caller-provided, must be all-zero on entry). The indices of every
    /// entry left nonzero are recorded in `ws.written`, so the caller can
    /// restore the all-zero state with [`SparseSolveWorkspace::clear_solution`]
    /// in O(nnz(t)) instead of an O(n) sweep — the per-site cost the EP
    /// inner loop relies on.
    ///
    /// `a_rows`/`a_vals` are the sorted pattern/values of `a`.
    pub fn solve_sparse_rhs(
        &self,
        a_rows: &[usize],
        a_vals: &[f64],
        ws: &mut SparseSolveWorkspace,
        t: &mut [f64],
    ) {
        // per-site-hot: a gated counter add is the entire obs footprint
        // (one relaxed load when tracing is off)
        crate::obs::counters::SOLVES.add(1);
        let sym = self.symbolic.clone();
        ws.tag += 1;
        etree_reach(&sym.parent, a_rows, &mut ws.mark, ws.tag, &mut ws.reach);
        // scatter a
        for (&i, &v) in a_rows.iter().zip(a_vals) {
            t[i] = v;
        }
        // forward solve restricted to the reach (ascending = topological)
        for &j in ws.reach.iter() {
            let xj = t[j];
            if xj != 0.0 {
                // SAFETY: pattern indices are < n by construction.
                unsafe {
                    let lo = *sym.col_ptr.get_unchecked(j);
                    let hi = *sym.col_ptr.get_unchecked(j + 1);
                    for p in lo..hi {
                        *t.get_unchecked_mut(*sym.row_idx.get_unchecked(p)) -=
                            self.l.get_unchecked(p) * xj;
                    }
                }
            }
        }
        // diagonal on the reach
        for &j in ws.reach.iter() {
            t[j] /= self.d[j];
        }
        // backward solve: t is generally dense from here on, but zeros stay
        // zeros, so only the entries that end up nonzero are recorded
        ws.written.clear();
        self.solve_upper_impl(t, Some(&mut ws.written), &mut ws.ext);
    }
}

/// Scratch for repeated sparse-RHS solves (no allocation per call).
pub struct SparseSolveWorkspace {
    pub mark: Vec<usize>,
    pub tag: usize,
    pub reach: Vec<usize>,
    /// Indices of the nonzero entries the last [`LdlFactor::solve_sparse_rhs`]
    /// left in the solution vector.
    pub written: Vec<usize>,
    /// Dense gather buffer of the blocked backward solve (`O(max t)`).
    ext: Vec<f64>,
}

impl SparseSolveWorkspace {
    pub fn new(n: usize) -> Self {
        SparseSolveWorkspace {
            mark: vec![0; n],
            tag: 0,
            reach: Vec::with_capacity(n),
            written: Vec::with_capacity(n),
            ext: Vec::new(),
        }
    }

    /// Re-zero exactly the entries the last solve wrote, restoring the
    /// all-zero precondition of `solve_sparse_rhs` without touching the
    /// other `n − nnz(t)` entries.
    pub fn clear_solution(&mut self, t: &mut [f64]) {
        for &i in &self.written {
            t[i] = 0.0;
        }
        self.written.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::symbolic::Symbolic;
    use crate::testutil::{assert_close, random_sparse_spd, random_vec};
    use std::sync::Arc;

    #[test]
    fn dense_solve_matches_dense_oracle() {
        for seed in 0..6 {
            let a = random_sparse_spd(30, 0.2, seed);
            let sym = Arc::new(Symbolic::analyze(&a));
            let f = LdlFactor::factor(sym, &a).unwrap();
            let b = random_vec(30, seed);
            let x = f.solve(&b);
            let x_ref = a.to_dense().solve_spd(&b).unwrap();
            assert_close(&x, &x_ref, 1e-9, "solve");
        }
    }

    #[test]
    fn sparse_rhs_solve_matches_dense_solve() {
        for seed in 0..6 {
            let n = 40;
            let a = random_sparse_spd(n, 0.1, seed + 100);
            let sym = Arc::new(Symbolic::analyze(&a));
            let f = LdlFactor::factor(sym, &a).unwrap();
            // sparse rhs: a few entries
            let rows = vec![3usize, 17, 29];
            let vals = vec![1.5, -0.5, 2.0];
            let mut b = vec![0.0; n];
            for (&i, &v) in rows.iter().zip(&vals) {
                b[i] = v;
            }
            let x_ref = f.solve(&b);
            let mut ws = SparseSolveWorkspace::new(n);
            let mut t = vec![0.0; n];
            f.solve_sparse_rhs(&rows, &vals, &mut ws, &mut t);
            assert_close(&t, &x_ref, 1e-10, "sparse-rhs solve");
        }
    }

    #[test]
    fn reach_on_path_etree() {
        // tridiagonal -> etree is a path; reach of {2} in a 6-node path is 2..6
        let parent = vec![1, 2, 3, 4, 5, usize::MAX];
        let mut mark = vec![0usize; 6];
        let mut out = Vec::new();
        etree_reach(&parent, &[2], &mut mark, 1, &mut out);
        assert_eq!(out, vec![2, 3, 4, 5]);
        // union of two seeds dedups
        etree_reach(&parent, &[4, 2], &mut mark, 2, &mut out);
        assert_eq!(out, vec![2, 3, 4, 5]);
    }

    #[test]
    fn written_set_tracks_nonzeros_and_clear_restores_zero() {
        let n = 40;
        let a = random_sparse_spd(n, 0.08, 12);
        let sym = Arc::new(Symbolic::analyze(&a));
        let f = LdlFactor::factor(sym, &a).unwrap();
        let mut ws = SparseSolveWorkspace::new(n);
        let mut t = vec![0.0; n];
        for seed in 0..n {
            let rows = vec![seed];
            let vals = vec![1.0 + seed as f64];
            f.solve_sparse_rhs(&rows, &vals, &mut ws, &mut t);
            // written == exactly the nonzero support of t
            let nz: Vec<usize> = (0..n).filter(|&i| t[i] != 0.0).collect();
            let mut written = ws.written.clone();
            written.sort_unstable();
            assert_eq!(written, nz, "seed {seed}");
            ws.clear_solution(&mut t);
            assert!(t.iter().all(|&v| v == 0.0), "seed {seed}: scratch not restored");
        }
    }

    #[test]
    fn repeated_solves_with_shared_workspace() {
        let n = 25;
        let a = random_sparse_spd(n, 0.15, 5);
        let sym = Arc::new(Symbolic::analyze(&a));
        let f = LdlFactor::factor(sym, &a).unwrap();
        let mut ws = SparseSolveWorkspace::new(n);
        for i in 0..n {
            let rows = vec![i];
            let vals = vec![1.0];
            let mut t = vec![0.0; n];
            f.solve_sparse_rhs(&rows, &vals, &mut ws, &mut t);
            let mut e = vec![0.0; n];
            e[i] = 1.0;
            let x_ref = f.solve(&e);
            assert_close(&t, &x_ref, 1e-10, "e_i solve");
        }
    }
}
