//! Takahashi sparsified inverse (Takahashi, Fagan & Chen 1973).
//!
//! Computes `Z^sp` — the entries of `B⁻¹` restricted to the symbolic
//! pattern of `L + Lᵀ` — from an LDLᵀ factor, without forming the (dense)
//! full inverse. This is exactly what the paper's gradient trace term
//! (eq. 11) needs: `tr(Z ∂K/∂θ)` only reads `Z` where `K` (⊆ pattern of
//! `B` ⊆ pattern of `L+Lᵀ`) is nonzero.
//!
//! Recurrence (from `Lᵀ Z = D⁻¹ L⁻¹`, valid entrywise for i ≥ j):
//!   `Z[j,i] = δ_ij / d_j − Σ_{k ∈ pat(L:,j)} L[k,j] · Z[k,i]`
//! processed for j = n−1 .. 0. All referenced `Z[k,i]` pairs (k, i > j,
//! both in column j's pattern) are themselves in the pattern by the
//! Cholesky fill rule, so the recurrence closes over the sparse storage.
//!
//! # Parallel waves
//!
//! Column j only reads `Z` entries of columns in `pat(L:,j)`, and every
//! row index in column j of `L` is an *ancestor* of j in the elimination
//! tree. Columns at the same etree depth therefore never depend on each
//! other, and the recurrence parallelizes as level waves processed from
//! the roots (depth 0) downward: within a wave, each column is an
//! independent task writing its own `z_lower` range and `z_diag` slot.
//! Small waves (the path-like top of a typical CS etree) run inline on
//! the caller; large waves fan out over [`crate::par`]. The arithmetic
//! per column is identical either way, so the result is bitwise-equal to
//! the serial recursion at any thread count.

use crate::par::SyncSlice;
use crate::sparse::cholesky::LdlFactor;
use crate::sparse::etree::depth_waves;

/// Waves shorter than this run inline on the caller's scratch — a
/// one-column wave (the etree's path-like top) gains nothing from the
/// pool and would pay a dispatch per level.
const PAR_WAVE_MIN: usize = 32;

/// Columns per chunk when a wave does fan out (leaf columns are cheap).
const WAVE_CHUNK: usize = 16;

/// Sparsified inverse on the factor's pattern.
#[derive(Clone, Debug, Default)]
pub struct SparseInverse {
    /// Strictly-lower entries aligned with `symbolic.row_idx`.
    pub z_lower: Vec<f64>,
    /// Diagonal of Z.
    pub z_diag: Vec<f64>,
    /// Cached wave schedule: the etree parents it was computed from, the
    /// columns grouped by depth (roots first, flat), and the wave
    /// boundaries (`wave_cols[wave_ptr[d]..wave_ptr[d + 1]]` is wave d).
    /// Rebuilt only when the factor's etree differs from `wave_parent` —
    /// repeated gradient evaluations on one pattern (the
    /// `PatternCache`-hit case) pay an `O(n)` comparison, zero
    /// allocations.
    wave_parent: Vec<usize>,
    wave_cols: Vec<usize>,
    wave_ptr: Vec<usize>,
}

impl LdlFactor {
    /// Compute the Takahashi sparsified inverse into fresh buffers.
    /// Gradient loops that evaluate repeatedly on one pattern should hold
    /// a [`SparseInverse`] and call
    /// [`takahashi_inverse_into`](LdlFactor::takahashi_inverse_into) so
    /// the `O(nnz(L))` buffers are reused instead of reallocated.
    pub fn takahashi_inverse(&self) -> SparseInverse {
        let mut zi = SparseInverse::default();
        self.takahashi_inverse_into(&mut zi);
        zi
    }

    /// Compute the Takahashi sparsified inverse, reusing `zi`'s buffers
    /// (resized as needed — a no-op when the pattern is unchanged, the
    /// `PatternCache`-hit case of the optimizer loop).
    ///
    /// Per column, L(:,j) is scattered into a dense work vector once;
    /// each entry `Z[j,i]` then gathers its sum from column i and row i
    /// of the already-computed part of `Z` with plain array walks — no
    /// per-entry searches. Every referenced `(k,i)` pair is in the
    /// pattern by the Cholesky fill rule (`k,i ∈ pat(j), k≠i ⇒
    /// (max,min) ∈ pattern`). Columns are processed in etree level waves
    /// (see the module docs); each wave may fan out over the worker pool.
    pub fn takahashi_inverse_into(&self, zi: &mut SparseInverse) {
        let sym = &self.symbolic;
        let n = sym.n;
        // resize only (no clear): every slot is overwritten by the column
        // loop below, so the unchanged-pattern case touches no memory here
        zi.z_lower.resize(sym.row_idx.len(), 0.0);
        zi.z_diag.resize(n, 0.0);
        if zi.wave_parent != sym.parent {
            zi.wave_parent.clear();
            zi.wave_parent.extend_from_slice(&sym.parent);
            depth_waves(&sym.parent, &mut zi.wave_cols, &mut zi.wave_ptr);
        }
        let (wave_cols, wave_ptr) = (&zi.wave_cols, &zi.wave_ptr);
        let z_lower = SyncSlice::new(&mut zi.z_lower);
        let z_diag = SyncSlice::new(&mut zi.z_diag);
        // caller-owned scratch for the inline (small-wave) path
        let mut w = vec![0.0; n];
        let mut in_pat = vec![false; n];
        for d in 0..wave_ptr.len().saturating_sub(1) {
            let wave = &wave_cols[wave_ptr[d]..wave_ptr[d + 1]];
            if wave.len() < PAR_WAVE_MIN || crate::par::current_threads() <= 1 {
                for &j in wave {
                    self.takahashi_column(j, &mut w, &mut in_pat, &z_lower, &z_diag);
                }
            } else {
                crate::par::for_chunks(
                    wave.len(),
                    WAVE_CHUNK,
                    || (vec![0.0; n], vec![false; n]),
                    |scratch, range| {
                        let (w, in_pat) = scratch;
                        for &j in &wave[range] {
                            self.takahashi_column(j, w, in_pat, &z_lower, &z_diag);
                        }
                    },
                );
            }
        }
    }

    /// One column of the recurrence. Requires every column in `pat(j)`
    /// (all strict ancestors of j) to be finished; writes only column j's
    /// `z_lower` range and `z_diag[j]`, which is what makes same-depth
    /// columns safe to run concurrently. `w`/`in_pat` are length-n
    /// scratch, all-zero / all-false on entry and restored on exit.
    fn takahashi_column(
        &self,
        j: usize,
        w: &mut [f64],
        in_pat: &mut [bool],
        z_lower: &SyncSlice<'_, f64>,
        z_diag: &SyncSlice<'_, f64>,
    ) {
        let sym = &self.symbolic;
        let lo = sym.col_ptr[j];
        let hi = sym.col_ptr[j + 1];
        // dense scatter of L(:, j): w[k] = L[k, j], in_pat marks membership
        for p in lo..hi {
            w[sym.row_idx[p]] = self.l[p];
            in_pat[sym.row_idx[p]] = true;
        }
        // off-diagonal entries Z[j, i], i ∈ pat(j):
        //   Z[j,i] = − Σ_{k ∈ pat(j)} L[k,j] Z[k,i]
        // split by k > i (column i of Z), k == i (diagonal),
        // k < i (row i of Z via the rowmap).
        for p in lo..hi {
            let i = sym.row_idx[p];
            // SAFETY: all pattern indices < n by construction, and every
            // Z entry read here lives in an ancestor column (an earlier,
            // barrier-separated wave) — never written concurrently.
            unsafe {
                let mut s = w[i] * z_diag.get(i);
                let ilo = *sym.col_ptr.get_unchecked(i);
                let ihi = *sym.col_ptr.get_unchecked(i + 1);
                for q in ilo..ihi {
                    let k = *sym.row_idx.get_unchecked(q);
                    if *in_pat.get_unchecked(k) {
                        s += w.get_unchecked(k) * z_lower.get(q);
                    }
                }
                for &(k, q) in sym.row_pattern(i) {
                    if k > j && *in_pat.get_unchecked(k) {
                        s += w.get_unchecked(k) * z_lower.get(q);
                    }
                }
                z_lower.set(p, -s);
            }
        }
        // diagonal, using the freshly computed column-j entries
        let mut s = 1.0 / self.d[j];
        for q in lo..hi {
            // SAFETY: in-bounds; entries of column j were written above by
            // this same call, and no other task touches column j.
            s -= self.l[q] * unsafe { z_lower.get(q) };
        }
        // SAFETY: slot j belongs exclusively to this column's task.
        unsafe { z_diag.set(j, s) };
        // clear the scatter
        for p in lo..hi {
            w[sym.row_idx[p]] = 0.0;
            in_pat[sym.row_idx[p]] = false;
        }
    }
}

impl SparseInverse {
    /// Read Z[i, j] (either triangle) if it is on the pattern.
    pub fn get(&self, sym: &crate::sparse::symbolic::Symbolic, i: usize, j: usize) -> Option<f64> {
        if i == j {
            return Some(self.z_diag[i]);
        }
        let (hi, lo) = if i > j { (i, j) } else { (j, i) };
        sym.find(hi, lo).map(|p| self.z_lower[p])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::symbolic::Symbolic;
    use crate::testutil::random_sparse_spd;
    use std::sync::Arc;

    #[test]
    fn matches_dense_inverse_on_pattern() {
        for seed in 0..8 {
            let n = 30;
            let a = random_sparse_spd(n, 0.12, seed + 400);
            let sym = Arc::new(Symbolic::analyze(&a));
            let f = LdlFactor::factor(sym.clone(), &a).unwrap();
            let zi = f.takahashi_inverse();
            let dense_inv = a.to_dense().inverse_spd().unwrap();
            for j in 0..n {
                let dd = (zi.z_diag[j] - dense_inv.at(j, j)).abs();
                assert!(dd < 1e-8, "seed {seed} diag {j}: {dd}");
                for p in sym.col_ptr[j]..sym.col_ptr[j + 1] {
                    let i = sym.row_idx[p];
                    let d = (zi.z_lower[p] - dense_inv.at(i, j)).abs();
                    assert!(d < 1e-8, "seed {seed} ({i},{j}): {d}");
                }
            }
        }
    }

    /// The patterns the EP gradient actually feeds this code: compact
    /// Wendland covariances over random geometric points (plus a diagonal
    /// shift, like EP's `B = I + S̃^{1/2}KS̃^{1/2}`), not just random
    /// sparse SPD matrices.
    #[test]
    fn matches_dense_inverse_on_cs_covariance_patterns() {
        use crate::gp::covariance::{CovFunction, CovKind};
        use crate::testutil::random_points;
        for (seed, dim, ls) in [(1u64, 2usize, 1.6), (2, 2, 2.4), (3, 3, 2.8)] {
            let x = random_points(70, dim, 6.0, seed);
            let cov = CovFunction::new(CovKind::Pp(3), dim, 1.0, ls);
            let mut k = cov.cov_matrix(&x);
            for j in 0..k.n_cols {
                *k.get_mut(j, j) += 1.0;
            }
            assert!(k.density() < 0.9, "pattern should be genuinely sparse");
            let sym = Arc::new(Symbolic::analyze(&k));
            let f = LdlFactor::factor(sym.clone(), &k).unwrap();
            let zi = f.takahashi_inverse();
            let dense_inv = k.to_dense().inverse_spd().unwrap();
            for j in 0..x.len() {
                let dd = (zi.z_diag[j] - dense_inv.at(j, j)).abs();
                assert!(dd < 1e-8, "seed {seed} diag {j}: {dd}");
                for p in sym.col_ptr[j]..sym.col_ptr[j + 1] {
                    let i = sym.row_idx[p];
                    let d = (zi.z_lower[p] - dense_inv.at(i, j)).abs();
                    assert!(d < 1e-8, "seed {seed} ({i},{j}): {d}");
                }
            }
        }
    }

    /// Wave-parallel evaluation is bitwise-identical to the single-thread
    /// path, and `takahashi_inverse_into` reuses buffers across calls.
    #[test]
    fn parallel_waves_are_bitwise_identical_and_buffers_reuse() {
        let n = 220;
        let a = random_sparse_spd(n, 0.06, 777);
        let sym = Arc::new(Symbolic::analyze(&a));
        let f = LdlFactor::factor(sym, &a).unwrap();
        let serial = crate::par::with_max_threads(1, || f.takahashi_inverse());
        let mut reused = SparseInverse::default();
        for width in [2usize, 4, 7] {
            crate::par::with_max_threads(width, || f.takahashi_inverse_into(&mut reused));
            assert_eq!(reused.z_lower, serial.z_lower, "width {width}");
            assert_eq!(reused.z_diag, serial.z_diag, "width {width}");
        }
    }

    #[test]
    fn wave_schedule_puts_roots_first() {
        let (mut cols, mut ptr) = (Vec::new(), Vec::new());
        // path etree 0 -> 1 -> 2 -> 3 (root): waves are singletons from
        // the root down
        depth_waves(&[1usize, 2, 3, usize::MAX], &mut cols, &mut ptr);
        assert_eq!(ptr, vec![0, 1, 2, 3, 4]);
        assert_eq!(cols, vec![3, 2, 1, 0]);
        // star: everything hangs off the root -> one wide wave
        depth_waves(&[4usize, 4, 4, 4, usize::MAX], &mut cols, &mut ptr);
        assert_eq!(ptr, vec![0, 1, 5]);
        assert_eq!(cols, vec![4, 0, 1, 2, 3]);
    }

    #[test]
    fn identity_inverse_is_identity() {
        let a = crate::sparse::csc::CscMatrix::identity(6);
        let sym = Arc::new(Symbolic::analyze(&a));
        let f = LdlFactor::factor(sym, &a).unwrap();
        let zi = f.takahashi_inverse();
        assert!(zi.z_diag.iter().all(|&z| (z - 1.0).abs() < 1e-15));
        assert!(zi.z_lower.is_empty());
    }

    #[test]
    fn get_accessor_both_triangles() {
        let a = random_sparse_spd(12, 0.3, 5);
        let sym = Arc::new(Symbolic::analyze(&a));
        let f = LdlFactor::factor(sym.clone(), &a).unwrap();
        let zi = f.takahashi_inverse();
        for j in 0..12 {
            for p in sym.col_ptr[j]..sym.col_ptr[j + 1] {
                let i = sym.row_idx[p];
                assert_eq!(zi.get(&sym, i, j), zi.get(&sym, j, i));
            }
        }
    }
}
