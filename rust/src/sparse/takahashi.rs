//! Takahashi sparsified inverse (Takahashi, Fagan & Chen 1973).
//!
//! Computes `Z^sp` — the entries of `B⁻¹` restricted to the symbolic
//! pattern of `L + Lᵀ` — from an LDLᵀ factor, without forming the (dense)
//! full inverse. This is exactly what the paper's gradient trace term
//! (eq. 11) needs: `tr(Z ∂K/∂θ)` only reads `Z` where `K` (⊆ pattern of
//! `B` ⊆ pattern of `L+Lᵀ`) is nonzero.
//!
//! Recurrence (from `Lᵀ Z = D⁻¹ L⁻¹`, valid entrywise for i ≥ j):
//!   `Z[j,i] = δ_ij / d_j − Σ_{k ∈ pat(L:,j)} L[k,j] · Z[k,i]`
//! processed for j = n−1 .. 0. All referenced `Z[k,i]` pairs (k, i > j,
//! both in column j's pattern) are themselves in the pattern by the
//! Cholesky fill rule, so the recurrence closes over the sparse storage.

use crate::sparse::cholesky::LdlFactor;

/// Sparsified inverse on the factor's pattern.
#[derive(Clone, Debug)]
pub struct SparseInverse {
    /// Strictly-lower entries aligned with `symbolic.row_idx`.
    pub z_lower: Vec<f64>,
    /// Diagonal of Z.
    pub z_diag: Vec<f64>,
}

impl LdlFactor {
    /// Compute the Takahashi sparsified inverse.
    ///
    /// Per column j (descending), L(:,j) is scattered into a dense work
    /// vector once; each entry `Z[j,i]` then gathers its sum from column i
    /// and row i of the already-computed part of `Z` with plain array
    /// walks — no per-entry searches. Every referenced `(k,i)` pair is in
    /// the pattern by the Cholesky fill rule (`k,i ∈ pat(j), k≠i ⇒
    /// (max,min) ∈ pattern`).
    pub fn takahashi_inverse(&self) -> SparseInverse {
        let sym = &self.symbolic;
        let n = sym.n;
        let mut z_lower = vec![0.0; sym.row_idx.len()];
        let mut z_diag = vec![0.0; n];
        // dense scatter of L(:, j): w[k] = L[k, j], in_pat marks membership
        let mut w = vec![0.0; n];
        let mut in_pat = vec![false; n];
        for j in (0..n).rev() {
            let lo = sym.col_ptr[j];
            let hi = sym.col_ptr[j + 1];
            for p in lo..hi {
                w[sym.row_idx[p]] = self.l[p];
                in_pat[sym.row_idx[p]] = true;
            }
            // off-diagonal entries Z[j, i], i ∈ pat(j):
            //   Z[j,i] = − Σ_{k ∈ pat(j)} L[k,j] Z[k,i]
            // split by k > i (column i of Z), k == i (diagonal),
            // k < i (row i of Z via the rowmap).
            for p in lo..hi {
                let i = sym.row_idx[p];
                let mut s = w[i] * z_diag[i];
                // SAFETY: all pattern indices < n by construction.
                unsafe {
                    let ilo = *sym.col_ptr.get_unchecked(i);
                    let ihi = *sym.col_ptr.get_unchecked(i + 1);
                    for q in ilo..ihi {
                        let k = *sym.row_idx.get_unchecked(q);
                        if *in_pat.get_unchecked(k) {
                            s += w.get_unchecked(k) * z_lower.get_unchecked(q);
                        }
                    }
                    for &(k, q) in sym.row_pattern(i) {
                        if k > j && *in_pat.get_unchecked(k) {
                            s += w.get_unchecked(k) * z_lower.get_unchecked(q);
                        }
                    }
                }
                z_lower[p] = -s;
            }
            // diagonal, using the freshly computed column-j entries
            let mut s = 1.0 / self.d[j];
            for q in lo..hi {
                s -= self.l[q] * z_lower[q];
            }
            z_diag[j] = s;
            // clear the scatter
            for p in lo..hi {
                w[sym.row_idx[p]] = 0.0;
                in_pat[sym.row_idx[p]] = false;
            }
        }
        SparseInverse { z_lower, z_diag }
    }
}

impl SparseInverse {
    /// Read Z[i, j] (either triangle) if it is on the pattern.
    pub fn get(&self, sym: &crate::sparse::symbolic::Symbolic, i: usize, j: usize) -> Option<f64> {
        if i == j {
            return Some(self.z_diag[i]);
        }
        let (hi, lo) = if i > j { (i, j) } else { (j, i) };
        sym.find(hi, lo).map(|p| self.z_lower[p])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::symbolic::Symbolic;
    use crate::testutil::random_sparse_spd;
    use std::sync::Arc;

    #[test]
    fn matches_dense_inverse_on_pattern() {
        for seed in 0..8 {
            let n = 30;
            let a = random_sparse_spd(n, 0.12, seed + 400);
            let sym = Arc::new(Symbolic::analyze(&a));
            let f = LdlFactor::factor(sym.clone(), &a).unwrap();
            let zi = f.takahashi_inverse();
            let dense_inv = a.to_dense().inverse_spd().unwrap();
            for j in 0..n {
                let dd = (zi.z_diag[j] - dense_inv.at(j, j)).abs();
                assert!(dd < 1e-8, "seed {seed} diag {j}: {dd}");
                for p in sym.col_ptr[j]..sym.col_ptr[j + 1] {
                    let i = sym.row_idx[p];
                    let d = (zi.z_lower[p] - dense_inv.at(i, j)).abs();
                    assert!(d < 1e-8, "seed {seed} ({i},{j}): {d}");
                }
            }
        }
    }

    /// The patterns the EP gradient actually feeds this code: compact
    /// Wendland covariances over random geometric points (plus a diagonal
    /// shift, like EP's `B = I + S̃^{1/2}KS̃^{1/2}`), not just random
    /// sparse SPD matrices.
    #[test]
    fn matches_dense_inverse_on_cs_covariance_patterns() {
        use crate::gp::covariance::{CovFunction, CovKind};
        use crate::testutil::random_points;
        for (seed, dim, ls) in [(1u64, 2usize, 1.6), (2, 2, 2.4), (3, 3, 2.8)] {
            let x = random_points(70, dim, 6.0, seed);
            let cov = CovFunction::new(CovKind::Pp(3), dim, 1.0, ls);
            let mut k = cov.cov_matrix(&x);
            for j in 0..k.n_cols {
                *k.get_mut(j, j) += 1.0;
            }
            assert!(k.density() < 0.9, "pattern should be genuinely sparse");
            let sym = Arc::new(Symbolic::analyze(&k));
            let f = LdlFactor::factor(sym.clone(), &k).unwrap();
            let zi = f.takahashi_inverse();
            let dense_inv = k.to_dense().inverse_spd().unwrap();
            for j in 0..x.len() {
                let dd = (zi.z_diag[j] - dense_inv.at(j, j)).abs();
                assert!(dd < 1e-8, "seed {seed} diag {j}: {dd}");
                for p in sym.col_ptr[j]..sym.col_ptr[j + 1] {
                    let i = sym.row_idx[p];
                    let d = (zi.z_lower[p] - dense_inv.at(i, j)).abs();
                    assert!(d < 1e-8, "seed {seed} ({i},{j}): {d}");
                }
            }
        }
    }

    #[test]
    fn identity_inverse_is_identity() {
        let a = crate::sparse::csc::CscMatrix::identity(6);
        let sym = Arc::new(Symbolic::analyze(&a));
        let f = LdlFactor::factor(sym, &a).unwrap();
        let zi = f.takahashi_inverse();
        assert!(zi.z_diag.iter().all(|&z| (z - 1.0).abs() < 1e-15));
        assert!(zi.z_lower.is_empty());
    }

    #[test]
    fn get_accessor_both_triangles() {
        let a = random_sparse_spd(12, 0.3, 5);
        let sym = Arc::new(Symbolic::analyze(&a));
        let f = LdlFactor::factor(sym.clone(), &a).unwrap();
        let zi = f.takahashi_inverse();
        for j in 0..12 {
            for p in sym.col_ptr[j]..sym.col_ptr[j + 1] {
                let i = sym.row_idx[p];
                assert_eq!(zi.get(&sym, i, j), zi.get(&sym, j, i));
            }
        }
    }
}
