//! Takahashi sparsified inverse (Takahashi, Fagan & Chen 1973).
//!
//! Computes `Z^sp` — the entries of `B⁻¹` restricted to the symbolic
//! pattern of `L + Lᵀ` — from an LDLᵀ factor, without forming the (dense)
//! full inverse. This is exactly what the paper's gradient trace term
//! (eq. 11) needs: `tr(Z ∂K/∂θ)` only reads `Z` where `K` (⊆ pattern of
//! `B` ⊆ pattern of `L+Lᵀ`) is nonzero.
//!
//! Recurrence (from `Lᵀ Z = D⁻¹ L⁻¹`, valid entrywise for i ≥ j):
//!   `Z[j,i] = δ_ij / d_j − Σ_{k ∈ pat(L:,j)} L[k,j] · Z[k,i]`
//! processed for j = n−1 .. 0. All referenced `Z[k,i]` pairs (k, i > j,
//! both in column j's pattern) are themselves in the pattern by the
//! Cholesky fill rule, so the recurrence closes over the sparse storage.
//! The (relaxed) supernodal pattern is closed under the same rule — each
//! padded column is a suffix of its supernode's trapezoid — so the
//! recurrence computes true `B⁻¹` entries at the padded slots too.
//!
//! # Blocked supernode waves
//!
//! The recurrence is evaluated one *supernode* at a time. For a supernode
//! spanning columns `[j0, jend)` with top row set `T` (the pattern of its
//! last column), every `Z` entry the recurrence touches lies in the dense
//! symmetric panel over `{j0..jend} ∪ T`. The kernel gathers `Z[T, T]`
//! from the already-finished ancestor columns once, then walks the
//! supernode's columns from `jend−1` down to `j0` as dense contiguous
//! matrix–vector products against the trailing block of that panel,
//! writing each finished column straight back to the sparse storage (a
//! supernode column's stored rows are exactly the panel's trailing rows,
//! in order). This replaces the per-column masked pattern walks — and the
//! row-map scans — of the scalar kernel with autovectorizable dense loops.
//!
//! Column j only reads columns in `pat(L:,j)`, which lie in supernode
//! ancestors of j's supernode in the assembly tree (amalgamation keeps
//! every non-final column's etree parent inside its supernode, so etree
//! ancestor paths exit a supernode only through `sparent`). Supernodes of
//! equal assembly-tree height are therefore independent, and the factor's
//! wave schedule — run in *reverse*, roots first — is a valid parallel
//! schedule here: within a wave each supernode's task writes only its own
//! `z_lower` ranges and `z_diag` slots. Small waves run inline on the
//! caller; large waves fan out over [`crate::par`]. The arithmetic per
//! supernode is identical either way, so the result is bitwise-equal to
//! the serial evaluation at any thread count.

use crate::par::SyncSlice;
use crate::sparse::cholesky::LdlFactor;

/// Waves with fewer supernodes than this run inline on the caller's
/// scratch — a one-supernode wave (the assembly tree's path-like top)
/// gains nothing from the pool and would pay a dispatch per level.
const PAR_WAVE_MIN: usize = 16;

/// Supernodes per chunk when a wave does fan out (leaf supernodes are
/// cheap; a few per task amortizes the queue hop).
const WAVE_CHUNK: usize = 4;

/// Sparsified inverse on the factor's pattern.
#[derive(Clone, Debug, Default)]
pub struct SparseInverse {
    /// Strictly-lower entries aligned with `symbolic.row_idx`.
    pub z_lower: Vec<f64>,
    /// Diagonal of Z.
    pub z_diag: Vec<f64>,
}

/// Per-task scratch for the blocked kernel: a panel-row map (`usize::MAX`
/// when unmapped), the dense symmetric panel, and one panel column.
struct TakahashiScratch {
    pos: Vec<usize>,
    panel: Vec<f64>,
    zcol: Vec<f64>,
}

impl TakahashiScratch {
    fn new(n: usize) -> TakahashiScratch {
        TakahashiScratch { pos: vec![usize::MAX; n], panel: Vec::new(), zcol: Vec::new() }
    }
}

impl LdlFactor {
    /// Compute the Takahashi sparsified inverse into fresh buffers.
    /// Gradient loops that evaluate repeatedly on one pattern should hold
    /// a [`SparseInverse`] and call
    /// [`takahashi_inverse_into`](LdlFactor::takahashi_inverse_into) so
    /// the `O(nnz(L))` buffers are reused instead of reallocated.
    pub fn takahashi_inverse(&self) -> SparseInverse {
        let mut zi = SparseInverse::default();
        self.takahashi_inverse_into(&mut zi);
        zi
    }

    /// Compute the Takahashi sparsified inverse, reusing `zi`'s buffers
    /// (resized as needed — a no-op when the pattern is unchanged, the
    /// `PatternCache`-hit case of the optimizer loop).
    ///
    /// Supernodes are processed in the factor's wave schedule run in
    /// reverse (roots first, see the module docs); each wave may fan out
    /// over the worker pool, and the per-supernode kernel is the blocked
    /// dense-panel recurrence either way.
    pub fn takahashi_inverse_into(&self, zi: &mut SparseInverse) {
        let sym = &self.symbolic;
        let n = sym.n;
        let mut tspan = crate::obs::span("takahashi");
        if tspan.is_active() {
            tspan.field_u64("n", n as u64);
            tspan.field_u64("waves", sym.schedule.n_waves() as u64);
        }
        crate::obs::counters::TAKAHASHI_RUNS.add(1);
        // resize only (no clear): every slot is overwritten by the
        // supernode loop below, so the unchanged-pattern case touches no
        // memory here
        zi.z_lower.resize(sym.row_idx.len(), 0.0);
        zi.z_diag.resize(n, 0.0);
        let sched = &sym.schedule;
        let z_lower = SyncSlice::new(&mut zi.z_lower);
        let z_diag = SyncSlice::new(&mut zi.z_diag);
        // caller-owned scratch for the inline (small-wave) path
        let mut ws_inline = TakahashiScratch::new(n);
        let n_waves = sched.wave_ptr.len().saturating_sub(1);
        for d in (0..n_waves).rev() {
            let wave = &sched.wave_snodes[sched.wave_ptr[d]..sched.wave_ptr[d + 1]];
            if wave.len() < PAR_WAVE_MIN || crate::par::current_threads() <= 1 {
                for &s in wave {
                    self.takahashi_supernode(s, &mut ws_inline, &z_lower, &z_diag);
                }
            } else {
                crate::par::for_chunks(
                    wave.len(),
                    WAVE_CHUNK,
                    || TakahashiScratch::new(n),
                    |ws, range| {
                        for &s in &wave[range] {
                            self.takahashi_supernode(s, ws, &z_lower, &z_diag);
                        }
                    },
                );
            }
        }
    }

    /// One supernode of the blocked recurrence. Requires every
    /// assembly-tree ancestor supernode to be finished; writes only this
    /// supernode's `z_lower` column ranges and `z_diag` slots, which is
    /// what makes same-height supernodes safe to run concurrently.
    fn takahashi_supernode(
        &self,
        s: usize,
        ws: &mut TakahashiScratch,
        z_lower: &SyncSlice<'_, f64>,
        z_diag: &SyncSlice<'_, f64>,
    ) {
        let sym = &self.symbolic;
        let cols = sym.schedule.columns(s);
        let (j0, jend) = (cols.start, cols.end);
        let w = jend - j0;
        // top row set T = pattern of the last column (every other column's
        // pattern is its intra suffix followed by exactly T)
        let top = &sym.row_idx[sym.col_ptr[jend - 1]..sym.col_ptr[jend]];
        let t = top.len();
        let m = w + t;
        let TakahashiScratch { pos, panel, zcol } = ws;
        panel.clear();
        panel.resize(m * m, 0.0);
        zcol.resize(m, 0.0);
        // gather Z[T, T] into the trailing t×t block of the panel. Every
        // pair (T[b], T[a]), b > a, is on column T[a]'s stored pattern by
        // the fill rule, so one masked walk of each ancestor column finds
        // them all — once per supernode, not once per column.
        for (b, &i) in top.iter().enumerate() {
            pos[i] = b;
        }
        for (a, &i) in top.iter().enumerate() {
            // SAFETY: column i belongs to an assembly-tree ancestor,
            // finished in an earlier, barrier-separated wave.
            panel[(w + a) * m + (w + a)] = unsafe { z_diag.get(i) };
            for q in sym.col_ptr[i]..sym.col_ptr[i + 1] {
                let b = pos[sym.row_idx[q]];
                if b != usize::MAX {
                    // SAFETY: same ancestor column as above.
                    let v = unsafe { z_lower.get(q) };
                    panel[(w + b) * m + (w + a)] = v;
                    panel[(w + a) * m + (w + b)] = v;
                }
            }
        }
        for &i in top {
            pos[i] = usize::MAX;
        }
        // columns from jend−1 down to j0: at step c the trailing
        // (m−c−1)² block of the panel is complete, and column j's stored
        // L values are exactly panel rows c+1..m, in order
        for c in (0..w).rev() {
            let j = j0 + c;
            let lo = sym.col_ptr[j];
            let lcol = &self.l[lo..sym.col_ptr[j + 1]];
            debug_assert_eq!(lcol.len(), m - c - 1);
            // Z[a,j] = −Σ_b Z[a,b] L[b,j]: one contiguous dot per row
            for a in c + 1..m {
                let row = &panel[a * m + c + 1..a * m + m];
                let mut acc = 0.0;
                for (zv, lv) in row.iter().zip(lcol) {
                    acc += zv * lv;
                }
                zcol[a] = -acc;
            }
            // Z[j,j] = 1/d_j − Σ_a L[a,j] Z[a,j]
            let mut diag = 1.0 / self.d[j];
            for (lv, zv) in lcol.iter().zip(&zcol[c + 1..]) {
                diag -= lv * zv;
            }
            // mirror the finished column into the panel for the next steps
            for a in c + 1..m {
                panel[a * m + c] = zcol[a];
                panel[c * m + a] = zcol[a];
            }
            panel[c * m + c] = diag;
            // SAFETY: column j's range and z_diag[j] belong exclusively to
            // this supernode's task.
            unsafe {
                z_lower.slice_mut(lo, m - c - 1).copy_from_slice(&zcol[c + 1..]);
                z_diag.set(j, diag);
            }
        }
    }
}

impl SparseInverse {
    /// Read Z[i, j] (either triangle) if it is on the pattern.
    pub fn get(&self, sym: &crate::sparse::symbolic::Symbolic, i: usize, j: usize) -> Option<f64> {
        if i == j {
            return Some(self.z_diag[i]);
        }
        let (hi, lo) = if i > j { (i, j) } else { (j, i) };
        sym.find(hi, lo).map(|p| self.z_lower[p])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::symbolic::{AmalgConfig, Symbolic};
    use crate::testutil::random_sparse_spd;
    use std::sync::Arc;

    #[test]
    fn matches_dense_inverse_on_pattern() {
        for seed in 0..8 {
            let n = 30;
            let a = random_sparse_spd(n, 0.12, seed + 400);
            for cfg in [AmalgConfig::default(), AmalgConfig::disabled()] {
                let sym = Arc::new(Symbolic::analyze_with(&a, None, &cfg));
                let f = LdlFactor::factor(sym.clone(), &a).unwrap();
                let zi = f.takahashi_inverse();
                let dense_inv = a.to_dense().inverse_spd().unwrap();
                for j in 0..n {
                    let dd = (zi.z_diag[j] - dense_inv.at(j, j)).abs();
                    assert!(dd < 1e-8, "seed {seed} diag {j}: {dd}");
                    for p in sym.col_ptr[j]..sym.col_ptr[j + 1] {
                        let i = sym.row_idx[p];
                        let d = (zi.z_lower[p] - dense_inv.at(i, j)).abs();
                        assert!(d < 1e-8, "seed {seed} ({i},{j}): {d}");
                    }
                }
            }
        }
    }

    /// The patterns the EP gradient actually feeds this code: compact
    /// Wendland covariances over random geometric points (plus a diagonal
    /// shift, like EP's `B = I + S̃^{1/2}KS̃^{1/2}`), not just random
    /// sparse SPD matrices.
    #[test]
    fn matches_dense_inverse_on_cs_covariance_patterns() {
        use crate::gp::covariance::{CovFunction, CovKind};
        use crate::testutil::random_points;
        for (seed, dim, ls) in [(1u64, 2usize, 1.6), (2, 2, 2.4), (3, 3, 2.8)] {
            let x = random_points(70, dim, 6.0, seed);
            let cov = CovFunction::new(CovKind::Pp(3), dim, 1.0, ls);
            let mut k = cov.cov_matrix(&x);
            for j in 0..k.n_cols {
                *k.get_mut(j, j) += 1.0;
            }
            assert!(k.density() < 0.9, "pattern should be genuinely sparse");
            let sym = Arc::new(Symbolic::analyze(&k));
            let f = LdlFactor::factor(sym.clone(), &k).unwrap();
            let zi = f.takahashi_inverse();
            let dense_inv = k.to_dense().inverse_spd().unwrap();
            for j in 0..x.len() {
                let dd = (zi.z_diag[j] - dense_inv.at(j, j)).abs();
                assert!(dd < 1e-8, "seed {seed} diag {j}: {dd}");
                for p in sym.col_ptr[j]..sym.col_ptr[j + 1] {
                    let i = sym.row_idx[p];
                    let d = (zi.z_lower[p] - dense_inv.at(i, j)).abs();
                    assert!(d < 1e-8, "seed {seed} ({i},{j}): {d}");
                }
            }
        }
    }

    /// Wave-parallel evaluation is bitwise-identical to the single-thread
    /// path — with amalgamation on and off — and `takahashi_inverse_into`
    /// reuses buffers across calls.
    #[test]
    fn parallel_waves_are_bitwise_identical_and_buffers_reuse() {
        let n = 220;
        let a = random_sparse_spd(n, 0.06, 777);
        for cfg in [AmalgConfig::default(), AmalgConfig::disabled()] {
            let sym = Arc::new(Symbolic::analyze_with(&a, None, &cfg));
            let f = LdlFactor::factor(sym, &a).unwrap();
            let serial = crate::par::with_max_threads(1, || f.takahashi_inverse());
            let mut reused = SparseInverse::default();
            for width in [2usize, 4, 7] {
                crate::par::with_max_threads(width, || f.takahashi_inverse_into(&mut reused));
                assert_eq!(reused.z_lower, serial.z_lower, "width {width}");
                assert_eq!(reused.z_diag, serial.z_diag, "width {width}");
            }
        }
    }

    #[test]
    fn wave_schedule_puts_roots_first() {
        use crate::sparse::etree::depth_waves;
        let (mut cols, mut ptr) = (Vec::new(), Vec::new());
        // path etree 0 -> 1 -> 2 -> 3 (root): waves are singletons from
        // the root down
        depth_waves(&[1usize, 2, 3, usize::MAX], &mut cols, &mut ptr);
        assert_eq!(ptr, vec![0, 1, 2, 3, 4]);
        assert_eq!(cols, vec![3, 2, 1, 0]);
        // star: everything hangs off the root -> one wide wave
        depth_waves(&[4usize, 4, 4, 4, usize::MAX], &mut cols, &mut ptr);
        assert_eq!(ptr, vec![0, 1, 5]);
        assert_eq!(cols, vec![4, 0, 1, 2, 3]);
    }

    #[test]
    fn identity_inverse_is_identity() {
        let a = crate::sparse::csc::CscMatrix::identity(6);
        let sym = Arc::new(Symbolic::analyze(&a));
        let f = LdlFactor::factor(sym, &a).unwrap();
        let zi = f.takahashi_inverse();
        assert!(zi.z_diag.iter().all(|&z| (z - 1.0).abs() < 1e-15));
        assert!(zi.z_lower.is_empty());
    }

    #[test]
    fn get_accessor_both_triangles() {
        let a = random_sparse_spd(12, 0.3, 5);
        let sym = Arc::new(Symbolic::analyze(&a));
        let f = LdlFactor::factor(sym.clone(), &a).unwrap();
        let zi = f.takahashi_inverse();
        for j in 0..12 {
            for p in sym.col_ptr[j]..sym.col_ptr[j + 1] {
                let i = sym.row_idx[p];
                assert_eq!(zi.get(&sym, i, j), zi.get(&sym, j, i));
            }
        }
    }
}
