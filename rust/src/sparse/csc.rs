//! Compressed-sparse-column matrices.
//!
//! Symmetric matrices (covariances, `B`) are stored with *both* triangles
//! so that a full column — which the EP inner loop reads at every site
//! visit — is a contiguous slice. Row indices are kept sorted within each
//! column.

/// A CSC matrix with sorted row indices per column.
#[derive(Clone, Debug, PartialEq)]
pub struct CscMatrix {
    pub n_rows: usize,
    pub n_cols: usize,
    /// Column pointers, length `n_cols + 1`.
    pub col_ptr: Vec<usize>,
    /// Row indices, length `nnz`, sorted within each column.
    pub row_idx: Vec<usize>,
    /// Values aligned with `row_idx`.
    pub values: Vec<f64>,
}

impl CscMatrix {
    /// Build from unsorted triplets; duplicate entries are summed.
    pub fn from_triplets(
        n_rows: usize,
        n_cols: usize,
        triplets: &[(usize, usize, f64)],
    ) -> CscMatrix {
        let mut per_col: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n_cols];
        for &(i, j, v) in triplets {
            assert!(i < n_rows && j < n_cols, "triplet ({i},{j}) out of bounds");
            per_col[j].push((i, v));
        }
        let mut col_ptr = Vec::with_capacity(n_cols + 1);
        let mut row_idx = Vec::with_capacity(triplets.len());
        let mut values = Vec::with_capacity(triplets.len());
        col_ptr.push(0);
        for col in per_col.iter_mut() {
            col.sort_by_key(|&(i, _)| i);
            let mut k = 0;
            while k < col.len() {
                let (i, mut v) = col[k];
                let mut m = k + 1;
                while m < col.len() && col[m].0 == i {
                    v += col[m].1;
                    m += 1;
                }
                row_idx.push(i);
                values.push(v);
                k = m;
            }
            col_ptr.push(row_idx.len());
        }
        CscMatrix { n_rows, n_cols, col_ptr, row_idx, values }
    }

    /// Build a dense-stored matrix (row-major closure `f(i, j)`) keeping
    /// entries with `|v| > drop_tol` plus the whole diagonal.
    pub fn from_fn(
        n: usize,
        drop_tol: f64,
        mut f: impl FnMut(usize, usize) -> f64,
    ) -> CscMatrix {
        let mut col_ptr = Vec::with_capacity(n + 1);
        let mut row_idx = Vec::new();
        let mut values = Vec::new();
        col_ptr.push(0);
        for j in 0..n {
            for i in 0..n {
                let v = f(i, j);
                if i == j || v.abs() > drop_tol {
                    row_idx.push(i);
                    values.push(v);
                }
            }
            col_ptr.push(row_idx.len());
        }
        CscMatrix { n_rows: n, n_cols: n, col_ptr, row_idx, values }
    }

    /// n-by-n identity.
    pub fn identity(n: usize) -> CscMatrix {
        CscMatrix {
            n_rows: n,
            n_cols: n,
            col_ptr: (0..=n).collect(),
            row_idx: (0..n).collect(),
            values: vec![1.0; n],
        }
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.row_idx.len()
    }

    /// (row indices, values) of column `j`.
    #[inline]
    pub fn col(&self, j: usize) -> (&[usize], &[f64]) {
        let r = self.col_ptr[j]..self.col_ptr[j + 1];
        (&self.row_idx[r.clone()], &self.values[r])
    }

    /// Value at (i, j); zero if not stored. Binary search within column.
    pub fn get(&self, i: usize, j: usize) -> f64 {
        let (rows, vals) = self.col(j);
        match rows.binary_search(&i) {
            Ok(p) => vals[p],
            Err(_) => 0.0,
        }
    }

    /// Mutable reference to a *stored* entry (i, j); panics otherwise.
    pub fn get_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        let lo = self.col_ptr[j];
        let hi = self.col_ptr[j + 1];
        let p = lo + self.row_idx[lo..hi]
            .binary_search(&i)
            .unwrap_or_else(|_| panic!("entry ({i},{j}) not in pattern"));
        &mut self.values[p]
    }

    /// y = A x (dense x).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n_cols);
        let mut y = vec![0.0; self.n_rows];
        for j in 0..self.n_cols {
            let xj = x[j];
            if xj == 0.0 {
                continue;
            }
            let (rows, vals) = self.col(j);
            for (&i, &v) in rows.iter().zip(vals) {
                y[i] += v * xj;
            }
        }
        y
    }

    /// y += alpha * A[:, j] (sparse axpy of one column into dense y).
    pub fn axpy_col(&self, j: usize, alpha: f64, y: &mut [f64]) {
        let (rows, vals) = self.col(j);
        for (&i, &v) in rows.iter().zip(vals) {
            y[i] += alpha * v;
        }
    }

    /// Transpose (also converts CSC<->CSR views).
    pub fn transpose(&self) -> CscMatrix {
        let mut count = vec![0usize; self.n_rows + 1];
        for &i in &self.row_idx {
            count[i + 1] += 1;
        }
        for i in 0..self.n_rows {
            count[i + 1] += count[i];
        }
        let col_ptr = count.clone();
        let mut next = count;
        let mut row_idx = vec![0usize; self.nnz()];
        let mut values = vec![0.0; self.nnz()];
        for j in 0..self.n_cols {
            let (rows, vals) = self.col(j);
            for (&i, &v) in rows.iter().zip(vals) {
                let p = next[i];
                next[i] += 1;
                row_idx[p] = j;
                values[p] = v;
            }
        }
        CscMatrix { n_rows: self.n_cols, n_cols: self.n_rows, col_ptr, row_idx, values }
    }

    /// Symmetric permutation `P A Pᵀ`: entry (i, j) moves to
    /// (perm[i], perm[j]) where `perm` maps old index -> new index.
    pub fn permute_sym(&self, perm: &[usize]) -> CscMatrix {
        assert_eq!(self.n_rows, self.n_cols);
        let n = self.n_rows;
        assert_eq!(perm.len(), n);
        let mut triplets = Vec::with_capacity(self.nnz());
        for j in 0..n {
            let (rows, vals) = self.col(j);
            for (&i, &v) in rows.iter().zip(vals) {
                triplets.push((perm[i], perm[j], v));
            }
        }
        CscMatrix::from_triplets(n, n, &triplets)
    }

    /// Fraction of stored entries: nnz / (n_rows * n_cols). Paper's fill-K.
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.n_rows as f64 * self.n_cols as f64)
    }

    /// Check structural invariants (sorted, in-bounds, monotone pointers).
    pub fn check(&self) -> bool {
        if self.col_ptr.len() != self.n_cols + 1 || self.col_ptr[0] != 0 {
            return false;
        }
        if *self.col_ptr.last().unwrap() != self.row_idx.len() {
            return false;
        }
        if self.row_idx.len() != self.values.len() {
            return false;
        }
        for j in 0..self.n_cols {
            if self.col_ptr[j] > self.col_ptr[j + 1] {
                return false;
            }
            let (rows, _) = self.col(j);
            for w in rows.windows(2) {
                if w[0] >= w[1] {
                    return false;
                }
            }
            if rows.iter().any(|&i| i >= self.n_rows) {
                return false;
            }
        }
        true
    }

    /// Dense copy (row-major), for tests and small problems.
    pub fn to_dense(&self) -> crate::sparse::dense::DenseMatrix {
        let mut d = crate::sparse::dense::DenseMatrix::zeros(self.n_rows, self.n_cols);
        for j in 0..self.n_cols {
            let (rows, vals) = self.col(j);
            for (&i, &v) in rows.iter().zip(vals) {
                *d.at_mut(i, j) = v;
            }
        }
        d
    }

    /// Is the matrix exactly symmetric (pattern and values)?
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.n_rows != self.n_cols {
            return false;
        }
        let t = self.transpose();
        if t.col_ptr != self.col_ptr || t.row_idx != self.row_idx {
            return false;
        }
        self.values.iter().zip(&t.values).all(|(a, b)| (a - b).abs() <= tol)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CscMatrix {
        // [ 4 1 0 ]
        // [ 1 5 2 ]
        // [ 0 2 6 ]
        CscMatrix::from_triplets(
            3,
            3,
            &[(0, 0, 4.0), (1, 0, 1.0), (0, 1, 1.0), (1, 1, 5.0), (2, 1, 2.0), (1, 2, 2.0), (2, 2, 6.0)],
        )
    }

    #[test]
    fn triplets_build_sorted_and_dedup() {
        let a = CscMatrix::from_triplets(2, 2, &[(1, 0, 1.0), (0, 0, 2.0), (1, 0, 3.0)]);
        assert!(a.check());
        assert_eq!(a.nnz(), 2);
        assert_eq!(a.get(1, 0), 4.0);
        assert_eq!(a.get(0, 0), 2.0);
        assert_eq!(a.get(0, 1), 0.0);
    }

    #[test]
    fn matvec_matches_dense() {
        let a = sample();
        let y = a.matvec(&[1.0, 2.0, 3.0]);
        assert_eq!(y, vec![4.0 + 2.0, 1.0 + 10.0 + 6.0, 4.0 + 18.0]);
    }

    #[test]
    fn transpose_involution() {
        let a = sample();
        let att = a.transpose().transpose();
        assert_eq!(a, att);
    }

    #[test]
    fn symmetric_detection() {
        assert!(sample().is_symmetric(0.0));
        let ns = CscMatrix::from_triplets(2, 2, &[(0, 1, 1.0), (0, 0, 1.0), (1, 1, 1.0)]);
        assert!(!ns.is_symmetric(0.0));
    }

    #[test]
    fn permute_sym_roundtrip() {
        let a = sample();
        let perm = vec![2usize, 0, 1]; // old->new
        let p = a.permute_sym(&perm);
        assert!(p.check());
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(p.get(perm[i], perm[j]), a.get(i, j));
            }
        }
        // inverse permutation restores
        let mut inv = vec![0usize; 3];
        for (old, &new) in perm.iter().enumerate() {
            inv[new] = old;
        }
        assert_eq!(p.permute_sym(&inv), a);
    }

    #[test]
    fn identity_and_density() {
        let i = CscMatrix::identity(4);
        assert!(i.check());
        assert_eq!(i.matvec(&[1.0, 2.0, 3.0, 4.0]), vec![1.0, 2.0, 3.0, 4.0]);
        assert!((i.density() - 0.25).abs() < 1e-15);
    }

    #[test]
    fn from_fn_drops_small_keeps_diagonal() {
        let a = CscMatrix::from_fn(3, 0.5, |i, j| if i == j { 0.0 } else { 1.0 / (1.0 + (i as f64 - j as f64).abs()) });
        // off-diagonals 0.5 dropped (not > tol), diagonal kept even at 0.0
        assert_eq!(a.nnz(), 3);
        assert_eq!(a.get(0, 0), 0.0);
    }

    #[test]
    fn axpy_col() {
        let a = sample();
        let mut y = vec![0.0; 3];
        a.axpy_col(1, 2.0, &mut y);
        assert_eq!(y, vec![2.0, 10.0, 4.0]);
    }
}
