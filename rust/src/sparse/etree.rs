//! Elimination tree of a symmetric sparse matrix (Davis 2006, §4.1).
//!
//! The etree drives everything downstream: symbolic row patterns
//! (`row_pattern`), the reach computation of sparse triangular solves, the
//! column sequence visited by rank-one updates, and — through the level
//! waves computed here — the parallel schedules of both the Takahashi
//! inverse ([`depth_waves`], roots first) and the supernodal numeric
//! factorization ([`height_waves`], leaves first). Everything in this
//! module is `O(n + nnz)` and allocation-light: the wave builders are
//! counting sorts into caller-provided buffers.

use crate::sparse::csc::CscMatrix;

/// Compute the elimination tree of symmetric `A` (full storage; only the
/// upper triangle is read). `parent[i] == usize::MAX` marks a root.
pub fn etree(a: &CscMatrix) -> Vec<usize> {
    assert_eq!(a.n_rows, a.n_cols);
    let n = a.n_rows;
    let mut parent = vec![usize::MAX; n];
    let mut ancestor = vec![usize::MAX; n];
    for k in 0..n {
        let (rows, _) = a.col(k);
        for &i in rows {
            if i >= k {
                break;
            }
            // Traverse from i to the root of its current subtree, with
            // path compression through `ancestor`.
            let mut i = i;
            while i != usize::MAX && i < k {
                let next = ancestor[i];
                ancestor[i] = k;
                if next == usize::MAX {
                    parent[i] = k;
                }
                i = next;
            }
        }
    }
    parent
}

/// Postorder of the forest given by `parent`.
pub fn postorder(parent: &[usize]) -> Vec<usize> {
    let n = parent.len();
    // children lists
    let mut head = vec![usize::MAX; n];
    let mut next = vec![usize::MAX; n];
    // iterate in reverse so children lists end up in ascending order
    for i in (0..n).rev() {
        let p = parent[i];
        if p != usize::MAX {
            next[i] = head[p];
            head[p] = i;
        }
    }
    let mut post = Vec::with_capacity(n);
    let mut stack = Vec::new();
    for root in 0..n {
        if parent[root] != usize::MAX {
            continue;
        }
        // iterative DFS
        stack.push(root);
        while let Some(&node) = stack.last() {
            let child = head[node];
            if child == usize::MAX {
                post.push(node);
                stack.pop();
            } else {
                head[node] = next[child];
                stack.push(child);
            }
        }
    }
    post
}

/// Group the nodes of the forest `parent` into *depth* level sets
/// ("waves"): wave 0 holds the roots, wave d the nodes at etree depth d.
/// `cols[ptr[d]..ptr[d + 1]]` is wave d. Nodes in one wave never lie on a
/// common root-ward path, which is the independence the Takahashi inverse
/// exploits (it recurs from the roots *down*). Counting sort; `parent[j] >
/// j` for non-roots, so one descending sweep computes all depths.
pub fn depth_waves(parent: &[usize], cols: &mut Vec<usize>, ptr: &mut Vec<usize>) {
    let n = parent.len();
    let mut depth = vec![0usize; n];
    let mut max_depth = 0;
    for j in (0..n).rev() {
        let p = parent[j];
        if p != usize::MAX {
            depth[j] = depth[p] + 1;
            max_depth = max_depth.max(depth[j]);
        }
    }
    fill_waves(&depth, max_depth, cols, ptr);
}

/// Group the nodes of the forest `parent` into *height* level sets: wave 0
/// holds the leaves, wave h the nodes whose tallest subtree has height h.
/// `cols[ptr[h]..ptr[h + 1]]` is wave h. Every strict descendant of a node
/// sits in an earlier wave, which is the independence the numeric
/// factorization exploits (column j of L only depends on columns in j's
/// etree subtree). Ascending sweep: all children of `p` are `< p`, so each
/// node's height is final before it is read.
pub fn height_waves(parent: &[usize], cols: &mut Vec<usize>, ptr: &mut Vec<usize>) {
    let n = parent.len();
    let mut height = vec![0usize; n];
    let mut max_height = 0;
    for j in 0..n {
        let p = parent[j];
        if p != usize::MAX {
            height[p] = height[p].max(height[j] + 1);
            max_height = max_height.max(height[p]);
        }
    }
    fill_waves(&height, max_height, cols, ptr);
}

/// Counting sort of `0..n` by `level`, into `cols` with wave boundaries in
/// `ptr`. Nodes within a wave stay in ascending index order, so wave
/// iteration order — and with it every parallel chunking decision — is a
/// pure function of the levels.
fn fill_waves(level: &[usize], max_level: usize, cols: &mut Vec<usize>, ptr: &mut Vec<usize>) {
    let n = level.len();
    ptr.clear();
    ptr.resize(max_level + 2, 0);
    for &d in level {
        ptr[d + 1] += 1;
    }
    for d in 0..=max_level {
        ptr[d + 1] += ptr[d];
    }
    cols.clear();
    cols.resize(n, 0);
    let mut next = ptr[..=max_level].to_vec();
    for (j, &d) in level.iter().enumerate() {
        cols[next[d]] = j;
        next[d] += 1;
    }
}

/// Row pattern of row `k` of the Cholesky factor: the indices `i < k`
/// reachable by walking each nonzero of `A(0..k, k)` up the etree until a
/// node already marked for `k`. Returns indices in `out` (unsorted) and
/// uses `mark`/`mark_tag` as a workspace (caller supplies arrays of len n).
///
/// This is the core of the up-looking factorization (Davis, `ereach`).
pub fn ereach(
    a: &CscMatrix,
    k: usize,
    parent: &[usize],
    mark: &mut [usize],
    out: &mut Vec<usize>,
) {
    out.clear();
    mark[k] = k; // mark the diagonal so walks stop before k
    let (rows, _) = a.col(k);
    for &i in rows {
        if i >= k {
            break;
        }
        let mut i = i;
        let mut path_start = out.len();
        while mark[i] != k {
            out.push(i);
            mark[i] = k;
            i = parent[i];
            debug_assert!(i != usize::MAX, "etree walk fell off the root before k");
        }
        // The path was appended leaf->ancestor; reverse it in place so the
        // full `out` ends up topologically sorted ancestors-last per path.
        out[path_start..].reverse();
        path_start = 0;
        let _ = path_start;
    }
    out.sort_unstable();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::csc::CscMatrix;

    /// Arrow matrix: dense last row/col + diagonal.
    fn arrow(n: usize) -> CscMatrix {
        let mut t = Vec::new();
        for i in 0..n {
            t.push((i, i, 4.0));
            if i + 1 < n {
                t.push((i, n - 1, 1.0));
                t.push((n - 1, i, 1.0));
            }
        }
        CscMatrix::from_triplets(n, n, &t)
    }

    /// Tridiagonal matrix.
    fn tridiag(n: usize) -> CscMatrix {
        let mut t = Vec::new();
        for i in 0..n {
            t.push((i, i, 4.0));
            if i + 1 < n {
                t.push((i, i + 1, 1.0));
                t.push((i + 1, i, 1.0));
            }
        }
        CscMatrix::from_triplets(n, n, &t)
    }

    #[test]
    fn etree_tridiagonal_is_a_path() {
        let a = tridiag(6);
        let p = etree(&a);
        assert_eq!(p, vec![1, 2, 3, 4, 5, usize::MAX]);
    }

    #[test]
    fn etree_arrow_all_point_to_last() {
        let a = arrow(5);
        let p = etree(&a);
        assert_eq!(p, vec![4, 4, 4, 4, usize::MAX]);
    }

    #[test]
    fn etree_diagonal_is_forest_of_roots() {
        let a = CscMatrix::identity(4);
        assert_eq!(etree(&a), vec![usize::MAX; 4]);
    }

    #[test]
    fn postorder_is_valid() {
        let a = arrow(7);
        let parent = etree(&a);
        let post = postorder(&parent);
        assert_eq!(post.len(), 7);
        // each node appears once, and children precede parents
        let mut pos = vec![0usize; 7];
        for (idx, &node) in post.iter().enumerate() {
            pos[node] = idx;
        }
        for i in 0..7 {
            if parent[i] != usize::MAX {
                assert!(pos[i] < pos[parent[i]], "child {i} after parent");
            }
        }
    }

    #[test]
    fn depth_waves_roots_first_height_waves_leaves_first() {
        // path etree 0 -> 1 -> 2 -> 3 (root)
        let parent = vec![1usize, 2, 3, usize::MAX];
        let (mut cols, mut ptr) = (Vec::new(), Vec::new());
        depth_waves(&parent, &mut cols, &mut ptr);
        assert_eq!((cols.clone(), ptr.clone()), (vec![3, 2, 1, 0], vec![0, 1, 2, 3, 4]));
        height_waves(&parent, &mut cols, &mut ptr);
        assert_eq!((cols, ptr), (vec![0, 1, 2, 3], vec![0, 1, 2, 3, 4]));
    }

    #[test]
    fn height_waves_star_has_parallel_leaf_wave() {
        // star: 0..3 hang off root 4 -> one wide leaf wave, then the root
        let parent = vec![4usize, 4, 4, 4, usize::MAX];
        let (mut cols, mut ptr) = (Vec::new(), Vec::new());
        height_waves(&parent, &mut cols, &mut ptr);
        assert_eq!(ptr, vec![0, 4, 5]);
        assert_eq!(cols, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn height_waves_put_every_descendant_in_an_earlier_wave() {
        // unbalanced forest: 0->2, 1->2, 2->5, 3->5, 4 root, 5 root
        let parent = vec![2usize, 2, 5, 5, usize::MAX, usize::MAX];
        let (mut cols, mut ptr) = (Vec::new(), Vec::new());
        height_waves(&parent, &mut cols, &mut ptr);
        let mut wave_of = vec![0usize; 6];
        for w in 0..ptr.len() - 1 {
            for &j in &cols[ptr[w]..ptr[w + 1]] {
                wave_of[j] = w;
            }
        }
        for j in 0..6 {
            if parent[j] != usize::MAX {
                assert!(wave_of[j] < wave_of[parent[j]], "node {j} not before its parent");
            }
        }
        assert_eq!(wave_of[4], 0, "childless root is a leaf wave node");
    }

    #[test]
    fn ereach_tridiagonal() {
        let a = tridiag(5);
        let parent = etree(&a);
        let mut mark = vec![usize::MAX; 5];
        let mut out = Vec::new();
        ereach(&a, 3, &parent, &mut mark, &mut out);
        // row 3 of L touches only column 2 for a tridiagonal matrix
        assert_eq!(out, vec![2]);
    }

    #[test]
    fn ereach_arrow_last_row_full() {
        let a = arrow(5);
        let parent = etree(&a);
        let mut mark = vec![usize::MAX; 5];
        let mut out = Vec::new();
        ereach(&a, 4, &parent, &mut mark, &mut out);
        assert_eq!(out, vec![0, 1, 2, 3]);
    }
}
