//! Elimination tree of a symmetric sparse matrix (Davis 2006, §4.1).
//!
//! The etree drives everything downstream: symbolic row patterns
//! (`row_pattern`), the reach computation of sparse triangular solves, and
//! the column sequence visited by rank-one updates.

use crate::sparse::csc::CscMatrix;

/// Compute the elimination tree of symmetric `A` (full storage; only the
/// upper triangle is read). `parent[i] == usize::MAX` marks a root.
pub fn etree(a: &CscMatrix) -> Vec<usize> {
    assert_eq!(a.n_rows, a.n_cols);
    let n = a.n_rows;
    let mut parent = vec![usize::MAX; n];
    let mut ancestor = vec![usize::MAX; n];
    for k in 0..n {
        let (rows, _) = a.col(k);
        for &i in rows {
            if i >= k {
                break;
            }
            // Traverse from i to the root of its current subtree, with
            // path compression through `ancestor`.
            let mut i = i;
            while i != usize::MAX && i < k {
                let next = ancestor[i];
                ancestor[i] = k;
                if next == usize::MAX {
                    parent[i] = k;
                }
                i = next;
            }
        }
    }
    parent
}

/// Postorder of the forest given by `parent`.
pub fn postorder(parent: &[usize]) -> Vec<usize> {
    let n = parent.len();
    // children lists
    let mut head = vec![usize::MAX; n];
    let mut next = vec![usize::MAX; n];
    // iterate in reverse so children lists end up in ascending order
    for i in (0..n).rev() {
        let p = parent[i];
        if p != usize::MAX {
            next[i] = head[p];
            head[p] = i;
        }
    }
    let mut post = Vec::with_capacity(n);
    let mut stack = Vec::new();
    for root in 0..n {
        if parent[root] != usize::MAX {
            continue;
        }
        // iterative DFS
        stack.push(root);
        while let Some(&node) = stack.last() {
            let child = head[node];
            if child == usize::MAX {
                post.push(node);
                stack.pop();
            } else {
                head[node] = next[child];
                stack.push(child);
            }
        }
    }
    post
}

/// Row pattern of row `k` of the Cholesky factor: the indices `i < k`
/// reachable by walking each nonzero of `A(0..k, k)` up the etree until a
/// node already marked for `k`. Returns indices in `out` (unsorted) and
/// uses `mark`/`mark_tag` as a workspace (caller supplies arrays of len n).
///
/// This is the core of the up-looking factorization (Davis, `ereach`).
pub fn ereach(
    a: &CscMatrix,
    k: usize,
    parent: &[usize],
    mark: &mut [usize],
    out: &mut Vec<usize>,
) {
    out.clear();
    mark[k] = k; // mark the diagonal so walks stop before k
    let (rows, _) = a.col(k);
    for &i in rows {
        if i >= k {
            break;
        }
        let mut i = i;
        let mut path_start = out.len();
        while mark[i] != k {
            out.push(i);
            mark[i] = k;
            i = parent[i];
            debug_assert!(i != usize::MAX, "etree walk fell off the root before k");
        }
        // The path was appended leaf->ancestor; reverse it in place so the
        // full `out` ends up topologically sorted ancestors-last per path.
        out[path_start..].reverse();
        path_start = 0;
        let _ = path_start;
    }
    out.sort_unstable();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::csc::CscMatrix;

    /// Arrow matrix: dense last row/col + diagonal.
    fn arrow(n: usize) -> CscMatrix {
        let mut t = Vec::new();
        for i in 0..n {
            t.push((i, i, 4.0));
            if i + 1 < n {
                t.push((i, n - 1, 1.0));
                t.push((n - 1, i, 1.0));
            }
        }
        CscMatrix::from_triplets(n, n, &t)
    }

    /// Tridiagonal matrix.
    fn tridiag(n: usize) -> CscMatrix {
        let mut t = Vec::new();
        for i in 0..n {
            t.push((i, i, 4.0));
            if i + 1 < n {
                t.push((i, i + 1, 1.0));
                t.push((i + 1, i, 1.0));
            }
        }
        CscMatrix::from_triplets(n, n, &t)
    }

    #[test]
    fn etree_tridiagonal_is_a_path() {
        let a = tridiag(6);
        let p = etree(&a);
        assert_eq!(p, vec![1, 2, 3, 4, 5, usize::MAX]);
    }

    #[test]
    fn etree_arrow_all_point_to_last() {
        let a = arrow(5);
        let p = etree(&a);
        assert_eq!(p, vec![4, 4, 4, 4, usize::MAX]);
    }

    #[test]
    fn etree_diagonal_is_forest_of_roots() {
        let a = CscMatrix::identity(4);
        assert_eq!(etree(&a), vec![usize::MAX; 4]);
    }

    #[test]
    fn postorder_is_valid() {
        let a = arrow(7);
        let parent = etree(&a);
        let post = postorder(&parent);
        assert_eq!(post.len(), 7);
        // each node appears once, and children precede parents
        let mut pos = vec![0usize; 7];
        for (idx, &node) in post.iter().enumerate() {
            pos[node] = idx;
        }
        for i in 0..7 {
            if parent[i] != usize::MAX {
                assert!(pos[i] < pos[parent[i]], "child {i} after parent");
            }
        }
    }

    #[test]
    fn ereach_tridiagonal() {
        let a = tridiag(5);
        let parent = etree(&a);
        let mut mark = vec![usize::MAX; 5];
        let mut out = Vec::new();
        ereach(&a, 3, &parent, &mut mark, &mut out);
        // row 3 of L touches only column 2 for a tridiagonal matrix
        assert_eq!(out, vec![2]);
    }

    #[test]
    fn ereach_arrow_last_row_full() {
        let a = arrow(5);
        let parent = etree(&a);
        let mut mark = vec![usize::MAX; 5];
        let mut out = Vec::new();
        ereach(&a, 4, &parent, &mut mark, &mut out);
        assert_eq!(out, vec![0, 1, 2, 3]);
    }
}
