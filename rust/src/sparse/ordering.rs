//! Fill-reducing orderings.
//!
//! The paper uses AMD (Amestoy–Davis–Duff); a faithful AMD is out of scope
//! here (see DESIGN.md §Substitutions), so we provide reverse Cuthill–McKee
//! — which performs well on the paper's geometric (low-dimensional spatial)
//! matrices — and a greedy minimum-degree as the AMD stand-in, plus the
//! natural ordering as a control. The `abl_ordering` bench compares them,
//! which the paper lists as future work.

use crate::sparse::csc::CscMatrix;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Ordering {
    /// Identity permutation.
    Natural,
    /// Reverse Cuthill–McKee (bandwidth-reducing BFS).
    Rcm,
    /// Greedy minimum degree (AMD substitute).
    MinDegree,
}

impl std::str::FromStr for Ordering {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "natural" => Ok(Ordering::Natural),
            "rcm" => Ok(Ordering::Rcm),
            "mindeg" | "min-degree" => Ok(Ordering::MinDegree),
            other => Err(format!("unknown ordering '{other}'")),
        }
    }
}

/// Compute a permutation (old index -> new index) for symmetric `a`.
pub fn compute_ordering(a: &CscMatrix, method: Ordering) -> Vec<usize> {
    match method {
        Ordering::Natural => (0..a.n_rows).collect(),
        Ordering::Rcm => rcm(a),
        Ordering::MinDegree => min_degree(a),
    }
}

/// Adjacency lists (excluding the diagonal) from a symmetric pattern.
fn adjacency(a: &CscMatrix) -> Vec<Vec<usize>> {
    let n = a.n_rows;
    let mut adj = vec![Vec::new(); n];
    for j in 0..n {
        let (rows, _) = a.col(j);
        for &i in rows {
            if i != j {
                adj[j].push(i);
            }
        }
    }
    adj
}

/// BFS from `start`; returns (visit order, eccentricity last-level node).
fn bfs(adj: &[Vec<usize>], start: usize, visited: &mut [bool], by_degree: bool) -> Vec<usize> {
    let mut order = vec![start];
    visited[start] = true;
    let mut head = 0;
    while head < order.len() {
        let u = order[head];
        head += 1;
        let mut nbrs: Vec<usize> = adj[u].iter().copied().filter(|&v| !visited[v]).collect();
        if by_degree {
            nbrs.sort_by_key(|&v| adj[v].len());
        }
        for v in nbrs {
            if !visited[v] {
                visited[v] = true;
                order.push(v);
            }
        }
    }
    order
}

/// Reverse Cuthill–McKee. Handles disconnected graphs; each component is
/// started from a pseudo-peripheral node (double-BFS heuristic).
pub fn rcm(a: &CscMatrix) -> Vec<usize> {
    let n = a.n_rows;
    let adj = adjacency(a);
    let mut visited = vec![false; n];
    let mut order = Vec::with_capacity(n);
    for seed in 0..n {
        if visited[seed] {
            continue;
        }
        // pseudo-peripheral: BFS from seed, restart from the last node found
        let mut scratch = visited.clone();
        let pass1 = bfs(&adj, seed, &mut scratch, false);
        let start = *pass1.last().unwrap();
        let comp = bfs(&adj, start, &mut visited, true);
        order.extend(comp);
    }
    // order[k] = old index of the k'th visited node; reverse for RCM
    order.reverse();
    let mut perm = vec![0usize; n];
    for (new, &old) in order.iter().enumerate() {
        perm[old] = new;
    }
    perm
}

/// Greedy minimum-degree with clique formation on elimination.
/// Quadratic-ish worst case; intended for the ordering ablation and for
/// moderate n (the default pipeline ordering is RCM).
pub fn min_degree(a: &CscMatrix) -> Vec<usize> {
    let n = a.n_rows;
    let mut adj: Vec<std::collections::BTreeSet<usize>> =
        adjacency(a).into_iter().map(|v| v.into_iter().collect()).collect();
    let mut eliminated = vec![false; n];
    let mut perm = vec![0usize; n];
    for step in 0..n {
        // pick min-degree uneliminated node (ties: smallest index)
        let v = (0..n)
            .filter(|&v| !eliminated[v])
            .min_by_key(|&v| (adj[v].len(), v))
            .unwrap();
        perm[v] = step;
        eliminated[v] = true;
        let nbrs: Vec<usize> = adj[v].iter().copied().filter(|&u| !eliminated[u]).collect();
        // form the clique of v's neighbours
        for (ai, &u) in nbrs.iter().enumerate() {
            adj[u].remove(&v);
            for &w in &nbrs[ai + 1..] {
                adj[u].insert(w);
                adj[w].insert(u);
            }
        }
        adj[v].clear();
    }
    perm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::symbolic::Symbolic;
    use crate::testutil::random_sparse_spd;

    fn is_permutation(p: &[usize]) -> bool {
        let mut seen = vec![false; p.len()];
        for &i in p {
            if i >= p.len() || seen[i] {
                return false;
            }
            seen[i] = true;
        }
        true
    }

    fn fill_with(a: &CscMatrix, ord: Ordering) -> usize {
        let perm = compute_ordering(a, ord);
        let ap = a.permute_sym(&perm);
        Symbolic::analyze(&ap).nnz_l()
    }

    #[test]
    fn orderings_are_permutations() {
        for seed in 0..4 {
            let a = random_sparse_spd(40, 0.1, seed + 500);
            for ord in [Ordering::Natural, Ordering::Rcm, Ordering::MinDegree] {
                let p = compute_ordering(&a, ord);
                assert!(is_permutation(&p), "{ord:?} seed {seed}");
            }
        }
    }

    #[test]
    fn arrow_matrix_reordering_kills_fill() {
        // arrow pointing the wrong way: natural ordering gives full fill,
        // both RCM and min-degree should order the hub last.
        let n = 30;
        let mut t = Vec::new();
        for i in 0..n {
            t.push((i, i, 4.0));
            if i > 0 {
                t.push((0, i, 1.0));
                t.push((i, 0, 1.0));
            }
        }
        let a = CscMatrix::from_triplets(n, n, &t);
        let natural = fill_with(&a, Ordering::Natural);
        let rcm_fill = fill_with(&a, Ordering::Rcm);
        let md_fill = fill_with(&a, Ordering::MinDegree);
        assert_eq!(natural, n * (n + 1) / 2); // dense
        assert!(rcm_fill < natural / 2, "rcm {rcm_fill} vs natural {natural}");
        assert_eq!(md_fill, 2 * n - 1 + n - n, "min-degree should give no fill"); // 2n-1
    }

    #[test]
    fn rcm_handles_disconnected() {
        // two disjoint triangles
        let mut t = Vec::new();
        for base in [0usize, 3] {
            for i in 0..3 {
                t.push((base + i, base + i, 2.0));
                for j in 0..i {
                    t.push((base + i, base + j, 1.0));
                    t.push((base + j, base + i, 1.0));
                }
            }
        }
        let a = CscMatrix::from_triplets(6, 6, &t);
        let p = rcm(&a);
        assert!(is_permutation(&p));
    }

    #[test]
    fn reordering_reduces_fill_on_geometric_like_matrices() {
        let a = random_sparse_spd(60, 0.07, 77);
        let natural = fill_with(&a, Ordering::Natural);
        let best = fill_with(&a, Ordering::MinDegree);
        assert!(best <= natural, "min-degree {best} vs natural {natural}");
    }
}
