//! Sparse-plus-low-rank solver: `B = S + U Uᵀ` with `S` sparse SPD and
//! `U` an n×m dense factor (m ≪ n).
//!
//! `S` is factored with the existing static-pattern LDLᵀ machinery
//! ([`Symbolic`] / [`LdlFactor`]); the low-rank part is handled by the
//! Woodbury identity through an m×m *capacitance* factor:
//!
//! ```text
//! B⁻¹ = S⁻¹ − S⁻¹ U C⁻¹ Uᵀ S⁻¹,   C = I_m + Uᵀ S⁻¹ U
//! log|B| = log|S| + log|C|
//! ```
//!
//! This is the algebra the CS+FIC hybrid prior needs (`gp::csfic`):
//! `B = I + S̃^{1/2} P S̃^{1/2}` with `P = K_cs + Λ + U Uᵀ` splits into a
//! sparse part on the CS pattern plus a rank-m part, so every EP solve
//! costs `O(nnz(L) + n·m)` instead of `O(n²)` — the n×n matrix is never
//! assembled. Cf. Vanhatalo & Vehtari (2008), *Modelling local and global
//! phenomena with sparse Gaussian processes*.

use std::sync::Arc;

use crate::par::SyncSlice;
use crate::sparse::cholesky::LdlFactor;
use crate::sparse::csc::CscMatrix;
use crate::sparse::dense::{DenseCholesky, DenseMatrix};
use crate::sparse::symbolic::Symbolic;
use crate::sparse::takahashi::SparseInverse;
use crate::sparse::triangular::SparseSolveWorkspace;

/// Factored representation of `B = S + U Uᵀ`.
pub struct SparseLowRank {
    /// LDLᵀ factor of the sparse part `S`.
    pub factor: LdlFactor,
    /// Low-rank factor `U` (n×m).
    pub u: DenseMatrix,
    /// `W = S⁻¹ U` (n×m).
    pub w: DenseMatrix,
    /// `M₁ = Uᵀ S⁻¹ U` (m×m, symmetric).
    pub m1: DenseMatrix,
    /// Cholesky of the capacitance `C = I_m + M₁`.
    pub cap: DenseCholesky,
}

/// `(W, M₁, chol(C))` from a factored sparse part and the low-rank factor.
/// The m columns of `W = S⁻¹ U` are independent dense solves, so they fan
/// out over the worker pool — this is the `O(m·nnz(L))` capacitance
/// refresh every CS+FIC sweep pays.
fn low_rank_parts(
    factor: &LdlFactor,
    u: &DenseMatrix,
) -> Result<(DenseMatrix, DenseMatrix, DenseCholesky), String> {
    let (n, m) = (u.n_rows, u.n_cols);
    let mut w = DenseMatrix::zeros(n, m);
    {
        let wd = SyncSlice::new(&mut w.data);
        crate::par::for_chunks(
            m,
            1,
            || vec![0.0; n],
            |col, range| {
                for a in range {
                    for (i, c) in col.iter_mut().enumerate() {
                        *c = u.at(i, a);
                    }
                    factor.solve_in_place(col);
                    for (i, &c) in col.iter().enumerate() {
                        // SAFETY: column a's slots (stride m) belong to
                        // exactly this chunk.
                        unsafe { wd.set(i * m + a, c) };
                    }
                }
            },
        );
    }
    let mut m1 = DenseMatrix::zeros(m, m);
    for a in 0..m {
        for b in a..m {
            let s: f64 = (0..n).map(|i| u.at(i, a) * w.at(i, b)).sum();
            *m1.at_mut(a, b) = s;
            *m1.at_mut(b, a) = s;
        }
    }
    let mut c = m1.clone();
    c.add_diag(1.0);
    let cap = c.cholesky().map_err(|e| format!("capacitance I + UᵀS⁻¹U: {e}"))?;
    Ok((w, m1, cap))
}

impl SparseLowRank {
    /// Factor `B = S + U Uᵀ`. `s` must be SPD on the pattern `symbolic`
    /// was analysed from.
    pub fn new(
        s: &CscMatrix,
        symbolic: Arc<Symbolic>,
        u: DenseMatrix,
    ) -> Result<SparseLowRank, String> {
        let factor = LdlFactor::factor(symbolic, s)?;
        SparseLowRank::from_factor(factor, u)
    }

    /// Wrap an already-computed sparse factor.
    pub fn from_factor(factor: LdlFactor, u: DenseMatrix) -> Result<SparseLowRank, String> {
        assert_eq!(u.n_rows, factor.n(), "U rows must match the sparse part");
        let (w, m1, cap) = low_rank_parts(&factor, &u)?;
        Ok(SparseLowRank { factor, u, w, m1, cap })
    }

    /// Refactor with new values of `S` (same pattern) and a new `U`. The
    /// symbolic analysis and the sparse factor's storage are reused in
    /// place — the numeric refactorization is the supernodal,
    /// wave-parallel [`LdlFactor::refactor`], so the CS+FIC sweep's
    /// sparse step scales with the pool like its W-column solves do. The
    /// low-rank blocks (`W`, `M₁`, the capacitance factor) are recomputed
    /// from scratch — they depend on every entry of the new factor, so
    /// when `S` changes there is nothing incremental to salvage
    /// (`O(m·nnz(L) + n·m²)` per call, and the old buffers are freed as
    /// the new ones land). When `S` is *unchanged* and only a few rows of
    /// `U` moved, [`SparseLowRank::update_rows`] revises the blocks
    /// incrementally instead.
    pub fn refresh(&mut self, s: &CscMatrix, u: DenseMatrix) -> Result<(), String> {
        assert_eq!(u.n_rows, self.factor.n());
        assert_eq!(u.n_cols, self.u.n_cols, "rank m must not change across refresh");
        self.factor.refactor(s)?;
        let (w, m1, cap) = low_rank_parts(&self.factor, &u)?;
        self.u = u;
        self.w = w;
        self.m1 = m1;
        self.cap = cap;
        Ok(())
    }

    /// Incrementally revise `B = S + U Uᵀ` after a *row-sparse* change of
    /// `U`: row `rows[t]` takes the values `new_rows[t]` (each `m` wide);
    /// `S` — and therefore the sparse LDLᵀ factor — is unchanged. This is
    /// the online-serving currency: an EP site update at `k ≪ n` appended
    /// or revised sites moves only those rows of `Us = S̃^{1/2} U`.
    ///
    /// With `ΔU` supported on `k = rows.len()` rows,
    ///
    /// ```text
    /// W  += S⁻¹ ΔU                      (m solves, no refactorization)
    /// M₁ += A + Aᵀ + ΔUᵀ S⁻¹ ΔU,   A = ΔUᵀ W_old   (O(k·m²))
    /// cap = chol(I + M₁)                (O(m³))
    /// ```
    ///
    /// versus [`SparseLowRank::refresh`]'s full numeric refactorization
    /// plus `O(n·m²)` block rebuild. Row indices must be in-bounds and
    /// distinct (duplicates would double-count the rank-k correction).
    pub fn update_rows(&mut self, rows: &[usize], new_rows: &[Vec<f64>]) -> Result<(), String> {
        let (n, m) = (self.u.n_rows, self.u.n_cols);
        assert_eq!(rows.len(), new_rows.len(), "one replacement row per index");
        // ΔU on the touched rows
        let mut delta: Vec<Vec<f64>> = Vec::with_capacity(rows.len());
        for (&i, row) in rows.iter().zip(new_rows) {
            assert!(i < n, "row {i} out of bounds for n = {n}");
            assert_eq!(row.len(), m, "replacement rows must be m wide");
            delta.push((0..m).map(|a| row[a] - self.u.at(i, a)).collect());
        }
        // A = ΔUᵀ W_old — read before W moves
        let mut amat = DenseMatrix::zeros(m, m);
        for (d, &i) in delta.iter().zip(rows) {
            let wrow = self.w.row(i);
            for p in 0..m {
                for q in 0..m {
                    *amat.at_mut(p, q) += d[p] * wrow[q];
                }
            }
        }
        // ΔW = S⁻¹ ΔU: one k-nonzero RHS per column against the existing
        // factor, fanned out like the full build in `low_rank_parts`.
        let mut dw = DenseMatrix::zeros(n, m);
        {
            let dwd = SyncSlice::new(&mut dw.data);
            crate::par::for_chunks(
                m,
                1,
                || vec![0.0; n],
                |col, range| {
                    for a in range {
                        for c in col.iter_mut() {
                            *c = 0.0;
                        }
                        for (d, &i) in delta.iter().zip(rows) {
                            col[i] = d[a];
                        }
                        self.factor.solve_in_place(col);
                        for (i, &c) in col.iter().enumerate() {
                            // SAFETY: column a's slots (stride m) belong
                            // to exactly this chunk.
                            unsafe { dwd.set(i * m + a, c) };
                        }
                    }
                },
            );
        }
        // ΔUᵀ ΔW touches only the k revised rows
        let mut dd = DenseMatrix::zeros(m, m);
        for (d, &i) in delta.iter().zip(rows) {
            let dwrow = dw.row(i);
            for p in 0..m {
                for q in 0..m {
                    *dd.at_mut(p, q) += d[p] * dwrow[q];
                }
            }
        }
        // merge the revision
        for (row, &i) in new_rows.iter().zip(rows) {
            for (a, &v) in row.iter().enumerate() {
                *self.u.at_mut(i, a) = v;
            }
        }
        for (wv, &dv) in self.w.data.iter_mut().zip(&dw.data) {
            *wv += dv;
        }
        for p in 0..m {
            for q in 0..m {
                *self.m1.at_mut(p, q) += amat.at(p, q) + amat.at(q, p) + dd.at(p, q);
            }
        }
        let mut c = self.m1.clone();
        c.add_diag(1.0);
        self.cap =
            c.cholesky().map_err(|e| format!("capacitance after row update: {e}"))?;
        Ok(())
    }

    pub fn n(&self) -> usize {
        self.u.n_rows
    }

    pub fn m(&self) -> usize {
        self.u.n_cols
    }

    /// `B⁻¹ b` for a dense right-hand side: one sparse solve plus the
    /// rank-m Woodbury correction.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let (n, m) = (self.u.n_rows, self.u.n_cols);
        let mut y = self.factor.solve(b);
        let mut h = vec![0.0; m];
        for (a, ha) in h.iter_mut().enumerate() {
            *ha = (0..n).map(|i| self.u.at(i, a) * y[i]).sum();
        }
        let z = self.cap.solve(&h);
        for (i, yi) in y.iter_mut().enumerate() {
            let corr: f64 = self.w.row(i).iter().zip(&z).map(|(a, b)| a * b).sum();
            *yi -= corr;
        }
        y
    }

    /// `log|B| = log|S| + log|C|`.
    pub fn logdet(&self) -> f64 {
        self.factor.logdet() + self.cap.logdet()
    }

    /// `g = Wᵀ a` for a sparse vector `a` (sorted rows, aligned values):
    /// only the stored rows of `W` are touched, `O(nnz(a)·m)`.
    pub fn wt_sparse(&self, rows: &[usize], vals: &[f64]) -> Vec<f64> {
        let m = self.u.n_cols;
        let mut g = vec![0.0; m];
        for (&i, &v) in rows.iter().zip(vals) {
            for (ga, &wa) in g.iter_mut().zip(self.w.row(i)) {
                *ga += wa * v;
            }
        }
        g
    }

    /// `aᵀ B⁻¹ a` for a sparse `a`: one sparse-RHS solve against `S` plus
    /// the m×m capacitance correction. `t` must be all-zero on entry and
    /// is restored before returning.
    pub fn quad_sparse(
        &self,
        rows: &[usize],
        vals: &[f64],
        ws: &mut SparseSolveWorkspace,
        t: &mut [f64],
    ) -> f64 {
        self.factor.solve_sparse_rhs(rows, vals, ws, t);
        let q1: f64 = rows.iter().zip(vals).map(|(&i, &v)| v * t[i]).sum();
        ws.clear_solution(t);
        let g = self.wt_sparse(rows, vals);
        let z = self.cap.solve(&g);
        let q2: f64 = g.iter().zip(&z).map(|(a, b)| a * b).sum();
        q1 - q2
    }

    /// `M₂ = Uᵀ B⁻¹ U = M₁ − M₁ C⁻¹ M₁` (m×m, symmetric).
    pub fn m2(&self) -> DenseMatrix {
        let m = self.u.n_cols;
        let mut out = self.m1.clone();
        for b in 0..m {
            let col: Vec<f64> = (0..m).map(|a| self.m1.at(a, b)).collect();
            let z = self.cap.solve(&col);
            for a in 0..m {
                let s: f64 = (0..m).map(|k| self.m1.at(a, k) * z[k]).sum();
                *out.at_mut(a, b) -= s;
            }
        }
        out
    }

    /// Entries of `B⁻¹` on `pattern` (which must lie inside the pattern of
    /// `S`, hence of `L + Lᵀ`): the Takahashi sparsified inverse of the
    /// sparse part minus the low-rank correction `(W C⁻¹ Wᵀ)ᵢⱼ = vᵢ · vⱼ`
    /// with `V = W L_C⁻ᵀ`. Cost `O(takahashi + n·m² + nnz(pattern)·m)` —
    /// the dense inverse is never formed. Values are aligned with
    /// `pattern`'s storage. Allocates fresh buffers; repeated gradient
    /// evaluations should hold an [`InversePatternScratch`] and call
    /// [`inverse_on_pattern_into`](SparseLowRank::inverse_on_pattern_into).
    pub fn inverse_on_pattern(&self, pattern: &CscMatrix) -> Vec<f64> {
        let mut scratch = InversePatternScratch::default();
        let mut out = Vec::new();
        self.inverse_on_pattern_into(pattern, &mut scratch, &mut out);
        out
    }

    /// [`inverse_on_pattern`](SparseLowRank::inverse_on_pattern) with
    /// caller-held buffers: the Takahashi z-arrays, the n×m `V` scratch
    /// and the output are all resized in place (no-ops while the pattern
    /// is unchanged — the `PatternCache`-hit case of the optimizer loop).
    /// The V rows and the pattern columns both fan out over the worker
    /// pool; every slot is written by one task, so the values are
    /// bitwise-identical to the serial path.
    pub fn inverse_on_pattern_into(
        &self,
        pattern: &CscMatrix,
        scratch: &mut InversePatternScratch,
        out: &mut Vec<f64>,
    ) {
        let (n, m) = (self.u.n_rows, self.u.n_cols);
        assert_eq!(pattern.n_rows, n);
        self.factor.takahashi_inverse_into(&mut scratch.takahashi);
        let sym = &self.factor.symbolic;
        // V = W L_C⁻ᵀ, one independent m-solve per row (row-major n×m).
        // Resize only — every slot is written below, so the
        // unchanged-pattern case skips the memset.
        scratch.v.resize(n * m, 0.0);
        {
            let vs = SyncSlice::new(&mut scratch.v);
            crate::par::for_chunks(
                n,
                64,
                || (),
                |_, range| {
                    for i in range {
                        let vi = self.cap.solve_lower(self.w.row(i));
                        for (a, &va) in vi.iter().enumerate() {
                            // SAFETY: row i's slots belong to this chunk only.
                            unsafe { vs.set(i * m + a, va) };
                        }
                    }
                },
            );
        }
        out.resize(pattern.nnz(), 0.0);
        let zsp = &scratch.takahashi;
        let v = &scratch.v;
        let os = SyncSlice::new(out);
        crate::par::for_chunks(
            pattern.n_cols,
            64,
            || (),
            |_, range| {
                for j in range {
                    for p in pattern.col_ptr[j]..pattern.col_ptr[j + 1] {
                        let i = pattern.row_idx[p];
                        let sinv = zsp
                            .get(sym, i, j)
                            .expect("pattern must lie inside the sparse factor's pattern");
                        let corr: f64 = (0..m).map(|a| v[i * m + a] * v[j * m + a]).sum();
                        // SAFETY: entry p lies in column j's range, owned
                        // by exactly this chunk.
                        unsafe { os.set(p, sinv - corr) };
                    }
                }
            },
        );
    }
}

/// Reusable buffers for
/// [`SparseLowRank::inverse_on_pattern_into`]: the Takahashi z-arrays
/// (`O(nnz(L))`) and the n×m `V = W L_C⁻ᵀ` block. Cached by
/// `gp::cache::PatternCache` so repeated CS+FIC gradient evaluations on a
/// cache hit stop reallocating them.
#[derive(Default)]
pub struct InversePatternScratch {
    /// Takahashi sparsified inverse of the sparse part.
    pub takahashi: SparseInverse,
    /// Row-major n×m `V` scratch.
    v: Vec<f64>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testutil::{assert_close, random_sparse_spd, random_vec};

    fn random_u(n: usize, m: usize, seed: u64) -> DenseMatrix {
        let mut rng = Rng::new(seed.wrapping_add(0x10));
        DenseMatrix::from_fn(n, m, |_, _| rng.normal() * 0.5)
    }

    /// Dense oracle: the explicitly assembled `S + U Uᵀ`.
    fn assembled(s: &CscMatrix, u: &DenseMatrix) -> DenseMatrix {
        let mut b = s.to_dense();
        for i in 0..u.n_rows {
            for j in 0..u.n_rows {
                let q: f64 = (0..u.n_cols).map(|a| u.at(i, a) * u.at(j, a)).sum();
                *b.at_mut(i, j) += q;
            }
        }
        b
    }

    fn build(n: usize, m: usize, seed: u64) -> (CscMatrix, DenseMatrix, SparseLowRank) {
        let s = random_sparse_spd(n, 0.12, seed);
        let u = random_u(n, m, seed);
        let sym = Arc::new(Symbolic::analyze(&s));
        let slr = SparseLowRank::new(&s, sym, u.clone()).unwrap();
        (s, u, slr)
    }

    /// The satellite's core check: the Woodbury-over-sparse solve agrees
    /// with a dense Cholesky of the explicitly assembled `S + U Uᵀ`.
    #[test]
    fn solve_matches_dense_cholesky_of_assembled_matrix() {
        for seed in 0..6 {
            let n = 35;
            let (s, u, slr) = build(n, 4, seed);
            let bd = assembled(&s, &u);
            let rhs = random_vec(n, seed + 7);
            let x = slr.solve(&rhs);
            let x_ref = bd.solve_spd(&rhs).unwrap();
            assert_close(&x, &x_ref, 1e-9, "woodbury solve");
        }
    }

    #[test]
    fn logdet_matches_dense() {
        for seed in 0..6 {
            let (s, u, slr) = build(30, 3, seed + 50);
            let bd = assembled(&s, &u);
            let want = bd.cholesky().unwrap().logdet();
            assert!(
                (slr.logdet() - want).abs() < 1e-9,
                "seed {seed}: {} vs {want}",
                slr.logdet()
            );
        }
    }

    #[test]
    fn quad_sparse_matches_dense() {
        for seed in 0..6 {
            let n = 32;
            let (s, u, slr) = build(n, 5, seed + 100);
            let bd = assembled(&s, &u);
            let binv = bd.inverse_spd().unwrap();
            let rows = vec![2usize, 9, 17, 30];
            let vals = vec![1.2, -0.7, 0.4, 2.0];
            let mut ws = SparseSolveWorkspace::new(n);
            let mut t = vec![0.0; n];
            let got = slr.quad_sparse(&rows, &vals, &mut ws, &mut t);
            assert!(t.iter().all(|&v| v == 0.0), "scratch not restored");
            let mut want = 0.0;
            for (&i, &vi) in rows.iter().zip(&vals) {
                for (&j, &vj) in rows.iter().zip(&vals) {
                    want += vi * binv.at(i, j) * vj;
                }
            }
            assert!((got - want).abs() < 1e-9, "seed {seed}: {got} vs {want}");
        }
    }

    #[test]
    fn m2_matches_dense() {
        let n = 28;
        let (s, u, slr) = build(n, 4, 9);
        let binv = assembled(&s, &u).inverse_spd().unwrap();
        let m2 = slr.m2();
        for a in 0..4 {
            for b in 0..4 {
                let mut want = 0.0;
                for i in 0..n {
                    for j in 0..n {
                        want += u.at(i, a) * binv.at(i, j) * u.at(j, b);
                    }
                }
                assert!((m2.at(a, b) - want).abs() < 1e-9, "({a},{b})");
            }
        }
    }

    #[test]
    fn inverse_on_pattern_matches_dense_inverse() {
        for seed in 0..4 {
            let (s, u, slr) = build(26, 3, seed + 200);
            let binv = assembled(&s, &u).inverse_spd().unwrap();
            let vals = slr.inverse_on_pattern(&s);
            for j in 0..s.n_cols {
                for p in s.col_ptr[j]..s.col_ptr[j + 1] {
                    let i = s.row_idx[p];
                    assert!(
                        (vals[p] - binv.at(i, j)).abs() < 1e-9,
                        "seed {seed} ({i},{j}): {} vs {}",
                        vals[p],
                        binv.at(i, j)
                    );
                }
            }
        }
    }

    /// Scratch-reusing, pool-parallel inverse is bitwise-identical to the
    /// fresh single-thread evaluation at any width.
    #[test]
    fn inverse_on_pattern_scratch_reuse_is_bitwise_stable() {
        let (s, _u, slr) = build(30, 4, 321);
        let serial = crate::par::with_max_threads(1, || slr.inverse_on_pattern(&s));
        let mut scratch = InversePatternScratch::default();
        let mut out = Vec::new();
        for width in [1usize, 3, 6] {
            crate::par::with_max_threads(width, || {
                slr.inverse_on_pattern_into(&s, &mut scratch, &mut out)
            });
            assert_eq!(out, serial, "width {width}");
        }
    }

    /// The online-update primitive against the from-scratch oracle: a
    /// row-sparse revision of `U` through `update_rows` must agree with a
    /// fresh construction at the revised `U` — solve, logdet and the
    /// capacitance blocks all flow through the updated `W`/`M₁`.
    #[test]
    fn update_rows_matches_fresh_construction() {
        for seed in 0..4 {
            let n = 34;
            let m = 4;
            let s = random_sparse_spd(n, 0.14, seed + 300);
            let u1 = random_u(n, m, seed + 300);
            let sym = Arc::new(Symbolic::analyze(&s));
            let mut slr = SparseLowRank::new(&s, sym.clone(), u1.clone()).unwrap();

            // revise three rows (one at the boundary), keep S fixed
            let rows = vec![0usize, 17, n - 1];
            let mut rng = Rng::new(seed + 11);
            let new_rows: Vec<Vec<f64>> =
                rows.iter().map(|_| (0..m).map(|_| rng.normal() * 0.7).collect()).collect();
            slr.update_rows(&rows, &new_rows).unwrap();

            let mut u2 = u1.clone();
            for (row, &i) in new_rows.iter().zip(&rows) {
                for (a, &v) in row.iter().enumerate() {
                    *u2.at_mut(i, a) = v;
                }
            }
            let fresh = SparseLowRank::new(&s, sym, u2).unwrap();
            let rhs = random_vec(n, seed + 23);
            assert_close(&slr.solve(&rhs), &fresh.solve(&rhs), 1e-9, "updated solve");
            assert!(
                (slr.logdet() - fresh.logdet()).abs() < 1e-9,
                "seed {seed}: logdet {} vs {}",
                slr.logdet(),
                fresh.logdet()
            );
            let (m2a, m2b) = (slr.m2(), fresh.m2());
            for a in 0..m {
                for b in 0..m {
                    assert!(
                        (m2a.at(a, b) - m2b.at(a, b)).abs() < 1e-9,
                        "M2 ({a},{b}) after update_rows"
                    );
                }
            }
        }
    }

    #[test]
    fn refresh_matches_fresh_construction() {
        let n = 30;
        let s1 = random_sparse_spd(n, 0.15, 31);
        let u1 = random_u(n, 4, 31);
        let sym = Arc::new(Symbolic::analyze(&s1));
        let mut slr = SparseLowRank::new(&s1, sym.clone(), u1).unwrap();
        // new values on the same pattern + a new U
        let mut s2 = s1.clone();
        for j in 0..n {
            *s2.get_mut(j, j) += 0.75;
        }
        let u2 = random_u(n, 4, 77);
        slr.refresh(&s2, u2.clone()).unwrap();
        let fresh = SparseLowRank::new(&s2, sym, u2).unwrap();
        let rhs = random_vec(n, 5);
        assert_close(&slr.solve(&rhs), &fresh.solve(&rhs), 1e-12, "refresh solve");
        assert!((slr.logdet() - fresh.logdet()).abs() < 1e-12);
    }
}
