//! Rank-one update/downdate of an LDLᵀ factor on a static pattern.
//!
//! Method C1' (Gill–Golub–Murray–Saunders; the form used by CHOLMOD's
//! `updown`): factor `A ± w wᵀ` by walking the elimination-tree path from
//! the first nonzero of `w`. The paper's `ldlrowmodify` (Algorithm 2,
//! line 5) calls this twice — an update with the old column scaled by
//! `√d₂₂` and a downdate with the new one — and relies on the support of
//! `w` lying on a single etree path, which holds because both vectors live
//! on the pattern of one column of `L` (every pattern row of a column is
//! an ancestor of that column).

use crate::sparse::cholesky::LdlFactor;

/// Sign of the rank-one modification.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateSign {
    Update,   // +w wᵀ
    Downdate, // -w wᵀ
}

impl LdlFactor {
    /// In-place rank-one modification `A ← A ± w wᵀ`.
    ///
    /// `w_rows` (sorted) / `w_vals` give the sparse `w`; `w_scratch` is a
    /// dense length-n scratch vector that must be all zeros on entry and is
    /// re-zeroed before returning. The support of `w` — including fill
    /// created during the sweep — must stay within the factor's symbolic
    /// pattern (guaranteed when `w`'s pattern is a subset of a column
    /// pattern of `L`, or when the pattern is dense).
    ///
    /// Errors if a downdate makes the factor indefinite. A failure leaves
    /// the factor corrupt (partially swept), so there is no in-place
    /// retry: callers recover by rebuilding the matrix and refactoring —
    /// see the recovery contract in [`crate::sparse::rowmod`] and
    /// [`LdlFactor::refactor_with_recovery`](crate::sparse::cholesky::LdlFactor::refactor_with_recovery).
    pub fn rank1(
        &mut self,
        w_rows: &[usize],
        w_vals: &[f64],
        sign: UpdateSign,
        w_scratch: &mut [f64],
    ) -> Result<(), String> {
        if w_rows.is_empty() {
            return Ok(());
        }
        let sym = self.symbolic.clone();
        let sigma = match sign {
            UpdateSign::Update => 1.0,
            UpdateSign::Downdate => -1.0,
        };
        for (&i, &v) in w_rows.iter().zip(w_vals) {
            w_scratch[i] = v;
        }
        let mut j = w_rows[0];
        let mut alpha = 1.0;
        let mut result = Ok(());
        while j != usize::MAX {
            let wj = w_scratch[j];
            if wj != 0.0 {
                let dj = self.d[j];
                let alpha_new = alpha + sigma * wj * wj / dj;
                if alpha_new <= 0.0 {
                    result = Err(format!(
                        "rank-1 downdate made factor indefinite at column {j} (alpha {alpha_new})"
                    ));
                    break;
                }
                self.d[j] = dj * alpha_new / alpha;
                let gamma = sigma * wj / (alpha_new * dj);
                let lo = sym.col_ptr[j];
                let hi = sym.col_ptr[j + 1];
                for p in lo..hi {
                    let r = sym.row_idx[p];
                    let wr = w_scratch[r] - wj * self.l[p];
                    w_scratch[r] = wr;
                    self.l[p] += gamma * wr;
                }
                w_scratch[j] = 0.0;
                alpha = alpha_new;
            }
            j = sym.parent[j];
        }
        // re-zero scratch (support may have grown to the whole path)
        let mut j = w_rows[0];
        while j != usize::MAX {
            w_scratch[j] = 0.0;
            j = sym.parent[j];
        }
        for &i in w_rows {
            w_scratch[i] = 0.0;
        }
        result
    }

    /// Fused rank-one update (+w₁w₁ᵀ) and downdate (−w₂w₂ᵀ) sharing one
    /// traversal of the etree path — the paper's §5.3 observation that,
    /// with an unchanged sparsity pattern, doing both simultaneously
    /// avoids scanning the factor's data structure twice. `w1`/`w2` share
    /// the sparse pattern `w_rows` (the column-i pattern in `ldlrowmodify`).
    ///
    /// Column-local correctness: column j's final value after "full
    /// update sweep then full downdate sweep" depends only on the two
    /// column-j transformations applied in order, which is exactly what
    /// the fused loop does.
    pub fn rank1_pair(
        &mut self,
        w_rows: &[usize],
        w1_vals: &[f64],
        w2_vals: &[f64],
        s1: &mut [f64],
        s2: &mut [f64],
    ) -> Result<(), String> {
        if w_rows.is_empty() {
            return Ok(());
        }
        let sym = self.symbolic.clone();
        for ((&i, &v1), &v2) in w_rows.iter().zip(w1_vals).zip(w2_vals) {
            s1[i] = v1;
            s2[i] = v2;
        }
        let mut alpha1 = 1.0f64;
        let mut alpha2 = 1.0f64;
        let mut j = w_rows[0];
        let mut result = Ok(());
        while j != usize::MAX {
            let w1j = s1[j];
            let w2j = s2[j];
            if w1j != 0.0 || w2j != 0.0 {
                let lo = sym.col_ptr[j];
                let hi = sym.col_ptr[j + 1];
                // --- update with w1 ---
                let mut d = self.d[j];
                let (g1, skip1) = if w1j != 0.0 {
                    let a_new = alpha1 + w1j * w1j / d;
                    let dn = d * a_new / alpha1;
                    let g = w1j / (a_new * d);
                    alpha1 = a_new;
                    d = dn;
                    (g, false)
                } else {
                    (0.0, true)
                };
                // --- downdate with w2 (uses post-update d) ---
                let (g2, skip2) = if w2j != 0.0 {
                    let a_new = alpha2 - w2j * w2j / d;
                    if a_new <= 0.0 {
                        result = Err(format!(
                            "fused downdate made factor indefinite at column {j} ({a_new})"
                        ));
                        break;
                    }
                    let dn = d * a_new / alpha2;
                    let g = -w2j / (a_new * d);
                    alpha2 = a_new;
                    d = dn;
                    (g, false)
                } else {
                    (0.0, true)
                };
                self.d[j] = d;
                // single pass over column j for both vectors
                // SAFETY: all indices come from the symbolic pattern,
                // which is bounds-checked at construction.
                unsafe {
                    for p in lo..hi {
                        let r = *sym.row_idx.get_unchecked(p);
                        let l = self.l.get_unchecked_mut(p);
                        let mut lv = *l;
                        if !skip1 {
                            let wr = *s1.get_unchecked(r) - w1j * lv;
                            *s1.get_unchecked_mut(r) = wr;
                            lv += g1 * wr;
                        }
                        if !skip2 {
                            let wr = *s2.get_unchecked(r) - w2j * lv;
                            *s2.get_unchecked_mut(r) = wr;
                            lv += g2 * wr;
                        }
                        *l = lv;
                    }
                }
            }
            j = sym.parent[j];
        }
        // re-zero both scratches along the path + original support
        let mut j = w_rows[0];
        while j != usize::MAX {
            s1[j] = 0.0;
            s2[j] = 0.0;
            j = sym.parent[j];
        }
        for &i in w_rows {
            s1[i] = 0.0;
            s2[i] = 0.0;
        }
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::sparse::csc::CscMatrix;
    use crate::sparse::symbolic::Symbolic;
    use crate::testutil::random_sparse_spd;
    use std::sync::Arc;

    /// Update with w supported on a single column's pattern (the rowmod use
    /// case): take w = scaled copy of an existing L column.
    #[test]
    fn update_then_downdate_roundtrips() {
        for seed in 0..8 {
            let n = 30;
            let a = random_sparse_spd(n, 0.15, seed);
            let sym = Arc::new(Symbolic::analyze(&a));
            let f0 = LdlFactor::factor(sym.clone(), &a).unwrap();
            let mut f = f0.clone();
            // pick a column with nonempty pattern
            let j = (0..n).find(|&j| !sym.col_pattern(j).is_empty()).unwrap();
            let rows: Vec<usize> = sym.col_pattern(j).to_vec();
            let mut rng = Rng::new(seed);
            let vals: Vec<f64> = rows.iter().map(|_| rng.uniform_in(-0.5, 0.5)).collect();
            let mut scratch = vec![0.0; n];
            f.rank1(&rows, &vals, UpdateSign::Update, &mut scratch).unwrap();
            assert!(scratch.iter().all(|&x| x == 0.0), "scratch not re-zeroed");
            f.rank1(&rows, &vals, UpdateSign::Downdate, &mut scratch).unwrap();
            let diff: f64 = f
                .l
                .iter()
                .zip(&f0.l)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0, f64::max);
            assert!(diff < 1e-8, "seed {seed}: L diff {diff}");
        }
    }

    #[test]
    fn update_matches_refactorization() {
        for seed in 0..8 {
            let n = 25;
            let a = random_sparse_spd(n, 0.2, seed + 50);
            let sym = Arc::new(Symbolic::analyze(&a));
            let mut f = LdlFactor::factor(sym.clone(), &a).unwrap();
            let j = (0..n).rev().find(|&j| sym.col_pattern(j).len() >= 2).unwrap_or(0);
            let rows: Vec<usize> = sym.col_pattern(j).to_vec();
            let mut rng = Rng::new(seed + 1);
            let vals: Vec<f64> = rows.iter().map(|_| rng.uniform_in(-0.4, 0.4)).collect();
            let mut scratch = vec![0.0; n];
            f.rank1(&rows, &vals, UpdateSign::Update, &mut scratch).unwrap();
            // oracle: dense A + wwT refactored
            let mut ad = a.to_dense();
            for (&r1, &v1) in rows.iter().zip(&vals) {
                for (&r2, &v2) in rows.iter().zip(&vals) {
                    *ad.at_mut(r1, r2) += v1 * v2;
                }
            }
            let rec = f.reconstruct();
            assert!(rec.max_abs_diff(&ad) < 1e-8, "seed {seed}: {}", rec.max_abs_diff(&ad));
        }
    }

    /// With a dense pattern the etree is a path, so arbitrary w is legal.
    #[test]
    fn dense_pattern_arbitrary_w() {
        let n = 12;
        let mut t = Vec::new();
        let mut rng = Rng::new(3);
        for i in 0..n {
            for j in 0..i {
                let v = rng.uniform_in(-0.3, 0.3);
                t.push((i, j, v));
                t.push((j, i, v));
            }
            t.push((i, i, n as f64));
        }
        let a = CscMatrix::from_triplets(n, n, &t);
        let sym = Arc::new(Symbolic::analyze(&a));
        let mut f = LdlFactor::factor(sym, &a).unwrap();
        let rows: Vec<usize> = (0..n).step_by(3).collect();
        let vals: Vec<f64> = rows.iter().map(|&i| 0.1 * (i as f64 + 1.0)).collect();
        let mut scratch = vec![0.0; n];
        f.rank1(&rows, &vals, UpdateSign::Update, &mut scratch).unwrap();
        let mut ad = a.to_dense();
        for (&r1, &v1) in rows.iter().zip(&vals) {
            for (&r2, &v2) in rows.iter().zip(&vals) {
                *ad.at_mut(r1, r2) += v1 * v2;
            }
        }
        assert!(f.reconstruct().max_abs_diff(&ad) < 1e-9);
    }

    #[test]
    fn fused_pair_matches_sequential() {
        for seed in 0..8 {
            let n = 28;
            let a = random_sparse_spd(n, 0.18, seed + 900);
            let sym = Arc::new(Symbolic::analyze(&a));
            let f0 = LdlFactor::factor(sym.clone(), &a).unwrap();
            let j = (0..n).find(|&j| sym.col_pattern(j).len() >= 2).unwrap_or(0);
            let rows: Vec<usize> = sym.col_pattern(j).to_vec();
            let mut rng = Rng::new(seed + 7);
            let w1: Vec<f64> = rows.iter().map(|_| rng.uniform_in(-0.4, 0.4)).collect();
            let w2: Vec<f64> = rows.iter().map(|_| rng.uniform_in(-0.3, 0.3)).collect();
            // sequential
            let mut fs = f0.clone();
            let mut scratch = vec![0.0; n];
            fs.rank1(&rows, &w1, UpdateSign::Update, &mut scratch).unwrap();
            fs.rank1(&rows, &w2, UpdateSign::Downdate, &mut scratch).unwrap();
            // fused
            let mut ff = f0.clone();
            let mut s1 = vec![0.0; n];
            let mut s2 = vec![0.0; n];
            ff.rank1_pair(&rows, &w1, &w2, &mut s1, &mut s2).unwrap();
            assert!(s1.iter().chain(&s2).all(|&x| x == 0.0), "scratch not re-zeroed");
            let dl: f64 =
                fs.l.iter().zip(&ff.l).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
            let dd: f64 =
                fs.d.iter().zip(&ff.d).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
            assert!(dl < 1e-10 && dd < 1e-10, "seed {seed}: dl={dl} dd={dd}");
        }
    }

    #[test]
    fn downdate_to_indefinite_errors() {
        let a = CscMatrix::from_triplets(2, 2, &[(0, 0, 1.0), (1, 1, 1.0)]);
        let sym = Arc::new(Symbolic::analyze(&a));
        let mut f = LdlFactor::factor(sym, &a).unwrap();
        let mut scratch = vec![0.0; 2];
        let r = f.rank1(&[0], &[2.0], UpdateSign::Downdate, &mut scratch);
        assert!(r.is_err());
    }

    #[test]
    fn empty_w_is_noop() {
        let a = CscMatrix::identity(3);
        let sym = Arc::new(Symbolic::analyze(&a));
        let mut f = LdlFactor::factor(sym, &a).unwrap();
        let d0 = f.d.clone();
        let mut scratch = vec![0.0; 3];
        f.rank1(&[], &[], UpdateSign::Update, &mut scratch).unwrap();
        assert_eq!(f.d, d0);
    }
}
