//! Nested dissection: recursive vertex bisection with an explicit
//! separator tree.
//!
//! ND orders each half of a bisected graph before the separator that
//! disconnects them, recursively: eliminating a half never creates fill
//! in the other half, so the elimination tree decomposes into the two
//! halves' subtrees hanging under the separator's chain. The recursion
//! therefore yields (a) near-optimal fill on geometric graphs and (b) a
//! **balanced** assembly tree — wide leaf waves for the supernodal
//! parallel factorization, where RCM's banded etrees degenerate to
//! near-paths (see `docs/ARCHITECTURE.md` §Ordering layer).
//!
//! Two bisection strategies:
//!
//! * **geometric** (fast path, used when the caller passes point
//!   coordinates — the covariance pipeline always has them): median split
//!   along the widest-spread axis, then the boundary vertices of one side
//!   become the separator. `O(len log len)` per level.
//! * **graph** (pattern-only): BFS level sets from a pseudo-peripheral
//!   vertex; the cut level with the smallest vertex count inside the
//!   balanced band becomes the separator.
//!
//! Both cuts are polished by a few Fiduccia-style single-vertex passes
//! (move separator vertices whose neighborhood lies on one side; shift
//! zero-gain vertices toward the lighter side), and every subproblem at
//! or below [`ND_LEAF`] vertices is ordered by the greedy min-degree —
//! the classic ND leaf treatment.
//!
//! The returned [`SeparatorTree`] describes the recursion in *permuted*
//! column coordinates; [`crate::sparse::symbolic::Symbolic`] carries it
//! so schedulers and benches can see the block hierarchy behind the
//! assembly-tree waves, and validates the separator invariant (no pattern
//! edge between sibling branches) in debug builds.

use crate::sparse::csc::CscMatrix;

/// Subgraphs at or below this size are ordered directly (greedy
/// min-degree) instead of being bisected further.
pub const ND_LEAF: usize = 64;

/// Recursion depth cap — a backstop for adversarial graphs where
/// bisection keeps degenerating; the remainder is ordered as one leaf.
const ND_MAX_DEPTH: usize = 64;

/// One node of the dissection recursion, in permuted column coordinates.
///
/// The node's subtree owns columns `start..end`; its two children (when
/// present) own the leading sub-ranges and the separator owns the tail
/// `sep_start..end`. Leaves have no separator: `sep_start == start`, the
/// whole range is the leaf block.
#[derive(Clone, Debug)]
pub struct SepNode {
    pub start: usize,
    pub end: usize,
    pub sep_start: usize,
    /// Child node ids (empty for leaves, otherwise exactly two).
    pub children: Vec<usize>,
    /// Parent node id (`usize::MAX` at the root).
    pub parent: usize,
}

impl SepNode {
    pub fn is_leaf(&self) -> bool {
        self.children.is_empty()
    }

    /// Columns of this node's own block: the separator for internal
    /// nodes, the whole range for leaves.
    pub fn block(&self) -> std::ops::Range<usize> {
        self.sep_start..self.end
    }
}

/// The dissection hierarchy: node 0 is the root; children always carry
/// larger ids than their parent.
#[derive(Clone, Debug)]
pub struct SeparatorTree {
    pub nodes: Vec<SepNode>,
}

impl SeparatorTree {
    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Number of levels (a single leaf tree has depth 1).
    pub fn depth(&self) -> usize {
        let mut depth = vec![0usize; self.nodes.len()];
        let mut max = 0;
        for (id, node) in self.nodes.iter().enumerate() {
            if node.parent != usize::MAX {
                depth[id] = depth[node.parent] + 1;
            }
            max = max.max(depth[id]);
        }
        max + 1
    }

    /// Check the separator invariant on the *permuted* pattern: for every
    /// internal node, no entry of `a_perm` connects the two children's
    /// column ranges (the separator disconnects them, and elimination
    /// preserves that — so the factor's fill cannot cross either).
    pub fn validate(&self, a_perm: &CscMatrix) -> Result<(), String> {
        for (id, node) in self.nodes.iter().enumerate() {
            if node.is_leaf() {
                continue;
            }
            let (l, r) = (&self.nodes[node.children[0]], &self.nodes[node.children[1]]);
            if l.start != node.start || l.end != r.start || r.end != node.sep_start {
                return Err(format!(
                    "node {id}: child ranges [{}, {}) + [{}, {}) do not tile [{}, {})",
                    l.start, l.end, r.start, r.end, node.start, node.sep_start
                ));
            }
            for j in l.start..l.end {
                let (rows, _) = a_perm.col(j);
                for &i in rows {
                    if i >= r.start && i < r.end {
                        return Err(format!(
                            "node {id}: pattern edge ({i}, {j}) crosses the cut \
                             [{}, {}) x [{}, {})",
                            l.start, l.end, r.start, r.end
                        ));
                    }
                }
            }
        }
        Ok(())
    }
}

/// Where a vertex currently sits during one bisection.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Side {
    A,
    B,
    Sep,
}

struct Dissector<'a> {
    adj: Vec<Vec<usize>>,
    points: Option<&'a [Vec<f64>]>,
    perm: Vec<usize>,
    nodes: Vec<SepNode>,
    /// Scratch keyed by original vertex id, valid while stamped.
    side: Vec<Side>,
    in_set: Vec<usize>,
    set_stamp: usize,
    level: Vec<usize>,
    visited: Vec<usize>,
    visit_stamp: usize,
}

/// Compute the nested-dissection ordering of symmetric `a`. `points`
/// (same index order as `a`'s columns) enable the geometric fast path.
/// Returns the permutation (old -> new) and the separator tree in
/// permuted coordinates.
pub fn nested_dissection(
    a: &CscMatrix,
    points: Option<&[Vec<f64>]>,
) -> (Vec<usize>, SeparatorTree) {
    let n = a.n_rows;
    if n == 0 {
        let root =
            SepNode { start: 0, end: 0, sep_start: 0, children: vec![], parent: usize::MAX };
        return (Vec::new(), SeparatorTree { nodes: vec![root] });
    }
    let points = points.filter(|p| p.len() == n);
    let mut d = Dissector {
        adj: super::adjacency(a),
        points,
        perm: vec![0usize; n],
        nodes: Vec::new(),
        side: vec![Side::A; n],
        in_set: vec![0usize; n],
        set_stamp: 0,
        level: vec![0usize; n],
        visited: vec![0usize; n],
        visit_stamp: 0,
    };
    let all: Vec<usize> = (0..n).collect();
    d.dissect(all, 0, usize::MAX, 0);
    let tree = SeparatorTree { nodes: d.nodes };
    (d.perm, tree)
}

impl Dissector<'_> {
    /// Order `verts` into permuted positions `base..base + verts.len()`;
    /// returns the tree node id.
    fn dissect(&mut self, verts: Vec<usize>, base: usize, parent: usize, depth: usize) -> usize {
        let len = verts.len();
        let id = self.nodes.len();
        self.nodes.push(SepNode {
            start: base,
            end: base + len,
            sep_start: base, // leaf layout until a split succeeds
            children: Vec::new(),
            parent,
        });
        if len <= ND_LEAF || depth >= ND_MAX_DEPTH {
            self.order_leaf(&verts, base);
            return id;
        }
        match self.bisect(&verts) {
            None => {
                self.order_leaf(&verts, base);
                id
            }
            Some((aset, bset, sset)) => {
                let (alen, blen) = (aset.len(), bset.len());
                let left = self.dissect(aset, base, id, depth + 1);
                let right = self.dissect(bset, base + alen, id, depth + 1);
                // separator columns take the tail of the range, in
                // ascending original order (deterministic)
                let sep_start = base + alen + blen;
                let mut sep = sset;
                sep.sort_unstable();
                for (k, &v) in sep.iter().enumerate() {
                    self.perm[v] = sep_start + k;
                }
                let node = &mut self.nodes[id];
                node.sep_start = sep_start;
                node.children = vec![left, right];
                id
            }
        }
    }

    /// Order a leaf block with min-degree on the subgraph — the classic
    /// ND leaf treatment. Small leaves use the greedy (cheap, exact
    /// degrees); the rare large leaf (depth-cap or clique-ish fallback)
    /// goes through the quotient-graph method to stay off the greedy's
    /// quadratic path.
    fn order_leaf(&mut self, verts: &[usize], base: usize) {
        let len = verts.len();
        self.mark_set(verts);
        let mut local_of = std::collections::HashMap::with_capacity(len);
        for (li, &v) in verts.iter().enumerate() {
            local_of.insert(v, li);
        }
        let mut t: Vec<(usize, usize, f64)> = (0..len).map(|i| (i, i, 1.0)).collect();
        for (li, &v) in verts.iter().enumerate() {
            for &u in &self.adj[v] {
                if self.contains(u) {
                    t.push((li, local_of[&u], 1.0));
                }
            }
        }
        let sub = CscMatrix::from_triplets(len, len, &t);
        let lperm = if len <= ND_LEAF {
            super::mindeg::min_degree_greedy(&sub)
        } else {
            super::mindeg::min_degree(&sub)
        };
        for (li, &v) in verts.iter().enumerate() {
            self.perm[v] = base + lperm[li];
        }
    }

    fn mark_set(&mut self, verts: &[usize]) {
        self.set_stamp += 1;
        for &v in verts {
            self.in_set[v] = self.set_stamp;
        }
    }

    #[inline]
    fn contains(&self, v: usize) -> bool {
        self.in_set[v] == self.set_stamp
    }

    /// Split `verts` into (A, B, separator). `None` when no useful split
    /// exists (e.g. a clique). A and B are non-empty on success.
    fn bisect(&mut self, verts: &[usize]) -> Option<(Vec<usize>, Vec<usize>, Vec<usize>)> {
        self.mark_set(verts);
        // Disconnected subgraph: pack components into two halves, no
        // separator needed.
        let comps = self.components(verts);
        if comps.len() > 1 {
            let (mut aset, mut bset) = (Vec::new(), Vec::new());
            for comp in comps {
                if aset.len() <= bset.len() {
                    aset.extend(comp);
                } else {
                    bset.extend(comp);
                }
            }
            aset.sort_unstable();
            bset.sort_unstable();
            return Some((aset, bset, Vec::new()));
        }
        let split = match self.points {
            Some(points) => self.geometric_split(verts, points),
            None => self.levelset_split(verts),
        };
        split.or_else(|| self.half_split(verts))?;
        self.refine(verts)
    }

    /// Connected components of the marked subgraph, each sorted. Uses the
    /// stamped `visited` scratch — no allocation or hashing per call.
    fn components(&mut self, verts: &[usize]) -> Vec<Vec<usize>> {
        self.visit_stamp += 1;
        let mut comps = Vec::new();
        for &s in verts {
            if self.visited[s] == self.visit_stamp {
                continue;
            }
            self.visited[s] = self.visit_stamp;
            let mut comp = vec![s];
            let mut head = 0;
            while head < comp.len() {
                let u = comp[head];
                head += 1;
                for k in 0..self.adj[u].len() {
                    let v = self.adj[u][k];
                    if self.contains(v) && self.visited[v] != self.visit_stamp {
                        self.visited[v] = self.visit_stamp;
                        comp.push(v);
                    }
                }
            }
            comp.sort_unstable();
            comps.push(comp);
        }
        comps
    }

    /// Geometric bisection: median split on the widest-spread axis, ties
    /// broken by vertex id so the cut is a pure function of the input.
    /// The A-side boundary becomes the separator candidate.
    fn geometric_split(&mut self, verts: &[usize], points: &[Vec<f64>]) -> Option<()> {
        let dim = points[verts[0]].len();
        if dim == 0 {
            return None;
        }
        let mut best_axis = 0;
        let mut best_spread = f64::NEG_INFINITY;
        for d in 0..dim {
            let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
            for &v in verts {
                lo = lo.min(points[v][d]);
                hi = hi.max(points[v][d]);
            }
            if hi - lo > best_spread {
                best_spread = hi - lo;
                best_axis = d;
            }
        }
        let mut by_coord: Vec<usize> = verts.to_vec();
        by_coord.sort_by(|&u, &v| {
            points[u][best_axis]
                .partial_cmp(&points[v][best_axis])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(u.cmp(&v))
        });
        let half = verts.len() / 2;
        for &v in &by_coord[..half] {
            self.side[v] = Side::A;
        }
        for &v in &by_coord[half..] {
            self.side[v] = Side::B;
        }
        self.seed_separator_from_boundary(verts);
        Some(())
    }

    /// Graph bisection: BFS level sets from a pseudo-peripheral vertex;
    /// the smallest level inside the balance band `[1/5, 4/5]` becomes
    /// the separator.
    fn levelset_split(&mut self, verts: &[usize]) -> Option<()> {
        let len = verts.len();
        // pseudo-peripheral start: BFS from the min-degree vertex, then
        // restart from the last vertex reached
        let s0 = *verts
            .iter()
            .min_by_key(|&&v| (self.adj[v].iter().filter(|&&u| self.contains(u)).count(), v))?;
        let far = *self.bfs_levels(s0).last().unwrap();
        let order = self.bfs_levels(far);
        debug_assert_eq!(order.len(), len, "subgraph must be connected here");
        let n_levels = self.level[*order.last().unwrap()] + 1;
        if n_levels < 3 {
            return None; // diameter too small to cut by levels (clique-ish)
        }
        let mut level_count = vec![0usize; n_levels];
        for &v in &order {
            level_count[self.level[v]] += 1;
        }
        let (mut best_cut, mut best_size) = (usize::MAX, usize::MAX);
        let mut prefix = 0usize;
        for (cut, &c) in level_count.iter().enumerate() {
            if prefix >= len / 5 && prefix + c <= len - len / 5 && c < best_size {
                best_size = c;
                best_cut = cut;
            }
            prefix += c;
        }
        if best_cut == usize::MAX {
            // no level inside the band: cut at the level holding the median
            let mut prefix = 0usize;
            for (cut, &c) in level_count.iter().enumerate() {
                if prefix + c > len / 2 {
                    best_cut = cut;
                    break;
                }
                prefix += c;
            }
        }
        for &v in &order {
            self.side[v] = match self.level[v].cmp(&best_cut) {
                std::cmp::Ordering::Less => Side::A,
                std::cmp::Ordering::Equal => Side::Sep,
                std::cmp::Ordering::Greater => Side::B,
            };
        }
        Some(())
    }

    /// Last-resort split: halve the BFS order (connected, but balance is
    /// forced) and seed the separator from the boundary.
    fn half_split(&mut self, verts: &[usize]) -> Option<()> {
        let order = self.bfs_levels(*verts.first()?);
        let half = order.len() / 2;
        if half == 0 {
            return None;
        }
        for (k, &v) in order.iter().enumerate() {
            self.side[v] = if k < half { Side::A } else { Side::B };
        }
        self.seed_separator_from_boundary(verts);
        Some(())
    }

    /// BFS over the marked subgraph from `start`, writing `self.level`
    /// and returning the visit order. Stamped `visited` scratch — no
    /// allocation or hashing in the hot loop.
    fn bfs_levels(&mut self, start: usize) -> Vec<usize> {
        self.visit_stamp += 1;
        self.visited[start] = self.visit_stamp;
        self.level[start] = 0;
        let mut order = vec![start];
        let mut head = 0;
        while head < order.len() {
            let u = order[head];
            head += 1;
            for k in 0..self.adj[u].len() {
                let v = self.adj[u][k];
                if self.contains(v) && self.visited[v] != self.visit_stamp {
                    self.visited[v] = self.visit_stamp;
                    self.level[v] = self.level[u] + 1;
                    order.push(v);
                }
            }
        }
        order
    }

    /// Move every A vertex with a B neighbor into the separator
    /// (A/B-only splits -> a valid vertex separator).
    fn seed_separator_from_boundary(&mut self, verts: &[usize]) {
        for &v in verts {
            if self.side[v] != Side::A {
                continue;
            }
            if self.adj[v].iter().any(|&u| self.contains(u) && self.side[u] == Side::B) {
                self.side[v] = Side::Sep;
            }
        }
    }

    /// Fiduccia-style polish of the cut in `self.side`, then package the
    /// three sets. Bails out (None) when refinement cannot keep both
    /// sides meaningfully populated.
    fn refine(&mut self, verts: &[usize]) -> Option<(Vec<usize>, Vec<usize>, Vec<usize>)> {
        let len = verts.len();
        for _pass in 0..4 {
            let mut moved = false;
            let (mut na_tot, mut nb_tot) = (0usize, 0usize);
            for &v in verts {
                match self.side[v] {
                    Side::A => na_tot += 1,
                    Side::B => nb_tot += 1,
                    Side::Sep => {}
                }
            }
            for &v in verts {
                if self.side[v] != Side::Sep {
                    continue;
                }
                let (mut na, mut nb) = (0usize, 0usize);
                for &u in &self.adj[v] {
                    if !self.contains(u) {
                        continue;
                    }
                    match self.side[u] {
                        Side::A => na += 1,
                        Side::B => nb += 1,
                        Side::Sep => {}
                    }
                }
                // free moves: the vertex only touches one side
                if na == 0 && nb == 0 {
                    let to_a = na_tot <= nb_tot;
                    self.side[v] = if to_a { Side::A } else { Side::B };
                    if to_a {
                        na_tot += 1
                    } else {
                        nb_tot += 1
                    }
                    moved = true;
                } else if nb == 0 {
                    self.side[v] = Side::A;
                    na_tot += 1;
                    moved = true;
                } else if na == 0 {
                    self.side[v] = Side::B;
                    nb_tot += 1;
                    moved = true;
                } else if nb == 1 && na_tot + 1 < nb_tot {
                    // zero-gain rebalance: v -> A, its single B neighbor
                    // joins the separator (|S| unchanged, balance better)
                    let u = *self
                        .adj[v]
                        .iter()
                        .find(|&&u| self.contains(u) && self.side[u] == Side::B)
                        .unwrap();
                    self.side[v] = Side::A;
                    self.side[u] = Side::Sep;
                    na_tot += 1;
                    nb_tot -= 1;
                    moved = true;
                } else if na == 1 && nb_tot + 1 < na_tot {
                    let u = *self
                        .adj[v]
                        .iter()
                        .find(|&&u| self.contains(u) && self.side[u] == Side::A)
                        .unwrap();
                    self.side[v] = Side::B;
                    self.side[u] = Side::Sep;
                    nb_tot += 1;
                    na_tot -= 1;
                    moved = true;
                }
            }
            if !moved {
                break;
            }
        }
        let (mut aset, mut bset, mut sset) = (Vec::new(), Vec::new(), Vec::new());
        for &v in verts {
            match self.side[v] {
                Side::A => aset.push(v),
                Side::B => bset.push(v),
                Side::Sep => sset.push(v),
            }
        }
        // a useful split keeps both halves populated; a separator that
        // swallowed a side (clique-ish graphs) means "stop dissecting"
        if aset.is_empty() || bset.is_empty() || sset.len() * 2 >= len {
            return None;
        }
        aset.sort_unstable();
        bset.sort_unstable();
        Some((aset, bset, sset))
    }
}

#[cfg(test)]
mod tests {
    use super::super::testfix::{cs_pattern, fill_of, is_permutation};
    use super::*;
    use crate::sparse::symbolic::Symbolic;
    use crate::testutil::{random_points, random_sparse_spd};

    fn tree_and_perm(a: &CscMatrix, pts: Option<&[Vec<f64>]>) -> (Vec<usize>, SeparatorTree) {
        let (perm, tree) = nested_dissection(a, pts);
        assert!(is_permutation(&perm));
        (perm, tree)
    }

    #[test]
    fn nd_is_a_valid_permutation_on_random_patterns() {
        for seed in 0..5 {
            let a = random_sparse_spd(90, 0.05, seed + 10);
            let (_, tree) = tree_and_perm(&a, None);
            assert!(tree.n_nodes() >= 1);
        }
    }

    /// The defining invariant: no pattern edge crosses the two halves of
    /// any dissection cut, for both the graph and the geometric path.
    #[test]
    fn separator_disconnects_the_halves() {
        for seed in [1u64, 5, 9] {
            let (k, x) = cs_pattern(350, 1.5, seed);
            for pts in [None, Some(x.as_slice())] {
                let (perm, tree) = tree_and_perm(&k, pts);
                let kp = k.permute_sym(&perm);
                tree.validate(&kp).unwrap_or_else(|e| {
                    panic!("seed {seed} geometric={}: {e}", pts.is_some())
                });
                assert!(tree.depth() > 1, "n = 350 must actually dissect");
            }
        }
        // pattern-only path on a non-geometric matrix
        let a = random_sparse_spd(200, 0.03, 77);
        let (perm, tree) = tree_and_perm(&a, None);
        tree.validate(&a.permute_sym(&perm)).unwrap();
    }

    #[test]
    fn tree_ranges_tile_the_column_space() {
        let (k, x) = cs_pattern(400, 1.4, 2);
        let (_, tree) = tree_and_perm(&k, Some(&x));
        let root = &tree.nodes[0];
        assert_eq!((root.start, root.end), (0, 400));
        for (id, node) in tree.nodes.iter().enumerate() {
            if node.is_leaf() {
                assert_eq!(node.sep_start, node.start, "leaf {id} owns its whole range");
            } else {
                assert_eq!(node.children.len(), 2, "internal node {id}");
                assert!(node.sep_start <= node.end);
            }
            if node.parent != usize::MAX {
                let p = &tree.nodes[node.parent];
                assert!(p.start <= node.start && node.end <= p.sep_start);
            }
        }
    }

    /// ND's point: a wide, balanced assembly tree. On a 2-D CS pattern
    /// the widest supernode wave must fan out far beyond RCM's near-path
    /// etree.
    #[test]
    fn nd_waves_fan_out_wider_than_rcm() {
        let (k, x) = cs_pattern(800, 1.4, 6);
        let nd = super::super::order(&k, super::super::Ordering::Nd, Some(&x));
        let rcm = super::super::order(&k, super::super::Ordering::Rcm, None);
        let s_nd = Symbolic::analyze(&k.permute_sym(&nd.perm));
        let s_rcm = Symbolic::analyze(&k.permute_sym(&rcm.perm));
        let w_nd = s_nd.schedule.wave_width_max();
        let w_rcm = s_rcm.schedule.wave_width_max();
        assert!(
            w_nd > w_rcm,
            "nd max wave width {w_nd} must beat rcm {w_rcm} \
             (nd waves {}, rcm waves {})",
            s_nd.schedule.n_waves(),
            s_rcm.schedule.n_waves()
        );
    }

    #[test]
    fn disconnected_graphs_split_by_component() {
        // two far-apart clusters: the root split needs no separator
        let mut x = random_points(60, 2, 3.0, 8);
        x.extend(random_points(60, 2, 3.0, 9).into_iter().map(|mut p| {
            p[0] += 100.0;
            p
        }));
        use crate::gp::covariance::{CovFunction, CovKind};
        let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 1.5);
        let mut k = cov.cov_matrix(&x);
        for j in 0..k.n_cols {
            *k.get_mut(j, j) += 1.0;
        }
        let (perm, tree) = tree_and_perm(&k, Some(&x));
        tree.validate(&k.permute_sym(&perm)).unwrap();
        let root = &tree.nodes[0];
        assert_eq!(root.block().len(), 0, "component split has an empty separator");
    }

    #[test]
    fn small_problems_are_a_single_leaf() {
        let a = random_sparse_spd(ND_LEAF - 1, 0.2, 3);
        let (_, tree) = tree_and_perm(&a, None);
        assert_eq!(tree.n_nodes(), 1);
        assert!(tree.nodes[0].is_leaf());
    }

    #[test]
    fn geometric_and_graph_paths_both_reduce_fill() {
        let (k, x) = cs_pattern(500, 1.5, 12);
        let natural: usize = Symbolic::analyze(&k).nnz_l();
        let (pg, _) = nested_dissection(&k, Some(&x));
        let (pp, _) = nested_dissection(&k, None);
        assert!(fill_of(&k, &pg) < natural);
        assert!(fill_of(&k, &pp) < natural);
    }
}
