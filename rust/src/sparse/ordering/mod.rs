//! Fill-reducing ordering subsystem.
//!
//! The cost of every numeric stage downstream — symbolic analysis, the
//! supernodal LDLᵀ, Takahashi, Woodbury — is set here: the permutation
//! decides both the *fill* (`nnz(L)`) and the *shape* of the elimination
//! tree, hence how wide the assembly-tree waves of the parallel
//! factorization fan out. The paper uses AMD and lists an ordering
//! comparison as future work; this module provides that comparison as a
//! family of interchangeable algorithms plus a policy that picks among
//! them:
//!
//! * [`rcm`] — reverse Cuthill–McKee (bandwidth reduction). Cheap and
//!   solid on banded geometric problems, but its etrees are near-paths:
//!   almost nothing for the wave-parallel factorization to fan out on.
//! * [`mindeg`] — minimum degree on a **quotient graph** (external
//!   degrees, element absorption, supervariable merging — the AMD
//!   family). Near-linear in practice and usable at serving-scale `n`;
//!   the old clique-forming greedy survives as
//!   [`mindeg::min_degree_greedy`], the fill oracle it is tested against.
//! * [`nd`] — nested dissection: recursive vertex bisection (geometric
//!   median split when the caller has point coordinates, BFS level-set
//!   plus Fiduccia-style boundary refinement on the bare pattern graph)
//!   producing a permutation *and* an explicit [`SeparatorTree`]. ND's
//!   balanced separator hierarchy is what gives the supernodal
//!   factorization wide, balanced assembly-tree waves.
//! * [`auto`] — the [`Ordering::Auto`] policy: picks among the three from
//!   cheap pattern statistics (n, density, estimated bandwidth) and the
//!   worker-pool width. Factorization-bound callers (`Inference::Sparse`,
//!   `Parallel`, `CsFic`, `gp::regression`) default to it; the
//!   `CSGP_ORDERING` environment variable overrides its choice (the CI
//!   hook — see `testutil::forced_ordering`).
//!
//! All orderings are exact: they permute the problem, never approximate
//! it, so EP results are identical up to the permutation and the
//! bitwise-determinism contract of the parallel factorization holds under
//! every one of them. The `abl_ordering` bench compares fill, ordering
//! time, factor time and wave shape across the whole family.

use crate::sparse::csc::CscMatrix;

pub mod auto;
pub mod mindeg;
pub mod nd;
pub mod rcm;

pub use auto::{auto_select, PatternStats};
pub use mindeg::{min_degree, min_degree_greedy};
pub use nd::{nested_dissection, SepNode, SeparatorTree};
pub use rcm::rcm;

/// Which fill-reducing ordering to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Ordering {
    /// Identity permutation.
    Natural,
    /// Reverse Cuthill–McKee (bandwidth-reducing BFS).
    Rcm,
    /// Quotient-graph minimum degree (the AMD family).
    MinDegree,
    /// Nested dissection (recursive bisection + separator tree).
    Nd,
    /// Pick among the above from pattern statistics and pool width
    /// (see [`auto_select`]); `CSGP_ORDERING` overrides the choice.
    Auto,
}

/// Every name `FromStr for Ordering` accepts (canonical spelling first).
pub const ORDERING_NAMES: &[&str] =
    &["natural", "rcm", "mindeg", "min-degree", "nd", "nested-dissection", "auto"];

impl std::str::FromStr for Ordering {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "natural" => Ok(Ordering::Natural),
            "rcm" => Ok(Ordering::Rcm),
            "mindeg" | "min-degree" => Ok(Ordering::MinDegree),
            "nd" | "nested-dissection" => Ok(Ordering::Nd),
            "auto" => Ok(Ordering::Auto),
            other => Err(format!(
                "unknown ordering '{other}' (valid: {})",
                ORDERING_NAMES.join(", ")
            )),
        }
    }
}

/// The outcome of [`order`]: the permutation, the concrete method that
/// produced it (`Auto` resolved to one of the real algorithms), and —
/// for nested dissection — the separator tree, which
/// [`crate::sparse::symbolic::Symbolic`] threads through to the
/// supernodal schedule.
#[derive(Clone, Debug)]
pub struct OrderingResult {
    /// old index -> new index.
    pub perm: Vec<usize>,
    /// The algorithm that actually ran (never `Auto`).
    pub resolved: Ordering,
    /// ND's separator hierarchy, in *permuted* column coordinates.
    pub septree: Option<SeparatorTree>,
}

/// Compute a fill-reducing ordering for symmetric `a`.
///
/// `points` are the geometric coordinates of the pattern's nodes in the
/// *same index order as `a`'s columns*, when the caller has them (the
/// covariance pipeline always does — they are the training inputs the
/// `geom::NeighborIndex` was built over). Nested dissection uses them for
/// its geometric-bisection fast path; every other method ignores them.
pub fn order(a: &CscMatrix, method: Ordering, points: Option<&[Vec<f64>]>) -> OrderingResult {
    // Auto reads the *configured* pool width (CSGP_THREADS / machine
    // parallelism), not the scope-capped current width: a
    // `with_max_threads` scope must never change which structure gets
    // built, or the bitwise-at-any-width contract (and the width sweeps
    // in `perf_parallel` / `pool_width_never_changes_any_result`) would
    // silently compare different factorizations.
    let resolved = match method {
        Ordering::Auto => auto::resolve(a, crate::par::default_threads()),
        m => m,
    };
    let (perm, septree) = match resolved {
        Ordering::Natural => ((0..a.n_rows).collect(), None),
        Ordering::Rcm => (rcm(a), None),
        Ordering::MinDegree => (min_degree(a), None),
        Ordering::Nd => {
            let (perm, tree) = nested_dissection(a, points);
            (perm, Some(tree))
        }
        Ordering::Auto => unreachable!("Auto resolves to a concrete method"),
    };
    OrderingResult { perm, resolved, septree }
}

/// Compute a permutation (old index -> new index) for symmetric `a`.
/// Pattern-only entry point: nested dissection falls back to graph
/// bisection and the separator tree is dropped — callers that want the
/// geometric fast path or the tree use [`order`].
pub fn compute_ordering(a: &CscMatrix, method: Ordering) -> Vec<usize> {
    order(a, method, None).perm
}

/// Adjacency lists (excluding the diagonal) from a symmetric pattern.
pub(crate) fn adjacency(a: &CscMatrix) -> Vec<Vec<usize>> {
    let n = a.n_rows;
    let mut adj = vec![Vec::new(); n];
    for j in 0..n {
        let (rows, _) = a.col(j);
        for &i in rows {
            if i != j {
                adj[j].push(i);
            }
        }
    }
    adj
}

#[cfg(test)]
pub(crate) mod testfix {
    //! Shared fixtures for the ordering submodule tests.
    use super::*;
    use crate::sparse::symbolic::Symbolic;

    pub fn is_permutation(p: &[usize]) -> bool {
        let mut seen = vec![false; p.len()];
        for &i in p {
            if i >= p.len() || seen[i] {
                return false;
            }
            seen[i] = true;
        }
        true
    }

    /// nnz(L) of `a` under ordering `ord` (pattern-only path).
    pub fn fill_with(a: &CscMatrix, ord: Ordering) -> usize {
        fill_of(a, &compute_ordering(a, ord))
    }

    /// nnz(L) of `a` under an explicit permutation.
    pub fn fill_of(a: &CscMatrix, perm: &[usize]) -> usize {
        Symbolic::analyze(&a.permute_sym(perm)).nnz_l()
    }

    /// A compact-support covariance pattern over random 2-D points — the
    /// geometry the paper's matrices come from. Returns the SPD matrix
    /// (`K + I`) and the points (for ND's geometric path).
    pub fn cs_pattern(n: usize, ls: f64, seed: u64) -> (CscMatrix, Vec<Vec<f64>>) {
        use crate::gp::covariance::{CovFunction, CovKind};
        let side = (n as f64).sqrt() * 0.45;
        let x = crate::testutil::random_points(n, 2, side.max(4.0), seed);
        let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, ls);
        let mut k = cov.cov_matrix(&x);
        for j in 0..k.n_cols {
            *k.get_mut(j, j) += 1.0;
        }
        (k, x)
    }

    pub fn arrow(n: usize) -> CscMatrix {
        let mut t = Vec::new();
        for i in 0..n {
            t.push((i, i, 4.0));
            if i > 0 {
                t.push((0, i, 1.0));
                t.push((i, 0, 1.0));
            }
        }
        CscMatrix::from_triplets(n, n, &t)
    }
}

#[cfg(test)]
mod tests {
    use super::testfix::*;
    use super::*;
    use crate::testutil::random_sparse_spd;

    const ALL: [Ordering; 4] =
        [Ordering::Natural, Ordering::Rcm, Ordering::MinDegree, Ordering::Nd];

    #[test]
    fn orderings_are_permutations() {
        for seed in 0..4 {
            let a = random_sparse_spd(40, 0.1, seed + 500);
            for ord in ALL {
                let p = compute_ordering(&a, ord);
                assert!(is_permutation(&p), "{ord:?} seed {seed}");
            }
        }
    }

    #[test]
    fn orderings_are_deterministic() {
        // same pattern -> bit-identical permutation, across repeated runs
        // and on both random-SPD and CS-geometry patterns
        for seed in 0..3 {
            let a = random_sparse_spd(60, 0.08, seed + 40);
            let (k, x) = cs_pattern(150, 1.5, seed);
            for ord in ALL {
                assert_eq!(
                    compute_ordering(&a, ord),
                    compute_ordering(&a, ord),
                    "{ord:?} seed {seed} (spd)"
                );
                let r1 = order(&k, ord, Some(&x));
                let r2 = order(&k, ord, Some(&x));
                assert_eq!(r1.perm, r2.perm, "{ord:?} seed {seed} (cs)");
            }
        }
    }

    #[test]
    fn from_str_roundtrip_and_error_lists_all_names() {
        for name in ORDERING_NAMES {
            let ord: Ordering = name.parse().unwrap_or_else(|e| panic!("{name}: {e}"));
            let _ = ord;
        }
        assert_eq!("nd".parse::<Ordering>(), Ok(Ordering::Nd));
        assert_eq!("auto".parse::<Ordering>(), Ok(Ordering::Auto));
        let err = "bogus".parse::<Ordering>().unwrap_err();
        for name in ORDERING_NAMES {
            assert!(err.contains(name), "error must list '{name}': {err}");
        }
    }

    #[test]
    fn arrow_matrix_reordering_kills_fill() {
        // arrow pointing the wrong way: natural ordering gives full fill,
        // the fill-reducing methods should order the hub last (exactly so
        // for min-degree; ND puts the hub in the root separator).
        let n = 30;
        let a = arrow(n);
        let natural = fill_with(&a, Ordering::Natural);
        let rcm_fill = fill_with(&a, Ordering::Rcm);
        let md_fill = fill_with(&a, Ordering::MinDegree);
        let nd_fill = fill_with(&a, Ordering::Nd);
        assert_eq!(natural, n * (n + 1) / 2); // dense
        assert!(rcm_fill < natural / 2, "rcm {rcm_fill} vs natural {natural}");
        assert_eq!(md_fill, 2 * n - 1, "min-degree should give no fill");
        assert_eq!(nd_fill, 2 * n - 1, "nd should give no fill on a star");
    }

    #[test]
    fn reordering_reduces_fill_on_geometric_like_matrices() {
        let a = random_sparse_spd(60, 0.07, 77);
        let natural = fill_with(&a, Ordering::Natural);
        let best = fill_with(&a, Ordering::MinDegree);
        assert!(best <= natural, "min-degree {best} vs natural {natural}");
    }

    #[test]
    fn fill_comparison_on_cs_geometry() {
        // the paper's workload: 2-D compact-support patterns. Both real
        // fill reducers must beat natural by a wide margin, and ND must be
        // in the same league as min-degree (its fill optimality class).
        for seed in [3u64, 9] {
            let (k, x) = cs_pattern(400, 1.6, seed);
            let natural = fill_with(&k, Ordering::Natural);
            let rcm_fill = fill_with(&k, Ordering::Rcm);
            let md_fill = fill_with(&k, Ordering::MinDegree);
            let nd_graph = fill_with(&k, Ordering::Nd);
            let nd_geom = fill_of(&k, &order(&k, Ordering::Nd, Some(&x)).perm);
            assert!(md_fill < natural, "seed {seed}: md {md_fill} vs natural {natural}");
            assert!(rcm_fill < natural, "seed {seed}: rcm {rcm_fill} vs natural {natural}");
            let best = md_fill.min(rcm_fill);
            for (name, f) in [("nd/graph", nd_graph), ("nd/geom", nd_geom)] {
                assert!(
                    f <= natural && f <= best * 2,
                    "seed {seed}: {name} fill {f} vs best {best}, natural {natural}"
                );
            }
        }
    }
}
