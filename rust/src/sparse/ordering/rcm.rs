//! Reverse Cuthill–McKee: bandwidth-reducing BFS ordering.
//!
//! RCM concentrates the pattern near the diagonal, which keeps fill low
//! on banded geometric problems at trivial cost (`O(n + nnz)`). Its
//! weakness is etree *shape*: a banded matrix eliminates like a path, so
//! the assembly-tree waves of the parallel factorization are near-width-1
//! — which is why the [`super::auto`] policy only picks RCM when the
//! pattern is small or already nearly banded.

use crate::sparse::csc::CscMatrix;

/// BFS from `start`; returns the visit order. With `by_degree`, each
/// node's unvisited neighbors are enqueued in ascending-degree order (the
/// Cuthill–McKee rule).
fn bfs(adj: &[Vec<usize>], start: usize, visited: &mut [bool], by_degree: bool) -> Vec<usize> {
    let mut order = vec![start];
    visited[start] = true;
    let mut head = 0;
    while head < order.len() {
        let u = order[head];
        head += 1;
        let mut nbrs: Vec<usize> = adj[u].iter().copied().filter(|&v| !visited[v]).collect();
        if by_degree {
            nbrs.sort_by_key(|&v| adj[v].len());
        }
        for v in nbrs {
            if !visited[v] {
                visited[v] = true;
                order.push(v);
            }
        }
    }
    order
}

/// Reverse Cuthill–McKee. Handles disconnected graphs; each component is
/// started from a pseudo-peripheral node (double-BFS heuristic).
pub fn rcm(a: &CscMatrix) -> Vec<usize> {
    let n = a.n_rows;
    let adj = super::adjacency(a);
    let mut visited = vec![false; n];
    let mut order = Vec::with_capacity(n);
    for seed in 0..n {
        if visited[seed] {
            continue;
        }
        // pseudo-peripheral: BFS from seed, restart from the last node found
        let mut scratch = visited.clone();
        let pass1 = bfs(&adj, seed, &mut scratch, false);
        let start = *pass1.last().unwrap();
        let comp = bfs(&adj, start, &mut visited, true);
        order.extend(comp);
    }
    // order[k] = old index of the k'th visited node; reverse for RCM
    order.reverse();
    let mut perm = vec![0usize; n];
    for (new, &old) in order.iter().enumerate() {
        perm[old] = new;
    }
    perm
}

#[cfg(test)]
mod tests {
    use super::super::testfix::is_permutation;
    use super::*;

    #[test]
    fn rcm_handles_disconnected() {
        // two disjoint triangles
        let mut t = Vec::new();
        for base in [0usize, 3] {
            for i in 0..3 {
                t.push((base + i, base + i, 2.0));
                for j in 0..i {
                    t.push((base + i, base + j, 1.0));
                    t.push((base + j, base + i, 1.0));
                }
            }
        }
        let a = CscMatrix::from_triplets(6, 6, &t);
        let p = rcm(&a);
        assert!(is_permutation(&p));
    }
}
