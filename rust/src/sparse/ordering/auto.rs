//! The `Ordering::Auto` policy: pick a concrete ordering from cheap
//! pattern statistics and the worker-pool width.
//!
//! Decision table (see `docs/ARCHITECTURE.md` §Ordering layer for the
//! rationale):
//!
//! | condition (first match wins)        | choice      | why |
//! |---|---|---|
//! | `n <= 400`                          | `Rcm`       | structure cost is noise at this size; RCM is the cheapest real reducer |
//! | `density >= 0.25`                   | `Natural`   | near-dense pattern: no ordering can reduce fill enough to repay itself |
//! | pool width 1, nearly banded         | `Rcm`       | serial factorization + banded graph: RCM is near-optimal fill at `O(n + nnz)` |
//! | pool width 1                        | `MinDegree` | fill is the only cost; the quotient-graph method minimizes it |
//! | otherwise                           | `Nd`        | the parallel factorization needs ND's wide, balanced assembly-tree waves |
//!
//! "Nearly banded" means the pattern's mean `|i − j|` is within a small
//! multiple of its average degree — i.e. the natural order is already
//! close to a band, so bandwidth reduction finishes the job.
//!
//! The `CSGP_ORDERING` environment variable overrides the policy's
//! choice (any name `FromStr for Ordering` accepts except `auto`;
//! unrecognized values are ignored). That is the CI hook: the suite runs
//! once with `CSGP_ORDERING=nd` so every Auto-defaulted pipeline —
//! regression, CS+FIC, the model-level defaults — exercises the
//! nested-dissection path end to end. Explicitly requested orderings are
//! never overridden. `testutil::forced_ordering` exposes the hook to
//! tests.

use crate::sparse::csc::CscMatrix;
use crate::sparse::ordering::Ordering;

/// Below this `n` the policy always answers RCM.
pub const AUTO_SMALL_N: usize = 400;

/// At or above this off-diagonal density the policy answers Natural.
pub const AUTO_DENSE: f64 = 0.25;

/// "Nearly banded": mean `|i − j|` within this multiple of the average
/// degree.
pub const AUTO_BAND_FACTOR: f64 = 2.0;

/// Cheap `O(nnz)` statistics of a symmetric pattern — everything the
/// auto policy looks at, exposed so benches and tests can print/probe
/// the decision inputs.
#[derive(Clone, Copy, Debug)]
pub struct PatternStats {
    pub n: usize,
    /// Off-diagonal nonzeros (full symmetric storage, both triangles).
    pub nnz_offdiag: usize,
    /// Mean off-diagonal entries per column.
    pub avg_degree: f64,
    /// Off-diagonal density in [0, 1].
    pub density: f64,
    /// Mean `|i − j|` over the off-diagonal entries — the bandwidth the
    /// *natural* order already has. Small relative to the degree means
    /// the pattern is essentially banded as given.
    pub bandwidth_est: f64,
}

impl PatternStats {
    pub fn of(a: &CscMatrix) -> PatternStats {
        let n = a.n_rows;
        let mut nnz_offdiag = 0usize;
        let mut band_sum = 0.0f64;
        for j in 0..n {
            let (rows, _) = a.col(j);
            for &i in rows {
                if i != j {
                    nnz_offdiag += 1;
                    band_sum += (i as f64 - j as f64).abs();
                }
            }
        }
        let nf = n as f64;
        PatternStats {
            n,
            nnz_offdiag,
            avg_degree: if n > 0 { nnz_offdiag as f64 / nf } else { 0.0 },
            density: if n > 1 { nnz_offdiag as f64 / (nf * (nf - 1.0)) } else { 0.0 },
            bandwidth_est: if nnz_offdiag > 0 { band_sum / nnz_offdiag as f64 } else { 0.0 },
        }
    }
}

/// The policy proper: a pure function of the statistics and the pool
/// width, so it is unit-testable without touching the environment.
/// Never returns `Auto`.
pub fn auto_select(stats: &PatternStats, threads: usize) -> Ordering {
    if stats.n <= AUTO_SMALL_N {
        Ordering::Rcm
    } else if stats.density >= AUTO_DENSE {
        Ordering::Natural
    } else if threads <= 1 {
        if stats.bandwidth_est <= AUTO_BAND_FACTOR * stats.avg_degree.max(1.0) {
            Ordering::Rcm
        } else {
            Ordering::MinDegree
        }
    } else {
        Ordering::Nd
    }
}

/// Parse a raw `CSGP_ORDERING` value into the ordering it forces:
/// `None` for unset, `auto`, or unrecognized values. The single parsing
/// rule shared by [`resolve_with`] and `testutil::forced_ordering`.
pub fn parse_override(env: Option<&str>) -> Option<Ordering> {
    env.and_then(|s| s.parse::<Ordering>().ok()).filter(|&o| o != Ordering::Auto)
}

/// [`auto_select`] with the `CSGP_ORDERING` override applied first;
/// `env` is the raw variable value. Split out so tests can drive the
/// override without mutating process-wide state.
pub fn resolve_with(env: Option<&str>, stats: &PatternStats, threads: usize) -> Ordering {
    if let Some(forced) = parse_override(env) {
        return forced;
    }
    auto_select(stats, threads)
}

/// Resolve `Ordering::Auto` for `a` at the configured pool width,
/// honoring `CSGP_ORDERING`. The env check runs before the `O(nnz)`
/// statistics scan so a forced ordering skips it entirely.
pub(crate) fn resolve(a: &CscMatrix, threads: usize) -> Ordering {
    if let Some(forced) = parse_override(std::env::var("CSGP_ORDERING").ok().as_deref()) {
        return forced;
    }
    auto_select(&PatternStats::of(a), threads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::random_sparse_spd;

    fn stats(n: usize, density: f64, bandwidth_est: f64) -> PatternStats {
        let avg = density * (n as f64 - 1.0);
        PatternStats {
            n,
            nnz_offdiag: (avg * n as f64) as usize,
            avg_degree: avg,
            density,
            bandwidth_est,
        }
    }

    #[test]
    fn decision_table() {
        // small -> RCM regardless of anything else
        assert_eq!(auto_select(&stats(200, 0.5, 100.0), 8), Ordering::Rcm);
        // near-dense -> Natural
        assert_eq!(auto_select(&stats(2000, 0.4, 500.0), 8), Ordering::Natural);
        // serial + scattered pattern -> MinDegree
        assert_eq!(auto_select(&stats(2000, 0.01, 700.0), 1), Ordering::MinDegree);
        // serial + already banded -> RCM (mean |i-j| ~ degree)
        assert_eq!(auto_select(&stats(2000, 0.005, 12.0), 1), Ordering::Rcm);
        // parallel + sparse -> ND
        assert_eq!(auto_select(&stats(2000, 0.01, 700.0), 8), Ordering::Nd);
    }

    #[test]
    fn stats_of_matches_the_pattern() {
        let a = random_sparse_spd(50, 0.1, 3);
        let s = PatternStats::of(&a);
        assert_eq!(s.n, 50);
        assert_eq!(s.nnz_offdiag, a.nnz() - 50);
        assert!(s.density > 0.0 && s.density < 1.0);
        assert!(s.bandwidth_est > 0.0);
    }

    #[test]
    fn env_override_wins_except_auto_and_garbage() {
        let big = stats(5000, 0.01, 900.0);
        assert_eq!(resolve_with(Some("nd"), &big, 1), Ordering::Nd);
        assert_eq!(resolve_with(Some("rcm"), &big, 8), Ordering::Rcm);
        assert_eq!(resolve_with(Some("mindeg"), &big, 8), Ordering::MinDegree);
        // "auto" and unparsable values fall through to the policy
        assert_eq!(resolve_with(Some("auto"), &big, 8), Ordering::Nd);
        assert_eq!(resolve_with(Some("bogus"), &big, 8), Ordering::Nd);
        assert_eq!(resolve_with(None, &big, 8), Ordering::Nd);
    }

    /// End to end: Auto through [`super::super::order`] resolves to a
    /// concrete method and never returns `Auto` itself. (We do not pin
    /// *which* one — the process-wide `CSGP_ORDERING` CI hook and the
    /// host's pool width legitimately change it.)
    #[test]
    fn order_resolves_auto_to_a_concrete_method() {
        let a = random_sparse_spd(60, 0.1, 9);
        let res = super::super::order(&a, Ordering::Auto, None);
        assert_ne!(res.resolved, Ordering::Auto);
        assert!(super::super::testfix::is_permutation(&res.perm));
    }
}
