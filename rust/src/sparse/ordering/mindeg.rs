//! Minimum degree on a quotient graph (the AMD family).
//!
//! The textbook greedy min-degree forms the clique of the pivot's
//! neighbors explicitly on every elimination — `O(clique²)` edge inserts
//! per pivot, quadratic-plus on the fill a real factorization produces,
//! which is why the seed's implementation was unusable beyond toy `n`.
//! [`min_degree`] instead maintains George & Liu's **quotient graph**
//! (Amestoy–Davis–Duff's data structure): an eliminated pivot becomes an
//! *element* that represents its clique implicitly by member list, the
//! pivot's adjacent elements are *absorbed* (their members are a subset
//! of the new element's), and variables that become indistinguishable are
//! merged into weighted **supervariables** and eliminated together.
//! Pivots are chosen by **external degree** — the total weight of a
//! supervariable's distinct neighbors through both variable and element
//! adjacencies, excluding the supervariable itself — with ties broken by
//! smallest index, so the ordering is a pure function of the pattern.
//! Storage never exceeds the input pattern plus member lists, and the
//! per-pivot work is proportional to the adjacency actually touched, so
//! the method stays usable at serving-scale `n` (the `abl_ordering`
//! bench tracks ordering time next to the fill).
//!
//! The old greedy survives as [`min_degree_greedy`]: it is the fill
//! oracle the quotient-graph implementation is tested against (same
//! degree rule, so fill must stay within a few percent — see
//! `quotient_fill_matches_greedy_oracle`).

use crate::sparse::csc::CscMatrix;

/// Resolve a (possibly merged) variable to its supervariable
/// representative, with path compression.
fn resolve(merged_into: &mut [usize], v: usize) -> usize {
    let mut root = v;
    while merged_into[root] != usize::MAX {
        root = merged_into[root];
    }
    let mut v = v;
    while merged_into[v] != usize::MAX {
        let next = merged_into[v];
        merged_into[v] = root;
        v = next;
    }
    root
}

/// Quotient-graph minimum degree: returns the permutation
/// (old index -> new index) for symmetric `a`.
pub fn min_degree(a: &CscMatrix) -> Vec<usize> {
    let n = a.n_rows;
    if n == 0 {
        return Vec::new();
    }
    // Variable-variable adjacency (reps; purged lazily), element
    // adjacency per variable, and member lists per element. An index is a
    // variable until eliminated (then it names the element it produced)
    // or merged (then `merged_into` points at its supervariable).
    let mut adj: Vec<Vec<usize>> = super::adjacency(a);
    let mut elems: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut elem_vars: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut weight = vec![1usize; n];
    let mut merged_into = vec![usize::MAX; n];
    let mut members: Vec<Vec<usize>> = (0..n).map(|i| vec![i]).collect();
    let mut eliminated = vec![false; n];
    let mut absorbed = vec![false; n];
    let mut deg: Vec<usize> = adj.iter().map(|l| l.len()).collect();

    // Degree buckets with lazy deletion: entries are (re-)pushed on every
    // degree change; stale ones are filtered at pop time. External degree
    // is < n, so n buckets suffice.
    let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); n];
    for i in 0..n {
        buckets[deg[i]].push(i);
    }
    let mut min_deg = 0usize;

    let mut mark = vec![0usize; n];
    let mut stamp = 0usize;
    let mut in_lp = vec![false; n];
    // Per-round compression tag so each element's member list is
    // compacted at most once per pivot.
    let mut elem_round = vec![0usize; n];

    let mut perm = vec![0usize; n];
    let mut pos = 0usize;
    let mut round = 0usize;
    let mut lp: Vec<usize> = Vec::new();

    while pos < n {
        round += 1;
        // ---- pick the pivot: minimum (external degree, index) ----------
        let p = loop {
            while buckets[min_deg].is_empty() {
                min_deg += 1;
            }
            let mut best: Option<usize> = None;
            buckets[min_deg].retain(|&i| {
                let live =
                    !eliminated[i] && merged_into[i] == usize::MAX && deg[i] == min_deg;
                if live {
                    best = Some(best.map_or(i, |b| b.min(i)));
                }
                live
            });
            match best {
                Some(p) => break p,
                None => continue,
            }
        };

        // ---- Lp: the pivot's live neighborhood (the new element) -------
        stamp += 1;
        lp.clear();
        mark[p] = stamp;
        for k in 0..adj[p].len() {
            let r = resolve(&mut merged_into, adj[p][k]);
            if !eliminated[r] && mark[r] != stamp {
                mark[r] = stamp;
                lp.push(r);
            }
        }
        let p_elems = std::mem::take(&mut elems[p]);
        for &e in &p_elems {
            if absorbed[e] {
                continue;
            }
            for k in 0..elem_vars[e].len() {
                let r = resolve(&mut merged_into, elem_vars[e][k]);
                if !eliminated[r] && mark[r] != stamp {
                    mark[r] = stamp;
                    lp.push(r);
                }
            }
            // e's live members are a subset of Lp ∪ {p}: absorbed.
            absorbed[e] = true;
        }
        lp.sort_unstable();

        eliminated[p] = true;
        elem_vars[p] = lp.clone();
        members[p].sort_unstable();
        for &m in &members[p] {
            perm[m] = pos;
            pos += 1;
        }
        members[p] = Vec::new();

        // ---- purge each neighbor's lists ------------------------------
        // Variable adjacency inside Lp is now represented by element p
        // (quotient-graph compression); merged/eliminated leftovers are
        // dropped at the same time.
        for &i in &lp {
            in_lp[i] = true;
        }
        for &i in &lp {
            let old = std::mem::take(&mut adj[i]);
            let mut cleaned: Vec<usize> = old
                .into_iter()
                .map(|v| resolve(&mut merged_into, v))
                .filter(|&r| !eliminated[r] && r != i && !in_lp[r])
                .collect();
            cleaned.sort_unstable();
            cleaned.dedup();
            adj[i] = cleaned;

            let mut el = std::mem::take(&mut elems[i]);
            el.retain(|&e| !absorbed[e]);
            el.push(p);
            el.sort_unstable();
            el.dedup();
            elems[i] = el;
        }

        // ---- external degrees of the touched variables ----------------
        for &i in &lp {
            stamp += 1;
            mark[i] = stamp; // exclude the supervariable itself
            let mut d = 0usize;
            for &v in &adj[i] {
                if mark[v] != stamp {
                    mark[v] = stamp;
                    d += weight[v];
                }
            }
            for k in 0..elems[i].len() {
                let e = elems[i][k];
                if elem_round[e] != round {
                    // compact e's member list once per round
                    elem_round[e] = round;
                    let old = std::mem::take(&mut elem_vars[e]);
                    let mut ev: Vec<usize> = old
                        .into_iter()
                        .map(|v| resolve(&mut merged_into, v))
                        .filter(|&r| !eliminated[r])
                        .collect();
                    ev.sort_unstable();
                    ev.dedup();
                    elem_vars[e] = ev;
                }
                for &r in &elem_vars[e] {
                    if mark[r] != stamp {
                        mark[r] = stamp;
                        d += weight[r];
                    }
                }
            }
            deg[i] = d;
            buckets[d].push(i);
            min_deg = min_deg.min(d);
        }

        // ---- supervariable merging ------------------------------------
        // Two touched variables with identical (cleaned, sorted) variable
        // and element adjacency are indistinguishable: they will be
        // eliminated consecutively with identical patterns, so fold one
        // into the other and update weights/degrees instead of tracking
        // both. Hash by list checksums, confirm by comparison, merge the
        // larger index into the smaller.
        let mut keyed: Vec<(u64, usize)> = lp
            .iter()
            .filter(|&&i| merged_into[i] == usize::MAX)
            .map(|&i| {
                let mut h = 0u64;
                for &v in &adj[i] {
                    h = h.wrapping_mul(0x9e37_79b9_7f4a_7c15).wrapping_add(v as u64 + 1);
                }
                for &e in &elems[i] {
                    h = h.wrapping_mul(0x85eb_ca6b_31ce_4b2f).wrapping_add(e as u64 + 1);
                }
                (h, i)
            })
            .collect();
        keyed.sort_unstable();
        for g in 0..keyed.len() {
            let (hg, i) = keyed[g];
            if merged_into[i] != usize::MAX {
                continue;
            }
            for &(_, j) in keyed[g + 1..].iter().take_while(|&&(hj, _)| hj == hg) {
                if merged_into[j] != usize::MAX || adj[i] != adj[j] || elems[i] != elems[j] {
                    continue;
                }
                merged_into[j] = i;
                weight[i] += weight[j];
                // external degree excludes the supervariable's own weight
                deg[i] -= weight[j];
                let mj = std::mem::take(&mut members[j]);
                members[i].extend(mj);
                buckets[deg[i]].push(i);
                min_deg = min_deg.min(deg[i]);
            }
        }

        for &i in &lp {
            in_lp[i] = false;
        }
    }
    perm
}

/// Greedy minimum-degree with explicit clique formation on elimination —
/// the seed implementation, kept as the fill oracle for the
/// quotient-graph method. Quadratic-ish; only for tests/ablations at
/// moderate `n`.
pub fn min_degree_greedy(a: &CscMatrix) -> Vec<usize> {
    let n = a.n_rows;
    let mut adj: Vec<std::collections::BTreeSet<usize>> =
        super::adjacency(a).into_iter().map(|v| v.into_iter().collect()).collect();
    let mut eliminated = vec![false; n];
    let mut perm = vec![0usize; n];
    for step in 0..n {
        // pick min-degree uneliminated node (ties: smallest index)
        let v = (0..n)
            .filter(|&v| !eliminated[v])
            .min_by_key(|&v| (adj[v].len(), v))
            .unwrap();
        perm[v] = step;
        eliminated[v] = true;
        let nbrs: Vec<usize> = adj[v].iter().copied().filter(|&u| !eliminated[u]).collect();
        // form the clique of v's neighbours
        for (ai, &u) in nbrs.iter().enumerate() {
            adj[u].remove(&v);
            for &w in &nbrs[ai + 1..] {
                adj[u].insert(w);
                adj[w].insert(u);
            }
        }
        adj[v].clear();
    }
    perm
}

#[cfg(test)]
mod tests {
    use super::super::testfix::{arrow, cs_pattern, fill_of, is_permutation};
    use super::*;
    use crate::testutil::random_sparse_spd;

    #[test]
    fn quotient_is_a_permutation_on_many_patterns() {
        for seed in 0..6 {
            let a = random_sparse_spd(50, 0.05 + 0.03 * seed as f64, seed + 90);
            assert!(is_permutation(&min_degree(&a)), "seed {seed}");
        }
        let (k, _) = cs_pattern(300, 1.5, 4);
        assert!(is_permutation(&min_degree(&k)));
    }

    #[test]
    fn quotient_handles_degenerate_patterns() {
        // diagonal-only (every degree 0), fully dense, and n = 0 / n = 1
        let d = CscMatrix::identity(5);
        assert!(is_permutation(&min_degree(&d)));
        let mut t = Vec::new();
        for i in 0..6 {
            for j in 0..6 {
                t.push((i, j, 1.0));
            }
        }
        let dense = CscMatrix::from_triplets(6, 6, &t);
        assert!(is_permutation(&min_degree(&dense)));
        assert!(min_degree(&CscMatrix::identity(1)).len() == 1);
        assert!(min_degree(&CscMatrix::from_triplets(0, 0, &[])).is_empty());
    }

    #[test]
    fn quotient_orders_the_arrow_hub_last() {
        let n = 25;
        let a = arrow(n);
        let perm = min_degree(&a);
        assert_eq!(perm[0], n - 1, "the hub must be eliminated last");
        assert_eq!(fill_of(&a, &perm), 2 * n - 1, "no fill on a star");
    }

    /// The quotient-graph method must track the greedy oracle's fill:
    /// same degree rule, different bookkeeping. The issue gate is 10%;
    /// assert it across random-SPD and CS-geometry fixtures.
    #[test]
    fn quotient_fill_matches_greedy_oracle() {
        let mut cases: Vec<CscMatrix> = (0..4)
            .map(|seed| random_sparse_spd(40, 0.1, seed + 500))
            .collect();
        cases.push(random_sparse_spd(80, 0.06, 11));
        cases.push(cs_pattern(250, 1.5, 7).0);
        for (c, a) in cases.iter().enumerate() {
            let f_q = fill_of(a, &min_degree(a));
            let f_g = fill_of(a, &min_degree_greedy(a));
            assert!(
                (f_q as f64) <= 1.10 * f_g as f64,
                "case {c}: quotient fill {f_q} vs greedy {f_g}"
            );
        }
    }

    /// Not quadratic any more: a banded-plus-random pattern at n large
    /// enough that the greedy's clique formation used to blow up. This is
    /// a smoke bound (generous wall-clock), not a benchmark — the
    /// `abl_ordering` bench measures real times.
    #[test]
    fn quotient_scales_past_the_greedy() {
        let (k, _) = cs_pattern(2000, 1.3, 2);
        let t0 = std::time::Instant::now();
        let perm = min_degree(&k);
        let dt = t0.elapsed();
        assert!(is_permutation(&perm));
        assert!(dt < std::time::Duration::from_secs(5), "min_degree took {dt:?}");
    }
}
