//! From-scratch sparse linear algebra substrate.
//!
//! This is the machinery the paper's sparse EP rests on (Davis, *Direct
//! Methods for Sparse Linear Systems*, 2006; Davis & Hager 2005;
//! Takahashi et al. 1973):
//!
//! * [`csc`] — compressed-sparse-column matrices (full symmetric storage).
//! * [`dense`] — dense matrix + Cholesky oracle used by the dense-EP
//!   baseline and by tests.
//! * [`etree`] — elimination tree, postorder, depth/height level waves
//!   (the parallel schedules of the Takahashi inverse and the numeric
//!   factorization).
//! * [`ordering`] — the fill-reducing ordering subsystem: RCM,
//!   quotient-graph minimum degree, nested dissection with separator
//!   trees, and the `Auto` policy that picks among them from pattern
//!   statistics and pool width.
//! * [`symbolic`] — static symbolic Cholesky analysis (pattern incl. fill,
//!   row-structure map used by the row-modification kernel, supernode
//!   partition + assembly-tree wave schedule, the threaded-through
//!   separator tree of a nested-dissection ordering).
//! * [`cholesky`] — numeric LDLᵀ on the static pattern: supernodal
//!   wave-parallel kernel (default) plus the serial up-looking oracle.
//! * [`triangular`] — dense- and sparse-RHS triangular solves.
//! * [`update`] — rank-one update/downdate (Method C) on the static pattern.
//! * [`rowmod`] — `ldlrowmodify`, the paper's Algorithm 2.
//! * [`takahashi`] — sparsified inverse on the factor pattern (paper eq. 11).
//! * [`lowrank`] — Woodbury solver for `B = S + U Uᵀ` (sparse plus
//!   low-rank, the CS+FIC hybrid prior's structure).

pub mod cholesky;
pub mod csc;
pub mod dense;
pub mod etree;
pub mod lowrank;
pub mod ordering;
pub mod rowmod;
pub mod symbolic;
pub mod takahashi;
pub mod triangular;
pub mod update;

pub use cholesky::LdlFactor;
pub use csc::CscMatrix;
pub use dense::DenseMatrix;
pub use lowrank::SparseLowRank;
pub use symbolic::Symbolic;
