//! Numeric LDLᵀ factorization on a static symbolic pattern.
//!
//! Because the EP algorithm keeps the pattern of `B` fixed, the factor is
//! allocated once from [`Symbolic`] and re-factored / row-modified in
//! place. Two numeric kernels share that storage:
//!
//! * [`LdlFactor::refactor`] — the default path: a supernode-aware,
//!   elimination-tree-wave-scheduled factorization that fans out over the
//!   [`crate::par`] worker pool. The [`Symbolic`]'s cached
//!   [`SupernodeSchedule`](crate::sparse::symbolic::SupernodeSchedule)
//!   supplies the tasks (supernodes — column runs with suffix-nested
//!   patterns) and the barriers (assembly-tree height waves, leaves
//!   first). Column j of L depends only on columns in j's etree subtree,
//!   so every task's inputs are finished strictly before its wave starts.
//! * [`LdlFactor::refactor_uplooking`] — the original serial up-looking
//!   algorithm (Davis's LDL package: row k of L solves a sparse
//!   triangular system over the etree reach of `A(0..k, k)`), kept as the
//!   independent comparison oracle for the parallel kernel.
//!
//! # Determinism
//!
//! Across waves the schedule is that of a right-looking/multifrontal
//! factorization (a supernode is eliminated before anything that depends
//! on it), but the per-entry arithmetic *pulls*: each column j gathers its
//! updates from the finished source columns of `row_pattern(j)` in
//! ascending column order, accumulating into a dense per-participant
//! scratch column. Summation order is therefore a pure function of the
//! pattern — never of chunk boundaries or thread interleaving — which
//! makes the factor bitwise-identical at any `CSGP_THREADS` width, the
//! invariant the EP determinism contract (README "Parallelism") rests on.
//! Width 1 runs the same per-column code inline, so the serial path *is*
//! the parallel path. Multi-column supernodes run a dense-panel
//! micro-kernel instead of the scalar per-column pull (see
//! `factor_supernode_blocked` — same source order, contiguous
//! arithmetic); the kernel *choice* depends only on the supernode's
//! shape, never on the pool, so it cannot perturb width invariance.
//!
//! Cost: identical flop count to the up-looking kernel (`Σⱼ |pat(j)|²`
//! over the fill pattern); the wave barriers add `O(n_waves)` pool
//! dispatches, amortized by running small waves inline on the caller.
//!
//! # Pivot recovery
//!
//! [`LdlFactor::refactor`] keeps its fail-fast contract (EP's row
//! modification relies on a failed refactor being reported, not papered
//! over). Callers that want to *survive* a lost pivot use
//! [`LdlFactor::refactor_with_recovery`]: a clean attempt first, then
//! retries with escalating diagonal jitter per [`JitterPolicy`]
//! (`initial_rel · mean|diag|`, doubling each retry up to the budget).
//! The retry decision is made after the wave join — the parallel kernel
//! has already agreed on the smallest failing column — so the retry
//! count, the final jitter and the recovered factor bits are identical
//! at every `CSGP_THREADS` width. The applied jitter is recorded on the
//! factor ([`LdlFactor::jitter`]), in
//! `obs::counters::FACTOR_JITTER_RETRIES` and on a `factor.recover`
//! span.

use std::sync::atomic::{AtomicUsize, Ordering as AtomicOrdering};
use std::sync::Arc;

use crate::par::SyncSlice;
use crate::sparse::csc::CscMatrix;
use crate::sparse::etree::ereach;
use crate::sparse::symbolic::Symbolic;

/// Waves with fewer supernodes than this run inline on the caller — the
/// path-like top of a typical etree gains nothing from the pool and would
/// pay a broadcast per level.
const PAR_WAVE_MIN: usize = 8;

/// Supernodes per chunk when a wave does fan out, scaled with the wave
/// width: narrow waves take singleton chunks so work stealing can balance
/// the skewed interior supernodes, wide leaf waves take coarse chunks so
/// the chunk-cursor traffic stays off the critical path.
fn snode_chunk(wave_len: usize) -> usize {
    (wave_len / 32).clamp(1, 8)
}

/// LDLᵀ factor: unit lower-triangular `L` (strict lower part stored on the
/// symbolic pattern) and diagonal `D`.
///
/// The symbolic analysis is paid once; every subsequent sweep re-fills the
/// same storage:
///
/// ```
/// use std::sync::Arc;
/// use csgp::sparse::{CscMatrix, LdlFactor, Symbolic};
///
/// // B = [[4, 2, 0], [2, 5, 2], [0, 2, 6]], full symmetric storage
/// let b = CscMatrix::from_triplets(3, 3, &[
///     (0, 0, 4.0), (1, 0, 2.0), (0, 1, 2.0),
///     (1, 1, 5.0), (2, 1, 2.0), (1, 2, 2.0), (2, 2, 6.0),
/// ]);
/// let sym = Arc::new(Symbolic::analyze(&b)); // pattern + schedule, once
/// let mut f = LdlFactor::factor(sym, &b).unwrap();
/// assert!((f.logdet() - 80f64.ln()).abs() < 1e-12); // det B = 80
///
/// // new values on the same pattern: refactor in place, no re-analysis
/// let mut b2 = b.clone();
/// *b2.get_mut(2, 2) += 1.0;
/// f.refactor(&b2).unwrap();
/// assert!((f.logdet() - 96f64.ln()).abs() < 1e-12);
/// ```
#[derive(Clone, Debug)]
pub struct LdlFactor {
    pub symbolic: Arc<Symbolic>,
    /// Values aligned with `symbolic.row_idx` (strictly lower triangle).
    pub l: Vec<f64>,
    /// Diagonal of D.
    pub d: Vec<f64>,
    /// Diagonal jitter the last (re)factorization added to stay positive
    /// definite: 0.0 on every clean factor, the absolute shift applied by
    /// [`LdlFactor::refactor_with_recovery`] after a pivot recovery.
    pub jitter: f64,
}

/// Escalating-jitter schedule for [`LdlFactor::refactor_with_recovery`]:
/// retry `r` (1-based) adds `initial_rel · growth^(r-1) · mean|diag(A)|`
/// to the diagonal. The defaults walk 1e-10 → ~5e-2 (relative) over 30
/// doublings — enough to absorb EP's near-semidefinite failures, small
/// enough that a genuinely indefinite matrix still errors out.
#[derive(Clone, Copy, Debug)]
pub struct JitterPolicy {
    /// First retry's jitter, relative to `mean|diag(A)|`.
    pub initial_rel: f64,
    /// Multiplier between consecutive retries.
    pub growth: f64,
    /// Retry budget; after this many jittered attempts the original
    /// failure is reported.
    pub max_retries: usize,
}

impl Default for JitterPolicy {
    fn default() -> Self {
        JitterPolicy { initial_rel: 1e-10, growth: 2.0, max_retries: 30 }
    }
}

impl LdlFactor {
    /// Factor symmetric positive-definite `a` (full storage). The pattern
    /// of `a` must match the pattern `symbolic` was analysed from (entries
    /// of `a` outside it will panic in debug, give wrong results in
    /// release — callers always pass the analysed matrix).
    pub fn factor(symbolic: Arc<Symbolic>, a: &CscMatrix) -> Result<LdlFactor, String> {
        let (n, nnz) = (symbolic.n, symbolic.row_idx.len());
        let mut f = LdlFactor { symbolic, l: vec![0.0; nnz], d: vec![0.0; n], jitter: 0.0 };
        f.refactor(a)?;
        Ok(f)
    }

    /// Identity factor (L = I, D = I); the state of `B = I` before any EP
    /// site has been updated.
    pub fn identity(symbolic: Arc<Symbolic>) -> LdlFactor {
        let n = symbolic.n;
        let nnz = symbolic.row_idx.len();
        LdlFactor { symbolic, l: vec![0.0; nnz], d: vec![1.0; n], jitter: 0.0 }
    }

    pub fn n(&self) -> usize {
        self.symbolic.n
    }

    /// Embed an existing factor of `B_old` (n_old × n_old) into a larger
    /// analysis whose leading n_old columns/rows describe the same matrix
    /// — the online-update fast path. Appended EP sites start at τ̃ = 0,
    /// so the extended `B = I + S̃^{1/2} K S̃^{1/2}` is exactly
    /// `diag(B_old, I_k)`: its LDLᵀ factor is the old factor's values in
    /// the new layout plus an identity tail, *pure data movement* — no
    /// numeric factorization, no pivoting. The subsequent partial EP
    /// sweep then revises the new rows/columns through
    /// [`LdlFactor::ldl_row_modify`].
    ///
    /// Why the copy is exact:
    ///
    /// * the leading principal block of an LDLᵀ factor depends only on
    ///   the leading principal block of the matrix, and block-diagonal
    ///   input gives a block-diagonal factor — every entry outside the
    ///   old block is structurally zero;
    /// * old-pattern positions absent from the new pattern hold exact
    ///   `±0.0` (structural zeros and amalgamation padding are computed
    ///   as `0/d` from all-zero products — pinned by
    ///   `padded_entries_are_exactly_zero`), so dropping them loses
    ///   nothing;
    /// * new-pattern positions absent from the old pattern (new-point
    ///   rows, fresh padding) are true zeros of `diag(B_old, I)`'s
    ///   factor.
    ///
    /// The per-column merge walks both sorted row lists once — `O(nnz)`.
    /// `symbolic.n` must be ≥ the old factor's n, and the leading columns
    /// of the new pattern must describe the same matrix values (the
    /// caller guarantees this by building the extended pattern from the
    /// same covariance on the same leading points).
    pub fn embed(old: &LdlFactor, symbolic: Arc<Symbolic>) -> LdlFactor {
        let n_old = old.n();
        let n = symbolic.n;
        assert!(n >= n_old, "embed target must not shrink ({n} < {n_old})");
        let mut f = LdlFactor::identity(symbolic);
        f.d[..n_old].copy_from_slice(&old.d);
        f.jitter = old.jitter;
        let osym = &old.symbolic;
        let nsym = f.symbolic.clone();
        for j in 0..n_old {
            let orows = osym.col_pattern(j);
            let ovals = &old.l[osym.col_ptr[j]..osym.col_ptr[j + 1]];
            let nrows = nsym.col_pattern(j);
            let nbase = nsym.col_ptr[j];
            let (mut op, mut np) = (0usize, 0usize);
            while op < orows.len() && np < nrows.len() {
                match orows[op].cmp(&nrows[np]) {
                    std::cmp::Ordering::Equal => {
                        f.l[nbase + np] = ovals[op];
                        op += 1;
                        np += 1;
                    }
                    // old-only position: exact 0.0 in the old factor
                    std::cmp::Ordering::Less => op += 1,
                    // new-only position: structurally zero here
                    std::cmp::Ordering::Greater => np += 1,
                }
            }
        }
        f
    }

    /// Re-run the numeric factorization of `a` in place — the supernodal,
    /// wave-scheduled kernel (see the module docs). Supernodes of one
    /// assembly-tree wave are independent tasks dispatched over
    /// [`crate::par::for_chunks`] with one dense scratch column per
    /// participant; small waves run inline on the caller. The result is
    /// bitwise-identical at any pool width, and within rounding of
    /// [`LdlFactor::refactor_uplooking`].
    ///
    /// On a non-positive pivot the error names the smallest-indexed
    /// failing column of the earliest failing wave (deterministic at any
    /// width); the factor's values are unspecified afterwards.
    pub fn refactor(&mut self, a: &CscMatrix) -> Result<(), String> {
        let sym = self.symbolic.clone();
        let n = sym.n;
        assert_eq!(a.n_rows, n);
        assert_eq!(a.n_cols, n);
        let sched = &sym.schedule;
        let failed = AtomicUsize::new(usize::MAX);
        let mut fspan = crate::obs::span("factor");
        if fspan.is_active() {
            fspan.field_u64("n", n as u64);
            fspan.field_u64("snodes", sched.n_snodes() as u64);
            fspan.field_u64("waves", sched.n_waves() as u64);
            // padded nnz(L): what the O(nnz) cost-model rows in `csgp
            // trace analyze` normalize per-sweep time by
            fspan.field_u64("nnz", sym.row_idx.len() as u64);
        }
        crate::obs::counters::FACTOR_REFACTORS.add(1);
        {
            let l = SyncSlice::new(&mut self.l);
            let d = SyncSlice::new(&mut self.d);
            let mut ws_inline = FactorScratch::new(&sym); // caller's scratch
            for w in 0..sched.n_waves() {
                let wave = sched.wave(w);
                // Observation only: per-wave spans (and the pool's chunk
                // telemetry below them) never influence the inline-vs-fanned
                // dispatch — that stays a pure function of wave shape and
                // configured width.
                let mut wspan = crate::obs::span("factor.wave");
                if wspan.is_active() {
                    wspan.field_u64("wave", w as u64);
                    wspan.field_u64("snodes", wave.len() as u64);
                    let cols: usize = wave.iter().map(|&s| sched.columns(s).len()).sum();
                    wspan.field_u64("cols", cols as u64);
                    // flop estimate: each column's pull-and-scale work is
                    // quadratic in its (padded) pattern length
                    let flops: u64 = wave
                        .iter()
                        .flat_map(|&s| sched.columns(s))
                        .map(|j| {
                            let len = (sym.col_ptr[j + 1] - sym.col_ptr[j]) as u64;
                            len * (len + 2)
                        })
                        .sum();
                    wspan.field_u64("flops", flops);
                }
                if wave.len() < PAR_WAVE_MIN || crate::par::current_threads() <= 1 {
                    for &s in wave {
                        factor_supernode(&sym, a, s, &mut ws_inline, &l, &d, &failed);
                    }
                } else {
                    crate::par::for_chunks(
                        wave.len(),
                        snode_chunk(wave.len()),
                        || FactorScratch::new(&sym),
                        |ws, range| {
                            for &s in &wave[range] {
                                factor_supernode(&sym, a, s, ws, &l, &d, &failed);
                            }
                        },
                    );
                }
                crate::obs::counters::FACTOR_WAVES.add(1);
                // Wave barriers double as failure checks: later waves
                // would divide by the bad pivot, so stop scheduling. The
                // break lands at the same wave at every width.
                if failed.load(AtomicOrdering::Relaxed) != usize::MAX {
                    break;
                }
            }
        }
        let bad = failed.into_inner();
        if bad != usize::MAX {
            return Err(format!(
                "matrix not positive definite at pivot {bad} (d = {})",
                self.d[bad]
            ));
        }
        self.jitter = 0.0;
        Ok(())
    }

    /// [`LdlFactor::refactor`] with pivot recovery: on a non-positive
    /// pivot, retry with escalating diagonal jitter per `policy` until the
    /// factorization goes through, and return the jitter that was applied
    /// (0.0 when the clean attempt succeeded). The retried matrix is
    /// `A + jitter·I`, so the factor is exact for a perturbation the
    /// caller knows about — recorded on [`LdlFactor::jitter`], counted in
    /// `obs::counters::FACTOR_JITTER_RETRIES` (once per retried attempt)
    /// and summarized on a `factor.recover` span.
    ///
    /// Deterministic at any pool width: each attempt reports the smallest
    /// failing column after its wave join, so whether to retry — and with
    /// how much jitter — never depends on thread interleaving.
    pub fn refactor_with_recovery(
        &mut self,
        a: &CscMatrix,
        policy: &JitterPolicy,
    ) -> Result<f64, String> {
        let first = match self.refactor(a) {
            Ok(()) => return Ok(0.0),
            Err(e) => e,
        };
        let n = a.n_rows;
        let mut mean_diag = 0.0;
        for j in 0..n {
            let (rows, vals) = a.col(j);
            if let Some(p) = rows.iter().position(|&i| i == j) {
                mean_diag += vals[p].abs();
            }
        }
        let scale = if mean_diag > 0.0 { mean_diag / n as f64 } else { 1.0 };
        let mut span = crate::obs::span("factor.recover");
        let mut jittered = a.clone();
        let mut added = 0.0; // jitter currently on `jittered`'s diagonal
        let mut rel = policy.initial_rel;
        for retry in 1..=policy.max_retries {
            let jitter = rel * scale;
            for j in 0..n {
                *jittered.get_mut(j, j) += jitter - added;
            }
            added = jitter;
            crate::obs::counters::FACTOR_JITTER_RETRIES.add(1);
            if self.refactor(&jittered).is_ok() {
                self.jitter = jitter;
                if span.is_active() {
                    span.field_u64("retries", retry as u64);
                    span.field_f64("jitter", jitter);
                }
                return Ok(jitter);
            }
            rel *= policy.growth;
        }
        if span.is_active() {
            span.field_u64("retries", policy.max_retries as u64);
            span.field_bool("gave_up", true);
        }
        Err(format!(
            "matrix not positive definite even with diagonal jitter up to {added:.3e} \
             ({} retries); first failure: {first}",
            policy.max_retries
        ))
    }

    /// The original serial up-looking factorization (Davis's LDL): row k
    /// of L solves a sparse triangular system over the etree reach of
    /// `A(0..k, k)`. Kept as the independent comparison oracle for
    /// [`LdlFactor::refactor`] — same answer within rounding, different
    /// algorithm, no pool involvement.
    pub fn refactor_uplooking(&mut self, a: &CscMatrix) -> Result<(), String> {
        let sym = self.symbolic.clone();
        let n = sym.n;
        assert_eq!(a.n_rows, n);
        let mut y = vec![0.0; n]; // dense accumulator for row k
        let mut mark = vec![usize::MAX; n];
        let mut pattern = Vec::with_capacity(n);
        let mut lnz = vec![0usize; n]; // entries placed per column so far
        self.l.iter_mut().for_each(|v| *v = 0.0);

        for k in 0..n {
            ereach(a, k, &sym.parent, &mut mark, &mut pattern);
            // scatter A(0..k, k) into y, pick up the diagonal
            let (rows, vals) = a.col(k);
            let mut dk = 0.0;
            for (&i, &v) in rows.iter().zip(vals) {
                if i < k {
                    y[i] = v;
                } else if i == k {
                    dk = v;
                }
            }
            // sparse triangular solve along the (ascending == topological
            // for an etree) pattern
            for &j in pattern.iter() {
                let yj = y[j];
                y[j] = 0.0;
                let lo = sym.col_ptr[j];
                for p in lo..lo + lnz[j] {
                    y[sym.row_idx[p]] -= self.l[p] * yj;
                }
                let lkj = yj / self.d[j];
                dk -= lkj * yj;
                // `ereach` walks the *true* pattern; the stored column may
                // interleave amalgamation padding. Advance the cursor past
                // padded slots — `l` is pre-zeroed, so they stay exactly
                // 0.0, which is their defined value.
                let mut slot = lo + lnz[j];
                while sym.row_idx[slot] != k {
                    debug_assert!(
                        sym.row_idx[slot] < k,
                        "pattern mismatch at ({k},{j})"
                    );
                    slot += 1;
                }
                self.l[slot] = lkj;
                lnz[j] = slot + 1 - lo;
            }
            if dk <= 0.0 {
                return Err(format!("matrix not positive definite at pivot {k} (d = {dk})"));
            }
            self.d[k] = dk;
        }
        Ok(())
    }

    /// log|A| = Σ log dᵢ.
    pub fn logdet(&self) -> f64 {
        self.d.iter().map(|&d| d.ln()).sum()
    }

    /// Values of the strictly-lower column j (aligned with
    /// `symbolic.col_pattern(j)`).
    #[inline]
    pub fn col_values(&self, j: usize) -> &[f64] {
        &self.l[self.symbolic.col_ptr[j]..self.symbolic.col_ptr[j + 1]]
    }

    /// Dense reconstruction L D Lᵀ (tests only).
    pub fn reconstruct(&self) -> crate::sparse::dense::DenseMatrix {
        let n = self.n();
        let mut ld = crate::sparse::dense::DenseMatrix::identity(n);
        for j in 0..n {
            let pat = self.symbolic.col_pattern(j);
            let vals = self.col_values(j);
            for (&i, &v) in pat.iter().zip(vals) {
                *ld.at_mut(i, j) = v;
            }
        }
        let mut out = crate::sparse::dense::DenseMatrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += ld.at(i, k) * self.d[k] * ld.at(j, k);
                }
                *out.at_mut(i, j) = s;
            }
        }
        out
    }
}

/// Per-participant scratch of the numeric factorization: the dense
/// accumulator column of the scalar (width-1) path plus the frontal-panel
/// buffers of the blocked path, allocated once per pool participant and
/// reused across every supernode that participant factors.
struct FactorScratch {
    /// Dense scratch column for width-1 supernodes.
    y: Vec<f64>,
    /// Global row index → panel row, refreshed per supernode (only the
    /// supernode's own rows are ever read, so no clearing).
    map: Vec<usize>,
    /// Column-major `(w+t) × w` frontal panel, `ld = w + t`.
    panel: Vec<f64>,
    /// Panel rows of the current source supernode's update rows.
    prow: Vec<usize>,
    /// One update column accumulated densely before the panel scatter.
    acc: Vec<f64>,
}

impl FactorScratch {
    fn new(sym: &Symbolic) -> FactorScratch {
        FactorScratch {
            y: vec![0.0; sym.n],
            map: vec![0; sym.n],
            panel: Vec::new(),
            prow: Vec::new(),
            acc: Vec::new(),
        }
    }
}

/// Factor the columns of supernode `s`. Width-1 supernodes run the scalar
/// per-column pull ([`factor_supernode_scalar`]); wider supernodes run the
/// dense-panel kernel ([`factor_supernode_blocked`]). The choice depends
/// only on the pattern, never on thread count or chunk shape, so the
/// factor stays bitwise-identical at any pool width.
fn factor_supernode(
    sym: &Symbolic,
    a: &CscMatrix,
    s: usize,
    ws: &mut FactorScratch,
    l: &SyncSlice<'_, f64>,
    d: &SyncSlice<'_, f64>,
    failed: &AtomicUsize,
) {
    if sym.schedule.columns(s).len() == 1 {
        factor_supernode_scalar(sym, a, s, &mut ws.y, l, d, failed);
    } else {
        factor_supernode_blocked(sym, a, s, ws, l, d, failed);
    }
}

/// The scalar path, for singleton supernodes (the panel setup would cost
/// more than it saves). For its column j: scatter the lower part of
/// `A(:, j)` into the dense scratch `y`, pull the updates
/// `y ← y − L[:,k] · (L[j,k] d_k)` from every finished source column
/// `k ∈ row_pattern(j)` in ascending-k order, then emit `d_j = y_j`,
/// `L[i,j] = y_i / d_j` and re-zero exactly the touched entries. The
/// ascending-k gather order is what pins bitwise determinism (see the
/// module docs); the fill rule guarantees every update target is inside
/// `pat(j)`, so the scratch stays clean.
///
/// A non-positive pivot is recorded into `failed` (`fetch_min`, so
/// concurrent failures resolve to the smallest column) and the division
/// proceeds — IEEE inf/NaN arithmetic is deterministic, the caller stops
/// scheduling at the wave barrier, and the factor is unspecified on error.
fn factor_supernode_scalar(
    sym: &Symbolic,
    a: &CscMatrix,
    s: usize,
    y: &mut [f64],
    l: &SyncSlice<'_, f64>,
    d: &SyncSlice<'_, f64>,
    failed: &AtomicUsize,
) {
    for j in sym.schedule.columns(s) {
        let (arows, avals) = a.col(j);
        let mut dj = 0.0;
        for (&i, &v) in arows.iter().zip(avals) {
            if i == j {
                dj = v;
            } else if i > j {
                debug_assert!(
                    sym.find(i, j).is_some(),
                    "A entry ({i},{j}) outside the analysed pattern"
                );
                y[i] = v;
            }
        }
        for &(k, pos) in sym.row_pattern(j) {
            // SAFETY: source column k finished either in an earlier wave
            // (completion barrier) or earlier in this supernode (same
            // task); no one writes those slots concurrently. Pattern
            // indices are < n by construction.
            unsafe {
                let ljk = l.get(pos);
                let c = ljk * d.get(k);
                dj -= ljk * c;
                let hi = *sym.col_ptr.get_unchecked(k + 1);
                for p in pos + 1..hi {
                    *y.get_unchecked_mut(*sym.row_idx.get_unchecked(p)) -= l.get(p) * c;
                }
            }
        }
        if crate::fault::should_fail_pivot(j) {
            dj = -1.0; // injected failure takes the real recovery path
        }
        if dj <= 0.0 {
            failed.fetch_min(j, AtomicOrdering::Relaxed);
        }
        // SAFETY: slot j of D and column j of L belong to this task alone.
        unsafe { d.set(j, dj) };
        for p in sym.col_ptr[j]..sym.col_ptr[j + 1] {
            let i = sym.row_idx[p];
            // SAFETY: as above — column j's slots are this task's.
            unsafe { l.set(p, y[i] / dj) };
            y[i] = 0.0;
        }
    }
}

/// The dense-panel kernel for supernodes of width ≥ 2.
///
/// Every column of supernode `[j0, jend)` stores the trapezoidal pattern
/// `{j+1..jend-1} ∪ T` with `T = pat(jend-1)` (strict supernodes by
/// suffix nesting, amalgamated ones by padding), so the whole supernode is
/// one `(w+t) × w` column-major panel with leading dimension `ld = w+t`:
/// panel rows `0..w` are the supernode's own columns, rows `w..w+t` are
/// `T`. The kernel gathers `A`, pulls every external update, factors the
/// panel in place, and scatters back — and because column `j0+c`'s storage
/// order equals panel rows `c+1..ld`, the scatter is one contiguous copy
/// per column.
///
/// External updates pull per *source supernode* `q` (ascending, from the
/// schedule's precomputed source list): the update rows are the suffix of
/// `q`'s top pattern at `≥ j0`, which every column of `q` stores as its
/// last `m` entries — contiguous slices, so the rank-`w_q` accumulation
/// `acc += L[rows,k] · (L[j,k] d_k)` runs over real slices the compiler
/// autovectorizes, with one indexed scatter into the panel per target
/// column. Summation order (sources ascending, then columns ascending,
/// then internal elimination ascending) is a pure function of the
/// pattern, preserving bitwise identity at any pool width.
///
/// Pivot failures are recorded exactly as in the scalar path.
fn factor_supernode_blocked(
    sym: &Symbolic,
    a: &CscMatrix,
    s: usize,
    ws: &mut FactorScratch,
    l: &SyncSlice<'_, f64>,
    d: &SyncSlice<'_, f64>,
    failed: &AtomicUsize,
) {
    let sched = &sym.schedule;
    let (j0, jend) = (sched.snode_ptr[s], sched.snode_ptr[s + 1]);
    let w = jend - j0;
    let ext = &sym.row_idx[sym.col_ptr[jend - 1]..sym.col_ptr[jend]];
    let t = ext.len();
    let ld = w + t;
    let FactorScratch { map, panel, prow, acc, .. } = ws;
    panel.clear();
    panel.resize(ld * w, 0.0);
    for (c, j) in (j0..jend).enumerate() {
        map[j] = c;
    }
    for (r, &i) in ext.iter().enumerate() {
        map[i] = w + r;
    }

    // Gather A's lower columns into the panel (diagonal at (c, c)).
    for c in 0..w {
        let j = j0 + c;
        let col = &mut panel[c * ld..(c + 1) * ld];
        let (arows, avals) = a.col(j);
        for (&i, &v) in arows.iter().zip(avals) {
            if i == j {
                col[c] = v;
            } else if i > j {
                debug_assert!(
                    sym.find(i, j).is_some(),
                    "A entry ({i},{j}) outside the analysed pattern"
                );
                col[map[i]] = v;
            }
        }
    }

    // External rank-k updates, one source supernode at a time, ascending.
    for &q in sched.sources(s) {
        let (q0, qend) = (sched.snode_ptr[q], sched.snode_ptr[q + 1]);
        let tq = &sym.row_idx[sym.col_ptr[qend - 1]..sym.col_ptr[qend]];
        let i0 = tq.partition_point(|&i| i < j0);
        let rows = &tq[i0..];
        let m = rows.len();
        // Rows ≥ j0 of q's top pattern all live in this panel (fill rule),
        // and the first nc of them are this supernode's own columns — the
        // update's target columns.
        let nc = rows.partition_point(|&i| i < jend);
        debug_assert!(nc > 0, "source list edge without target columns");
        prow.clear();
        prow.extend(rows.iter().map(|&i| map[i]));
        if acc.len() < m {
            acc.resize(m, 0.0);
        }
        for r in 0..nc {
            let cj = rows[r] - j0;
            let accs = &mut acc[r..m];
            accs.fill(0.0);
            for k in q0..qend {
                let hi = sym.col_ptr[k + 1];
                // SAFETY: column k's last `m` slots are its copy of the
                // top-pattern suffix; the column finished in an earlier
                // wave (q is a strict assembly-tree descendant), so reads
                // race with nothing.
                let sk = unsafe { l.slice(hi - m, m) };
                // SAFETY: same earlier-wave argument for d[k].
                let coef = sk[r] * unsafe { d.get(k) };
                for (av, &sv) in accs.iter_mut().zip(&sk[r..]) {
                    *av += sv * coef;
                }
            }
            let col = cj * ld;
            for (r2, &av) in (r..m).zip(accs.iter()) {
                panel[col + prow[r2]] -= av;
            }
        }
    }

    // Dense right-looking LDLᵀ of the panel; scatter each finished column.
    for c in 0..w {
        let j = j0 + c;
        let (head, tail) = panel.split_at_mut((c + 1) * ld);
        let colc = &mut head[c * ld..];
        let mut dj = colc[c];
        if crate::fault::should_fail_pivot(j) {
            dj = -1.0; // injected failure takes the real recovery path
        }
        if dj <= 0.0 {
            failed.fetch_min(j, AtomicOrdering::Relaxed);
        }
        // SAFETY: slot j of D belongs to this task alone.
        unsafe { d.set(j, dj) };
        for v in &mut colc[c + 1..] {
            *v /= dj;
        }
        for c2 in c + 1..w {
            let coef = colc[c2] * dj;
            let col2 = &mut tail[(c2 - c - 1) * ld..(c2 - c) * ld];
            for (o, &v) in col2[c2..].iter_mut().zip(&colc[c2..]) {
                *o -= v * coef;
            }
        }
        // SAFETY: column j's slots are this task's; its storage order is
        // exactly panel rows c+1..ld.
        let lo = sym.col_ptr[j];
        debug_assert_eq!(sym.col_ptr[j + 1] - lo, ld - c - 1);
        unsafe { l.slice_mut(lo, ld - c - 1) }.copy_from_slice(&colc[c + 1..]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testutil::random_sparse_spd;

    #[test]
    fn factor_reconstructs_small() {
        // 3x3 SPD with known factor
        let a = CscMatrix::from_triplets(
            3,
            3,
            &[(0, 0, 4.0), (1, 0, 2.0), (0, 1, 2.0), (1, 1, 5.0), (2, 1, 2.0), (1, 2, 2.0), (2, 2, 6.0)],
        );
        let sym = Arc::new(Symbolic::analyze(&a));
        let f = LdlFactor::factor(sym, &a).unwrap();
        let rec = f.reconstruct();
        assert!(rec.max_abs_diff(&a.to_dense()) < 1e-12);
    }

    #[test]
    fn factor_matches_dense_on_random_spd() {
        for seed in 0..8 {
            let a = random_sparse_spd(40, 0.15, seed);
            let sym = Arc::new(Symbolic::analyze(&a));
            let f = LdlFactor::factor(sym, &a).unwrap();
            let rec = f.reconstruct();
            assert!(
                rec.max_abs_diff(&a.to_dense()) < 1e-9,
                "seed {seed}: {}",
                rec.max_abs_diff(&a.to_dense())
            );
        }
    }

    #[test]
    fn logdet_matches_dense() {
        let a = random_sparse_spd(30, 0.2, 42);
        let sym = Arc::new(Symbolic::analyze(&a));
        let f = LdlFactor::factor(sym, &a).unwrap();
        let dense_logdet = a.to_dense().cholesky().unwrap().logdet();
        assert!((f.logdet() - dense_logdet).abs() < 1e-9);
    }

    #[test]
    fn identity_factor() {
        let a = CscMatrix::identity(5);
        let sym = Arc::new(Symbolic::analyze(&a));
        let f = LdlFactor::identity(sym);
        assert_eq!(f.d, vec![1.0; 5]);
        assert!((f.logdet()).abs() < 1e-15);
    }

    #[test]
    fn refactor_in_place_after_value_change() {
        let mut rng = Rng::new(9);
        let a = random_sparse_spd(25, 0.2, 7);
        let sym = Arc::new(Symbolic::analyze(&a));
        let mut f = LdlFactor::factor(sym, &a).unwrap();
        // change values (same pattern), refactor, compare
        let mut a2 = a.clone();
        for v in a2.values.iter_mut() {
            *v *= 1.0 + 0.01 * rng.uniform();
        }
        // keep symmetric + diagonally dominant
        let a2 = {
            let t = a2.transpose();
            let mut sym_vals = a2.clone();
            for p in 0..sym_vals.values.len() {
                sym_vals.values[p] = 0.5 * (a2.values[p] + t.values[p]);
            }
            for j in 0..25 {
                *sym_vals.get_mut(j, j) += 5.0;
            }
            sym_vals
        };
        f.refactor(&a2).unwrap();
        assert!(f.reconstruct().max_abs_diff(&a2.to_dense()) < 1e-9);
    }

    #[test]
    fn indefinite_matrix_errors() {
        let a = CscMatrix::from_triplets(2, 2, &[(0, 0, 1.0), (1, 0, 2.0), (0, 1, 2.0), (1, 1, 1.0)]);
        let sym = Arc::new(Symbolic::analyze(&a));
        assert!(LdlFactor::factor(sym, &a).is_err());
    }

    /// A CS covariance + unit diagonal — the matrix shape EP actually
    /// factors (`B = I + S̃^{1/2} K S̃^{1/2}`).
    fn cs_b_matrix(n: usize, ls: f64, seed: u64) -> CscMatrix {
        use crate::gp::covariance::{CovFunction, CovKind};
        use crate::testutil::random_points;
        let x = random_points(n, 2, 8.0, seed);
        let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, ls);
        let mut k = cov.cov_matrix(&x);
        for j in 0..k.n_cols {
            *k.get_mut(j, j) += 1.0;
        }
        k
    }

    /// The online-update embed: a factor of `B_old` copied into the
    /// analysis of the extended matrix `diag(B_old, I_k)` (the exact
    /// shape appended τ̃ = 0 EP sites produce — the cross-block pattern
    /// entries exist but hold zero values) matches a direct factorization
    /// of the extended matrix, with exactly-zero new rows/columns and an
    /// identity tail — no numeric factorization happened.
    #[test]
    fn embed_matches_direct_factor_of_block_extended_matrix() {
        use crate::gp::covariance::{CovFunction, CovKind};
        use crate::testutil::random_points;
        let (n_old, k) = (90usize, 7usize);
        let x = random_points(n_old + k, 2, 8.0, 17);
        let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 2.2);
        let tau = |i: usize| if i < n_old { 0.4 + (i % 5) as f64 * 0.3 } else { 0.0 };
        let scale = |kmat: &CscMatrix| {
            let mut b = kmat.clone();
            for j in 0..b.n_cols {
                for p in b.col_ptr[j]..b.col_ptr[j + 1] {
                    let i = b.row_idx[p];
                    let v = tau(i).sqrt() * tau(j).sqrt() * b.values[p];
                    b.values[p] = if i == j { 1.0 + v } else { v };
                }
            }
            b
        };
        let b_old = scale(&cov.cov_matrix(&x[..n_old]));
        let b_ext = scale(&cov.cov_matrix(&x));
        let sym_old = Arc::new(Symbolic::analyze(&b_old));
        let sym_ext = Arc::new(Symbolic::analyze(&b_ext));
        let f_old = LdlFactor::factor(sym_old, &b_old).unwrap();
        let embedded = LdlFactor::embed(&f_old, sym_ext.clone());
        let direct = LdlFactor::factor(sym_ext.clone(), &b_ext).unwrap();
        for j in 0..n_old + k {
            for (p, &i) in sym_ext.col_pattern(j).iter().enumerate() {
                let (e, d) = (
                    embedded.l[sym_ext.col_ptr[j] + p],
                    direct.l[sym_ext.col_ptr[j] + p],
                );
                if i >= n_old || j >= n_old {
                    assert_eq!(e, 0.0, "new row/col entry ({i},{j}) must be zero");
                    assert_eq!(d, 0.0, "direct factor disagrees at ({i},{j})");
                } else {
                    assert!((e - d).abs() < 1e-12, "({i},{j}): {e} vs {d}");
                }
            }
        }
        for j in 0..n_old {
            assert!((embedded.d[j] - direct.d[j]).abs() < 1e-12, "d[{j}]");
        }
        assert_eq!(&embedded.d[n_old..], &vec![1.0; k][..], "identity tail");
        // and the embedded factor actually solves the extended system
        let rhs: Vec<f64> = (0..n_old + k).map(|i| 0.3 + (i % 7) as f64).collect();
        let xs = embedded.solve(&rhs);
        let back = b_ext.matvec(&xs);
        for (a, b) in back.iter().zip(&rhs) {
            assert!((a - b).abs() < 1e-8, "{a} vs {b}");
        }
    }

    /// The supernodal wave-scheduled kernel against the up-looking serial
    /// oracle, on both random SPD patterns and real CS covariance
    /// patterns, with amalgamation on *and* off: same factor within
    /// rounding (the oracle runs on the same padded pattern — its cursor
    /// skips padded slots).
    #[test]
    fn supernodal_matches_uplooking_oracle() {
        use crate::sparse::symbolic::AmalgConfig;
        let cases: Vec<CscMatrix> = (0..4)
            .map(|s| random_sparse_spd(60, 0.12, 900 + s))
            .chain([cs_b_matrix(150, 1.6, 5), cs_b_matrix(150, 2.6, 6)])
            .collect();
        for (c, a) in cases.iter().enumerate() {
            for cfg in [AmalgConfig::default(), AmalgConfig::disabled()] {
                let sym = Arc::new(Symbolic::analyze_with(a, None, &cfg));
                let f = LdlFactor::factor(sym.clone(), a).unwrap();
                let mut oracle = LdlFactor::identity(sym);
                oracle.refactor_uplooking(a).unwrap();
                let dl =
                    f.l.iter().zip(&oracle.l).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
                let dd =
                    f.d.iter().zip(&oracle.d).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
                assert!(
                    dl < 1e-10 && dd < 1e-10,
                    "case {c} (amalg={}): dl={dl} dd={dd}",
                    cfg.enabled
                );
            }
        }
    }

    /// The amalgamated factor agrees with the strict-supernode factor
    /// entrywise (looked up through each pattern, so the padded layout
    /// difference is invisible) on random-SPD and CS fixtures.
    #[test]
    fn amalgamated_factor_matches_strict_factor() {
        use crate::sparse::symbolic::AmalgConfig;
        let cases: Vec<CscMatrix> = (0..3)
            .map(|s| random_sparse_spd(50, 0.15, 300 + s))
            .chain([cs_b_matrix(200, 1.8, 8)])
            .collect();
        for (c, a) in cases.iter().enumerate() {
            let sym_a = Arc::new(Symbolic::analyze_with(a, None, &AmalgConfig::default()));
            let sym_s = Arc::new(Symbolic::analyze_with(a, None, &AmalgConfig::disabled()));
            let fa = LdlFactor::factor(sym_a.clone(), a).unwrap();
            let fs = LdlFactor::factor(sym_s.clone(), a).unwrap();
            for (j, (da, ds)) in fa.d.iter().zip(&fs.d).enumerate() {
                assert!((da - ds).abs() < 1e-10, "case {c}: d[{j}]: {da} vs {ds}");
            }
            for j in 0..sym_s.n {
                for (&i, &vs) in sym_s.col_pattern(j).iter().zip(fs.col_values(j)) {
                    let p = sym_a.find(i, j).expect("strict entry missing from padded");
                    let va = fa.l[p];
                    assert!((va - vs).abs() < 1e-10, "case {c}: L({i},{j}): {va} vs {vs}");
                }
            }
        }
    }

    /// Amalgamation padding is *structural* zero: every padded slot (in
    /// the padded pattern but not the strict one) holds exactly 0.0 after
    /// factoring — the invariant that keeps the solves, Takahashi
    /// recursion and rank-one updates semantically unchanged.
    #[test]
    fn padded_entries_are_exactly_zero() {
        use crate::sparse::symbolic::AmalgConfig;
        for a in [cs_b_matrix(200, 1.4, 13), random_sparse_spd(80, 0.1, 77)] {
            let sym_a = Arc::new(Symbolic::analyze_with(&a, None, &AmalgConfig::default()));
            let sym_s = Arc::new(Symbolic::analyze_with(&a, None, &AmalgConfig::disabled()));
            assert!(
                sym_a.row_idx.len() > sym_s.row_idx.len(),
                "fixture produced no padding"
            );
            let f = LdlFactor::factor(sym_a.clone(), &a).unwrap();
            let mut padded = 0usize;
            for j in 0..sym_a.n {
                for (&i, &v) in sym_a.col_pattern(j).iter().zip(f.col_values(j)) {
                    if sym_s.find(i, j).is_none() {
                        padded += 1;
                        assert!(v == 0.0, "padded slot ({i},{j}) = {v}");
                    }
                }
            }
            assert_eq!(padded, sym_a.row_idx.len() - sym_s.row_idx.len());
        }
    }

    /// The determinism contract of the parallel factorization: identical
    /// L and D *bits* at widths 1, 2 and 7 (width 1 is the inline serial
    /// path), on a pattern large enough that waves genuinely fan out —
    /// with amalgamation on (the blocked kernel) and off (strict panels).
    #[test]
    fn parallel_refactor_is_bitwise_identical_across_widths() {
        use crate::sparse::symbolic::AmalgConfig;
        let a = cs_b_matrix(500, 1.2, 11);
        for cfg in [AmalgConfig::default(), AmalgConfig::disabled()] {
            let sym = Arc::new(Symbolic::analyze_with(&a, None, &cfg));
            assert!(
                sym.schedule.wave(0).len() >= super::PAR_WAVE_MIN,
                "fixture too small to exercise the parallel path"
            );
            let reference =
                crate::par::with_max_threads(1, || LdlFactor::factor(sym.clone(), &a).unwrap());
            let mut f = LdlFactor::identity(sym.clone());
            for width in [2usize, 7] {
                crate::par::with_max_threads(width, || f.refactor(&a).unwrap());
                assert_eq!(f.l, reference.l, "width {width}: L bits differ");
                assert_eq!(f.d, reference.d, "width {width}: D bits differ");
            }
        }
    }

    /// A barely-indefinite matrix (pivot lost to rounding-scale mass) is
    /// recovered by a small jitter, and the recovered factor reproduces
    /// the jittered matrix exactly.
    #[test]
    fn jitter_recovery_fixes_a_near_semidefinite_matrix() {
        // [[1, 1], [1, 1 - 1e-12]]: second pivot = -1e-12.
        let a = CscMatrix::from_triplets(
            2,
            2,
            &[(0, 0, 1.0), (1, 0, 1.0), (0, 1, 1.0), (1, 1, 1.0 - 1e-12)],
        );
        let sym = Arc::new(Symbolic::analyze(&a));
        let mut f = LdlFactor::identity(sym);
        assert!(f.refactor(&a).is_err(), "fail-fast refactor must still error");
        let jitter = f.refactor_with_recovery(&a, &JitterPolicy::default()).unwrap();
        assert!(jitter > 0.0 && jitter < 1e-8, "tiny deficit, tiny jitter: {jitter}");
        assert_eq!(f.jitter, jitter);
        let mut aj = a.to_dense();
        for j in 0..2 {
            *aj.at_mut(j, j) += jitter;
        }
        assert!(f.reconstruct().max_abs_diff(&aj) < 1e-12);
        // a clean refactor afterwards clears the recorded jitter
        let spd = CscMatrix::from_triplets(2, 2, &[(0, 0, 2.0), (1, 1, 2.0)]);
        // (same pattern superset not required: refactor only reads `a`'s
        // entries, missing ones stay zero)
        f.refactor_with_recovery(&spd, &JitterPolicy::default()).unwrap();
        assert_eq!(f.jitter, 0.0);
    }

    /// The schedule escalates: a deeper deficit takes more doublings, and
    /// each retried attempt is counted.
    #[test]
    fn jitter_recovery_escalates_and_counts() {
        use crate::obs::{self, TraceMode};
        // [[1, 1], [1, 1 - 1e-9]]: needs jitter > ~5e-10·mean|diag|,
        // i.e. several doublings from 1e-10.
        let a = CscMatrix::from_triplets(
            2,
            2,
            &[(0, 0, 1.0), (1, 0, 1.0), (0, 1, 1.0), (1, 1, 1.0 - 1e-9)],
        );
        let sym = Arc::new(Symbolic::analyze(&a));
        obs::with_mode(TraceMode::Counters, || {
            let before = obs::snapshot();
            let mut f = LdlFactor::identity(sym.clone());
            let jitter = f.refactor_with_recovery(&a, &JitterPolicy::default()).unwrap();
            assert!(jitter > 5e-10, "escalated past the first rungs: {jitter}");
            let after = obs::snapshot();
            assert!(
                after.factor_jitter_retries - before.factor_jitter_retries >= 3,
                "expected several counted retries"
            );
        });
        // an exhausted budget reports the original failure
        let mut f = LdlFactor::identity(sym);
        let policy = JitterPolicy { max_retries: 2, ..JitterPolicy::default() };
        let err = f.refactor_with_recovery(&a, &policy).unwrap_err();
        assert!(err.contains("not positive definite"), "{err}");
    }

    /// An injected pivot failure takes the identical recovery path at
    /// widths 1/2/7: same retry count, same jitter bits, same factor bits.
    #[test]
    fn injected_pivot_recovery_is_identical_across_widths() {
        let a = cs_b_matrix(500, 1.2, 11);
        let sym = Arc::new(Symbolic::analyze(&a));
        assert!(
            sym.schedule.wave(0).len() >= super::PAR_WAVE_MIN,
            "fixture too small to exercise the parallel path"
        );
        let runs: Vec<(f64, Vec<f64>, Vec<f64>)> = [1usize, 2, 7]
            .iter()
            .map(|&w| {
                crate::fault::with_plan(crate::fault::Plan::new().pivot(120), || {
                    crate::par::with_max_threads(w, || {
                        let mut f = LdlFactor::identity(sym.clone());
                        let jitter =
                            f.refactor_with_recovery(&a, &JitterPolicy::default()).unwrap();
                        assert!(jitter > 0.0, "width {w}: the injected failure must recover");
                        (jitter, f.l, f.d)
                    })
                })
            })
            .collect();
        for (w, run) in [2usize, 7].iter().zip(&runs[1..]) {
            assert_eq!(run.0.to_bits(), runs[0].0.to_bits(), "width {w}: jitter differs");
            assert_eq!(run.1, runs[0].1, "width {w}: L bits differ");
            assert_eq!(run.2, runs[0].2, "width {w}: D bits differ");
        }
    }

    /// Error reporting is deterministic at any width: a matrix that goes
    /// indefinite mid-elimination names the same pivot at widths 1/2/7.
    #[test]
    fn indefinite_error_is_deterministic_across_widths() {
        // start from a CS B-matrix and break one interior diagonal entry
        let mut a = cs_b_matrix(300, 1.4, 21);
        *a.get_mut(120, 120) = -3.0;
        let sym = Arc::new(Symbolic::analyze(&a));
        let errs: Vec<String> = [1usize, 2, 7]
            .iter()
            .map(|&w| {
                crate::par::with_max_threads(w, || {
                    LdlFactor::factor(sym.clone(), &a).unwrap_err()
                })
            })
            .collect();
        assert_eq!(errs[0], errs[1]);
        assert_eq!(errs[0], errs[2]);
        assert!(errs[0].contains("not positive definite"), "{}", errs[0]);
    }
}
