//! Numeric LDLᵀ factorization on a static symbolic pattern.
//!
//! Up-looking algorithm (Davis's LDL package): row k of L is the solution
//! of a sparse lower-triangular system whose pattern is the etree reach of
//! `A(0..k, k)`. Because the EP algorithm keeps the pattern of `B` fixed,
//! the factor is allocated once from [`Symbolic`] and re-factored /
//! row-modified in place.

use std::sync::Arc;

use crate::sparse::csc::CscMatrix;
use crate::sparse::etree::ereach;
use crate::sparse::symbolic::Symbolic;

/// LDLᵀ factor: unit lower-triangular `L` (strict lower part stored on the
/// symbolic pattern) and diagonal `D`.
#[derive(Clone, Debug)]
pub struct LdlFactor {
    pub symbolic: Arc<Symbolic>,
    /// Values aligned with `symbolic.row_idx` (strictly lower triangle).
    pub l: Vec<f64>,
    /// Diagonal of D.
    pub d: Vec<f64>,
}

impl LdlFactor {
    /// Factor symmetric positive-definite `a` (full storage). The pattern
    /// of `a` must match the pattern `symbolic` was analysed from (entries
    /// of `a` outside it will panic in debug, give wrong results in
    /// release — callers always pass the analysed matrix).
    pub fn factor(symbolic: Arc<Symbolic>, a: &CscMatrix) -> Result<LdlFactor, String> {
        let n = symbolic.n;
        let mut f = LdlFactor { symbolic, l: vec![0.0; 0], d: vec![0.0; n] };
        f.l = vec![0.0; f.symbolic.row_idx.len()];
        f.refactor(a)?;
        Ok(f)
    }

    /// Identity factor (L = I, D = I); the state of `B = I` before any EP
    /// site has been updated.
    pub fn identity(symbolic: Arc<Symbolic>) -> LdlFactor {
        let n = symbolic.n;
        let nnz = symbolic.row_idx.len();
        LdlFactor { symbolic, l: vec![0.0; nnz], d: vec![1.0; n] }
    }

    pub fn n(&self) -> usize {
        self.symbolic.n
    }

    /// Re-run the numeric factorization of `a` in place.
    pub fn refactor(&mut self, a: &CscMatrix) -> Result<(), String> {
        let sym = self.symbolic.clone();
        let n = sym.n;
        assert_eq!(a.n_rows, n);
        let mut y = vec![0.0; n]; // dense accumulator for row k
        let mut mark = vec![usize::MAX; n];
        let mut pattern = Vec::with_capacity(n);
        let mut lnz = vec![0usize; n]; // entries placed per column so far
        self.l.iter_mut().for_each(|v| *v = 0.0);

        for k in 0..n {
            ereach(a, k, &sym.parent, &mut mark, &mut pattern);
            // scatter A(0..k, k) into y, pick up the diagonal
            let (rows, vals) = a.col(k);
            let mut dk = 0.0;
            for (&i, &v) in rows.iter().zip(vals) {
                if i < k {
                    y[i] = v;
                } else if i == k {
                    dk = v;
                }
            }
            // sparse triangular solve along the (ascending == topological
            // for an etree) pattern
            for &j in pattern.iter() {
                let yj = y[j];
                y[j] = 0.0;
                let lo = sym.col_ptr[j];
                for p in lo..lo + lnz[j] {
                    y[sym.row_idx[p]] -= self.l[p] * yj;
                }
                let lkj = yj / self.d[j];
                dk -= lkj * yj;
                let slot = lo + lnz[j];
                debug_assert_eq!(sym.row_idx[slot], k, "pattern mismatch at ({k},{j})");
                self.l[slot] = lkj;
                lnz[j] += 1;
            }
            if dk <= 0.0 {
                return Err(format!("matrix not positive definite at pivot {k} (d = {dk})"));
            }
            self.d[k] = dk;
        }
        Ok(())
    }

    /// log|A| = Σ log dᵢ.
    pub fn logdet(&self) -> f64 {
        self.d.iter().map(|&d| d.ln()).sum()
    }

    /// Values of the strictly-lower column j (aligned with
    /// `symbolic.col_pattern(j)`).
    #[inline]
    pub fn col_values(&self, j: usize) -> &[f64] {
        &self.l[self.symbolic.col_ptr[j]..self.symbolic.col_ptr[j + 1]]
    }

    /// Dense reconstruction L D Lᵀ (tests only).
    pub fn reconstruct(&self) -> crate::sparse::dense::DenseMatrix {
        let n = self.n();
        let mut ld = crate::sparse::dense::DenseMatrix::identity(n);
        for j in 0..n {
            let pat = self.symbolic.col_pattern(j);
            let vals = self.col_values(j);
            for (&i, &v) in pat.iter().zip(vals) {
                *ld.at_mut(i, j) = v;
            }
        }
        let mut out = crate::sparse::dense::DenseMatrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += ld.at(i, k) * self.d[k] * ld.at(j, k);
                }
                *out.at_mut(i, j) = s;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testutil::random_sparse_spd;

    #[test]
    fn factor_reconstructs_small() {
        // 3x3 SPD with known factor
        let a = CscMatrix::from_triplets(
            3,
            3,
            &[(0, 0, 4.0), (1, 0, 2.0), (0, 1, 2.0), (1, 1, 5.0), (2, 1, 2.0), (1, 2, 2.0), (2, 2, 6.0)],
        );
        let sym = Arc::new(Symbolic::analyze(&a));
        let f = LdlFactor::factor(sym, &a).unwrap();
        let rec = f.reconstruct();
        assert!(rec.max_abs_diff(&a.to_dense()) < 1e-12);
    }

    #[test]
    fn factor_matches_dense_on_random_spd() {
        for seed in 0..8 {
            let a = random_sparse_spd(40, 0.15, seed);
            let sym = Arc::new(Symbolic::analyze(&a));
            let f = LdlFactor::factor(sym, &a).unwrap();
            let rec = f.reconstruct();
            assert!(
                rec.max_abs_diff(&a.to_dense()) < 1e-9,
                "seed {seed}: {}",
                rec.max_abs_diff(&a.to_dense())
            );
        }
    }

    #[test]
    fn logdet_matches_dense() {
        let a = random_sparse_spd(30, 0.2, 42);
        let sym = Arc::new(Symbolic::analyze(&a));
        let f = LdlFactor::factor(sym, &a).unwrap();
        let dense_logdet = a.to_dense().cholesky().unwrap().logdet();
        assert!((f.logdet() - dense_logdet).abs() < 1e-9);
    }

    #[test]
    fn identity_factor() {
        let a = CscMatrix::identity(5);
        let sym = Arc::new(Symbolic::analyze(&a));
        let f = LdlFactor::identity(sym);
        assert_eq!(f.d, vec![1.0; 5]);
        assert!((f.logdet()).abs() < 1e-15);
    }

    #[test]
    fn refactor_in_place_after_value_change() {
        let mut rng = Rng::new(9);
        let a = random_sparse_spd(25, 0.2, 7);
        let sym = Arc::new(Symbolic::analyze(&a));
        let mut f = LdlFactor::factor(sym, &a).unwrap();
        // change values (same pattern), refactor, compare
        let mut a2 = a.clone();
        for v in a2.values.iter_mut() {
            *v *= 1.0 + 0.01 * rng.uniform();
        }
        // keep symmetric + diagonally dominant
        let a2 = {
            let t = a2.transpose();
            let mut sym_vals = a2.clone();
            for p in 0..sym_vals.values.len() {
                sym_vals.values[p] = 0.5 * (a2.values[p] + t.values[p]);
            }
            for j in 0..25 {
                *sym_vals.get_mut(j, j) += 5.0;
            }
            sym_vals
        };
        f.refactor(&a2).unwrap();
        assert!(f.reconstruct().max_abs_diff(&a2.to_dense()) < 1e-9);
    }

    #[test]
    fn indefinite_matrix_errors() {
        let a = CscMatrix::from_triplets(2, 2, &[(0, 0, 1.0), (1, 0, 2.0), (0, 1, 2.0), (1, 1, 1.0)]);
        let sym = Arc::new(Symbolic::analyze(&a));
        assert!(LdlFactor::factor(sym, &a).is_err());
    }
}
