//! Dense matrices with Cholesky factorization.
//!
//! Used three ways: (1) the dense-EP baseline the paper compares against
//! (`k_se` with full covariance), (2) the m×m inner solves of FIC, and
//! (3) the *oracle* every sparse kernel is tested against.

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    pub n_rows: usize,
    pub n_cols: usize,
    pub data: Vec<f64>,
}

impl DenseMatrix {
    pub fn zeros(n_rows: usize, n_cols: usize) -> DenseMatrix {
        DenseMatrix { n_rows, n_cols, data: vec![0.0; n_rows * n_cols] }
    }

    pub fn identity(n: usize) -> DenseMatrix {
        let mut m = DenseMatrix::zeros(n, n);
        for i in 0..n {
            *m.at_mut(i, i) = 1.0;
        }
        m
    }

    pub fn from_fn(n_rows: usize, n_cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> DenseMatrix {
        let mut m = DenseMatrix::zeros(n_rows, n_cols);
        for i in 0..n_rows {
            for j in 0..n_cols {
                *m.at_mut(i, j) = f(i, j);
            }
        }
        m
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.n_cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        &mut self.data[i * self.n_cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.n_cols..(i + 1) * self.n_cols]
    }

    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n_cols);
        (0..self.n_rows)
            .map(|i| self.row(i).iter().zip(x).map(|(a, b)| a * b).sum())
            .collect()
    }

    pub fn matmul(&self, other: &DenseMatrix) -> DenseMatrix {
        assert_eq!(self.n_cols, other.n_rows);
        let mut out = DenseMatrix::zeros(self.n_rows, other.n_cols);
        for i in 0..self.n_rows {
            for k in 0..self.n_cols {
                let aik = self.at(i, k);
                if aik == 0.0 {
                    continue;
                }
                for j in 0..other.n_cols {
                    *out.at_mut(i, j) += aik * other.at(k, j);
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> DenseMatrix {
        DenseMatrix::from_fn(self.n_cols, self.n_rows, |i, j| self.at(j, i))
    }

    pub fn add_diag(&mut self, d: f64) {
        let n = self.n_rows.min(self.n_cols);
        for i in 0..n {
            *self.at_mut(i, i) += d;
        }
    }

    /// Lower-triangular Cholesky `A = L Lᵀ`. Errors if not positive definite.
    pub fn cholesky(&self) -> Result<DenseCholesky, String> {
        assert_eq!(self.n_rows, self.n_cols);
        let n = self.n_rows;
        let mut l = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..=i {
                let mut sum = self.at(i, j);
                for k in 0..j {
                    sum -= l[i * n + k] * l[j * n + k];
                }
                if i == j {
                    if sum <= 0.0 {
                        return Err(format!("not positive definite at pivot {i} ({sum})"));
                    }
                    l[i * n + j] = sum.sqrt();
                } else {
                    l[i * n + j] = sum / l[j * n + j];
                }
            }
        }
        Ok(DenseCholesky { n, l })
    }

    /// Solve A x = b via an internal Cholesky (A must be SPD).
    pub fn solve_spd(&self, b: &[f64]) -> Result<Vec<f64>, String> {
        Ok(self.cholesky()?.solve(b))
    }

    /// Explicit inverse of an SPD matrix (tests / Takahashi oracle).
    pub fn inverse_spd(&self) -> Result<DenseMatrix, String> {
        let ch = self.cholesky()?;
        let n = self.n_rows;
        let mut inv = DenseMatrix::zeros(n, n);
        let mut e = vec![0.0; n];
        for j in 0..n {
            e[j] = 1.0;
            let x = ch.solve(&e);
            e[j] = 0.0;
            for i in 0..n {
                *inv.at_mut(i, j) = x[i];
            }
        }
        Ok(inv)
    }

    pub fn max_abs_diff(&self, other: &DenseMatrix) -> f64 {
        assert_eq!(self.data.len(), other.data.len());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

/// Dense lower Cholesky factor.
#[derive(Clone, Debug)]
pub struct DenseCholesky {
    pub n: usize,
    /// Row-major lower-triangular factor (upper part zero).
    pub l: Vec<f64>,
}

impl DenseCholesky {
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.l[i * self.n + j]
    }

    /// Solve L y = b (forward substitution).
    pub fn solve_lower(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n;
        let mut y = b.to_vec();
        for i in 0..n {
            for k in 0..i {
                y[i] -= self.l[i * n + k] * y[k];
            }
            y[i] /= self.l[i * n + i];
        }
        y
    }

    /// Solve Lᵀ x = y (backward substitution).
    pub fn solve_upper(&self, y: &[f64]) -> Vec<f64> {
        let n = self.n;
        let mut x = y.to_vec();
        for i in (0..n).rev() {
            for k in i + 1..n {
                x[i] -= self.l[k * n + i] * x[k];
            }
            x[i] /= self.l[i * n + i];
        }
        x
    }

    /// Solve A x = b.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        self.solve_upper(&self.solve_lower(b))
    }

    /// log |A| = 2 Σ log L_ii.
    pub fn logdet(&self) -> f64 {
        (0..self.n).map(|i| self.l[i * self.n + i].ln()).sum::<f64>() * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_spd(n: usize, seed: u64) -> DenseMatrix {
        let mut rng = Rng::new(seed);
        let g = DenseMatrix::from_fn(n, n, |_, _| rng.normal());
        let mut a = g.matmul(&g.transpose());
        a.add_diag(n as f64 * 0.5);
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = random_spd(12, 3);
        let ch = a.cholesky().unwrap();
        let l = DenseMatrix { n_rows: 12, n_cols: 12, data: ch.l.clone() };
        let rec = l.matmul(&l.transpose());
        assert!(rec.max_abs_diff(&a) < 1e-9);
    }

    #[test]
    fn solve_matches_matvec() {
        let a = random_spd(15, 4);
        let mut rng = Rng::new(5);
        let x = rng.normal_vec(15);
        let b = a.matvec(&x);
        let x2 = a.solve_spd(&b).unwrap();
        for (u, v) in x.iter().zip(&x2) {
            assert!((u - v).abs() < 1e-8, "{u} vs {v}");
        }
    }

    #[test]
    fn inverse_spd_is_inverse() {
        let a = random_spd(8, 6);
        let inv = a.inverse_spd().unwrap();
        let prod = a.matmul(&inv);
        assert!(prod.max_abs_diff(&DenseMatrix::identity(8)) < 1e-9);
    }

    #[test]
    fn logdet_matches_2x2() {
        let a = DenseMatrix::from_fn(2, 2, |i, j| if i == j { 3.0 } else { 1.0 });
        let det: f64 = 3.0 * 3.0 - 1.0;
        assert!((a.cholesky().unwrap().logdet() - det.ln()).abs() < 1e-12);
    }

    #[test]
    fn not_pd_errors() {
        let a = DenseMatrix::from_fn(2, 2, |i, j| if i == j { -1.0 } else { 0.0 });
        assert!(a.cholesky().is_err());
    }
}
