//! `fault` — deterministic fault injection for exercising recovery paths.
//!
//! Recovery code that only runs when the numerics go bad is recovery code
//! that never runs in CI. This module turns each failure the stack claims
//! to survive — a lost pivot, a NaN site update, a straggling pool chunk —
//! into something a test can *schedule*: a [`Plan`] names exact injection
//! points, the kernels consult it at well-defined probes, and every fault
//! fires exactly once so the retry that follows sees clean numerics.
//!
//! Determinism: each injection point is owned by exactly one task — an
//! elimination column is factored by one chunk, an EP site visit happens
//! on the (serial) sweep driver — so consuming a fault is race-free and
//! the injected failure, and therefore the recovery sequence it triggers,
//! is identical at every `CSGP_THREADS` width. Slow-chunk faults perturb
//! timing only and can never change results (the pool's width contract).
//!
//! Activation, in precedence order:
//!
//! * programmatically via [`with_plan`] — tests; serialized process-wide
//!   (like [`crate::obs::with_mode`]) so concurrent tests cannot observe
//!   each other's plans;
//! * the `CSGP_FAULT` environment variable, parsed lazily once, e.g.
//!   `CSGP_FAULT="pivot@12;nansite@1:7;slowchunk@3:25"`. Entries are
//!   `;`-separated:
//!   - `pivot@COL` — the first factorization attempt to eliminate
//!     post-ordering column `COL` reports a non-positive pivot;
//!   - `nansite@SWEEP:SITE` — EP sweep `SWEEP` (0-based) poisons the
//!     site-`SITE` update to NaN;
//!   - `slowchunk@INDEX[:MS]` — sleep `MS` ms (default 20) before pool
//!     chunk `INDEX` runs;
//!   - `io@OP` — the next I/O operation labelled `OP` (e.g.
//!     `snapshot.save`) fails before any durable effect, once.
//!
//! With no plan installed every probe is a single relaxed atomic load —
//! the same near-zero disabled cost as [`crate::obs`]. Each fired fault
//! bumps `obs::counters::FAULTS_INJECTED` so tests can assert the
//! injection actually happened (and clean runs can assert it did not).

use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

use crate::obs;

#[derive(Debug)]
struct PivotFault {
    col: usize,
    fired: AtomicBool,
}

#[derive(Debug)]
struct NanSiteFault {
    sweep: usize,
    site: usize,
    fired: AtomicBool,
}

#[derive(Debug)]
struct SlowChunkFault {
    chunk: usize,
    millis: u64,
    fired: AtomicBool,
}

#[derive(Debug)]
struct IoFault {
    op: String,
    fired: AtomicBool,
}

/// A deterministic fault-injection plan: a finite set of one-shot faults,
/// each keyed to an exact point in the computation. Build one with the
/// chained constructors ([`Plan::pivot`], [`Plan::nan_site`],
/// [`Plan::slow_chunk`]) or parse the `CSGP_FAULT` syntax with
/// [`Plan::parse`]; install it with [`with_plan`].
#[derive(Debug, Default)]
pub struct Plan {
    pivots: Vec<PivotFault>,
    nan_sites: Vec<NanSiteFault>,
    slow_chunks: Vec<SlowChunkFault>,
    ios: Vec<IoFault>,
}

impl Plan {
    /// An empty plan (no faults).
    pub fn new() -> Plan {
        Plan::default()
    }

    /// Fail the pivot of post-ordering elimination column `col` on the
    /// first factorization attempt that reaches it (consumed once, so
    /// the jittered retry succeeds).
    pub fn pivot(mut self, col: usize) -> Plan {
        self.pivots.push(PivotFault { col, fired: AtomicBool::new(false) });
        self
    }

    /// Poison the site-`site` update of EP sweep `sweep` (0-based sweep
    /// ordinal, which keeps advancing across rollbacks) to NaN, once.
    pub fn nan_site(mut self, sweep: usize, site: usize) -> Plan {
        self.nan_sites.push(NanSiteFault { sweep, site, fired: AtomicBool::new(false) });
        self
    }

    /// Sleep `millis` ms before pool chunk `chunk` runs, once. Timing
    /// only — results are unaffected by construction.
    pub fn slow_chunk(mut self, chunk: usize, millis: u64) -> Plan {
        self.slow_chunks.push(SlowChunkFault { chunk, millis, fired: AtomicBool::new(false) });
        self
    }

    /// Fail the next I/O operation labelled `op` (see
    /// [`should_fail_io`]) before it has any durable effect, once.
    pub fn io(mut self, op: &str) -> Plan {
        self.ios.push(IoFault { op: op.to_string(), fired: AtomicBool::new(false) });
        self
    }

    /// Does this plan inject anything at all?
    pub fn is_empty(&self) -> bool {
        self.pivots.is_empty()
            && self.nan_sites.is_empty()
            && self.slow_chunks.is_empty()
            && self.ios.is_empty()
    }

    /// Parse the `CSGP_FAULT` syntax (see the module docs for the
    /// grammar). Whitespace around entries is ignored; empty entries are
    /// skipped, so a trailing `;` is fine.
    pub fn parse(raw: &str) -> Result<Plan, String> {
        let mut plan = Plan::new();
        for entry in raw.split(';') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let (kind, args) = entry
                .split_once('@')
                .ok_or_else(|| format!("fault entry `{entry}` is missing `@`"))?;
            let num = |s: &str| {
                s.trim()
                    .parse::<usize>()
                    .map_err(|_| format!("bad number `{}` in fault entry `{entry}`", s.trim()))
            };
            match kind.trim() {
                "pivot" => plan = plan.pivot(num(args)?),
                "nansite" => {
                    let (a, b) = args
                        .split_once(':')
                        .ok_or_else(|| format!("`{entry}` needs nansite@SWEEP:SITE"))?;
                    plan = plan.nan_site(num(a)?, num(b)?);
                }
                "slowchunk" => {
                    let (c, ms) = match args.split_once(':') {
                        Some((c, ms)) => (num(c)?, num(ms)? as u64),
                        None => (num(args)?, 20),
                    };
                    plan = plan.slow_chunk(c, ms);
                }
                "io" => {
                    let op = args.trim();
                    if op.is_empty() {
                        return Err(format!("`{entry}` needs io@OP"));
                    }
                    plan = plan.io(op);
                }
                other => return Err(format!("unknown fault kind `{other}` in `{entry}`")),
            }
        }
        Ok(plan)
    }

    /// Re-arm every fault (a plan installed by [`with_plan`] always
    /// starts fresh, even if the same `Plan` value was fired before).
    fn reset(&self) {
        for p in &self.pivots {
            p.fired.store(false, Ordering::Relaxed);
        }
        for s in &self.nan_sites {
            s.fired.store(false, Ordering::Relaxed);
        }
        for c in &self.slow_chunks {
            c.fired.store(false, Ordering::Relaxed);
        }
        for f in &self.ios {
            f.fired.store(false, Ordering::Relaxed);
        }
    }
}

// ---------------------------------------------------------------------------
// Global installation: one relaxed load on the disabled fast path.
// ---------------------------------------------------------------------------

const STATE_UNINIT: u8 = 0xFF;
const STATE_OFF: u8 = 0;
const STATE_ON: u8 = 1;
static STATE: AtomicU8 = AtomicU8::new(STATE_UNINIT);

fn store() -> &'static Mutex<Option<Arc<Plan>>> {
    static STORE: OnceLock<Mutex<Option<Arc<Plan>>>> = OnceLock::new();
    STORE.get_or_init(|| Mutex::new(None))
}

#[cold]
fn init_from_env() -> bool {
    let plan = match std::env::var("CSGP_FAULT") {
        Ok(raw) if !raw.trim().is_empty() => match Plan::parse(&raw) {
            Ok(p) if !p.is_empty() => Some(Arc::new(p)),
            Ok(_) => None,
            Err(e) => {
                eprintln!("csgp: ignoring invalid CSGP_FAULT: {e}");
                None
            }
        },
        _ => None,
    };
    let mut guard = store().lock().unwrap_or_else(|e| e.into_inner());
    // A `with_plan` that raced in first wins; only fill the uninit slot.
    if STATE.load(Ordering::Relaxed) == STATE_UNINIT {
        let on = plan.is_some();
        *guard = plan;
        STATE.store(if on { STATE_ON } else { STATE_OFF }, Ordering::Relaxed);
    }
    STATE.load(Ordering::Relaxed) == STATE_ON
}

/// Is any fault plan installed? One relaxed load once initialized.
#[inline]
fn active() -> bool {
    match STATE.load(Ordering::Relaxed) {
        STATE_UNINIT => init_from_env(),
        s => s == STATE_ON,
    }
}

fn current() -> Option<Arc<Plan>> {
    store().lock().unwrap_or_else(|e| e.into_inner()).clone()
}

/// Run `f` with `plan` installed as the process fault plan, restoring the
/// previous plan (env-derived or none) afterwards, even on panic. Like
/// [`obs::with_mode`], callers are serialized through an internal lock so
/// concurrent tests cannot observe each other's plans; the lock is not
/// reentrant, so do not nest `with_plan` calls on one thread.
pub fn with_plan<T>(plan: Plan, f: impl FnOnce() -> T) -> T {
    static LOCK: Mutex<()> = Mutex::new(());
    let guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _ = active(); // force lazy env init so we restore the right state
    let prev_state = STATE.load(Ordering::Relaxed);
    let prev_plan = current();
    plan.reset();
    *store().lock().unwrap_or_else(|e| e.into_inner()) = Some(Arc::new(plan));
    STATE.store(STATE_ON, Ordering::Relaxed);

    struct Restore<'a> {
        plan: Option<Arc<Plan>>,
        state: u8,
        _serial: std::sync::MutexGuard<'a, ()>,
    }
    impl Drop for Restore<'_> {
        fn drop(&mut self) {
            *store().lock().unwrap_or_else(|e| e.into_inner()) = self.plan.take();
            STATE.store(self.state, Ordering::Relaxed);
        }
    }
    let _restore = Restore { plan: prev_plan, state: prev_state, _serial: guard };
    f()
}

// ---------------------------------------------------------------------------
// Probes — the library's injection points.
// ---------------------------------------------------------------------------

/// Factorization probe: should the pivot of post-ordering column `col`
/// be reported non-positive on this attempt? Consuming — returns `true`
/// at most once per armed `pivot@col` fault. Only the task that owns
/// column `col` calls this, so consumption is race-free at any width.
pub fn should_fail_pivot(col: usize) -> bool {
    if !active() {
        return false;
    }
    let Some(plan) = current() else { return false };
    for p in &plan.pivots {
        if p.col == col && !p.fired.swap(true, Ordering::Relaxed) {
            obs::counters::FAULTS_INJECTED.add(1);
            return true;
        }
    }
    false
}

/// EP probe: should the site-`site` update of sweep `sweep` be poisoned
/// to NaN? Consuming. Called from the (serial) sweep driver only.
pub fn should_poison_site(sweep: usize, site: usize) -> bool {
    if !active() {
        return false;
    }
    let Some(plan) = current() else { return false };
    for f in &plan.nan_sites {
        if f.sweep == sweep && f.site == site && !f.fired.swap(true, Ordering::Relaxed) {
            obs::counters::FAULTS_INJECTED.add(1);
            return true;
        }
    }
    false
}

/// I/O probe: should the operation labelled `op` fail on this attempt?
/// Consuming. Callers probe *before* any durable effect (e.g. the
/// snapshot writer probes before publishing its temp file), so an
/// injected failure models a crash that leaves no partial artifact.
pub fn should_fail_io(op: &str) -> bool {
    if !active() {
        return false;
    }
    let Some(plan) = current() else { return false };
    for f in &plan.ios {
        if f.op == op && !f.fired.swap(true, Ordering::Relaxed) {
            obs::counters::FAULTS_INJECTED.add(1);
            return true;
        }
    }
    false
}

/// Pool probe: sleep before chunk `chunk` if a `slowchunk` fault is
/// armed for it. Consuming; affects timing only, never results.
pub fn maybe_slow_chunk(chunk: usize) {
    if !active() {
        return;
    }
    let Some(plan) = current() else { return };
    for f in &plan.slow_chunks {
        if f.chunk == chunk && !f.fired.swap(true, Ordering::Relaxed) {
            obs::counters::FAULTS_INJECTED.add(1);
            std::thread::sleep(Duration::from_millis(f.millis));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_the_documented_grammar() {
        let p =
            Plan::parse("pivot@12; nansite@1:7 ;slowchunk@3:25;slowchunk@9;io@snapshot.save;")
                .unwrap();
        assert_eq!(p.pivots.len(), 1);
        assert_eq!(p.pivots[0].col, 12);
        assert_eq!(p.nan_sites.len(), 1);
        assert_eq!((p.nan_sites[0].sweep, p.nan_sites[0].site), (1, 7));
        assert_eq!(p.slow_chunks.len(), 2);
        assert_eq!(p.slow_chunks[0].millis, 25);
        assert_eq!(p.slow_chunks[1].millis, 20); // default
        assert_eq!(p.ios.len(), 1);
        assert_eq!(p.ios[0].op, "snapshot.save");
        assert!(Plan::parse("").unwrap().is_empty());
    }

    #[test]
    fn parse_rejects_malformed_entries() {
        assert!(Plan::parse("pivot12").is_err());
        assert!(Plan::parse("pivot@twelve").is_err());
        assert!(Plan::parse("nansite@3").is_err());
        assert!(Plan::parse("frobnicate@1").is_err());
        assert!(Plan::parse("io@ ").is_err());
    }

    #[test]
    fn io_faults_fire_once_per_labelled_op() {
        with_plan(Plan::new().io("snapshot.save"), || {
            assert!(!should_fail_io("snapshot.load"), "wrong op must not fire");
            assert!(should_fail_io("snapshot.save"), "armed fault fires");
            assert!(!should_fail_io("snapshot.save"), "fault is consumed");
        });
    }

    #[test]
    fn faults_fire_exactly_once_and_only_under_a_plan() {
        // Outside any plan every probe is inert.
        assert!(!should_fail_pivot(5) || active(), "no plan, no faults");
        with_plan(Plan::new().pivot(5).nan_site(0, 2), || {
            assert!(!should_fail_pivot(4), "wrong column must not fire");
            assert!(should_fail_pivot(5), "armed fault fires");
            assert!(!should_fail_pivot(5), "fault is consumed");
            assert!(should_poison_site(0, 2));
            assert!(!should_poison_site(0, 2));
            assert!(!should_poison_site(1, 2), "wrong sweep must not fire");
        });
    }

    #[test]
    fn with_plan_rearms_and_restores() {
        let plan = || Plan::new().pivot(3);
        with_plan(plan(), || assert!(should_fail_pivot(3)));
        // a fresh installation starts fresh
        with_plan(plan(), || assert!(should_fail_pivot(3)));
    }
}
