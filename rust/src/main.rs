//! `csgp` CLI — leader entrypoint for the sparse-EP GP classification
//! system.
//!
//! Subcommands (argument parsing is hand-rolled; no clap offline):
//!
//! * `train     --data <cluster2d|cluster5d|uci:<name>> --n <n> --cov <se|pp0..3> [--inference <dense|sparse|parallel|fic|csfic>] [--ordering <natural|rcm|mindeg|nd|auto>] [--optimize] [--snapshot-save <path>]`
//!   (`csfic` pairs the compact `--cov` with a global SE term;
//!   `--global-lengthscale` and `--m` tune the hybrid; `--ordering`
//!   defaults to `auto` — the pattern-statistics policy — and applies to
//!   every sparse-factorization backend, `csfic` included;
//!   `--snapshot-save` persists the fitted model to a versioned binary
//!   snapshot)
//! * `cv        --data uci:<name> --cov pp3 --folds 10`
//! * `serve     --n <train size> [--requests <r>] [--batch <b>] [--queue <capacity>] [--snapshot-load <path>] [--online-append <k>]` — demo server + load
//!   (`--snapshot-load` serves a previously saved model instead of
//!   fitting; `--online-append` absorbs k fresh points through the
//!   incremental EP update before serving — the model/cov flags must
//!   match the snapshot's configuration for the fast paths to engage)
//! * `snapshot  --probe <path>` — validate a snapshot container (magic, version, checksum) and report its backend
//! * `artifacts-check` — verify the PJRT artifacts load and agree with native code
//! * `fill      --n <n> --dim <2|5> --cov pp3` — fill-K/fill-L statistics (Table 1)
//! * `trace     analyze <trace.jsonl> [--json]` — aggregate a span/metrics
//!   JSONL file into a hierarchical profile with flops/s, pool
//!   utilization and the measured-vs-predicted cost-model table;
//!   `trace diff <a.jsonl> <b.jsonl> [--tolerance 0.25] [--json]`
//!   compares two traces and flags drifting phases
//!
//! `serve` also takes `--metrics <path>`: a background exporter appends
//! one JSONL counters/latency snapshot per `CSGP_METRICS_INTERVAL_MS`
//! (default 1000) while serving. On SIGINT/SIGTERM the trace sink, the
//! obs summary and a final metrics snapshot are flushed before exit, so
//! interrupted servers keep their telemetry.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use csgp::coordinator::{PredictionService, ServiceConfig};
use csgp::data::synthetic::{cluster_dataset, ClusterConfig};
use csgp::data::{cv, uci, Dataset};
use csgp::gp::covariance::{CovFunction, CovKind};
use csgp::gp::model::{FittedClassifier, GpClassifier, Inference};
use csgp::rng::Rng;
use csgp::runtime::Runtime;
use csgp::sparse::ordering::Ordering;

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    flags
}

fn load_dataset(spec: &str, n: usize, seed: u64) -> Result<Dataset, String> {
    if spec == "cluster2d" {
        Ok(cluster_dataset(&ClusterConfig::paper_2d(n), seed))
    } else if spec == "cluster5d" {
        Ok(cluster_dataset(&ClusterConfig::paper_5d(n), seed))
    } else if let Some(name) = spec.strip_prefix("uci:") {
        uci::UCI_SPECS
            .iter()
            .find(|s| s.name == name)
            .map(|s| uci::generate(s, seed))
            .ok_or_else(|| format!("unknown uci dataset '{name}'"))
    } else {
        Err(format!("unknown dataset spec '{spec}'"))
    }
}

fn build_model(flags: &HashMap<String, String>, dim: usize) -> Result<GpClassifier, String> {
    let kind = CovKind::parse(flags.get("cov").map(String::as_str).unwrap_or("pp3"))?;
    let ls: f64 = flags.get("lengthscale").map(|s| s.parse().unwrap()).unwrap_or(2.0);
    let s2: f64 = flags.get("magnitude").map(|s| s.parse().unwrap()).unwrap_or(1.0);
    let cov = CovFunction::new(kind, dim, s2, ls);
    let ordering: Ordering =
        flags.get("ordering").map(String::as_str).unwrap_or("auto").parse()?;
    let inference_str = flags.get("inference").map(String::as_str).unwrap_or("sparse");
    if inference_str == "csfic" {
        // CS+FIC hybrid: --cov is the compact local term, the global SE
        // trend gets --global-lengthscale (default 2x the local one)
        let m = flags.get("m").map(|s| s.parse().unwrap()).unwrap_or(64);
        let gls: f64 = flags
            .get("global-lengthscale")
            .map(|s| s.parse().unwrap())
            .unwrap_or(2.0 * ls);
        let global = CovFunction::new(CovKind::Se, dim, s2, gls);
        // the CLI ordering drives the hybrid's CS block too
        return GpClassifier::new_cs_fic_with_ordering(cov, global, m, ordering);
    }
    let inference = match inference_str {
        "dense" => Inference::Dense,
        "sparse" => Inference::Sparse(ordering),
        "parallel" => Inference::Parallel(ordering),
        "fic" => Inference::Fic {
            m: flags.get("m").map(|s| s.parse().unwrap()).unwrap_or(64),
        },
        other => return Err(format!("unknown inference '{other}'")),
    };
    Ok(GpClassifier::new(cov, inference))
}

fn cmd_train(flags: HashMap<String, String>) -> Result<(), String> {
    let n: usize = flags.get("n").map(|s| s.parse().unwrap()).unwrap_or(500);
    let seed: u64 = flags.get("seed").map(|s| s.parse().unwrap()).unwrap_or(1);
    let spec = flags.get("data").cloned().unwrap_or_else(|| "cluster2d".into());
    let data = load_dataset(&spec, n + n / 2, seed)?;
    let (train, test) = data.split(n.min(data.n() * 2 / 3));
    let model = build_model(&flags, train.dim())?;
    println!(
        "training on {} (n={}, d={}) cov={:?} inference={:?}",
        train.name,
        train.n(),
        train.dim(),
        model.cov.kind,
        model.inference
    );
    let fitted = if flags.contains_key("optimize") {
        model.fit(&train.x, &train.y)?
    } else {
        model.infer_only(&train.x, &train.y)?
    };
    let m = fitted.evaluate(&test.x, &test.y);
    println!(
        "logZ = {:.4}  fill-K = {:.3}  fill-L = {:.3}  opt = {:?} ({} iters)  EP = {:?}",
        fitted.report.log_z,
        fitted.report.fill_k,
        fitted.report.fill_l,
        fitted.report.opt_time,
        fitted.report.opt_iters,
        fitted.report.ep_time
    );
    println!("test err = {:.4}  nlpd = {:.4}  (n_test = {})", m.err, m.nlpd, m.n);
    if let Some(path) = flags.get("snapshot-save") {
        fitted
            .save_snapshot(std::path::Path::new(path))
            .map_err(|e| format!("snapshot save failed: {e}"))?;
        println!("snapshot saved to {path}");
    }
    Ok(())
}

fn cmd_cv(flags: HashMap<String, String>) -> Result<(), String> {
    let spec = flags.get("data").cloned().unwrap_or_else(|| "uci:crabs".into());
    let seed: u64 = flags.get("seed").map(|s| s.parse().unwrap()).unwrap_or(1);
    let folds: usize = flags.get("folds").map(|s| s.parse().unwrap()).unwrap_or(10);
    let data = load_dataset(&spec, 0, seed)?;
    let model = build_model(&flags, data.dim())?;
    let optimize = flags.contains_key("optimize");
    let res = cv::cross_validate(&model, &data, folds, optimize, seed)?;
    println!(
        "{}: err = {:.3}  nlpd = {:.3}  opt = {:?}  EP = {:?}  fill-L = {:.2}",
        data.name, res.err, res.nlpd, res.opt_time, res.ep_time, res.fill_l
    );
    Ok(())
}

fn cmd_serve(flags: HashMap<String, String>) -> Result<(), String> {
    let n: usize = flags.get("n").map(|s| s.parse().unwrap()).unwrap_or(500);
    let requests: usize = flags.get("requests").map(|s| s.parse().unwrap()).unwrap_or(2000);
    let batch: usize = flags.get("batch").map(|s| s.parse().unwrap()).unwrap_or(256);
    let queue: usize = flags
        .get("queue")
        .map(|s| s.parse().unwrap())
        .unwrap_or(ServiceConfig::default().queue_capacity);
    let mut fitted = if let Some(path) = flags.get("snapshot-load") {
        let path = std::path::Path::new(path);
        let info =
            csgp::gp::snapshot::probe(path).map_err(|e| format!("snapshot probe failed: {e}"))?;
        println!(
            "loading snapshot {} (v{}, backend {}, {} payload bytes)",
            path.display(),
            info.version,
            info.backend,
            info.payload_len
        );
        FittedClassifier::load_snapshot(path).map_err(|e| format!("snapshot load failed: {e}"))?
    } else {
        let data = cluster_dataset(&ClusterConfig::paper_2d(n), 7);
        let model = build_model(&flags, 2)?;
        println!("fitting serving model on n={n}...");
        model.infer_only(&data.x, &data.y)?
    };
    if let Some(k) = flags.get("online-append") {
        let k: usize = k.parse().map_err(|_| "bad --online-append".to_string())?;
        let dim = fitted.x.first().map(Vec::len).unwrap_or(2);
        if dim != 2 {
            return Err("--online-append demo generates 2-d cluster points".into());
        }
        let extra = cluster_dataset(&ClusterConfig::paper_2d(k), 99);
        let model = build_model(&flags, dim)?;
        let (updated, rep) = model.update(&fitted, &extra.x, &extra.y)?;
        println!(
            "online update: +{} points via {:?} in {:?} ({} sweeps, n now {})",
            rep.k_new,
            rep.path,
            rep.update_time,
            rep.sweeps,
            updated.x.len()
        );
        fitted = updated;
    }
    let fitted = Arc::new(fitted);
    let artifact_dir = std::path::PathBuf::from(
        std::env::var("CSGP_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string()),
    );
    let artifacts = artifact_dir.join("manifest.json").exists().then_some(artifact_dir);
    println!(
        "probability stage: {}",
        if artifacts.is_some() { "XLA predict_probit artifact" } else { "native probit" }
    );
    let svc = Arc::new(PredictionService::start(
        fitted,
        artifacts,
        ServiceConfig {
            max_batch: batch,
            max_wait: Duration::from_millis(2),
            queue_capacity: queue,
        },
    ));
    // --metrics [path]: periodic counters/latency snapshots while serving
    let metrics = match flags.get("metrics") {
        Some(p) => {
            let path = if p == "true" { "metrics.jsonl" } else { p.as_str() };
            let interval = csgp::coordinator::metrics_interval_from_env();
            let exporter = csgp::coordinator::MetricsExporter::start(
                path,
                interval,
                Some(svc.stats.clone()),
            )
            .map_err(|e| format!("cannot open metrics file '{path}': {e}"))?;
            println!("metrics to {path} every {interval:?}");
            Some(exporter)
        }
        None => None,
    };
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    let client_count = 8;
    for c in 0..client_count {
        let svc = svc.clone();
        let per_client = requests / client_count;
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(c as u64);
            let mut lat = Vec::with_capacity(per_client);
            for _ in 0..per_client {
                let x = vec![rng.uniform_in(0.0, 10.0), rng.uniform_in(0.0, 10.0)];
                let p = svc.predict(x).unwrap();
                lat.push(p.service_time);
            }
            lat
        }));
    }
    let mut latencies: Vec<Duration> = Vec::new();
    for h in handles {
        latencies.extend(h.join().unwrap());
    }
    let wall = t0.elapsed();
    let total = latencies.len();
    let stats = csgp::bench::Stats::from_samples(latencies);
    println!(
        "served {total} requests in {:.3}s  ({:.0} req/s)",
        wall.as_secs_f64(),
        total as f64 / wall.as_secs_f64()
    );
    println!(
        "latency p50 = {:?}  p90 = {:?}  p99 = {:?}  max batch = {}  rejected = {}",
        stats.p50,
        stats.p90,
        stats.p99,
        svc.stats.batched_items_max.load(std::sync::atomic::Ordering::Relaxed),
        svc.stats.rejected.load(std::sync::atomic::Ordering::Relaxed)
    );
    if let Some(b) = svc.stats.batch_latency_stats() {
        println!("batch compute p50 = {:?}  p99 = {:?}  over {} batches", b.p50, b.p99, b.iters);
    }
    if let Some(m) = &metrics {
        m.stop(); // writes the final snapshot line
    }
    svc.shutdown();
    Ok(())
}

fn load_profile(path: &str) -> Result<csgp::obs::Profile, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("cannot read '{path}': {e}"))?;
    let data = csgp::obs::parse_trace(&text).map_err(|e| format!("{path}: {e}"))?;
    if data.spans.is_empty() && data.metrics.is_empty() {
        return Err(format!("{path}: no span or metrics events found"));
    }
    Ok(csgp::obs::Profile::from_trace(&data))
}

fn cmd_trace(args: &[String]) -> Result<(), String> {
    let usage = "trace: expected 'analyze <trace.jsonl> [--json]' or \
                 'diff <a.jsonl> <b.jsonl> [--tolerance 0.25] [--json]'";
    let Some(sub) = args.first() else {
        return Err(usage.into());
    };
    let mut paths: Vec<&str> = Vec::new();
    let mut json = false;
    let mut tolerance = 0.25_f64;
    let mut i = 1;
    while i < args.len() {
        match args[i].as_str() {
            "--json" => json = true,
            "--tolerance" => {
                i += 1;
                tolerance = args
                    .get(i)
                    .and_then(|s| s.parse().ok())
                    .ok_or("trace: --tolerance needs a number")?;
            }
            a if !a.starts_with("--") => paths.push(a),
            a => return Err(format!("trace: unknown flag '{a}'")),
        }
        i += 1;
    }
    match (sub.as_str(), paths.as_slice()) {
        ("analyze", [path]) => {
            let profile = load_profile(path)?;
            print!("{}", if json { profile.render_json() } else { profile.render_text() });
            Ok(())
        }
        ("diff", [a, b]) => {
            let pa = load_profile(a)?;
            let pb = load_profile(b)?;
            let d = csgp::obs::profile::diff(&pa, &pb, tolerance);
            print!("{}", if json { d.render_json() } else { d.render_text() });
            Ok(())
        }
        _ => Err(usage.into()),
    }
}

/// Install a SIGINT/SIGTERM watchdog that flushes telemetry before exit:
/// final metrics snapshots for every live exporter, the obs summary, and
/// the trace sink. The handler itself only sets a flag (async-signal
/// safe); a polling thread does the I/O and exits with the conventional
/// 128+SIGINT status.
#[cfg(unix)]
fn install_signal_flush() {
    use std::sync::atomic::{AtomicBool, Ordering};
    static INTERRUPTED: AtomicBool = AtomicBool::new(false);
    extern "C" fn on_signal(_sig: i32) {
        INTERRUPTED.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    unsafe {
        signal(2, on_signal as usize); // SIGINT
        signal(15, on_signal as usize); // SIGTERM
    }
    std::thread::spawn(|| loop {
        if INTERRUPTED.load(Ordering::SeqCst) {
            csgp::coordinator::flush_all_exporters();
            if csgp::obs::counters_on() {
                eprintln!("{}", csgp::obs::summary());
            }
            match csgp::obs::flush() {
                Ok(0) => {}
                Ok(n) => eprintln!("flushed {n} trace spans"),
                Err(e) => eprintln!("warning: trace flush failed: {e}"),
            }
            std::process::exit(130);
        }
        std::thread::sleep(Duration::from_millis(50));
    });
}

#[cfg(not(unix))]
fn install_signal_flush() {}

fn cmd_snapshot(flags: HashMap<String, String>) -> Result<(), String> {
    let Some(path) = flags.get("probe") else {
        return Err("snapshot: expected --probe <path>".into());
    };
    let info = csgp::gp::snapshot::probe(std::path::Path::new(path))
        .map_err(|e| format!("snapshot probe failed: {e}"))?;
    println!(
        "{path}: version {} backend {} payload {} bytes (checksum OK)",
        info.version, info.backend, info.payload_len
    );
    Ok(())
}

fn cmd_artifacts_check() -> Result<(), String> {
    let rt = Runtime::open_default()?;
    println!(
        "runtime backend: {} (manifest {})",
        rt.platform(),
        if rt.artifacts_present() { "validated" } else { "absent" }
    );
    let (lnz, muh, s2h) = rt.probit_moments(&[1.0, -1.0], &[0.5, -0.5], &[1.0, 2.0])?;
    for i in 0..2 {
        let (l, m, s) = csgp::gp::likelihood::probit_moments(
            [1.0, -1.0][i],
            [0.5, -0.5][i],
            [1.0, 2.0][i],
        );
        assert!((lnz[i] - l).abs() < 1e-10 && (muh[i] - m).abs() < 1e-10);
        assert!((s2h[i] - s).abs() < 1e-10);
    }
    println!("probit_moments: runtime == likelihood reference OK");
    // compare the runtime's assembly against the independent brute-force
    // path (on the native backend the default assembly is index-backed, so
    // this is a genuine cross-check, not the same code path twice)
    let x: Vec<Vec<f64>> = (0..140).map(|i| vec![(i % 12) as f64, (i / 12) as f64]).collect();
    let cov = CovFunction::new(CovKind::Pp(3), 2, 1.0, 2.0);
    let k_rt = rt.cov_matrix(&cov, &x)?;
    let k_ref = cov.cov_matrix_brute(&x);
    assert_eq!(k_rt.col_ptr, k_ref.col_ptr);
    assert_eq!(k_rt.row_idx, k_ref.row_idx);
    let max_diff = k_rt
        .values
        .iter()
        .zip(&k_ref.values)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f64::max);
    println!("cov_tile_pp3: runtime == brute-force reference (max |delta| = {max_diff:.2e}) OK");
    println!("artifacts OK");
    Ok(())
}

fn cmd_fill(flags: HashMap<String, String>) -> Result<(), String> {
    let n: usize = flags.get("n").map(|s| s.parse().unwrap()).unwrap_or(1000);
    let dim: usize = flags.get("dim").map(|s| s.parse().unwrap()).unwrap_or(2);
    let seed: u64 = flags.get("seed").map(|s| s.parse().unwrap()).unwrap_or(1);
    let cfg = if dim == 2 { ClusterConfig::paper_2d(n) } else { ClusterConfig::paper_5d(n) };
    let data = cluster_dataset(&cfg, seed);
    let model = build_model(&flags, dim)?;
    let fitted = model.infer_only(&data.x, &data.y)?;
    println!(
        "n = {n} dim = {dim}: fill-K = {:.3}  fill-L = {:.3}  ratio = {:.2}",
        fitted.report.fill_k,
        fitted.report.fill_l,
        fitted.report.fill_l / fitted.report.fill_k
    );
    Ok(())
}

fn cmd_profile(flags: HashMap<String, String>) -> Result<(), String> {
    let n: usize = flags.get("n").map(|s| s.parse().unwrap()).unwrap_or(1000);
    let dim: usize = flags.get("dim").map(|s| s.parse().unwrap()).unwrap_or(2);
    let ls: f64 = flags.get("lengthscale").map(|s| s.parse().unwrap()).unwrap_or(1.3);
    let cfg = if dim == 2 { ClusterConfig::paper_2d(n) } else { ClusterConfig::paper_5d(n) };
    let data = cluster_dataset(&cfg, 1);
    let cov = CovFunction::new(CovKind::Pp(3), dim, 1.0, ls);
    let metrics = csgp::metrics::Metrics::new();
    let t0 = std::time::Instant::now();
    let ep = csgp::gp::ep_sparse::SparseEp::run(
        &cov,
        &data.x,
        &data.y,
        Ordering::Rcm,
        &csgp::gp::marginal::EpOptions::default(),
        Some(&metrics),
    )?;
    let total = t0.elapsed();
    println!(
        "n = {n} dim = {dim}: EP {:?} over {} sweeps (fill-L {:.3}, logZ {:.2})",
        total, ep.sweeps, ep.fill_l, ep.log_z
    );
    println!("{}", metrics.report());
    Ok(())
}

fn usage() -> ! {
    eprintln!(
        "usage: csgp <train|cv|serve|snapshot|artifacts-check|fill|trace> [--flags ...]\n\
         see rust/src/main.rs header for the flag reference"
    );
    std::process::exit(2);
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    let flags = parse_flags(&args[1..]);
    // --trace [path]: full tracing to a JSONL sink (default trace.jsonl),
    // overriding whatever CSGP_TRACE says
    if let Some(path) = flags.get("trace") {
        let path = if path == "true" { "trace.jsonl" } else { path.as_str() };
        csgp::obs::set_mode(csgp::obs::TraceMode::Full);
        if let Err(e) = csgp::obs::set_sink(path) {
            eprintln!("error: cannot open trace sink '{path}': {e}");
            std::process::exit(1);
        }
        eprintln!("tracing to {path}");
    }
    install_signal_flush();
    let result = match cmd.as_str() {
        "train" => cmd_train(flags),
        "cv" => cmd_cv(flags),
        "serve" => cmd_serve(flags),
        "snapshot" => cmd_snapshot(flags),
        "artifacts-check" => cmd_artifacts_check(),
        "fill" => cmd_fill(flags),
        "profile" => cmd_profile(flags),
        "trace" => cmd_trace(&args[1..]),
        _ => usage(),
    };
    if csgp::obs::counters_on() {
        eprintln!("{}", csgp::obs::summary());
    }
    match csgp::obs::flush() {
        Ok(0) => {}
        Ok(n) => eprintln!("flushed {n} trace spans"),
        Err(e) => eprintln!("warning: trace flush failed: {e}"),
    }
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
