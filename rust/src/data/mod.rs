//! Data generation and evaluation harnesses.
//!
//! * [`synthetic`] — the paper's §6.1 simulation workload: uniform inputs
//!   in a hypercube labelled by nearest cluster centre.
//! * [`uci`] — synthetic stand-ins for the six UCI datasets of §6.2
//!   (identical n and d; see DESIGN.md §Substitutions).
//! * [`cv`] — k-fold cross-validation with the paper's metrics.
//! * [`kmeans`] — inducing-input selection for FIC.

pub mod cv;
pub mod kmeans;
pub mod synthetic;
pub mod uci;

/// A labelled binary-classification dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub x: Vec<Vec<f64>>,
    /// Labels in {−1, +1}.
    pub y: Vec<f64>,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.x.len()
    }

    pub fn dim(&self) -> usize {
        self.x.first().map(|v| v.len()).unwrap_or(0)
    }

    /// Split into (train, test) by index count.
    pub fn split(&self, n_train: usize) -> (Dataset, Dataset) {
        assert!(n_train <= self.n());
        let tr = Dataset {
            name: format!("{}-train", self.name),
            x: self.x[..n_train].to_vec(),
            y: self.y[..n_train].to_vec(),
        };
        let te = Dataset {
            name: format!("{}-test", self.name),
            x: self.x[n_train..].to_vec(),
            y: self.y[n_train..].to_vec(),
        };
        (tr, te)
    }

    /// Standardize features to zero mean / unit variance (fitted on self).
    pub fn standardize(&mut self) {
        let d = self.dim();
        let n = self.n() as f64;
        for j in 0..d {
            let mean = self.x.iter().map(|r| r[j]).sum::<f64>() / n;
            let var = self.x.iter().map(|r| (r[j] - mean) * (r[j] - mean)).sum::<f64>() / n;
            let sd = var.sqrt().max(1e-12);
            for r in self.x.iter_mut() {
                r[j] = (r[j] - mean) / sd;
            }
        }
    }

    /// Fraction of +1 labels.
    pub fn positive_rate(&self) -> f64 {
        self.y.iter().filter(|&&v| v > 0.0).count() as f64 / self.n() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_and_standardize() {
        let mut d = Dataset {
            name: "t".into(),
            x: vec![vec![1.0, 10.0], vec![3.0, 20.0], vec![5.0, 30.0], vec![7.0, 40.0]],
            y: vec![1.0, -1.0, 1.0, -1.0],
        };
        let (tr, te) = d.split(3);
        assert_eq!(tr.n(), 3);
        assert_eq!(te.n(), 1);
        d.standardize();
        for j in 0..2 {
            let mean: f64 = d.x.iter().map(|r| r[j]).sum::<f64>() / 4.0;
            assert!(mean.abs() < 1e-12);
        }
        assert!((d.positive_rate() - 0.5).abs() < 1e-12);
    }
}
