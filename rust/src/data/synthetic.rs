//! The paper's §6.1 simulation workload.
//!
//! "We constructed two data sets by sampling 15 000 inputs randomly from
//! the hypercubes [0 10]² and [0 10]⁵. After this we drew 200/1000 center
//! points which were assigned randomly to either class. Then each input
//! was assigned to the class of its nearest center point."

use crate::data::Dataset;
use crate::rng::Rng;

/// Configuration mirroring the paper's setup.
#[derive(Clone, Copy, Debug)]
pub struct ClusterConfig {
    pub n_points: usize,
    pub dim: usize,
    pub n_centers: usize,
    pub side: f64,
}

impl ClusterConfig {
    /// Paper's 2-D setting (downscalable via `n_points`).
    pub fn paper_2d(n_points: usize) -> Self {
        ClusterConfig { n_points, dim: 2, n_centers: 200, side: 10.0 }
    }

    /// Paper's 5-D setting.
    pub fn paper_5d(n_points: usize) -> Self {
        ClusterConfig { n_points, dim: 5, n_centers: 1000, side: 10.0 }
    }
}

/// Uniform random points in `[0, side]^d` (shared by tests and benches).
pub fn uniform_points(n: usize, d: usize, side: f64, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Rng::new(seed.wrapping_add(0x5151));
    (0..n).map(|_| (0..d).map(|_| rng.uniform_in(0.0, side)).collect()).collect()
}

/// Generate the nearest-centre cluster dataset.
pub fn cluster_dataset(cfg: &ClusterConfig, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let centers: Vec<Vec<f64>> = (0..cfg.n_centers)
        .map(|_| (0..cfg.dim).map(|_| rng.uniform_in(0.0, cfg.side)).collect())
        .collect();
    let center_class: Vec<f64> =
        (0..cfg.n_centers).map(|_| if rng.uniform() < 0.5 { 1.0 } else { -1.0 }).collect();
    let mut x = Vec::with_capacity(cfg.n_points);
    let mut y = Vec::with_capacity(cfg.n_points);
    for _ in 0..cfg.n_points {
        let p: Vec<f64> = (0..cfg.dim).map(|_| rng.uniform_in(0.0, cfg.side)).collect();
        let mut best = 0usize;
        let mut best_d = f64::INFINITY;
        for (c, cp) in centers.iter().enumerate() {
            let d: f64 = cp.iter().zip(&p).map(|(a, b)| (a - b) * (a - b)).sum();
            if d < best_d {
                best_d = d;
                best = c;
            }
        }
        x.push(p);
        y.push(center_class[best]);
    }
    Dataset { name: format!("cluster-{}d-n{}", cfg.dim, cfg.n_points), x, y }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_shape() {
        let d = cluster_dataset(&ClusterConfig::paper_2d(500), 1);
        assert_eq!(d.n(), 500);
        assert_eq!(d.dim(), 2);
        assert!(d.x.iter().all(|p| p.iter().all(|&v| (0.0..10.0).contains(&v))));
        assert!(d.y.iter().all(|&v| v == 1.0 || v == -1.0));
    }

    #[test]
    fn labels_are_roughly_balanced() {
        let d = cluster_dataset(&ClusterConfig::paper_2d(2000), 3);
        let rate = d.positive_rate();
        assert!(rate > 0.3 && rate < 0.7, "positive rate {rate}");
    }

    #[test]
    fn labels_are_spatially_coherent() {
        // nearest-centre labelling: a point's label should usually agree
        // with its nearest neighbour's label
        let d = cluster_dataset(&ClusterConfig::paper_2d(800), 5);
        let mut agree = 0;
        for i in 0..200 {
            let mut best = usize::MAX;
            let mut best_d = f64::INFINITY;
            for j in 0..d.n() {
                if i == j {
                    continue;
                }
                let dist: f64 =
                    d.x[i].iter().zip(&d.x[j]).map(|(a, b)| (a - b) * (a - b)).sum();
                if dist < best_d {
                    best_d = dist;
                    best = j;
                }
            }
            if d.y[i] == d.y[best] {
                agree += 1;
            }
        }
        assert!(agree > 140, "only {agree}/200 nearest neighbours agree");
    }

    #[test]
    fn deterministic_by_seed() {
        let a = cluster_dataset(&ClusterConfig::paper_5d(100), 42);
        let b = cluster_dataset(&ClusterConfig::paper_5d(100), 42);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }
}
