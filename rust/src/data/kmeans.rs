//! Lloyd's k-means with k-means++ seeding — used to place FIC inducing
//! inputs (DESIGN.md §Substitutions: the paper co-optimizes them; k-means
//! placement is the standard modern alternative and favours FIC's
//! optimization time if anything).

use crate::rng::Rng;

fn dist2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// k cluster centres of `x` (k-means++ init, `iters` Lloyd steps).
pub fn kmeans(x: &[Vec<f64>], k: usize, iters: usize, seed: u64) -> Vec<Vec<f64>> {
    let n = x.len();
    assert!(k >= 1);
    if k >= n {
        return x.to_vec();
    }
    let mut rng = Rng::new(seed);
    // k-means++ seeding
    let mut centers: Vec<Vec<f64>> = vec![x[rng.below(n)].clone()];
    let mut d2: Vec<f64> = x.iter().map(|p| dist2(p, &centers[0])).collect();
    while centers.len() < k {
        let total: f64 = d2.iter().sum();
        let mut pick = rng.uniform() * total;
        let mut idx = 0;
        for (i, &w) in d2.iter().enumerate() {
            pick -= w;
            if pick <= 0.0 {
                idx = i;
                break;
            }
        }
        centers.push(x[idx].clone());
        for (i, p) in x.iter().enumerate() {
            d2[i] = d2[i].min(dist2(p, centers.last().unwrap()));
        }
    }
    // Lloyd iterations
    let dim = x[0].len();
    let mut assign = vec![0usize; n];
    for _ in 0..iters {
        let mut changed = false;
        for (i, p) in x.iter().enumerate() {
            let best = (0..k)
                .min_by(|&a, &b| {
                    dist2(p, &centers[a]).partial_cmp(&dist2(p, &centers[b])).unwrap()
                })
                .unwrap();
            if assign[i] != best {
                assign[i] = best;
                changed = true;
            }
        }
        let mut sums = vec![vec![0.0; dim]; k];
        let mut counts = vec![0usize; k];
        for (i, p) in x.iter().enumerate() {
            counts[assign[i]] += 1;
            for d in 0..dim {
                sums[assign[i]][d] += p[d];
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                for d in 0..dim {
                    centers[c][d] = sums[c][d] / counts[c] as f64;
                }
            } else {
                // re-seed empty cluster at a random point
                centers[c] = x[rng.below(n)].clone();
            }
        }
        if !changed {
            break;
        }
    }
    centers
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn separates_two_obvious_blobs() {
        let mut x = Vec::new();
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            x.push(vec![rng.normal() * 0.1, rng.normal() * 0.1]);
            x.push(vec![10.0 + rng.normal() * 0.1, 10.0 + rng.normal() * 0.1]);
        }
        let c = kmeans(&x, 2, 30, 7);
        assert_eq!(c.len(), 2);
        let near_origin = c.iter().any(|p| p[0].abs() < 1.0 && p[1].abs() < 1.0);
        let near_ten = c.iter().any(|p| (p[0] - 10.0).abs() < 1.0 && (p[1] - 10.0).abs() < 1.0);
        assert!(near_origin && near_ten, "centres: {c:?}");
    }

    #[test]
    fn k_ge_n_returns_points() {
        let x = vec![vec![1.0], vec![2.0]];
        let c = kmeans(&x, 5, 10, 3);
        assert_eq!(c.len(), 2);
    }
}
