//! Synthetic stand-ins for the six UCI datasets of the paper's §6.2.
//!
//! This environment has no network access, so the real UCI files cannot be
//! fetched (DESIGN.md §Substitutions). Each generator reproduces the
//! dataset's **(n, d)** exactly and draws labels from a latent
//! GP-like score over correlated Gaussian features, with per-dataset
//! length-scale and label-noise chosen so classification difficulty lands
//! near the paper's reported error. What Tables 2–3 actually probe —
//! relative EP cost of k_se / k_pp3 / FIC at a given (n, d) and the fill
//! of the CS Cholesky at the hyperparameter mode — depends on (n, d,
//! geometry), which is preserved; absolute err/nlpd values are NOT
//! comparable to the paper and are flagged as such in EXPERIMENTS.md.

use crate::data::Dataset;
use crate::rng::Rng;

/// Specification of one synthetic UCI analogue.
#[derive(Clone, Copy, Debug)]
pub struct UciSpec {
    pub name: &'static str,
    pub n: usize,
    pub d: usize,
    /// Number of "informative" feature directions forming the score.
    pub informative: usize,
    /// Smoothing of the decision surface (bigger = easier problem).
    pub margin: f64,
    /// Label-flip noise.
    pub flip: f64,
}

/// The paper's Table 2 datasets (n/d from the paper).
pub const UCI_SPECS: [UciSpec; 6] = [
    UciSpec { name: "australian", n: 690, d: 14, informative: 6, margin: 1.0, flip: 0.08 },
    UciSpec { name: "breast", n: 683, d: 9, informative: 5, margin: 2.0, flip: 0.02 },
    UciSpec { name: "crabs", n: 200, d: 6, informative: 3, margin: 3.0, flip: 0.0 },
    UciSpec { name: "ionosphere", n: 351, d: 33, informative: 8, margin: 1.2, flip: 0.06 },
    UciSpec { name: "pima", n: 768, d: 8, informative: 4, margin: 0.7, flip: 0.15 },
    UciSpec { name: "sonar", n: 208, d: 60, informative: 10, margin: 1.0, flip: 0.08 },
];

/// Generate the synthetic analogue of a UCI dataset.
pub fn generate(spec: &UciSpec, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x0c1_dadau64.wrapping_mul(spec.n as u64));
    // correlated features: x = A z with a random mixing of `informative`
    // latent factors plus independent noise — mimics the redundancy of
    // real tabular data.
    let k = spec.informative.min(spec.d);
    let mixing: Vec<Vec<f64>> =
        (0..spec.d).map(|_| (0..k).map(|_| rng.normal() * 0.8).collect()).collect();
    // random nonlinear score weights over the latent factors
    let w1: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
    let w2: Vec<f64> = (0..k).map(|_| rng.normal() * 0.6).collect();
    let centers: Vec<Vec<f64>> =
        (0..3).map(|_| (0..k).map(|_| rng.normal() * 1.5).collect()).collect();

    let mut x = Vec::with_capacity(spec.n);
    let mut y = Vec::with_capacity(spec.n);
    for _ in 0..spec.n {
        let z: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
        let mut row: Vec<f64> = (0..spec.d)
            .map(|j| {
                let m: f64 = (0..k).map(|a| mixing[j][a] * z[a]).sum();
                m + rng.normal() * 0.5
            })
            .collect();
        // a mildly nonlinear, smooth score: linear + quadratic + RBF bumps
        let lin: f64 = (0..k).map(|a| w1[a] * z[a]).sum();
        let quad: f64 = (0..k).map(|a| w2[a] * (z[a] * z[a] - 1.0)).sum();
        let mut bumps = 0.0;
        for c in &centers {
            let d2: f64 = c.iter().zip(&z).map(|(a, b)| (a - b) * (a - b)).sum();
            bumps += (-0.5 * d2).exp();
        }
        let score = lin + 0.5 * quad + 2.0 * bumps - 2.0 * 0.6;
        let mut label = if score * spec.margin > 0.0 { 1.0 } else { -1.0 };
        if rng.uniform() < spec.flip {
            label = -label;
        }
        // store
        for v in row.iter_mut() {
            *v = (*v * 1000.0).round() / 1000.0; // UCI-like quantization
        }
        x.push(row);
        y.push(label);
    }
    let mut ds = Dataset { name: spec.name.to_string(), x, y };
    ds.standardize();
    ds
}

/// All six analogues.
pub fn all_datasets(seed: u64) -> Vec<Dataset> {
    UCI_SPECS.iter().map(|s| generate(s, seed)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_the_paper() {
        for spec in &UCI_SPECS {
            let d = generate(spec, 1);
            assert_eq!(d.n(), spec.n, "{}", spec.name);
            assert_eq!(d.dim(), spec.d, "{}", spec.name);
        }
    }

    #[test]
    fn labels_not_degenerate() {
        for spec in &UCI_SPECS {
            let d = generate(spec, 2);
            let rate = d.positive_rate();
            assert!(rate > 0.1 && rate < 0.9, "{}: rate {rate}", spec.name);
        }
    }

    #[test]
    fn features_standardized() {
        let d = generate(&UCI_SPECS[0], 3);
        for j in 0..d.dim() {
            let mean: f64 = d.x.iter().map(|r| r[j]).sum::<f64>() / d.n() as f64;
            let var: f64 = d.x.iter().map(|r| r[j] * r[j]).sum::<f64>() / d.n() as f64;
            assert!(mean.abs() < 1e-9);
            assert!((var - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn learnable_by_a_linear_probe() {
        // sanity: a trivial 1-NN on a train/test split should beat chance,
        // i.e. the labels depend on the features
        for spec in &UCI_SPECS {
            let d = generate(spec, 5);
            let (tr, te) = d.split(d.n() * 4 / 5);
            let mut correct = 0;
            for (xt, yt) in te.x.iter().zip(&te.y) {
                let mut best = 0usize;
                let mut best_d = f64::INFINITY;
                for (i, xr) in tr.x.iter().enumerate() {
                    let dist: f64 = xr.iter().zip(xt).map(|(a, b)| (a - b) * (a - b)).sum();
                    if dist < best_d {
                        best_d = dist;
                        best = i;
                    }
                }
                if tr.y[best] == *yt {
                    correct += 1;
                }
            }
            let acc = correct as f64 / te.n() as f64;
            assert!(acc > 0.55, "{}: 1-NN acc {acc}", spec.name);
        }
    }
}
