//! k-fold cross-validation with the paper's metrics (err, nlpd) —
//! Table 2 uses 10-fold CV.

use crate::data::Dataset;
use crate::gp::model::GpClassifier;
use crate::gp::predict::evaluate;
use crate::rng::Rng;
use std::time::Duration;

/// Per-fold and aggregate results.
#[derive(Clone, Debug)]
pub struct CvResult {
    pub err: f64,
    pub nlpd: f64,
    pub fold_err: Vec<f64>,
    pub fold_nlpd: Vec<f64>,
    /// Mean per-fold hyperparameter-optimization and single-EP times.
    pub opt_time: Duration,
    pub ep_time: Duration,
    pub fill_l: f64,
}

/// Deterministic fold assignment: shuffled indices chunked into k folds.
pub fn fold_indices(n: usize, k: usize, seed: u64) -> Vec<Vec<usize>> {
    let mut rng = Rng::new(seed);
    let idx = rng.permutation(n);
    let mut folds = vec![Vec::new(); k];
    for (pos, &i) in idx.iter().enumerate() {
        folds[pos % k].push(i);
    }
    folds
}

/// Run k-fold CV of `model` on `data`. `optimize` controls whether each
/// fold re-optimizes hyperparameters (the paper's protocol) or only runs
/// EP at the provided ones (cheaper; used in quick benches).
pub fn cross_validate(
    model: &GpClassifier,
    data: &Dataset,
    k: usize,
    optimize: bool,
    seed: u64,
) -> Result<CvResult, String> {
    let folds = fold_indices(data.n(), k, seed);
    let mut fold_err = Vec::with_capacity(k);
    let mut fold_nlpd = Vec::with_capacity(k);
    let mut opt_time = Duration::ZERO;
    let mut ep_time = Duration::ZERO;
    let mut fill_l = 0.0;
    for test_fold in folds.iter() {
        let test_set: std::collections::HashSet<usize> = test_fold.iter().copied().collect();
        let mut xtr = Vec::new();
        let mut ytr = Vec::new();
        let mut xte = Vec::new();
        let mut yte = Vec::new();
        for i in 0..data.n() {
            if test_set.contains(&i) {
                xte.push(data.x[i].clone());
                yte.push(data.y[i]);
            } else {
                xtr.push(data.x[i].clone());
                ytr.push(data.y[i]);
            }
        }
        let fitted = if optimize { model.fit(&xtr, &ytr)? } else { model.infer_only(&xtr, &ytr)? };
        let m = evaluate(&fitted.predict_latent_batch(&xte), &yte);
        fold_err.push(m.err);
        fold_nlpd.push(m.nlpd);
        opt_time += fitted.report.opt_time;
        ep_time += fitted.report.ep_time;
        fill_l += fitted.report.fill_l;
    }
    let kf = k as f64;
    Ok(CvResult {
        err: fold_err.iter().sum::<f64>() / kf,
        nlpd: fold_nlpd.iter().sum::<f64>() / kf,
        fold_err,
        fold_nlpd,
        opt_time: opt_time / k as u32,
        ep_time: ep_time / k as u32,
        fill_l: fill_l / kf,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::covariance::{CovFunction, CovKind};
    use crate::gp::model::Inference;
    use crate::sparse::ordering::Ordering;
    use crate::testutil::random_points;

    #[test]
    fn folds_partition_everything() {
        let folds = fold_indices(103, 10, 7);
        assert_eq!(folds.len(), 10);
        let mut seen = vec![false; 103];
        for f in &folds {
            for &i in f {
                assert!(!seen[i], "index {i} in two folds");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
        // balanced sizes
        assert!(folds.iter().all(|f| f.len() == 10 || f.len() == 11));
    }

    #[test]
    fn cv_runs_end_to_end() {
        let x = random_points(60, 2, 6.0, 55);
        let y: Vec<f64> = x.iter().map(|p| if p[0] > 3.0 { 1.0 } else { -1.0 }).collect();
        let data = Dataset { name: "toy".into(), x, y };
        let model = GpClassifier::new(
            CovFunction::new(CovKind::Pp(3), 2, 1.0, 2.0),
            Inference::Sparse(Ordering::Rcm),
        );
        let res = cross_validate(&model, &data, 5, false, 1).unwrap();
        assert_eq!(res.fold_err.len(), 5);
        assert!(res.err < 0.35, "CV err {}", res.err);
        assert!(res.nlpd.is_finite());
    }
}
