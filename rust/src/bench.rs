//! Minimal benchmark harness (criterion is not available offline).
//!
//! Benches under `benches/` are `harness = false` binaries that call
//! [`Bencher::run`] and print a fixed-width table; `cargo bench` therefore
//! emits exactly the rows each paper table/figure needs.

use std::time::{Duration, Instant};

/// Timing statistics over a set of measured iterations.
#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
    pub stddev: Duration,
    pub p50: Duration,
    pub p90: Duration,
    pub p99: Duration,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<Duration>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        let mean = total / n as u32;
        let mean_s = mean.as_secs_f64();
        let var = samples
            .iter()
            .map(|d| {
                let x = d.as_secs_f64() - mean_s;
                x * x
            })
            .sum::<f64>()
            / n as f64;
        // even n: midpoint of the two middle samples (samples[n/2] alone is
        // the *upper* middle and biases the median high)
        let median = if n % 2 == 1 {
            samples[n / 2]
        } else {
            (samples[n / 2 - 1] + samples[n / 2]) / 2
        };
        Stats {
            iters: n,
            mean,
            median,
            min: samples[0],
            max: samples[n - 1],
            stddev: Duration::from_secs_f64(var.sqrt()),
            p50: percentile_sorted(&samples, 50.0),
            p90: percentile_sorted(&samples, 90.0),
            p99: percentile_sorted(&samples, 99.0),
        }
    }
}

/// Nearest-rank percentile of an already-sorted sample set. The same
/// convention as `obs::Histogram::percentile_ns`, but exact (no bucketing):
/// rank ⌈p/100 · n⌉, clamped to [1, n].
pub fn percentile_sorted(sorted: &[Duration], p: f64) -> Duration {
    assert!(!sorted.is_empty());
    let n = sorted.len();
    let rank = ((p / 100.0) * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

/// Harness configuration: `warmup` unmeasured runs then up to `max_iters`
/// measured runs, stopping early once `max_time` has elapsed (always at
/// least one measured run).
pub struct Bencher {
    pub warmup: usize,
    pub max_iters: usize,
    pub max_time: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 1, max_iters: 20, max_time: Duration::from_secs(10) }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher { warmup: 1, max_iters: 5, max_time: Duration::from_secs(5) }
    }

    /// Measure `f`, which should perform one complete unit of work and
    /// return a value that we `std::hint::black_box` to keep the work alive.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> Stats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let start = Instant::now();
        let mut samples = Vec::new();
        for _ in 0..self.max_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
            if start.elapsed() > self.max_time {
                break;
            }
        }
        Stats::from_samples(samples)
    }
}

/// Machine-readable bench results: the perf benches push one record per
/// measured configuration and write a JSON file (hand-rolled — no external
/// crates) next to the printed table, so the perf trajectory is tracked
/// across PRs (`BENCH_parallel.json`, …).
pub mod report {
    use std::io::Write;
    use std::path::{Path, PathBuf};

    use super::Stats;

    /// One measured configuration.
    #[derive(Clone, Debug)]
    pub struct Record {
        /// Which loop was measured ("sweep", "gradient", "predict", …).
        pub bench: String,
        /// Which backend ran it ("cs", "csfic", …).
        pub backend: String,
        /// Problem size.
        pub n: usize,
        /// Pool width the measurement ran at.
        pub threads: usize,
        /// Median nanoseconds per iteration.
        pub ns_per_iter: f64,
        /// Extra per-record fields serialized alongside the fixed ones
        /// (e.g. the factor stage's `nnz_l` / `snodes` / `waves` /
        /// `max_wave_width` structure statistics). Values are emitted as
        /// JSON numbers; keys must be plain ASCII identifiers.
        pub extra: Vec<(String, f64)>,
    }

    /// Accumulates records and serializes them as a JSON array.
    pub struct Report {
        path: PathBuf,
        records: Vec<Record>,
    }

    impl Report {
        pub fn new(path: impl AsRef<Path>) -> Report {
            Report { path: path.as_ref().to_path_buf(), records: Vec::new() }
        }

        /// Record one measurement (median time of `stats`).
        pub fn push(&mut self, bench: &str, backend: &str, n: usize, threads: usize, stats: &Stats) {
            self.push_with(bench, backend, n, threads, stats, &[]);
        }

        /// [`Report::push`] with extra numeric fields attached to the
        /// record — how the factor stage reports per-ordering structure
        /// (`nnz_l`, supernode count, wave count, max wave width) next to
        /// its timing.
        pub fn push_with(
            &mut self,
            bench: &str,
            backend: &str,
            n: usize,
            threads: usize,
            stats: &Stats,
            extra: &[(&str, f64)],
        ) {
            self.records.push(Record {
                bench: bench.to_string(),
                backend: backend.to_string(),
                n,
                threads,
                ns_per_iter: stats.median.as_nanos() as f64,
                extra: extra.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
            });
        }

        /// Serialize every record. The field names are stable — downstream
        /// tooling diffs these files across PRs.
        pub fn write(&self) -> std::io::Result<()> {
            let mut f = std::fs::File::create(&self.path)?;
            writeln!(f, "[")?;
            for (i, r) in self.records.iter().enumerate() {
                let comma = if i + 1 < self.records.len() { "," } else { "" };
                let extra: String = r
                    .extra
                    .iter()
                    .map(|(k, v)| format!(", \"{}\": {}", json_escape(k), fmt_num(*v)))
                    .collect();
                writeln!(
                    f,
                    "  {{\"bench\": \"{}\", \"backend\": \"{}\", \"n\": {}, \
                     \"threads\": {}, \"ns_per_iter\": {:.1}{extra}}}{comma}",
                    json_escape(&r.bench),
                    json_escape(&r.backend),
                    r.n,
                    r.threads,
                    r.ns_per_iter,
                )?;
            }
            writeln!(f, "]")?;
            Ok(())
        }

        pub fn records(&self) -> &[Record] {
            &self.records
        }
    }

    /// Render an f64 as a JSON number: integral values drop the fraction
    /// (counts stay counts), non-finite values become null.
    fn fmt_num(v: f64) -> String {
        if !v.is_finite() {
            "null".to_string()
        } else if v == v.trunc() && v.abs() < 1e15 {
            format!("{}", v as i64)
        } else {
            format!("{v}")
        }
    }

    /// Minimal string escape (the names are library-controlled ASCII, but
    /// never emit structurally broken JSON).
    fn json_escape(s: &str) -> String {
        s.chars()
            .flat_map(|c| match c {
                '"' => "\\\"".chars().collect::<Vec<_>>(),
                '\\' => "\\\\".chars().collect(),
                c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
                c => vec![c],
            })
            .collect()
    }
}

/// Render seconds compactly: "1.234 s", "12.3 ms", "45.6 µs".
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} µs", s * 1e6)
    }
}

/// Print a markdown-ish table row with `|`-separated cells.
pub fn row(cells: &[String]) {
    println!("| {} |", cells.join(" | "));
}

/// Print a table header and separator.
pub fn header(cells: &[&str]) {
    println!("| {} |", cells.join(" | "));
    println!("|{}|", cells.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_known_samples() {
        let s = Stats::from_samples(vec![
            Duration::from_millis(1),
            Duration::from_millis(2),
            Duration::from_millis(3),
        ]);
        assert_eq!(s.iters, 3);
        assert_eq!(s.median, Duration::from_millis(2));
        assert_eq!(s.min, Duration::from_millis(1));
        assert_eq!(s.max, Duration::from_millis(3));
        assert_eq!(s.mean, Duration::from_millis(2));
        assert_eq!(s.p50, Duration::from_millis(2));
        assert_eq!(s.p99, Duration::from_millis(3));
    }

    /// Even sample counts take the midpoint of the two middle samples —
    /// `samples[n/2]` alone is the upper middle and biased the median high.
    #[test]
    fn even_sample_median_is_the_midpoint() {
        let s = Stats::from_samples(vec![
            Duration::from_millis(1),
            Duration::from_millis(2),
            Duration::from_millis(4),
            Duration::from_millis(8),
        ]);
        assert_eq!(s.median, Duration::from_millis(3));
        // nearest-rank percentiles stay actual samples
        assert_eq!(s.p50, Duration::from_millis(2));
        assert_eq!(s.p90, Duration::from_millis(8));
        assert_eq!(s.p99, Duration::from_millis(8));
    }

    #[test]
    fn percentile_sorted_nearest_rank() {
        let samples: Vec<Duration> = (1..=100).map(Duration::from_nanos).collect();
        assert_eq!(percentile_sorted(&samples, 50.0), Duration::from_nanos(50));
        assert_eq!(percentile_sorted(&samples, 90.0), Duration::from_nanos(90));
        assert_eq!(percentile_sorted(&samples, 99.0), Duration::from_nanos(99));
        assert_eq!(percentile_sorted(&samples, 0.0), Duration::from_nanos(1));
        assert_eq!(percentile_sorted(&samples, 100.0), Duration::from_nanos(100));
    }

    #[test]
    fn bencher_runs_and_respects_caps() {
        let b = Bencher { warmup: 0, max_iters: 3, max_time: Duration::from_secs(5) };
        let mut count = 0;
        let s = b.run(|| {
            count += 1;
            count
        });
        assert_eq!(s.iters, 3);
        assert_eq!(count, 3);
    }

    #[test]
    fn report_writes_stable_json() {
        let path = std::env::temp_dir().join("csgp-bench-report-test.json");
        let mut rep = report::Report::new(&path);
        let s = Stats::from_samples(vec![Duration::from_nanos(1500)]);
        rep.push("sweep", "cs", 4000, 4, &s);
        rep.push("pre\"dict", "csfic", 10, 1, &s);
        rep.push_with(
            "factor_nd",
            "cs",
            4000,
            8,
            &s,
            &[("nnz_l", 123456.0), ("max_wave_width", 41.0), ("frac", 0.25)],
        );
        rep.write().unwrap();
        assert_eq!(rep.records().len(), 3);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.trim_start().starts_with('['), "{text}");
        assert!(text.trim_end().ends_with(']'), "{text}");
        assert!(text.contains("\"bench\": \"sweep\""));
        assert!(text.contains("\"threads\": 4"));
        assert!(text.contains("\"ns_per_iter\": 1500.0"));
        assert!(text.contains("pre\\\"dict"), "quotes must be escaped: {text}");
        // extra fields: counts stay integral, fractions keep their point
        assert!(text.contains("\"nnz_l\": 123456"), "{text}");
        assert!(text.contains("\"max_wave_width\": 41"), "{text}");
        assert!(text.contains("\"frac\": 0.25"), "{text}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fmt_duration_ranges() {
        assert!(fmt_duration(Duration::from_secs(2)).ends_with(" s"));
        assert!(fmt_duration(Duration::from_millis(5)).ends_with(" ms"));
        assert!(fmt_duration(Duration::from_micros(5)).ends_with(" µs"));
    }
}
