//! Minimal benchmark harness (criterion is not available offline).
//!
//! Benches under `benches/` are `harness = false` binaries that call
//! [`Bencher::run`] and print a fixed-width table; `cargo bench` therefore
//! emits exactly the rows each paper table/figure needs.

use std::time::{Duration, Instant};

/// Timing statistics over a set of measured iterations.
#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
    pub stddev: Duration,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<Duration>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        let mean = total / n as u32;
        let mean_s = mean.as_secs_f64();
        let var = samples
            .iter()
            .map(|d| {
                let x = d.as_secs_f64() - mean_s;
                x * x
            })
            .sum::<f64>()
            / n as f64;
        Stats {
            iters: n,
            mean,
            median: samples[n / 2],
            min: samples[0],
            max: samples[n - 1],
            stddev: Duration::from_secs_f64(var.sqrt()),
        }
    }
}

/// Harness configuration: `warmup` unmeasured runs then up to `max_iters`
/// measured runs, stopping early once `max_time` has elapsed (always at
/// least one measured run).
pub struct Bencher {
    pub warmup: usize,
    pub max_iters: usize,
    pub max_time: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 1, max_iters: 20, max_time: Duration::from_secs(10) }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher { warmup: 1, max_iters: 5, max_time: Duration::from_secs(5) }
    }

    /// Measure `f`, which should perform one complete unit of work and
    /// return a value that we `std::hint::black_box` to keep the work alive.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> Stats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let start = Instant::now();
        let mut samples = Vec::new();
        for _ in 0..self.max_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
            if start.elapsed() > self.max_time {
                break;
            }
        }
        Stats::from_samples(samples)
    }
}

/// Render seconds compactly: "1.234 s", "12.3 ms", "45.6 µs".
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} µs", s * 1e6)
    }
}

/// Print a markdown-ish table row with `|`-separated cells.
pub fn row(cells: &[String]) {
    println!("| {} |", cells.join(" | "));
}

/// Print a table header and separator.
pub fn header(cells: &[&str]) {
    println!("| {} |", cells.join(" | "));
    println!("|{}|", cells.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_known_samples() {
        let s = Stats::from_samples(vec![
            Duration::from_millis(1),
            Duration::from_millis(2),
            Duration::from_millis(3),
        ]);
        assert_eq!(s.iters, 3);
        assert_eq!(s.median, Duration::from_millis(2));
        assert_eq!(s.min, Duration::from_millis(1));
        assert_eq!(s.max, Duration::from_millis(3));
        assert_eq!(s.mean, Duration::from_millis(2));
    }

    #[test]
    fn bencher_runs_and_respects_caps() {
        let b = Bencher { warmup: 0, max_iters: 3, max_time: Duration::from_secs(5) };
        let mut count = 0;
        let s = b.run(|| {
            count += 1;
            count
        });
        assert_eq!(s.iters, 3);
        assert_eq!(count, 3);
    }

    #[test]
    fn fmt_duration_ranges() {
        assert!(fmt_duration(Duration::from_secs(2)).ends_with(" s"));
        assert!(fmt_duration(Duration::from_millis(5)).ends_with(" ms"));
        assert!(fmt_duration(Duration::from_micros(5)).ends_with(" µs"));
    }
}
