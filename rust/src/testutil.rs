//! Shared randomized-test helpers (the crate's proptest substitute).
//!
//! Each helper is deterministic given a seed; property tests loop over many
//! seeds so failures are reproducible by seed number.

use crate::rng::Rng;
use crate::sparse::csc::CscMatrix;
use crate::sparse::ordering::Ordering;

/// The `CSGP_ORDERING` override the `Ordering::Auto` policy honors —
/// the CI hook that lets one run of the whole suite pin every
/// Auto-defaulted pipeline to a specific ordering (CI runs the suite
/// once under `CSGP_ORDERING=nd` so the nested-dissection paths cannot
/// rot). Explicitly requested orderings are never affected, and every
/// ordering is exact, so the override can only change structure, never
/// results. Returns `None` when the variable is unset, `auto`, or
/// unparsable — this is the same `parse_override` the resolution path
/// itself runs, so what this reports is what the pipelines do.
pub fn forced_ordering() -> Option<Ordering> {
    crate::sparse::ordering::auto::parse_override(std::env::var("CSGP_ORDERING").ok().as_deref())
}

/// Random sparse symmetric positive-definite matrix: a random sparse
/// symmetric pattern with `density` off-diagonal fill, values in
/// [-1, 1], made SPD by diagonal dominance.
pub fn random_sparse_spd(n: usize, density: f64, seed: u64) -> CscMatrix {
    let mut rng = Rng::new(seed.wrapping_mul(0x9e37_79b9).wrapping_add(17));
    let mut triplets = Vec::new();
    let mut row_sums = vec![0.0f64; n];
    for i in 0..n {
        for j in 0..i {
            if rng.uniform() < density {
                let v = rng.uniform_in(-1.0, 1.0);
                triplets.push((i, j, v));
                triplets.push((j, i, v));
                row_sums[i] += v.abs();
                row_sums[j] += v.abs();
            }
        }
    }
    for i in 0..n {
        triplets.push((i, i, row_sums[i] + 1.0 + rng.uniform()));
    }
    CscMatrix::from_triplets(n, n, &triplets)
}

/// Random dense vector with entries in [-1, 1].
pub fn random_vec(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed.wrapping_add(0xabcd));
    (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect()
}

/// Random geometric points in `[0, side]^d` — the kind of input geometry
/// the paper's CS covariance matrices come from.
pub fn random_points(n: usize, d: usize, side: f64, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Rng::new(seed.wrapping_add(0x5151));
    (0..n).map(|_| (0..d).map(|_| rng.uniform_in(0.0, side)).collect()).collect()
}

/// Assert two slices are elementwise close.
pub fn assert_close(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (k, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
            "{what}: element {k}: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The hook must report exactly what `Auto` resolution does in this
    /// process (unset locally; `nd` in the dedicated CI run) — checked
    /// against a real `order()` call, not a re-derivation of the parse.
    #[test]
    fn forced_ordering_matches_live_auto_resolution() {
        let a = random_sparse_spd(30, 0.2, 1);
        let resolved = crate::sparse::ordering::order(&a, Ordering::Auto, None).resolved;
        match forced_ordering() {
            Some(forced) => assert_eq!(resolved, forced, "override must drive resolution"),
            // no override: the policy answers RCM at this tiny n
            None => assert_eq!(resolved, Ordering::Rcm),
        }
    }

    #[test]
    fn spd_generator_is_spd_and_symmetric() {
        for seed in 0..5 {
            let a = random_sparse_spd(20, 0.3, seed);
            assert!(a.check());
            assert!(a.is_symmetric(0.0));
            assert!(a.to_dense().cholesky().is_ok(), "seed {seed} not SPD");
        }
    }
}
