//! Scoped, chunk-stealing worker pool for data-parallel loops.
//!
//! The coordinator's [`crate::coordinator::JobManager`] parallelizes
//! *across models*; this module parallelizes *within* one model run: the
//! per-site variance solves of a parallel-EP sweep, the Takahashi wave
//! columns of a gradient evaluation, index-backed covariance assembly and
//! batched prediction are all independent across sites / columns / test
//! points. Std threads + channels only (no external crates), one
//! process-wide pool shared by every caller.
//!
//! Design:
//!
//! * **Scoped.** [`for_chunks`] / [`map_indexed`] borrow their closures
//!   and outputs from the caller's stack; the caller participates in the
//!   work and does not return until every chunk is done *and* every pool
//!   worker has left the closure (entrant-counted revocation), so no
//!   `'static` bounds leak into the hot loops.
//! * **Chunk-stealing.** Work is split into contiguous chunks of at least
//!   `min_chunk` items; participants claim chunks from a shared atomic
//!   cursor, so an unlucky slow chunk does not idle the other workers.
//! * **Deterministic.** Each output slot is written by exactly one chunk
//!   and every item is computed from the same inputs as the serial loop,
//!   so results are bitwise-identical at any thread count (the property
//!   test in `rust/tests/integration.rs` pins this down).
//! * **Sized once.** The pool takes its default width from
//!   `CSGP_THREADS` (if set) or `std::thread::available_parallelism`.
//!   [`with_max_threads`] caps the width for parallel regions issued from
//!   the current thread — the bench and the thread-invariance tests use
//!   it to sweep widths inside one process. Workers are spawned lazily
//!   and only up to the widest request seen.
//! * **Observed, never steered.** With `CSGP_TRACE` on, every fanned-out
//!   region records per-chunk latencies, steal counts, caller wait time
//!   and per-participant busy spans through [`crate::obs`] — the data the
//!   chunk auto-tuning follow-on needs — but none of it feeds back into
//!   splitting or scheduling, so the width contract is untouched.
//!
//! Per-worker state (a `SparseSolveWorkspace`, a forked
//! `PredictWorkspace`, a dense scatter column, …) is created by the
//! `init` closure once per participant per call and reused across the
//! chunks that participant steals.

pub mod slice;

pub use slice::SyncSlice;

use std::cell::Cell;
use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering as AtomicOrdering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

use crate::obs;

/// Hard cap on pool workers, a backstop against absurd `CSGP_THREADS`
/// values or runaway `with_max_threads` requests.
const MAX_WORKERS: usize = 64;

/// Chunks per participant the splitter aims for — enough slack for
/// stealing to balance uneven chunks without drowning in cursor traffic.
const CHUNKS_PER_THREAD: usize = 4;

fn env_threads() -> Option<usize> {
    let raw = std::env::var("CSGP_THREADS").ok()?;
    raw.trim().parse::<usize>().ok().filter(|&k| k >= 1)
}

/// The process-wide default width: `CSGP_THREADS` if set (and >= 1),
/// otherwise the machine's available parallelism.
pub fn default_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        env_threads()
            .unwrap_or_else(|| std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1))
            .min(MAX_WORKERS)
    })
}

thread_local! {
    /// 0 = no override; otherwise the cap installed by `with_max_threads`.
    static THREAD_CAP: Cell<usize> = const { Cell::new(0) };
}

/// Width parallel regions issued from this thread will use.
pub fn current_threads() -> usize {
    let cap = THREAD_CAP.with(|c| c.get());
    if cap == 0 {
        default_threads()
    } else {
        cap
    }
}

/// Run `f` with parallel regions issued from this thread capped at `k`
/// participants (including the caller). `k = 1` forces the inline serial
/// path; `k` larger than the machine oversubscribes (the bench uses this
/// to measure 8-way scaling regardless of the host). The cap is
/// thread-local, so concurrent tests cannot race on it, and it is
/// restored even if `f` panics.
pub fn with_max_threads<R>(k: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            THREAD_CAP.with(|c| c.set(self.0));
        }
    }
    let prev = THREAD_CAP.with(|c| c.replace(k.clamp(1, MAX_WORKERS)));
    let _restore = Restore(prev);
    f()
}

// ---------------------------------------------------------------------------
// The pool: lazily spawned workers draining a shared queue of job handles.
// ---------------------------------------------------------------------------

/// Monomorphized trampoline to a borrowed `Fn()` — the type-erased form a
/// worker can call without generics or `dyn` lifetime erasure.
#[derive(Clone, Copy)]
struct RunPtr {
    data: *const (),
    call: unsafe fn(*const ()),
}

// SAFETY: the pointee is a `Sync` closure (enforced by `erase`) that the
// issuing thread keeps alive until every entrant has left (see `JobMsg`).
unsafe impl Send for RunPtr {}

fn erase<F: Fn() + Sync>(f: &F) -> RunPtr {
    unsafe fn call<F: Fn()>(p: *const ()) {
        // SAFETY: `p` was produced from `&F` by `erase` and the issuing
        // thread blocks in `revoke_and_wait` until this call returns.
        unsafe { (*(p as *const F))() }
    }
    RunPtr { data: f as *const F as *const (), call: call::<F> }
}

struct MsgState {
    run: Option<RunPtr>,
    entrants: usize,
}

/// One broadcast job handle. Workers *enter* under the lock (so the
/// pointer is only ever dereferenced by registered entrants), and the
/// issuing thread revokes the pointer and waits for `entrants == 0`
/// before its stack frame — which owns the closure — goes away.
struct JobMsg {
    state: Mutex<MsgState>,
    cv: Condvar,
    /// The issuer's effective width, installed as the worker's
    /// thread-local cap for the duration of the closure so nested
    /// parallel regions issued from a chunk body honour the same
    /// `with_max_threads` scope as the issuer.
    cap: usize,
}

impl JobMsg {
    fn new(run: RunPtr, cap: usize) -> JobMsg {
        JobMsg {
            state: Mutex::new(MsgState { run: Some(run), entrants: 0 }),
            cv: Condvar::new(),
            cap,
        }
    }

    /// Worker side: join the job if it is still live, run the
    /// participation closure (under the issuer's width cap), sign out.
    fn participate(&self) {
        let run = {
            let mut st = self.state.lock().unwrap();
            match st.run {
                Some(run) => {
                    st.entrants += 1;
                    run
                }
                None => return, // stale broadcast; the job already finished
            }
        };
        // The participation closure handles its own panics per chunk;
        // this outer catch keeps a worker thread alive no matter what.
        // SAFETY: entrant-registered above, so the closure is alive.
        let _ = catch_unwind(AssertUnwindSafe(|| {
            with_max_threads(self.cap, || unsafe { (run.call)(run.data) })
        }));
        let mut st = self.state.lock().unwrap();
        st.entrants -= 1;
        if st.entrants == 0 {
            self.cv.notify_all();
        }
    }

    /// Issuer side: stop new entrants, then wait out the current ones.
    fn revoke_and_wait(&self) {
        let mut st = self.state.lock().unwrap();
        st.run = None;
        while st.entrants > 0 {
            st = self.cv.wait(st).unwrap();
        }
    }
}

struct Pool {
    tx: Mutex<Sender<Arc<JobMsg>>>,
    rx: Arc<Mutex<Receiver<Arc<JobMsg>>>>,
    spawned: Mutex<usize>,
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| {
        let (tx, rx) = channel::<Arc<JobMsg>>();
        Pool { tx: Mutex::new(tx), rx: Arc::new(Mutex::new(rx)), spawned: Mutex::new(0) }
    })
}

impl Pool {
    /// Make sure at least `want` workers exist (lazy, monotone, capped);
    /// returns how many actually exist, so broadcasts never enqueue more
    /// copies than there are consumers (spawn failure must not leak
    /// messages into a channel no one drains).
    fn ensure_workers(&self, want: usize) -> usize {
        let want = want.min(MAX_WORKERS);
        let mut spawned = self.spawned.lock().unwrap();
        while *spawned < want {
            let rx = self.rx.clone();
            let name = format!("csgp-par-{}", *spawned);
            let res = std::thread::Builder::new().name(name).spawn(move || worker_loop(rx));
            if res.is_err() {
                // Degraded but correct: the caller participates in every
                // job, so fewer workers only means less parallelism.
                break;
            }
            *spawned += 1;
        }
        *spawned
    }

    fn broadcast(&self, msg: &Arc<JobMsg>, copies: usize) {
        let tx = self.tx.lock().unwrap();
        for _ in 0..copies {
            let _ = tx.send(msg.clone());
        }
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<Arc<JobMsg>>>>) {
    loop {
        let msg = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        match msg {
            Ok(m) => m.participate(),
            Err(_) => return, // channel closed: process is shutting down
        }
    }
}

// ---------------------------------------------------------------------------
// Primitives.
// ---------------------------------------------------------------------------

/// Run `body` over contiguous chunk ranges covering `0..n`.
///
/// Each participant (the caller plus up to `current_threads() - 1` pool
/// workers) builds its own state with `init` and steals chunks until the
/// cursor runs dry. Chunks hold at least `min_chunk` items. With one
/// thread (or one chunk) the body runs inline on the caller — the serial
/// path *is* the parallel path at width 1.
///
/// Panics in `body`/`init` are caught per chunk, the remaining chunks are
/// drained without executing, and the panic is re-raised on the caller
/// once every participant has left.
///
/// Disjoint output slots go through [`SyncSlice`]; per-participant
/// scratch comes from `init`:
///
/// ```
/// use csgp::par::{for_chunks, SyncSlice};
///
/// let n = 100;
/// let mut out = vec![0.0f64; n];
/// {
///     let slots = SyncSlice::new(&mut out);
///     for_chunks(n, 16, || /* per-participant state */ (), |_, range| {
///         for i in range {
///             // SAFETY: chunk ranges partition 0..n, so slot i is
///             // written by exactly this chunk.
///             unsafe { slots.set(i, (i * i) as f64) };
///         }
///     });
/// }
/// assert_eq!(out[7], 49.0);
/// ```
pub fn for_chunks<S, I, F>(n: usize, min_chunk: usize, init: I, body: F)
where
    I: Fn() -> S + Sync,
    F: Fn(&mut S, Range<usize>) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = current_threads();
    let min_chunk = min_chunk.max(1);
    if threads <= 1 || n <= min_chunk {
        let mut state = init();
        body(&mut state, 0..n);
        return;
    }
    let chunk = min_chunk.max(n.div_ceil(threads * CHUNKS_PER_THREAD));
    let n_chunks = n.div_ceil(chunk);
    if n_chunks <= 1 {
        let mut state = init();
        body(&mut state, 0..n);
        return;
    }

    let cursor = AtomicUsize::new(0);
    let poisoned = AtomicBool::new(false);
    let done = Mutex::new(0usize);
    let done_cv = Condvar::new();

    // Observation only: chunk timings, steal counts and per-participant
    // busy time never influence chunk splitting or scheduling (the
    // bitwise width contract must hold with tracing on, off, and mixed).
    let obs_counters = obs::counters_on();
    let obs_spans = obs::spans_on();
    let issuer_span = if obs_spans { obs::current_span_id() } else { 0 };
    let busy_max = AtomicU64::new(0);
    let busy_sum = AtomicU64::new(0);
    let busy_participants = AtomicUsize::new(0);

    let participate = |is_caller: bool| {
        // Workers parent their spans to the issuer's open span; the
        // caller's thread-local parent chain already points there.
        let _scope = if is_caller { None } else { Some(obs::parent_scope(issuer_span)) };
        let mut wspan: Option<obs::Span> = None;
        let mut busy_ns = 0u64;
        let mut chunks_run = 0u64;
        let mut state: Option<S> = None;
        loop {
            let c = cursor.fetch_add(1, AtomicOrdering::Relaxed);
            if c >= n_chunks {
                break;
            }
            // Fault injection (timing only): an armed `slowchunk` fault
            // stalls this chunk so tests can exercise the stealing /
            // imbalance paths. Results cannot change — the width contract.
            crate::fault::maybe_slow_chunk(c);
            let t_chunk = if obs_counters { Some(Instant::now()) } else { None };
            if obs_spans && wspan.is_none() {
                wspan = Some(obs::span("par.worker"));
            }
            let lo = c * chunk;
            let hi = ((c + 1) * chunk).min(n);
            let bad = if poisoned.load(AtomicOrdering::Relaxed) {
                true // drain the cursor without executing
            } else {
                catch_unwind(AssertUnwindSafe(|| {
                    let s = state.get_or_insert_with(&init);
                    body(s, lo..hi);
                }))
                .is_err()
            };
            if bad {
                poisoned.store(true, AtomicOrdering::Relaxed);
                state = None; // per-worker state may be mid-mutation
            }
            if let Some(t0) = t_chunk {
                let ns = t0.elapsed().as_nanos() as u64;
                busy_ns += ns;
                chunks_run += 1;
                obs::counters::POOL_CHUNK_NS.record_ns(ns);
            }
            let mut g = done.lock().unwrap();
            *g += 1;
            if *g == n_chunks {
                done_cv.notify_all();
            }
        }
        if chunks_run > 0 {
            obs::counters::POOL_CHUNKS.add(chunks_run);
            if !is_caller {
                obs::counters::POOL_STEALS.add(chunks_run);
            }
            obs::counters::POOL_BUSY_NS.add(busy_ns);
            busy_max.fetch_max(busy_ns, AtomicOrdering::Relaxed);
            busy_sum.fetch_add(busy_ns, AtomicOrdering::Relaxed);
            busy_participants.fetch_add(1, AtomicOrdering::Relaxed);
        }
        if let Some(mut s) = wspan {
            s.field_u64("chunks", chunks_run);
            s.field_u64("busy_ns", busy_ns);
            s.field_bool("stolen", !is_caller);
        }
    };

    let width = threads.min(n_chunks);
    let p = pool();
    let workers = p.ensure_workers(width - 1);
    let worker_run = || participate(false);
    let msg = Arc::new(JobMsg::new(erase(&worker_run), threads));
    p.broadcast(&msg, (width - 1).min(workers));

    participate(true); // the caller is always a participant

    {
        let t_wait = if obs_counters { Some(Instant::now()) } else { None };
        let mut g = done.lock().unwrap();
        while *g < n_chunks {
            g = done_cv.wait(g).unwrap();
        }
        drop(g);
        if let Some(t0) = t_wait {
            obs::counters::POOL_CALLER_WAIT_NS.add(t0.elapsed().as_nanos() as u64);
        }
    }
    // No worker may still be inside `participate` (it borrows this stack
    // frame) once we return.
    msg.revoke_and_wait();

    if obs_counters {
        let parts = busy_participants.load(AtomicOrdering::Relaxed) as u64;
        if parts > 1 {
            let mean = busy_sum.load(AtomicOrdering::Relaxed) / parts;
            if mean > 0 {
                let max = busy_max.load(AtomicOrdering::Relaxed);
                obs::counters::POOL_IMBALANCE_MAX_PERMILLE
                    .record(max.saturating_mul(1000) / mean);
            }
        }
    }

    if poisoned.load(AtomicOrdering::Relaxed) {
        panic!("csgp::par: a worker panicked inside a parallel region");
    }
}

/// Parallel indexed map: `out[i] = f(state, i)` for `i in 0..n`, with
/// per-participant state from `init`. Slot `i` is written by exactly one
/// chunk, so the result is identical to the serial map at any width.
///
/// `T: Default + Clone` keeps the output buffer initialized without any
/// `unsafe` length games; the defaults are overwritten slot by slot.
pub fn map_indexed<T, S, I, F>(n: usize, min_chunk: usize, init: I, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots = SyncSlice::new(&mut out);
        for_chunks(n, min_chunk, init, |state, range| {
            for i in range {
                let v = f(state, i);
                // SAFETY: chunk ranges partition 0..n, so slot i belongs
                // to exactly this chunk; in-bounds by construction.
                unsafe { slots.set(i, v) };
            }
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_indexed_matches_serial_at_every_width() {
        let n = 1000;
        let serial: Vec<f64> = (0..n).map(|i| (i as f64).sqrt() * 1.5).collect();
        for width in [1usize, 2, 3, 7, 16] {
            let par = with_max_threads(width, || {
                map_indexed(n, 8, || (), |_, i| (i as f64).sqrt() * 1.5)
            });
            assert_eq!(par, serial, "width {width}");
        }
    }

    #[test]
    fn for_chunks_covers_every_index_exactly_once() {
        let n = 513;
        for width in [1usize, 2, 5, 9] {
            let mut hits = vec![0u8; n];
            {
                let slots = SyncSlice::new(&mut hits);
                with_max_threads(width, || {
                    for_chunks(n, 7, || (), |_, range| {
                        for i in range {
                            // SAFETY: ranges are disjoint chunks of 0..n.
                            unsafe { slots.set(i, slots.get(i) + 1) };
                        }
                    });
                });
            }
            assert!(hits.iter().all(|&h| h == 1), "width {width}");
        }
    }

    #[test]
    fn per_worker_state_is_reused_not_shared() {
        // Each participant counts the items it processed in its own state;
        // the grand total must be n even though states never synchronize.
        let n = 4096;
        let total = std::sync::atomic::AtomicUsize::new(0);
        with_max_threads(4, || {
            for_chunks(
                n,
                16,
                || 0usize,
                |count, range| {
                    *count += range.len();
                    total.fetch_add(range.len(), AtomicOrdering::Relaxed);
                },
            );
        });
        assert_eq!(total.load(AtomicOrdering::Relaxed), n);
    }

    #[test]
    fn with_max_threads_nests_and_restores() {
        let outer = current_threads();
        with_max_threads(3, || {
            assert_eq!(current_threads(), 3);
            with_max_threads(1, || assert_eq!(current_threads(), 1));
            assert_eq!(current_threads(), 3);
        });
        assert_eq!(current_threads(), outer);
    }

    #[test]
    fn empty_and_tiny_inputs_are_fine() {
        assert_eq!(map_indexed(0, 4, || (), |_, i| i), Vec::<usize>::new());
        assert_eq!(map_indexed(1, 4, || (), |_, i| i + 41), vec![41]);
    }

    #[test]
    fn panics_propagate_from_serial_and_parallel_paths() {
        for width in [1usize, 4] {
            let caught = catch_unwind(AssertUnwindSafe(|| {
                with_max_threads(width, || {
                    for_chunks(100, 4, || (), |_, range| {
                        if range.contains(&37) {
                            panic!("boom");
                        }
                    });
                });
            }));
            assert!(caught.is_err(), "width {width} should propagate the panic");
        }
        // and the pool is still usable afterwards
        let v = with_max_threads(4, || map_indexed(64, 4, || (), |_, i| i * 2));
        assert_eq!(v[31], 62);
    }

    #[test]
    fn concurrent_callers_share_the_pool() {
        let handles: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    with_max_threads(3, || {
                        map_indexed(500, 8, || (), |_, i| i as u64 + t as u64).iter().sum::<u64>()
                    })
                })
            })
            .collect();
        for (t, h) in handles.into_iter().enumerate() {
            let want: u64 = (0..500u64).map(|i| i + t as u64).sum();
            assert_eq!(h.join().unwrap(), want);
        }
    }
}
