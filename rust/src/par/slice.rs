//! Shared-slice write handle for the worker pool's disjoint-slot writes.
//!
//! The pool's determinism contract is that every output slot is written by
//! exactly one chunk, so parallel results are bitwise-identical to the
//! serial loop. Rust's borrow checker cannot see "disjoint indices across
//! threads", so the hot loops coordinate through [`SyncSlice`]: a raw
//! view of a `&mut [T]` whose per-element accessors are `unsafe` with the
//! disjointness obligation stated at each call site.

use std::marker::PhantomData;

/// A `&mut [T]` that can be shared across pool workers for writes to
/// *disjoint* indices (and reads of indices no one is writing).
///
/// The lifetime keeps the underlying borrow alive, so the view can never
/// outlive the slice; all aliasing discipline is delegated to the
/// `unsafe` accessors.
pub struct SyncSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

// SAFETY: the accessors require callers to keep concurrent accesses to
// disjoint indices, which makes sharing the view across threads sound for
// `T: Send` (elements are only ever owned/written by one thread at a time).
unsafe impl<T: Send> Send for SyncSlice<'_, T> {}
unsafe impl<T: Send> Sync for SyncSlice<'_, T> {}

impl<'a, T> SyncSlice<'a, T> {
    pub fn new(slice: &'a mut [T]) -> SyncSlice<'a, T> {
        SyncSlice { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write `v` into slot `i` (dropping the previous value).
    ///
    /// # Safety
    ///
    /// `i < len()`, and no other thread reads or writes slot `i`
    /// concurrently (the pool's one-chunk-per-slot contract).
    #[inline]
    pub unsafe fn set(&self, i: usize, v: T) {
        debug_assert!(i < self.len);
        *self.ptr.add(i) = v;
    }

    /// Read slot `i`.
    ///
    /// # Safety
    ///
    /// `i < len()`, and no thread writes slot `i` concurrently. Reading
    /// slots written by *earlier* parallel phases (e.g. previous Takahashi
    /// waves, separated by the pool's completion barrier) is fine.
    #[inline]
    pub unsafe fn get(&self, i: usize) -> T
    where
        T: Copy,
    {
        debug_assert!(i < self.len);
        *self.ptr.add(i)
    }

    /// Borrow `len` contiguous slots starting at `i` as a shared slice —
    /// the blocked numeric kernels read finished source columns this way
    /// so the dense inner loops see real slices the compiler can
    /// autovectorize.
    ///
    /// # Safety
    ///
    /// `i + len <= len()`, and no thread writes any of those slots for
    /// the lifetime of the returned borrow (slots finished in earlier
    /// waves, behind the pool's completion barrier, qualify).
    #[inline]
    pub unsafe fn slice(&self, i: usize, len: usize) -> &[T] {
        debug_assert!(i.checked_add(len).is_some_and(|e| e <= self.len));
        std::slice::from_raw_parts(self.ptr.add(i), len)
    }

    /// Borrow `len` contiguous slots starting at `i` mutably — the blocked
    /// scatter-back writes a whole column in one `copy_from_slice`.
    ///
    /// # Safety
    ///
    /// `i + len <= len()`, and no other thread reads or writes any of
    /// those slots for the lifetime of the returned borrow (the pool's
    /// one-chunk-per-slot contract).
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, i: usize, len: usize) -> &mut [T] {
        debug_assert!(i.checked_add(len).is_some_and(|e| e <= self.len));
        std::slice::from_raw_parts_mut(self.ptr.add(i), len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disjoint_writes_land() {
        let mut v = vec![0.0f64; 100];
        {
            let s = SyncSlice::new(&mut v);
            assert_eq!(s.len(), 100);
            assert!(!s.is_empty());
            for i in 0..100 {
                // SAFETY: single-threaded, in-bounds.
                unsafe { s.set(i, i as f64) };
            }
            // SAFETY: no concurrent writes.
            assert_eq!(unsafe { s.get(7) }, 7.0);
        }
        assert!(v.iter().enumerate().all(|(i, &x)| x == i as f64));
    }
}
