//! Log₂-bucketed latency histograms: fixed-size, lock-free, const-init.
//!
//! One bucket per power of two of nanoseconds (64 buckets cover the whole
//! `u64` range), so recording is a `leading_zeros` plus three relaxed
//! atomic adds and a percentile query walks 64 slots. Percentiles are
//! therefore bucket-resolution estimates (within ~1.5× of the true
//! value) — exactly enough to tell a 2 µs chunk from a 2 ms one, which is
//! what the pool auto-tuning and serving-latency questions need. Exact
//! percentiles over raw samples stay in [`crate::bench::Stats`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use super::counters_on;

const BUCKETS: usize = 64;

/// A histogram of durations in log₂(ns) buckets, plus total count and
/// sum. All methods are lock-free; recording is gated on
/// [`counters_on`], so a disabled histogram costs one relaxed load.
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub const fn new() -> Histogram {
        Histogram {
            buckets: [const { AtomicU64::new(0) }; BUCKETS],
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }

    #[inline]
    fn bucket_of(ns: u64) -> usize {
        // floor(log2(max(ns, 1))): 0..=63
        (63 - (ns | 1).leading_zeros()) as usize
    }

    /// Record one latency in nanoseconds (no-op unless counters are on).
    #[inline]
    pub fn record_ns(&self, ns: u64) {
        if !counters_on() {
            return;
        }
        self.buckets[Self::bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Record one latency as a [`Duration`].
    #[inline]
    pub fn record(&self, d: Duration) {
        self.record_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum_ns(&self) -> u64 {
        self.sum_ns.load(Ordering::Relaxed)
    }

    /// Mean recorded latency in nanoseconds (0 when empty).
    pub fn mean_ns(&self) -> u64 {
        let n = self.count();
        if n == 0 {
            0
        } else {
            self.sum_ns() / n
        }
    }

    /// Nearest-rank percentile (`p` in 0..=100), reported as the midpoint
    /// of the winning bucket `[2^b, 2^(b+1))`. Returns 0 when empty.
    pub fn percentile_ns(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let rank = (((p / 100.0) * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (b, slot) in self.buckets.iter().enumerate() {
            seen += slot.load(Ordering::Relaxed);
            if seen >= rank {
                return (1u64 << b) + ((1u64 << b) >> 1);
            }
        }
        u64::MAX
    }

    /// [`Histogram::percentile_ns`] as a [`Duration`].
    pub fn percentile(&self, p: f64) -> Duration {
        Duration::from_nanos(self.percentile_ns(p))
    }

    /// Zero every bucket and the count/sum. Not atomic with respect to
    /// concurrent recording — callers reset between measurement windows.
    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum_ns.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::super::{with_mode, TraceMode};
    use super::*;

    #[test]
    fn buckets_cover_the_u64_range() {
        assert_eq!(Histogram::bucket_of(0), 0);
        assert_eq!(Histogram::bucket_of(1), 0);
        assert_eq!(Histogram::bucket_of(2), 1);
        assert_eq!(Histogram::bucket_of(3), 1);
        assert_eq!(Histogram::bucket_of(1024), 10);
        assert_eq!(Histogram::bucket_of(u64::MAX), 63);
    }

    #[test]
    fn percentiles_land_in_the_right_bucket() {
        let h = Histogram::new();
        with_mode(TraceMode::Counters, || {
            // 90 fast (~1 µs) + 10 slow (~1 ms) samples
            for _ in 0..90 {
                h.record_ns(1_000);
            }
            for _ in 0..10 {
                h.record_ns(1_000_000);
            }
            assert_eq!(h.count(), 100);
            assert_eq!(h.sum_ns(), 90 * 1_000 + 10 * 1_000_000);
            let p50 = h.percentile_ns(50.0);
            let p99 = h.percentile_ns(99.0);
            // bucket midpoints: 1000 -> [512, 1024) midpoint 768;
            // 1_000_000 -> [2^19, 2^20) midpoint 786432
            assert_eq!(p50, 768);
            assert_eq!(p99, 786_432);
            assert!(h.percentile_ns(0.0) <= p50 && p50 <= p99);
            h.reset();
            assert_eq!(h.count(), 0);
            assert_eq!(h.percentile_ns(50.0), 0);
        });
    }

    #[test]
    fn disabled_histogram_records_nothing() {
        let h = Histogram::new();
        with_mode(TraceMode::Off, || {
            h.record_ns(123);
            h.record(Duration::from_micros(5));
        });
        assert_eq!(h.count(), 0);
        assert_eq!(h.sum_ns(), 0);
    }
}
