//! Log₂-bucketed latency histograms: fixed-size, lock-free, const-init.
//!
//! One bucket per power of two of nanoseconds (64 buckets cover the whole
//! `u64` range), so recording is a `leading_zeros` plus a handful of
//! relaxed atomic ops and a percentile query walks 64 slots. Percentile
//! queries interpolate linearly inside the winning bucket and clamp to
//! the exact min/max seen, so tails stay honest even though storage is
//! log-bucketed — enough resolution to tell a 2 µs chunk from a 2 ms
//! one, which is what the pool auto-tuning and serving-latency questions
//! need. Exact percentiles over raw samples stay in
//! [`crate::bench::Stats`].
//!
//! Histograms [`merge`](Histogram::merge): the metrics exporter and
//! `csgp trace diff` combine per-window or per-run histograms without
//! losing tail resolution (bucket-wise addition, min/max folded).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use super::counters_on;

const BUCKETS: usize = 64;

/// A histogram of durations in log₂(ns) buckets, plus total count, sum,
/// and exact min/max. All methods are lock-free; recording is gated on
/// [`counters_on`], so a disabled histogram costs one relaxed load.
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_ns: AtomicU64,
    /// Smallest recorded value (`u64::MAX` while empty).
    min_ns: AtomicU64,
    /// Largest recorded value (0 while empty).
    max_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub const fn new() -> Histogram {
        Histogram {
            buckets: [const { AtomicU64::new(0) }; BUCKETS],
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            min_ns: AtomicU64::new(u64::MAX),
            max_ns: AtomicU64::new(0),
        }
    }

    #[inline]
    fn bucket_of(ns: u64) -> usize {
        // floor(log2(max(ns, 1))): 0..=63
        (63 - (ns | 1).leading_zeros()) as usize
    }

    /// Record one latency in nanoseconds (no-op unless counters are on).
    #[inline]
    pub fn record_ns(&self, ns: u64) {
        if !counters_on() {
            return;
        }
        self.buckets[Self::bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.min_ns.fetch_min(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    /// Record one latency as a [`Duration`].
    #[inline]
    pub fn record(&self, d: Duration) {
        self.record_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum_ns(&self) -> u64 {
        self.sum_ns.load(Ordering::Relaxed)
    }

    /// Mean recorded latency in nanoseconds (0 when empty).
    pub fn mean_ns(&self) -> u64 {
        let n = self.count();
        if n == 0 {
            0
        } else {
            self.sum_ns() / n
        }
    }

    /// Exact smallest recorded value in nanoseconds (0 when empty).
    pub fn min_ns(&self) -> u64 {
        let v = self.min_ns.load(Ordering::Relaxed);
        if v == u64::MAX {
            0
        } else {
            v
        }
    }

    /// Exact largest recorded value in nanoseconds (0 when empty).
    pub fn max_ns(&self) -> u64 {
        self.max_ns.load(Ordering::Relaxed)
    }

    /// Fold `other` into `self`: bucket-wise addition plus count/sum, with
    /// the exact min/max taken across both. Not gated on the trace mode —
    /// merging is aggregation (the metrics exporter combining windows,
    /// `trace diff` combining runs), not hot-path recording. Not atomic
    /// with respect to concurrent recording into `other`.
    pub fn merge(&self, other: &Histogram) {
        for (dst, src) in self.buckets.iter().zip(&other.buckets) {
            let v = src.load(Ordering::Relaxed);
            if v > 0 {
                dst.fetch_add(v, Ordering::Relaxed);
            }
        }
        let c = other.count.load(Ordering::Relaxed);
        if c == 0 {
            return;
        }
        self.count.fetch_add(c, Ordering::Relaxed);
        self.sum_ns.fetch_add(other.sum_ns.load(Ordering::Relaxed), Ordering::Relaxed);
        self.min_ns.fetch_min(other.min_ns.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max_ns.fetch_max(other.max_ns.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Nearest-rank percentile (`p` in 0..=100), interpolated linearly
    /// *within* the winning bucket `[2^b, 2^(b+1))` by the rank's position
    /// among that bucket's samples, then clamped to the exact observed
    /// `[min, max]`. Interpolation keeps percentiles monotone in `p` and
    /// removes the old bucket-edge bias (every percentile inside one
    /// bucket used to collapse to the same midpoint). Returns 0 when
    /// empty.
    pub fn percentile_ns(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let rank = (((p / 100.0) * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (b, slot) in self.buckets.iter().enumerate() {
            let c = slot.load(Ordering::Relaxed);
            if c > 0 && seen + c >= rank {
                let lo = if b == 0 { 0u64 } else { 1u64 << b };
                let hi = if b == BUCKETS - 1 { u64::MAX } else { 1u64 << (b + 1) };
                let frac = (rank - seen) as f64 / c as f64;
                let est = lo as f64 + frac * (hi - lo) as f64;
                return (est as u64).clamp(self.min_ns(), self.max_ns());
            }
            seen += c;
        }
        self.max_ns()
    }

    /// [`Histogram::percentile_ns`] as a [`Duration`].
    pub fn percentile(&self, p: f64) -> Duration {
        Duration::from_nanos(self.percentile_ns(p))
    }

    /// Zero every bucket and the count/sum. Not atomic with respect to
    /// concurrent recording — callers reset between measurement windows.
    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum_ns.store(0, Ordering::Relaxed);
        self.min_ns.store(u64::MAX, Ordering::Relaxed);
        self.max_ns.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::super::{with_mode, TraceMode};
    use super::*;

    #[test]
    fn buckets_cover_the_u64_range() {
        assert_eq!(Histogram::bucket_of(0), 0);
        assert_eq!(Histogram::bucket_of(1), 0);
        assert_eq!(Histogram::bucket_of(2), 1);
        assert_eq!(Histogram::bucket_of(3), 1);
        assert_eq!(Histogram::bucket_of(1024), 10);
        assert_eq!(Histogram::bucket_of(u64::MAX), 63);
    }

    #[test]
    fn percentiles_interpolate_within_the_bucket() {
        let h = Histogram::new();
        with_mode(TraceMode::Counters, || {
            // 90 fast (~1 µs) + 10 slow (~1 ms) samples
            for _ in 0..90 {
                h.record_ns(1_000);
            }
            for _ in 0..10 {
                h.record_ns(1_000_000);
            }
            assert_eq!(h.count(), 100);
            assert_eq!(h.sum_ns(), 90 * 1_000 + 10 * 1_000_000);
            assert_eq!(h.min_ns(), 1_000);
            assert_eq!(h.max_ns(), 1_000_000);
            let p50 = h.percentile_ns(50.0);
            let p99 = h.percentile_ns(99.0);
            // p50: rank 50 of 90 in [512, 1024) interpolates to ~796,
            // then the exact-min clamp pulls it to the true 1000 (the old
            // midpoint answer was 768, off by 23%)
            assert_eq!(p50, 1_000);
            // p99: rank 99 = 9th of 10 in [2^19, 2^20) -> 524288 + 0.9*524288
            assert_eq!(p99, 996_147);
            assert!((p99 as f64 - 1_000_000.0).abs() / 1_000_000.0 < 0.01);
            assert!(h.percentile_ns(0.0) <= p50 && p50 <= p99);
            assert!(h.percentile_ns(100.0) <= h.max_ns());
            h.reset();
            assert_eq!(h.count(), 0);
            assert_eq!(h.percentile_ns(50.0), 0);
            assert_eq!(h.min_ns(), 0);
            assert_eq!(h.max_ns(), 0);
        });
    }

    /// Uniform samples inside one bucket: interpolated percentiles are
    /// monotone and track the true quantiles far better than the bucket
    /// midpoint.
    #[test]
    fn interpolation_tracks_uniform_samples() {
        let h = Histogram::new();
        with_mode(TraceMode::Counters, || {
            for v in 1024..2048u64 {
                h.record_ns(v);
            }
            let mut prev = 0;
            for p in [10.0, 25.0, 50.0, 75.0, 90.0] {
                let got = h.percentile_ns(p);
                let want = 1024.0 + (p / 100.0) * 1024.0;
                assert!(
                    (got as f64 - want).abs() / want < 0.01,
                    "p{p}: got {got}, want ~{want}"
                );
                assert!(got >= prev, "percentiles must be monotone");
                prev = got;
            }
        });
    }

    #[test]
    fn merge_combines_without_losing_the_tail() {
        let a = Histogram::new();
        let b = Histogram::new();
        with_mode(TraceMode::Counters, || {
            for _ in 0..90 {
                a.record_ns(1_000);
            }
            for _ in 0..10 {
                b.record_ns(1_000_000);
            }
        });
        // merging is aggregation, not recording: works in any mode
        a.merge(&b);
        assert_eq!(a.count(), 100);
        assert_eq!(a.sum_ns(), 90 * 1_000 + 10 * 1_000_000);
        assert_eq!(a.min_ns(), 1_000);
        assert_eq!(a.max_ns(), 1_000_000);
        // the slow tail survives the merge at full resolution
        assert_eq!(a.percentile_ns(99.0), 996_147);
        // merging an empty histogram is a no-op
        let before = a.count();
        a.merge(&Histogram::new());
        assert_eq!(a.count(), before);
        assert_eq!(a.min_ns(), 1_000);
    }

    #[test]
    fn disabled_histogram_records_nothing() {
        let h = Histogram::new();
        with_mode(TraceMode::Off, || {
            h.record_ns(123);
            h.record(Duration::from_micros(5));
        });
        assert_eq!(h.count(), 0);
        assert_eq!(h.sum_ns(), 0);
        assert_eq!(h.min_ns(), 0);
        assert_eq!(h.max_ns(), 0);
    }
}
